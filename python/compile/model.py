"""L2 JAX compute graphs for the one-pass kernel clustering pipeline.

Each public function here is a fixed-shape jax computation that calls the
L1 Pallas kernels; python/compile/aot.py lowers them once to HLO text and
the rust coordinator (rust/src/runtime) loads and executes the artifacts
via the PJRT C API. Python is never on the request path.

Pipeline stages (Alg. 1 of the paper):
  gram_block         columns K[:, J] of the kernel matrix, on the fly
  precondition_block (H D) K[:, J]   -- SRHT preconditioning (step 2)
  kmeans_step        one Lloyd iteration over the embedding Y (step 7)

The small dense algebra between stages (QR of the n x r' sketch, the
r' x r' solve + Jacobi eigendecomposition, steps 3-6) lives in rust
(rust/src/lowrank) -- it is latency-bound and tiny, not worth a PJRT
round trip.
"""

import jax.numpy as jnp

from .kernels import fwht as _fwht
from .kernels import gram, kmeans


def gram_block(x, xb, *, kind="poly", gamma=0.0, degree=2, interpret=True):
    """Kernel-matrix column block K[:, J] = kappa(X, Xb), shape (n, b)."""
    if kind == "poly":
        return gram.gram_block_poly(
            x, xb, gamma=gamma, degree=degree, interpret=interpret)
    if kind == "rbf":
        return gram.gram_block_rbf(x, xb, gamma=gamma, interpret=interpret)
    raise ValueError(f"unknown kernel kind: {kind!r}")


def precondition_block(kb, d, *, interpret=True):
    """SRHT preconditioning of a column block: (H D) @ kb, shape (n, b).

    kb: (n, b) kernel columns (n a power of two, zero-padded upstream);
    d: (n,) Rademacher signs. The coordinator subsamples r' rows of the
    result to build the sketch W = (R^T H D K)^T one block at a time.
    """
    return _fwht(kb * d[:, None], interpret=interpret)


def gram_precondition_block(x, xb, d, *, kind="poly", gamma=0.0, degree=2,
                            interpret=True):
    """Fused stage: gram block + SRHT preconditioning in one HLO module.

    This is the production artifact for the sketch pass -- the (n, b)
    kernel block never leaves the device between the two stages.
    """
    kb = gram_block(x, xb, kind=kind, gamma=gamma, degree=degree,
                    interpret=interpret)
    return precondition_block(kb, d, interpret=interpret)


def kmeans_step(y, c, w, *, interpret=True):
    """One Lloyd iteration on the embedding. y (r, n), c (r, K), w (n,).

    Returns (assign (n,) int32, sums (K, r), counts (K,)). w masks padded
    columns out of the centroid statistics; the rust driver computes the
    new centroids sums/counts and handles empty clusters.
    """
    assign = kmeans.kmeans_assign(y, c, interpret=interpret)
    k = c.shape[1]
    onehot = (assign[None, :] == jnp.arange(k)[:, None]).astype(y.dtype)
    onehot = onehot * w[None, :]
    sums = jnp.dot(onehot, y.T)
    counts = jnp.sum(onehot, axis=1)
    return assign, sums, counts


def kmeans_objective(y, c, assign, w):
    """Masked K-means objective sum_i w_i ||y_i - c_{assign_i}||^2."""
    picked = c[:, assign]                      # (r, n)
    diff = y - picked
    return jnp.sum(w * jnp.sum(diff * diff, axis=0))
