"""AOT lowering: JAX/Pallas graphs -> HLO text artifacts + manifest.

Run once at build time (`make artifacts`); the rust runtime loads the
emitted `artifacts/*.hlo.txt` via `HloModuleProto::from_text_file` and
executes them on the PJRT CPU client. Interchange is HLO *text*, not
serialized protos: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 rejects; the text parser reassigns ids.

Each artifact is a fixed-shape compilation of one L2 graph. The manifest
(artifacts/manifest.json) records, per artifact: the op, the parameter
shapes/dtypes in call order, the output shapes, and the static params --
the rust ArtifactRegistry is driven entirely by this file.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_entry(s):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def artifact_configs():
    """The full artifact set: production shapes + test-scale shapes.

    Production: n padded to 4096 (two_rings n=4000, segmentation n=2310),
    column-block width b=256, embeddings r=2, K in {2, 7}.
    Test scale: n=256, b=64 -- loaded by `cargo test` for fast runtime
    integration tests.
    """
    cfgs = []

    def add(name, fn, args, params):
        cfgs.append({"name": name, "fn": fn, "args": args, "params": params})

    def gram(p, n, b, kind, gamma, degree):
        return (
            functools.partial(model.gram_block, kind=kind, gamma=gamma,
                              degree=degree),
            [_spec((p, n)), _spec((p, b))],
            {"op": "gram", "kind": kind, "gamma": gamma, "degree": degree,
             "p": p, "n": n, "b": b},
        )

    def sketch(p, n, b, kind, gamma, degree):
        return (
            functools.partial(model.gram_precondition_block, kind=kind,
                              gamma=gamma, degree=degree),
            [_spec((p, n)), _spec((p, b)), _spec((n,))],
            {"op": "sketch", "kind": kind, "gamma": gamma, "degree": degree,
             "p": p, "n": n, "b": b},
        )

    def precond(n, b):
        return (
            model.precondition_block,
            [_spec((n, b)), _spec((n,))],
            {"op": "precond", "n": n, "b": b},
        )

    def kstep(r, k, n):
        return (
            model.kmeans_step,
            [_spec((r, n)), _spec((r, k)), _spec((n,))],
            {"op": "kmeans_step", "r": r, "k": k, "n": n},
        )

    # --- production shapes ---
    for p in (2, 19):
        fn, args, params = gram(p, 4096, 256, "poly", 0.0, 2)
        add(f"gram_poly2h_p{p}_n4096_b256", fn, args, params)
        fn, args, params = sketch(p, 4096, 256, "poly", 0.0, 2)
        add(f"sketch_poly2h_p{p}_n4096_b256", fn, args, params)
    fn, args, params = gram(2, 4096, 256, "rbf", 2.0, 0)
    add("gram_rbf_p2_n4096_b256", fn, args, params)
    fn, args, params = sketch(2, 4096, 256, "rbf", 2.0, 0)
    add("sketch_rbf_p2_n4096_b256", fn, args, params)
    fn, args, params = precond(4096, 256)
    add("precond_n4096_b256", fn, args, params)
    for k in (2, 7):
        fn, args, params = kstep(2, k, 4096)
        add(f"kmeans_step_r2_k{k}_n4096", fn, args, params)

    # --- test scale (fast cargo-test integration) ---
    for p in (2, 4):
        fn, args, params = gram(p, 256, 64, "poly", 0.0, 2)
        add(f"gram_poly2h_p{p}_n256_b64", fn, args, params)
        fn, args, params = sketch(p, 256, 64, "poly", 0.0, 2)
        add(f"sketch_poly2h_p{p}_n256_b64", fn, args, params)
    fn, args, params = gram(2, 256, 64, "rbf", 2.0, 0)
    add("gram_rbf_p2_n256_b64", fn, args, params)
    fn, args, params = precond(256, 64)
    add("precond_n256_b64", fn, args, params)
    for k in (2, 3):
        fn, args, params = kstep(2, k, 256)
        add(f"kmeans_step_r2_k{k}_n256", fn, args, params)

    return cfgs



def lower_one(cfg):
    lowered = jax.jit(cfg["fn"]).lower(*cfg["args"])
    text = to_hlo_text(lowered)
    out_list = jax.tree_util.tree_leaves(lowered.out_info)
    return text, out_list


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact names to (re)build")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    manifest = []
    for cfg in artifact_configs():
        name = cfg["name"]
        if only is not None and name not in only:
            continue
        text, out_list = lower_one(cfg)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entry = {
            "name": name,
            "file": f"{name}.hlo.txt",
            "params": cfg["params"],
            "inputs": [_shape_entry(s) for s in cfg["args"]],
            "outputs": [{"shape": list(o.shape), "dtype": str(o.dtype)}
                        for o in out_list],
        }
        manifest.append(entry)
        print(f"  {name}: {len(text)} chars, "
              f"{len(entry['inputs'])} in / {len(entry['outputs'])} out")

    man_path = os.path.join(args.out_dir, "manifest.json")
    if only is not None and os.path.exists(man_path):
        with open(man_path) as f:
            old = {e["name"]: e for e in json.load(f)}
        for e in manifest:
            old[e["name"]] = e
        manifest = list(old.values())
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {man_path} ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
