"""L1 Pallas kernels: tiled gram-block computation.

The O(n^2 p) hot spot of kernel clustering is forming blocks of the kernel
matrix K[:, J] = kappa(X, X[:, J]). We tile the (n, b) output into
(tn, tb) blocks; each grid cell loads a (p, tn) slab of X and a (p, tb)
slab of the query block into VMEM, runs a single MXU-shaped matmul
(contraction over p), and applies the kernel nonlinearity elementwise.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the BlockSpecs express
the HBM->VMEM schedule; tn/tb default to 128 to match the MXU systolic
array's 128-lane geometry. On this image kernels run with interpret=True
(CPU PJRT cannot execute Mosaic custom-calls), which lowers to plain HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_poly_kernel(x_ref, y_ref, o_ref, *, gamma, degree):
    """One (tn, tb) tile: (X_tile^T @ Y_tile + gamma)^degree."""
    g = jnp.dot(x_ref[...].T, y_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = (g + gamma) ** degree


def _gram_rbf_kernel(x_ref, y_ref, o_ref, *, gamma):
    """One (tn, tb) tile: exp(-gamma * ||x_i - y_j||^2) via the norm trick."""
    x = x_ref[...]
    y = y_ref[...]
    g = jnp.dot(x.T, y, preferred_element_type=jnp.float32)
    xs = jnp.sum(x * x, axis=0)[:, None]
    ys = jnp.sum(y * y, axis=0)[None, :]
    o_ref[...] = jnp.exp(-gamma * (xs + ys - 2.0 * g))


def _tiled_gram(kernel, x, xb, tn, tb, interpret):
    p, n = x.shape
    pb, b = xb.shape
    assert p == pb, f"feature dims disagree: {p} vs {pb}"
    tn = min(tn, n)
    tb = min(tb, b)
    assert n % tn == 0 and b % tb == 0, (
        f"tile sizes must divide block shape: n={n} tn={tn} b={b} tb={tb}")
    grid = (n // tn, b // tb)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, tn), lambda i, j: (0, i)),
            pl.BlockSpec((p, tb), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tn, tb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, b), jnp.float32),
        interpret=interpret,
    )(x, xb)


def gram_block_poly(x, xb, *, gamma=0.0, degree=2, tn=128, tb=128,
                    interpret=True):
    """Polynomial-kernel gram block K = (X^T Xb + gamma)^degree, (n, b).

    gamma=0, degree=2 is the homogeneous quadratic kernel used for both
    the two-rings (Table 1) and image-segmentation (Fig. 3) experiments.
    """
    kernel = functools.partial(_gram_poly_kernel, gamma=float(gamma),
                               degree=int(degree))
    return _tiled_gram(kernel, x, xb, tn, tb, interpret)


def gram_block_rbf(x, xb, *, gamma=1.0, tn=128, tb=128, interpret=True):
    """Gaussian RBF gram block K = exp(-gamma ||x_i - xb_j||^2), (n, b)."""
    kernel = functools.partial(_gram_rbf_kernel, gamma=float(gamma))
    return _tiled_gram(kernel, x, xb, tn, tb, interpret)
