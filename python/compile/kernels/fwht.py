"""L1 Pallas kernel: fast Walsh-Hadamard transform butterfly stages.

The SRHT preconditioning K |-> (H D) K applies the unnormalized Hadamard
transform along the n-dimension of each column block. We implement the
classic iterative FWHT: log2(n) stages, stage h pairing element i with
i+h inside contiguous groups of 2h.

Scheduling: a naive one-group-per-grid-cell kernel gives a grid of
n/(2h) cells -- 2048 tiny steps at h=1 for n=4096, which is both slow on
the CPU interpret path and a poor VMEM schedule on TPU. Instead each
grid cell owns a (g * 2h, b) slab of `rows_per_block` rows (g butterfly
groups), reshapes it to (g, 2, h, b) in registers/VMEM, and performs all
g butterflies with two vectorized adds. The grid never exceeds
n / rows_per_block cells per stage.

On TPU this is the natural HBM->VMEM schedule (one slab resident per
step); with interpret=True the same kernel lowers to plain HLO for the
CPU PJRT client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _butterfly_kernel(x_ref, o_ref, *, h):
    """g = rows/(2h) butterfly groups: (a, c) -> (a + c, a - c)."""
    blk = x_ref[...]                     # (g * 2h, b)
    rows, b = blk.shape
    g = rows // (2 * h)
    v = blk.reshape(g, 2, h, b)
    a = v[:, 0]
    c = v[:, 1]
    out = jnp.stack([a + c, a - c], axis=1)
    o_ref[...] = out.reshape(rows, b)


def fwht_stage(x, h, *, rows_per_block=4096, interpret=True):
    """Apply the stride-h butterfly stage to x (n, b) along axis 0."""
    n, b = x.shape
    assert n % (2 * h) == 0, f"stage h={h} invalid for n={n}"
    rows = max(2 * h, min(n, rows_per_block))
    rows -= rows % (2 * h)               # multiple of the group size
    grid = (n // rows,)
    kernel = functools.partial(_butterfly_kernel, h=h)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, b), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b), jnp.float32),
        interpret=interpret,
    )(x)


def fwht(x, *, rows_per_block=4096, interpret=True):
    """Unnormalized FWHT along axis 0 of x (n, b); n must be a power of two.

    Composes log2(n) Pallas butterfly stages; XLA fuses the interpret-mode
    lowering into one module. Matches ref.fwht_ref (explicit H matmul).
    """
    n = x.shape[0]
    assert n > 0 and (n & (n - 1)) == 0, "n must be a power of two"
    h = 1
    while h < n:
        x = fwht_stage(x, h, rows_per_block=rows_per_block,
                       interpret=interpret)
        h *= 2
    return x
