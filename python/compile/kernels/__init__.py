"""L1 Pallas kernels (build-time only; lowered into HLO artifacts)."""

from .gram import gram_block_poly, gram_block_rbf
from .fwht import fwht, fwht_stage
from .kmeans import kmeans_assign
from . import ref

__all__ = [
    "gram_block_poly", "gram_block_rbf", "fwht", "fwht_stage",
    "kmeans_assign", "ref",
]
