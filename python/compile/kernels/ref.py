"""Pure-jnp reference oracles for the Pallas kernels.

Everything here is deliberately naive and obviously-correct; the pytest
suite asserts the Pallas kernels (gram.py / fwht.py / kmeans.py) match
these references to float32 tolerance across shape sweeps.
"""

import jax.numpy as jnp
import numpy as np


def gram_poly_ref(x, xb, gamma: float = 0.0, degree: int = 2):
    """Polynomial-kernel gram block: K[i, j] = (<x_i, xb_j> + gamma)^degree.

    x: (p, n) data matrix, xb: (p, b) block of query points -> (n, b).
    gamma = 0 gives the homogeneous polynomial kernel used in the paper.
    """
    return (jnp.dot(x.T, xb) + gamma) ** degree


def gram_rbf_ref(x, xb, gamma: float = 1.0):
    """Gaussian RBF gram block: K[i, j] = exp(-gamma * ||x_i - xb_j||^2)."""
    xs = jnp.sum(x * x, axis=0)[:, None]
    ys = jnp.sum(xb * xb, axis=0)[None, :]
    cross = jnp.dot(x.T, xb)
    return jnp.exp(-gamma * (xs + ys - 2.0 * cross))


def hadamard_matrix(n: int) -> np.ndarray:
    """Unnormalized Walsh-Hadamard matrix H_n (n must be a power of two).

    H[i, j] = (-1)^{popcount(i & j)}; H is symmetric and H @ H = n * I.
    """
    assert n > 0 and (n & (n - 1)) == 0, "n must be a power of two"
    h = np.array([[1.0]], dtype=np.float64)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return h


def fwht_ref(x):
    """Unnormalized FWHT applied along axis 0 of x (n, b) via explicit H."""
    n = x.shape[0]
    h = jnp.asarray(hadamard_matrix(n), dtype=x.dtype)
    return h @ x


def precondition_ref(kb, d):
    """Reference for the SRHT preconditioning of a block of kernel columns.

    kb: (n, b) block of columns of K; d: (n,) Rademacher signs.
    Returns (H D) @ kb, the preconditioned block whose rows the coordinator
    subsamples (Alg. 1 step 2: W = (R^T H D K)^T, row-sampling done in rust).
    """
    return fwht_ref(kb * d[:, None])


def kmeans_assign_ref(y, c):
    """Nearest-centroid assignment. y: (r, n) points, c: (r, K) centroids.

    Returns int32 (n,) of argmin_k ||y_i - c_k||^2. The ||y||^2 term is
    constant in k and omitted, matching the Pallas kernel.
    """
    cross = jnp.dot(y.T, c)
    cn = jnp.sum(c * c, axis=0)[None, :]
    return jnp.argmin(cn - 2.0 * cross, axis=1).astype(jnp.int32)


def kmeans_step_ref(y, c, w):
    """One Lloyd step. y: (r, n), c: (r, K), w: (n,) 0/1 validity mask.

    Returns (assign (n,) int32, sums (K, r) masked per-cluster coordinate
    sums, counts (K,) masked member counts). Padded columns (w == 0) still
    receive an assignment but contribute nothing to sums/counts.
    """
    assign = kmeans_assign_ref(y, c)
    k = c.shape[1]
    onehot = (assign[None, :] == jnp.arange(k)[:, None]).astype(y.dtype)
    onehot = onehot * w[None, :]
    sums = jnp.dot(onehot, y.T)          # (K, r)
    counts = jnp.sum(onehot, axis=1)     # (K,)
    return assign, sums, counts
