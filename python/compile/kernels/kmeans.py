"""L1 Pallas kernel: K-means assignment step over the embedded points.

After the one-pass recovery, clustering runs on Y (r, n) with r tiny
(r = 2 in the paper). The assignment step is the O(n K r) hot loop; we
tile n and keep the full (r, K) centroid block in VMEM per grid cell.
The distance uses ||y - c||^2 = ||y||^2 - 2 y.c + ||c||^2 and drops the
||y||^2 term (constant in k), matching kernels/ref.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(y_ref, c_ref, o_ref):
    """One tile of tn points: argmin_k of (||c_k||^2 - 2 y^T c_k)."""
    y = y_ref[...]
    c = c_ref[...]
    cross = jnp.dot(y.T, c, preferred_element_type=jnp.float32)  # (tn, K)
    cn = jnp.sum(c * c, axis=0)[None, :]
    o_ref[...] = jnp.argmin(cn - 2.0 * cross, axis=1).astype(jnp.int32)


def kmeans_assign(y, c, *, tn=1024, interpret=True):
    """Nearest-centroid assignment: y (r, n), c (r, K) -> int32 (n,)."""
    r, n = y.shape
    rc, k = c.shape
    assert r == rc, f"embedding dims disagree: {r} vs {rc}"
    tn = min(tn, n)
    assert n % tn == 0, f"tile tn={tn} must divide n={n}"
    grid = (n // tn,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r, tn), lambda i: (0, i)),
            pl.BlockSpec((r, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(y, c)
