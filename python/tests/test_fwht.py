"""Pallas FWHT butterfly kernel vs the explicit-Hadamard oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fwht import fwht, fwht_stage

SETTINGS = dict(max_examples=25, deadline=None)


@settings(**SETTINGS)
@given(
    logn=st.integers(1, 9),
    b=st.integers(1, 8),
    rpb=st.sampled_from([2, 64, 1024, 4096]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fwht_matches_explicit_hadamard(logn, b, rpb, seed):
    n = 1 << logn
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, b)).astype(np.float32)
    got = fwht(x, rows_per_block=max(2, min(rpb, n)))
    want = ref.fwht_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-4,
                               atol=1e-4 * np.abs(want).max())


@settings(**SETTINGS)
@given(logn=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_fwht_involution(logn, seed):
    """H (H x) = n x for the unnormalized transform."""
    n = 1 << logn
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, 3)).astype(np.float32)
    twice = np.asarray(fwht(np.asarray(fwht(x))))
    np.testing.assert_allclose(twice, n * x, rtol=1e-4,
                               atol=1e-4 * n * np.abs(x).max())


@settings(**SETTINGS)
@given(logn=st.integers(1, 7), seed=st.integers(0, 2**31 - 1))
def test_fwht_is_linear(logn, seed):
    n = 1 << logn
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, 2)).astype(np.float32)
    y = rng.standard_normal((n, 2)).astype(np.float32)
    lhs = np.asarray(fwht(2.0 * x + 3.0 * y))
    rhs = 2.0 * np.asarray(fwht(x)) + 3.0 * np.asarray(fwht(y))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-3)


def test_fwht_preserves_energy():
    """||H x||^2 = n ||x||^2 (Parseval for the unnormalized transform)."""
    rng = np.random.default_rng(11)
    n = 256
    x = rng.standard_normal((n, 5)).astype(np.float32)
    hx = np.asarray(fwht(x), dtype=np.float64)
    np.testing.assert_allclose((hx * hx).sum(axis=0),
                               n * (x.astype(np.float64) ** 2).sum(axis=0),
                               rtol=1e-5)


def test_fwht_first_row_is_column_sum():
    rng = np.random.default_rng(12)
    x = rng.standard_normal((128, 4)).astype(np.float32)
    hx = np.asarray(fwht(x))
    np.testing.assert_allclose(hx[0], x.sum(axis=0), rtol=1e-4, atol=1e-4)


def test_single_stage_butterfly():
    x = np.array([[1.0], [2.0], [3.0], [4.0]], np.float32)
    got = np.asarray(fwht_stage(x, 1))
    want = np.array([[3.0], [-1.0], [7.0], [-1.0]], np.float32)
    np.testing.assert_allclose(got, want)


def test_fwht_n1_identity():
    x = np.array([[5.0, -2.0]], np.float32)
    np.testing.assert_allclose(np.asarray(fwht(x)), x)
