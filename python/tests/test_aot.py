"""AOT emission: every artifact config lowers to parseable HLO text and the
manifest agrees with the lowered computation's signature."""

import json
import os

import numpy as np
import pytest

from compile import aot

CFGS = {c["name"]: c for c in aot.artifact_configs()}
TEST_SCALE = [n for n in CFGS if "n256" in n]


def test_configs_are_unique_and_complete():
    names = [c["name"] for c in aot.artifact_configs()]
    assert len(names) == len(set(names))
    # every op family is represented at production scale
    for needle in ("gram_poly2h_p2_n4096", "gram_poly2h_p19_n4096",
                   "sketch_poly2h_p19_n4096", "precond_n4096",
                   "kmeans_step_r2_k2_n4096", "kmeans_step_r2_k7_n4096"):
        assert any(needle in n for n in names), needle


@pytest.mark.parametrize("name", TEST_SCALE)
def test_lowering_emits_hlo_entry(name):
    text, outs = aot.lower_one(CFGS[name])
    assert "ENTRY" in text and "HloModule" in text
    assert len(outs) >= 1


def test_lowered_shapes_match_manifest_declaration():
    cfg = CFGS["kmeans_step_r2_k3_n256"]
    _, outs = aot.lower_one(cfg)
    shapes = [tuple(o.shape) for o in outs]
    assert shapes == [(256,), (3, 2), (3,)]
    dtypes = [str(o.dtype) for o in outs]
    assert dtypes == ["int32", "float32", "float32"]


def test_gram_artifact_numerics_via_jit():
    """Executing the exact graph that gets lowered reproduces the oracle."""
    import jax
    from compile.kernels import ref
    cfg = CFGS["gram_poly2h_p4_n256_b64"]
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 256)).astype(np.float32)
    xb = rng.standard_normal((4, 64)).astype(np.float32)
    got = np.asarray(jax.jit(cfg["fn"])(x, xb))
    want = np.asarray(ref.gram_poly_ref(x, xb))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_manifest_written(tmp_path):
    import subprocess
    import sys
    out = tmp_path / "arts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--only", "precond_n256_b64"],
        check=True, cwd=os.path.dirname(os.path.dirname(__file__)))
    man = json.loads((out / "manifest.json").read_text())
    assert len(man) == 1
    entry = man[0]
    assert entry["name"] == "precond_n256_b64"
    assert entry["inputs"][0]["shape"] == [256, 64]
    assert (out / entry["file"]).exists()
