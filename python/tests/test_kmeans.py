"""Pallas K-means assignment kernel and the L2 Lloyd step vs oracles."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import kmeans, ref

SETTINGS = dict(max_examples=25, deadline=None)


@settings(**SETTINGS)
@given(
    r=st.integers(1, 8),
    k=st.integers(1, 9),
    nt=st.integers(1, 6),
    tile=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_assign_matches_ref(r, k, nt, tile, seed):
    n = nt * tile
    rng = np.random.default_rng(seed)
    y = rng.standard_normal((r, n)).astype(np.float32)
    c = rng.standard_normal((r, k)).astype(np.float32)
    got = np.asarray(kmeans.kmeans_assign(y, c, tn=tile))
    want = np.asarray(ref.kmeans_assign_ref(y, c))
    # ties between centroids may break differently; compare distances
    d_got = ((y - c[:, got]) ** 2).sum(axis=0)
    d_want = ((y - c[:, want]) ** 2).sum(axis=0)
    np.testing.assert_allclose(d_got, d_want, rtol=1e-4, atol=1e-5)


def test_assign_exact_on_separated_clusters():
    rng = np.random.default_rng(0)
    c = np.array([[0.0, 100.0], [0.0, 100.0]], np.float32)  # (r=2, K=2)
    labels = rng.integers(0, 2, 128)
    y = c[:, labels] + 0.1 * rng.standard_normal((2, 128)).astype(np.float32)
    got = np.asarray(kmeans.kmeans_assign(y, c, tn=64))
    np.testing.assert_array_equal(got, labels)


@settings(**SETTINGS)
@given(
    k=st.integers(1, 7),
    pad=st.integers(0, 60),
    seed=st.integers(0, 2**31 - 1),
)
def test_kmeans_step_masks_padding(k, pad, seed):
    """Padded columns must not contribute to sums/counts."""
    n = 128
    rng = np.random.default_rng(seed)
    y = rng.standard_normal((2, n)).astype(np.float32)
    y[:, n - pad:] = 0.0  # padded embedding columns are zero
    c = rng.standard_normal((2, k)).astype(np.float32)
    w = np.ones(n, np.float32)
    if pad:
        w[n - pad:] = 0.0
    assign, sums, counts = (np.asarray(o) for o in model.kmeans_step(y, c, w))
    ra, rs, rc = (np.asarray(o) for o in ref.kmeans_step_ref(y, c, w))
    np.testing.assert_allclose(sums, rs, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(counts, rc)
    assert counts.sum() == n - pad
    # recompute sums from the masked assignment directly
    manual = np.zeros_like(sums)
    for i in range(n - pad):
        manual[assign[i]] += y[:, i]
    np.testing.assert_allclose(sums, manual, rtol=1e-4, atol=1e-4)


def test_kmeans_objective_matches_manual():
    rng = np.random.default_rng(5)
    y = rng.standard_normal((3, 64)).astype(np.float32)
    c = rng.standard_normal((3, 4)).astype(np.float32)
    w = np.ones(64, np.float32)
    w[50:] = 0.0
    assign = np.asarray(ref.kmeans_assign_ref(y, c))
    got = float(model.kmeans_objective(y, c, assign, w))
    want = sum(((y[:, i] - c[:, assign[i]]) ** 2).sum() for i in range(50))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_lloyd_iterations_decrease_objective():
    """Full Lloyd loop through the L2 step must monotonically improve."""
    rng = np.random.default_rng(9)
    centers = rng.standard_normal((2, 3)).astype(np.float32) * 4
    labels = rng.integers(0, 3, 256)
    y = (centers[:, labels]
         + 0.3 * rng.standard_normal((2, 256))).astype(np.float32)
    w = np.ones(256, np.float32)
    c = y[:, :3].copy()
    prev = np.inf
    for _ in range(8):
        assign, sums, counts = model.kmeans_step(y, c, w)
        obj = float(model.kmeans_objective(y, c, np.asarray(assign), w))
        assert obj <= prev + 1e-3
        prev = obj
        counts = np.maximum(np.asarray(counts), 1e-9)
        c = (np.asarray(sums) / counts[:, None]).T.astype(np.float32)
