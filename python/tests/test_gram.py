"""Pallas gram-block kernel vs the pure-jnp oracle (kernels/ref.py)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gram, ref

SETTINGS = dict(max_examples=25, deadline=None)


def _data(p, n, b, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((p, n)).astype(np.float32)
    xb = rng.standard_normal((p, b)).astype(np.float32)
    return x, xb


@settings(**SETTINGS)
@given(
    p=st.integers(1, 24),
    nt=st.integers(1, 4),
    bt=st.integers(1, 4),
    tile=st.sampled_from([8, 16, 32]),
    degree=st.sampled_from([1, 2, 3]),
    gamma=st.sampled_from([0.0, 0.5, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_poly_matches_ref(p, nt, bt, tile, degree, gamma, seed):
    n, b = nt * tile, bt * tile
    x, xb = _data(p, n, b, seed)
    got = gram.gram_block_poly(x, xb, gamma=gamma, degree=degree,
                               tn=tile, tb=tile)
    want = ref.gram_poly_ref(x, xb, gamma=gamma, degree=degree)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    p=st.integers(1, 24),
    nt=st.integers(1, 4),
    bt=st.integers(1, 4),
    tile=st.sampled_from([8, 16, 32]),
    gamma=st.sampled_from([0.1, 1.0, 2.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_rbf_matches_ref(p, nt, bt, tile, gamma, seed):
    n, b = nt * tile, bt * tile
    x, xb = _data(p, n, b, seed)
    got = gram.gram_block_rbf(x, xb, gamma=gamma, tn=tile, tb=tile)
    want = ref.gram_rbf_ref(x, xb, gamma=gamma)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_gram_poly_homogeneous_is_paper_kernel():
    """gamma=0, d=2 must equal <x, y>^2 exactly (the paper's kernel)."""
    x, xb = _data(5, 32, 16, 7)
    got = np.asarray(gram.gram_block_poly(x, xb, gamma=0.0, degree=2,
                                          tn=16, tb=16))
    want = np.dot(x.T, xb) ** 2
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_gram_block_of_self_is_symmetric_psd():
    """K = gram(X, X) must be symmetric PSD for the poly kernel."""
    rng = np.random.default_rng(3)
    x = rng.standard_normal((4, 64)).astype(np.float32)
    k = np.asarray(gram.gram_block_poly(x, x, gamma=0.0, degree=2,
                                        tn=32, tb=32), dtype=np.float64)
    np.testing.assert_allclose(k, k.T, atol=1e-4)
    evals = np.linalg.eigvalsh((k + k.T) / 2)
    assert evals.min() > -1e-3 * max(1.0, evals.max())


def test_gram_rbf_diagonal_is_one():
    rng = np.random.default_rng(4)
    x = rng.standard_normal((6, 32)).astype(np.float32)
    k = np.asarray(gram.gram_block_rbf(x, x, gamma=0.8, tn=16, tb=16))
    np.testing.assert_allclose(np.diag(k), np.ones(32), rtol=1e-5)
    assert k.max() <= 1.0 + 1e-5


def test_gram_rejects_mismatched_feature_dims():
    x = np.zeros((3, 16), np.float32)
    xb = np.zeros((4, 16), np.float32)
    with pytest.raises(AssertionError):
        gram.gram_block_poly(x, xb, tn=16, tb=16)


def test_gram_rejects_nondividing_tiles():
    x = np.zeros((3, 24), np.float32)
    xb = np.zeros((3, 24), np.float32)
    with pytest.raises(AssertionError):
        gram.gram_block_poly(x, xb, tn=16, tb=16)
