"""L2 model graphs: SRHT preconditioning properties + fused-stage equality."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SETTINGS = dict(max_examples=15, deadline=None)


def _rademacher(n, rng):
    return rng.choice([-1.0, 1.0], size=n).astype(np.float32)


@settings(**SETTINGS)
@given(
    logn=st.integers(2, 9),
    b=st.sampled_from([1, 4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_precondition_matches_ref(logn, b, seed):
    n = 1 << logn
    rng = np.random.default_rng(seed)
    kb = rng.standard_normal((n, b)).astype(np.float32)
    d = _rademacher(n, rng)
    got = np.asarray(model.precondition_block(kb, d))
    want = np.asarray(ref.precondition_ref(kb, d))
    np.testing.assert_allclose(got, want, rtol=1e-4,
                               atol=1e-4 * np.abs(want).max())


@settings(**SETTINGS)
@given(
    p=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_sketch_equals_composition(p, seed):
    """gram_precondition_block == precondition_block(gram_block(.))."""
    n, b = 128, 32
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((p, n)).astype(np.float32)
    xb = rng.standard_normal((p, b)).astype(np.float32)
    d = _rademacher(n, rng)
    fused = np.asarray(model.gram_precondition_block(
        x, xb, d, kind="poly", gamma=0.0, degree=2))
    kb = model.gram_block(x, xb, kind="poly", gamma=0.0, degree=2)
    comp = np.asarray(model.precondition_block(np.asarray(kb), d))
    np.testing.assert_allclose(fused, comp, rtol=1e-4,
                               atol=1e-3 * max(1.0, np.abs(comp).max()))


def test_precondition_is_orthogonal_up_to_scale():
    """(HD) is orthogonal up to sqrt(n): preconditioning preserves the
    gram/eigen structure, which is why subsampling after it works."""
    n = 64
    rng = np.random.default_rng(21)
    kb = rng.standard_normal((n, 8)).astype(np.float32)
    d = _rademacher(n, rng)
    pre = np.asarray(model.precondition_block(kb, d), dtype=np.float64)
    gram_pre = pre.T @ pre
    gram_orig = n * (kb.astype(np.float64).T @ kb)
    np.testing.assert_allclose(gram_pre, gram_orig, rtol=1e-4,
                               atol=1e-3 * np.abs(gram_orig).max())


def test_precondition_row_norm_equilibration():
    """The paper's motivation for SRHT: HD flattens coherent structure.
    A kernel block with one dominant row spreads over all rows after HD."""
    n = 256
    rng = np.random.default_rng(2)
    kb = np.zeros((n, 4), np.float32)
    kb[17, :] = 10.0  # a single spiked row: maximally coherent
    d = _rademacher(n, rng)
    pre = np.asarray(model.precondition_block(kb, d))
    row_energy = (pre ** 2).sum(axis=1)
    # all rows end up with identical energy (|H_ij| = 1 for all i, j)
    np.testing.assert_allclose(row_energy, row_energy[0], rtol=1e-4)


def test_streaming_sketch_assembles_full_transform():
    """Processing K in column blocks then stacking rows of W must equal the
    one-shot transform of the full matrix — the coordinator's core loop."""
    n, b = 64, 16
    rng = np.random.default_rng(33)
    x = rng.standard_normal((3, n)).astype(np.float32)
    k = (x.T @ x) ** 2  # full homogeneous quadratic kernel
    d = _rademacher(n, rng)
    full = np.asarray(model.precondition_block(k.astype(np.float32), d))
    blocks = [
        np.asarray(model.precondition_block(
            k[:, j:j + b].astype(np.float32), d))
        for j in range(0, n, b)
    ]
    np.testing.assert_allclose(np.hstack(blocks), full, rtol=1e-4,
                               atol=1e-3 * np.abs(full).max())


def test_sampled_rows_give_sketch_w():
    """Subsampling r' rows of (HD)K and transposing gives W = K (DHR):
    checks the rust-side convention Omega[i, j] = d_i * H[i, idx_j]."""
    n, rp = 32, 5
    rng = np.random.default_rng(44)
    x = rng.standard_normal((3, n)).astype(np.float32)
    k = ((x.T @ x) ** 2).astype(np.float32)
    d = _rademacher(n, rng)
    idx = rng.choice(n, size=rp, replace=False)
    pre = np.asarray(model.precondition_block(k, d), dtype=np.float64)
    w_stream = pre[idx, :].T                      # (n, r')
    h = ref.hadamard_matrix(n)
    omega = (d[:, None].astype(np.float64)) * h[:, idx]
    w_direct = k.astype(np.float64) @ omega
    np.testing.assert_allclose(w_stream, w_direct, rtol=1e-6,
                               atol=1e-6 * np.abs(w_direct).max())
