//! Bench: regenerate Fig. 3(a) + (b) — the segmentation workload sweep.
//!
//! Series: Nyström error/accuracy vs m ∈ {10..100}; flat reference lines
//! for ours (r' = 7) and the exact decomposition; full-kernel K-means
//! accuracy reference (paper: 0.46). Paper shape: ours ≈ exact at r'=7
//! while Nyström needs m ≈ 50 ≈ 7·r' to reach our error.
//!
//! Every run rewrites `BENCH_fig3.json`: one object per series point
//! with `{bench, series, m, approx_err, accuracy, time_s}` (`m` is 0
//! for the flat reference lines). `RKC_BENCH_QUICK=1` shrinks n, the
//! m-grid, and trials to a CI smoke shape.

use std::collections::BTreeMap;

use rkc::bench_harness::{quick_mode, write_bench_json};
use rkc::config::{ExperimentConfig, Method};
use rkc::coordinator::{build_dataset, run_trials};
use rkc::metrics::Table;
use rkc::util::Json;

fn main() {
    let quick = quick_mode();
    let trials: usize = std::env::var("RKC_TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if quick { 1 } else { 5 });
    let mut cfg = ExperimentConfig::default();
    cfg.trials = trials;
    if quick {
        cfg.n = 350;
        // force the synthetic generator: a real data/segmentation.csv
        // would override cfg.n with the full 2310-row dataset
        cfg.data_dir = "data-quick-disabled".into();
    }
    let ds = build_dataset(&cfg).expect("dataset");
    println!("bench_fig3: {} trials={} (RKC_TRIALS to change)", ds.name, trials);

    let mut table = Table::new(
        "Fig. 3 | x=m; ours r'=7 and exact are the flat reference lines",
        &["series", "m", "approx err (3a)", "accuracy (3b)"],
    );
    let mut records = Vec::new();

    let mut run = |method: Method, label: &str, m: usize, trials: usize| {
        let mut c = cfg.clone();
        c.method = method;
        c.trials = trials;
        let agg = run_trials(&c, &ds, None).expect("run");
        let m_label = if m == 0 { "-".to_string() } else { m.to_string() };
        table.row(vec![
            label.into(),
            m_label,
            if agg.error_mean.is_nan() { "-".into() } else { format!("{:.3}", agg.error_mean) },
            format!("{:.3}", agg.accuracy_mean),
        ]);
        eprintln!("  {label} m={m} ({:.1}s)", agg.total_time.as_secs_f64());
        records.push(Json::Obj(BTreeMap::from([
            ("bench".to_string(), Json::Str("fig3".to_string())),
            ("series".to_string(), Json::Str(label.to_string())),
            ("m".to_string(), Json::Num(m as f64)),
            ("approx_err".to_string(), Json::finite_num(agg.error_mean)),
            ("accuracy".to_string(), Json::finite_num(agg.accuracy_mean)),
            ("time_s".to_string(), Json::finite_num(agg.total_time.as_secs_f64())),
        ])));
    };

    run(Method::Exact, "exact", 0, 1);
    run(Method::OnePass, "ours", 0, trials);
    run(Method::FullKernel, "full_kernel_kmeans", 0, 1);
    let m_grid: &[usize] = if quick { &[10, 30] } else { &[10, 20, 30, 40, 50, 70, 100] };
    for &m in m_grid {
        run(Method::Nystrom { m }, "nystrom", m, trials);
    }
    print!("{}", table.render());
    write_bench_json("BENCH_fig3.json", records);
}
