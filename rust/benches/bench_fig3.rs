//! Bench: regenerate Fig. 3(a) + (b) — the segmentation workload sweep.
//!
//! Series: Nyström error/accuracy vs m ∈ {10..100}; flat reference lines
//! for ours (r' = 7) and the exact decomposition; full-kernel K-means
//! accuracy reference (paper: 0.46). Paper shape: ours ≈ exact at r'=7
//! while Nyström needs m ≈ 50 ≈ 7·r' to reach our error.

use rkc::config::{ExperimentConfig, Method};
use rkc::coordinator::{build_dataset, run_trials};
use rkc::metrics::Table;

fn main() {
    let trials: usize = std::env::var("RKC_TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    let mut cfg = ExperimentConfig::default();
    cfg.trials = trials;
    let ds = build_dataset(&cfg).expect("dataset");
    println!("bench_fig3: {} trials={} (RKC_TRIALS to change)", ds.name, trials);

    let mut table = Table::new(
        "Fig. 3 | x=m; ours r'=7 and exact are the flat reference lines",
        &["series", "m", "approx err (3a)", "accuracy (3b)"],
    );

    let mut run = |method: Method, label: &str, m: &str, trials: usize| {
        let mut c = cfg.clone();
        c.method = method;
        c.trials = trials;
        let agg = run_trials(&c, &ds, None).expect("run");
        table.row(vec![
            label.into(),
            m.into(),
            if agg.error_mean.is_nan() { "-".into() } else { format!("{:.3}", agg.error_mean) },
            format!("{:.3}", agg.accuracy_mean),
        ]);
        eprintln!("  {label} m={m} ({:.1}s)", agg.total_time.as_secs_f64());
    };

    run(Method::Exact, "exact", "-", 1);
    run(Method::OnePass, "ours", "-", trials);
    run(Method::FullKernel, "full_kernel_kmeans", "-", 1);
    for m in [10, 20, 30, 40, 50, 70, 100] {
        run(Method::Nystrom { m }, "nystrom", &m.to_string(), trials);
    }
    print!("{}", table.render());
}
