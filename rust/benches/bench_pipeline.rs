//! Bench: end-to-end pipeline throughput per stage, on both backends,
//! plus 1-vs-N-thread scaling of the native parallel subsystem.
//!
//! This is the L3 perf driver for EXPERIMENTS.md §Perf: wall time of the
//! sketch pass (gram + SRHT), recovery, K-means, and the error pass, on
//! the Fig-3 production shape. `RKC_BACKEND=xla` runs the PJRT artifact
//! path (requires `make artifacts`). `RKC_THREADS` overrides the thread
//! list for the scaling section (comma-separated; `0` = auto-detect).
//!
//! Besides the human-readable stdout rows, every run rewrites
//! `BENCH_pipeline.json` in the working directory — one JSON object per
//! configuration — so the perf trajectory is machine-diffable across
//! commits.

use std::collections::BTreeMap;

use rkc::bench_harness::quick_mode;
use rkc::clustering::{kmeans, kmeans_reference, KmeansOpts};
use rkc::config::{Backend, ExperimentConfig, Method};
use rkc::coordinator::{build_dataset, run_experiment, run_sketch_pass, NativeSketchRows};
use rkc::kernels::NativeBlockSource;
use rkc::lowrank::{one_pass_recovery_entrywise_reference, one_pass_recovery_threaded};
use rkc::rng::Pcg64;
use rkc::runtime::ArtifactRegistry;
use rkc::sketch::Srht;
use rkc::util::parallel::{available_threads, resolve_threads};
use rkc::util::Json;

/// The bench's base configuration: Fig-3 production shape, shrunk to a
/// smoke shape under `RKC_BENCH_QUICK=1`.
fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    if quick_mode() {
        cfg.n = 400;
        cfg.trials = 1;
        // force the synthetic generator: with a real data/segmentation.csv
        // present, build_dataset would ignore cfg.n and load all 2310 rows
        cfg.data_dir = "data-quick-disabled".into();
    }
    cfg
}

struct StageRow {
    backend: Backend,
    threads: usize,
    sketch_s: f64,
    recovery_s: f64,
    kmeans_s: f64,
    error_s: f64,
    n: usize,
    batch: usize,
    iters: usize,
}

impl StageRow {
    fn total_s(&self) -> f64 {
        self.sketch_s + self.recovery_s + self.kmeans_s + self.error_s
    }

    /// the stages the thread-scaling section compares
    fn hot_s(&self) -> f64 {
        self.sketch_s + self.kmeans_s
    }

    fn to_json(&self, speedup: Option<f64>) -> Json {
        // measured floats go through finite_num: a degenerate 0-second
        // median would otherwise put an unparseable "inf" in the file
        let mut obj = BTreeMap::from([
            ("backend".to_string(), Json::Str(format!("{:?}", self.backend).to_lowercase())),
            ("threads".to_string(), Json::Num(self.threads as f64)),
            ("sketch_s".to_string(), Json::finite_num(self.sketch_s)),
            ("recovery_s".to_string(), Json::finite_num(self.recovery_s)),
            ("kmeans_s".to_string(), Json::finite_num(self.kmeans_s)),
            ("error_pass_s".to_string(), Json::finite_num(self.error_s)),
            ("total_s".to_string(), Json::finite_num(self.total_s())),
            ("n".to_string(), Json::Num(self.n as f64)),
            ("batch".to_string(), Json::Num(self.batch as f64)),
            ("iters".to_string(), Json::Num(self.iters as f64)),
            (
                "sketch_columns_per_s".to_string(),
                Json::finite_num(self.n as f64 / self.sketch_s.max(1e-12)),
            ),
        ]);
        if let Some(s) = speedup {
            obj.insert("speedup_vs_first_row".to_string(), Json::finite_num(s));
        }
        Json::Obj(obj)
    }
}

fn run(be: Backend, threads: usize, iters: usize, registry: Option<&ArtifactRegistry>) -> StageRow {
    let med = |v: &[f64]| rkc::util::percentile(v, 50.0);
    let mut cfg = base_cfg();
    cfg.backend = be;
    cfg.method = Method::OnePass;
    cfg.threads = threads;
    let ds = build_dataset(&cfg).expect("dataset");
    let mut sketch = Vec::new();
    let mut recovery = Vec::new();
    let mut kmeans = Vec::new();
    let mut error = Vec::new();
    for i in 0..iters {
        let out = run_experiment(&cfg, &ds, registry, 100 + i as u64).expect("run");
        sketch.push(out.sketch_time.as_secs_f64());
        recovery.push(out.recovery_time.as_secs_f64());
        kmeans.push(out.kmeans_time.as_secs_f64());
        error.push(out.error_time.as_secs_f64());
    }
    let row = StageRow {
        backend: be,
        threads: resolve_threads(threads),
        sketch_s: med(&sketch),
        recovery_s: med(&recovery),
        kmeans_s: med(&kmeans),
        error_s: med(&error),
        n: ds.n(),
        batch: cfg.batch,
        iters,
    };
    println!(
        "pipeline {:?} threads={}: sketch {:.3}s | recovery {:.4}s | kmeans {:.3}s | \
         error-pass {:.3}s | total {:.3}s (n={}, batch={}, median of {iters})",
        be, row.threads, row.sketch_s, row.recovery_s, row.kmeans_s, row.error_s,
        row.total_s(), row.n, row.batch,
    );
    println!(
        "  sketch throughput: {:.0} kernel-columns/s",
        row.n as f64 / row.sketch_s.max(1e-12)
    );
    row
}

/// Single-threaded before/after of the recovery and K-means stages
/// against the retained pre-PR reference implementations (entrywise
/// `QᵀΩ` recovery, column-strided per-pair K-means). Returned as extra
/// keys merged into the first native row of `BENCH_pipeline.json`, so
/// the stage-level speedup rides the same record the trajectory diffs.
fn stage_compare(iters: usize) -> BTreeMap<String, Json> {
    let med = |v: &[f64]| rkc::util::percentile(v, 50.0);
    let cfg = base_cfg();
    let ds = build_dataset(&cfg).expect("dataset");
    let n = ds.n();
    let n_pad = n.next_power_of_two();
    let mut rng = Pcg64::seed(42);
    let mut srht = Srht::draw(&mut rng, n_pad, cfg.sketch_width());
    srht.mask_padding(n);
    let mut producer = NativeSketchRows {
        src: NativeBlockSource::new(ds.x.clone(), cfg.kernel, n_pad),
        srht,
        threads: 1,
        scratch: Vec::new(),
    };
    let (sketch, _) = run_sketch_pass(&mut producer, n, cfg.batch);

    let time = |f: &mut dyn FnMut()| {
        let t0 = std::time::Instant::now();
        f();
        t0.elapsed().as_secs_f64()
    };
    let (mut rec_before, mut rec_after) = (Vec::new(), Vec::new());
    for _ in 0..iters.max(1) {
        rec_before.push(time(&mut || {
            std::hint::black_box(one_pass_recovery_entrywise_reference(&sketch, cfg.rank));
        }));
        rec_after.push(time(&mut || {
            std::hint::black_box(one_pass_recovery_threaded(&sketch, cfg.rank, 1));
        }));
    }

    let emb = one_pass_recovery_threaded(&sketch, cfg.rank, 1);
    let opts = KmeansOpts {
        k: cfg.k,
        restarts: cfg.kmeans_restarts,
        max_iters: cfg.kmeans_iters,
        tol: cfg.kmeans_tol,
    };
    let (mut km_before, mut km_after) = (Vec::new(), Vec::new());
    for _ in 0..iters.max(1) {
        km_before.push(time(&mut || {
            let mut r = Pcg64::seed(7);
            std::hint::black_box(kmeans_reference(&emb.y, &opts, &mut r));
        }));
        km_after.push(time(&mut || {
            let mut r = Pcg64::seed(7);
            std::hint::black_box(kmeans(&emb.y, &opts, &mut r));
        }));
    }

    let (rb, ra) = (med(&rec_before), med(&rec_after));
    let (kb, ka) = (med(&km_before), med(&km_after));
    println!(
        "stage before/after (1 thread, pre-PR reference impls): recovery {:.4}s -> {:.4}s \
         ({:.1}x) | kmeans {:.3}s -> {:.3}s ({:.1}x)",
        rb,
        ra,
        rb / ra.max(1e-12),
        kb,
        ka,
        kb / ka.max(1e-12),
    );
    BTreeMap::from([
        ("recovery_before_s".to_string(), Json::finite_num(rb)),
        ("recovery_after_s".to_string(), Json::finite_num(ra)),
        ("recovery_speedup".to_string(), Json::finite_num(rb / ra.max(1e-12))),
        ("kmeans_before_s".to_string(), Json::finite_num(kb)),
        ("kmeans_after_s".to_string(), Json::finite_num(ka)),
        ("kmeans_speedup".to_string(), Json::finite_num(kb / ka.max(1e-12))),
    ])
}

fn main() {
    let backend = std::env::var("RKC_BACKEND").unwrap_or_else(|_| "both".into());
    let iters: usize =
        std::env::var("RKC_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(3);

    let mut records: Vec<Json> = Vec::new();
    if backend == "native" || backend == "both" {
        // 1-vs-N thread scaling of the sharded sketch + parallel K-means
        // (the threads=1 row doubles as the plain native baseline)
        let mut thread_list: Vec<usize> = std::env::var("RKC_THREADS")
            .ok()
            .map(|v| v.split(',').filter_map(|t| t.trim().parse().ok()).collect())
            .filter(|v: &Vec<usize>| !v.is_empty()) // malformed env → default
            .unwrap_or_else(|| vec![1, available_threads()]);
        thread_list.dedup_by_key(|t| resolve_threads(*t));
        println!(
            "scaling (native, sketch + kmeans stages, auto = {} threads):",
            available_threads()
        );
        let mut base = f64::NAN;
        for &t in &thread_list {
            let row = run(Backend::Native, t, iters, None);
            let hot = row.hot_s();
            if base.is_nan() {
                base = hot;
            }
            println!(
                "  threads={}: speedup {:.2}x vs {}-thread baseline",
                row.threads,
                base / hot,
                resolve_threads(thread_list[0])
            );
            records.push(row.to_json(Some(base / hot)));
        }
        // recovery+kmeans before/after vs the pre-PR reference impls,
        // attached to the first native row
        let extras = stage_compare(iters);
        if let Some(Json::Obj(first)) = records.first_mut() {
            first.extend(extras);
        }
    }
    if backend == "xla" || backend == "both" {
        // don't let a missing artifacts/ panic away the native records
        // already measured (the default build ships no artifacts); open
        // once and pass the handle down — no second racy open
        match ArtifactRegistry::open("artifacts") {
            Ok(reg) => {
                let row = run(Backend::Xla, 1, iters, Some(&reg));
                records.push(row.to_json(None));
            }
            Err(_) => {
                eprintln!("skipping xla section: no artifacts/ (run `make artifacts`)");
            }
        }
    }

    if records.is_empty() {
        // e.g. a typo'd RKC_BACKEND — don't clobber the recorded perf
        // trajectory with an empty array
        eprintln!("no configurations ran (RKC_BACKEND={backend:?}); BENCH_pipeline.json untouched");
        return;
    }
    let out = Json::Arr(records).to_string();
    match std::fs::write("BENCH_pipeline.json", &out) {
        Ok(()) => println!("wrote BENCH_pipeline.json ({} bytes)", out.len()),
        Err(e) => eprintln!("could not write BENCH_pipeline.json: {e}"),
    }
}
