//! Bench: end-to-end pipeline throughput per stage, on both backends,
//! plus 1-vs-N-thread scaling of the native parallel subsystem.
//!
//! This is the L3 perf driver for EXPERIMENTS.md §Perf: wall time of the
//! sketch pass (gram + SRHT), recovery, K-means, and the error pass, on
//! the Fig-3 production shape. `RKC_BACKEND=xla` runs the PJRT artifact
//! path (requires `make artifacts`). `RKC_THREADS` overrides the thread
//! list for the scaling section (comma-separated; `0` = auto-detect).

use rkc::config::{Backend, ExperimentConfig, Method};
use rkc::coordinator::{build_dataset, run_experiment};
use rkc::runtime::ArtifactRegistry;
use rkc::util::parallel::{available_threads, resolve_threads};

fn main() {
    let backend = std::env::var("RKC_BACKEND").unwrap_or_else(|_| "both".into());
    let iters: usize = std::env::var("RKC_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(3);

    let med = |v: &[f64]| rkc::util::percentile(v, 50.0);
    let run = |be: Backend, threads: usize| {
        let mut cfg = ExperimentConfig::default();
        cfg.backend = be;
        cfg.method = Method::OnePass;
        cfg.threads = threads;
        let registry = match be {
            Backend::Xla => Some(ArtifactRegistry::open("artifacts").expect("make artifacts")),
            Backend::Native => None,
        };
        let ds = build_dataset(&cfg).expect("dataset");
        let mut sketch = Vec::new();
        let mut recovery = Vec::new();
        let mut kmeans = Vec::new();
        let mut error = Vec::new();
        for i in 0..iters {
            let out = run_experiment(&cfg, &ds, registry.as_ref(), 100 + i as u64).expect("run");
            sketch.push(out.sketch_time.as_secs_f64());
            recovery.push(out.recovery_time.as_secs_f64());
            kmeans.push(out.kmeans_time.as_secs_f64());
            error.push(out.error_time.as_secs_f64());
        }
        println!(
            "pipeline {:?} threads={threads}: sketch {:.3}s | recovery {:.4}s | kmeans {:.3}s | error-pass {:.3}s | total {:.3}s (n={}, batch={}, median of {iters})",
            be,
            med(&sketch),
            med(&recovery),
            med(&kmeans),
            med(&error),
            med(&sketch) + med(&recovery) + med(&kmeans) + med(&error),
            ds.n(),
            cfg.batch,
        );
        // kernel-columns/second through the full sketch stage
        println!(
            "  sketch throughput: {:.0} kernel-columns/s",
            ds.n() as f64 / med(&sketch)
        );
        med(&sketch) + med(&kmeans)
    };

    if backend == "native" || backend == "both" {
        // 1-vs-N thread scaling of the sharded sketch + parallel K-means
        // (the threads=1 row doubles as the plain native baseline)
        let mut thread_list: Vec<usize> = std::env::var("RKC_THREADS")
            .ok()
            .map(|v| v.split(',').filter_map(|t| t.trim().parse().ok()).collect())
            .filter(|v: &Vec<usize>| !v.is_empty()) // malformed env → default
            .unwrap_or_else(|| vec![1, available_threads()]);
        thread_list.dedup_by_key(|t| resolve_threads(*t));
        println!(
            "scaling (native, sketch + kmeans stages, auto = {} threads):",
            available_threads()
        );
        let mut base = f64::NAN;
        for &t in &thread_list {
            let resolved = resolve_threads(t);
            let hot = run(Backend::Native, t);
            if base.is_nan() {
                base = hot;
            }
            println!(
                "  threads={resolved}: speedup {:.2}x vs {}-thread baseline",
                base / hot,
                resolve_threads(thread_list[0])
            );
        }
    }
    if backend == "xla" || backend == "both" {
        run(Backend::Xla, 1);
    }
}
