//! Bench: FWHT throughput and thread scaling (paper §4 reports an 11×
//! speedup on 16 pthreads for the C/mex Hadamard code).
//!
//! The scaling series runs 1, 2, 4, … up to the auto-detected hardware
//! parallelism (the `threads(0)` resolution the library uses); on a
//! 1-core container it mostly demonstrates the fork-join overhead
//! structure, and the per-size single-thread series is the meaningful
//! number (elements/s vs the O(n log n) roofline).

use rkc::bench_harness::{bench, black_box};
use rkc::rng::{Pcg64, Rng};
use rkc::sketch::fwht_parallel;
use rkc::util::parallel::available_threads;

fn main() {
    let mut rng = Pcg64::seed(1);
    println!("bench_fwht: batch of 256 vectors per transform");

    for logn in [10usize, 12, 14] {
        let n = 1usize << logn;
        let batch = 256usize;
        let data: Vec<f64> = (0..n * batch).map(|_| rng.normal()).collect();
        let r = bench(&format!("fwht n={n} x{batch} t=1"), 2, 8, || {
            let mut d = data.clone();
            fwht_parallel(&mut d, n, 1);
            black_box(d)
        });
        let elems = (n * batch) as f64;
        let flops = elems * logn as f64; // one add/sub pair per element per stage
        println!(
            "  n={n}: {:.1} Melem/s, {:.2} GFLOP/s (clone overhead included)",
            elems / r.median_s / 1e6,
            flops / r.median_s / 1e9
        );
    }

    // thread scaling at the production shape, up to the hardware limit
    let n = 4096usize;
    let batch = 256usize;
    let auto = available_threads();
    let mut series: Vec<usize> = (0..)
        .map(|e| 1usize << e)
        .take_while(|&t| t < auto)
        .collect();
    series.push(auto);
    let data: Vec<f64> = (0..n * batch).map(|_| rng.normal()).collect();
    let mut base = f64::NAN;
    println!("thread scaling (auto-detect resolves threads=0 to {auto}):");
    for threads in series {
        let r = bench(&format!("fwht n={n} x{batch} t={threads}"), 2, 8, || {
            let mut d = data.clone();
            fwht_parallel(&mut d, n, threads);
            black_box(d)
        });
        if threads == 1 {
            base = r.median_s;
        }
        println!("  threads={threads}: speedup {:.2}x vs 1 thread", base / r.median_s);
    }
}
