//! Bench: FWHT throughput and thread scaling (paper §4 reports an 11×
//! speedup on 16 pthreads for the C/mex Hadamard code).
//!
//! On this 1-core container the scaling series mostly demonstrates the
//! fork-join overhead structure; the per-size single-thread series is
//! the meaningful number (elements/s vs the O(n log n) roofline).

use rkc::bench_harness::{bench, black_box};
use rkc::rng::{Pcg64, Rng};
use rkc::sketch::fwht_parallel;

fn main() {
    let mut rng = Pcg64::seed(1);
    println!("bench_fwht: batch of 256 vectors per transform");

    for logn in [10usize, 12, 14] {
        let n = 1usize << logn;
        let batch = 256usize;
        let data: Vec<f64> = (0..n * batch).map(|_| rng.normal()).collect();
        let r = bench(&format!("fwht n={n} x{batch} t=1"), 2, 8, || {
            let mut d = data.clone();
            fwht_parallel(&mut d, n, 1);
            black_box(d)
        });
        let elems = (n * batch) as f64;
        let flops = elems * logn as f64; // one add/sub pair per element per stage
        println!(
            "  n={n}: {:.1} Melem/s, {:.2} GFLOP/s (clone overhead included)",
            elems / r.median_s / 1e6,
            flops / r.median_s / 1e9
        );
    }

    // thread scaling at the production shape
    let n = 4096usize;
    let batch = 256usize;
    let data: Vec<f64> = (0..n * batch).map(|_| rng.normal()).collect();
    let mut base = f64::NAN;
    for threads in [1usize, 2, 4, 8, 16] {
        let r = bench(&format!("fwht n={n} x{batch} t={threads}"), 2, 8, || {
            let mut d = data.clone();
            fwht_parallel(&mut d, n, threads);
            black_box(d)
        });
        if threads == 1 {
            base = r.median_s;
        }
        println!("  threads={threads}: speedup {:.2}x (1-core container: expect ≤1)", base / r.median_s);
    }
}
