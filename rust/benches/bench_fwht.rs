//! Bench: FWHT throughput and thread scaling (paper §4 reports an 11×
//! speedup on 16 pthreads for the C/mex Hadamard code).
//!
//! The scaling series runs 1, 2, 4, … up to the auto-detected hardware
//! parallelism (the `threads(0)` resolution the library uses); on a
//! 1-core container it mostly demonstrates the fork-join overhead
//! structure, and the per-size single-thread series is the meaningful
//! number (elements/s vs the O(n log n) roofline).
//!
//! Every run rewrites `BENCH_fwht.json`: one object per configuration
//! with `{bench, n, batch, threads, median_s, melems_per_s, speedup}`.
//! `RKC_BENCH_QUICK=1` shrinks sizes and iterations to a CI smoke shape.

use std::collections::BTreeMap;

use rkc::bench_harness::{bench, black_box, quick_mode, write_bench_json};
use rkc::rng::{Pcg64, Rng};
use rkc::sketch::fwht_parallel;
use rkc::util::parallel::available_threads;
use rkc::util::Json;

fn row(n: usize, batch: usize, threads: usize, median_s: f64, speedup: f64) -> Json {
    Json::Obj(BTreeMap::from([
        ("bench".to_string(), Json::Str("fwht".to_string())),
        ("n".to_string(), Json::Num(n as f64)),
        ("batch".to_string(), Json::Num(batch as f64)),
        ("threads".to_string(), Json::Num(threads as f64)),
        ("median_s".to_string(), Json::finite_num(median_s)),
        (
            "melems_per_s".to_string(),
            Json::finite_num((n * batch) as f64 / median_s.max(1e-12) / 1e6),
        ),
        ("speedup".to_string(), Json::finite_num(speedup)),
    ]))
}

fn main() {
    let quick = quick_mode();
    let iters = if quick { 1 } else { 8 };
    let batch = if quick { 16usize } else { 256 };
    let mut rng = Pcg64::seed(1);
    let mut records = Vec::new();
    println!("bench_fwht: batch of {batch} vectors per transform");

    let sizes: &[usize] = if quick { &[10] } else { &[10, 12, 14] };
    for &logn in sizes {
        let n = 1usize << logn;
        let data: Vec<f64> = (0..n * batch).map(|_| rng.normal()).collect();
        let r = bench(&format!("fwht n={n} x{batch} t=1"), 2.min(iters), iters, || {
            let mut d = data.clone();
            fwht_parallel(&mut d, n, 1);
            black_box(d)
        });
        let elems = (n * batch) as f64;
        let flops = elems * logn as f64; // one add/sub pair per element per stage
        println!(
            "  n={n}: {:.1} Melem/s, {:.2} GFLOP/s (clone overhead included)",
            elems / r.median_s / 1e6,
            flops / r.median_s / 1e9
        );
        records.push(row(n, batch, 1, r.median_s, 1.0));
    }

    // thread scaling at the production shape, up to the hardware limit
    let n = if quick { 1024usize } else { 4096 };
    let auto = available_threads();
    let mut series: Vec<usize> = (0..)
        .map(|e| 1usize << e)
        .take_while(|&t| t < auto)
        .collect();
    series.push(auto);
    let data: Vec<f64> = (0..n * batch).map(|_| rng.normal()).collect();
    let mut base = f64::NAN;
    println!("thread scaling (auto-detect resolves threads=0 to {auto}):");
    for threads in series {
        let r = bench(&format!("fwht n={n} x{batch} t={threads}"), 2.min(iters), iters, || {
            let mut d = data.clone();
            fwht_parallel(&mut d, n, threads);
            black_box(d)
        });
        if threads == 1 {
            base = r.median_s;
        }
        println!("  threads={threads}: speedup {:.2}x vs 1 thread", base / r.median_s);
        records.push(row(n, batch, threads, r.median_s, base / r.median_s));
    }

    write_bench_json("BENCH_fwht.json", records);
}
