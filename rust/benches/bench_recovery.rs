//! Bench: the recovery hot paths — entrywise vs FWHT-based `QᵀΩ`, and
//! the full Alg. 1 steps 3–6 before/after the GEMM+FWHT overhaul.
//!
//! The headline row is the acceptance shape n=4096, r=8, r'=18: the
//! FWHT identity costs O(n log n · r) independent of r', while the
//! entrywise path pays O(n · r · r') with a popcount per scalar.
//!
//! Every run rewrites `BENCH_recovery.json`: one object per row with
//! `{bench, n, r, rp, threads, before_s, after_s, speedup}` —
//! `before_s` is the pre-PR reference path, `after_s` the shipping one.
//! `RKC_BENCH_QUICK=1` shrinks everything to a CI smoke shape.

use std::collections::BTreeMap;

use rkc::bench_harness::{bench, black_box, quick_mode, write_bench_json};
use rkc::kernels::{column_batches, BlockSource, Kernel, NativeBlockSource};
use rkc::linalg::Mat;
use rkc::lowrank::{
    one_pass_recovery_entrywise_reference, one_pass_recovery_threaded, OnePassSketch,
};
use rkc::rng::{Pcg64, Rng};
use rkc::sketch::{fwht_inplace_with, Srht};
use rkc::util::parallel::available_threads;
use rkc::util::Json;

fn row(
    name: &str,
    n: usize,
    r: usize,
    rp: usize,
    threads: usize,
    before_s: f64,
    after_s: f64,
) -> Json {
    Json::Obj(BTreeMap::from([
        ("bench".to_string(), Json::Str(name.to_string())),
        ("n".to_string(), Json::Num(n as f64)),
        ("r".to_string(), Json::Num(r as f64)),
        ("rp".to_string(), Json::Num(rp as f64)),
        ("threads".to_string(), Json::Num(threads as f64)),
        ("before_s".to_string(), Json::finite_num(before_s)),
        ("after_s".to_string(), Json::finite_num(after_s)),
        ("speedup".to_string(), Json::finite_num(before_s / after_s.max(1e-12))),
    ]))
}

/// Entrywise vs FWHT `QᵀΩ` at one shape.
fn qt_omega_row(n: usize, r: usize, rp: usize, threads: usize, iters: usize) -> Json {
    let mut rng = Pcg64::seed(0xabc ^ (n as u64) ^ ((rp as u64) << 32));
    let srht = Srht::draw(&mut rng, n, rp);
    let q = Mat::from_fn(n, r, |_, _| rng.normal());
    let before = bench(
        &format!("qt_omega entrywise n={n} r={r} rp={rp}"),
        1,
        iters,
        || black_box(srht.qt_omega_entrywise(&q)),
    );
    let after = bench(
        &format!("qt_omega fwht      n={n} r={r} rp={rp} t={threads}"),
        1,
        iters,
        || black_box(srht.qt_omega_threaded(&q, threads)),
    );
    println!(
        "  => fwht speedup {:.1}x at n={n}, r={r}, r'={rp}, threads={threads}",
        before.median_s / after.median_s.max(1e-12)
    );
    row("qt_omega", n, r, rp, threads, before.median_s, after.median_s)
}

/// Full recovery (QR + solve + eig + Y) before/after, on a real sketch.
fn recovery_row(n: usize, r: usize, rp: usize, iters: usize) -> Json {
    let mut rng = Pcg64::seed(17);
    let x = Mat::from_fn(4, n, |_, _| rng.normal());
    let mut src = NativeBlockSource::pow2(x, Kernel::paper_poly2());
    let (n_real, np) = (src.n(), src.n_padded());
    let mut srht = Srht::draw(&mut rng, np, rp);
    srht.mask_padding(n_real);
    let mut sketch = OnePassSketch::new(srht, n_real);
    let mut scratch = Vec::new();
    for cols in column_batches(n_real, 256) {
        let kb = src.block(&cols);
        let rows = sketch.srht().apply_to_block_with(&kb, 1, &mut scratch);
        sketch.ingest(&cols, &rows);
    }
    let before = bench(&format!("recovery entrywise n={n} r={r} rp={rp}"), 1, iters, || {
        black_box(one_pass_recovery_entrywise_reference(&sketch, r))
    });
    let after = bench(&format!("recovery fwht+gemm n={n} r={r} rp={rp}"), 1, iters, || {
        black_box(one_pass_recovery_threaded(&sketch, r, 1))
    });
    row("recovery_total", np, r, rp, 1, before.median_s, after.median_s)
}

/// FWHT butterfly through the pinned scalar kernel table vs the
/// runtime-dispatched one, over an r'-column batch of length-n
/// transforms (the `QᵀΩ` shape). Outputs are bit-identical on every
/// ISA by the per-ISA determinism contract; only the wall clock moves.
/// Tagged `"mode": "simd"` for check_bench_json.py's tagged-row gate.
fn simd_fwht_row(n: usize, rp: usize, iters: usize) -> Json {
    let mut rng = Pcg64::seed(0xf1417 ^ (n as u64));
    let cols: Vec<Vec<f64>> =
        (0..rp).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    let run = |table: &rkc::simd::KernelTable| {
        let mut work = cols.clone();
        for col in &mut work {
            fwht_inplace_with(col, table);
        }
        work
    };
    let scalar = rkc::simd::scalar_table();
    let table = rkc::simd::dispatch();
    let before = bench(&format!("fwht scalar n={n} cols={rp}"), 1, iters, || {
        black_box(run(scalar))
    });
    let after = bench(
        &format!("fwht {:<6} n={n} cols={rp}", table.isa.name()),
        1,
        iters,
        || black_box(run(table)),
    );
    println!(
        "  => {} butterfly speedup {:.1}x at n={n}, cols={rp}",
        table.isa.name(),
        before.median_s / after.median_s.max(1e-12)
    );
    let mut record = row("fwht_butterfly", n, 0, rp, 1, before.median_s, after.median_s);
    if let Json::Obj(ref mut map) = record {
        map.insert("mode".to_string(), Json::Str("simd".to_string()));
        map.insert("isa".to_string(), Json::Str(table.isa.name().to_string()));
    }
    record
}

fn main() {
    let quick = quick_mode();
    let iters = if quick { 1 } else { 9 };
    let mut records = Vec::new();

    println!("bench_recovery: QᵀΩ entrywise vs FWHT, full recovery before/after");
    if quick {
        records.push(qt_omega_row(256, 4, 9, 1, iters));
        records.push(recovery_row(200, 2, 6, iters));
        records.push(simd_fwht_row(1024, 9, iters));
    } else {
        // acceptance shape first, then r'-scaling and thread rows
        records.push(qt_omega_row(4096, 8, 18, 1, iters));
        records.push(qt_omega_row(4096, 8, 40, 1, iters));
        records.push(qt_omega_row(16384, 8, 18, 1, iters));
        let auto = available_threads();
        if auto > 1 {
            records.push(qt_omega_row(4096, 8, 18, auto, iters));
        }
        records.push(recovery_row(4000, 8, 18, iters.min(5)));
        records.push(simd_fwht_row(16384, 18, iters));
    }

    write_bench_json("BENCH_recovery.json", records);
}
