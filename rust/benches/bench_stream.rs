//! Bench: online one-pass clustering (`rkc::stream`) under drift —
//! refresh latency and accuracy lag versus a full batch refit.
//!
//! Two synthetic non-stationary sources (`data::DriftStream`):
//!
//! 1. `moving_blobs` — cluster centers translate a little per chunk;
//! 2. `label_churn` — the class mixture rotates while geometry holds.
//!
//! For each scenario the `StreamClusterer` ingests `chunk`-sized
//! batches and refreshes on the point trigger; every refresh is timed
//! (p50/p95 across the run). After the stream drains, a batch
//! `KernelClusterer` refit on the identical point set gives the
//! accuracy ceiling, and `acc_lag = acc_refit − acc_stream` is the cost
//! of folding incrementally + warm-starting instead of refitting cold.
//!
//! Env knobs: `RKC_STREAM_N` (total points, default 2000),
//! `RKC_STREAM_CHUNK` (points per ingest batch, default 250),
//! `RKC_STREAM_REFRESH` (refresh-every-points trigger, default 500).
//!
//! Besides the stdout summary, every run rewrites `BENCH_stream.json`
//! in the working directory so the streaming perf trajectory is
//! machine-diffable across commits.

use std::collections::BTreeMap;
use std::time::Instant;

use rkc::api::KernelClusterer;
use rkc::bench_harness::latency_summary;
use rkc::clustering::accuracy;
use rkc::data::DriftStream;
use rkc::linalg::Mat;
use rkc::stream::StreamClusterer;
use rkc::util::Json;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// One streaming run. With `refit` the full batch ceiling (a cold
/// `KernelClusterer` fit on the identical points) and the accuracy-lag
/// fields are computed; without it those fields serialize as null —
/// the obs-overhead "off" run only needs `wall_s`, not a second refit.
fn run_scenario(
    scenario: &str,
    mut source: DriftStream,
    k: usize,
    n_total: usize,
    chunk: usize,
    refresh_points: usize,
    refit: bool,
) -> Json {
    let mut sc = StreamClusterer::new(k)
        .rank(2)
        .oversample(10)
        .seed(42)
        .threads(0)
        .capacity(n_total)
        .refresh_every_points(refresh_points);

    let mut truth: Vec<usize> = Vec::with_capacity(n_total);
    let mut coords: Vec<f64> = Vec::new(); // point-major replay buffer
    let mut refresh_s: Vec<f64> = Vec::new();
    let t_run = Instant::now();
    let mut fed = 0usize;
    while fed < n_total {
        let m = chunk.min(n_total - fed);
        let ds = source.chunk(m);
        // coords are only consumed by the refit, but collecting them
        // unconditionally keeps the timed loop identical between the
        // obs-overhead on/off runs (wall_s covers this loop)
        truth.extend_from_slice(&ds.labels);
        for j in 0..m {
            for i in 0..ds.x.rows() {
                coords.push(ds.x[(i, j)]);
            }
        }
        sc.ingest(&ds.x).expect("ingest");
        fed += m;
        let flush = fed == n_total && sc.pending_points() > 0;
        if (sc.refresh_due() || flush) && sc.can_refresh() {
            let t = Instant::now();
            sc.refresh().expect("refresh");
            refresh_s.push(t.elapsed().as_secs_f64());
        }
    }
    let wall_s = t_run.elapsed().as_secs_f64();

    let acc_stream = accuracy(sc.last_labels().expect("refreshed at least once"), &truth, k);

    // batch ceiling: one cold fit on the identical point set
    let (acc_refit, refit_s) = if refit {
        let p = coords.len() / n_total;
        let x = Mat::from_fn(p, n_total, |i, j| coords[j * p + i]);
        let t_refit = Instant::now();
        let refitted = KernelClusterer::new(k)
            .rank(2)
            .oversample(10)
            .seed(42)
            .threads(0)
            .fit(&x)
            .expect("batch refit");
        (accuracy(refitted.labels(), &truth, k), t_refit.elapsed().as_secs_f64())
    } else {
        (f64::NAN, f64::NAN)
    };

    let lat = latency_summary(&refresh_s);
    println!(
        "stream[{scenario}] n={n_total} chunk={chunk} refreshes={}: \
         refresh p50 {:.1}ms p95 {:.1}ms | \
         acc stream {acc_stream:.3} vs refit {acc_refit:.3} (lag {:+.3}) | \
         stream wall {wall_s:.2}s, one refit {refit_s:.2}s",
        refresh_s.len(),
        lat.p50_ms,
        lat.p95_ms,
        acc_refit - acc_stream,
    );
    let mut fields = BTreeMap::from([
        ("bench".to_string(), Json::Str("stream".to_string())),
        ("scenario".to_string(), Json::Str(scenario.to_string())),
        ("n_total".to_string(), Json::Num(n_total as f64)),
        ("chunk".to_string(), Json::Num(chunk as f64)),
        ("refresh_every_points".to_string(), Json::Num(refresh_points as f64)),
        ("refreshes".to_string(), Json::Num(refresh_s.len() as f64)),
        ("acc_stream".to_string(), Json::finite_num(acc_stream)),
        ("acc_refit".to_string(), Json::finite_num(acc_refit)),
        ("acc_lag".to_string(), Json::finite_num(acc_refit - acc_stream)),
        ("wall_s".to_string(), Json::finite_num(wall_s)),
        ("refit_s".to_string(), Json::finite_num(refit_s)),
    ]);
    fields.extend(lat.json_fields("refresh_"));
    Json::Obj(fields)
}

fn main() {
    // quick mode (RKC_BENCH_QUICK=1) shrinks the defaults to a CI smoke
    // shape; explicit RKC_STREAM_* env knobs still win
    let quick = rkc::bench_harness::quick_mode();
    let n_total = env_usize("RKC_STREAM_N", if quick { 600 } else { 2000 });
    let chunk = env_usize("RKC_STREAM_CHUNK", if quick { 150 } else { 250 }).max(1);
    let refresh_points =
        env_usize("RKC_STREAM_REFRESH", if quick { 300 } else { 500 }).max(chunk);

    let blobs_row = run_scenario(
        "moving_blobs",
        DriftStream::moving_blobs(7, 2, 2, 0.5, 0.02),
        2,
        n_total,
        chunk,
        refresh_points,
        true,
    );
    let churn_row = run_scenario(
        "label_churn",
        DriftStream::label_churn(7, 2, 2, 0.5, 0.4),
        2,
        n_total,
        chunk,
        refresh_points,
        true,
    );

    // --- obs overhead row: the moving_blobs scenario with recording on
    // vs off; the wall-clock delta is the cost of the ingest/refresh
    // histograms, gauges, and fit-stage series on the streaming path
    let wall = |row: &Json| match row {
        Json::Obj(m) => match m.get("wall_s") {
            Some(Json::Num(v)) => *v,
            _ => f64::NAN,
        },
        _ => f64::NAN,
    };
    rkc::obs::set_enabled(true);
    let on_row = run_scenario(
        "obs_overhead",
        DriftStream::moving_blobs(7, 2, 2, 0.5, 0.02),
        2,
        n_total,
        chunk,
        refresh_points,
        true,
    );
    rkc::obs::set_enabled(false);
    let off_row = run_scenario(
        "obs_overhead_off",
        DriftStream::moving_blobs(7, 2, 2, 0.5, 0.02),
        2,
        n_total,
        chunk,
        refresh_points,
        false,
    );
    rkc::obs::set_enabled(true);
    let obs_overhead_pct = (wall(&on_row) / wall(&off_row) - 1.0) * 100.0;
    println!(
        "obs overhead: instrumented {:.3}s vs disabled {:.3}s ({obs_overhead_pct:+.1}%)",
        wall(&on_row),
        wall(&off_row),
    );
    let obs_row = match on_row {
        Json::Obj(mut m) => {
            m.insert("obs_overhead_pct".to_string(), Json::finite_num(obs_overhead_pct));
            Json::Obj(m)
        }
        other => other,
    };

    rkc::bench_harness::write_bench_json(
        "BENCH_stream.json",
        vec![blobs_row, churn_row, obs_row],
    );
}
