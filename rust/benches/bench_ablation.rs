//! Bench: ablations of the design choices DESIGN.md calls out.
//!
//! 1. SRHT preconditioning ON vs OFF (off = sample kernel columns
//!    directly — degenerates toward Nyström-quality sketches);
//! 2. SRHT vs dense Gaussian test matrix (accuracy parity, memory gap);
//! 3. oversampling l sweep;
//! 4. streaming batch size sweep (throughput vs transient memory).
//!
//! Every run rewrites `BENCH_ablation.json`: one object per grid point,
//! tagged by a `bench` key per section (`ablation_precond`,
//! `ablation_testmatrix`, `ablation_batch`). `RKC_BENCH_QUICK=1`
//! shrinks n, trials, and the sweeps to a CI smoke shape.

use std::collections::BTreeMap;

use rkc::bench_harness::{quick_mode, write_bench_json};
use rkc::config::{ExperimentConfig, Method};
use rkc::coordinator::{build_dataset, run_trials};
use rkc::kernels::{column_batches, BlockSource, NativeBlockSource};
use rkc::lowrank::{one_pass_recovery, streamed_frobenius_error, OnePassSketch};
use rkc::metrics::{MemoryModel, Table};
use rkc::rng::Pcg64;
use rkc::sketch::Srht;
use rkc::util::Json;

fn main() {
    let quick = quick_mode();
    let trials: usize = std::env::var("RKC_TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if quick { 1 } else { 5 });
    let mut cfg = ExperimentConfig::table1();
    cfg.n = if quick { 300 } else { 2000 }; // keep the ablation grid affordable
    cfg.trials = trials;
    let mut records: Vec<Json> = Vec::new();
    let ds = build_dataset(&cfg).expect("dataset");
    let n = ds.n();
    let n_pad = n.next_power_of_two();

    // ---- 1. preconditioning on/off ----
    let mut t = Table::new(
        "Ablation: SRHT preconditioning (HD) on vs off (r'=12)",
        &["variant", "approx err (mean over trials)"],
    );
    for precondition in [true, false] {
        let mut errs = Vec::new();
        for trial in 0..trials {
            let mut rng = Pcg64::seed(500 + trial as u64);
            let mut srht = Srht::draw(&mut rng, n_pad, cfg.sketch_width());
            srht.mask_padding(n);
            if !precondition {
                // identity preconditioner: d = 1 everywhere (real rows),
                // H dropped by sampling W = K[:, idx-as-rows]... i.e.
                // rows of W are just sampled kernel entries
                for i in 0..n {
                    srht.d[i] = 1.0;
                }
            }
            let mut src = NativeBlockSource::new(ds.x.clone(), cfg.kernel, n_pad);
            let mut sk = OnePassSketch::new(srht.clone(), n);
            for cols in column_batches(n, cfg.batch) {
                let kb = src.block(&cols);
                let rows = if precondition {
                    srht.apply_to_block(&kb, 1)
                } else {
                    // no-FWHT variant: sample raw (signed) kernel rows
                    rkc::linalg::Mat::from_fn(cols.len(), srht.samples(), |bj, s| {
                        kb[(srht.idx[s], bj)]
                    })
                };
                sk.ingest(&cols, &rows);
            }
            let emb = one_pass_recovery_no_h(&sk, cfg.rank, precondition);
            errs.push(streamed_frobenius_error(&mut src, &emb, cfg.batch));
        }
        t.row(vec![
            if precondition { "HD preconditioning (paper)" } else { "raw row sampling" }.into(),
            format!("{:.3} ± {:.3}", rkc::util::mean(&errs), rkc::util::std_dev(&errs)),
        ]);
        records.push(Json::Obj(BTreeMap::from([
            ("bench".to_string(), Json::Str("ablation_precond".to_string())),
            (
                "variant".to_string(),
                Json::Str(if precondition { "hd" } else { "raw" }.to_string()),
            ),
            ("approx_err".to_string(), Json::finite_num(rkc::util::mean(&errs))),
        ])));
    }
    print!("{}", t.render());

    // ---- 2. SRHT vs Gaussian; 3. oversampling sweep ----
    let mut t = Table::new(
        "Ablation: test matrix & oversampling l (accuracy parity, memory gap)",
        &["method", "l", "approx err", "accuracy", "persistent MiB"],
    );
    let l_grid: &[usize] = if quick { &[0, 5] } else { &[0, 2, 5, 10, 20] };
    for (method, label) in [(Method::OnePass, "srht"), (Method::GaussianOnePass, "gaussian")] {
        for &l in l_grid {
            let mut c = cfg.clone();
            c.method = method;
            c.oversample = l;
            let agg = run_trials(&c, &ds, None).expect("run");
            let mut mem = MemoryModel::one_pass(n, n_pad, c.sketch_width(), c.rank, c.batch);
            if method == Method::GaussianOnePass {
                mem.persistent += 8 * n_pad * c.sketch_width();
            }
            t.row(vec![
                label.into(),
                l.to_string(),
                format!("{:.3}", agg.error_mean),
                format!("{:.3}", agg.accuracy_mean),
                format!("{:.3}", mem.persistent as f64 / (1024.0 * 1024.0)),
            ]);
            records.push(Json::Obj(BTreeMap::from([
                ("bench".to_string(), Json::Str("ablation_testmatrix".to_string())),
                ("variant".to_string(), Json::Str(label.to_string())),
                ("oversample".to_string(), Json::Num(l as f64)),
                ("approx_err".to_string(), Json::finite_num(agg.error_mean)),
                ("accuracy".to_string(), Json::finite_num(agg.accuracy_mean)),
                ("persistent_bytes".to_string(), Json::Num(mem.persistent as f64)),
            ])));
        }
    }
    print!("{}", t.render());

    // ---- 4. batch size sweep ----
    let mut t = Table::new(
        "Ablation: streaming batch size (sketch wall time vs transient MiB)",
        &["batch", "sketch time s", "transient MiB"],
    );
    let batch_grid: &[usize] = if quick { &[32, 256] } else { &[32, 128, 256, 1024] };
    for &batch in batch_grid {
        let mut c = cfg.clone();
        c.method = Method::OnePass;
        c.batch = batch;
        c.trials = 1;
        let ds2 = ds.clone();
        let out = rkc::coordinator::run_experiment(&c, &ds2, None, 42).expect("run");
        let mem = MemoryModel::one_pass(n, n_pad, c.sketch_width(), c.rank, batch);
        t.row(vec![
            batch.to_string(),
            format!("{:.3}", out.sketch_time.as_secs_f64()),
            format!("{:.2}", mem.transient as f64 / (1024.0 * 1024.0)),
        ]);
        records.push(Json::Obj(BTreeMap::from([
            ("bench".to_string(), Json::Str("ablation_batch".to_string())),
            ("batch".to_string(), Json::Num(batch as f64)),
            ("sketch_s".to_string(), Json::finite_num(out.sketch_time.as_secs_f64())),
            ("transient_bytes".to_string(), Json::Num(mem.transient as f64)),
        ])));
    }
    print!("{}", t.render());
    write_bench_json("BENCH_ablation.json", records);
}

/// Recovery for both ablation variants: with preconditioning the normal
/// path; without, Ω = R (identity columns) so QᵀΩ = (Q rows at idx)ᵀ.
fn one_pass_recovery_no_h(
    sketch: &OnePassSketch,
    rank: usize,
    preconditioned: bool,
) -> rkc::lowrank::Embedding {
    if preconditioned {
        return one_pass_recovery(sketch, rank);
    }
    use rkc::linalg::{householder_qr, jacobi_eig, least_squares, Mat};
    let w = sketch.w();
    let n = w.rows();
    let srht = sketch.srht();
    let (q, _) = householder_qr(w); // n × r'
    let qdim = q.cols();
    // Ω = R: omega[i, j] = 1 iff i == idx[j] ⇒ QᵀΩ columns are Q rows
    let rp = srht.samples();
    let mut qt_omega = Mat::zeros(qdim, rp);
    for (j, &i) in srht.idx.iter().enumerate() {
        if i < n {
            for k in 0..qdim {
                qt_omega[(k, j)] = q[(i, k)];
            }
        }
    }
    let qt_w = q.t_matmul(w); // r' × r'
    let bt = least_squares(&qt_omega.transpose(), &qt_w.transpose());
    let mut b = bt.transpose();
    b.symmetrize();
    let (evals, v) = jacobi_eig(&b);
    let clamped: Vec<f64> = evals.iter().take(rank).map(|&l| l.max(0.0)).collect();
    let mut y = Mat::zeros(rank, n);
    for i in 0..rank {
        let s = clamped[i].sqrt();
        for j in 0..n {
            let mut acc = 0.0;
            for k in 0..qdim {
                acc += v[(k, i)] * q[(j, k)];
            }
            y[(i, j)] = s * acc;
        }
    }
    rkc::lowrank::Embedding { y, eigenvalues: clamped }
}
