//! Bench: regenerate Table 1 (cross_lines synthetic, r = 2, l = 10).
//!
//! Paper values — exact: 0.40 / 0.99, ours: 0.40 / 0.99,
//! Nyström m=20: 0.56 / 0.74, Nyström m=100: 0.44 / 0.75; plain 0.53.
//! The acceptance criterion is the *shape*: ours ≈ exact in both
//! columns, Nyström worse at matched-or-larger memory.
//!
//! Every run rewrites `BENCH_table1.json`: one object per method with
//! `{bench, method, trials, n, approx_err, accuracy, time_s}`
//! (`approx_err` is `null` for plain K-means, which has no embedding).
//! `RKC_BENCH_QUICK=1` shrinks n and trials to a CI smoke shape.

use std::collections::BTreeMap;

use rkc::bench_harness::{quick_mode, write_bench_json};
use rkc::config::{ExperimentConfig, Method};
use rkc::coordinator::{build_dataset, run_trials};
use rkc::metrics::Table;
use rkc::util::Json;

fn main() {
    let quick = quick_mode();
    let trials: usize = std::env::var("RKC_TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if quick { 1 } else { 10 });
    let mut cfg = ExperimentConfig::table1();
    cfg.trials = trials;
    if quick {
        cfg.n = 320;
    }
    let ds = build_dataset(&cfg).expect("dataset");
    println!("bench_table1: {} trials={} (RKC_TRIALS to change)", ds.name, trials);

    let mut table = Table::new(
        "Table 1 | paper: exact 0.40/0.99, ours 0.40/0.99, nys20 0.56/0.74, nys100 0.44/0.75, plain -/0.53",
        &["method", "approx err", "accuracy", "time_s"],
    );
    let mut records = Vec::new();
    for method in [
        Method::Exact,
        Method::OnePass,
        Method::Nystrom { m: 20 },
        Method::Nystrom { m: 100 },
        Method::PlainKmeans,
    ] {
        let mut c = cfg.clone();
        c.method = method;
        let agg = run_trials(&c, &ds, None).expect("run");
        table.row(vec![
            agg.method.clone(),
            if agg.error_mean.is_nan() { "-".into() } else { format!("{:.2}", agg.error_mean) },
            format!("{:.2}", agg.accuracy_mean),
            format!("{:.1}", agg.total_time.as_secs_f64()),
        ]);
        records.push(Json::Obj(BTreeMap::from([
            ("bench".to_string(), Json::Str("table1".to_string())),
            ("method".to_string(), Json::Str(agg.method.clone())),
            ("trials".to_string(), Json::Num(agg.trials as f64)),
            ("n".to_string(), Json::Num(ds.n() as f64)),
            ("approx_err".to_string(), Json::finite_num(agg.error_mean)),
            ("accuracy".to_string(), Json::finite_num(agg.accuracy_mean)),
            ("time_s".to_string(), Json::finite_num(agg.total_time.as_secs_f64())),
        ])));
    }
    print!("{}", table.render());
    write_bench_json("BENCH_table1.json", records);
}
