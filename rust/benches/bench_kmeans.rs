//! Bench: the K-means assignment overhaul — norm-identity + GEMM cross
//! term ([`rkc::clustering::kmeans`]) vs the pre-GEMM per-(point,
//! centroid) column-strided reference ([`kmeans_reference`]).
//!
//! Every run rewrites `BENCH_kmeans.json`: one object per row with
//! `{bench, n, r, k, restarts, threads, before_s, after_s, speedup}` —
//! `before_s` is the sequential reference implementation, `after_s` the
//! shipping path at the row's thread count (threads=1 rows are the
//! like-for-like algorithmic comparison; threaded rows fold in the
//! restart fan-out). `RKC_BENCH_QUICK=1` shrinks to a CI smoke shape.

use std::collections::BTreeMap;

use rkc::bench_harness::{bench, black_box, quick_mode, write_bench_json};
use rkc::clustering::{kmeans_reference, kmeans_threaded, KmeansOpts};
use rkc::linalg::Mat;
use rkc::rng::{Pcg64, Rng};
use rkc::util::parallel::available_threads;
use rkc::util::Json;

/// k separated Gaussian blobs in R^r, point-per-column like the
/// embedding the pipeline feeds to K-means.
fn blobs(rng: &mut Pcg64, n: usize, r: usize, k: usize) -> Mat {
    let centers = Mat::from_fn(r, k, |_, _| 10.0 * rng.normal());
    Mat::from_fn(r, n, |i, j| centers[(i, j % k)] + 0.5 * rng.normal())
}

fn kmeans_row(n: usize, r: usize, k: usize, restarts: usize, threads: usize, iters: usize) -> Json {
    let mut rng = Pcg64::seed(0x5eed ^ (n as u64) ^ ((k as u64) << 32));
    let y = blobs(&mut rng, n, r, k);
    let opts = KmeansOpts { k, restarts, max_iters: 20, tol: 1e-9 };
    let before = bench(&format!("kmeans reference n={n} r={r} k={k} R={restarts}"), 1, iters, || {
        let mut rr = Pcg64::seed(99);
        black_box(kmeans_reference(&y, &opts, &mut rr))
    });
    let after = bench(
        &format!("kmeans gemm      n={n} r={r} k={k} R={restarts} t={threads}"),
        1,
        iters,
        || {
            let mut rr = Pcg64::seed(99);
            black_box(kmeans_threaded(&y, &opts, &mut rr, threads))
        },
    );
    println!(
        "  => gemm speedup {:.1}x at n={n}, r={r}, k={k}, threads={threads}",
        before.median_s / after.median_s.max(1e-12)
    );
    Json::Obj(BTreeMap::from([
        ("bench".to_string(), Json::Str("kmeans".to_string())),
        ("n".to_string(), Json::Num(n as f64)),
        ("r".to_string(), Json::Num(r as f64)),
        ("k".to_string(), Json::Num(k as f64)),
        ("restarts".to_string(), Json::Num(restarts as f64)),
        ("threads".to_string(), Json::Num(threads as f64)),
        ("before_s".to_string(), Json::finite_num(before.median_s)),
        ("after_s".to_string(), Json::finite_num(after.median_s)),
        ("speedup".to_string(), Json::finite_num(before.median_s / after.median_s.max(1e-12))),
    ]))
}

/// SIMD-dispatch contribution on the assignment argmin hot loop: the
/// pinned scalar kernel table vs the runtime-dispatched one, on the
/// exact point-major gram layout `assign_range` consumes. Results are
/// identical by the per-ISA determinism contract (see `rkc::simd`);
/// only the wall clock moves. Tagged `"mode": "simd"` so
/// check_bench_json.py's tagged-row gate can require it.
fn simd_row(n: usize, r: usize, k: usize, iters: usize) -> Json {
    let mut rng = Pcg64::seed(0x51d ^ (n as u64) ^ ((k as u64) << 32));
    let y = blobs(&mut rng, n, r, k);
    let c = Mat::from_fn(r, k, |_, _| 10.0 * rng.normal());
    let yn: Vec<f64> =
        (0..n).map(|j| (0..r).map(|i| y[(i, j)] * y[(i, j)]).sum::<f64>()).collect();
    let cn: Vec<f64> =
        (0..k).map(|cc| (0..r).map(|i| c[(i, cc)] * c[(i, cc)]).sum::<f64>()).collect();
    let mut g = Vec::with_capacity(n * k);
    for j in 0..n {
        for cc in 0..k {
            g.push((0..r).map(|i| y[(i, j)] * c[(i, cc)]).sum::<f64>());
        }
    }
    let run = |table: &rkc::simd::KernelTable| {
        let argmin = table.argmin_dist2;
        let mut acc = 0usize;
        for j in 0..n {
            let (best, _) = argmin(&g[j * k..(j + 1) * k], yn[j], &cn);
            acc ^= best;
        }
        acc
    };
    let scalar = rkc::simd::scalar_table();
    let table = rkc::simd::dispatch();
    let before = bench(&format!("assign argmin scalar n={n} k={k}"), 1, iters, || {
        black_box(run(scalar))
    });
    let after = bench(
        &format!("assign argmin {:<6} n={n} k={k}", table.isa.name()),
        1,
        iters,
        || black_box(run(table)),
    );
    println!(
        "  => {} argmin speedup {:.1}x at n={n}, k={k}",
        table.isa.name(),
        before.median_s / after.median_s.max(1e-12)
    );
    Json::Obj(BTreeMap::from([
        ("bench".to_string(), Json::Str("kmeans_assign_argmin".to_string())),
        ("mode".to_string(), Json::Str("simd".to_string())),
        ("isa".to_string(), Json::Str(table.isa.name().to_string())),
        ("n".to_string(), Json::Num(n as f64)),
        ("r".to_string(), Json::Num(r as f64)),
        ("k".to_string(), Json::Num(k as f64)),
        ("restarts".to_string(), Json::Num(1.0)),
        ("threads".to_string(), Json::Num(1.0)),
        ("before_s".to_string(), Json::finite_num(before.median_s)),
        ("after_s".to_string(), Json::finite_num(after.median_s)),
        ("speedup".to_string(), Json::finite_num(before.median_s / after.median_s.max(1e-12))),
    ]))
}

fn main() {
    let quick = quick_mode();
    let iters = if quick { 1 } else { 7 };
    let mut records = Vec::new();

    println!("bench_kmeans: norm-identity + GEMM assignment vs pre-GEMM reference");
    if quick {
        records.push(kmeans_row(600, 2, 3, 3, 1, iters));
        // k=8 so even quick mode drives the 4-lane (AVX2) / 2-lane
        // (NEON) vector body, not just the scalar tail
        records.push(simd_row(600, 2, 8, iters));
    } else {
        // the pipeline shape (tiny r, few clusters), a wider embedding,
        // and a larger-n row; threads=1 is the algorithmic comparison
        records.push(kmeans_row(4096, 2, 2, 10, 1, iters));
        records.push(kmeans_row(4096, 8, 16, 10, 1, iters));
        records.push(kmeans_row(32768, 4, 8, 3, 1, iters.min(5)));
        let auto = available_threads();
        if auto > 1 {
            records.push(kmeans_row(4096, 8, 16, 10, auto, iters));
        }
        records.push(simd_row(32768, 8, 16, iters));
    }

    write_bench_json("BENCH_kmeans.json", records);
}
