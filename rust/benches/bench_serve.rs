//! Bench: serving throughput and latency of the `rkc::serve` runtime —
//! concurrent clients hammering a `ModelServer`'s micro-batch queue with
//! out-of-sample predict requests.
//!
//! Env knobs: `RKC_SERVE_N` (training size, default 1024),
//! `RKC_SERVE_CLIENTS` (concurrent client threads, default 4),
//! `RKC_SERVE_REQS` (requests per client, default 25),
//! `RKC_SERVE_POINTS` (query points per request, default 16).
//!
//! Besides the stdout summary, every run rewrites `BENCH_serve.json` in
//! the working directory so the serving perf trajectory is
//! machine-diffable across commits.

use std::collections::BTreeMap;
use std::time::Instant;

use rkc::api::KernelClusterer;
use rkc::data;
use rkc::rng::Pcg64;
use rkc::serve::{ModelServer, ServeOpts};
use rkc::util::{percentile, Json};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    // quick mode (RKC_BENCH_QUICK=1) shrinks the defaults to a CI smoke
    // shape; explicit RKC_SERVE_* env knobs still win
    let quick = rkc::bench_harness::quick_mode();
    let n = env_usize("RKC_SERVE_N", if quick { 256 } else { 1024 });
    let clients = env_usize("RKC_SERVE_CLIENTS", if quick { 2 } else { 4 }).max(1);
    let reqs = env_usize("RKC_SERVE_REQS", if quick { 5 } else { 25 }).max(1);
    let points_per_req = env_usize("RKC_SERVE_POINTS", if quick { 4 } else { 16 }).max(1);

    let ds = data::cross_lines(&mut Pcg64::seed(7), n);
    let t_fit = Instant::now();
    let model = KernelClusterer::new(2)
        .oversample(10)
        .seed(42)
        .threads(0)
        .fit(&ds.x)
        .expect("fit");
    let fit_s = t_fit.elapsed().as_secs_f64();
    let query = data::cross_lines(&mut Pcg64::seed(8), points_per_req).x;

    let server =
        ModelServer::new(model, ServeOpts { threads: 0, ..Default::default() }).expect("server");
    let t0 = Instant::now();
    let mut latencies_s: Vec<f64> = Vec::with_capacity(clients * reqs);
    std::thread::scope(|s| {
        let workers: Vec<_> = (0..clients)
            .map(|_| {
                let h = server.handle();
                let q = query.clone();
                s.spawn(move || {
                    let mut lat = Vec::with_capacity(reqs);
                    for _ in 0..reqs {
                        let t = Instant::now();
                        h.predict(q.clone()).expect("predict");
                        lat.push(t.elapsed().as_secs_f64());
                    }
                    lat
                })
            })
            .collect();
        for w in workers {
            latencies_s.extend(w.join().expect("client thread"));
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    server.shutdown();

    let total_reqs = (clients * reqs) as f64;
    let total_points = total_reqs * points_per_req as f64;
    let p50_ms = percentile(&latencies_s, 50.0) * 1e3;
    let p95_ms = percentile(&latencies_s, 95.0) * 1e3;
    let p99_ms = percentile(&latencies_s, 99.0) * 1e3;
    println!(
        "serve n={n} clients={clients} reqs/client={reqs} points/req={points_per_req}: \
         {:.0} req/s | {:.0} points/s | p50 {p50_ms:.2}ms p95 {p95_ms:.2}ms p99 {p99_ms:.2}ms \
         (fit {fit_s:.2}s, mean batch {:.2})",
        total_reqs / wall_s,
        total_points / wall_s,
        stats.mean_batch(),
    );

    let record = Json::Obj(BTreeMap::from([
        ("bench".to_string(), Json::Str("serve".to_string())),
        ("n_train".to_string(), Json::Num(n as f64)),
        ("clients".to_string(), Json::Num(clients as f64)),
        ("requests_per_client".to_string(), Json::Num(reqs as f64)),
        ("points_per_request".to_string(), Json::Num(points_per_req as f64)),
        ("fit_s".to_string(), Json::finite_num(fit_s)),
        ("wall_s".to_string(), Json::finite_num(wall_s)),
        ("requests_per_s".to_string(), Json::finite_num(total_reqs / wall_s)),
        ("points_per_s".to_string(), Json::finite_num(total_points / wall_s)),
        ("p50_ms".to_string(), Json::finite_num(p50_ms)),
        ("p95_ms".to_string(), Json::finite_num(p95_ms)),
        ("p99_ms".to_string(), Json::finite_num(p99_ms)),
        ("batches".to_string(), Json::Num(stats.batches as f64)),
        ("mean_batch".to_string(), Json::finite_num(stats.mean_batch())),
        ("mean_latency_us".to_string(), Json::finite_num(stats.mean_latency_us())),
    ]));
    // one-row array: every BENCH_*.json is a JSON array of row objects
    rkc::bench_harness::write_bench_json("BENCH_serve.json", vec![record]);
}
