//! Bench: serving throughput and latency of the `rkc::serve` runtime —
//! concurrent clients hammering a `ModelServer`'s micro-batch queue with
//! out-of-sample predict requests, three ways:
//!
//! 1. `in_process` — `ServerHandle::predict` straight into the batcher
//!    (no HTTP), the ceiling the front-end is measured against;
//! 2. `http_close` — one TCP connection **per request**
//!    (`Connection: close`), the pre-registry front-end's only mode;
//! 3. `http_keepalive` — one persistent connection per client, all of
//!    that client's requests riding it (HTTP/1.1 keep-alive).
//!
//! The keep-alive row carries `speedup_vs_close` so the
//! connection-reuse win is machine-diffable across commits.
//!
//! Env knobs: `RKC_SERVE_N` (training size, default 1024),
//! `RKC_SERVE_CLIENTS` (concurrent client threads, default 4),
//! `RKC_SERVE_REQS` (requests per client, default 25),
//! `RKC_SERVE_POINTS` (query points per request, default 16).
//!
//! Besides the stdout summary, every run rewrites `BENCH_serve.json` in
//! the working directory so the serving perf trajectory is
//! machine-diffable across commits.

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Instant;

use rkc::api::KernelClusterer;
use rkc::bench_harness::{latency_summary, MiniHttpClient};
use rkc::data;
use rkc::linalg::Mat;
use rkc::rng::Pcg64;
use rkc::serve::{serve_http_registry, HttpOpts, ModelRegistry, ServeOpts};
use rkc::util::Json;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn points_json(x: &Mat) -> String {
    let pts: Vec<Json> = (0..x.cols())
        .map(|j| Json::Arr((0..x.rows()).map(|i| Json::Num(x[(i, j)])).collect()))
        .collect();
    Json::Obj(BTreeMap::from([("points".to_string(), Json::Arr(pts))])).to_string()
}

/// Fan `clients` threads out over `reqs` requests each; `run` does one
/// request and returns nothing. Returns (wall seconds, per-request
/// latency seconds).
fn drive(
    clients: usize,
    reqs: usize,
    run: impl Fn(usize, &mut Vec<f64>) + Sync,
) -> (f64, Vec<f64>) {
    let t0 = Instant::now();
    let mut latencies_s: Vec<f64> = Vec::with_capacity(clients * reqs);
    std::thread::scope(|s| {
        let workers: Vec<_> = (0..clients)
            .map(|c| {
                let run = &run;
                s.spawn(move || {
                    let mut lat = Vec::with_capacity(reqs);
                    run(c, &mut lat);
                    lat
                })
            })
            .collect();
        for w in workers {
            latencies_s.extend(w.join().expect("client thread"));
        }
    });
    (t0.elapsed().as_secs_f64(), latencies_s)
}

fn record(
    mode: &str,
    n: usize,
    clients: usize,
    reqs: usize,
    points_per_req: usize,
    wall_s: f64,
    latencies_s: &[f64],
    extra: Vec<(String, Json)>,
) -> Json {
    let total_reqs = (clients * reqs) as f64;
    let total_points = total_reqs * points_per_req as f64;
    let lat = latency_summary(latencies_s);
    println!(
        "serve[{mode}] n={n} clients={clients} reqs/client={reqs} points/req={points_per_req}: \
         {:.0} req/s | {:.0} points/s | p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms",
        total_reqs / wall_s,
        total_points / wall_s,
        lat.p50_ms,
        lat.p95_ms,
        lat.p99_ms,
    );
    let mut fields = BTreeMap::from([
        ("bench".to_string(), Json::Str("serve".to_string())),
        ("mode".to_string(), Json::Str(mode.to_string())),
        ("n_train".to_string(), Json::Num(n as f64)),
        ("clients".to_string(), Json::Num(clients as f64)),
        ("requests_per_client".to_string(), Json::Num(reqs as f64)),
        ("points_per_request".to_string(), Json::Num(points_per_req as f64)),
        ("wall_s".to_string(), Json::finite_num(wall_s)),
        ("requests_per_s".to_string(), Json::finite_num(total_reqs / wall_s)),
        ("points_per_s".to_string(), Json::finite_num(total_points / wall_s)),
    ]);
    fields.extend(lat.json_fields(""));
    fields.extend(extra);
    Json::Obj(fields)
}

fn main() {
    // quick mode (RKC_BENCH_QUICK=1) shrinks the defaults to a CI smoke
    // shape; explicit RKC_SERVE_* env knobs still win
    let quick = rkc::bench_harness::quick_mode();
    let n = env_usize("RKC_SERVE_N", if quick { 256 } else { 1024 });
    let clients = env_usize("RKC_SERVE_CLIENTS", if quick { 2 } else { 4 }).max(1);
    let reqs = env_usize("RKC_SERVE_REQS", if quick { 5 } else { 25 }).max(1);
    let points_per_req = env_usize("RKC_SERVE_POINTS", if quick { 4 } else { 16 }).max(1);

    let ds = data::cross_lines(&mut Pcg64::seed(7), n);
    let t_fit = Instant::now();
    let model = KernelClusterer::new(2)
        .oversample(10)
        .seed(42)
        .threads(0)
        .fit(&ds.x)
        .expect("fit");
    let fit_s = t_fit.elapsed().as_secs_f64();
    let query = data::cross_lines(&mut Pcg64::seed(8), points_per_req).x;
    let body = points_json(&query);

    // --- row 1: in-process (no HTTP) --------------------------------
    let registry = Arc::new(ModelRegistry::new(ServeOpts { threads: 0, ..Default::default() }));
    registry.insert("default", model).expect("register model");
    let handle = registry.get("default").expect("handle");
    let (wall_s, latencies_s) = drive(clients, reqs, |_, lat| {
        let h = handle.clone();
        for _ in 0..reqs {
            let t = Instant::now();
            h.predict(query.clone()).expect("predict");
            lat.push(t.elapsed().as_secs_f64());
        }
    });
    let stats = registry.get("default").expect("handle").stats();
    let row_inproc = record(
        "in_process",
        n,
        clients,
        reqs,
        points_per_req,
        wall_s,
        &latencies_s,
        vec![
            ("fit_s".to_string(), Json::finite_num(fit_s)),
            ("batches".to_string(), Json::Num(stats.batches as f64)),
            ("mean_batch".to_string(), Json::finite_num(stats.mean_batch())),
            ("mean_latency_us".to_string(), Json::finite_num(stats.mean_latency_us())),
        ],
    );

    // --- rows 2+3: HTTP front-end, close vs keep-alive --------------
    let http = serve_http_registry(
        Arc::clone(&registry),
        "127.0.0.1:0",
        HttpOpts { workers: clients.max(2), ..Default::default() },
    )
    .expect("serve http");
    let addr: SocketAddr = http.local_addr();

    let (wall_s, latencies_s) = drive(clients, reqs, |_, lat| {
        for _ in 0..reqs {
            let t = Instant::now();
            let mut client = MiniHttpClient::connect(addr);
            let (status, _) = client.request_with("POST", "/predict", &body, true);
            assert_eq!(status, 200);
            lat.push(t.elapsed().as_secs_f64());
        }
    });
    let close_rps = (clients * reqs) as f64 / wall_s;
    let row_close = record(
        "http_close",
        n,
        clients,
        reqs,
        points_per_req,
        wall_s,
        &latencies_s,
        vec![("connections".to_string(), Json::Num((clients * reqs) as f64))],
    );

    let (wall_s, latencies_s) = drive(clients, reqs, |_, lat| {
        let mut client = MiniHttpClient::connect(addr);
        for _ in 0..reqs {
            let t = Instant::now();
            let (status, _) = client.request("POST", "/predict", &body);
            assert_eq!(status, 200);
            lat.push(t.elapsed().as_secs_f64());
        }
    });
    let keepalive_rps = (clients * reqs) as f64 / wall_s;
    let fe = http.frontend_stats();
    assert!(
        fe.requests > fe.connections,
        "keep-alive must reuse connections ({} requests over {} connections)",
        fe.requests,
        fe.connections
    );
    let row_keepalive = record(
        "http_keepalive",
        n,
        clients,
        reqs,
        points_per_req,
        wall_s,
        &latencies_s,
        vec![
            ("connections".to_string(), Json::Num(clients as f64)),
            ("speedup_vs_close".to_string(), Json::finite_num(keepalive_rps / close_rps)),
        ],
    );
    println!(
        "keep-alive vs close: {keepalive_rps:.0} vs {close_rps:.0} req/s ({:.2}x); \
         front-end saw {} requests over {} connections",
        keepalive_rps / close_rps,
        fe.requests,
        fe.connections
    );
    http.shutdown();

    // --- row 4: obs overhead (in-process hot path, recording on/off) -
    // same drive as row 1; the delta is the cost of the request/batch
    // counters + latency histogram on the serving fast path
    let measure = |on: bool| {
        rkc::obs::set_enabled(on);
        drive(clients, reqs, |_, lat| {
            let h = handle.clone();
            for _ in 0..reqs {
                let t = Instant::now();
                h.predict(query.clone()).expect("predict");
                lat.push(t.elapsed().as_secs_f64());
            }
        })
    };
    let _ = measure(true); // warm-up, discarded
    let (on_s, on_lat) = measure(true);
    let (off_s, _) = measure(false);
    rkc::obs::set_enabled(true);
    let obs_overhead_pct = (on_s / off_s - 1.0) * 100.0;
    println!(
        "obs overhead: instrumented {on_s:.3}s vs disabled {off_s:.3}s ({obs_overhead_pct:+.1}%)"
    );
    let row_obs = record(
        "obs_overhead",
        n,
        clients,
        reqs,
        points_per_req,
        on_s,
        &on_lat,
        vec![("obs_overhead_pct".to_string(), Json::finite_num(obs_overhead_pct))],
    );

    // --- row 5: opt-in f32 serving path vs the default f64 path -----
    // a fresh fit of the same model (fitting always stays f64);
    // `set_precision(F32)` swaps only the embed/predict leg. The
    // accuracy guard rides the row as `f32_max_abs_dev`: the largest
    // |f32 − f64| embedding deviation on the bench query batch.
    let mut model_f32 = KernelClusterer::new(2)
        .oversample(10)
        .seed(42)
        .threads(0)
        .fit(&ds.x)
        .expect("fit f32 model");
    let y64 = model_f32.embed(&query).expect("embed f64");
    model_f32.set_precision(rkc::config::Precision::F32);
    let y32 = model_f32.embed(&query).expect("embed f32");
    let f32_max_abs_dev = y32.sub(&y64).max_abs();
    registry.insert("f32", model_f32).expect("register f32 model");
    let handle_f32 = registry.get("f32").expect("f32 handle");
    // discarded warm-up: the freshly inserted server's batch worker
    // (and any remaining lazy state) must not land inside the timed
    // pass — the f64 comparison handle has been warm for rows 1-4
    let _ = drive(clients, reqs, |_, lat| {
        let h = handle_f32.clone();
        for _ in 0..reqs {
            let t = Instant::now();
            h.predict(query.clone()).expect("predict");
            lat.push(t.elapsed().as_secs_f64());
        }
    });
    let (f64_s, _) = drive(clients, reqs, |_, lat| {
        let h = handle.clone();
        for _ in 0..reqs {
            let t = Instant::now();
            h.predict(query.clone()).expect("predict");
            lat.push(t.elapsed().as_secs_f64());
        }
    });
    let (f32_s, f32_lat) = drive(clients, reqs, |_, lat| {
        let h = handle_f32.clone();
        for _ in 0..reqs {
            let t = Instant::now();
            h.predict(query.clone()).expect("predict");
            lat.push(t.elapsed().as_secs_f64());
        }
    });
    let f32_speedup = f64_s / f32_s.max(1e-12);
    println!(
        "f32 path: {f32_s:.3}s vs f64 {f64_s:.3}s ({f32_speedup:.2}x); \
         max |f32-f64| embedding deviation {f32_max_abs_dev:.3e}"
    );
    let row_f32 = record(
        "f32_path",
        n,
        clients,
        reqs,
        points_per_req,
        f32_s,
        &f32_lat,
        vec![
            ("speedup".to_string(), Json::finite_num(f32_speedup)),
            ("f32_max_abs_dev".to_string(), Json::finite_num(f32_max_abs_dev)),
        ],
    );

    rkc::bench_harness::write_bench_json(
        "BENCH_serve.json",
        vec![row_inproc, row_close, row_keepalive, row_obs, row_f32],
    );
}
