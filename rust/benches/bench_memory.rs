//! Bench: the paper's headline — memory at matched accuracy (§1: "around
//! 10 times lower memory" than Nyström; quadratically less than exact).
//!
//! For both workloads, finds the smallest Nyström m whose mean error
//! matches ours, then reports the persistent-memory ratio.

use rkc::config::{ExperimentConfig, Method};
use rkc::coordinator::{build_dataset, run_trials};
use rkc::metrics::{MemoryModel, Table};

fn main() {
    let trials: usize = std::env::var("RKC_TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    for (name, mut cfg) in [
        ("table1/cross_lines", ExperimentConfig::table1()),
        ("fig3/segmentation", ExperimentConfig::default()),
    ] {
        cfg.trials = trials;
        let ds = build_dataset(&cfg).expect("dataset");
        let n_pad = ds.n().next_power_of_two();
        println!("bench_memory: {name} (n={}, r'={})", ds.n(), cfg.sketch_width());

        let mut c = cfg.clone();
        c.method = Method::OnePass;
        let ours = run_trials(&c, &ds, None).expect("ours");
        let ours_mem =
            MemoryModel::one_pass(ds.n(), n_pad, cfg.sketch_width(), cfg.rank, cfg.batch);

        let mut table = Table::new(
            &format!("{name}: memory to reach ours' error ({:.3})", ours.error_mean),
            &["method", "approx err", "persistent MiB", "ratio vs ours"],
        );
        let mib = |b: usize| b as f64 / (1024.0 * 1024.0);
        table.row(vec![
            format!("ours r'={}", cfg.sketch_width()),
            format!("{:.3}", ours.error_mean),
            format!("{:.3}", mib(ours_mem.persistent)),
            "1.0x".into(),
        ]);

        let mut matched = None;
        for m in [10, 20, 30, 50, 70, 100, 150] {
            let mut c = cfg.clone();
            c.method = Method::Nystrom { m };
            let agg = run_trials(&c, &ds, None).expect("nystrom");
            let mem = MemoryModel::nystrom(ds.n(), m, cfg.rank);
            let ratio = mem.persistent as f64 / ours_mem.persistent as f64;
            table.row(vec![
                format!("nystrom m={m}"),
                format!("{:.3}", agg.error_mean),
                format!("{:.3}", mib(mem.persistent)),
                format!("{ratio:.1}x"),
            ]);
            if matched.is_none() && agg.error_mean <= ours.error_mean * 1.02 {
                matched = Some((m, ratio));
            }
        }
        let dense = MemoryModel::exact_dense(ds.n());
        table.row(vec![
            "exact (dense EVD)".into(),
            "optimal".into(),
            format!("{:.1}", mib(dense.persistent)),
            format!("{:.0}x", dense.persistent as f64 / ours_mem.persistent as f64),
        ]);
        print!("{}", table.render());
        match matched {
            Some((m, ratio)) => println!(
                "=> Nyström needs m≈{m} to match our error: {ratio:.1}× our memory (paper: ≈10×)\n"
            ),
            None => println!("=> no m ≤ 150 matched our error: ratio > {:.1}×\n",
                MemoryModel::nystrom(ds.n(), 150, cfg.rank).persistent as f64
                    / ours_mem.persistent as f64),
        }
    }
}
