//! Bench: the paper's headline — memory at matched accuracy (§1: "around
//! 10 times lower memory" than Nyström; quadratically less than exact).
//!
//! For both workloads, finds the smallest Nyström m whose mean error
//! matches ours, then reports the persistent-memory ratio.
//!
//! Every run rewrites `BENCH_memory.json`: one object per (workload,
//! method) with `{bench, workload, method, approx_err,
//! persistent_bytes, ratio_vs_ours}`. `RKC_BENCH_QUICK=1` shrinks n,
//! trials, and the m-grid to a CI smoke shape.

use std::collections::BTreeMap;

use rkc::bench_harness::{quick_mode, write_bench_json};
use rkc::config::{ExperimentConfig, Method};
use rkc::coordinator::{build_dataset, run_trials};
use rkc::metrics::{MemoryModel, Table};
use rkc::util::Json;

fn main() {
    let quick = quick_mode();
    let trials: usize = std::env::var("RKC_TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if quick { 1 } else { 5 });
    let mut records: Vec<Json> = Vec::new();
    let mut record = |workload: &str, method: String, err: f64, bytes: usize, ratio: f64| {
        records.push(Json::Obj(BTreeMap::from([
            ("bench".to_string(), Json::Str("memory".to_string())),
            ("workload".to_string(), Json::Str(workload.to_string())),
            ("method".to_string(), Json::Str(method)),
            ("approx_err".to_string(), Json::finite_num(err)),
            ("persistent_bytes".to_string(), Json::Num(bytes as f64)),
            ("ratio_vs_ours".to_string(), Json::finite_num(ratio)),
        ])));
    };
    for (name, mut cfg) in [
        ("table1/cross_lines", ExperimentConfig::table1()),
        ("fig3/segmentation", ExperimentConfig::default()),
    ] {
        cfg.trials = trials;
        if quick {
            cfg.n = 320;
            // force the synthetic generator: a real data/segmentation.csv
            // would override cfg.n with the full 2310-row dataset
            cfg.data_dir = "data-quick-disabled".into();
        }
        let ds = build_dataset(&cfg).expect("dataset");
        let n_pad = ds.n().next_power_of_two();
        println!("bench_memory: {name} (n={}, r'={})", ds.n(), cfg.sketch_width());

        let mut c = cfg.clone();
        c.method = Method::OnePass;
        let ours = run_trials(&c, &ds, None).expect("ours");
        let ours_mem =
            MemoryModel::one_pass(ds.n(), n_pad, cfg.sketch_width(), cfg.rank, cfg.batch);

        let mut table = Table::new(
            &format!("{name}: memory to reach ours' error ({:.3})", ours.error_mean),
            &["method", "approx err", "persistent MiB", "ratio vs ours"],
        );
        let mib = |b: usize| b as f64 / (1024.0 * 1024.0);
        table.row(vec![
            format!("ours r'={}", cfg.sketch_width()),
            format!("{:.3}", ours.error_mean),
            format!("{:.3}", mib(ours_mem.persistent)),
            "1.0x".into(),
        ]);
        record(name, format!("ours r'={}", cfg.sketch_width()), ours.error_mean,
            ours_mem.persistent, 1.0);

        let m_grid: &[usize] = if quick { &[10, 50] } else { &[10, 20, 30, 50, 70, 100, 150] };
        let mut matched = None;
        for &m in m_grid {
            let mut c = cfg.clone();
            c.method = Method::Nystrom { m };
            let agg = run_trials(&c, &ds, None).expect("nystrom");
            let mem = MemoryModel::nystrom(ds.n(), m, cfg.rank);
            let ratio = mem.persistent as f64 / ours_mem.persistent as f64;
            table.row(vec![
                format!("nystrom m={m}"),
                format!("{:.3}", agg.error_mean),
                format!("{:.3}", mib(mem.persistent)),
                format!("{ratio:.1}x"),
            ]);
            record(name, format!("nystrom m={m}"), agg.error_mean, mem.persistent, ratio);
            if matched.is_none() && agg.error_mean <= ours.error_mean * 1.02 {
                matched = Some((m, ratio));
            }
        }
        let dense = MemoryModel::exact_dense(ds.n());
        table.row(vec![
            "exact (dense EVD)".into(),
            "optimal".into(),
            format!("{:.1}", mib(dense.persistent)),
            format!("{:.0}x", dense.persistent as f64 / ours_mem.persistent as f64),
        ]);
        record(name, "exact_dense".to_string(), f64::NAN, dense.persistent,
            dense.persistent as f64 / ours_mem.persistent as f64);
        print!("{}", table.render());
        match matched {
            Some((m, ratio)) => println!(
                "=> Nyström needs m≈{m} to match our error: {ratio:.1}× our memory (paper: ≈10×)\n"
            ),
            None => println!("=> no m ≤ {} matched our error: ratio > {:.1}×\n",
                m_grid.last().copied().unwrap_or(150),
                MemoryModel::nystrom(ds.n(), m_grid.last().copied().unwrap_or(150), cfg.rank)
                    .persistent as f64
                    / ours_mem.persistent as f64),
        }
    }
    write_bench_json("BENCH_memory.json", records);
}
