//! Subcommand implementations shared by the CLI binary — thin clients of
//! the `rkc::api` layer plus table formatting.

use std::collections::BTreeMap;
use std::time::Instant;

use rkc::api::{Embedder, FittedModel, KernelClusterer, OnePassEmbedder};
use rkc::clustering::{kernel_kmeans_objective, kmeans, KmeansOpts};
use rkc::config::{ExperimentConfig, Method};
use rkc::coordinator::{build_dataset, run_trials};
use rkc::data;
use rkc::error::Result;
use rkc::kernels::full_kernel_matrix;
use rkc::linalg::Mat;
use rkc::lowrank::{exact_topr_dense, trace_norm_error_psd};
use rkc::metrics::{MemoryModel, Table};
use rkc::rng::Pcg64;
use rkc::runtime::ArtifactRegistry;

pub fn cmd_run(cfg: &ExperimentConfig, registry: Option<&ArtifactRegistry>) -> Result<()> {
    let ds = build_dataset(cfg)?;
    println!(
        "dataset={} method={} backend={} r={} l={} trials={}",
        ds.name, cfg.method, cfg.backend, cfg.rank, cfg.oversample, cfg.trials
    );
    let agg = run_trials(cfg, &ds, registry)?;
    let mut t = Table::new(
        "Run result",
        &["method", "trials", "accuracy", "nmi", "approx_err", "peak_mem_MiB", "time_s"],
    );
    t.row(vec![
        agg.method.clone(),
        agg.trials.to_string(),
        format!("{:.3} ± {:.3}", agg.accuracy_mean, agg.accuracy_std),
        format!("{:.3}", agg.nmi_mean),
        format!("{:.3} ± {:.3}", agg.error_mean, agg.error_std),
        format!("{:.2}", agg.peak_memory_bytes as f64 / (1024.0 * 1024.0)),
        format!("{:.2}", agg.total_time.as_secs_f64()),
    ]);
    print!("{}", t.render());
    Ok(())
}

/// Table 1: exact / ours / Nyström m=20 / m=100 on the Fig-1 synthetic
/// set, plus the plain K-means reference mentioned in its caption.
pub fn cmd_table1(cfg: &ExperimentConfig, registry: Option<&ArtifactRegistry>) -> Result<()> {
    let ds = build_dataset(cfg)?;
    println!("Table 1 — {} kernel={} r={} l={} ({} trials of stochastic methods)",
        ds.name, cfg.kernel.describe(), cfg.rank, cfg.oversample, cfg.trials);
    let methods = [
        Method::Exact,
        Method::OnePass,
        Method::Nystrom { m: 20 },
        Method::Nystrom { m: 100 },
        Method::PlainKmeans,
    ];
    let mut t = Table::new(
        "Table 1 (paper: exact 0.40/0.99, ours 0.40/0.99, nys20 0.56/0.74, nys100 0.44/0.75, plain –/0.53)",
        &["method", "kernel approx err", "clustering accuracy"],
    );
    for m in methods {
        let mut c = cfg.clone();
        c.method = m;
        let agg = run_trials(&c, &ds, registry)?;
        t.row(vec![
            agg.method.clone(),
            if agg.error_mean.is_nan() {
                "–".into()
            } else {
                format!("{:.2}", agg.error_mean)
            },
            format!("{:.2}", agg.accuracy_mean),
        ]);
        eprintln!("  {} done in {:.1}s", agg.method, agg.total_time.as_secs_f64());
    }
    print!("{}", t.render());
    Ok(())
}

/// Fig. 1 + Fig. 2: dump the raw data and the embeddings produced by the
/// exact decomposition and by our method, as CSV for plotting.
pub fn cmd_fig2(
    cfg: &ExperimentConfig,
    _registry: Option<&ArtifactRegistry>,
    out_dir: &str,
) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let ds = build_dataset(cfg)?;

    // Fig. 1: original data + plain K-means centroids
    let mut rng = Pcg64::seed(cfg.seed);
    let km = kmeans(&ds.x, &KmeansOpts::paper(ds.k), &mut rng);
    data::write_points_csv(&format!("{out_dir}/fig1_data.csv"), &ds.x, &ds.labels)?;
    data::write_points_csv(
        &format!("{out_dir}/fig1_centroids.csv"),
        &km.centroids,
        &(0..ds.k).collect::<Vec<_>>(),
    )?;

    // Fig. 2(a): exact rank-r embedding; (b): our one-pass embedding.
    // Streaming exact: O(rn) memory even at the full n = 4000.
    let mut src = rkc::kernels::NativeBlockSource::pow2(ds.x.clone(), cfg.kernel);
    let exact = rkc::lowrank::exact_topr_streaming_threaded(
        &mut src,
        cfg.rank,
        40,
        cfg.batch,
        rkc::util::parallel::resolve_threads(cfg.threads).max(1),
    );
    data::write_points_csv(&format!("{out_dir}/fig2a_exact.csv"), &exact.y, &ds.labels)?;

    // one-pass embedding via the method object (no K-means needed here)
    let one_pass = OnePassEmbedder {
        rank: cfg.rank,
        oversample: cfg.oversample,
        batch: cfg.batch,
        threads: rkc::util::parallel::resolve_threads(cfg.threads).max(1),
    };
    let mut rng2 = Pcg64::seed_stream(cfg.seed, 0xf162);
    let ours = one_pass.embed(&mut src, &mut rng2)?.embedding;
    data::write_points_csv(&format!("{out_dir}/fig2b_ours.csv"), &ours.y, &ds.labels)?;

    // quantitative proxy for "almost identical to exact": streamed
    // reconstruction errors
    let err_exact = rkc::lowrank::streamed_frobenius_error(&mut src, &exact, cfg.batch);
    let err_ours = rkc::lowrank::streamed_frobenius_error(&mut src, &ours, cfg.batch);
    println!("fig2: wrote {out_dir}/fig1_data.csv, fig1_centroids.csv, fig2a_exact.csv, fig2b_ours.csv");
    println!("fig2: exact err={err_exact:.4}  ours err={err_ours:.4} (paper: both 0.40)");
    Ok(())
}

/// Fig. 3: normalized approximation error (a) and clustering accuracy
/// (b) for Nyström with m ∈ sweep, vs ours (r' = r + l fixed) and the
/// exact decomposition, on the segmentation workload.
pub fn cmd_fig3(
    cfg: &ExperimentConfig,
    registry: Option<&ArtifactRegistry>,
    out_dir: &str,
) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let ds = build_dataset(cfg)?;
    println!(
        "Fig 3 — {} kernel={} r={} l={} trials={}",
        ds.name, cfg.kernel.describe(), cfg.rank, cfg.oversample, cfg.trials
    );

    // reference lines
    let mut c = cfg.clone();
    c.method = Method::Exact;
    let exact = run_trials(&c, &ds, registry)?;
    c.method = Method::OnePass;
    let ours = run_trials(&c, &ds, registry)?;
    c.method = Method::FullKernel;
    c.trials = 1;
    let full = run_trials(&c, &ds, registry)?;

    let sweep: Vec<usize> = vec![10, 20, 30, 40, 50, 60, 80, 100];
    let mut t = Table::new(
        "Fig. 3 series (paper shape: ours ≈ exact; Nyström needs m ≈ 7–8·r' to catch up)",
        &["method", "m", "approx err (a)", "accuracy (b)"],
    );
    t.row(vec!["exact".into(), "–".into(), format!("{:.3}", exact.error_mean),
        format!("{:.3}", exact.accuracy_mean)]);
    t.row(vec![format!("ours (r'={})", cfg.sketch_width()), "–".into(),
        format!("{:.3}", ours.error_mean), format!("{:.3}", ours.accuracy_mean)]);
    t.row(vec!["full kernel k-means".into(), "–".into(), "0.000".into(),
        format!("{:.3}", full.accuracy_mean)]);

    let mut rows = Vec::new();
    for &m in &sweep {
        let mut c = cfg.clone();
        c.method = Method::Nystrom { m };
        let agg = run_trials(&c, &ds, registry)?;
        t.row(vec![
            "nystrom".into(),
            m.to_string(),
            format!("{:.3}", agg.error_mean),
            format!("{:.3}", agg.accuracy_mean),
        ]);
        rows.push(vec![m as f64, agg.error_mean, agg.accuracy_mean]);
        eprintln!("  nystrom m={m} done in {:.1}s", agg.total_time.as_secs_f64());
    }
    print!("{}", t.render());

    rkc::metrics::write_csv(
        &format!("{out_dir}/fig3_nystrom_sweep.csv"),
        &["m", "approx_error", "accuracy"],
        &rows,
    )?;
    rkc::metrics::write_csv(
        &format!("{out_dir}/fig3_references.csv"),
        &["exact_err", "exact_acc", "ours_err", "ours_acc", "full_acc"],
        &[vec![exact.error_mean, exact.accuracy_mean, ours.error_mean, ours.accuracy_mean,
               full.accuracy_mean]],
    )?;
    println!("fig3: wrote {out_dir}/fig3_nystrom_sweep.csv, fig3_references.csv");
    Ok(())
}

/// Theorem 1: L(Ĉ) − L(C*) ≤ 2‖E‖_* (any PSD approx) and ≤ tr(E) (best
/// rank-r approx), validated on dense instances where the optimal
/// partitions can be found reliably by many restarts.
pub fn cmd_theorem1(cfg: &ExperimentConfig) -> Result<()> {
    let mut t = Table::new(
        "Theorem 1 — clustering suboptimality vs trace-norm bounds",
        &["n", "r", "L(Chat)", "L(C*)", "gap", "tr(E)", "2||E||_*", "gap ≤ tr(E)", "gap ≤ 2||E||_*"],
    );
    let mut rng = Pcg64::seed(cfg.seed);
    for &(n, r) in &[(60usize, 1usize), (80, 2), (100, 2), (120, 3)] {
        let ds = data::gaussian_blobs(&mut rng, n, 3, 3, 0.8);
        let kmat = full_kernel_matrix(&ds.x, cfg.kernel);
        let emb = exact_topr_dense(&kmat, r);

        // optimal (well, best-of-many) partitions under K and K̂
        let opts = KmeansOpts { k: 3, restarts: 60, max_iters: 100, tol: 1e-12 };
        let mut rng_a = Pcg64::seed(1);
        let chat = kmeans(&emb.y, &opts, &mut rng_a);
        let l_chat = kernel_kmeans_objective(&kmat, &chat.labels, 3);
        let mut rng_b = Pcg64::seed(2);
        let cstar_lbl = best_kernel_partition(&kmat, 3, &mut rng_b);
        let l_cstar = kernel_kmeans_objective(&kmat, &cstar_lbl, 3);

        let gap = (l_chat - l_cstar).max(0.0);
        let tr_e = (kmat.trace() - khat_trace(&emb)).max(0.0);
        let tn_e = trace_norm_error_psd(&kmat, &emb);
        t.row(vec![
            n.to_string(),
            r.to_string(),
            format!("{l_chat:.3}"),
            format!("{l_cstar:.3}"),
            format!("{gap:.3}"),
            format!("{tr_e:.3}"),
            format!("{:.3}", 2.0 * tn_e),
            (gap <= tr_e + 1e-6).to_string(),
            (gap <= 2.0 * tn_e + 1e-6).to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn khat_trace(emb: &rkc::lowrank::Embedding) -> f64 {
    // tr(YᵀY) = ||Y||_F²
    emb.y.frobenius_norm().powi(2)
}

fn best_kernel_partition(kmat: &Mat, k: usize, rng: &mut Pcg64) -> Vec<usize> {
    let res = rkc::clustering::kernel_kmeans(kmat, k, 60, 200, rng);
    res.labels
}

/// Memory model comparison (the paper's headline axis).
pub fn cmd_memory(cfg: &ExperimentConfig) -> Result<()> {
    let n = cfg.n;
    let n_pad = n.next_power_of_two();
    let rp = cfg.sketch_width();
    let mut t = Table::new(
        &format!("Peak working-set model, n={n} (r={}, r'={rp}, batch={})", cfg.rank, cfg.batch),
        &["method", "persistent MiB", "peak MiB", "vs ours (persistent)"],
    );
    let ours = MemoryModel::one_pass(n, n_pad, rp, cfg.rank, cfg.batch);
    let mib = |b: usize| b as f64 / (1024.0 * 1024.0);
    let mut row = |m: rkc::metrics::MethodMemory| {
        let ratio = m.persistent as f64 / ours.persistent as f64;
        t.row(vec![
            m.method.clone(),
            format!("{:.2}", mib(m.persistent)),
            format!("{:.2}", mib(m.peak())),
            format!("{ratio:.1}x"),
        ]);
    };
    row(ours.clone());
    for m in [10, 20, 50, 100] {
        row(MemoryModel::nystrom(n, m, cfg.rank));
    }
    row(MemoryModel::exact_streaming(n, n_pad, cfg.rank, cfg.batch));
    row(MemoryModel::exact_dense(n));
    row(MemoryModel::full_kernel_kmeans(n, cfg.k));
    print!("{}", t.render());
    Ok(())
}

/// `rkc save` — fit once on the configured dataset and persist the model
/// through the builder's artifacts-dir-driven auto-save.
pub fn cmd_save(cfg: &ExperimentConfig, registry: Option<&ArtifactRegistry>) -> Result<()> {
    let ds = build_dataset(cfg)?;
    let path = cfg.resolved_model_path();
    let t0 = Instant::now();
    let model = KernelClusterer::from_config(cfg)
        .clusters(ds.k)
        .auto_save(path.as_str())
        .fit_with_registry(&ds.x, registry)?;
    let acc = rkc::clustering::accuracy(model.labels(), &ds.labels, ds.k);
    let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    println!(
        "fit {} on {} (n={}, k={}) in {:.2}s — in-sample accuracy {acc:.3}",
        cfg.method,
        ds.name,
        ds.n(),
        ds.k,
        t0.elapsed().as_secs_f64()
    );
    println!("saved model to {path} ({bytes} bytes)");
    Ok(())
}

/// `rkc predict` — load a saved model and assign query points offline.
/// Queries come from `--data points.csv` (one comma-separated coordinate
/// row per point) or, absent that, the configured dataset. Output is one
/// machine-readable JSON object on stdout.
pub fn cmd_predict(cfg: &ExperimentConfig, data_csv: Option<&str>) -> Result<()> {
    let path = cfg.resolved_model_path();
    let model = FittedModel::load(&path)?;
    let (source, x) = match data_csv {
        Some(f) => (f.to_string(), rkc::data::load_points_csv(f)?),
        None => {
            let ds = build_dataset(cfg)?;
            (ds.name, ds.x)
        }
    };
    let labels = model.predict(&x)?;
    let out = rkc::util::Json::Obj(BTreeMap::from([
        ("model".to_string(), rkc::util::Json::Str(path)),
        ("source".to_string(), rkc::util::Json::Str(source)),
        ("n".to_string(), rkc::util::Json::Num(labels.len() as f64)),
        (
            "labels".to_string(),
            rkc::util::Json::Arr(
                labels.iter().map(|&l| rkc::util::Json::Num(l as f64)).collect(),
            ),
        ),
    ]));
    println!("{out}");
    Ok(())
}

/// `rkc serve` — serve saved model(s) over HTTP until the process is
/// stopped. `--models DIR` loads every `.rkc` in the directory into the
/// registry (name = file stem, runtime `PUT`/`DELETE /models/{name}`
/// load/unload more); otherwise the single `--model` file is served
/// under the name `default`. Either way the legacy `/predict`/`/embed`
/// routes alias the default model.
pub fn cmd_serve(cfg: &ExperimentConfig) -> Result<()> {
    use rkc::serve::{serve_http_registry, HttpOpts, ModelRegistry, ServeOpts};
    use std::sync::Arc;
    use std::time::Duration;

    // an explicit --precision forces every hosted model onto that
    // serving path (f64 included, so a model persisted with f32 can be
    // forced back to double precision); unset respects each model's
    // own persisted precision header
    let precision_override = cfg.precision;
    let registry = Arc::new(ModelRegistry::new(ServeOpts {
        threads: cfg.threads,
        precision: precision_override,
        ..Default::default()
    }));
    if let Some(p) = precision_override {
        eprintln!("serving precision forced to {p} for every hosted model");
    }
    if cfg.models_dir.is_empty() {
        let path = cfg.resolved_model_path();
        registry.load("default", &path)?;
        eprintln!("loaded default: {path}");
    } else {
        let names = registry.load_dir(&cfg.models_dir)?;
        eprintln!("loaded {} model(s) from {}: {}", names.len(), cfg.models_dir, names.join(", "));
    }
    for info in registry.list() {
        eprintln!(
            "  {}{}: method={} n={} k={} rank={}",
            info.name,
            if info.is_default { " (default)" } else { "" },
            info.method,
            info.n_train,
            info.k,
            info.rank
        );
    }
    let http = serve_http_registry(
        registry,
        &cfg.serve_addr,
        HttpOpts {
            workers: cfg.http_workers,
            keep_alive: Duration::from_secs(cfg.keep_alive_s),
            ..Default::default()
        },
    )?;
    println!(
        "rkc serve: listening on http://{} (POST /models/{{name}}/predict|embed, GET /models, \
         PUT/DELETE /models/{{name}}, GET /healthz; /predict and /embed hit the default model)",
        http.local_addr()
    );
    http.wait();
    Ok(())
}

/// `rkc stream` — online one-pass clustering over an unbounded-style
/// source. Points arrive in `--chunk`-sized batches from one of:
///
/// - `--scenario moving_blobs|label_churn` — the synthetic drift
///   generators (drift magnitude `--drift`, `--n` total points);
/// - `--data points.csv` (or `--data -` for stdin) — CSV coordinates;
/// - the configured `--dataset` otherwise (stationary replay).
///
/// Each batch folds into the running SRHT sketch; whenever the refresh
/// policy fires (`--refresh_points` / `--refresh_secs`), the model is
/// refit warm-started from the previous labels and atomically published
/// into the registry under the name `stream` with a new generation.
/// `--stream_http true` additionally serves every published generation
/// on `--addr` while ingestion continues.
pub fn cmd_stream(cfg: &ExperimentConfig, data_csv: Option<&str>) -> Result<()> {
    use rkc::serve::{serve_http_registry, HttpOpts, ModelRegistry, ServeOpts};
    use rkc::stream::{CheckpointPolicy, Checkpointer, StreamClusterer};
    use std::io::Read as _;
    use std::sync::Arc;
    use std::time::Duration;

    // --- source: synthetic drift scenario, CSV/stdin, or dataset replay
    let chunk = cfg.chunk.max(1);
    let mut drift: Option<data::DriftStream> = match cfg.scenario.as_str() {
        "" => None,
        "moving_blobs" => {
            Some(data::DriftStream::moving_blobs(cfg.seed, cfg.p, cfg.k, 0.5, cfg.drift))
        }
        "label_churn" => {
            Some(data::DriftStream::label_churn(cfg.seed, cfg.p, cfg.k, 0.5, cfg.drift))
        }
        other => {
            return Err(rkc::error::RkcError::invalid_config(format!(
                "unknown scenario '{other}' (expected moving_blobs or label_churn)"
            )))
        }
    };
    // finite replay source: full matrix + truth labels (when known)
    let replay: Option<(Mat, Vec<usize>)> = if drift.is_some() {
        None
    } else {
        match data_csv {
            Some("-") => {
                let mut text = String::new();
                std::io::stdin().read_to_string(&mut text)?;
                Some((data::parse_points_csv("stdin", &text)?, Vec::new()))
            }
            Some(f) => Some((data::load_points_csv(f)?, Vec::new())),
            None => {
                let ds = build_dataset(cfg)?;
                Some((ds.x, ds.labels))
            }
        }
    };
    let total = replay.as_ref().map(|(x, _)| x.cols()).unwrap_or(cfg.n);

    // --- crash recovery: an existing checkpoint wins over the flags
    // (its header carries the full fit configuration), so the exact
    // command that crashed can simply be re-run and it picks up from
    // the last durable `.rkcs` state instead of starting cold
    let resumed = !cfg.checkpoint_path.is_empty()
        && std::path::Path::new(&cfg.checkpoint_path).exists();
    let mut sc = if resumed {
        let sc = StreamClusterer::resume(&cfg.checkpoint_path)?;
        println!(
            "rkc stream: resumed from {} (n={}, {} refresh(es))",
            cfg.checkpoint_path,
            sc.n_points(),
            sc.refreshes()
        );
        sc
    } else {
        StreamClusterer::new(cfg.k)
            .kernel(cfg.kernel)
            .rank(cfg.rank)
            .oversample(cfg.oversample)
            .batch(cfg.batch)
            .seed(cfg.seed)
            .threads(cfg.threads)
            .kmeans_restarts(cfg.kmeans_restarts)
            .kmeans_iters(cfg.kmeans_iters)
            .kmeans_tol(cfg.kmeans_tol)
            .refresh_every_points(cfg.refresh_points)
            // config rejects non-finite/negative values; the cap keeps any
            // in-range f64 inside Duration::from_secs_f64's panic-free domain
            .refresh_every(Duration::from_secs_f64(cfg.refresh_secs.min(1.0e9)))
            .capacity(total)
    };
    let mut ckpt = (!cfg.checkpoint_path.is_empty()).then(|| {
        Checkpointer::new(
            cfg.checkpoint_path.as_str(),
            CheckpointPolicy {
                points: (cfg.checkpoint_points > 0).then_some(cfg.checkpoint_points),
                interval: (cfg.checkpoint_secs > 0.0)
                    .then(|| Duration::from_secs_f64(cfg.checkpoint_secs.min(1.0e9))),
                on_refresh: true,
            },
        )
    });

    // the registry (and the ModelServer each publish spins up inside
    // it) only exists when something can actually query it — without
    // --stream_http a plain refresh() gives the same generations with
    // no dead server churn inside the timed loop
    let serving = if cfg.stream_http {
        // same contract as `rkc serve`: an explicit `precision` forces
        // every published generation onto that serving path (the
        // registry stamps it in ModelServer::named on each publish)
        let precision_override = cfg.precision;
        let registry = Arc::new(ModelRegistry::new(ServeOpts {
            threads: cfg.threads,
            precision: precision_override,
            ..Default::default()
        }));
        if let Some(p) = precision_override {
            eprintln!("serving precision forced to {p} for every published generation");
        }
        let http = serve_http_registry(
            Arc::clone(&registry),
            &cfg.serve_addr,
            HttpOpts {
                workers: cfg.http_workers,
                keep_alive: Duration::from_secs(cfg.keep_alive_s),
                ..Default::default()
            },
        )?;
        println!("rkc stream: serving generations on http://{}", http.local_addr());
        Some((registry, http))
    } else {
        None
    };

    println!(
        "rkc stream: source={} total={total} chunk={chunk} refresh_points={} refresh_secs={}",
        if drift.is_some() {
            cfg.scenario.clone()
        } else {
            data_csv.map(str::to_string).unwrap_or_else(|| cfg.dataset.clone())
        },
        cfg.refresh_points,
        cfg.refresh_secs,
    );

    // Fast-forward a resumed run past what the checkpoint already holds:
    // the replay/scenario sources are deterministic, so skipping the
    // first `n_points()` draws re-aligns them with the saved state.
    let already = if resumed { sc.n_points().min(total) } else { 0 };
    let mut truth: Vec<usize> = Vec::new();
    let mut fed = 0usize;
    while fed < already {
        let m = chunk.min(already - fed);
        match (&mut drift, &replay) {
            (Some(d), _) => truth.extend_from_slice(&d.chunk(m).labels),
            (None, Some((_, labels))) => {
                if !labels.is_empty() {
                    truth.extend_from_slice(&labels[fed..fed + m]);
                }
            }
            (None, None) => unreachable!("stream source resolved above"),
        }
        fed += m;
    }
    while fed < total {
        let m = chunk.min(total - fed);
        let batch = match (&mut drift, &replay) {
            (Some(d), _) => {
                let ds = d.chunk(m);
                truth.extend_from_slice(&ds.labels);
                ds.x
            }
            (None, Some((x, labels))) => {
                if !labels.is_empty() {
                    truth.extend_from_slice(&labels[fed..fed + m]);
                }
                Mat::from_fn(x.rows(), m, |i, j| x[(i, fed + j)])
            }
            (None, None) => unreachable!("stream source resolved above"),
        };
        sc.ingest(&batch)?;
        fed += m;

        let flush = fed == total && sc.pending_points() > 0;
        let refreshed = (sc.refresh_due() || flush) && sc.can_refresh();
        if refreshed {
            let t0 = Instant::now();
            let generation = match &serving {
                Some((registry, _)) => sc.publish(registry, "stream")?,
                None => {
                    sc.refresh()?;
                    sc.refreshes()
                }
            };
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            let acc = sc
                .last_labels()
                .filter(|l| l.len() == truth.len())
                .map(|l| rkc::clustering::accuracy(l, &truth, cfg.k));
            println!(
                "  generation={generation} n={} refresh={ms:.1}ms{}",
                sc.n_points(),
                acc.map(|a| format!(" accuracy={a:.3}")).unwrap_or_default()
            );
        }
        if let Some(c) = ckpt.as_mut() {
            // a failed periodic checkpoint must not abort ingestion —
            // that would lose the very state it exists to protect. The
            // window stays open on failure, so the next chunk retries.
            if let Err(e) = c.maybe_write(&sc, m, refreshed) {
                eprintln!(
                    "rkc stream: checkpoint to {} failed ({e}); continuing, \
                     will retry at the next trigger",
                    c.path()
                );
            }
        }
    }
    // one final unconditional checkpoint so the saved state always
    // reflects the completed run (a rerun then resumes as a no-op)
    if let Some(c) = ckpt.as_mut() {
        c.write(&sc)?;
        println!("rkc stream: checkpointed state to {}", c.path());
    }
    println!(
        "rkc stream: ingested {} new point(s) ({} total), published {} generation(s)",
        fed - already,
        sc.n_points(),
        sc.refreshes()
    );
    if let Some((_registry, http)) = serving {
        http.wait();
    }
    Ok(())
}

/// `rkc experiment --plan plans/foo.plan [--out results.jsonl]`: run a
/// declarative grid or load-scenario plan (see `rkc::experiment`) and
/// write its JSONL report. `--threads` sets the grid runner's
/// parallelism only — per-trial thread counts come from the plan.
pub fn cmd_experiment(cfg: &ExperimentConfig) -> Result<()> {
    use rkc::error::RkcError;

    if cfg.plan_path.is_empty() {
        return Err(RkcError::invalid_config("experiment needs --plan <file.plan>"));
    }
    let text = std::fs::read_to_string(&cfg.plan_path)
        .map_err(|e| RkcError::io(format!("reading plan {}", cfg.plan_path), e))?;
    let t0 = Instant::now();
    let report = rkc::experiment::run_plan_text(&text, cfg.threads)?;
    let out = if cfg.out_path.is_empty() {
        // exp_<stem>.jsonl: the exp_* prefix is what CI globs for the
        // artifact upload next to BENCH_*.json
        let stem = std::path::Path::new(&cfg.plan_path)
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("experiment");
        format!("exp_{stem}.jsonl")
    } else {
        cfg.out_path.clone()
    };
    std::fs::write(&out, &report.jsonl).map_err(|e| RkcError::io(format!("writing {out}"), e))?;
    println!(
        "experiment: {} ({} plan, hash {:016x}) -> {} row(s) in {} [{:.2}s]",
        cfg.plan_path,
        report.kind,
        report.plan_hash,
        report.rows,
        out,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

pub fn cmd_artifacts(registry: Option<&ArtifactRegistry>) -> Result<()> {
    match registry {
        None => println!("no artifacts/ directory (run `make artifacts`)"),
        Some(reg) => {
            println!("platform: {}", reg.platform());
            for name in reg.names() {
                let info = reg.info(&name).unwrap();
                println!(
                    "  {:36} {:>12} inputs={:?} outputs={:?}",
                    info.name,
                    info.params.get("op").cloned().unwrap_or_default(),
                    info.inputs,
                    info.outputs
                );
            }
        }
    }
    Ok(())
}
