//! `rkc::stream` — online one-pass clustering with live model hot-swap.
//!
//! A [`StreamClusterer`] ingests point batches from an unbounded source
//! and folds them into a *running* SRHT sketch `W = K Ω` without ever
//! materializing the kernel matrix. On a configurable refresh policy
//! (every N points, every T seconds, or on demand) it runs the paper's
//! recovery step on the accumulated sketch, re-clusters with a K-means
//! warm-started from the previous generation's assignment, and publishes
//! the resulting [`FittedModel`] into a live
//! [`ModelRegistry`](crate::serve::ModelRegistry) — requests racing the
//! swap see the old model or the new one, never a blend.
//!
//! # The incremental fold
//!
//! The batch pipeline streams *columns* of a fixed kernel matrix; here
//! the matrix itself grows. When `m` new points arrive (global indices
//! `n_old..n_old+m`), one padded kernel block `kb = K[:, new]`
//! (`n_cap × m`, rows above the current count zero) yields **both**
//! halves of the update:
//!
//! 1. the new sketch rows `W[new, :]` via the usual scale-by-`D` →
//!    FWHT → row-gather ([`Srht::apply_to_block_with`]), and
//! 2. the fold of the new columns into every existing row — by symmetry
//!    `K[j, new_c] = kb[(j, c)]`, so
//!    `W[j, s] += Σ_c kb[(j, c)] · Ω[n_old + c, s]`
//!    with `Ω` entries generated on the fly
//!    ([`Srht::omega_entry`]) — zero extra kernel evaluations.
//!
//! The padded rows of the operator are **not** masked: future points
//! will claim those Rademacher signs, and masking is redundant anyway
//! (kernel blocks zero-pad their rows, and the recovery's
//! `QᵀΩ`-via-FWHT implicitly zero-pads `Q`).
//!
//! When the point count outgrows the operator (`n > n_cap`), a fresh
//! SRHT is drawn deterministically at the next power of two and the
//! sketch is rebuilt by one bulk pass over the buffered points —
//! amortized O(1) redraws per doubling.
//!
//! # Determinism
//!
//! Every published generation independently satisfies the crate's
//! `threads = 1 ≡ threads = N` contract: the fold writes disjoint sketch
//! rows per worker with a fixed per-entry accumulation order, the FWHT
//! path is per-column independent, and the warm-started K-means is a
//! pure function of (embedding, previous labels). Fix the seed and the
//! ingest sequence and the g-th published model is bit-identical
//! regardless of thread count — and round-trips bit-exactly through
//! `.rkc` save/load like any batch fit.
//!
//! # Memory bound
//!
//! The running state is the sketch `W` (n × r' doubles), the operator
//! (`n_cap` signs + r' indices), and the raw point buffer (p × n,
//! retained so refreshed models can answer out-of-sample queries) —
//! O(n·(p + r')) total, never O(n²).

use std::sync::OnceLock;
use std::time::{Duration, Instant};

use crate::api::{Assigner, FitMetrics, FittedModel};
use crate::clustering::{kmeans_threaded, kmeans_warm_threaded, KmeansOpts};
use crate::error::{Result, RkcError};
use crate::kernels::{column_batches, Kernel};
use crate::linalg::Mat;
use crate::lowrank::{one_pass_recovery_threaded, OnePassSketch};
use crate::metrics::MemoryModel;
use crate::obs;
use crate::rng::Pcg64;
use crate::serve::ModelRegistry;
use crate::sketch::{next_pow2, Srht};
use crate::util::parallel;

mod checkpoint;
pub use checkpoint::{CheckpointPolicy, Checkpointer, STATE_MAGIC, STATE_VERSION};

/// Process-wide metric handles for the streaming layer, registered once
/// and shared by every [`StreamClusterer`] in the process (Prometheus
/// series are global; per-instance state stays on the clusterer itself).
/// The memory gauges put the [`MemoryModel`] *prediction* next to the
/// bytes actually held, so model-vs-actual drift is visible on a scrape.
struct StreamObs {
    ingest_seconds: std::sync::Arc<obs::Histogram>,
    refresh_seconds: std::sync::Arc<obs::Histogram>,
    refreshes_total: std::sync::Arc<obs::Counter>,
    points: std::sync::Arc<obs::Gauge>,
    sketch_bytes: std::sync::Arc<obs::Gauge>,
    buffer_bytes: std::sync::Arc<obs::Gauge>,
    model_bytes: std::sync::Arc<obs::Gauge>,
}

fn stream_obs() -> &'static StreamObs {
    static OBS: OnceLock<StreamObs> = OnceLock::new();
    OBS.get_or_init(|| {
        let r = obs::registry();
        StreamObs {
            ingest_seconds: r.histogram(
                "rkc_stream_ingest_seconds",
                "Wall time folding one ingested chunk into the running sketch.",
                &[],
                obs::latency_buckets(),
            ),
            refresh_seconds: r.histogram(
                "rkc_stream_refresh_seconds",
                "Wall time of one refresh (recovery + K-means).",
                &[],
                obs::latency_buckets(),
            ),
            refreshes_total: r.counter(
                "rkc_stream_refreshes_total",
                "Refreshes (model generations produced) across all streams.",
                &[],
            ),
            points: r.gauge(
                "rkc_stream_points",
                "Points ingested by the most recently active stream.",
                &[],
            ),
            sketch_bytes: r.gauge(
                "rkc_stream_sketch_bytes",
                "Bytes actually held by the running sketch state (W + operator).",
                &[],
            ),
            buffer_bytes: r.gauge(
                "rkc_stream_buffer_bytes",
                "Bytes actually held by the retained raw point buffer.",
                &[],
            ),
            model_bytes: r.gauge(
                "rkc_stream_memory_model_bytes",
                "MemoryModel::one_pass persistent-bytes prediction for the current stream shape.",
                &[],
            ),
        }
    })
}

/// Sub-stream of the master seed the SRHT operators draw from (the
/// g-th redraw consumes the next draw of this one stream, so the
/// operator sequence depends only on seed + capacity crossings).
const SRHT_STREAM: u64 = 0x57cea;
/// Sub-stream for the cold-start K-means of refresh g (warm refreshes
/// consume no randomness at all).
const KMEANS_STREAM: u64 = 0x57c1d;

/// When a [`StreamClusterer`] considers a refresh due: after `points`
/// newly ingested points, after `interval` wall time, or — with both
/// unset (the default) — only on explicit demand.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RefreshPolicy {
    /// refresh once this many points arrived since the last refresh
    pub points: Option<usize>,
    /// refresh once this much wall time passed since the last refresh
    pub interval: Option<Duration>,
}

/// Online one-pass kernel clusterer: ingest → fold → refresh → publish.
///
/// Built like [`KernelClusterer`](crate::api::KernelClusterer) (same
/// defaults, consuming setters), then driven imperatively:
/// [`ingest`](Self::ingest) point chunks, check
/// [`refresh_due`](Self::refresh_due), and either take a refreshed
/// [`FittedModel`](Self::refresh) or
/// [`publish`](Self::publish) it straight into a registry under a
/// monotone generation number.
///
/// ```
/// use rkc::stream::StreamClusterer;
/// use rkc::data;
/// use rkc::rng::Pcg64;
///
/// let mut sc = StreamClusterer::new(2).oversample(10).seed(7);
/// let ds = data::cross_lines(&mut Pcg64::seed(3), 256);
/// sc.ingest(&ds.x)?;
/// let model = sc.refresh()?;
/// let acc = rkc::clustering::accuracy(model.labels(), &ds.labels, 2);
/// assert!(acc > 0.9, "streamed accuracy {acc}");
/// # Ok::<(), rkc::error::RkcError>(())
/// ```
pub struct StreamClusterer {
    // configuration (consuming setters, fixed once ingestion starts)
    k: usize,
    kernel: Kernel,
    rank: usize,
    oversample: usize,
    batch: usize,
    seed: u64,
    threads: usize,
    kmeans_restarts: usize,
    kmeans_iters: usize,
    kmeans_tol: f64,
    policy: RefreshPolicy,
    capacity_hint: usize,
    // runtime state
    p: Option<usize>,
    /// point-major buffer: point j occupies `buf[j*p..(j+1)*p]`
    buf: Vec<f64>,
    n: usize,
    srht: Option<Srht>,
    srht_rng: Option<Pcg64>,
    /// running sketch `W = K Ω`, row-major n × r'
    w: Vec<f64>,
    scratch: Vec<f64>,
    prev_labels: Option<Vec<usize>>,
    refreshes: u64,
    points_since_refresh: usize,
    last_refresh: Instant,
    /// cumulative ingest/fold time since the last refresh — becomes the
    /// published model's `sketch_time`
    fold_time: Duration,
}

impl StreamClusterer {
    /// A stream clusterer for `k` clusters with the paper's defaults
    /// (quadratic kernel, r = 2, l = 5, 10×20 K-means) and no automatic
    /// refresh policy (refreshes happen on demand).
    pub fn new(k: usize) -> Self {
        StreamClusterer {
            k,
            kernel: Kernel::paper_poly2(),
            rank: 2,
            oversample: 5,
            batch: 256,
            seed: 2016,
            threads: 1,
            kmeans_restarts: 10,
            kmeans_iters: 20,
            kmeans_tol: 1e-9,
            policy: RefreshPolicy::default(),
            capacity_hint: 0,
            p: None,
            buf: Vec::new(),
            n: 0,
            srht: None,
            srht_rng: None,
            w: Vec::new(),
            scratch: Vec::new(),
            prev_labels: None,
            refreshes: 0,
            points_since_refresh: 0,
            last_refresh: Instant::now(),
            fold_time: Duration::ZERO,
        }
    }

    /// The Mercer kernel to cluster under.
    pub fn kernel(mut self, kernel: Kernel) -> Self {
        self.kernel = kernel;
        self
    }

    /// Embedding rank r.
    pub fn rank(mut self, rank: usize) -> Self {
        self.rank = rank;
        self
    }

    /// Oversampling l; the sketch width is r' = r + l.
    pub fn oversample(mut self, oversample: usize) -> Self {
        self.oversample = oversample;
        self
    }

    /// Column-batch width for the bulk rebuild passes.
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch.max(1);
        self
    }

    /// Master seed: the SRHT draw/redraw sequence and every cold-start
    /// K-means derive from it through split PCG streams.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Worker threads (0 = auto-detect); bit-identical results for any
    /// value, per the crate determinism contract.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// K-means++ restarts for the *cold* (first) refresh; warm refreshes
    /// run one Lloyd descent from the inherited centroids.
    pub fn kmeans_restarts(mut self, restarts: usize) -> Self {
        self.kmeans_restarts = restarts;
        self
    }

    /// Lloyd-iteration cap per refresh.
    pub fn kmeans_iters(mut self, iters: usize) -> Self {
        self.kmeans_iters = iters;
        self
    }

    /// Relative objective-improvement tolerance for K-means early stop.
    pub fn kmeans_tol(mut self, tol: f64) -> Self {
        self.kmeans_tol = tol;
        self
    }

    /// Consider a refresh due every `points` newly ingested points.
    pub fn refresh_every_points(mut self, points: usize) -> Self {
        self.policy.points = if points == 0 { None } else { Some(points) };
        self
    }

    /// Consider a refresh due every `interval` of wall time.
    pub fn refresh_every(mut self, interval: Duration) -> Self {
        self.policy.interval =
            if interval == Duration::ZERO { None } else { Some(interval) };
        self
    }

    /// Pre-size the SRHT operator for roughly this many points, so
    /// streams with a known scale avoid the early redraw/rebuild cycles
    /// (the operator capacity is `next_pow2(max(hint, n, r'))`).
    pub fn capacity(mut self, points: usize) -> Self {
        self.capacity_hint = points;
        self
    }

    /// r' = r + l, the sketch width.
    pub fn sketch_width(&self) -> usize {
        self.rank + self.oversample
    }

    /// Points ingested so far.
    pub fn n_points(&self) -> usize {
        self.n
    }

    /// Points ingested since the last refresh.
    pub fn pending_points(&self) -> usize {
        self.points_since_refresh
    }

    /// Refreshes performed so far (== the generation the *next* publish
    /// into a fresh registry would receive, minus any external bumps).
    pub fn refreshes(&self) -> u64 {
        self.refreshes
    }

    /// The latest refresh's training labels (None before any refresh).
    pub fn last_labels(&self) -> Option<&[usize]> {
        self.prev_labels.as_deref()
    }

    /// Bytes held by the running sketch state (sketch rows + operator),
    /// excluding the raw point buffer — the paper's O(r'n) figure.
    pub fn sketch_bytes(&self) -> usize {
        let f64s = std::mem::size_of::<f64>();
        let op = self.srht.as_ref().map_or(0, |s| {
            s.d.len() * f64s + s.idx.len() * std::mem::size_of::<usize>()
        });
        self.w.len() * f64s + op
    }

    /// Bytes held by the retained raw point buffer (kept so refreshed
    /// models can answer out-of-sample `embed`/`predict`).
    pub fn buffer_bytes(&self) -> usize {
        self.buf.len() * std::mem::size_of::<f64>()
    }

    /// Whether the configured policy (points and/or interval) says a
    /// refresh is due. Always false while nothing new was ingested; on
    /// demand-only streams (no policy) it is never true — call
    /// [`refresh`](Self::refresh) directly.
    pub fn refresh_due(&self) -> bool {
        if self.points_since_refresh == 0 {
            return false;
        }
        if let Some(points) = self.policy.points {
            if self.points_since_refresh >= points {
                return true;
            }
        }
        if let Some(interval) = self.policy.interval {
            if self.last_refresh.elapsed() >= interval {
                return true;
            }
        }
        false
    }

    /// Whether enough points arrived for a refresh to succeed
    /// (`n ≥ max(k, r')`; below that [`refresh`](Self::refresh) is a
    /// typed error).
    pub fn can_refresh(&self) -> bool {
        self.n >= self.k.max(self.sketch_width()).max(1)
    }

    fn threads_resolved(&self) -> usize {
        parallel::resolve_threads(self.threads).max(1)
    }

    /// Ingest a chunk of points (p × m, columns are samples) into the
    /// running sketch. The first chunk fixes the stream's dimension p;
    /// later chunks must match it. O(n·m) kernel evaluations — each
    /// new column is evaluated against every point exactly once, ever.
    pub fn ingest(&mut self, chunk: &Mat) -> Result<()> {
        let m = chunk.cols();
        if m == 0 {
            return Err(RkcError::invalid_config("cannot ingest an empty chunk"));
        }
        match self.p {
            None => {
                if chunk.rows() == 0 {
                    return Err(RkcError::invalid_config(
                        "cannot ingest zero-dimensional points",
                    ));
                }
                self.p = Some(chunk.rows());
            }
            Some(p) if p != chunk.rows() => {
                return Err(RkcError::invalid_config(format!(
                    "chunk dimension {} does not match the stream dimension {p}",
                    chunk.rows()
                )))
            }
            _ => {}
        }
        let p = self.p.expect("just set");
        let t0 = Instant::now();
        let n_old = self.n;
        self.buf.reserve(m * p);
        for j in 0..m {
            for i in 0..p {
                self.buf.push(chunk[(i, j)]);
            }
        }
        self.n = n_old + m;

        let needs_rebuild = match &self.srht {
            None => true,
            Some(s) => self.n > s.n,
        };
        if needs_rebuild {
            self.rebuild_operator();
        } else {
            self.fold_chunk(n_old, m);
        }
        self.points_since_refresh += m;
        let folded = t0.elapsed();
        self.fold_time += folded;

        // strictly out-of-band: nothing below feeds back into the sketch
        let o = stream_obs();
        o.ingest_seconds.observe(folded.as_secs_f64());
        obs::record_span("stream.ingest", folded);
        o.points.set(self.n as u64);
        o.sketch_bytes.set(self.sketch_bytes() as u64);
        o.buffer_bytes.set(self.buffer_bytes() as u64);
        if let Some(srht) = &self.srht {
            let predicted =
                MemoryModel::one_pass(self.n, srht.n, self.sketch_width(), self.rank, self.batch)
                    .persistent;
            o.model_bytes.set(predicted as u64);
        }
        Ok(())
    }

    /// Incremental fold of `m` freshly buffered points (global indices
    /// `n_old..n_old+m`) into the running sketch — see the module docs
    /// for the math.
    fn fold_chunk(&mut self, n_old: usize, m: usize) {
        let StreamClusterer { srht, buf, w, scratch, kernel, p, threads, n, .. } = self;
        let srht = srht.as_ref().expect("fold requires a drawn operator");
        let buf: &[f64] = buf;
        let (p, threads) = ((*p).expect("points buffered"), parallel::resolve_threads(*threads).max(1));
        let rp = srht.samples();
        let n_new = *n;

        // one padded kernel block K[:, new]: all current rows × the m
        // new columns (padded rows stay zero)
        let mut kb = Mat::zeros(srht.n, m);
        {
            let kernel = *kernel;
            let live = &mut kb.data_mut()[..n_new * m];
            parallel::for_each_row_chunk(live, m, threads, |first, rows| {
                for (di, row) in rows.chunks_mut(m).enumerate() {
                    let i = first + di;
                    let xi = &buf[i * p..(i + 1) * p];
                    for (c, slot) in row.iter_mut().enumerate() {
                        let zc = &buf[(n_old + c) * p..(n_old + c + 1) * p];
                        *slot = kernel.eval(xi, zc);
                    }
                }
            });
        }

        // half 1: the new columns' own sketch rows, via the FWHT path
        let rows = srht.apply_to_block_with(&kb, threads, scratch);
        w.extend_from_slice(rows.data());

        // half 2: fold the new columns into every existing row. By
        // symmetry K[j, new_c] = kb[(j, c)], so no kernel re-evaluation;
        // disjoint rows per worker + a fixed (c ascending, s ascending)
        // per-entry order keep this bit-identical for any thread count.
        if n_old > 0 {
            // only the m × r' Ω block for the new rows is ever read here;
            // tabulate it once instead of redoing the popcount-based
            // omega_entry for every one of the n_old existing rows
            // (same values, same (c asc, s asc) order ⇒ bit-identical)
            let mut om = vec![0.0; m * rp];
            for (c, orow) in om.chunks_mut(rp).enumerate() {
                for (s, o) in orow.iter_mut().enumerate() {
                    *o = srht.omega_entry(n_old + c, s);
                }
            }
            let om = &om;
            let w_old = &mut w[..n_old * rp];
            parallel::for_each_row_chunk(w_old, rp, threads, |first, out| {
                for (dj, wrow) in out.chunks_mut(rp).enumerate() {
                    let j = first + dj;
                    for c in 0..m {
                        let kjc = kb[(j, c)];
                        if kjc == 0.0 {
                            continue;
                        }
                        let orow = &om[c * rp..(c + 1) * rp];
                        for (ws, o) in wrow.iter_mut().zip(orow) {
                            *ws += kjc * o;
                        }
                    }
                }
            });
        }
    }

    /// Draw (or redraw) the SRHT at the capacity the current point count
    /// demands and rebuild the whole sketch with one bulk pass over the
    /// buffer. Draws come from a dedicated PCG stream of the master
    /// seed, so the operator sequence is reproducible.
    fn rebuild_operator(&mut self) {
        let rp = self.sketch_width();
        let cap = next_pow2(self.n.max(rp).max(self.capacity_hint.max(1)));
        let rng = self
            .srht_rng
            .get_or_insert_with(|| Pcg64::seed_stream(self.seed, SRHT_STREAM));
        let srht = Srht::draw(rng, cap, rp);

        let StreamClusterer { buf, w, scratch, kernel, p, threads, n, batch, .. } = self;
        let buf: &[f64] = buf;
        let (p, threads) = ((*p).expect("points buffered"), parallel::resolve_threads(*threads).max(1));
        let (n, batch, kernel) = (*n, *batch, *kernel);
        w.clear();
        w.reserve(n * rp);
        let mut kb = Mat::zeros(srht.n, 0);
        for cols in column_batches(n, batch) {
            let b = cols.len();
            if kb.cols() != b {
                kb = Mat::zeros(srht.n, b);
            }
            let j0 = cols[0];
            let live = &mut kb.data_mut()[..n * b];
            parallel::for_each_row_chunk(live, b, threads, |first, rows| {
                for (di, row) in rows.chunks_mut(b).enumerate() {
                    let i = first + di;
                    let xi = &buf[i * p..(i + 1) * p];
                    for (c, slot) in row.iter_mut().enumerate() {
                        let zc = &buf[(j0 + c) * p..(j0 + c + 1) * p];
                        *slot = kernel.eval(xi, zc);
                    }
                }
            });
            let rows = srht.apply_to_block_with(&kb, threads, scratch);
            w.extend_from_slice(rows.data());
        }
        self.srht = Some(srht);
    }

    /// Run recovery + K-means on the current sketch and return the
    /// refreshed model (generation 0 — publishing through a registry
    /// stamps the real one). The first refresh cold-starts K-means++
    /// with the configured restarts; later refreshes warm-start one
    /// Lloyd descent from the previous generation's assignment, re-based
    /// into the *new* embedding (per-cluster means of the new embedding
    /// columns grouped by the old labels), which is invariant to the
    /// eigenbasis sign/rotation flips between refreshes.
    pub fn refresh(&mut self) -> Result<FittedModel> {
        let n = self.n;
        let rp = self.sketch_width();
        if n == 0 {
            return Err(RkcError::invalid_config(
                "refresh before any points were ingested",
            ));
        }
        if self.k == 0 || self.rank == 0 {
            return Err(RkcError::invalid_config(
                "k and rank must both be at least 1",
            ));
        }
        if self.k > n {
            return Err(RkcError::invalid_config(format!(
                "k={} clusters exceed the {n} points ingested so far",
                self.k
            )));
        }
        if rp > n {
            return Err(RkcError::invalid_config(format!(
                "sketch width r'={rp} exceeds the {n} points ingested so far"
            )));
        }
        let threads = self.threads_resolved();
        let refresh_t0 = Instant::now();
        let srht = self.srht.as_ref().expect("points exist, so the operator does");
        let n_pad = srht.n;

        // wrap the accumulated rows as a complete one-pass sketch and
        // run the batch recovery on it. from_rows takes the W matrix
        // directly — one clone (streaming continues on self.w), no
        // column-by-column re-ingest copy on the latency-measured path
        let t0 = Instant::now();
        let sketch =
            OnePassSketch::from_rows(srht.clone(), Mat::from_vec(n, rp, self.w.clone()));
        let embedding = one_pass_recovery_threaded(&sketch, self.rank, threads);
        let recovery_time = t0.elapsed();

        let kopts = KmeansOpts {
            k: self.k,
            restarts: self.kmeans_restarts,
            max_iters: self.kmeans_iters,
            tol: self.kmeans_tol,
        };
        let t1 = Instant::now();
        let res = match self.prev_labels.as_deref() {
            Some(prev) => {
                let init = warm_centroids(&embedding.y, prev, self.k);
                kmeans_warm_threaded(&embedding.y, &init, &kopts, threads)
            }
            None => {
                let mut rng = Pcg64::seed_stream(
                    self.seed,
                    KMEANS_STREAM.wrapping_add(self.refreshes),
                );
                kmeans_threaded(&embedding.y, &kopts, &mut rng, threads)
            }
        };
        let kmeans_time = t1.elapsed();

        self.prev_labels = Some(res.labels.clone());
        self.refreshes += 1;
        let sketch_time = self.fold_time;
        self.fold_time = Duration::ZERO;
        self.points_since_refresh = 0;
        self.last_refresh = Instant::now();

        // out-of-band: the refresh shares the batch pipeline's per-stage
        // series (streaming fold time stands in for the sketch pass)
        let o = stream_obs();
        o.refreshes_total.inc();
        o.refresh_seconds.observe(refresh_t0.elapsed().as_secs_f64());
        obs::record_span("stream.refresh", refresh_t0.elapsed());
        obs::record_stage("sketch", sketch_time);
        obs::record_stage("recovery", recovery_time);
        obs::record_stage("kmeans", kmeans_time);

        let p = self.p.expect("points buffered");
        let buf = &self.buf;
        let x = Mat::from_fn(p, n, |i, j| buf[j * p + i]);
        Ok(FittedModel {
            kernel: self.kernel,
            k: self.k,
            labels: res.labels,
            assigner: Assigner::Embedded { centroids: res.centroids },
            train_x: Some(x),
            train_cols: OnceLock::new(),
            precision: crate::config::Precision::F64,
            f32_state: OnceLock::new(),
            generation: 0,
            n_pad,
            batch: self.batch,
            metrics: FitMetrics {
                method: "stream_one_pass".into(),
                n,
                rank: embedding.rank(),
                objective: res.objective,
                memory: MemoryModel::one_pass(n, n_pad, rp, self.rank, self.batch),
                sketch_time,
                recovery_time,
                kmeans_time,
            },
            embedding: Some(embedding),
        })
    }

    /// [`refresh`](Self::refresh) and atomically publish the result into
    /// `registry` under `name`; returns the generation the registry
    /// stamped. In-flight requests see the previous generation or this
    /// one — never a mixture (see
    /// [`ModelRegistry::publish`](crate::serve::ModelRegistry::publish)).
    pub fn publish(&mut self, registry: &ModelRegistry, name: &str) -> Result<u64> {
        let model = self.refresh()?;
        registry.publish(name, model)
    }
}

/// Warm-start centroids: per-cluster means of the new embedding's
/// columns, grouped by the previous generation's labels (over the prefix
/// both generations share). Rotation-invariant — old centroid
/// *coordinates* are meaningless after the eigenbasis moves, but old
/// *membership* transfers directly. Clusters with no previous members
/// start at the origin and are repaired by the Lloyd loop's
/// empty-cluster handling.
fn warm_centroids(y: &Mat, prev: &[usize], k: usize) -> Mat {
    let r = y.rows();
    let shared = prev.len().min(y.cols());
    let mut counts = vec![0usize; k];
    let mut c = Mat::zeros(r, k);
    for j in 0..shared {
        let g = prev[j];
        counts[g] += 1;
        for i in 0..r {
            c[(i, g)] += y[(i, j)];
        }
    }
    for (g, &cnt) in counts.iter().enumerate() {
        if cnt > 0 {
            let inv = 1.0 / cnt as f64;
            for i in 0..r {
                c[(i, g)] *= inv;
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::accuracy;
    use crate::data;

    pub(crate) fn chunked(x: &Mat, width: usize) -> Vec<Mat> {
        let (p, n) = (x.rows(), x.cols());
        let mut out = Vec::new();
        let mut j0 = 0;
        while j0 < n {
            let m = width.min(n - j0);
            out.push(Mat::from_fn(p, m, |i, j| x[(i, j0 + j)]));
            j0 += m;
        }
        out
    }

    /// Dense reference: W[j, s] = Σ_i K[j, i]·Ω[i, s] over the real
    /// points only (padded rows of K are zero by construction).
    fn dense_sketch(x: &Mat, kernel: Kernel, srht: &Srht) -> Mat {
        let n = x.cols();
        let rp = srht.samples();
        let cols: Vec<Vec<f64>> = (0..n).map(|j| x.col(j)).collect();
        let mut w = Mat::zeros(n, rp);
        for j in 0..n {
            for i in 0..n {
                let kij = kernel.eval(&cols[i], &cols[j]);
                for s in 0..rp {
                    w[(j, s)] += kij * srht.omega_entry(i, s);
                }
            }
        }
        w
    }

    #[test]
    fn incremental_fold_matches_dense_reference() {
        let ds = data::gaussian_blobs(&mut Pcg64::seed(11), 90, 3, 3, 0.4);
        let mut sc = StreamClusterer::new(3).oversample(5).seed(5).capacity(90);
        for chunk in chunked(&ds.x, 17) {
            sc.ingest(&chunk).unwrap();
        }
        let srht = sc.srht.as_ref().unwrap();
        let reference = dense_sketch(&ds.x, sc.kernel, srht);
        assert_eq!(sc.w.len(), reference.data().len());
        let scale = reference.data().iter().fold(1.0f64, |a, v| a.max(v.abs()));
        for (got, want) in sc.w.iter().zip(reference.data()) {
            assert!(
                (got - want).abs() <= 1e-9 * scale,
                "fold diverged from dense sketch: {got} vs {want}"
            );
        }
    }

    #[test]
    fn capacity_regrowth_rebuilds_an_equivalent_sketch() {
        // no capacity hint: 20 points fit in cap 32, the next 30 force a
        // redraw at 64 — the rebuilt sketch must still match the dense
        // reference under the *new* operator
        let ds = data::gaussian_blobs(&mut Pcg64::seed(12), 50, 4, 2, 0.5);
        let mut sc = StreamClusterer::new(2).oversample(4).seed(9);
        for chunk in chunked(&ds.x, 10) {
            sc.ingest(&chunk).unwrap();
        }
        let srht = sc.srht.as_ref().unwrap();
        assert_eq!(srht.n, 64, "50 points should have forced a 64-cap redraw");
        let reference = dense_sketch(&ds.x, sc.kernel, srht);
        let scale = reference.data().iter().fold(1.0f64, |a, v| a.max(v.abs()));
        for (got, want) in sc.w.iter().zip(reference.data()) {
            assert!((got - want).abs() <= 1e-9 * scale);
        }
        // and a refresh on the regrown state still clusters
        let model = sc.refresh().unwrap();
        assert_eq!(model.labels().len(), 50);
        assert_eq!(model.n_padded(), 64);
    }

    #[test]
    fn published_generations_are_thread_count_invariant() {
        let ds = data::cross_lines(&mut Pcg64::seed(21), 240);
        let chunks = chunked(&ds.x, 60);
        let run = |threads: usize| {
            let mut sc = StreamClusterer::new(2)
                .oversample(10)
                .seed(33)
                .threads(threads)
                .capacity(240);
            let mut models = Vec::new();
            for chunk in &chunks {
                sc.ingest(chunk).unwrap();
                if sc.can_refresh() {
                    models.push(sc.refresh().unwrap());
                }
            }
            models
        };
        let base = run(1);
        assert!(base.len() >= 2, "expected a cold and at least one warm refresh");
        for threads in [2, 4, 7] {
            let other = run(threads);
            assert_eq!(base.len(), other.len());
            for (a, b) in base.iter().zip(&other) {
                assert_eq!(a.labels(), b.labels(), "threads={threads}");
                let (ea, eb) = (a.embedding().unwrap(), b.embedding().unwrap());
                assert_eq!(ea.y.data(), eb.y.data(), "threads={threads}");
                assert_eq!(ea.eigenvalues, eb.eigenvalues, "threads={threads}");
                assert_eq!(
                    a.centroids().unwrap().data(),
                    b.centroids().unwrap().data(),
                    "threads={threads}"
                );
            }
        }
    }

    #[test]
    fn refresh_policy_triggers() {
        let ds = data::gaussian_blobs(&mut Pcg64::seed(13), 60, 3, 2, 0.3);
        let chunks = chunked(&ds.x, 20);
        // on-demand stream: never due by itself
        let mut demand = StreamClusterer::new(2).seed(1).capacity(60);
        demand.ingest(&chunks[0]).unwrap();
        assert!(!demand.refresh_due());
        // point-count policy
        let mut byn = StreamClusterer::new(2)
            .seed(1)
            .capacity(60)
            .refresh_every_points(40);
        byn.ingest(&chunks[0]).unwrap();
        assert!(!byn.refresh_due(), "20 < 40 points");
        byn.ingest(&chunks[1]).unwrap();
        assert!(byn.refresh_due(), "40 >= 40 points");
        byn.refresh().unwrap();
        assert!(!byn.refresh_due(), "counter resets on refresh");
        // wall-time policy: a zero-ish interval is due as soon as
        // anything new arrived
        let mut byt = StreamClusterer::new(2)
            .seed(1)
            .capacity(60)
            .refresh_every(Duration::from_nanos(1));
        byt.ingest(&chunks[0]).unwrap();
        std::thread::sleep(Duration::from_millis(2));
        assert!(byt.refresh_due());
    }

    #[test]
    fn warm_refresh_tracks_the_stream_accurately() {
        let ds = data::cross_lines(&mut Pcg64::seed(30), 300);
        let mut sc = StreamClusterer::new(2).oversample(10).seed(8).capacity(300);
        let mut seen = 0usize;
        for chunk in chunked(&ds.x, 100) {
            sc.ingest(&chunk).unwrap();
            seen += chunk.cols();
            let model = sc.refresh().unwrap();
            let acc = accuracy(model.labels(), &ds.labels[..seen], 2);
            assert!(acc > 0.9, "generation at n={seen} has accuracy {acc}");
            assert_eq!(model.metrics().method, "stream_one_pass");
        }
        assert_eq!(sc.refreshes(), 3);
    }

    #[test]
    fn refreshed_models_roundtrip_and_predict_out_of_sample() {
        let _g = crate::fault::test_guard(); // saves cross a failpoint site
        let ds = data::cross_lines(&mut Pcg64::seed(40), 200);
        let mut sc = StreamClusterer::new(2).oversample(10).seed(4).capacity(200);
        sc.ingest(&ds.x).unwrap();
        let model = sc.refresh().unwrap();
        let novel = data::cross_lines(&mut Pcg64::seed(41), 32);
        let direct = model.predict(&novel.x).unwrap();
        let path = std::env::temp_dir()
            .join(format!("rkc_stream_model_{}.rkc", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        model.save(&path).unwrap();
        let back = FittedModel::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.predict(&novel.x).unwrap(), direct);
        assert_eq!(back.labels(), model.labels());
    }

    #[test]
    fn publish_stamps_monotone_generations_into_the_registry() {
        use crate::serve::{ModelRegistry, ServeOpts};
        let ds = data::gaussian_blobs(&mut Pcg64::seed(50), 120, 3, 3, 0.3);
        let registry = ModelRegistry::new(ServeOpts::default());
        let mut sc = StreamClusterer::new(3).oversample(5).seed(2).capacity(120);
        let mut generation = 0;
        for chunk in chunked(&ds.x, 40) {
            sc.ingest(&chunk).unwrap();
            generation = sc.publish(&registry, "stream").unwrap();
        }
        assert_eq!(generation, 3);
        let info = registry
            .list()
            .into_iter()
            .find(|i| i.name == "stream")
            .expect("published model listed");
        assert_eq!(info.generation, 3);
        assert_eq!(info.n_train, 120);
    }

    #[test]
    fn ingest_and_refresh_reject_bad_shapes() {
        let mut sc = StreamClusterer::new(2);
        assert!(sc.refresh().is_err(), "refresh before any ingest");
        assert!(sc.ingest(&Mat::zeros(3, 0)).is_err(), "empty chunk");
        sc.ingest(&Mat::zeros(3, 4)).unwrap();
        assert!(sc.ingest(&Mat::zeros(2, 4)).is_err(), "dimension change");
        // 4 points < r' = 7
        assert!(!sc.can_refresh());
        assert!(sc.refresh().is_err());
    }
}
