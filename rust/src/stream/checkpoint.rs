//! Durable checkpoint/resume for [`StreamClusterer`] — the `.rkcs`
//! stream-state format.
//!
//! The paper's one-pass property is also its fragility: every kernel
//! entry is touched exactly once, so a crash mid-stream loses a sketch
//! that **cannot be recomputed from replay** without re-evaluating the
//! kernel. Durability of the O(n·(p + r′)) state is therefore the whole
//! recovery story — and that state is small and well-defined: the
//! sketch rows `W`, the SRHT operator, the buffered points, and the
//! PRNG positions. This module serializes exactly that surface.
//!
//! # Byte-level format (version 1)
//!
//! Identical framing discipline to the `.rkc` model format
//! ([`crate::model_io`]): everything little-endian, integrity checked
//! before version negotiation.
//!
//! ```text
//! offset        size  contents
//! 0             8     magic, the ASCII bytes "RKCSTATE"
//! 8             4     u32 format version (currently 1)
//! 12            4     u32 header length H in bytes
//! 16            H     UTF-8 JSON header (see below)
//! 16+H          8·Σ   payload: for each header `sections` entry, in
//!                     order, `len` f64 values
//! end−8         8     u64 FNV-1a checksum of every preceding byte
//! ```
//!
//! The header carries the full builder configuration (so `resume` needs
//! no arguments but the path) plus the scalar runtime state. `u64`
//! values that may exceed 2⁵³ (the master seed, the SRHT PRNG state,
//! `f64` bit patterns) travel as 16-hex-digit strings — JSON numbers
//! are `f64` and would silently round them.
//!
//! Sections (flat f64 vectors, present only when non-empty): `buf`
//! (n·p point-major points), `w` (n·r′ sketch rows — the fold
//! accumulator), `srht_d` / `srht_idx` (the operator), `prev_labels`
//! (the last refresh's assignment, for the warm start).
//!
//! # Resume determinism
//!
//! [`StreamClusterer::resume`] restores *everything* future computation
//! reads: the SRHT PRNG is restored from its raw `(state, inc)` pair
//! (its consumption count per redraw is unknowable — rejection sampling
//! draws a variable number of words), `refreshes` keeps the cold-start
//! K-means sub-stream aligned, and `prev_labels` keeps warm refreshes
//! warm. The contract, enforced by the kill-and-resume test: checkpoint
//! after chunk i, resume in a fresh process, ingest chunks i+1.., and
//! the final [`refresh`](StreamClusterer::refresh) model is
//! **bit-identical** to an uninterrupted run over the same chunk
//! sequence (wall-clock timings aside — those measure the run, not the
//! model). Not covered: the checkpoint stores state, not history, so
//! resuming and then ingesting a *different* chunk sequence is a
//! different stream, exactly as it would be uninterrupted.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::error::{Result, RkcError};
use crate::kernels::Kernel;
use crate::obs;
use crate::rng::Pcg64;
use crate::sketch::Srht;
use crate::util::Json;

use super::{RefreshPolicy, StreamClusterer};

/// The 8 magic bytes opening every `.rkcs` stream-state file.
pub const STATE_MAGIC: [u8; 8] = *b"RKCSTATE";

/// Newest `.rkcs` version this build writes (and the newest it reads).
pub const STATE_VERSION: u32 = 1;

/// magic + version + header length before the header itself
const FIXED_PREFIX: usize = 8 + 4 + 4;

fn hex64(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

fn uint(v: usize) -> Json {
    Json::Num(v as f64)
}

impl StreamClusterer {
    /// Serialize the full stream state into the `.rkcs` byte format.
    pub fn state_to_bytes(&self) -> Vec<u8> {
        let rp = self.sketch_width();
        let p = self.p.unwrap_or(0);

        // borrow the O(n·(p+r')) state; only the small index/label
        // casts materialize temporaries (a clone of buf/w would double
        // peak memory for the duration of every checkpoint)
        let idx_f: Vec<f64>;
        let labels_f: Vec<f64>;
        let mut sections: Vec<(&'static str, &[f64])> = Vec::new();
        if !self.buf.is_empty() {
            sections.push(("buf", &self.buf));
        }
        if !self.w.is_empty() {
            sections.push(("w", &self.w));
        }
        if let Some(srht) = &self.srht {
            sections.push(("srht_d", &srht.d));
            idx_f = srht.idx.iter().map(|&i| i as f64).collect();
            sections.push(("srht_idx", &idx_f));
        }
        if let Some(labels) = &self.prev_labels {
            labels_f = labels.iter().map(|&l| l as f64).collect();
            sections.push(("prev_labels", &labels_f));
        }

        let mut header = BTreeMap::new();
        header.insert("format".into(), Json::Str("rkc-stream-state".into()));
        header.insert("kernel".into(), Json::Str(self.kernel.to_string()));
        header.insert("k".into(), uint(self.k));
        header.insert("rank".into(), uint(self.rank));
        header.insert("oversample".into(), uint(self.oversample));
        header.insert("batch".into(), uint(self.batch));
        header.insert("threads".into(), uint(self.threads));
        header.insert("kmeans_restarts".into(), uint(self.kmeans_restarts));
        header.insert("kmeans_iters".into(), uint(self.kmeans_iters));
        // exact bit pattern: a JSON decimal would round the tolerance
        // and warm/cold refits after resume would stop early differently
        header.insert("kmeans_tol_bits".into(), hex64(self.kmeans_tol.to_bits()));
        header.insert("seed".into(), hex64(self.seed));
        header.insert("capacity_hint".into(), uint(self.capacity_hint));
        if let Some(points) = self.policy.points {
            header.insert("policy_points".into(), uint(points));
        }
        if let Some(interval) = self.policy.interval {
            header.insert(
                "policy_interval_s".into(),
                Json::finite_num(interval.as_secs_f64()),
            );
        }
        header.insert("p".into(), uint(p));
        header.insert("n".into(), uint(self.n));
        header.insert("rp".into(), uint(rp));
        header.insert("refreshes".into(), hex64(self.refreshes));
        header.insert("points_since_refresh".into(), uint(self.points_since_refresh));
        if let Some(srht) = &self.srht {
            header.insert("srht_n".into(), uint(srht.n));
            let (state, inc) = self
                .srht_rng
                .as_ref()
                .expect("a drawn operator implies an initialized SRHT stream")
                .state_parts();
            header.insert("srht_rng_state".into(), hex64(state));
            header.insert("srht_rng_inc".into(), hex64(inc));
        }
        header.insert(
            "sections".into(),
            Json::Arr(
                sections
                    .iter()
                    .map(|(name, data)| {
                        Json::Obj(BTreeMap::from([
                            ("name".to_string(), Json::Str((*name).into())),
                            ("len".to_string(), uint(data.len())),
                        ]))
                    })
                    .collect(),
            ),
        );

        let header_bytes = Json::Obj(header).to_string().into_bytes();
        let payload_len: usize = sections.iter().map(|(_, d)| 8 * d.len()).sum();
        let mut out = Vec::with_capacity(FIXED_PREFIX + header_bytes.len() + payload_len + 8);
        out.extend_from_slice(&STATE_MAGIC);
        out.extend_from_slice(&STATE_VERSION.to_le_bytes());
        out.extend_from_slice(&(header_bytes.len() as u32).to_le_bytes());
        out.extend_from_slice(&header_bytes);
        for (_, data) in &sections {
            for v in data.iter() {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        let ck = crate::model_io::checksum(&out);
        out.extend_from_slice(&ck.to_le_bytes());
        out
    }

    /// Deserialize a `.rkcs` byte buffer into a ready-to-continue
    /// clusterer. `origin` names the source in error messages. Every
    /// way a file can be wrong — truncation, bit flips, inconsistent
    /// shapes, out-of-range indices — is a typed error, never a panic.
    pub fn state_from_bytes(bytes: &[u8], origin: &str) -> Result<StreamClusterer> {
        let bad = |d: String| RkcError::model(origin, d);
        if bytes.len() < FIXED_PREFIX + 8 {
            return Err(bad(format!(
                "truncated: {} bytes is shorter than the fixed framing",
                bytes.len()
            )));
        }
        if bytes[..8] != STATE_MAGIC {
            return Err(bad("bad magic (not an .rkcs stream-state file)".into()));
        }
        // integrity before version negotiation, same rationale as .rkc:
        // the outer framing is invariant across versions, so a checksum
        // mismatch always means corruption, never a newer format
        let payload_end = bytes.len() - 8;
        let stored = u64::from_le_bytes(bytes[payload_end..].try_into().unwrap());
        let computed = crate::model_io::checksum(&bytes[..payload_end]);
        if stored != computed {
            return Err(bad(format!(
                "checksum mismatch (stored {stored:#018x}, computed {computed:#018x}); \
                 the file is corrupt"
            )));
        }
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        if version > STATE_VERSION {
            return Err(RkcError::ModelVersion { found: version, supported: STATE_VERSION });
        }
        if version == 0 {
            return Err(bad("format version 0 is invalid".into()));
        }
        let hlen = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        if FIXED_PREFIX + hlen > payload_end {
            return Err(bad(format!("truncated: header length {hlen} exceeds the file")));
        }
        let header_text = std::str::from_utf8(&bytes[FIXED_PREFIX..FIXED_PREFIX + hlen])
            .map_err(|_| bad("header is not UTF-8".into()))?;
        let header = Json::parse(header_text)
            .map_err(|e| bad(format!("header is not valid JSON: {e}")))?;
        if header.get("format").and_then(Json::as_str) != Some("rkc-stream-state") {
            return Err(bad("header 'format' field is not 'rkc-stream-state'".into()));
        }

        let uint_of = |key: &str| {
            header
                .get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| bad(format!("header is missing integer field '{key}'")))
        };
        let hex_of = |key: &str| {
            header
                .get(key)
                .and_then(Json::as_str)
                .and_then(|s| u64::from_str_radix(s, 16).ok())
                .ok_or_else(|| bad(format!("header field '{key}' is not a 16-hex u64")))
        };

        // payload sections (flat f64 vectors, in header order)
        let secs = header
            .get("sections")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("header is missing the 'sections' array".into()))?;
        let mut vecs: BTreeMap<String, Vec<f64>> = BTreeMap::new();
        let mut off = FIXED_PREFIX + hlen;
        for s in secs {
            let name = s.str_field("name").map_err(|e| bad(e.to_string()))?.to_string();
            let len = s.usize_field("len").map_err(|e| bad(e.to_string()))?;
            let n_bytes = len
                .checked_mul(8)
                .ok_or_else(|| bad(format!("section '{name}' length {len} overflows")))?;
            let end = off.checked_add(n_bytes).filter(|&e| e <= payload_end).ok_or_else(
                || {
                    bad(format!(
                        "truncated payload: section '{name}' ({len} values) runs past \
                         the end of the file"
                    ))
                },
            )?;
            let data: Vec<f64> = bytes[off..end]
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            off = end;
            if vecs.insert(name.clone(), data).is_some() {
                return Err(bad(format!("duplicate section '{name}'")));
            }
        }
        if off != payload_end {
            return Err(bad(format!(
                "payload size mismatch: {} trailing bytes after the last section",
                payload_end - off
            )));
        }

        // configuration
        let kernel_spec = header
            .get("kernel")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("header is missing string field 'kernel'".into()))?;
        let kernel: Kernel = kernel_spec
            .parse()
            .map_err(|_| bad(format!("unknown kernel spec '{kernel_spec}'")))?;
        let k = uint_of("k")?;
        let rank = uint_of("rank")?;
        let oversample = uint_of("oversample")?;
        if k == 0 || rank == 0 {
            return Err(bad("k and rank must both be at least 1".into()));
        }
        let rp = uint_of("rp")?;
        if rank.checked_add(oversample) != Some(rp) {
            return Err(bad(format!(
                "sketch width rp={rp} disagrees with rank {rank} + oversample {oversample}"
            )));
        }
        let batch = uint_of("batch")?;
        if batch == 0 {
            return Err(bad("batch must be at least 1".into()));
        }
        let kmeans_restarts = uint_of("kmeans_restarts")?;
        let kmeans_iters = uint_of("kmeans_iters")?;
        let kmeans_tol = f64::from_bits(hex_of("kmeans_tol_bits")?);
        let seed = hex_of("seed")?;
        let policy = RefreshPolicy {
            points: match header.get("policy_points") {
                Some(v) => Some(v.as_usize().ok_or_else(|| {
                    bad("header field 'policy_points' is not an integer".into())
                })?),
                None => None,
            },
            interval: match header.get("policy_interval_s").and_then(Json::as_f64) {
                Some(s) => Some(Duration::try_from_secs_f64(s).map_err(|_| {
                    bad(format!("policy interval {s}s is not a valid duration"))
                })?),
                None => None,
            },
        };

        // runtime state
        let p = uint_of("p")?;
        let n = uint_of("n")?;
        if n > 0 && p == 0 {
            return Err(bad(format!("{n} points buffered with dimension p=0")));
        }
        // header-supplied sizes are untrusted even after the checksum
        // (a re-sealed file is checksum-valid): checked arithmetic, so
        // an absurd n/p is a typed error, never an overflow panic
        let np = n
            .checked_mul(p)
            .ok_or_else(|| bad(format!("header n={n} times p={p} overflows")))?;
        let buf = vecs.remove("buf").unwrap_or_default();
        if buf.len() != np {
            return Err(bad(format!(
                "buf section holds {} values but n·p = {n}·{p} = {np}",
                buf.len(),
            )));
        }
        let nrp = n
            .checked_mul(rp)
            .ok_or_else(|| bad(format!("header n={n} times r'={rp} overflows")))?;
        let w = vecs.remove("w").unwrap_or_default();
        if w.len() != nrp {
            return Err(bad(format!(
                "w section holds {} values but n·r' = {n}·{rp} = {nrp}",
                w.len(),
            )));
        }
        let srht = match (vecs.remove("srht_d"), vecs.remove("srht_idx")) {
            (Some(d), Some(idx_f)) => {
                let cap = uint_of("srht_n")?;
                if !cap.is_power_of_two() || cap < n.max(rp).max(1) {
                    return Err(bad(format!(
                        "operator capacity {cap} is not a power of two covering \
                         n={n} and r'={rp}"
                    )));
                }
                if d.len() != cap {
                    return Err(bad(format!(
                        "srht_d holds {} signs but the operator capacity is {cap}",
                        d.len()
                    )));
                }
                if d.iter().any(|&s| s != 1.0 && s != -1.0) {
                    return Err(bad("srht_d carries a non-Rademacher sign".into()));
                }
                if idx_f.len() != rp {
                    return Err(bad(format!(
                        "srht_idx holds {} indices but r' = {rp}",
                        idx_f.len()
                    )));
                }
                let mut idx = Vec::with_capacity(rp);
                for &v in &idx_f {
                    if !(v.is_finite() && v >= 0.0 && v.fract() == 0.0 && (v as usize) < cap) {
                        return Err(bad(format!(
                            "srht_idx value {v} is not an index below capacity {cap}"
                        )));
                    }
                    idx.push(v as usize);
                }
                Some(Srht { n: cap, d, idx })
            }
            (None, None) => {
                if n > 0 {
                    return Err(bad(format!(
                        "{n} points buffered but no operator sections present"
                    )));
                }
                None
            }
            _ => {
                return Err(bad(
                    "'srht_d' and 'srht_idx' sections must appear together".into(),
                ))
            }
        };
        let srht_rng = if srht.is_some() {
            Some(Pcg64::from_parts(hex_of("srht_rng_state")?, hex_of("srht_rng_inc")?))
        } else {
            None
        };
        let prev_labels = match vecs.remove("prev_labels") {
            Some(lf) => {
                if lf.len() > n {
                    return Err(bad(format!(
                        "prev_labels holds {} labels but only {n} points are buffered",
                        lf.len()
                    )));
                }
                let mut labels = Vec::with_capacity(lf.len());
                for &v in &lf {
                    if !(v.is_finite() && v >= 0.0 && v.fract() == 0.0 && (v as usize) < k) {
                        return Err(bad(format!(
                            "prev_labels value {v} is not a cluster index below k={k}"
                        )));
                    }
                    labels.push(v as usize);
                }
                Some(labels)
            }
            None => None,
        };
        if !vecs.is_empty() {
            let names: Vec<&str> = vecs.keys().map(String::as_str).collect();
            return Err(bad(format!("unknown sections {names:?}")));
        }
        let refreshes = hex_of("refreshes")?;
        let points_since_refresh = uint_of("points_since_refresh")?;

        let mut sc = StreamClusterer::new(k)
            .kernel(kernel)
            .rank(rank)
            .oversample(oversample)
            .batch(batch)
            .seed(seed)
            .threads(uint_of("threads")?)
            .kmeans_restarts(kmeans_restarts)
            .kmeans_iters(kmeans_iters)
            .kmeans_tol(kmeans_tol);
        sc.policy = policy;
        sc.capacity_hint = uint_of("capacity_hint")?;
        // the hint feeds next_power_of_two at the next operator draw —
        // an absurd value must fail here, not panic there
        if sc.capacity_hint > 1 << 48 {
            return Err(bad(format!(
                "capacity hint {} cannot describe a real stream",
                sc.capacity_hint
            )));
        }
        sc.p = if p == 0 { None } else { Some(p) };
        sc.buf = buf;
        sc.n = n;
        sc.srht = srht;
        sc.srht_rng = srht_rng;
        sc.w = w;
        sc.prev_labels = prev_labels;
        sc.refreshes = refreshes;
        sc.points_since_refresh = points_since_refresh;
        sc.last_refresh = Instant::now();
        sc.fold_time = Duration::ZERO;
        Ok(sc)
    }

    /// Write the stream state to `path` atomically and durably
    /// (temp file + fsync + rename + parent-directory fsync, via
    /// [`crate::model_io::write_durable`]): a crash at any instant
    /// leaves either the previous checkpoint or this one, never a torn
    /// file. Failpoint site: [`crate::fault::STREAM_CHECKPOINT`].
    pub fn checkpoint(&self, path: &str) -> Result<()> {
        crate::fault::trip(crate::fault::STREAM_CHECKPOINT)?;
        let t0 = Instant::now();
        crate::model_io::write_durable(path, &self.state_to_bytes())?;
        obs::record_span("stream.checkpoint", t0.elapsed());
        obs::registry()
            .counter(
                "rkc_stream_checkpoints_total",
                "Durable .rkcs stream-state checkpoints written.",
                &[],
            )
            .inc();
        Ok(())
    }

    /// Load a checkpoint written by [`checkpoint`](Self::checkpoint)
    /// and continue the stream exactly where it left off (see the
    /// module docs for the determinism contract).
    pub fn resume(path: &str) -> Result<StreamClusterer> {
        let bytes = std::fs::read(path)
            .map_err(|e| RkcError::io(format!("reading stream checkpoint {path}"), e))?;
        Self::state_from_bytes(&bytes, path)
    }
}

/// When a [`Checkpointer`] writes: after `points` newly ingested
/// points, after `interval` wall time, and/or after every refresh.
/// All unset (the default) means only explicit
/// [`Checkpointer::write`] calls persist anything.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CheckpointPolicy {
    /// checkpoint once this many points arrived since the last write
    pub points: Option<usize>,
    /// checkpoint once this much wall time passed since the last write
    pub interval: Option<Duration>,
    /// checkpoint after every successful refresh
    pub on_refresh: bool,
}

/// Drives periodic checkpoints of one stream: feed it every ingest
/// (and refresh) and it writes `.rkcs` snapshots per its
/// [`CheckpointPolicy`]. Kept outside [`StreamClusterer`] so the
/// clusterer itself stays a pure in-memory state machine.
#[derive(Debug)]
pub struct Checkpointer {
    path: String,
    policy: CheckpointPolicy,
    points_since_write: usize,
    last_write: Instant,
}

impl Checkpointer {
    pub fn new(path: impl Into<String>, policy: CheckpointPolicy) -> Self {
        Checkpointer {
            path: path.into(),
            policy,
            points_since_write: 0,
            last_write: Instant::now(),
        }
    }

    /// The `.rkcs` path this checkpointer writes.
    pub fn path(&self) -> &str {
        &self.path
    }

    /// Unconditional checkpoint; resets the points/interval windows.
    pub fn write(&mut self, sc: &StreamClusterer) -> Result<()> {
        sc.checkpoint(&self.path)?;
        self.points_since_write = 0;
        self.last_write = Instant::now();
        Ok(())
    }

    /// Account `ingested` new points (and whether a refresh just
    /// happened) against the policy; write a checkpoint if one is due.
    /// Returns whether a checkpoint was written.
    pub fn maybe_write(
        &mut self,
        sc: &StreamClusterer,
        ingested: usize,
        refreshed: bool,
    ) -> Result<bool> {
        self.points_since_write += ingested;
        let due = (refreshed && self.policy.on_refresh)
            || self.policy.points.is_some_and(|p| self.points_since_write >= p)
            || self.policy.interval.is_some_and(|t| self.last_write.elapsed() >= t);
        if due {
            self.write(sc)?;
        }
        Ok(due)
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::chunked;
    use super::*;
    use crate::data;

    fn tmp_path(name: &str) -> String {
        std::env::temp_dir()
            .join(format!("rkc_ckpt_{name}_{}.rkcs", std::process::id()))
            .to_str()
            .unwrap()
            .to_string()
    }

    /// Model bytes with the wall-clock timings zeroed — two models from
    /// different runs can only be byte-compared after canonicalizing
    /// the fields that measure the run instead of the model.
    fn canonical_bytes(model: &mut crate::api::FittedModel) -> Vec<u8> {
        let m = model.metrics_mut();
        m.sketch_time = Duration::ZERO;
        m.recovery_time = Duration::ZERO;
        m.kmeans_time = Duration::ZERO;
        crate::model_io::model_to_bytes(model)
    }

    #[test]
    fn state_roundtrips_bit_exactly() {
        let ds = data::cross_lines(&mut Pcg64::seed(61), 150);
        let mut sc = StreamClusterer::new(2).oversample(9).seed(13).capacity(150);
        let chunks = chunked(&ds.x, 50);
        sc.ingest(&chunks[0]).unwrap();
        sc.refresh().unwrap();
        sc.ingest(&chunks[1]).unwrap();
        let bytes = sc.state_to_bytes();
        let back = StreamClusterer::state_from_bytes(&bytes, "mem").unwrap();
        assert_eq!(back.n, sc.n);
        assert_eq!(back.buf, sc.buf);
        assert_eq!(back.w, sc.w, "fold accumulator must survive bit-exactly");
        assert_eq!(back.prev_labels, sc.prev_labels);
        assert_eq!(back.refreshes, sc.refreshes);
        assert_eq!(back.points_since_refresh, sc.points_since_refresh);
        let (a, b) = (back.srht.as_ref().unwrap(), sc.srht.as_ref().unwrap());
        assert_eq!((a.n, &a.d, &a.idx), (b.n, &b.d, &b.idx));
        assert_eq!(
            back.srht_rng.as_ref().unwrap().state_parts(),
            sc.srht_rng.as_ref().unwrap().state_parts()
        );
        assert_eq!(back.kmeans_tol.to_bits(), sc.kmeans_tol.to_bits());
        // and a fresh stream (no ingest yet) roundtrips too
        let empty = StreamClusterer::new(3).seed(7);
        let back = StreamClusterer::state_from_bytes(&empty.state_to_bytes(), "mem").unwrap();
        assert_eq!(back.n, 0);
        assert!(back.srht.is_none() && back.srht_rng.is_none());
    }

    #[test]
    fn kill_and_resume_model_is_bit_identical_to_uninterrupted() {
        let _g = crate::fault::test_guard(); // checkpoints cross a failpoint site
        let ds = data::cross_lines(&mut Pcg64::seed(62), 240);
        let chunks = chunked(&ds.x, 48);
        let build = || StreamClusterer::new(2).oversample(10).seed(21).capacity(240);

        // uninterrupted reference: ingest all 5 chunks, refresh after
        // chunks 2 and 5 (a warm refresh exercises prev_labels)
        let mut full = build();
        for (i, chunk) in chunks.iter().enumerate() {
            full.ingest(chunk).unwrap();
            if i == 1 {
                full.refresh().unwrap();
            }
        }
        let want = canonical_bytes(&mut full.refresh().unwrap());

        // interrupted run: same schedule, checkpoint after chunk 3,
        // drop the live clusterer (the "kill"), resume from the file
        let path = tmp_path("bitident");
        {
            let mut sc = build();
            for (i, chunk) in chunks.iter().take(3).enumerate() {
                sc.ingest(chunk).unwrap();
                if i == 1 {
                    sc.refresh().unwrap();
                }
            }
            sc.checkpoint(&path).unwrap();
            // sc dropped here — the in-memory state dies with it
        }
        let mut resumed = StreamClusterer::resume(&path).unwrap();
        for chunk in &chunks[3..] {
            resumed.ingest(chunk).unwrap();
        }
        let got = canonical_bytes(&mut resumed.refresh().unwrap());
        std::fs::remove_file(&path).ok();
        assert_eq!(got, want, "resumed final model must be byte-identical");
    }

    #[test]
    fn resume_preserves_pending_operator_redraws() {
        let _g = crate::fault::test_guard(); // checkpoints cross a failpoint site
        // checkpoint BEFORE a capacity crossing: the redraw after resume
        // must consume the SRHT stream exactly where the uninterrupted
        // run would — this is what the raw (state, inc) persistence buys
        let ds = data::gaussian_blobs(&mut Pcg64::seed(63), 80, 3, 2, 0.4);
        let chunks = chunked(&ds.x, 20);
        let build = || StreamClusterer::new(2).oversample(4).seed(31); // no hint: cap 32 → 64 → 128
        let mut full = build();
        for chunk in &chunks {
            full.ingest(chunk).unwrap();
        }
        let want = canonical_bytes(&mut full.refresh().unwrap());

        let path = tmp_path("redraw");
        {
            let mut sc = build();
            sc.ingest(&chunks[0]).unwrap(); // 20 points: cap 32
            sc.checkpoint(&path).unwrap();
        }
        let mut resumed = StreamClusterer::resume(&path).unwrap();
        for chunk in &chunks[1..] {
            resumed.ingest(chunk).unwrap(); // 80 points: redraws at 128
        }
        let got = canonical_bytes(&mut resumed.refresh().unwrap());
        std::fs::remove_file(&path).ok();
        assert_eq!(got, want, "post-resume redraw must stay on the seed stream");
    }

    #[test]
    fn corrupt_checkpoints_are_typed_errors_never_panics() {
        let ds = data::cross_lines(&mut Pcg64::seed(64), 60);
        let mut sc = StreamClusterer::new(2).oversample(6).seed(3).capacity(60);
        sc.ingest(&ds.x).unwrap();
        sc.refresh().unwrap();
        let bytes = sc.state_to_bytes();

        // bad magic
        let mut b = bytes.clone();
        b[0] = b'X';
        let err = StreamClusterer::state_from_bytes(&b, "mem").unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");

        // future version (re-sealed so the version check fires)
        let mut b = bytes.clone();
        b[8..12].copy_from_slice(&99u32.to_le_bytes());
        let end = b.len() - 8;
        let ck = crate::model_io::checksum(&b[..end]);
        b[end..].copy_from_slice(&ck.to_le_bytes());
        assert!(matches!(
            StreamClusterer::state_from_bytes(&b, "mem").unwrap_err(),
            RkcError::ModelVersion { found: 99, .. }
        ));

        // truncation at every section boundary and a sweep of interior
        // cuts: always a typed error
        for cut in [0, 5, FIXED_PREFIX, bytes.len() / 2, bytes.len() - 9, bytes.len() - 1] {
            let err = StreamClusterer::state_from_bytes(&bytes[..cut], "mem").unwrap_err();
            assert!(
                matches!(err, RkcError::Model { .. } | RkcError::ModelVersion { .. }),
                "cut at {cut}: {err}"
            );
        }

        // pseudo-random interior bit flips fail the checksum
        let mut rng = Pcg64::seed(99);
        use crate::rng::Rng as _;
        for _ in 0..32 {
            let mut b = bytes.clone();
            let at = rng.below(b.len() - 8);
            b[at] ^= 1 << rng.below(8);
            assert!(
                StreamClusterer::state_from_bytes(&b, "mem").is_err(),
                "bit flip at byte {at} must not load"
            );
        }
    }

    #[test]
    fn resealed_semantic_corruption_is_caught_by_shape_checks() {
        // checksum-valid but internally inconsistent: flip an srht_idx
        // value beyond the capacity and re-seal
        let ds = data::cross_lines(&mut Pcg64::seed(65), 40);
        let mut sc = StreamClusterer::new(2).oversample(4).seed(5).capacity(40);
        sc.ingest(&ds.x).unwrap();
        let mut bytes = sc.state_to_bytes();
        let hlen = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        let text =
            std::str::from_utf8(&bytes[FIXED_PREFIX..FIXED_PREFIX + hlen]).unwrap().to_string();
        // the last payload section before prev_labels is srht_idx; its
        // values sit at the very end of the payload. Overwrite the last
        // f64 with an out-of-range index.
        let end = bytes.len() - 8;
        bytes[end - 8..end].copy_from_slice(&1e9f64.to_le_bytes());
        let ck = crate::model_io::checksum(&bytes[..end]);
        bytes[end..].copy_from_slice(&ck.to_le_bytes());
        let err = StreamClusterer::state_from_bytes(&bytes, "mem").unwrap_err();
        assert!(err.to_string().contains("srht_idx"), "{err}");
        assert!(text.contains("srht_idx"), "layout assumption: {text}");
    }

    #[test]
    fn checkpointer_policy_triggers_on_points_and_refresh() {
        let _g = crate::fault::test_guard(); // checkpoints cross a failpoint site
        let ds = data::gaussian_blobs(&mut Pcg64::seed(66), 90, 3, 2, 0.3);
        let chunks = chunked(&ds.x, 30);
        let mut sc = StreamClusterer::new(2).oversample(5).seed(2).capacity(90);
        let path = tmp_path("policy");
        let mut ck = Checkpointer::new(
            &path,
            CheckpointPolicy { points: Some(60), interval: None, on_refresh: true },
        );
        sc.ingest(&chunks[0]).unwrap();
        assert!(!ck.maybe_write(&sc, 30, false).unwrap(), "30 < 60 points");
        assert!(!std::path::Path::new(&path).exists());
        sc.ingest(&chunks[1]).unwrap();
        assert!(ck.maybe_write(&sc, 30, false).unwrap(), "60 >= 60 points");
        assert!(std::path::Path::new(&path).exists());
        sc.ingest(&chunks[2]).unwrap();
        sc.refresh().unwrap();
        assert!(ck.maybe_write(&sc, 30, true).unwrap(), "on_refresh fires");
        let resumed = StreamClusterer::resume(&path).unwrap();
        assert_eq!(resumed.n, 90);
        assert_eq!(resumed.refreshes, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn checkpoint_rejects_unreadable_paths_and_missing_files() {
        let _g = crate::fault::test_guard(); // checkpoints cross a failpoint site
        let sc = StreamClusterer::new(2);
        // /dev/null is a file, so the parent "directory" can never exist
        assert!(sc.checkpoint("/dev/null/x/y.rkcs").is_err());
        assert!(matches!(
            StreamClusterer::resume("/nonexistent/rkc.rkcs").unwrap_err(),
            RkcError::Io { .. }
        ));
        // an .rkc model file is not an .rkcs checkpoint
        let ds = data::cross_lines(&mut Pcg64::seed(67), 64);
        let mut sc = StreamClusterer::new(2).oversample(8).capacity(64);
        sc.ingest(&ds.x).unwrap();
        let model = sc.refresh().unwrap();
        let err = StreamClusterer::state_from_bytes(
            &crate::model_io::model_to_bytes(&model),
            "mem",
        )
        .unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
    }

    #[test]
    fn injected_checkpoint_fault_is_transient_and_leaves_prior_file() {
        let _g = crate::fault::test_guard();
        let ds = data::cross_lines(&mut Pcg64::seed(68), 60);
        let mut sc = StreamClusterer::new(2).oversample(6).seed(4).capacity(60);
        sc.ingest(&ds.x).unwrap();
        let path = tmp_path("fault");
        sc.checkpoint(&path).unwrap();
        let good = std::fs::read(&path).unwrap();
        crate::fault::configure("stream.checkpoint=io_error:1.0").unwrap();
        let err = sc.checkpoint(&path).unwrap_err();
        assert!(err.is_transient(), "{err}");
        crate::fault::clear();
        // the injected failure never touched the previous checkpoint
        assert_eq!(std::fs::read(&path).unwrap(), good);
        std::fs::remove_file(&path).ok();
    }
}
