//! Lightweight span tracing into a bounded lock-striped ring buffer.
//!
//! [`span`] hands out an RAII [`SpanGuard`]; on drop it pushes a
//! [`SpanRecord`] — name, start offset from the process epoch (µs),
//! duration (µs), and a small monotone thread id — into one of
//! [`STRIPES`] mutex-protected rings selected by thread id, so threads
//! almost never contend. Each stripe holds [`STRIPE_CAP`] records and
//! overwrites its oldest once full (the `dropped` counter keeps the
//! overwrite tally), bounding trace memory at
//! `STRIPES * STRIPE_CAP * sizeof(SpanRecord)` regardless of run length.
//!
//! [`dump_trace`] serializes the ring as JSONL — one header line with
//! the schema id and drop count, then one line per span sorted by start
//! time. The CLI wires this to `--trace out.jsonl` / `RKC_TRACE`.
//!
//! Recording is out-of-band: when [`super::enabled`] is off, [`span`]
//! returns an inert guard and [`record_span`] is a no-op.

use crate::error::{Result, RkcError};
use crate::util::Json;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Number of independently locked rings.
const STRIPES: usize = 8;
/// Spans retained per stripe before the ring wraps.
const STRIPE_CAP: usize = 4096;

/// One recorded span.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    /// static span name, e.g. `"stream.refresh"`
    pub name: &'static str,
    /// start offset from the process trace epoch, microseconds
    pub start_us: u64,
    /// wall-clock duration, microseconds
    pub dur_us: u64,
    /// small monotone per-thread id (not the OS tid)
    pub thread: u64,
}

struct Stripe {
    buf: Vec<SpanRecord>,
    /// next overwrite position once `buf.len() == STRIPE_CAP`
    next: usize,
    /// spans overwritten after the ring wrapped
    dropped: u64,
}

fn ring() -> &'static [Mutex<Stripe>; STRIPES] {
    static RING: OnceLock<[Mutex<Stripe>; STRIPES]> = OnceLock::new();
    RING.get_or_init(|| {
        std::array::from_fn(|_| Mutex::new(Stripe { buf: Vec::new(), next: 0, dropped: 0 }))
    })
}

/// Process trace epoch: pinned on first use, all `start_us` offsets are
/// relative to it.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Small monotone thread id ( `std::thread::ThreadId` has no stable
/// integer form on this toolchain).
fn thread_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    thread_local! {
        static TID: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

/// RAII span: records on drop. Inert when recording is disabled.
pub struct SpanGuard {
    name: &'static str,
    start: Option<Instant>,
}

/// Open a span; the returned guard records `{name, wall-time, thread}`
/// into the ring when dropped.
pub fn span(name: &'static str) -> SpanGuard {
    if !super::enabled() {
        return SpanGuard { name, start: None };
    }
    let _ = epoch(); // pin the epoch no later than the first span
    SpanGuard { name, start: Some(Instant::now()) }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            push(self.name, start, start.elapsed());
        }
    }
}

/// Backfill a span from an already-measured duration (stage timers that
/// predate the obs layer measure with raw `Instant` pairs); the span is
/// placed as if it just ended.
pub fn record_span(name: &'static str, dur: Duration) {
    if !super::enabled() {
        return;
    }
    let now = Instant::now();
    push(name, now.checked_sub(dur).unwrap_or(now), dur);
}

fn push(name: &'static str, start: Instant, dur: Duration) {
    let start_us = start.saturating_duration_since(epoch()).as_micros() as u64;
    let tid = thread_id();
    let rec = SpanRecord { name, start_us, dur_us: dur.as_micros() as u64, thread: tid };
    let mut s = ring()[tid as usize % STRIPES].lock().unwrap_or_else(|p| p.into_inner());
    if s.buf.len() < STRIPE_CAP {
        s.buf.push(rec);
    } else {
        let at = s.next;
        s.buf[at] = rec;
        s.next = (at + 1) % STRIPE_CAP;
        s.dropped += 1;
    }
}

/// All retained spans sorted by start time, plus the overwrite count.
pub fn trace_snapshot() -> (Vec<SpanRecord>, u64) {
    let mut spans = Vec::new();
    let mut dropped = 0u64;
    for stripe in ring() {
        let s = stripe.lock().unwrap_or_else(|p| p.into_inner());
        dropped += s.dropped;
        spans.extend(s.buf.iter().cloned());
    }
    spans.sort_by(|a, b| (a.start_us, a.thread, a.name).cmp(&(b.start_us, b.thread, b.name)));
    (spans, dropped)
}

/// Empty the ring (test isolation; the CLI never clears).
pub fn clear_trace() {
    for stripe in ring() {
        let mut s = stripe.lock().unwrap_or_else(|p| p.into_inner());
        s.buf.clear();
        s.next = 0;
        s.dropped = 0;
    }
}

/// Dump the span ring as JSONL: a `rkc.trace.v1` header line, then one
/// object per span sorted by start time. Returns the span count.
pub fn dump_trace(path: &Path) -> Result<usize> {
    let (spans, dropped) = trace_snapshot();
    let mut out = String::new();
    let mut header = BTreeMap::new();
    header.insert("row".to_string(), Json::Str("header".into()));
    header.insert("schema".to_string(), Json::Str("rkc.trace.v1".into()));
    header.insert("spans".to_string(), Json::Num(spans.len() as f64));
    header.insert("dropped".to_string(), Json::Num(dropped as f64));
    out.push_str(&Json::Obj(header).to_string());
    out.push('\n');
    for r in &spans {
        let mut m = BTreeMap::new();
        m.insert("span".to_string(), Json::Str(r.name.to_string()));
        m.insert("start_us".to_string(), Json::Num(r.start_us as f64));
        m.insert("dur_us".to_string(), Json::Num(r.dur_us as f64));
        m.insert("thread".to_string(), Json::Num(r.thread as f64));
        out.push_str(&Json::Obj(m).to_string());
        out.push('\n');
    }
    std::fs::write(path, &out)
        .map_err(|e| RkcError::io(format!("writing trace {}", path.display()), e))?;
    Ok(spans.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_guard_records_and_disabled_is_inert() {
        let _g = super::super::test_guard();
        clear_trace();
        {
            let _s = span("test.span");
            std::thread::sleep(Duration::from_millis(1));
        }
        record_span("test.backfill", Duration::from_micros(250));
        let (spans, _) = trace_snapshot();
        let names: Vec<_> = spans.iter().map(|s| s.name).collect();
        assert!(names.contains(&"test.span"), "{names:?}");
        assert!(names.contains(&"test.backfill"), "{names:?}");
        let guard_span = spans.iter().find(|s| s.name == "test.span").unwrap();
        assert!(guard_span.dur_us >= 1_000, "slept 1ms, got {}µs", guard_span.dur_us);

        super::super::set_enabled(false);
        {
            let _s = span("test.off");
        }
        record_span("test.off2", Duration::from_micros(1));
        super::super::set_enabled(true);
        let (spans, _) = trace_snapshot();
        assert!(spans.iter().all(|s| !s.name.starts_with("test.off")));
        clear_trace();
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let _g = super::super::test_guard();
        clear_trace();
        // the current thread maps to one stripe; overfill it
        for _ in 0..(STRIPE_CAP + 10) {
            record_span("test.flood", Duration::from_micros(1));
        }
        // other test threads may share this stripe concurrently, so the
        // assertions check the bound and the drop tally, not exact counts
        let (spans, dropped) = trace_snapshot();
        let flood = spans.iter().filter(|s| s.name == "test.flood").count();
        assert!(flood <= STRIPE_CAP, "stripe must cap at STRIPE_CAP, held {flood}");
        assert!(dropped >= 10, "overfilling by 10 must count >= 10 drops, got {dropped}");
        assert!(spans.len() <= STRIPES * STRIPE_CAP);
        clear_trace();
    }

    #[test]
    fn dump_trace_writes_parseable_jsonl() {
        let _g = super::super::test_guard();
        clear_trace();
        record_span("test.dump", Duration::from_micros(42));
        let dir = std::env::temp_dir().join("rkc-obs-span-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let n = dump_trace(&path).unwrap();
        assert!(n >= 1);
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        let header = Json::parse(lines.next().unwrap()).unwrap();
        assert_eq!(header.str_field("schema").unwrap(), "rkc.trace.v1");
        assert_eq!(header.usize_field("spans").unwrap(), n);
        // every remaining line is a parseable span row; ours is among them
        // (concurrent tests may have contributed more)
        let rows: Vec<Json> = lines.map(|l| Json::parse(l).unwrap()).collect();
        assert_eq!(rows.len(), n);
        let ours = rows
            .iter()
            .find(|r| r.str_field("span").ok() == Some("test.dump"))
            .expect("dumped span present");
        assert_eq!(ours.usize_field("dur_us").unwrap(), 42);
        assert!(ours.get("thread").is_some() && ours.get("start_us").is_some());
        std::fs::remove_file(&path).ok();
        clear_trace();
    }
}
