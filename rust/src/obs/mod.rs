//! Process-wide observability: metrics registry, span tracing, and a
//! hand-rolled Prometheus text renderer — all zero-dependency.
//!
//! # Registry
//!
//! [`registry()`] returns the global [`Registry`]: a name → family map
//! of [`Counter`]s, [`Gauge`]s, and fixed-bucket [`Histogram`]s, each
//! family holding one series per label set. Lookups are get-or-create
//! and return `Arc` handles; hot paths grab their handle once and bump
//! lock-free atomics from then on. [`Registry::render`] emits the whole
//! registry in Prometheus text exposition format (served by the HTTP
//! front-end at `GET /metrics`).
//!
//! # Histograms
//!
//! [`Histogram`]s use fixed log-spaced bucket bounds ([`latency_buckets`],
//! [`size_buckets`]); an observation lands in the first bucket whose
//! upper bound is `>= v` (Prometheus `le` semantics), with a final
//! overflow (`+Inf`) bucket. [`HistogramSnapshot`]s are mergeable
//! (associative, bound-checked) and answer upper-bound
//! [`quantile`](HistogramSnapshot::quantile) queries for `/healthz`.
//!
//! # Spans
//!
//! [`span`] returns an RAII guard that records `{name, start, duration,
//! thread}` into a bounded lock-striped ring buffer on drop;
//! [`record_span`] backfills a span from an already-measured duration.
//! [`dump_trace`] exports the ring as JSONL — the CLI wires it to
//! `--trace out.jsonl` / the `RKC_TRACE` env var.
//!
//! # Out-of-band rule
//!
//! Observability must never perturb computation: no record path touches
//! an RNG, reorders floating-point work, or feeds anything back into a
//! pipeline. The `threads=1 ≡ threads=N` bit-identity and byte-identical
//! experiment JSONL contracts hold with tracing on or off (enforced by
//! `tests/experiment_golden.rs` and `tests/parallel_determinism.rs`).
//! The whole layer can be switched off with [`set_enabled`]`(false)` or
//! `RKC_OBS=0` (read by [`init_from_env`]); disabled record paths are a
//! single relaxed atomic load.

mod span;
mod stopwatch;

pub use span::{
    clear_trace, dump_trace, record_span, span, trace_snapshot, SpanGuard, SpanRecord,
};
pub use stopwatch::{ScopedTimer, Stopwatch};

use crate::error::{Result, RkcError};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};
use std::time::Duration;

// ---------------------------------------------------------------------------
// global enable switch

static ENABLED: AtomicBool = AtomicBool::new(true);

/// Whether metric/span recording is active (default: yes).
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn all recording on or off process-wide. Disabled record paths
/// cost one relaxed atomic load — the `obs_overhead` bench rows measure
/// the instrumented-vs-disabled delta on the serve hot path.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Apply the `RKC_OBS` environment variable (`0` / `false` / `off`
/// disables recording). Called once by the CLI at startup.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("RKC_OBS") {
        let v = v.trim().to_ascii_lowercase();
        if v == "0" || v == "false" || v == "off" {
            set_enabled(false);
        }
    }
}

// ---------------------------------------------------------------------------
// metric primitives

/// Monotone counter (lock-free, relaxed).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        if enabled() {
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (lock-free, relaxed).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        if enabled() {
            self.0.store(v, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket histogram: log-spaced upper bounds plus an overflow
/// bucket, a CAS-accumulated `f64` sum, all relaxed atomics. An
/// observation lands in the first bucket whose bound is `>= v`
/// (Prometheus `le` semantics — boundary values land *in* the bucket
/// they name).
#[derive(Debug)]
pub struct Histogram {
    bounds: Arc<[f64]>,
    /// per-bucket (non-cumulative) counts; `bounds.len() + 1` entries,
    /// the last being the overflow (`+Inf`) bucket
    buckets: Box<[AtomicU64]>,
    sum_bits: AtomicU64,
}

impl Histogram {
    fn new(bounds: &[f64]) -> Self {
        debug_assert!(
            bounds.windows(2).all(|w| w[0] < w[1]) && bounds.iter().all(|b| b.is_finite()),
            "histogram bounds must be finite and strictly increasing"
        );
        let buckets: Box<[AtomicU64]> = (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect();
        Histogram { bounds: bounds.into(), buckets, sum_bits: AtomicU64::new(0.0f64.to_bits()) }
    }

    pub fn observe(&self, v: f64) {
        if !enabled() {
            return;
        }
        let idx = self.bounds.partition_point(|&b| b < v);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self
                .sum_bits
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Point-in-time copy. Concurrent `observe` calls may land between
    /// the bucket loads, so `sum` can lag the bucket counts by a few
    /// in-flight observations; `count` is derived from the buckets
    /// themselves so the rendered `+Inf` cumulative always equals
    /// `_count`.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let buckets: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let count = buckets.iter().sum();
        HistogramSnapshot {
            bounds: self.bounds.to_vec(),
            buckets,
            count,
            sum: f64::from_bits(self.sum_bits.load(Ordering::Relaxed)),
        }
    }
}

/// Mergeable point-in-time histogram state.
#[derive(Clone, Debug, PartialEq)]
pub struct HistogramSnapshot {
    pub bounds: Vec<f64>,
    /// per-bucket counts, `bounds.len() + 1` entries (last = overflow)
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

impl HistogramSnapshot {
    /// Fold `other` into `self`. Associative and commutative on the
    /// counts (exact integer adds); the sums are `f64` adds, associative
    /// up to rounding. Errors if the bucket bounds differ.
    pub fn merge(&mut self, other: &HistogramSnapshot) -> Result<()> {
        if self.bounds != other.bounds {
            return Err(RkcError::invalid_config(
                "histogram merge: bucket bounds differ between snapshots",
            ));
        }
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        Ok(())
    }

    /// Upper-bound quantile estimate: the smallest bucket bound whose
    /// cumulative count reaches `q * count`. Observations in the
    /// overflow bucket report the largest finite bound (the histogram
    /// cannot resolve beyond it). Returns 0 for an empty snapshot.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                return match self.bounds.get(i) {
                    Some(&b) => b,
                    None => self.bounds.last().copied().unwrap_or(0.0),
                };
            }
        }
        self.bounds.last().copied().unwrap_or(0.0)
    }
}

// ---------------------------------------------------------------------------
// bucket presets

/// Log-spaced latency bounds, 10 µs … 10 s (seconds).
pub fn latency_buckets() -> &'static [f64] {
    &[
        1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2, 0.1,
        0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
    ]
}

/// Power-of-two size bounds, 1 … 1024 (batch sizes, chunk counts).
pub fn size_buckets() -> &'static [f64] {
    &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0]
}

// ---------------------------------------------------------------------------
// registry

#[derive(Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

struct Family {
    help: &'static str,
    kind: &'static str,
    /// label-set key (rendered `{k="v",…}`, `""` for unlabeled) → series
    series: BTreeMap<String, Metric>,
}

/// Global name → family map behind [`registry()`]. Lookups take the
/// `RwLock` once to fetch an `Arc` handle; recording through the handle
/// never locks.
pub struct Registry {
    families: RwLock<BTreeMap<&'static str, Family>>,
}

/// The process-wide registry.
pub fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(|| Registry { families: RwLock::new(BTreeMap::new()) })
}

/// Render a label set as the Prometheus series suffix: `{k="v",…}`
/// with keys sorted and values escaped, `""` when empty.
fn label_key(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let mut sorted: Vec<(&str, &str)> = labels.to_vec();
    sorted.sort_by(|a, b| a.0.cmp(b.0));
    let mut s = String::from("{");
    for (i, (k, v)) in sorted.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(k);
        s.push_str("=\"");
        for c in v.chars() {
            match c {
                '\\' => s.push_str("\\\\"),
                '"' => s.push_str("\\\""),
                '\n' => s.push_str("\\n"),
                c => s.push(c),
            }
        }
        s.push('"');
    }
    s.push('}');
    s
}

/// Splice an `le` label into an existing label-set key.
fn with_le(key: &str, le: &str) -> String {
    if key.is_empty() {
        format!("{{le=\"{le}\"}}")
    } else {
        format!("{},le=\"{le}\"}}", &key[..key.len() - 1])
    }
}

impl Registry {
    fn get_or_insert(
        &self,
        name: &'static str,
        help: &'static str,
        kind: &'static str,
        labels: &[(&str, &str)],
        make: impl FnOnce() -> Metric,
    ) -> Metric {
        let key = label_key(labels);
        {
            let fams = self.families.read().unwrap_or_else(|p| p.into_inner());
            if let Some(f) = fams.get(name) {
                if f.kind == kind {
                    if let Some(m) = f.series.get(&key) {
                        return m.clone();
                    }
                }
            }
        }
        let mut fams = self.families.write().unwrap_or_else(|p| p.into_inner());
        let fam = fams.entry(name).or_insert_with(|| Family {
            help,
            kind,
            series: BTreeMap::new(),
        });
        if fam.kind != kind {
            // name registered under another kind: hand back a detached
            // metric rather than corrupting the family (programming
            // error; loud in debug builds, harmless in release)
            debug_assert!(false, "metric '{name}' re-registered as {kind}, was {}", fam.kind);
            return make();
        }
        fam.series.entry(key).or_insert_with(make).clone()
    }

    /// Get-or-create a counter series.
    pub fn counter(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&str, &str)],
    ) -> Arc<Counter> {
        match self.get_or_insert(name, help, "counter", labels, || {
            Metric::Counter(Arc::new(Counter::default()))
        }) {
            Metric::Counter(c) => c,
            _ => Arc::new(Counter::default()),
        }
    }

    /// Get-or-create a gauge series.
    pub fn gauge(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&str, &str)],
    ) -> Arc<Gauge> {
        match self.get_or_insert(name, help, "gauge", labels, || {
            Metric::Gauge(Arc::new(Gauge::default()))
        }) {
            Metric::Gauge(g) => g,
            _ => Arc::new(Gauge::default()),
        }
    }

    /// Get-or-create a histogram series. The bounds are fixed at first
    /// creation; later callers get the existing series regardless of
    /// the bounds they pass.
    pub fn histogram(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&str, &str)],
        bounds: &[f64],
    ) -> Arc<Histogram> {
        match self.get_or_insert(name, help, "histogram", labels, || {
            Metric::Histogram(Arc::new(Histogram::new(bounds)))
        }) {
            Metric::Histogram(h) => h,
            _ => Arc::new(Histogram::new(bounds)),
        }
    }

    /// Snapshot an existing histogram series, if registered.
    pub fn histogram_snapshot(
        &self,
        name: &str,
        labels: &[(&str, &str)],
    ) -> Option<HistogramSnapshot> {
        let key = label_key(labels);
        let fams = self.families.read().unwrap_or_else(|p| p.into_inner());
        match fams.get(name)?.series.get(&key)? {
            Metric::Histogram(h) => Some(h.snapshot()),
            _ => None,
        }
    }

    /// Prometheus text exposition (format version 0.0.4): `# HELP` /
    /// `# TYPE` per family, families and series in sorted order,
    /// histogram series as cumulative `_bucket{le=…}` plus `_sum` /
    /// `_count`.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let fams = self.families.read().unwrap_or_else(|p| p.into_inner());
        let mut out = String::new();
        for (name, fam) in fams.iter() {
            let _ = writeln!(out, "# HELP {name} {}", fam.help);
            let _ = writeln!(out, "# TYPE {name} {}", fam.kind);
            for (key, metric) in &fam.series {
                match metric {
                    Metric::Counter(c) => {
                        let _ = writeln!(out, "{name}{key} {}", c.get());
                    }
                    Metric::Gauge(g) => {
                        let _ = writeln!(out, "{name}{key} {}", g.get());
                    }
                    Metric::Histogram(h) => {
                        let snap = h.snapshot();
                        let mut cum = 0u64;
                        for (i, &c) in snap.buckets.iter().enumerate() {
                            cum += c;
                            let le = match snap.bounds.get(i) {
                                Some(b) => format!("{b}"),
                                None => "+Inf".to_string(),
                            };
                            let _ =
                                writeln!(out, "{name}_bucket{} {cum}", with_le(key, &le));
                        }
                        let _ = writeln!(out, "{name}_sum{key} {}", snap.sum);
                        let _ = writeln!(out, "{name}_count{key} {}", snap.count);
                    }
                }
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// fit-stage shorthand

/// Record one fit pipeline stage: an observation in
/// `rkc_fit_stage_seconds{stage=…}` plus a backfilled span. Called from
/// the `api` fit paths and `stream::StreamClusterer::refresh` — the one
/// choke point, so batch and streaming fits land in the same series.
pub fn record_stage(stage: &'static str, d: Duration) {
    if !enabled() {
        return;
    }
    registry()
        .histogram(
            "rkc_fit_stage_seconds",
            "Wall time of fit pipeline stages (sketch pass, recovery, K-means).",
            &[("stage", stage)],
            latency_buckets(),
        )
        .observe(d.as_secs_f64());
    let span_name = match stage {
        "sketch" => "fit.sketch",
        "recovery" => "fit.recovery",
        "kmeans" => "fit.kmeans",
        other => other,
    };
    record_span(span_name, d);
}

/// Unit tests that toggle [`set_enabled`] or assert on gated record
/// paths serialize on this lock — `cargo test` runs tests in parallel
/// threads and the enable switch is process-global.
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static GUARD: std::sync::Mutex<()> = std::sync::Mutex::new(());
    GUARD.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let _g = test_guard();
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::default();
        g.set(17);
        assert_eq!(g.get(), 17);
        g.set(3);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn histogram_boundary_lands_in_named_bucket() {
        let _g = test_guard();
        let h = Histogram::new(&[1.0, 2.0, 4.0]);
        h.observe(1.0); // le="1" bucket, not le="2"
        h.observe(1.5);
        h.observe(4.0);
        h.observe(100.0); // overflow
        let s = h.snapshot();
        assert_eq!(s.buckets, vec![1, 1, 1, 1]);
        assert_eq!(s.count, 4);
        assert!((s.sum - 106.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantile_is_bucket_upper_bound() {
        let _g = test_guard();
        let h = Histogram::new(&[1.0, 2.0, 4.0]);
        for _ in 0..9 {
            h.observe(0.5);
        }
        h.observe(3.0);
        let s = h.snapshot();
        assert_eq!(s.quantile(0.5), 1.0);
        assert_eq!(s.quantile(0.95), 4.0);
        // overflow reports the largest finite bound
        let h2 = Histogram::new(&[1.0, 2.0]);
        h2.observe(50.0);
        assert_eq!(h2.snapshot().quantile(0.5), 2.0);
        // empty
        assert_eq!(Histogram::new(&[1.0]).snapshot().quantile(0.5), 0.0);
    }

    #[test]
    fn snapshot_merge_checks_bounds() {
        let _g = test_guard();
        let a = Histogram::new(&[1.0, 2.0]);
        a.observe(0.5);
        let b = Histogram::new(&[1.0, 2.0]);
        b.observe(1.5);
        let mut m = a.snapshot();
        m.merge(&b.snapshot()).unwrap();
        assert_eq!(m.count, 2);
        assert_eq!(m.buckets, vec![1, 1, 0]);
        let other = Histogram::new(&[1.0, 3.0]);
        assert!(m.merge(&other.snapshot()).is_err());
    }

    #[test]
    fn registry_reuses_series_and_renders_exposition() {
        let _g = test_guard();
        let r = registry();
        let c1 = r.counter("rkc_test_registry_total", "test counter", &[("who", "a")]);
        let c2 = r.counter("rkc_test_registry_total", "test counter", &[("who", "a")]);
        c1.add(2);
        c2.inc();
        assert_eq!(c1.get(), 3, "same labels must share one series");
        let h = r.histogram(
            "rkc_test_registry_seconds",
            "test histogram",
            &[],
            &[0.1, 1.0],
        );
        h.observe(0.05);
        h.observe(5.0);
        let snap = r
            .histogram_snapshot("rkc_test_registry_seconds", &[])
            .expect("registered histogram is snapshottable by name");
        assert_eq!(snap.count, 2);
        assert_eq!(snap.buckets, vec![1, 0, 1]);
        // unknown label set and non-histogram families both miss
        assert!(r.histogram_snapshot("rkc_test_registry_seconds", &[("who", "b")]).is_none());
        assert!(r.histogram_snapshot("rkc_test_registry_total", &[("who", "a")]).is_none());
        let text = r.render();
        assert!(text.contains("# TYPE rkc_test_registry_total counter"));
        assert!(text.contains("rkc_test_registry_total{who=\"a\"} 3"));
        assert!(text.contains("# TYPE rkc_test_registry_seconds histogram"));
        assert!(text.contains("rkc_test_registry_seconds_bucket{le=\"0.1\"} 1"));
        assert!(text.contains("rkc_test_registry_seconds_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("rkc_test_registry_seconds_count 2"));
    }

    #[test]
    fn label_values_are_escaped() {
        assert_eq!(label_key(&[("m", "a\"b\\c")]), "{m=\"a\\\"b\\\\c\"}");
        assert_eq!(with_le("{m=\"x\"}", "0.5"), "{m=\"x\",le=\"0.5\"}");
        assert_eq!(with_le("", "+Inf"), "{le=\"+Inf\"}");
    }

    #[test]
    fn disabled_recording_is_inert() {
        let _g = test_guard();
        let h = Histogram::new(&[1.0]);
        let c = Counter::default();
        set_enabled(false);
        h.observe(0.5);
        c.inc();
        set_enabled(true);
        assert_eq!(h.snapshot().count, 0);
        assert_eq!(c.get(), 0);
    }
}
