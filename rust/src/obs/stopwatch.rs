//! Wall-clock timing helpers (absorbed from the old `metrics::timer`
//! module — the obs registry is the one timing system).

use crate::error::{Result, RkcError};
use std::time::{Duration, Instant};

/// Accumulating stopwatch: start/stop across many block iterations.
///
/// Re-entrancy safe: starting an already-running stopwatch is a no-op
/// (the running lap keeps its original start instant and the lap count
/// stays honest); use [`try_start`](Stopwatch::try_start) when the
/// caller wants to detect the double start.
#[derive(Debug)]
pub struct Stopwatch {
    total: Duration,
    started: Option<Instant>,
    laps: usize,
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { total: Duration::ZERO, started: None, laps: 0 }
    }

    /// Start a lap; a no-op if one is already running.
    pub fn start(&mut self) {
        let _ = self.try_start();
    }

    /// Start a lap, reporting a typed error if one is already running
    /// (instead of the old `debug_assert!`, which vanished in release
    /// builds and let a re-entrant stage silently corrupt lap counts).
    pub fn try_start(&mut self) -> Result<()> {
        if self.started.is_some() {
            return Err(RkcError::invalid_config("stopwatch already running"));
        }
        self.started = Some(Instant::now());
        Ok(())
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.total += t0.elapsed();
            self.laps += 1;
        }
    }

    pub fn elapsed(&self) -> Duration {
        match self.started {
            Some(t0) => self.total + t0.elapsed(),
            None => self.total,
        }
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn laps(&self) -> usize {
        self.laps
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

/// RAII timer: adds its lifetime to a cell on drop.
pub struct ScopedTimer<'a> {
    target: &'a mut Duration,
    start: Instant,
}

impl<'a> ScopedTimer<'a> {
    pub fn new(target: &'a mut Duration) -> Self {
        ScopedTimer { target, start: Instant::now() }
    }
}

impl Drop for ScopedTimer<'_> {
    fn drop(&mut self) {
        *self.target += self.start.elapsed();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates_laps() {
        let mut sw = Stopwatch::new();
        for _ in 0..3 {
            sw.start();
            std::thread::sleep(Duration::from_millis(2));
            sw.stop();
        }
        assert_eq!(sw.laps(), 3);
        assert!(sw.secs() >= 0.006);
        assert!(sw.secs() < 1.0);
    }

    #[test]
    fn double_start_is_safe_and_detectable() {
        let mut sw = Stopwatch::new();
        sw.try_start().unwrap();
        // re-entrant start: typed error via try_start, no-op via start
        assert!(sw.try_start().is_err());
        sw.start();
        std::thread::sleep(Duration::from_millis(1));
        sw.stop();
        assert_eq!(sw.laps(), 1, "double start must not inflate lap counts");
        assert!(sw.secs() >= 0.001, "the original lap start must survive");
    }

    #[test]
    fn scoped_timer_adds_on_drop() {
        let mut total = Duration::ZERO;
        {
            let _t = ScopedTimer::new(&mut total);
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(total >= Duration::from_millis(2));
    }
}
