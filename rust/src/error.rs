//! Crate-wide typed errors.
//!
//! Every library layer (`api`, `lowrank`, `sketch`, `kernels`,
//! `coordinator`, `config`, `runtime`) returns [`RkcError`]; only the
//! CLI binary sits at the edge and is free to format them for humans.
//! Hand-rolled `thiserror`-style (the image is offline — no proc-macro
//! dependencies), so each variant carries enough context to be matched
//! on programmatically and still renders an actionable message.
//!
//! # Examples
//!
//! ```
//! use rkc::config::Method;
//! use rkc::error::RkcError;
//!
//! let err = "warp_drive".parse::<Method>().unwrap_err();
//! assert!(matches!(err, RkcError::Parse { what: "method", .. }));
//! assert_eq!(err.to_string(), "cannot parse method from 'warp_drive'");
//! ```

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, RkcError>;

/// Typed error for every fallible path in the library layers.
#[derive(Debug)]
pub enum RkcError {
    /// A builder / config combination that can never produce a valid run
    /// (rank 0, oversampling below rank, k > n, unknown config key, …).
    InvalidConfig(String),
    /// A string failed to parse as the named domain type
    /// (`Method`, `Backend`, `Kernel`, a numeric field, …).
    Parse {
        /// what we tried to parse (e.g. "method")
        what: &'static str,
        /// the offending input
        input: String,
    },
    /// Dataset construction or loading failed (unknown name, bad CSV, …).
    Dataset(String),
    /// No compiled artifact matches the requested shape / operation.
    MissingArtifact(String),
    /// The compute backend (PJRT runtime, artifact execution) failed or
    /// is unavailable in this build.
    Backend(String),
    /// The operation is not defined for this model / method combination
    /// (e.g. `embed` on a plain-K-means model).
    Unsupported(String),
    /// An underlying I/O failure, with the path or operation attached.
    Io {
        context: String,
        source: std::io::Error,
    },
    /// A failure that is expected to clear on retry (an injected fault,
    /// a momentarily unavailable file, a refused dial during startup).
    /// Callers with a retry budget (registry load, PUT /models) back
    /// off and try again; everyone else treats it like [`Io`](Self::Io).
    Transient {
        context: String,
    },
    /// A saved `.rkc` model file is unreadable: bad magic, corrupt or
    /// truncated header/payload, or a checksum mismatch.
    Model {
        /// the file (or byte-source description) that failed to load
        path: String,
        /// what exactly was wrong with it
        detail: String,
    },
    /// A saved model declares a format version this build does not
    /// support (written by a newer release).
    ModelVersion {
        /// version found in the file
        found: u32,
        /// newest version this build reads/writes
        supported: u32,
    },
}

impl RkcError {
    /// Shorthand constructors keep call sites one-liners.
    pub fn invalid_config(msg: impl Into<String>) -> Self {
        RkcError::InvalidConfig(msg.into())
    }

    pub fn parse(what: &'static str, input: impl Into<String>) -> Self {
        RkcError::Parse { what, input: input.into() }
    }

    pub fn dataset(msg: impl Into<String>) -> Self {
        RkcError::Dataset(msg.into())
    }

    pub fn missing_artifact(msg: impl Into<String>) -> Self {
        RkcError::MissingArtifact(msg.into())
    }

    pub fn backend(msg: impl Into<String>) -> Self {
        RkcError::Backend(msg.into())
    }

    pub fn unsupported(msg: impl Into<String>) -> Self {
        RkcError::Unsupported(msg.into())
    }

    pub fn io(context: impl Into<String>, source: std::io::Error) -> Self {
        RkcError::Io { context: context.into(), source }
    }

    pub fn model(path: impl Into<String>, detail: impl Into<String>) -> Self {
        RkcError::Model { path: path.into(), detail: detail.into() }
    }

    pub fn transient(context: impl Into<String>) -> Self {
        RkcError::Transient { context: context.into() }
    }

    /// Whether a bounded-backoff retry is worth attempting: the typed
    /// [`Transient`](Self::Transient) variant, or an [`Io`](Self::Io)
    /// whose kind the OS itself labels as momentary.
    pub fn is_transient(&self) -> bool {
        use std::io::ErrorKind;
        match self {
            RkcError::Transient { .. } => true,
            RkcError::Io { source, .. } => matches!(
                source.kind(),
                ErrorKind::Interrupted
                    | ErrorKind::WouldBlock
                    | ErrorKind::TimedOut
                    | ErrorKind::ConnectionRefused
                    | ErrorKind::ConnectionReset
            ),
            _ => false,
        }
    }
}

impl fmt::Display for RkcError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RkcError::InvalidConfig(m) => write!(f, "invalid config: {m}"),
            RkcError::Parse { what, input } => {
                write!(f, "cannot parse {what} from '{input}'")
            }
            RkcError::Dataset(m) => write!(f, "dataset error: {m}"),
            RkcError::MissingArtifact(m) => write!(f, "{m}"),
            RkcError::Backend(m) => write!(f, "{m}"),
            RkcError::Unsupported(m) => write!(f, "unsupported operation: {m}"),
            RkcError::Io { context, source } => write!(f, "{context}: {source}"),
            RkcError::Transient { context } => {
                write!(f, "transient failure (retryable): {context}")
            }
            RkcError::Model { path, detail } => {
                write!(f, "invalid model file {path}: {detail}")
            }
            RkcError::ModelVersion { found, supported } => write!(
                f,
                "model format version {found} is newer than the supported \
                 version {supported} (upgrade rkc to read this file)"
            ),
        }
    }
}

impl std::error::Error for RkcError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RkcError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<std::io::Error> for RkcError {
    fn from(e: std::io::Error) -> Self {
        RkcError::Io { context: "io error".into(), source: e }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_keeps_context() {
        let e = RkcError::parse("method", "warp_drive");
        assert_eq!(e.to_string(), "cannot parse method from 'warp_drive'");
        let e = RkcError::missing_artifact("no gram artifact for p=4");
        assert_eq!(e.to_string(), "no gram artifact for p=4");
        let e = RkcError::invalid_config("rank must be >= 1");
        assert!(e.to_string().contains("rank must be >= 1"));
    }

    #[test]
    fn io_errors_chain_source() {
        use std::error::Error as _;
        let inner = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e = RkcError::io("reading manifest.json", inner);
        assert!(e.to_string().contains("manifest.json"));
        assert!(e.source().is_some());
    }

    #[test]
    fn model_errors_render_actionably() {
        let e = RkcError::model("m.rkc", "checksum mismatch");
        assert_eq!(e.to_string(), "invalid model file m.rkc: checksum mismatch");
        let e = RkcError::ModelVersion { found: 9, supported: 1 };
        assert!(e.to_string().contains("version 9"));
        assert!(e.to_string().contains("supported version 1"));
    }

    #[test]
    fn transient_classification_covers_typed_and_os_momentary() {
        let e = RkcError::transient("injected fault at failpoint 'serve.load'");
        assert!(e.is_transient());
        assert!(e.to_string().contains("retryable"));
        let momentary = RkcError::io(
            "dialing front-end",
            std::io::Error::new(std::io::ErrorKind::ConnectionRefused, "refused"),
        );
        assert!(momentary.is_transient());
        let hard = RkcError::io(
            "reading model",
            std::io::Error::new(std::io::ErrorKind::NotFound, "gone"),
        );
        assert!(!hard.is_transient());
        assert!(!RkcError::invalid_config("rank 0").is_transient());
    }

    #[test]
    fn from_io_error_works_with_question_mark() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/nonexistent/rkc")?)
        }
        assert!(matches!(read(), Err(RkcError::Io { .. })));
    }
}
