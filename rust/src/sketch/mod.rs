//! Randomized sketching: FWHT, the SRHT operator, and Gaussian sketches.
//!
//! The paper's structured test matrix is `Ω = D H R` (Rademacher diagonal,
//! Walsh–Hadamard, uniform column subsampling). The coordinator applies
//! it *implicitly* to streamed kernel columns — scale by `D`, FWHT,
//! subsample r' entries — so `H` is never stored (§4 of the paper). The
//! explicit small matrices needed by the recovery step (`Ω` restricted to
//! the sketch rows, `QᵀΩ`) are generated entry-wise from the same seed.

mod fwht;

pub use fwht::{fwht_inplace, fwht_parallel, fwht_columns};

use crate::linalg::Mat;
use crate::rng::{normal_vec, rademacher_vec, sample_without_replacement, Pcg64};

/// Next power of two (FWHT length requirement; data is zero-padded).
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// The paper's structured random test matrix `Ω = D H R`, held implicitly:
/// the Rademacher signs `d` and the sampled row indices `idx` (columns of
/// the identity forming `R`). `H` is applied via FWHT only.
#[derive(Clone, Debug)]
pub struct Srht {
    /// padded transform length (power of two)
    pub n: usize,
    /// Rademacher diagonal of `D`, length `n`
    pub d: Vec<f64>,
    /// the r' sampled indices (rows of `HD K` kept / columns of `R`)
    pub idx: Vec<usize>,
}

impl Srht {
    /// Draw a fresh SRHT for padded dimension `n` (power of two) keeping
    /// `rp = r + l` samples.
    pub fn draw(rng: &mut Pcg64, n: usize, rp: usize) -> Self {
        assert!(n.is_power_of_two(), "SRHT length must be a power of two");
        assert!(rp <= n, "cannot keep {rp} of {n} rows");
        Srht {
            n,
            d: rademacher_vec(rng, n),
            idx: sample_without_replacement(rng, n, rp),
        }
    }

    pub fn samples(&self) -> usize {
        self.idx.len()
    }

    /// Zero the Rademacher signs of the padded rows (`i >= n_real`).
    /// This makes the implicit padded kernel matrix exactly zero in the
    /// padding block for *any* kernel (the RBF gram of zero-padded data
    /// is not zero by itself) while keeping the recovery identity
    /// `W = K̃ Ω` exact. Must be called before any `apply_to_block` /
    /// `omega_entry` use when `n_real < n`.
    pub fn mask_padding(&mut self, n_real: usize) {
        for i in n_real..self.n {
            self.d[i] = 0.0;
        }
    }

    /// One entry of the *explicit* `Ω = D H R`: `Ω[i, j] = d_i · H[i, idx_j]`
    /// with the unnormalized Hadamard `H[a, b] = (-1)^{popcount(a & b)}`.
    #[inline]
    pub fn omega_entry(&self, i: usize, j: usize) -> f64 {
        let sign = ((i & self.idx[j]).count_ones() & 1) as i32;
        self.d[i] * if sign == 0 { 1.0 } else { -1.0 }
    }

    /// Materialize `Ω` (n × r') — only used by the recovery step to form
    /// `QᵀΩ`, never by the streaming pass.
    pub fn omega(&self) -> Mat {
        Mat::from_fn(self.n, self.idx.len(), |i, j| self.omega_entry(i, j))
    }

    /// `Qᵀ Ω` (r × r') without materializing Ω: for each sampled column,
    /// compute `Qᵀ (D h_idx)` where `h_idx` is a Hadamard column.
    /// O(n · r · r') — the same cost as the matmul against explicit Ω but
    /// with O(1) extra memory.
    pub fn qt_omega(&self, q: &Mat) -> Mat {
        assert_eq!(q.rows(), self.n, "basis rows must match SRHT length");
        let r = q.cols();
        let rp = self.idx.len();
        let mut out = Mat::zeros(r, rp);
        for i in 0..self.n {
            // out[:, j] += Ω[i, j] * q[i, :]
            for j in 0..rp {
                let w = self.omega_entry(i, j);
                for k in 0..r {
                    out[(k, j)] += w * q[(i, k)];
                }
            }
        }
        out
    }

    /// Apply the streaming half of the sketch to a block of kernel columns
    /// `kb` (n × b, already zero-padded): scale rows by `d`, FWHT each
    /// column, and gather the sampled rows. Returns the (b × r') slab of
    /// new sketch rows `W[J, :]` — exactly what the XLA `precond` artifact
    /// plus a row-gather produces on the accelerated path.
    pub fn apply_to_block(&self, kb: &Mat, threads: usize) -> Mat {
        assert_eq!(kb.rows(), self.n, "block rows must equal SRHT length");
        // work column-major: transpose block, FWHT along rows
        let b = kb.cols();
        let mut buf: Vec<Vec<f64>> = (0..b)
            .map(|j| {
                let mut col: Vec<f64> = (0..self.n).map(|i| kb[(i, j)] * self.d[i]).collect();
                col.shrink_to_fit();
                col
            })
            .collect();
        fwht_columns(&mut buf, threads);
        Mat::from_fn(b, self.idx.len(), |j, s| buf[j][self.idx[s]])
    }
}

/// Dense Gaussian test matrix (the un-structured alternative from
/// Halko et al. §4; ablation baseline — same accuracy, O(n r') memory
/// for Ω itself and O(n² r') time for W = KΩ).
pub struct GaussianSketch {
    pub omega: Mat,
}

impl GaussianSketch {
    pub fn draw(rng: &mut Pcg64, n: usize, rp: usize) -> Self {
        let data = normal_vec(rng, n * rp);
        GaussianSketch { omega: Mat::from_vec(n, rp, data) }
    }

    /// `W[J, :] = kbᵀ Ω` for a block of kernel columns.
    pub fn apply_to_block(&self, kb: &Mat) -> Mat {
        kb.t_matmul(&self.omega)
    }
}

/// Zero-pad a vector to length `n` (kernel columns before FWHT).
pub fn pad_to(v: &[f64], n: usize) -> Vec<f64> {
    let mut out = vec![0.0; n];
    out[..v.len()].copy_from_slice(v);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::rng::Pcg64;

    fn hadamard_entry(i: usize, j: usize) -> f64 {
        if (i & j).count_ones() % 2 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    #[test]
    fn omega_matches_explicit_dhr() {
        let mut rng = Pcg64::seed(1);
        let s = Srht::draw(&mut rng, 32, 5);
        let om = s.omega();
        for i in 0..32 {
            for j in 0..5 {
                let want = s.d[i] * hadamard_entry(i, s.idx[j]);
                assert_eq!(om[(i, j)], want);
            }
        }
    }

    #[test]
    fn apply_to_block_equals_k_times_omega() {
        // the streaming path (scale, FWHT, gather) must equal K Ω exactly
        let mut rng = Pcg64::seed(2);
        let n = 64;
        let s = Srht::draw(&mut rng, n, 7);
        let kb = crate::linalg::testutil::random_mat(&mut rng, n, 9);
        let got = s.apply_to_block(&kb, 1); // (9, 7) = rows of W
        let want = kb.t_matmul(&s.omega()); // (9, 7)
        crate::linalg::testutil::assert_mat_close(&got, &want, 1e-9);
    }

    #[test]
    fn qt_omega_matches_explicit() {
        let mut rng = Pcg64::seed(3);
        let n = 64;
        let s = Srht::draw(&mut rng, n, 6);
        let q = crate::linalg::testutil::random_mat(&mut rng, n, 3);
        let got = s.qt_omega(&q);
        let want = q.t_matmul(&s.omega());
        crate::linalg::testutil::assert_mat_close(&got, &want, 1e-9);
    }

    #[test]
    fn srht_preserves_column_gram_up_to_scale() {
        // (HD) is n-times-orthogonal: (HDx)ᵀ(HDy) = n xᵀy; sampling then
        // estimates it. With all rows kept the identity is exact.
        let mut rng = Pcg64::seed(4);
        let n = 32;
        let mut s = Srht::draw(&mut rng, n, n);
        s.idx = (0..n).collect(); // keep every row
        let kb = crate::linalg::testutil::random_mat(&mut rng, n, 4);
        let w = s.apply_to_block(&kb, 1); // (4, n) rows of W
        let got = w.matmul_t(&w); // (4, 4) = kbᵀ (HD)ᵀ(HD) kb … wait, w = kbᵀ·(DH·)… w (4,n)
        let want = {
            let mut g = kb.t_matmul(&kb);
            g.scale(n as f64);
            g
        };
        crate::linalg::testutil::assert_mat_close(&got, &want, 1e-8);
    }

    #[test]
    fn gaussian_sketch_shapes_and_moments() {
        let mut rng = Pcg64::seed(5);
        let g = GaussianSketch::draw(&mut rng, 200, 10);
        assert_eq!((g.omega.rows(), g.omega.cols()), (200, 10));
        let mean: f64 = g.omega.data().iter().sum::<f64>() / 2000.0;
        assert!(mean.abs() < 0.08, "mean={mean}");
    }

    #[test]
    fn pad_to_extends_with_zeros() {
        let v = pad_to(&[1.0, 2.0], 8);
        assert_eq!(v, vec![1.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn srht_rejects_non_pow2() {
        let mut rng = Pcg64::seed(6);
        let _ = Srht::draw(&mut rng, 48, 4);
    }
}
