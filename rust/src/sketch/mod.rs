//! Randomized sketching: FWHT, the SRHT operator, and Gaussian sketches.
//!
//! The paper's structured test matrix is `Ω = D H R` (Rademacher diagonal,
//! Walsh–Hadamard, uniform column subsampling). The coordinator applies
//! it *implicitly* to streamed kernel columns — scale by `D`, FWHT,
//! subsample r' entries — so `H` is never stored (§4 of the paper). The
//! explicit small matrices needed by the recovery step (`Ω` restricted to
//! the sketch rows, `QᵀΩ`) are generated entry-wise from the same seed.

mod fwht;

pub use fwht::{fwht_columns, fwht_inplace, fwht_inplace_with, fwht_parallel};

use crate::linalg::Mat;
use crate::rng::{normal_vec, rademacher_vec, sample_without_replacement, Pcg64};

/// Next power of two (FWHT length requirement; data is zero-padded).
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// The paper's structured random test matrix `Ω = D H R`, held implicitly:
/// the Rademacher signs `d` and the sampled row indices `idx` (columns of
/// the identity forming `R`). `H` is applied via FWHT only.
#[derive(Clone, Debug)]
pub struct Srht {
    /// padded transform length (power of two)
    pub n: usize,
    /// Rademacher diagonal of `D`, length `n`
    pub d: Vec<f64>,
    /// the r' sampled indices (rows of `HD K` kept / columns of `R`)
    pub idx: Vec<usize>,
}

impl Srht {
    /// Draw a fresh SRHT for padded dimension `n` (power of two) keeping
    /// `rp = r + l` samples.
    pub fn draw(rng: &mut Pcg64, n: usize, rp: usize) -> Self {
        assert!(n.is_power_of_two(), "SRHT length must be a power of two");
        assert!(rp <= n, "cannot keep {rp} of {n} rows");
        Srht {
            n,
            d: rademacher_vec(rng, n),
            idx: sample_without_replacement(rng, n, rp),
        }
    }

    pub fn samples(&self) -> usize {
        self.idx.len()
    }

    /// Zero the Rademacher signs of the padded rows (`i >= n_real`).
    /// This makes the implicit padded kernel matrix exactly zero in the
    /// padding block for *any* kernel (the RBF gram of zero-padded data
    /// is not zero by itself) while keeping the recovery identity
    /// `W = K̃ Ω` exact. Must be called before any `apply_to_block` /
    /// `omega_entry` use when `n_real < n`.
    pub fn mask_padding(&mut self, n_real: usize) {
        for i in n_real..self.n {
            self.d[i] = 0.0;
        }
    }

    /// One entry of the *explicit* `Ω = D H R`: `Ω[i, j] = d_i · H[i, idx_j]`
    /// with the unnormalized Hadamard `H[a, b] = (-1)^{popcount(a & b)}`.
    #[inline]
    pub fn omega_entry(&self, i: usize, j: usize) -> f64 {
        let sign = ((i & self.idx[j]).count_ones() & 1) as i32;
        self.d[i] * if sign == 0 { 1.0 } else { -1.0 }
    }

    /// Materialize `Ω` (n × r') — only used by the recovery step to form
    /// `QᵀΩ`, never by the streaming pass.
    pub fn omega(&self) -> Mat {
        Mat::from_fn(self.n, self.idx.len(), |i, j| self.omega_entry(i, j))
    }

    /// `Qᵀ Ω` (r × r') without materializing Ω, via the FWHT identity
    /// `QᵀΩ = ((H (D Q))[idx, :])ᵀ` (H and D are symmetric): scale Q's
    /// rows by `d`, FWHT each column, gather the r' sampled rows.
    /// O(n log n · r) — independent of r', versus O(n · r · r') for the
    /// entrywise path ([`qt_omega_entrywise`](Self::qt_omega_entrywise)).
    pub fn qt_omega(&self, q: &Mat) -> Mat {
        self.qt_omega_threaded(q, 1)
    }

    /// [`qt_omega`](Self::qt_omega) with the per-column FWHTs fanned out
    /// over `threads` workers (bit-identical for any thread count — each
    /// column transforms independently).
    pub fn qt_omega_threaded(&self, q: &Mat, threads: usize) -> Mat {
        assert_eq!(q.rows(), self.n, "basis rows must match SRHT length");
        qt_omega_via_fwht(self, q, threads)
    }

    /// The pre-FWHT entrywise `QᵀΩ`: for each sampled column, accumulate
    /// `Qᵀ (D h_idx)` one Hadamard entry at a time — O(n · r · r') with a
    /// popcount per scalar. Kept as the reference/oracle for the sketch
    /// exactness tests and the `bench_recovery` before/after rows; the
    /// hot path is [`qt_omega`](Self::qt_omega).
    pub fn qt_omega_entrywise(&self, q: &Mat) -> Mat {
        assert_eq!(q.rows(), self.n, "basis rows must match SRHT length");
        let r = q.cols();
        let rp = self.idx.len();
        let mut out = Mat::zeros(r, rp);
        for i in 0..self.n {
            // out[:, j] += Ω[i, j] * q[i, :]
            for j in 0..rp {
                let w = self.omega_entry(i, j);
                for k in 0..r {
                    out[(k, j)] += w * q[(i, k)];
                }
            }
        }
        out
    }

    /// Apply the streaming half of the sketch to a block of kernel columns
    /// `kb` (n × b, already zero-padded): scale rows by `d`, FWHT each
    /// column, and gather the sampled rows. Returns the (b × r') slab of
    /// new sketch rows `W[J, :]` — exactly what the XLA `precond` artifact
    /// plus a row-gather produces on the accelerated path.
    ///
    /// Allocates a fresh transform buffer per call; streaming loops pass
    /// a reused one through
    /// [`apply_to_block_with`](Self::apply_to_block_with) instead.
    pub fn apply_to_block(&self, kb: &Mat, threads: usize) -> Mat {
        let mut scratch = Vec::new();
        self.apply_to_block_with(kb, threads, &mut scratch)
    }

    /// [`apply_to_block`](Self::apply_to_block) with a caller-owned flat
    /// scratch buffer: grown to `b · n` once and reused across blocks,
    /// so the streaming pass performs no per-block allocation (the old
    /// path built a `Vec<Vec<f64>>` per block).
    pub fn apply_to_block_with(
        &self,
        kb: &Mat,
        threads: usize,
        scratch: &mut Vec<f64>,
    ) -> Mat {
        assert_eq!(kb.rows(), self.n, "block rows must equal SRHT length");
        let b = kb.cols();
        let n = self.n;
        if scratch.len() < b * n {
            scratch.resize(b * n, 0.0);
        }
        let buf = &mut scratch[..b * n];
        // transpose to column-major while scaling by d: buf row j is
        // column j of kb times D (every entry written, no clearing)
        for i in 0..n {
            let di = self.d[i];
            for (j, &v) in kb.row(i).iter().enumerate() {
                buf[j * n + i] = di * v;
            }
        }
        fwht_parallel(buf, n, threads);
        Mat::from_fn(b, self.idx.len(), |j, s| buf[j * n + self.idx[s]])
    }
}

/// Core of the FWHT identity `QᵀΩ = ((H (D Q))[idx, :])ᵀ`, accepting a
/// basis with `q.rows() ≤ srht.n` rows — missing rows are implicit
/// zeros, exactly the zero-padded-kernel convention the recovery step
/// relies on (its Q spans the *real* rows only). Bit-identical for any
/// thread count; matches the explicit `QᵀΩ` up to FWHT summation-order
/// rounding.
pub fn qt_omega_via_fwht(srht: &Srht, q: &Mat, threads: usize) -> Mat {
    let n = srht.n;
    let n_real = q.rows();
    assert!(n_real <= n, "basis taller than the SRHT length");
    let r = q.cols();
    // buf row t = column t of Q scaled by D, zero-padded to length n
    let mut buf = vec![0.0f64; r * n];
    for i in 0..n_real {
        let di = srht.d[i];
        for (t, &v) in q.row(i).iter().enumerate() {
            buf[t * n + i] = di * v;
        }
    }
    fwht_parallel(&mut buf, n, threads);
    Mat::from_fn(r, srht.idx.len(), |t, j| buf[t * n + srht.idx[j]])
}

/// Dense Gaussian test matrix (the un-structured alternative from
/// Halko et al. §4; ablation baseline — same accuracy, O(n r') memory
/// for Ω itself and O(n² r') time for W = KΩ).
pub struct GaussianSketch {
    pub omega: Mat,
}

impl GaussianSketch {
    pub fn draw(rng: &mut Pcg64, n: usize, rp: usize) -> Self {
        let data = normal_vec(rng, n * rp);
        GaussianSketch { omega: Mat::from_vec(n, rp, data) }
    }

    /// `W[J, :] = kbᵀ Ω` for a block of kernel columns, through the
    /// shared GEMM core (`threads` fan the output rows; bit-identical
    /// for any thread count).
    pub fn apply_to_block(&self, kb: &Mat, threads: usize) -> Mat {
        crate::linalg::gemm_tn(kb, &self.omega, threads)
    }
}

/// Zero-pad a vector to length `n` (kernel columns before FWHT).
pub fn pad_to(v: &[f64], n: usize) -> Vec<f64> {
    let mut out = vec![0.0; n];
    out[..v.len()].copy_from_slice(v);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::rng::Pcg64;

    fn hadamard_entry(i: usize, j: usize) -> f64 {
        if (i & j).count_ones() % 2 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    #[test]
    fn omega_matches_explicit_dhr() {
        let mut rng = Pcg64::seed(1);
        let s = Srht::draw(&mut rng, 32, 5);
        let om = s.omega();
        for i in 0..32 {
            for j in 0..5 {
                let want = s.d[i] * hadamard_entry(i, s.idx[j]);
                assert_eq!(om[(i, j)], want);
            }
        }
    }

    #[test]
    fn apply_to_block_equals_k_times_omega() {
        // the streaming path (scale, FWHT, gather) must equal K Ω exactly
        let mut rng = Pcg64::seed(2);
        let n = 64;
        let s = Srht::draw(&mut rng, n, 7);
        let kb = crate::linalg::testutil::random_mat(&mut rng, n, 9);
        let got = s.apply_to_block(&kb, 1); // (9, 7) = rows of W
        let want = kb.t_matmul(&s.omega()); // (9, 7)
        crate::linalg::testutil::assert_mat_close(&got, &want, 1e-9);
    }

    #[test]
    fn qt_omega_matches_explicit() {
        let mut rng = Pcg64::seed(3);
        let n = 64;
        let s = Srht::draw(&mut rng, n, 6);
        let q = crate::linalg::testutil::random_mat(&mut rng, n, 3);
        let got = s.qt_omega(&q);
        let want = q.t_matmul(&s.omega());
        crate::linalg::testutil::assert_mat_close(&got, &want, 1e-9);
    }

    #[test]
    fn qt_omega_fwht_matches_entrywise_and_is_thread_invariant() {
        let mut rng = Pcg64::seed(7);
        let n = 128;
        let s = Srht::draw(&mut rng, n, 11);
        let q = crate::linalg::testutil::random_mat(&mut rng, n, 5);
        let fwht = s.qt_omega(&q);
        crate::linalg::testutil::assert_mat_close(&fwht, &s.qt_omega_entrywise(&q), 1e-10);
        for threads in [2usize, 4] {
            assert_eq!(fwht.data(), s.qt_omega_threaded(&q, threads).data(), "threads={threads}");
        }
    }

    #[test]
    fn qt_omega_fwht_matches_explicit_on_masked_padding() {
        // 50 real rows padded to 64 with mask_padding applied: the
        // real-rows variant (implicit zero rows) and the full padded
        // basis must agree bit-for-bit with each other and match the
        // explicit QᵀΩ — the identity the recovery solve rests on
        let mut rng = Pcg64::seed(8);
        let (n_real, n) = (50usize, 64usize);
        let mut s = Srht::draw(&mut rng, n, 9);
        s.mask_padding(n_real);
        let q_real = crate::linalg::testutil::random_mat(&mut rng, n_real, 4);
        let q_pad = Mat::from_fn(n, 4, |i, j| if i < n_real { q_real[(i, j)] } else { 0.0 });
        let want = q_pad.t_matmul(&s.omega());
        let got_real = qt_omega_via_fwht(&s, &q_real, 1);
        let got_pad = s.qt_omega(&q_pad);
        assert_eq!(got_real.data(), got_pad.data(), "padding rows must be inert");
        crate::linalg::testutil::assert_mat_close(&got_real, &want, 1e-10);
    }

    #[test]
    fn apply_to_block_with_reuses_scratch_across_block_sizes() {
        let mut rng = Pcg64::seed(9);
        let n = 64;
        let s = Srht::draw(&mut rng, n, 6);
        let mut scratch = Vec::new();
        // shrinking block sizes must not read stale scratch contents
        for b in [7usize, 3, 5] {
            let kb = crate::linalg::testutil::random_mat(&mut rng, n, b);
            let got = s.apply_to_block_with(&kb, 1, &mut scratch);
            let want = s.apply_to_block(&kb, 1);
            assert_eq!(got.data(), want.data(), "b={b}");
        }
    }

    #[test]
    fn srht_preserves_column_gram_up_to_scale() {
        // (HD) is n-times-orthogonal: (HDx)ᵀ(HDy) = n xᵀy; sampling then
        // estimates it. With all rows kept the identity is exact.
        let mut rng = Pcg64::seed(4);
        let n = 32;
        let mut s = Srht::draw(&mut rng, n, n);
        s.idx = (0..n).collect(); // keep every row
        let kb = crate::linalg::testutil::random_mat(&mut rng, n, 4);
        let w = s.apply_to_block(&kb, 1); // (4, n) rows of W
        let got = w.matmul_t(&w); // (4, 4) = kbᵀ (HD)ᵀ(HD) kb … wait, w = kbᵀ·(DH·)… w (4,n)
        let want = {
            let mut g = kb.t_matmul(&kb);
            g.scale(n as f64);
            g
        };
        crate::linalg::testutil::assert_mat_close(&got, &want, 1e-8);
    }

    #[test]
    fn gaussian_sketch_shapes_and_moments() {
        let mut rng = Pcg64::seed(5);
        let g = GaussianSketch::draw(&mut rng, 200, 10);
        assert_eq!((g.omega.rows(), g.omega.cols()), (200, 10));
        let mean: f64 = g.omega.data().iter().sum::<f64>() / 2000.0;
        assert!(mean.abs() < 0.08, "mean={mean}");
    }

    #[test]
    fn pad_to_extends_with_zeros() {
        let v = pad_to(&[1.0, 2.0], 8);
        assert_eq!(v, vec![1.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn srht_rejects_non_pow2() {
        let mut rng = Pcg64::seed(6);
        let _ = Srht::draw(&mut rng, 48, 4);
    }
}
