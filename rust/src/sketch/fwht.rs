//! Fast Walsh–Hadamard transform: scalar and fork-join multithreaded.
//!
//! The paper parallelizes the Hadamard application with pthreads and
//! reports an 11× speedup on 16 threads. Parallelism across *columns* is
//! embarrassing (each kernel column transforms independently), so the
//! rust hot path fans disjoint column chunks out through the shared
//! fork-join helper in [`crate::util::parallel`] — no locks on the data,
//! no shared mutable state. The per-vector transform is the classic
//! in-place butterfly: O(n log n), no allocation. The butterfly layer
//! routes through [`crate::simd::dispatch`]; being purely elementwise
//! (`a+b` / `a−b`, no reduction) it is bit-identical to the scalar
//! kernel on every ISA, so the FWHT keeps the crate-wide bit-exactness
//! contract even across `RKC_SIMD` modes.

use crate::simd::KernelTable;
use crate::util::parallel::for_each_task;

/// In-place unnormalized FWHT of a single power-of-two-length vector.
pub fn fwht_inplace(x: &mut [f64]) {
    fwht_inplace_with(x, crate::simd::dispatch());
}

/// [`fwht_inplace`] with an explicit kernel table — the seam the
/// cross-ISA property tests and `#simd` bench rows use to pin a
/// specific butterfly kernel regardless of the process dispatch.
pub fn fwht_inplace_with(x: &mut [f64], table: &KernelTable) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    let butterfly = table.butterfly;
    let mut h = 1;
    while h < n {
        let step = h * 2;
        // n is a power of two and step divides it, so every chunk is
        // exactly `step` long: lo/hi are the classic paired halves
        for chunk in x.chunks_mut(step) {
            let (lo, hi) = chunk.split_at_mut(h);
            butterfly(lo, hi);
        }
        h = step;
    }
}

/// FWHT of each column buffer, fork-joining over `threads` workers.
/// With `threads <= 1` this is the scalar loop (no spawn overhead).
pub fn fwht_columns(cols: &mut [Vec<f64>], threads: usize) {
    if threads <= 1 || cols.len() <= 1 {
        for c in cols.iter_mut() {
            fwht_inplace(c);
        }
        return;
    }
    let workers = threads.min(cols.len());
    let chunk = cols.len().div_ceil(workers);
    let tasks: Vec<&mut [Vec<f64>]> = cols.chunks_mut(chunk).collect();
    for_each_task(tasks, workers, |group| {
        for c in group.iter_mut() {
            fwht_inplace(c);
        }
    });
}

/// Convenience: parallel FWHT over a row-major (n_vectors × len) buffer.
pub fn fwht_parallel(data: &mut [f64], len: usize, threads: usize) {
    assert_eq!(data.len() % len, 0, "buffer must be a multiple of len");
    if threads <= 1 {
        for row in data.chunks_mut(len) {
            fwht_inplace(row);
        }
        return;
    }
    let nrows = data.len() / len;
    if nrows == 0 {
        return;
    }
    let workers = threads.min(nrows);
    let rows_per = nrows.div_ceil(workers);
    let tasks: Vec<&mut [f64]> = data.chunks_mut(rows_per * len).collect();
    for_each_task(tasks, workers, |group| {
        for row in group.chunks_mut(len) {
            fwht_inplace(row);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    fn slow_hadamard(x: &[f64]) -> Vec<f64> {
        let n = x.len();
        (0..n)
            .map(|i| {
                (0..n)
                    .map(|j| {
                        let s = if (i & j).count_ones() % 2 == 0 { 1.0 } else { -1.0 };
                        s * x[j]
                    })
                    .sum()
            })
            .collect()
    }

    #[test]
    fn matches_explicit_hadamard() {
        let mut rng = Pcg64::seed(1);
        for logn in 0..10 {
            let n = 1usize << logn;
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut y = x.clone();
            fwht_inplace(&mut y);
            let want = slow_hadamard(&x);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-9 * (n as f64), "n={n}");
            }
        }
    }

    #[test]
    fn involution_up_to_n() {
        let mut rng = Pcg64::seed(2);
        let n = 256;
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut y = x.clone();
        fwht_inplace(&mut y);
        fwht_inplace(&mut y);
        for (a, b) in y.iter().zip(&x) {
            assert!((a - n as f64 * b).abs() < 1e-9 * n as f64);
        }
    }

    #[test]
    fn parseval_energy() {
        let mut rng = Pcg64::seed(3);
        let n = 512;
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let e0: f64 = x.iter().map(|v| v * v).sum();
        let mut y = x;
        fwht_inplace(&mut y);
        let e1: f64 = y.iter().map(|v| v * v).sum();
        assert!((e1 - n as f64 * e0).abs() < 1e-8 * n as f64 * e0);
    }

    #[test]
    fn parallel_matches_scalar() {
        let mut rng = Pcg64::seed(4);
        let (nvec, len) = (13, 128);
        let base: Vec<f64> = (0..nvec * len).map(|_| rng.normal()).collect();
        let mut scalar = base.clone();
        fwht_parallel(&mut scalar, len, 1);
        for threads in [2, 3, 8, 32] {
            let mut par = base.clone();
            fwht_parallel(&mut par, len, threads);
            assert_eq!(scalar, par, "threads={threads}");
        }
    }

    #[test]
    fn columns_parallel_matches_scalar() {
        let mut rng = Pcg64::seed(5);
        let mut a: Vec<Vec<f64>> =
            (0..9).map(|_| (0..64).map(|_| rng.normal()).collect()).collect();
        let mut b = a.clone();
        fwht_columns(&mut a, 1);
        fwht_columns(&mut b, 4);
        assert_eq!(a, b);
    }

    #[test]
    fn every_available_table_is_bit_identical_to_scalar() {
        let mut rng = Pcg64::seed(6);
        for logn in [0usize, 1, 2, 5, 8, 10] {
            let n = 1usize << logn;
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut want = x.clone();
            fwht_inplace_with(&mut want, crate::simd::scalar_table());
            for table in crate::simd::available_tables() {
                let mut got = x.clone();
                fwht_inplace_with(&mut got, table);
                assert_eq!(got, want, "n={n} isa={}", table.isa.name());
            }
        }
    }

    #[test]
    fn length_one_is_identity() {
        let mut x = vec![7.5];
        fwht_inplace(&mut x);
        assert_eq!(x, vec![7.5]);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_pow2() {
        let mut x = vec![0.0; 48];
        fwht_inplace(&mut x);
    }
}
