//! Micro-benchmark harness (criterion substitute for this offline image).
//!
//! Warmup + timed iterations, reporting median / mean / p95 wall time.
//! Every `rust/benches/*.rs` target (`harness = false`) uses this to
//! print the paper's tables and figure series in a stable format that
//! `cargo bench 2>&1 | tee bench_output.txt` captures.
//!
//! Every bench also emits a machine-readable `BENCH_<name>.json` through
//! [`write_bench_json`] — one JSON object per configuration row, all
//! numeric values finite (non-finite values serialize as `null` via
//! [`Json::finite_num`](crate::util::Json::finite_num)). CI runs each
//! bench in the reduced [`quick_mode`] shape and validates the files
//! against `tools/check_bench_json.py`; timings themselves are never
//! gated in CI — the JSON trail exists so the perf trajectory is
//! diffable across commits.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use crate::util::{mean, percentile, Json};

/// Minimal Content-Length-framed HTTP/1.1 client for exercising the
/// serve front-end from benches and integration tests (the only two
/// in-crate HTTP clients). Sends requests sequentially on ONE socket
/// and parses each response by its `Content-Length`, so the connection
/// stays usable for the next request (keep-alive); panics on protocol
/// violations — it is test/bench plumbing, not production code.
pub struct MiniHttpClient {
    stream: TcpStream,
}

impl MiniHttpClient {
    /// Connect with a 10 s read timeout, so a server that wrongly stops
    /// responding fails the caller instead of hanging it.
    pub fn connect(addr: SocketAddr) -> Self {
        Self::try_connect(addr).expect("connecting to the serve front-end")
    }

    /// Non-panicking `connect`: `None` when the dial itself fails
    /// (refused, OS backlog overflow). Load replays count that as a
    /// dropped attempt instead of aborting the run.
    pub fn try_connect(addr: SocketAddr) -> Option<Self> {
        let stream = TcpStream::connect(addr).ok()?;
        stream.set_read_timeout(Some(Duration::from_secs(10))).ok()?;
        Some(MiniHttpClient { stream })
    }

    /// `try_connect` with a bounded retry budget: up to `attempts` dials,
    /// sleeping `backoff` (doubled each round) between failures. For
    /// chaos/recovery tests that poll a server which is still binding or
    /// restarting — NOT for load replays, whose dropped-attempt
    /// accounting depends on `try_connect`'s raw single-dial semantics.
    pub fn connect_with_retry(addr: SocketAddr, attempts: u32, backoff: Duration) -> Option<Self> {
        let mut delay = backoff;
        for attempt in 1..=attempts.max(1) {
            if let Some(client) = Self::try_connect(addr) {
                return Some(client);
            }
            if attempt < attempts {
                std::thread::sleep(delay);
                delay = delay.saturating_mul(2);
            }
        }
        None
    }

    /// Write raw bytes (hand-framed requests for malformed-input tests).
    pub fn send_raw(&mut self, bytes: &[u8]) {
        self.stream.write_all(bytes).expect("writing request");
    }

    /// One keep-alive request → `(status, body)`.
    pub fn request(&mut self, method: &str, path: &str, body: &str) -> (u16, String) {
        self.request_with(method, path, body, false)
    }

    /// One request, optionally asking the server to close afterwards.
    pub fn request_with(
        &mut self,
        method: &str,
        path: &str,
        body: &str,
        close: bool,
    ) -> (u16, String) {
        self.send_raw(frame_request(method, path, body, close).as_bytes());
        self.read_response().expect("server closed instead of responding")
    }

    /// Read one Content-Length-framed response; `None` on a clean close
    /// before any byte arrived.
    pub fn read_response(&mut self) -> Option<(u16, String)> {
        match self.read_response_impl() {
            Ok(resp) => resp,
            Err(e) => panic!("{e}"),
        }
    }

    /// Non-panicking `send_raw`: false when the socket refuses the
    /// write (the peer reset or closed it). For fault-injection traffic
    /// where broken connections are the point, not a bug.
    pub fn try_send_raw(&mut self, bytes: &[u8]) -> bool {
        self.stream.write_all(bytes).is_ok()
    }

    /// Non-panicking request/response pair: `None` on any transport or
    /// framing failure instead of a panic, so load replays can count a
    /// dead connection and move on.
    pub fn try_request(
        &mut self,
        method: &str,
        path: &str,
        body: &str,
        close: bool,
    ) -> Option<(u16, String)> {
        if !self.try_send_raw(frame_request(method, path, body, close).as_bytes()) {
            return None;
        }
        self.try_read_response()
    }

    /// Non-panicking `read_response`: `None` on close, reset, timeout,
    /// or a malformed head.
    pub fn try_read_response(&mut self) -> Option<(u16, String)> {
        self.read_response_impl().ok().flatten()
    }

    /// Wait up to `timeout` for a response the server pushed WITHOUT a
    /// request — the shed 503 a full connection queue writes at accept.
    /// `None` means nothing arrived (the connection was admitted and is
    /// still usable). Restores the default 10 s read timeout afterwards.
    pub fn probe(&mut self, timeout: Duration) -> Option<(u16, String)> {
        let _ = self.stream.set_read_timeout(Some(timeout));
        let got = self.read_response_impl().ok().flatten();
        let _ = self.stream.set_read_timeout(Some(Duration::from_secs(10)));
        got
    }

    fn read_response_impl(&mut self) -> Result<Option<(u16, String)>, String> {
        let mut buf: Vec<u8> = Vec::with_capacity(1024);
        let mut chunk = [0u8; 4096];
        let head_end = loop {
            if let Some(p) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
                break p;
            }
            match self.stream.read(&mut chunk) {
                Ok(0) if buf.is_empty() => return Ok(None),
                Ok(0) => return Err("connection closed mid-response-head".to_string()),
                Ok(n) => buf.extend_from_slice(&chunk[..n]),
                Err(e) => return Err(format!("reading response head: {e}")),
            }
        };
        let head = std::str::from_utf8(&buf[..head_end])
            .map_err(|_| "response head is not UTF-8".to_string())?;
        let status: u16 = head
            .split_whitespace()
            .nth(1)
            .ok_or_else(|| "response is missing its status line".to_string())?
            .parse()
            .map_err(|_| "non-numeric response status".to_string())?;
        let content_length: usize = head
            .lines()
            .find_map(|l| {
                let (k, v) = l.split_once(':')?;
                if k.trim().eq_ignore_ascii_case("content-length") {
                    v.trim().parse().ok()
                } else {
                    None
                }
            })
            .ok_or_else(|| "response is missing content-length".to_string())?;
        let total = head_end + 4 + content_length;
        while buf.len() < total {
            match self.stream.read(&mut chunk) {
                Ok(0) => return Err("connection closed mid-response-body".to_string()),
                Ok(n) => buf.extend_from_slice(&chunk[..n]),
                Err(e) => return Err(format!("reading response body: {e}")),
            }
        }
        Ok(Some((status, String::from_utf8_lossy(&buf[head_end + 4..total]).to_string())))
    }

    /// Assert the server closes this connection (after draining
    /// whatever response bytes remain in flight).
    pub fn assert_closed(mut self) {
        let mut chunk = [0u8; 256];
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => return,
                Ok(_) => continue,
                Err(e) => panic!("expected a clean close, got {e}"),
            }
        }
    }
}

fn frame_request(method: &str, path: &str, body: &str, close: bool) -> String {
    let connection = if close { "Connection: close\r\n" } else { "" };
    format!(
        "{method} {path} HTTP/1.1\r\nHost: rkc\r\nContent-Type: application/json\r\n\
         {connection}Content-Length: {}\r\n\r\n{body}",
        body.len()
    )
}

/// Percentile summary of per-request latencies, in milliseconds — the
/// single implementation behind `bench_serve`, `bench_stream`, and the
/// experiment load replayer (each used to hand-roll the same
/// `percentile(..) * 1e3` math).
#[derive(Clone, Copy, Debug)]
pub struct LatencySummary {
    pub count: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
}

impl LatencySummary {
    /// The `{prefix}p50_ms` / `{prefix}p95_ms` / `{prefix}p99_ms` /
    /// `{prefix}mean_ms` JSON fields every latency row shares
    /// (`BENCH_serve.json` rows use no prefix, `BENCH_stream.json` rows
    /// use `refresh_`). Non-finite values — the empty-sample case —
    /// serialize as `null`.
    pub fn json_fields(&self, prefix: &str) -> Vec<(String, Json)> {
        vec![
            (format!("{prefix}p50_ms"), Json::finite_num(self.p50_ms)),
            (format!("{prefix}p95_ms"), Json::finite_num(self.p95_ms)),
            (format!("{prefix}p99_ms"), Json::finite_num(self.p99_ms)),
            (format!("{prefix}mean_ms"), Json::finite_num(self.mean_ms)),
        ]
    }
}

/// Summarize latencies measured in SECONDS (what `Instant::elapsed`
/// yields) into milliseconds. An empty sample yields `count == 0` and
/// NaN statistics rather than panicking, so a scenario in which every
/// request died still produces a row.
pub fn latency_summary(latencies_s: &[f64]) -> LatencySummary {
    if latencies_s.is_empty() {
        return LatencySummary {
            count: 0,
            p50_ms: f64::NAN,
            p95_ms: f64::NAN,
            p99_ms: f64::NAN,
            mean_ms: f64::NAN,
        };
    }
    LatencySummary {
        count: latencies_s.len(),
        p50_ms: percentile(latencies_s, 50.0) * 1e3,
        p95_ms: percentile(latencies_s, 95.0) * 1e3,
        p99_ms: percentile(latencies_s, 99.0) * 1e3,
        mean_ms: mean(latencies_s) * 1e3,
    }
}

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "bench {:40} iters={:4} median={} mean={} p95={} min={}",
            self.name,
            self.iters,
            fmt_time(self.median_s),
            fmt_time(self.mean_s),
            fmt_time(self.p95_s),
            fmt_time(self.min_s),
        )
    }
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Time `f` with `warmup` unmeasured and `iters` measured runs. The
/// closure's return value is black-boxed to keep the optimizer honest.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(iters >= 1);
    for _ in 0..warmup {
        black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let result = BenchResult {
        name: name.to_string(),
        iters,
        median_s: percentile(&times, 50.0),
        mean_s: mean(&times),
        p95_s: percentile(&times, 95.0),
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
    };
    println!("{}", result.report_line());
    result
}

/// Adaptive variant: runs for roughly `budget_s` seconds (at least
/// `min_iters`), for benches whose single-run cost is unknown up front.
pub fn bench_for<T>(
    name: &str,
    budget_s: f64,
    min_iters: usize,
    mut f: impl FnMut() -> T,
) -> BenchResult {
    // one calibration run
    let t0 = Instant::now();
    black_box(f());
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / once) as usize).clamp(min_iters, 10_000);
    bench(name, (iters / 10).min(3), iters, f)
}

/// Prevent the optimizer from eliding the computation.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// True when `RKC_BENCH_QUICK=1` (or `true`): benches shrink to a CI
/// smoke shape — small n, one measured rep — that exists to exercise
/// the code paths and validate the emitted `BENCH_*.json` schema, not
/// to produce meaningful timings.
pub fn quick_mode() -> bool {
    std::env::var("RKC_BENCH_QUICK")
        .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
        .unwrap_or(false)
}

/// Write a bench's configuration rows to `path` as a JSON array — the
/// shared `BENCH_*.json` convention. An empty record set leaves any
/// previously recorded trajectory untouched rather than clobbering it.
pub fn write_bench_json(path: &str, records: Vec<Json>) {
    if records.is_empty() {
        eprintln!("no configurations measured; {path} untouched");
        return;
    }
    let out = Json::Arr(records).to_string();
    match std::fs::write(path, &out) {
        Ok(()) => println!("wrote {path} ({} bytes)", out.len()),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_statistics() {
        let r = bench("sleep_1ms", 1, 5, || {
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert_eq!(r.iters, 5);
        assert!(r.median_s >= 0.001);
        assert!(r.min_s <= r.median_s);
        assert!(r.median_s <= r.p95_s + 1e-9);
    }

    #[test]
    fn bench_for_respects_min_iters() {
        let r = bench_for("noop", 0.0, 3, || 42);
        assert!(r.iters >= 3);
    }

    #[test]
    fn latency_summary_converts_seconds_to_ms() {
        // 1..=100 ms; rank = round(p/100 * 99) lands on exact samples
        let lat_s: Vec<f64> = (1..=100).map(|i| i as f64 * 1e-3).collect();
        let s = latency_summary(&lat_s);
        assert_eq!(s.count, 100);
        assert!((s.p50_ms - 51.0).abs() < 1e-9);
        assert!((s.p95_ms - 95.0).abs() < 1e-9);
        assert!((s.p99_ms - 99.0).abs() < 1e-9);
        assert!((s.mean_ms - 50.5).abs() < 1e-9);
    }

    #[test]
    fn latency_summary_empty_is_nan_not_panic() {
        let s = latency_summary(&[]);
        assert_eq!(s.count, 0);
        assert!(s.p50_ms.is_nan() && s.p95_ms.is_nan() && s.p99_ms.is_nan());
        // finite_num turns those NaNs into null in the JSON row
        for (key, value) in s.json_fields("refresh_") {
            assert!(key.starts_with("refresh_"));
            assert_eq!(value.to_string(), "null");
        }
    }

    #[test]
    fn latency_json_fields_use_prefix_and_finite_values() {
        let s = latency_summary(&[0.002, 0.004, 0.006]);
        let fields = s.json_fields("");
        assert_eq!(fields.len(), 4);
        assert_eq!(fields[0].0, "p50_ms");
        assert_eq!(fields[0].1.to_string(), "4");
        assert_eq!(fields[3].0, "mean_ms");
        assert_eq!(fields[3].1.to_string(), "4");
    }

    #[test]
    fn report_line_formats_units() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            median_s: 0.5,
            mean_s: 2.0,
            p95_s: 0.0005,
            min_s: 0.0000005,
        };
        let line = r.report_line();
        assert!(line.contains("500.000ms"));
        assert!(line.contains("2.000s"));
        assert!(line.contains("0.5us"));
    }
}
