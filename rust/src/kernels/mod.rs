//! Kernel functions and streaming gram-block production.
//!
//! The coordinator never materializes the full n × n kernel matrix: it
//! consumes column blocks `K[:, J]` through the [`BlockSource`] trait.
//! `NativeBlockSource` computes blocks in rust (reference path, used by
//! tests and small problems); the XLA-artifact-backed source lives in
//! `runtime`/`coordinator` and runs the L1 Pallas gram kernel instead.

use std::fmt;
use std::str::FromStr;

use crate::error::RkcError;
use crate::linalg::Mat;

/// Mercer kernel functions used in the paper's experiments.
///
/// # Examples
///
/// ```
/// use rkc::kernels::Kernel;
///
/// // the paper's homogeneous quadratic: κ(x, y) = ⟨x, y⟩²
/// let k = Kernel::paper_poly2();
/// assert_eq!(k.eval(&[1.0, 2.0], &[3.0, -1.0]), 1.0);
///
/// // spec strings round-trip through FromStr/Display
/// assert_eq!("rbf:0.5".parse::<Kernel>().unwrap(), Kernel::Rbf { gamma: 0.5 });
/// assert_eq!(Kernel::Rbf { gamma: 0.5 }.to_string(), "rbf:0.5");
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// `(<x, y> + gamma)^degree`; `gamma = 0` is the homogeneous
    /// polynomial kernel `<x, y>^d` used for Table 1 and Fig. 3 (d = 2).
    Poly { gamma: f64, degree: u32 },
    /// `exp(-gamma ||x - y||²)`.
    Rbf { gamma: f64 },
    /// plain inner product (kernel K-means degenerates to K-means).
    Linear,
}

impl Kernel {
    /// The paper's kernel: homogeneous quadratic.
    pub fn paper_poly2() -> Self {
        Kernel::Poly { gamma: 0.0, degree: 2 }
    }

    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        match *self {
            Kernel::Poly { gamma, degree } => (dot(x, y) + gamma).powi(degree as i32),
            Kernel::Rbf { gamma } => {
                let d2: f64 = x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum();
                (-gamma * d2).exp()
            }
            Kernel::Linear => dot(x, y),
        }
    }

    /// Single-precision kernel evaluation for the opt-in f32 serving
    /// path ([`crate::config::Precision::F32`]): for dot-product kernels
    /// the inner product runs through the given table's `dot_f32`
    /// kernel, the nonlinearity in f32; the RBF squared distance stays a
    /// scalar pass (it needs `x - y`, not a dot, and the compiler
    /// vectorizes the subtract-square-sum on its own). Accuracy is
    /// bounded by the `f32_max_abs_dev` guard the serve bench reports,
    /// not by the crate's f64 contracts.
    ///
    /// Hot loops (one call per training point per query) should resolve
    /// the table once and use this; [`eval_f32`](Self::eval_f32) is the
    /// dispatch-per-call convenience wrapper.
    pub fn eval_f32_with(&self, x: &[f32], y: &[f32], table: &crate::simd::KernelTable) -> f32 {
        debug_assert_eq!(x.len(), y.len());
        match *self {
            Kernel::Poly { gamma, degree } => {
                ((table.dot_f32)(x, y) + gamma as f32).powi(degree as i32)
            }
            Kernel::Rbf { gamma } => {
                let d2: f32 = x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum();
                (-(gamma as f32) * d2).exp()
            }
            Kernel::Linear => (table.dot_f32)(x, y),
        }
    }

    /// [`eval_f32_with`](Self::eval_f32_with) on the process-selected
    /// kernel table.
    pub fn eval_f32(&self, x: &[f32], y: &[f32]) -> f32 {
        self.eval_f32_with(x, y, crate::simd::dispatch())
    }

    /// `κ(x, x)` from the squared norm alone (diagonal of K).
    pub fn eval_diag(&self, norm2: f64) -> f64 {
        match *self {
            Kernel::Poly { gamma, degree } => (norm2 + gamma).powi(degree as i32),
            Kernel::Rbf { .. } => 1.0,
            Kernel::Linear => norm2,
        }
    }

    /// Human-readable description (not parseable; see [`fmt::Display`]
    /// for the round-trippable form).
    pub fn describe(&self) -> String {
        match *self {
            Kernel::Poly { gamma, degree } => format!("poly(gamma={gamma},d={degree})"),
            Kernel::Rbf { gamma } => format!("rbf(gamma={gamma})"),
            Kernel::Linear => "linear".to_string(),
        }
    }
}

impl fmt::Display for Kernel {
    /// Round-trippable spec string: `poly2` (the paper's kernel),
    /// `poly:<gamma>:<degree>`, `rbf:<gamma>`, `linear`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            Kernel::Poly { gamma, degree } if gamma == 0.0 && degree == 2 => {
                write!(f, "poly2")
            }
            Kernel::Poly { gamma, degree } => write!(f, "poly:{gamma}:{degree}"),
            Kernel::Rbf { gamma } => write!(f, "rbf:{gamma}"),
            Kernel::Linear => write!(f, "linear"),
        }
    }
}

impl FromStr for Kernel {
    type Err = RkcError;

    fn from_str(s: &str) -> Result<Kernel, RkcError> {
        let bad = || RkcError::parse("kernel", s);
        match s {
            "poly2" => Ok(Kernel::paper_poly2()),
            "linear" => Ok(Kernel::Linear),
            _ if s.starts_with("rbf:") => {
                let g: f64 = s[4..].parse().map_err(|_| bad())?;
                Ok(Kernel::Rbf { gamma: g })
            }
            _ if s.starts_with("poly:") => {
                let rest = &s[5..];
                let (g, d) = rest.split_once(':').ok_or_else(bad)?;
                Ok(Kernel::Poly {
                    gamma: g.parse().map_err(|_| bad())?,
                    degree: d.parse().map_err(|_| bad())?,
                })
            }
            _ => Err(bad()),
        }
    }
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Produces column blocks of the (implicit, possibly padded) kernel
/// matrix. `n_padded` rows per block; columns indexed by the *unpadded*
/// sample index. Implementations must be deterministic.
pub trait BlockSource {
    /// number of real (unpadded) samples
    fn n(&self) -> usize;
    /// padded row count (power of two for the SRHT path; == n otherwise)
    fn n_padded(&self) -> usize;
    /// compute `K[:, cols]` as an (n_padded × cols.len()) matrix; padded
    /// rows are zero.
    fn block(&mut self, cols: &[usize]) -> Mat;
    /// the diagonal entries `K_ii` for i in 0..n (cheap: O(n p)).
    fn diag(&mut self) -> Vec<f64>;
    /// bytes of working memory a single `block` call requires (for the
    /// memory-accounting model; excludes the returned block itself).
    fn working_bytes(&self, block_cols: usize) -> usize {
        // default: the returned block dominates
        self.n_padded() * block_cols * std::mem::size_of::<f64>()
    }
}

/// Reference rust block source: gram blocks computed directly from the
/// data matrix (p × n) with the requested padding.
///
/// Block production parallelizes across output *rows* when configured
/// with [`with_threads`](Self::with_threads): each worker fills a
/// disjoint row range of the block, and every entry is computed with the
/// same accumulation order regardless of the worker count, so blocks are
/// bit-identical for any `threads` setting.
#[derive(Clone)]
pub struct NativeBlockSource {
    /// the data, transposed once to point-major `xᵀ` (n × p) — the gram
    /// GEMM's left operand and the *only* copy this source holds (the
    /// memory model's "data is shared, not accounted" premise stays true)
    xt: Mat,
    /// per-point squared norms `‖x_i‖²` (RBF distance identity + diag)
    xnorm2: Vec<f64>,
    kernel: Kernel,
    n_padded: usize,
    threads: usize,
}

impl NativeBlockSource {
    /// Source over `x` (p × n) padding blocks to `n_padded` rows.
    pub fn new(x: Mat, kernel: Kernel, n_padded: usize) -> Self {
        assert!(n_padded >= x.cols(), "padding smaller than data");
        let xt = x.transpose();
        let xnorm2 = (0..xt.rows()).map(|i| xt.row(i).iter().map(|v| v * v).sum()).collect();
        NativeBlockSource { xt, xnorm2, kernel, n_padded, threads: 1 }
    }

    /// Convenience: pad to the next power of two (SRHT requirement).
    pub fn pow2(x: Mat, kernel: Kernel) -> Self {
        let n_padded = x.cols().next_power_of_two();
        Self::new(x, kernel, n_padded)
    }

    /// Fan gram-row computation out over `threads` workers per `block`
    /// call (`0` = auto-detect; see [`crate::util::parallel`]).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = crate::util::parallel::resolve_threads(threads).max(1);
        self
    }

    /// The kernel function this source evaluates.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// Compute `K[:, cols]` without requiring `&mut self` — the native
    /// gram path is pure, so concurrent producers can share one source
    /// by reference ([`BlockSource::block`] delegates here).
    pub fn compute_block(&self, cols: &[usize]) -> Mat {
        let n = self.xt.rows();
        let p = self.xt.cols();
        let b = cols.len();
        let mut out = Mat::zeros(self.n_padded, b);
        if b == 0 || n == 0 {
            return out;
        }
        let xb = Mat::from_fn(p, b, |d, bj| {
            let j = cols[bj];
            assert!(j < n, "column index {j} out of range (n={n})");
            self.xt[(j, d)]
        });
        // Gram core: out[:n, :] = xᵀ · xb as one call into the shared
        // cache-blocked GEMM (linalg::gemm) — branch-free inner axpy (the
        // old per-element `xi == 0.0` skip pessimized dense data), packed
        // panels, threaded over output rows with a scheduling-independent
        // accumulation order, so blocks stay bit-identical for any
        // `threads` setting. The padded tail is untouched (stays zero).
        let (real_rows, _padding) = out.data_mut().split_at_mut(n * b);
        crate::linalg::gemm_into(real_rows, &self.xt, &xb, self.threads);
        // kernel nonlinearity as a second elementwise pass over the rows
        match self.kernel {
            Kernel::Linear => {}
            Kernel::Poly { gamma, degree } => {
                let e = degree as i32;
                crate::util::parallel::for_each_row_chunk(real_rows, b, self.threads, |_, rows| {
                    for v in rows.iter_mut() {
                        *v = (*v + gamma).powi(e);
                    }
                });
            }
            Kernel::Rbf { gamma } => {
                // ‖x−y‖² = ‖x‖² + ‖y‖² − 2⟨x,y⟩ from the dot product
                let xn = &self.xnorm2;
                let ys: Vec<f64> = cols.iter().map(|&j| xn[j]).collect();
                let ys = &ys;
                crate::util::parallel::for_each_row_chunk(real_rows, b, self.threads, |i0, rows| {
                    for (di, orow) in rows.chunks_mut(b).enumerate() {
                        let xs_i = xn[i0 + di];
                        for (bj, v) in orow.iter_mut().enumerate() {
                            *v = (-gamma * (xs_i + ys[bj] - 2.0 * *v)).exp();
                        }
                    }
                });
            }
        }
        out
    }
}

impl BlockSource for NativeBlockSource {
    fn n(&self) -> usize {
        self.xt.rows()
    }

    fn n_padded(&self) -> usize {
        self.n_padded
    }

    fn block(&mut self, cols: &[usize]) -> Mat {
        self.compute_block(cols)
    }

    fn diag(&mut self) -> Vec<f64> {
        self.xnorm2.iter().map(|&norm2| self.kernel.eval_diag(norm2)).collect()
    }
}

/// Materialize the full (unpadded) kernel matrix — baselines and tests
/// only; the O(n²) cost is the problem the paper solves.
pub fn full_kernel_matrix(x: &Mat, kernel: Kernel) -> Mat {
    let n = x.cols();
    let p = x.rows();
    let cols: Vec<Vec<f64>> =
        (0..n).map(|j| (0..p).map(|d| x[(d, j)]).collect()).collect();
    let mut k = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let v = kernel.eval(&cols[i], &cols[j]);
            k[(i, j)] = v;
            k[(j, i)] = v;
        }
    }
    k
}

/// [`full_kernel_matrix`] with the rows fanned out over `threads`
/// workers (`0` = auto-detect). Each worker evaluates full rows of a
/// disjoint range — symmetry is *not* exploited, trading 2× arithmetic
/// for an embarrassingly parallel layout — and `κ(x, y)` is evaluated
/// with a scheduling-independent accumulation order, so the result is
/// bit-identical to the sequential baseline for any thread count.
pub fn full_kernel_matrix_threaded(x: &Mat, kernel: Kernel, threads: usize) -> Mat {
    let threads = crate::util::parallel::resolve_threads(threads);
    if threads <= 1 {
        return full_kernel_matrix(x, kernel);
    }
    let n = x.cols();
    let p = x.rows();
    let mut k = Mat::zeros(n, n);
    if n == 0 {
        return k;
    }
    let cols: Vec<Vec<f64>> =
        (0..n).map(|j| (0..p).map(|d| x[(d, j)]).collect()).collect();
    let cols_ref = &cols;
    crate::util::parallel::for_each_row_chunk(k.data_mut(), n, threads, |i0, rows| {
        for (di, krow) in rows.chunks_mut(n).enumerate() {
            let xi = &cols_ref[i0 + di];
            for (j, v) in krow.iter_mut().enumerate() {
                *v = kernel.eval(xi, &cols_ref[j]);
            }
        }
    });
    k
}

/// Split `0..n` into consecutive batches of at most `batch` columns.
pub fn column_batches(n: usize, batch: usize) -> Vec<Vec<usize>> {
    assert!(batch > 0);
    (0..n)
        .step_by(batch)
        .map(|start| (start..(start + batch).min(n)).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::testutil::{assert_mat_close, random_mat};
    use crate::rng::{Pcg64, Rng};

    #[test]
    fn kernel_evals() {
        let x = [1.0, 2.0];
        let y = [3.0, -1.0];
        assert_eq!(Kernel::Linear.eval(&x, &y), 1.0);
        assert_eq!(Kernel::paper_poly2().eval(&x, &y), 1.0);
        assert_eq!(Kernel::Poly { gamma: 1.0, degree: 3 }.eval(&x, &y), 8.0);
        let rbf = Kernel::Rbf { gamma: 0.5 }.eval(&x, &y);
        assert!((rbf - (-0.5f64 * 13.0).exp()).abs() < 1e-12);
    }

    #[test]
    fn eval_f32_tracks_f64_within_single_precision() {
        let mut rng = Pcg64::seed(31);
        for kern in [
            Kernel::Linear,
            Kernel::paper_poly2(),
            Kernel::Poly { gamma: 1.0, degree: 3 },
            Kernel::Rbf { gamma: 0.7 },
        ] {
            // odd length exercises the dot_f32 tail
            for _ in 0..20 {
                let x: Vec<f64> = (0..13).map(|_| rng.normal()).collect();
                let y: Vec<f64> = (0..13).map(|_| rng.normal()).collect();
                let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
                let yf: Vec<f32> = y.iter().map(|&v| v as f32).collect();
                let want = kern.eval(&x, &y);
                let got = kern.eval_f32(&xf, &yf) as f64;
                let tol = 1e-4 * want.abs().max(1.0);
                assert!((got - want).abs() <= tol, "{kern:?}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn diag_matches_eval() {
        let x = [0.5, -2.0, 1.0];
        let n2: f64 = x.iter().map(|v| v * v).sum();
        for k in [Kernel::Linear, Kernel::paper_poly2(), Kernel::Rbf { gamma: 0.7 }] {
            assert!((k.eval(&x, &x) - k.eval_diag(n2)).abs() < 1e-12);
        }
    }

    #[test]
    fn native_block_source_matches_full_matrix() {
        let mut rng = Pcg64::seed(1);
        let x = random_mat(&mut rng, 3, 20);
        let kern = Kernel::paper_poly2();
        let full = full_kernel_matrix(&x, kern);
        let mut src = NativeBlockSource::new(x, kern, 32);
        let cols: Vec<usize> = vec![0, 5, 19, 7];
        let block = src.block(&cols);
        assert_eq!((block.rows(), block.cols()), (32, 4));
        for (bj, &j) in cols.iter().enumerate() {
            for i in 0..20 {
                assert!((block[(i, bj)] - full[(i, j)]).abs() < 1e-12);
            }
            for i in 20..32 {
                assert_eq!(block[(i, bj)], 0.0, "padding must be zero");
            }
        }
    }

    #[test]
    fn diag_matches_full_matrix() {
        let mut rng = Pcg64::seed(2);
        let x = random_mat(&mut rng, 4, 15);
        let kern = Kernel::Rbf { gamma: 1.3 };
        let full = full_kernel_matrix(&x, kern);
        let mut src = NativeBlockSource::pow2(x, kern);
        let d = src.diag();
        for i in 0..15 {
            assert!((d[i] - full[(i, i)]).abs() < 1e-12);
        }
    }

    #[test]
    fn full_kernel_is_symmetric_psd_for_poly() {
        let mut rng = Pcg64::seed(3);
        let x = random_mat(&mut rng, 2, 12);
        let k = full_kernel_matrix(&x, Kernel::paper_poly2());
        assert_mat_close(&k.transpose(), &k, 1e-12);
        let (evals, _) = crate::linalg::jacobi_eig(&k);
        assert!(evals.iter().all(|&l| l > -1e-9 * evals[0].max(1.0)));
    }

    #[test]
    fn kernel_display_fromstr_roundtrip() {
        for k in [
            Kernel::paper_poly2(),
            Kernel::Poly { gamma: 1.0, degree: 3 },
            Kernel::Rbf { gamma: 2.5 },
            Kernel::Linear,
        ] {
            assert_eq!(k.to_string().parse::<Kernel>().unwrap(), k, "{k}");
        }
        assert_eq!("poly2".parse::<Kernel>().unwrap(), Kernel::paper_poly2());
        assert!("poly:abc:2".parse::<Kernel>().is_err());
        assert!("sigmoid".parse::<Kernel>().is_err());
    }

    #[test]
    fn column_batches_cover_everything() {
        let batches = column_batches(10, 4);
        assert_eq!(batches, vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7], vec![8, 9]]);
        let flat: Vec<usize> = batches.into_iter().flatten().collect();
        assert_eq!(flat, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn threaded_block_source_is_bit_identical() {
        let mut rng = Pcg64::seed(5);
        let x = random_mat(&mut rng, 4, 37);
        let cols: Vec<usize> = vec![0, 3, 9, 36, 17];
        for kern in [Kernel::paper_poly2(), Kernel::Rbf { gamma: 0.8 }, Kernel::Linear] {
            let base = NativeBlockSource::pow2(x.clone(), kern).block(&cols);
            for threads in [2usize, 3, 8] {
                let mut par = NativeBlockSource::pow2(x.clone(), kern).with_threads(threads);
                assert_eq!(base.data(), par.block(&cols).data(), "{kern} threads={threads}");
            }
        }
    }

    #[test]
    fn threaded_full_kernel_matrix_is_bit_identical() {
        let mut rng = Pcg64::seed(6);
        let x = random_mat(&mut rng, 3, 25);
        for kern in [Kernel::paper_poly2(), Kernel::Rbf { gamma: 1.1 }, Kernel::Linear] {
            let a = full_kernel_matrix(&x, kern);
            let b = full_kernel_matrix_threaded(&x, kern, 4);
            assert_eq!(a.data(), b.data(), "{kern}");
        }
    }

    #[test]
    fn streamed_blocks_reassemble_full_kernel() {
        let mut rng = Pcg64::seed(4);
        let x = random_mat(&mut rng, 3, 17);
        let kern = Kernel::paper_poly2();
        let full = full_kernel_matrix(&x, kern);
        let mut src = NativeBlockSource::new(x, kern, 17);
        let mut rebuilt = Mat::zeros(17, 17);
        for batch in column_batches(17, 5) {
            let blk = src.block(&batch);
            for (bj, &j) in batch.iter().enumerate() {
                for i in 0..17 {
                    rebuilt[(i, j)] = blk[(i, bj)];
                }
            }
        }
        assert_mat_close(&rebuilt, &full, 1e-12);
    }
}
