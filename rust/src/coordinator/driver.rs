//! Experiment driver: dataset construction, method dispatch over both
//! backends, metric collection, and the multi-trial protocol (the paper
//! re-runs every stochastic method 100 times and reports means).

use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::clustering::{
    accuracy, adjusted_rand_index, kernel_kmeans, kmeans, normalized_mutual_info, KmeansOpts,
};
use crate::config::{Backend, ExperimentConfig, Method};
use crate::data::{self, Dataset};
use crate::kernels::{full_kernel_matrix, BlockSource, NativeBlockSource};
use crate::linalg::Mat;
use crate::lowrank::{
    exact_topr_streaming, nystrom, one_pass_recovery, streamed_frobenius_error, Embedding,
    NystromSampling, OnePassSketch,
};
use crate::metrics::{MemoryModel, MethodMemory};
use crate::rng::Pcg64;
use crate::runtime::ArtifactRegistry;
use crate::sketch::{GaussianSketch, Srht};

use super::pipeline::{run_sketch_pass, run_sketch_pass_threaded};
use super::sources::{FusedXlaSketchRows, NativeSketchRows, XlaBlockSource};

/// Everything one trial produces.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub method: String,
    pub accuracy: f64,
    pub nmi: f64,
    pub ari: f64,
    /// normalized kernel approximation error ‖K−K̂‖_F/‖K‖_F (NaN when
    /// the method has no embedding, e.g. plain K-means)
    pub approx_error: f64,
    pub kmeans_objective: f64,
    pub memory: MethodMemory,
    pub sketch_time: Duration,
    pub recovery_time: Duration,
    pub kmeans_time: Duration,
    pub error_time: Duration,
}

/// Construct the dataset named in the config (deterministic per seed).
pub fn build_dataset(cfg: &ExperimentConfig) -> Result<Dataset> {
    let mut rng = Pcg64::seed_stream(cfg.seed, 0xda7a);
    Ok(match cfg.dataset.as_str() {
        "two_rings" => data::two_rings(&mut rng, cfg.n),
        "cross_lines" => data::cross_lines(&mut rng, cfg.n),
        "segmentation_like" => {
            // prefer the real UCI file when the user provides it
            if let Some(ds) = data::load_segmentation_csv("data/segmentation.csv") {
                ds
            } else {
                data::segmentation_like(&mut rng, cfg.n, cfg.p, cfg.k)
            }
        }
        "blobs" => data::gaussian_blobs(&mut rng, cfg.n, cfg.p, cfg.k, 0.6),
        "two_moons" => data::two_moons(&mut rng, cfg.n, 0.08),
        path if path.ends_with(".csv") => data::load_segmentation_csv(path)
            .ok_or_else(|| anyhow!("cannot load dataset file {path}"))?,
        other => return Err(anyhow!("unknown dataset '{other}'")),
    })
}

/// Run one trial of `cfg.method` with the trial-specific `seed`.
pub fn run_experiment(
    cfg: &ExperimentConfig,
    ds: &Dataset,
    registry: Option<&ArtifactRegistry>,
    seed: u64,
) -> Result<RunOutcome> {
    let mut rng = Pcg64::seed_stream(seed, 0x7a1a1);
    let n = ds.n();
    // XLA backend: pad up to the nearest compiled artifact size (free —
    // padded rows/cols of the implicit kernel are zero); native: pow2.
    let n_pad = match (cfg.backend, registry) {
        (Backend::Xla, Some(reg)) => {
            super::sources::xla_preferred_n_pad(reg, cfg.kernel, ds.p(), n)
                .unwrap_or_else(|| n.next_power_of_two())
        }
        _ => n.next_power_of_two(),
    };
    let kopts = KmeansOpts {
        k: ds.k,
        restarts: cfg.kmeans_restarts,
        max_iters: cfg.kmeans_iters,
        tol: 1e-9,
    };

    let mut sketch_time = Duration::ZERO;
    let mut recovery_time = Duration::ZERO;
    let mut kmeans_time = Duration::ZERO;
    let mut error_time = Duration::ZERO;

    // --- produce the embedding (or run the non-embedding baselines) ---
    let (embedding, memory): (Option<Embedding>, MethodMemory) = match cfg.method {
        Method::PlainKmeans => {
            let t0 = Instant::now();
            let res = kmeans(&ds.x, &kopts, &mut rng);
            kmeans_time += t0.elapsed();
            let acc = accuracy(&res.labels, &ds.labels, ds.k.max(cfg.k));
            return Ok(RunOutcome {
                method: cfg.method.name(),
                accuracy: acc,
                nmi: normalized_mutual_info(&res.labels, &ds.labels, ds.k),
                ari: adjusted_rand_index(&res.labels, &ds.labels, ds.k),
                approx_error: f64::NAN,
                kmeans_objective: res.objective,
                memory: MethodMemory {
                    method: cfg.method.name(),
                    persistent: 8 * ds.p() * ds.k,
                    transient: 0,
                    recovery: 0,
                },
                sketch_time,
                recovery_time,
                kmeans_time,
                error_time,
            });
        }
        Method::FullKernel => {
            let t0 = Instant::now();
            let kmat = full_kernel_matrix(&ds.x, cfg.kernel);
            sketch_time += t0.elapsed(); // "sketch" = materialization here
            let t1 = Instant::now();
            let res = kernel_kmeans(&kmat, ds.k, cfg.kmeans_restarts, cfg.kmeans_iters, &mut rng);
            kmeans_time += t1.elapsed();
            let acc = accuracy(&res.labels, &ds.labels, ds.k);
            return Ok(RunOutcome {
                method: cfg.method.name(),
                accuracy: acc,
                nmi: normalized_mutual_info(&res.labels, &ds.labels, ds.k),
                ari: adjusted_rand_index(&res.labels, &ds.labels, ds.k),
                approx_error: 0.0,
                kmeans_objective: res.objective,
                memory: MemoryModel::full_kernel_kmeans(n, ds.k),
                sketch_time,
                recovery_time,
                kmeans_time,
                error_time,
            });
        }
        Method::OnePass => {
            let rp = cfg.sketch_width();
            let mut srht = Srht::draw(&mut rng, n_pad, rp);
            srht.mask_padding(n);
            let t0 = Instant::now();
            let (sketch, _stats) = match cfg.backend {
                Backend::Native => {
                    if cfg.threads > 1 {
                        run_sketch_pass_threaded(
                            NativeBlockSource::new(ds.x.clone(), cfg.kernel, n_pad),
                            srht,
                            cfg.batch,
                            2,
                            cfg.threads,
                        )
                    } else {
                        let mut p = NativeSketchRows {
                            src: NativeBlockSource::new(ds.x.clone(), cfg.kernel, n_pad),
                            srht,
                            threads: 1,
                        };
                        run_sketch_pass(&mut p, n, cfg.batch)
                    }
                }
                Backend::Xla => {
                    let registry =
                        registry.ok_or_else(|| anyhow!("XLA backend requires a registry"))?;
                    match FusedXlaSketchRows::new(registry, &ds.x, cfg.kernel, srht.clone()) {
                        Ok(mut p) => run_xla_sketch_pass(&mut p, &ds.x, n)?,
                        // no artifact for this (kernel, p, n) — fall back
                        // to the native path rather than failing the job
                        // (the artifact set covers the paper's workloads)
                        Err(_) => {
                            let mut p = NativeSketchRows {
                                src: NativeBlockSource::new(ds.x.clone(), cfg.kernel, n_pad),
                                srht,
                                threads: cfg.threads.max(1),
                            };
                            run_sketch_pass(&mut p, n, cfg.batch)
                        }
                    }
                }
            };
            sketch_time += t0.elapsed();
            let t1 = Instant::now();
            let emb = one_pass_recovery(&sketch, cfg.rank);
            recovery_time += t1.elapsed();
            (Some(emb), MemoryModel::one_pass(n, n_pad, rp, cfg.rank, cfg.batch))
        }
        Method::GaussianOnePass => {
            let rp = cfg.sketch_width();
            // dense Gaussian test matrix over the padded length, padded
            // rows zeroed (same masking convention as the SRHT)
            let gauss = {
                let mut g = GaussianSketch::draw(&mut rng, n_pad, rp);
                for i in n..n_pad {
                    for j in 0..rp {
                        g.omega[(i, j)] = 0.0;
                    }
                }
                g
            };
            // reuse the one-pass recovery through a synthetic Srht-free
            // sketch: accumulate W = KΩ block by block
            let t0 = Instant::now();
            let mut src: Box<dyn BlockSource> = make_block_source(cfg, ds, registry, n_pad)?;
            let mut w = Mat::zeros(n, rp);
            for cols in crate::kernels::column_batches(n, cfg.batch) {
                let kb = src.block(&cols);
                let rows = gauss.apply_to_block(&kb); // b × r'
                for (bj, &j) in cols.iter().enumerate() {
                    w.row_mut(j).copy_from_slice(rows.row(bj));
                }
            }
            sketch_time += t0.elapsed();
            let t1 = Instant::now();
            let emb = gaussian_recovery(&w, &gauss, n, cfg.rank);
            recovery_time += t1.elapsed();
            // memory: Ω itself is n_pad × r' dense — the structured-vs-
            // Gaussian gap the paper's §4 calls out
            let mut mem = MemoryModel::one_pass(n, n_pad, rp, cfg.rank, cfg.batch);
            mem.method = cfg.method.name();
            mem.persistent += 8 * n_pad * rp;
            (Some(emb), mem)
        }
        Method::Nystrom { m } => {
            let t0 = Instant::now();
            let mut src: Box<dyn BlockSource> = make_block_source(cfg, ds, registry, n_pad)?;
            let emb = nystrom(src.as_mut(), m, cfg.rank, NystromSampling::Uniform, &mut rng);
            sketch_time += t0.elapsed();
            (Some(emb), MemoryModel::nystrom(n, m, cfg.rank))
        }
        Method::Exact => {
            let t0 = Instant::now();
            let mut src: Box<dyn BlockSource> = make_block_source(cfg, ds, registry, n_pad)?;
            let emb = exact_topr_streaming(src.as_mut(), cfg.rank, 40, cfg.batch);
            sketch_time += t0.elapsed();
            (Some(emb), MemoryModel::exact_streaming(n, n_pad, cfg.rank, cfg.batch))
        }
    };

    let emb = embedding.expect("embedding methods reach here");

    // --- K-means on the embedding ---
    let t0 = Instant::now();
    let res = match cfg.backend {
        Backend::Xla => {
            let registry = registry.ok_or_else(|| anyhow!("XLA backend requires a registry"))?;
            match super::xla_kmeans(registry, &emb.y, &kopts, &mut rng) {
                Ok(r) => r,
                // no artifact for this (r, k, n) — fall back silently;
                // the artifact set covers the paper's experiments
                Err(_) => kmeans(&emb.y, &kopts, &mut rng),
            }
        }
        Backend::Native => kmeans(&emb.y, &kopts, &mut rng),
    };
    kmeans_time += t0.elapsed();

    // --- streamed approximation error (one extra pass) ---
    let t1 = Instant::now();
    let mut src: Box<dyn BlockSource> = make_block_source(cfg, ds, registry, n_pad)?;
    let approx_error = streamed_frobenius_error(src.as_mut(), &emb, cfg.batch);
    error_time += t1.elapsed();

    Ok(RunOutcome {
        method: cfg.method.name(),
        accuracy: accuracy(&res.labels, &ds.labels, ds.k),
        nmi: normalized_mutual_info(&res.labels, &ds.labels, ds.k),
        ari: adjusted_rand_index(&res.labels, &ds.labels, ds.k),
        approx_error,
        kmeans_objective: res.objective,
        memory,
        sketch_time,
        recovery_time,
        kmeans_time,
        error_time,
    })
}

fn make_block_source(
    cfg: &ExperimentConfig,
    ds: &Dataset,
    registry: Option<&ArtifactRegistry>,
    n_pad: usize,
) -> Result<Box<dyn BlockSource>> {
    Ok(match cfg.backend {
        Backend::Native => Box::new(NativeBlockSource::new(ds.x.clone(), cfg.kernel, n_pad)),
        Backend::Xla => {
            let registry = registry.ok_or_else(|| anyhow!("XLA backend requires a registry"))?;
            match XlaBlockSource::new(registry, ds.x.clone(), cfg.kernel, n_pad) {
                Ok(src) => Box::new(src),
                // graceful degradation when no gram artifact matches
                Err(_) => Box::new(NativeBlockSource::new(ds.x.clone(), cfg.kernel, n_pad)),
            }
        }
    })
}

/// Sequential sketch pass over the fused XLA producer (PJRT handles are
/// not Send, so this cannot reuse the threaded native pipeline).
fn run_xla_sketch_pass(
    p: &mut FusedXlaSketchRows,
    x: &Mat,
    n_real: usize,
) -> Result<(OnePassSketch, super::pipeline::StageStats)> {
    let mut sketch = OnePassSketch::new(p.srht().clone(), n_real);
    let mut stats = super::pipeline::StageStats::default();
    // the artifact has a fixed batch width; stream at exactly that width
    let width = p.batch_width();
    for cols in crate::kernels::column_batches(n_real, width) {
        let t0 = Instant::now();
        let rows = p.rows_for(x, &cols)?;
        stats.produce_time += t0.elapsed();
        sketch.ingest(&cols, &rows);
        stats.blocks += 1;
    }
    stats.peak_in_flight = 1;
    Ok((sketch, stats))
}

/// One-pass recovery for the Gaussian sketch (Ω explicit): identical
/// math to `one_pass_recovery` (full-r'-basis variant) with a dense Ω.
fn gaussian_recovery(w: &Mat, gauss: &GaussianSketch, n_real: usize, rank: usize) -> Embedding {
    use crate::linalg::{householder_qr, jacobi_eig, least_squares};
    let rp = w.cols();
    let (qfull, rmat) = householder_qr(w); // n × r'
    let rrt = rmat.matmul_t(&rmat);
    let (sv2, u) = jacobi_eig(&rrt);
    let smax2 = sv2[0].max(0.0);
    let numerical_rank = sv2.iter().filter(|&&s2| s2 > 1e-14 * smax2).count();
    let qdim = numerical_rank.clamp(rank.min(rp), rp);
    let uq = Mat::from_fn(rp, qdim, |i, j| u[(i, j)]);
    let q = qfull.matmul(&uq);
    // QᵀΩ over real rows
    let omega_real = Mat::from_fn(n_real, rp, |i, j| gauss.omega[(i, j)]);
    let qt_omega = q.t_matmul(&omega_real); // q × r'
    let qt_w = q.t_matmul(w); // q × r'
    let bt = least_squares(&qt_omega.transpose(), &qt_w.transpose());
    let mut b = bt.transpose();
    b.symmetrize();
    let (evals, v) = jacobi_eig(&b);
    let mut clamped: Vec<f64> =
        evals.iter().take(rank.min(qdim)).map(|&l| l.max(0.0)).collect();
    clamped.resize(rank, 0.0);
    let mut y = Mat::zeros(rank, n_real);
    for i in 0..rank.min(qdim) {
        let s = clamped[i].sqrt();
        for j in 0..n_real {
            let mut acc = 0.0;
            for k in 0..qdim {
                acc += v[(k, i)] * q[(j, k)];
            }
            y[(i, j)] = s * acc;
        }
    }
    Embedding { y, eigenvalues: clamped }
}

/// Aggregate over trials: mean ± std of the headline metrics.
#[derive(Clone, Debug)]
pub struct TrialAggregate {
    pub method: String,
    pub trials: usize,
    pub accuracy_mean: f64,
    pub accuracy_std: f64,
    pub error_mean: f64,
    pub error_std: f64,
    pub nmi_mean: f64,
    pub objective_mean: f64,
    pub peak_memory_bytes: usize,
    pub total_time: Duration,
}

/// The paper's protocol: `cfg.trials` independent runs (distinct seeds),
/// means reported. Deterministic methods (exact, full, plain) run once.
pub fn run_trials(
    cfg: &ExperimentConfig,
    ds: &Dataset,
    registry: Option<&ArtifactRegistry>,
) -> Result<TrialAggregate> {
    let deterministic = matches!(cfg.method, Method::Exact | Method::FullKernel);
    let trials = if deterministic { 1 } else { cfg.trials.max(1) };
    let t_start = Instant::now();
    let mut accs = Vec::with_capacity(trials);
    let mut errs = Vec::with_capacity(trials);
    let mut nmis = Vec::with_capacity(trials);
    let mut objs = Vec::with_capacity(trials);
    let mut peak = 0usize;
    for t in 0..trials {
        let out = run_experiment(cfg, ds, registry, cfg.seed.wrapping_add(t as u64 * 7919))?;
        accs.push(out.accuracy);
        if out.approx_error.is_finite() {
            errs.push(out.approx_error);
        }
        nmis.push(out.nmi);
        objs.push(out.kmeans_objective);
        peak = peak.max(out.memory.peak());
    }
    Ok(TrialAggregate {
        method: cfg.method.name(),
        trials,
        accuracy_mean: crate::util::mean(&accs),
        accuracy_std: crate::util::std_dev(&accs),
        error_mean: if errs.is_empty() { f64::NAN } else { crate::util::mean(&errs) },
        error_std: crate::util::std_dev(&errs),
        nmi_mean: crate::util::mean(&nmis),
        objective_mean: crate::util::mean(&objs),
        peak_memory_bytes: peak,
        total_time: t_start.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(method: Method) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.dataset = "cross_lines".into();
        cfg.n = 240;
        cfg.p = 2;
        cfg.k = 2;
        cfg.method = method;
        cfg.rank = 2;
        cfg.oversample = 8;
        cfg.batch = 64;
        cfg.trials = 3;
        cfg.kmeans_restarts = 5;
        cfg.kmeans_iters = 20;
        cfg
    }

    #[test]
    fn one_pass_beats_plain_kmeans_on_cross_lines() {
        let cfg = small_cfg(Method::OnePass);
        let ds = build_dataset(&cfg).unwrap();
        let ours = run_trials(&cfg, &ds, None).unwrap();
        let plain = run_trials(&small_cfg(Method::PlainKmeans), &ds, None).unwrap();
        assert!(ours.accuracy_mean > 0.95, "ours {:?}", ours.accuracy_mean);
        assert!(plain.accuracy_mean < 0.75, "plain {:?}", plain.accuracy_mean);
    }

    #[test]
    fn exact_and_one_pass_agree_on_error() {
        let cfg = small_cfg(Method::OnePass);
        let ds = build_dataset(&cfg).unwrap();
        let ours = run_trials(&cfg, &ds, None).unwrap();
        let exact = run_trials(&small_cfg(Method::Exact), &ds, None).unwrap();
        // rank-2 truncation error is the floor; ours should be close
        assert!(exact.error_mean <= ours.error_mean + 1e-9);
        assert!(ours.error_mean < exact.error_mean + 0.15, "ours {} exact {}", ours.error_mean, exact.error_mean);
    }

    #[test]
    fn nystrom_small_m_is_worse_than_ours() {
        let ds = build_dataset(&small_cfg(Method::OnePass)).unwrap();
        let ours = run_trials(&small_cfg(Method::OnePass), &ds, None).unwrap();
        let nys = run_trials(&small_cfg(Method::Nystrom { m: 10 }), &ds, None).unwrap();
        assert!(
            ours.error_mean < nys.error_mean,
            "ours {} vs nystrom {}",
            ours.error_mean,
            nys.error_mean
        );
    }

    #[test]
    fn gaussian_matches_srht_accuracy() {
        let ds = build_dataset(&small_cfg(Method::OnePass)).unwrap();
        let srht = run_trials(&small_cfg(Method::OnePass), &ds, None).unwrap();
        let gauss = run_trials(&small_cfg(Method::GaussianOnePass), &ds, None).unwrap();
        assert!((srht.error_mean - gauss.error_mean).abs() < 0.1);
        assert!(gauss.accuracy_mean > 0.9);
        // but the Gaussian test matrix costs extra persistent memory
        assert!(gauss.peak_memory_bytes > srht.peak_memory_bytes);
    }

    #[test]
    fn full_kernel_runs_once() {
        let mut cfg = small_cfg(Method::FullKernel);
        cfg.n = 100;
        let ds = build_dataset(&cfg).unwrap();
        let agg = run_trials(&cfg, &ds, None).unwrap();
        assert_eq!(agg.trials, 1);
        assert!(agg.accuracy_mean > 0.9, "kernel kmeans on rings: {}", agg.accuracy_mean);
    }

    #[test]
    fn threaded_backend_path_works() {
        let mut cfg = small_cfg(Method::OnePass);
        cfg.threads = 3;
        let ds = build_dataset(&cfg).unwrap();
        let agg = run_trials(&cfg, &ds, None).unwrap();
        assert!(agg.accuracy_mean > 0.95);
    }

    #[test]
    fn unknown_dataset_errors() {
        let mut cfg = small_cfg(Method::OnePass);
        cfg.dataset = "wat".into();
        assert!(build_dataset(&cfg).is_err());
    }
}
