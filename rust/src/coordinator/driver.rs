//! Experiment driver: dataset construction, metric collection, and the
//! multi-trial protocol (the paper re-runs every stochastic method 100
//! times and reports means).
//!
//! Since the `api` redesign this layer is a thin compatibility wrapper:
//! [`run_experiment`] builds a [`KernelClusterer`](crate::api::KernelClusterer)
//! from the [`ExperimentConfig`] and scores the resulting
//! [`FittedModel`](crate::api::FittedModel) against the dataset's ground
//! truth. Method dispatch, backend selection, and the fast paths all
//! live in `api`.

use std::path::Path;
use std::time::{Duration, Instant};

use crate::api::KernelClusterer;
use crate::clustering::{accuracy, adjusted_rand_index, normalized_mutual_info};
use crate::config::{Backend, ExperimentConfig, Method};
use crate::data::{self, Dataset};
use crate::error::{Result, RkcError};
use crate::kernels::{BlockSource, NativeBlockSource};
use crate::metrics::MethodMemory;
use crate::rng::Pcg64;
use crate::runtime::ArtifactRegistry;

use super::sources::XlaBlockSource;

/// Everything one trial produces.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub method: String,
    pub accuracy: f64,
    pub nmi: f64,
    pub ari: f64,
    /// normalized kernel approximation error ‖K−K̂‖_F/‖K‖_F (NaN when
    /// the method has no embedding, e.g. plain K-means)
    pub approx_error: f64,
    pub kmeans_objective: f64,
    pub memory: MethodMemory,
    pub sketch_time: Duration,
    pub recovery_time: Duration,
    pub kmeans_time: Duration,
    pub error_time: Duration,
}

/// Construct the dataset named in the config (deterministic per seed).
/// On-disk CSV datasets resolve against `cfg.data_dir` when the path is
/// not found as given.
pub fn build_dataset(cfg: &ExperimentConfig) -> Result<Dataset> {
    let mut rng = Pcg64::seed_stream(cfg.seed, 0xda7a);
    Ok(match cfg.dataset.as_str() {
        "two_rings" => data::two_rings(&mut rng, cfg.n),
        "cross_lines" => data::cross_lines(&mut rng, cfg.n),
        "segmentation_like" => {
            // prefer the real UCI file when the user provides it
            let csv = Path::new(&cfg.data_dir).join("segmentation.csv");
            if let Some(ds) = csv.to_str().and_then(data::load_segmentation_csv) {
                ds
            } else {
                data::segmentation_like(&mut rng, cfg.n, cfg.p, cfg.k)
            }
        }
        "blobs" => data::gaussian_blobs(&mut rng, cfg.n, cfg.p, cfg.k, 0.6),
        "two_moons" => data::two_moons(&mut rng, cfg.n, 0.08),
        path if path.ends_with(".csv") => {
            let direct = data::load_segmentation_csv(path);
            let resolved = direct.or_else(|| {
                Path::new(&cfg.data_dir)
                    .join(path)
                    .to_str()
                    .and_then(data::load_segmentation_csv)
            });
            resolved.ok_or_else(|| {
                RkcError::dataset(format!(
                    "cannot load dataset file {path} (also tried under {})",
                    cfg.data_dir
                ))
            })?
        }
        other => return Err(RkcError::dataset(format!("unknown dataset '{other}'"))),
    })
}

/// Run one trial of `cfg.method` with the trial-specific `seed`.
///
/// Compatibility wrapper over [`KernelClusterer::fit_with_registry`]:
/// fits the model, then scores it against the dataset labels and runs
/// the streamed approximation-error pass. `cfg.threads` flows through
/// unchanged (`0` = auto-detect); results are bit-identical for any
/// thread count, so threaded trials stay comparable to recorded runs.
pub fn run_experiment(
    cfg: &ExperimentConfig,
    ds: &Dataset,
    registry: Option<&ArtifactRegistry>,
    seed: u64,
) -> Result<RunOutcome> {
    let clusterer = KernelClusterer::from_config(cfg).clusters(ds.k).seed(seed);
    let model = clusterer.fit_with_registry(&ds.x, registry)?;

    // --- streamed approximation error (one extra pass, through the
    // configured backend's gram path when an artifact matches) ---
    let t0 = Instant::now();
    let approx_error = match cfg.method {
        Method::PlainKmeans => f64::NAN,
        // the materialized kernel is its own approximation
        Method::FullKernel => 0.0,
        _ => {
            let n_pad = model.n_padded();
            let mut src: Box<dyn BlockSource> = match (cfg.backend, registry) {
                (Backend::Xla, Some(reg)) => {
                    match XlaBlockSource::new(reg, ds.x.clone(), cfg.kernel, n_pad) {
                        Ok(s) => Box::new(s),
                        Err(_) => Box::new(NativeBlockSource::new(ds.x.clone(), cfg.kernel, n_pad)),
                    }
                }
                _ => Box::new(NativeBlockSource::new(ds.x.clone(), cfg.kernel, n_pad)),
            };
            model.approx_error_with(src.as_mut())?
        }
    };
    let error_time = t0.elapsed();

    let k_eval = if cfg.method == Method::PlainKmeans { ds.k.max(cfg.k) } else { ds.k };
    let m = model.metrics();
    Ok(RunOutcome {
        method: m.method.clone(),
        accuracy: accuracy(model.labels(), &ds.labels, k_eval),
        nmi: normalized_mutual_info(model.labels(), &ds.labels, ds.k),
        ari: adjusted_rand_index(model.labels(), &ds.labels, ds.k),
        approx_error,
        kmeans_objective: m.objective,
        memory: m.memory.clone(),
        sketch_time: m.sketch_time,
        recovery_time: m.recovery_time,
        kmeans_time: m.kmeans_time,
        error_time,
    })
}

/// Aggregate over trials: mean ± std of the headline metrics.
#[derive(Clone, Debug)]
pub struct TrialAggregate {
    pub method: String,
    pub trials: usize,
    pub accuracy_mean: f64,
    pub accuracy_std: f64,
    pub error_mean: f64,
    pub error_std: f64,
    pub nmi_mean: f64,
    pub objective_mean: f64,
    pub peak_memory_bytes: usize,
    pub total_time: Duration,
}

/// The paper's protocol: `cfg.trials` independent runs (distinct seeds),
/// means reported. Deterministic methods (exact, full, plain) run once.
pub fn run_trials(
    cfg: &ExperimentConfig,
    ds: &Dataset,
    registry: Option<&ArtifactRegistry>,
) -> Result<TrialAggregate> {
    let deterministic = matches!(cfg.method, Method::Exact | Method::FullKernel);
    let trials = if deterministic { 1 } else { cfg.trials.max(1) };
    let t_start = Instant::now();
    let mut accs = Vec::with_capacity(trials);
    let mut errs = Vec::with_capacity(trials);
    let mut nmis = Vec::with_capacity(trials);
    let mut objs = Vec::with_capacity(trials);
    let mut peak = 0usize;
    for t in 0..trials {
        let out = run_experiment(cfg, ds, registry, cfg.seed.wrapping_add(t as u64 * 7919))?;
        accs.push(out.accuracy);
        if out.approx_error.is_finite() {
            errs.push(out.approx_error);
        }
        nmis.push(out.nmi);
        objs.push(out.kmeans_objective);
        peak = peak.max(out.memory.peak());
    }
    Ok(TrialAggregate {
        method: cfg.method.to_string(),
        trials,
        accuracy_mean: crate::util::mean(&accs),
        accuracy_std: crate::util::std_dev(&accs),
        error_mean: if errs.is_empty() { f64::NAN } else { crate::util::mean(&errs) },
        error_std: crate::util::std_dev(&errs),
        nmi_mean: crate::util::mean(&nmis),
        objective_mean: crate::util::mean(&objs),
        peak_memory_bytes: peak,
        total_time: t_start.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(method: Method) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.dataset = "cross_lines".into();
        cfg.n = 240;
        cfg.p = 2;
        cfg.k = 2;
        cfg.method = method;
        cfg.rank = 2;
        cfg.oversample = 8;
        cfg.batch = 64;
        cfg.trials = 3;
        cfg.kmeans_restarts = 5;
        cfg.kmeans_iters = 20;
        cfg
    }

    #[test]
    fn one_pass_beats_plain_kmeans_on_cross_lines() {
        let cfg = small_cfg(Method::OnePass);
        let ds = build_dataset(&cfg).unwrap();
        let ours = run_trials(&cfg, &ds, None).unwrap();
        let plain = run_trials(&small_cfg(Method::PlainKmeans), &ds, None).unwrap();
        assert!(ours.accuracy_mean > 0.95, "ours {:?}", ours.accuracy_mean);
        assert!(plain.accuracy_mean < 0.75, "plain {:?}", plain.accuracy_mean);
    }

    #[test]
    fn exact_and_one_pass_agree_on_error() {
        let cfg = small_cfg(Method::OnePass);
        let ds = build_dataset(&cfg).unwrap();
        let ours = run_trials(&cfg, &ds, None).unwrap();
        let exact = run_trials(&small_cfg(Method::Exact), &ds, None).unwrap();
        // rank-2 truncation error is the floor; ours should be close
        assert!(exact.error_mean <= ours.error_mean + 1e-9);
        assert!(ours.error_mean < exact.error_mean + 0.15, "ours {} exact {}", ours.error_mean, exact.error_mean);
    }

    #[test]
    fn nystrom_small_m_is_worse_than_ours() {
        let ds = build_dataset(&small_cfg(Method::OnePass)).unwrap();
        let ours = run_trials(&small_cfg(Method::OnePass), &ds, None).unwrap();
        let nys = run_trials(&small_cfg(Method::Nystrom { m: 10 }), &ds, None).unwrap();
        assert!(
            ours.error_mean < nys.error_mean,
            "ours {} vs nystrom {}",
            ours.error_mean,
            nys.error_mean
        );
    }

    #[test]
    fn gaussian_matches_srht_accuracy() {
        let ds = build_dataset(&small_cfg(Method::OnePass)).unwrap();
        let srht = run_trials(&small_cfg(Method::OnePass), &ds, None).unwrap();
        let gauss = run_trials(&small_cfg(Method::GaussianOnePass), &ds, None).unwrap();
        assert!((srht.error_mean - gauss.error_mean).abs() < 0.1);
        assert!(gauss.accuracy_mean > 0.9);
        // but the Gaussian test matrix costs extra persistent memory
        assert!(gauss.peak_memory_bytes > srht.peak_memory_bytes);
    }

    #[test]
    fn full_kernel_runs_once() {
        let mut cfg = small_cfg(Method::FullKernel);
        cfg.n = 100;
        let ds = build_dataset(&cfg).unwrap();
        let agg = run_trials(&cfg, &ds, None).unwrap();
        assert_eq!(agg.trials, 1);
        assert!(agg.accuracy_mean > 0.9, "kernel kmeans on rings: {}", agg.accuracy_mean);
    }

    #[test]
    fn threaded_backend_path_works() {
        let mut cfg = small_cfg(Method::OnePass);
        cfg.threads = 3;
        let ds = build_dataset(&cfg).unwrap();
        let agg = run_trials(&cfg, &ds, None).unwrap();
        assert!(agg.accuracy_mean > 0.95);
    }

    #[test]
    fn unknown_dataset_errors() {
        let mut cfg = small_cfg(Method::OnePass);
        cfg.dataset = "wat".into();
        assert!(build_dataset(&cfg).is_err());
    }

    #[test]
    fn csv_dataset_resolves_through_data_dir() {
        use std::io::Write as _;
        let dir = std::env::temp_dir().join("rkc_driver_data_dir");
        std::fs::create_dir_all(&dir).unwrap();
        let mut f = std::fs::File::create(dir.join("mini.csv")).unwrap();
        for j in 0..12 {
            writeln!(f, "CLASS{},{}.0,{}.0", j % 2, j, j + 1).unwrap();
        }
        drop(f);
        let mut cfg = small_cfg(Method::PlainKmeans);
        cfg.dataset = "mini.csv".into();
        cfg.data_dir = dir.to_str().unwrap().to_string();
        let ds = build_dataset(&cfg).unwrap();
        assert_eq!(ds.n(), 12);
        // and an unresolvable file is a typed dataset error
        cfg.dataset = "missing.csv".into();
        assert!(matches!(build_dataset(&cfg), Err(RkcError::Dataset(_))));
    }
}
