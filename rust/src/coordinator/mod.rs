//! L3 coordinator: the paper's pipeline as a streaming system.
//!
//! ```text
//!  data (p×n) ──► block scheduler ──► gram blocks K[:,J] ──► SRHT stage ──► sketch W
//!                 (column batches)    (native rust or XLA     (D, FWHT,      (n × r')
//!                                      artifact, on the fly)   row gather)
//!                                                                 │
//!            K-means on Y  ◄── embedding Y = Σ^½VᵀQᵀ ◄── one-pass recovery
//!            (native or XLA artifact)                     (QR, LS solve, Jacobi)
//! ```
//!
//! The full kernel matrix never exists in memory: peak usage is the
//! sketch (`n·r'` f64) plus the in-flight blocks (`P·b·n_pad` with `P`
//! producer shards). The native backend runs the sharded multi-producer
//! pipeline with bounded-channel backpressure
//! ([`run_sketch_pass_sharded`]); the XLA backend routes the bulk
//! compute through the PJRT artifacts (compiled from JAX + Pallas) on
//! the main thread — the PJRT CPU client is not Sync, and on a real
//! accelerator the overlap comes from device streams instead.

mod driver;
mod pipeline;
mod sources;
mod xla_kmeans;

pub use driver::{build_dataset, run_experiment, run_trials, RunOutcome, TrialAggregate};
pub use pipeline::{
    run_sketch_pass, run_sketch_pass_sharded, run_sketch_pass_threaded, SketchRowProducer,
    StageStats,
};
pub use sources::{xla_preferred_n_pad, FusedXlaSketchRows, NativeSketchRows, XlaBlockSource};
pub use xla_kmeans::xla_kmeans;
