//! Lloyd K-means driven through the `kmeans_step` XLA artifact.
//!
//! The assignment + masked centroid statistics run in the compiled HLO
//! module (the L1 Pallas assign kernel); rust owns the restart loop,
//! k-means++ seeding, empty-cluster repair and convergence detection.
//! Matches `clustering::kmeans` bit-for-bit up to f32 rounding (tested in
//! `rust/tests/xla_integration.rs`).

use crate::clustering::{KmeansOpts, KmeansResult};
use crate::error::{Result, RkcError};
use crate::linalg::Mat;
use crate::rng::Pcg64;
use crate::runtime::{
    literal_to_indices, literal_to_mat, literal_to_vec, mat_to_literal, vec_to_literal,
    ArtifactRegistry, Executable, Literal,
};

/// K-means on `y` (r × n) using the artifact matching (r, k, n_pad).
pub fn xla_kmeans(
    registry: &ArtifactRegistry,
    y: &Mat,
    opts: &KmeansOpts,
    rng: &mut Pcg64,
) -> Result<KmeansResult> {
    let (r, n) = (y.rows(), y.cols());
    let info = registry
        .find(|i| {
            i.params.get("op").map(String::as_str) == Some("kmeans_step")
                && i.param_usize("r").ok() == Some(r)
                && i.param_usize("k").ok() == Some(opts.k)
                && i.param_usize("n").ok().is_some_and(|np| np >= n)
        })
        .ok_or_else(|| {
            RkcError::missing_artifact(format!(
                "no kmeans_step artifact for r={r} k={} n>={n}",
                opts.k
            ))
        })?
        .clone();
    let n_pad = info.param_usize("n")?;
    let exe = registry.get(&info.name)?;

    // pad the embedding with zero columns and mask them out
    let y_pad = Mat::from_fn(r, n_pad, |i, j| if j < n { y[(i, j)] } else { 0.0 });
    let y_lit = mat_to_literal(&y_pad)?;
    let mut w = vec![1.0; n_pad];
    for wj in w.iter_mut().skip(n) {
        *wj = 0.0;
    }
    let w_lit = vec_to_literal(&w)?;

    let mut best: Option<KmeansResult> = None;
    for t in 0..opts.restarts.max(1) {
        let mut run_rng = rng.split(t as u64 + 1);
        let run = lloyd_once(exe, &y_lit, &w_lit, y, opts, n_pad, &mut run_rng)?;
        if best.as_ref().is_none_or(|b| run.objective < b.objective) {
            best = Some(run);
        }
    }
    Ok(best.unwrap())
}

fn lloyd_once(
    exe: &'static Executable,
    y_lit: &Literal,
    w_lit: &Literal,
    y: &Mat,
    opts: &KmeansOpts,
    _n_pad: usize,
    rng: &mut Pcg64,
) -> Result<KmeansResult> {
    let (r, n) = (y.rows(), y.cols());
    let k = opts.k;
    // seed with k-means++ on the native side (cheap, O(nk))
    let seed_run = crate::clustering::kmeans_once(
        y,
        &KmeansOpts { k, restarts: 1, max_iters: 0, tol: 0.0 },
        rng,
    );
    let mut centroids = seed_run.centroids;
    let mut labels = vec![0usize; n];
    let mut iterations = 0;

    let mut prev_obj = f64::INFINITY;
    for it in 0..opts.max_iters.max(1) {
        iterations = it + 1;
        let c_lit = mat_to_literal(&centroids)?;
        let outs = exe.run(&[y_lit.clone(), c_lit, w_lit.clone()])?;
        let assign = literal_to_indices(&outs[0])?;
        let sums = literal_to_mat(&outs[1], k, r)?;
        let counts = literal_to_vec(&outs[2])?;
        labels.copy_from_slice(&assign[..n]);
        // objective under current centroids (native, O(rn))
        let mut obj = 0.0;
        for j in 0..n {
            let c = labels[j];
            for i in 0..r {
                let d = y[(i, j)] - centroids[(i, c)];
                obj += d * d;
            }
        }
        // update step with empty-cluster repair
        for c in 0..k {
            if counts[c] < 0.5 {
                let far = (0..n)
                    .max_by(|&a, &b| {
                        let da: f64 =
                            (0..r).map(|i| (y[(i, a)] - centroids[(i, labels[a])]).powi(2)).sum();
                        let db: f64 =
                            (0..r).map(|i| (y[(i, b)] - centroids[(i, labels[b])]).powi(2)).sum();
                        // total order: a NaN distance must not panic the
                        // repair (same fix as clustering::kmeans)
                        da.total_cmp(&db)
                    })
                    .expect("kmeans on zero points");
                for i in 0..r {
                    centroids[(i, c)] = y[(i, far)];
                }
            } else {
                for i in 0..r {
                    centroids[(i, c)] = sums[(c, i)] / counts[c];
                }
            }
        }
        if (prev_obj - obj).abs() <= opts.tol * obj.max(1e-300) && it > 0 {
            prev_obj = obj;
            break;
        }
        prev_obj = obj;
    }

    // final consistent assignment + objective
    let mut obj = 0.0;
    for j in 0..n {
        let mut best_c = 0;
        let mut best_d = f64::INFINITY;
        for c in 0..k {
            let mut d = 0.0;
            for i in 0..r {
                let t = y[(i, j)] - centroids[(i, c)];
                d += t * t;
            }
            if d < best_d {
                best_d = d;
                best_c = c;
            }
        }
        labels[j] = best_c;
        obj += best_d;
    }
    let _ = prev_obj;
    Ok(KmeansResult { labels, centroids, objective: obj, iterations })
}
