//! Block / sketch-row producers over both backends.
//!
//! The sketch pass consumes *rows of W* (one per streamed kernel column);
//! [`SketchRowProducer`](super::SketchRowProducer) abstracts who computes
//! them:
//! - [`NativeSketchRows`] — rust gram + rust FWHT (reference backend).
//! - [`FusedXlaSketchRows`] — the `sketch_*` artifact (Pallas gram kernel
//!   + Pallas FWHT butterflies fused into one HLO module) + a row gather.
//!
//! [`XlaBlockSource`] adapts a `gram_*` artifact to the [`BlockSource`]
//! trait so Nyström / exact / error measurement run on the XLA backend too.

use crate::error::{Result, RkcError};
use crate::kernels::{BlockSource, Kernel, NativeBlockSource};
use crate::linalg::Mat;
use crate::runtime::{
    literal_to_mat, mat_to_literal, vec_to_literal, ArtifactRegistry, Executable, Literal,
};
use crate::sketch::Srht;

/// Pick the padded transform length for the XLA backend: the smallest
/// `sketch` artifact (matching kernel kind and p) whose baked n is at
/// least `next_pow2(n)`. Padding beyond the minimum is mathematically
/// free (padded kernel rows/columns are zero) — it just buys artifact
/// reuse across workload sizes.
pub fn xla_preferred_n_pad(
    registry: &ArtifactRegistry,
    kernel: Kernel,
    p: usize,
    n: usize,
) -> Option<usize> {
    let kind = match kernel {
        Kernel::Poly { .. } => "poly",
        Kernel::Rbf { .. } => "rbf",
        Kernel::Linear => "linear",
    };
    let min = n.next_power_of_two();
    let mut best: Option<usize> = None;
    for name in registry.names() {
        let info = registry.info(&name).unwrap();
        if info.params.get("op").map(String::as_str) == Some("sketch")
            && info.params.get("kind").map(String::as_str) == Some(kind)
            && info.param_usize("p").ok() == Some(p)
        {
            if let Ok(na) = info.param_usize("n") {
                if na >= min && best.is_none_or(|b| na < b) {
                    best = Some(na);
                }
            }
        }
    }
    best
}

/// Native reference producer: gram block in rust, SRHT in rust.
/// (The `SketchRowProducer` impl lives in `pipeline.rs`.)
pub struct NativeSketchRows {
    pub src: NativeBlockSource,
    pub srht: Srht,
    pub threads: usize,
    /// flat SRHT transform buffer, grown once and reused across blocks
    /// (see [`Srht::apply_to_block_with`]); start with `Vec::new()`
    pub scratch: Vec<f64>,
}

/// XLA fused producer: one artifact call computes `(H D) K[:, J]` from
/// the raw data; rust gathers the r' sampled rows.
pub struct FusedXlaSketchRows {
    exe: &'static Executable,
    x_lit: Literal,
    d_lit: Literal,
    srht: Srht,
    n_pad: usize,
    b_art: usize,
    p: usize,
}

impl FusedXlaSketchRows {
    /// Find a `sketch` artifact matching (kernel, p, n_pad) in the
    /// registry. `srht.d` must already have padded rows zeroed (see
    /// `Srht::mask_padding`) so that non-poly kernels stay consistent.
    pub fn new(
        registry: &ArtifactRegistry,
        x: &Mat,
        kernel: Kernel,
        srht: Srht,
    ) -> Result<Self> {
        let p = x.rows();
        let n_pad = srht.n;
        let kind = match kernel {
            Kernel::Poly { .. } => "poly",
            Kernel::Rbf { .. } => "rbf",
            Kernel::Linear => "linear",
        };
        let info = registry
            .find(|i| {
                i.params.get("op").map(String::as_str) == Some("sketch")
                    && i.params.get("kind").map(String::as_str) == Some(kind)
                    && i.param_usize("p").ok() == Some(p)
                    && i.param_usize("n").ok() == Some(n_pad)
            })
            .ok_or_else(|| {
                RkcError::missing_artifact(format!(
                    "no sketch artifact for kind={kind} p={p} n={n_pad}; run `make artifacts`"
                ))
            })?
            .clone();
        let b_art = info.param_usize("b")?;
        let exe = registry.get(&info.name)?;
        // pad x to (p, n_pad) with zero columns
        let x_pad = Mat::from_fn(p, n_pad, |i, j| if j < x.cols() { x[(i, j)] } else { 0.0 });
        let x_lit = mat_to_literal(&x_pad)?;
        let d_lit = vec_to_literal(&srht.d)?;
        Ok(FusedXlaSketchRows { exe, x_lit, d_lit, srht, n_pad, b_art, p })
    }

    pub fn srht(&self) -> &Srht {
        &self.srht
    }

    /// The artifact's fixed batch width (stream at exactly this size).
    pub fn batch_width(&self) -> usize {
        self.b_art
    }

    /// Compute W rows for `cols` (|cols| ≤ artifact batch width).
    pub fn rows_for(&mut self, x: &Mat, cols: &[usize]) -> Result<Mat> {
        if cols.len() > self.b_art {
            return Err(RkcError::backend(format!(
                "batch of {} exceeds artifact width {}",
                cols.len(),
                self.b_art
            )));
        }
        // query block, zero-padded to the artifact's fixed width
        let xb = Mat::from_fn(self.p, self.b_art, |i, bj| {
            if bj < cols.len() {
                x[(i, cols[bj])]
            } else {
                0.0
            }
        });
        let xb_lit = mat_to_literal(&xb)?;
        let outs = self.exe.run(&[
            self.x_lit.clone(),
            xb_lit,
            self.d_lit.clone(),
        ])?;
        let pre = literal_to_mat(&outs[0], self.n_pad, self.b_art)?;
        // gather the r' sampled rows for the real columns: row j of W
        Ok(Mat::from_fn(cols.len(), self.srht.samples(), |bj, s| pre[(self.srht.idx[s], bj)]))
    }
}

/// `BlockSource` over a `gram_*` artifact: streams `K[:, J]` through the
/// compiled Pallas gram kernel. Padded *rows* are re-zeroed in rust (for
/// the RBF kernel the artifact's padded data columns do not map to zero).
pub struct XlaBlockSource {
    exe: &'static Executable,
    x: Mat,
    x_lit: Literal,
    kernel: Kernel,
    n_pad: usize,
    b_art: usize,
}

impl XlaBlockSource {
    pub fn new(
        registry: &ArtifactRegistry,
        x: Mat,
        kernel: Kernel,
        n_pad: usize,
    ) -> Result<Self> {
        let p = x.rows();
        let kind = match kernel {
            Kernel::Poly { .. } => "poly",
            Kernel::Rbf { .. } => "rbf",
            Kernel::Linear => "linear",
        };
        let info = registry
            .find(|i| {
                i.params.get("op").map(String::as_str) == Some("gram")
                    && i.params.get("kind").map(String::as_str) == Some(kind)
                    && i.param_usize("p").ok() == Some(p)
                    && i.param_usize("n").ok() == Some(n_pad)
            })
            .ok_or_else(|| {
                RkcError::missing_artifact(format!(
                    "no gram artifact for kind={kind} p={p} n={n_pad}; run `make artifacts`"
                ))
            })?
            .clone();
        let b_art = info.param_usize("b")?;
        let exe = registry.get(&info.name)?;
        let x_pad = Mat::from_fn(p, n_pad, |i, j| if j < x.cols() { x[(i, j)] } else { 0.0 });
        let x_lit = mat_to_literal(&x_pad)?;
        Ok(XlaBlockSource { exe, x, x_lit, kernel, n_pad, b_art })
    }

    pub fn batch_width(&self) -> usize {
        self.b_art
    }
}

impl BlockSource for XlaBlockSource {
    fn n(&self) -> usize {
        self.x.cols()
    }

    fn n_padded(&self) -> usize {
        self.n_pad
    }

    fn block(&mut self, cols: &[usize]) -> Mat {
        let p = self.x.rows();
        let n = self.x.cols();
        let mut out = Mat::zeros(self.n_pad, cols.len());
        for (chunk_idx, chunk) in cols.chunks(self.b_art).enumerate() {
            let xb = Mat::from_fn(p, self.b_art, |i, bj| {
                if bj < chunk.len() {
                    self.x[(i, chunk[bj])]
                } else {
                    0.0
                }
            });
            let xb_lit = mat_to_literal(&xb).expect("literal conversion");
            let outs = self
                .exe
                .run(&[self.x_lit.clone(), xb_lit])
                .expect("gram artifact execution");
            let kb = literal_to_mat(&outs[0], self.n_pad, self.b_art).expect("gram output");
            let chunk_start = chunk_idx * self.b_art;
            for bj in 0..chunk.len() {
                // rows ≥ n stay zero (RBF padding correction)
                for i in 0..n {
                    out[(i, chunk_start + bj)] = kb[(i, bj)];
                }
            }
        }
        out
    }

    fn diag(&mut self) -> Vec<f64> {
        let p = self.x.rows();
        (0..self.x.cols())
            .map(|i| {
                let norm2: f64 = (0..p).map(|d| self.x[(d, i)].powi(2)).sum();
                self.kernel.eval_diag(norm2)
            })
            .collect()
    }
}
