//! The streaming sketch pass: block scheduler + SRHT stage + accumulator.
//!
//! Two execution modes:
//! - [`run_sketch_pass`] — sequential loop, works with any producer
//!   (including the XLA-backed one, whose PJRT handles are not `Send`).
//! - [`run_sketch_pass_threaded`] — producer/consumer with a bounded
//!   `sync_channel`: the producer thread computes kernel blocks while the
//!   consumer applies the FWHT and gathers sketch rows. Backpressure is
//!   the channel bound — at most `channel_cap` blocks (each n_pad × b
//!   f64) are ever in flight, keeping peak memory at the documented
//!   O(n·r' + b·n_pad) regardless of producer speed.

use std::sync::mpsc::sync_channel;
use std::time::Duration;

use crate::kernels::{column_batches, BlockSource, NativeBlockSource};
use crate::linalg::Mat;
use crate::lowrank::OnePassSketch;
use crate::sketch::Srht;

/// Per-stage wall-clock accounting for the sketch pass.
#[derive(Clone, Debug, Default)]
pub struct StageStats {
    pub blocks: usize,
    pub produce_time: Duration,
    pub transform_time: Duration,
    /// peak number of blocks simultaneously alive (threaded mode)
    pub peak_in_flight: usize,
}

/// Anything that can turn a set of kernel-column indices into the
/// corresponding rows of the sketch `W` (b × r').
pub trait SketchRowProducer {
    fn rows_for(&mut self, cols: &[usize]) -> Mat;
    fn srht(&self) -> &Srht;
}

impl SketchRowProducer for super::NativeSketchRows {
    fn rows_for(&mut self, cols: &[usize]) -> Mat {
        let kb = self.src.block(cols);
        self.srht.apply_to_block(&kb, self.threads)
    }

    fn srht(&self) -> &Srht {
        &self.srht
    }
}

/// Sequential sketch pass over all columns.
pub fn run_sketch_pass(
    producer: &mut dyn SketchRowProducer,
    n_real: usize,
    batch: usize,
) -> (OnePassSketch, StageStats) {
    let mut sketch = OnePassSketch::new(producer.srht().clone(), n_real);
    let mut stats = StageStats::default();
    for cols in column_batches(n_real, batch) {
        let t0 = std::time::Instant::now();
        let rows = producer.rows_for(&cols);
        stats.produce_time += t0.elapsed();
        let t1 = std::time::Instant::now();
        sketch.ingest(&cols, &rows);
        stats.transform_time += t1.elapsed();
        stats.blocks += 1;
    }
    stats.peak_in_flight = 1;
    (sketch, stats)
}

/// Threaded sketch pass (native backend): the producer thread computes
/// raw kernel blocks; the consumer applies `D`, FWHT and the row gather.
pub fn run_sketch_pass_threaded(
    mut src: NativeBlockSource,
    srht: Srht,
    batch: usize,
    channel_cap: usize,
    fwht_threads: usize,
) -> (OnePassSketch, StageStats) {
    let n_real = src.n();
    let mut sketch = OnePassSketch::new(srht.clone(), n_real);
    let mut stats = StageStats::default();
    let batches = column_batches(n_real, batch);
    let nbatches = batches.len();
    let (tx, rx) = sync_channel::<(Vec<usize>, Mat)>(channel_cap.max(1));

    std::thread::scope(|scope| {
        let producer = scope.spawn(move || {
            let mut produce_time = Duration::ZERO;
            for cols in batches {
                let t0 = std::time::Instant::now();
                let kb = src.block(&cols);
                produce_time += t0.elapsed();
                if tx.send((cols, kb)).is_err() {
                    break; // consumer hung up (panic downstream)
                }
            }
            produce_time
        });

        for (cols, kb) in rx.iter() {
            let t1 = std::time::Instant::now();
            let rows = srht.apply_to_block(&kb, fwht_threads);
            sketch.ingest(&cols, &rows);
            stats.transform_time += t1.elapsed();
            stats.blocks += 1;
        }
        stats.produce_time = producer.join().expect("producer thread panicked");
    });

    assert_eq!(stats.blocks, nbatches);
    stats.peak_in_flight = channel_cap.max(1) + 1;
    (sketch, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NativeSketchRows;
    use crate::kernels::Kernel;
    use crate::linalg::testutil::{assert_mat_close, random_mat};
    use crate::rng::Pcg64;

    fn setup(seed: u64, n: usize) -> (Mat, Srht) {
        let mut rng = Pcg64::seed(seed);
        let x = random_mat(&mut rng, 3, n);
        let n_pad = n.next_power_of_two();
        let mut srht = Srht::draw(&mut rng, n_pad, 6);
        srht.mask_padding(n);
        (x, srht)
    }

    #[test]
    fn threaded_equals_sequential() {
        let (x, srht) = setup(1, 53);
        let kern = Kernel::paper_poly2();
        let mut seq = NativeSketchRows {
            src: NativeBlockSource::pow2(x.clone(), kern),
            srht: srht.clone(),
            threads: 1,
        };
        let (sk_seq, st_seq) = run_sketch_pass(&mut seq, 53, 10);
        let (sk_thr, st_thr) = run_sketch_pass_threaded(
            NativeBlockSource::pow2(x, kern),
            srht,
            10,
            2,
            2,
        );
        assert_mat_close(sk_seq.w(), sk_thr.w(), 1e-12);
        assert_eq!(st_seq.blocks, st_thr.blocks);
        assert!(sk_thr.is_complete());
    }

    #[test]
    fn backpressure_bounds_in_flight_blocks() {
        let (x, srht) = setup(2, 40);
        let (_, stats) = run_sketch_pass_threaded(
            NativeBlockSource::pow2(x, Kernel::paper_poly2()),
            srht,
            4,
            1,
            1,
        );
        assert_eq!(stats.blocks, 10);
        assert!(stats.peak_in_flight <= 2);
    }

    #[test]
    fn stats_account_all_blocks() {
        let (x, srht) = setup(3, 17);
        let mut p = NativeSketchRows {
            src: NativeBlockSource::pow2(x, Kernel::Rbf { gamma: 0.5 }),
            srht,
            threads: 1,
        };
        let (sk, stats) = run_sketch_pass(&mut p, 17, 5);
        assert_eq!(stats.blocks, 4); // 5+5+5+2
        assert!(sk.is_complete());
    }
}
