//! The streaming sketch pass: block scheduler + SRHT stage + accumulator.
//!
//! Three execution modes:
//! - [`run_sketch_pass`] — sequential loop, works with any producer
//!   (including the XLA-backed one, whose PJRT handles are not `Send`).
//! - [`run_sketch_pass_threaded`] — single producer/consumer with a
//!   bounded `sync_channel` (the sharded pass with one producer).
//! - [`run_sketch_pass_sharded`] — P producer workers, each computing a
//!   disjoint contiguous shard of the kernel column blocks, feeding one
//!   bounded channel; the consumer applies `D`, the FWHT, and the row
//!   gather, then writes each streamed column's sketch row into its own
//!   slot of `W`. Backpressure is the channel bound: at most
//!   `channel_cap` queued blocks plus one in-production block per
//!   producer (each n_pad × b f64) are ever alive, keeping peak memory
//!   at the documented O(n·r' + P·b·n_pad) regardless of producer speed.
//!
//! Determinism: the accumulator is order-independent (each column owns a
//! row of `W`; [`OnePassSketch::ingest`] asserts no column streams
//! twice), and block contents are pure functions of `(x, kernel, cols)`,
//! so the sharded pass is bit-identical to the sequential one for any
//! producer count and any arrival interleaving.

use std::sync::mpsc::sync_channel;
use std::time::Duration;

use crate::kernels::{column_batches, BlockSource, NativeBlockSource};
use crate::linalg::Mat;
use crate::lowrank::OnePassSketch;
use crate::obs;
use crate::sketch::Srht;

/// Publish one finished pass's [`StageStats`] into the process-wide
/// metric registry and backfill a `pipeline.sketch_pass` span. Strictly
/// out-of-band: called once after the pass completes, never inside it.
fn record_pass_obs(stats: &StageStats, wall: Duration) {
    let r = obs::registry();
    r.counter(
        "rkc_pipeline_gram_blocks_total",
        "Kernel column blocks streamed through the sketch pass.",
        &[],
    )
    .add(stats.blocks as u64);
    let stage_help = "Cumulative per-pass stage time inside the sketch pass.";
    r.histogram(
        "rkc_pipeline_stage_seconds",
        stage_help,
        &[("stage", "produce")],
        obs::latency_buckets(),
    )
    .observe(stats.produce_time.as_secs_f64());
    r.histogram(
        "rkc_pipeline_stage_seconds",
        stage_help,
        &[("stage", "transform")],
        obs::latency_buckets(),
    )
    .observe(stats.transform_time.as_secs_f64());
    obs::record_span("pipeline.sketch_pass", wall);
}

/// Per-stage wall-clock accounting for the sketch pass.
#[derive(Clone, Debug, Default)]
pub struct StageStats {
    /// kernel column blocks processed end to end
    pub blocks: usize,
    /// gram-block production time; in sharded mode this is the *sum*
    /// across producer workers, so it can exceed the pass's wall clock
    pub produce_time: Duration,
    /// consumer-side SRHT stage time (scale, FWHT, row gather, ingest)
    pub transform_time: Duration,
    /// upper bound on blocks simultaneously alive (queue + producers)
    pub peak_in_flight: usize,
}

/// Anything that can turn a set of kernel-column indices into the
/// corresponding rows of the sketch `W` (b × r').
pub trait SketchRowProducer {
    fn rows_for(&mut self, cols: &[usize]) -> Mat;
    fn srht(&self) -> &Srht;
}

impl SketchRowProducer for super::NativeSketchRows {
    fn rows_for(&mut self, cols: &[usize]) -> Mat {
        let kb = self.src.block(cols);
        self.srht.apply_to_block_with(&kb, self.threads, &mut self.scratch)
    }

    fn srht(&self) -> &Srht {
        &self.srht
    }
}

/// Sequential sketch pass over all columns.
pub fn run_sketch_pass(
    producer: &mut dyn SketchRowProducer,
    n_real: usize,
    batch: usize,
) -> (OnePassSketch, StageStats) {
    let wall = std::time::Instant::now();
    let mut sketch = OnePassSketch::new(producer.srht().clone(), n_real);
    let mut stats = StageStats::default();
    for cols in column_batches(n_real, batch) {
        let t0 = std::time::Instant::now();
        let rows = producer.rows_for(&cols);
        stats.produce_time += t0.elapsed();
        let t1 = std::time::Instant::now();
        sketch.ingest(&cols, &rows);
        stats.transform_time += t1.elapsed();
        stats.blocks += 1;
    }
    stats.peak_in_flight = 1;
    record_pass_obs(&stats, wall.elapsed());
    (sketch, stats)
}

/// Threaded sketch pass (native backend): one producer thread computes
/// raw kernel blocks; the consumer applies `D`, FWHT and the row gather.
/// Equivalent to [`run_sketch_pass_sharded`] with a single producer.
pub fn run_sketch_pass_threaded(
    src: NativeBlockSource,
    srht: Srht,
    batch: usize,
    channel_cap: usize,
    fwht_threads: usize,
) -> (OnePassSketch, StageStats) {
    run_sketch_pass_sharded(&src, srht, batch, channel_cap, 1, fwht_threads)
}

/// Sharded sketch pass (native backend): `producers` workers — sharing
/// the block source by reference (native gram blocks are a pure `&self`
/// computation) — compute disjoint contiguous shards of the
/// column-batch list and feed one bounded channel; the consumer runs
/// the SRHT stage (FWHT fanned over `fwht_threads`) and accumulates
/// `W`. See the module docs for the memory bound and the determinism
/// argument.
pub fn run_sketch_pass_sharded(
    src: &NativeBlockSource,
    srht: Srht,
    batch: usize,
    channel_cap: usize,
    producers: usize,
    fwht_threads: usize,
) -> (OnePassSketch, StageStats) {
    let n_real = src.n();
    let wall = std::time::Instant::now();
    let mut sketch = OnePassSketch::new(srht.clone(), n_real);
    let mut stats = StageStats::default();
    let batches = column_batches(n_real, batch);
    let nbatches = batches.len();
    if nbatches == 0 {
        return (sketch, stats);
    }
    let producers = producers.clamp(1, nbatches);
    let per_shard = nbatches.div_ceil(producers);
    let shards: Vec<Vec<Vec<usize>>> =
        batches.chunks(per_shard).map(|c| c.to_vec()).collect();
    let (tx, rx) = sync_channel::<(Vec<usize>, Mat)>(channel_cap.max(1));

    std::thread::scope(|scope| {
        let handles: Vec<_> = shards
            .into_iter()
            .map(|shard| {
                let tx = tx.clone();
                scope.spawn(move || {
                    let mut produce_time = Duration::ZERO;
                    for cols in shard {
                        let t0 = std::time::Instant::now();
                        let kb = src.compute_block(&cols);
                        produce_time += t0.elapsed();
                        if tx.send((cols, kb)).is_err() {
                            break; // consumer hung up (panic downstream)
                        }
                    }
                    produce_time
                })
            })
            .collect();
        // drop the original sender so `rx.iter()` terminates once every
        // producer has drained its shard
        drop(tx);

        // one flat transform buffer reused for every block the consumer
        // drains — the SRHT stage allocates nothing per block
        let mut scratch = Vec::new();
        for (cols, kb) in rx.iter() {
            let t1 = std::time::Instant::now();
            let rows = srht.apply_to_block_with(&kb, fwht_threads, &mut scratch);
            sketch.ingest(&cols, &rows);
            stats.transform_time += t1.elapsed();
            stats.blocks += 1;
        }
        for h in handles {
            stats.produce_time += h.join().expect("producer thread panicked");
        }
    });

    assert_eq!(stats.blocks, nbatches);
    stats.peak_in_flight = channel_cap.max(1) + producers;
    record_pass_obs(&stats, wall.elapsed());
    (sketch, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NativeSketchRows;
    use crate::kernels::Kernel;
    use crate::linalg::testutil::{assert_mat_close, random_mat};
    use crate::rng::Pcg64;

    fn setup(seed: u64, n: usize) -> (Mat, Srht) {
        let mut rng = Pcg64::seed(seed);
        let x = random_mat(&mut rng, 3, n);
        let n_pad = n.next_power_of_two();
        let mut srht = Srht::draw(&mut rng, n_pad, 6);
        srht.mask_padding(n);
        (x, srht)
    }

    #[test]
    fn threaded_equals_sequential() {
        let (x, srht) = setup(1, 53);
        let kern = Kernel::paper_poly2();
        let mut seq = NativeSketchRows {
            src: NativeBlockSource::pow2(x.clone(), kern),
            srht: srht.clone(),
            threads: 1,
            scratch: Vec::new(),
        };
        let (sk_seq, st_seq) = run_sketch_pass(&mut seq, 53, 10);
        let (sk_thr, st_thr) = run_sketch_pass_threaded(
            NativeBlockSource::pow2(x, kern),
            srht,
            10,
            2,
            2,
        );
        assert_mat_close(sk_seq.w(), sk_thr.w(), 1e-12);
        assert_eq!(st_seq.blocks, st_thr.blocks);
        assert!(sk_thr.is_complete());
    }

    #[test]
    fn sharded_is_bit_identical_to_sequential() {
        let (x, srht) = setup(4, 61);
        let kern = Kernel::paper_poly2();
        let mut seq = NativeSketchRows {
            src: NativeBlockSource::pow2(x.clone(), kern),
            srht: srht.clone(),
            threads: 1,
            scratch: Vec::new(),
        };
        let (sk_seq, _) = run_sketch_pass(&mut seq, 61, 7);
        for producers in [2usize, 3, 5] {
            let src = NativeBlockSource::pow2(x.clone(), kern);
            let (sk_shard, st) = run_sketch_pass_sharded(
                &src,
                srht.clone(),
                7,
                producers,
                producers,
                2,
            );
            assert_eq!(sk_seq.w().data(), sk_shard.w().data(), "producers={producers}");
            assert!(sk_shard.is_complete());
            assert_eq!(st.blocks, 9); // ceil(61 / 7)
            assert!(st.peak_in_flight <= 2 * producers);
        }
    }

    #[test]
    fn backpressure_bounds_in_flight_blocks() {
        let (x, srht) = setup(2, 40);
        let (_, stats) = run_sketch_pass_threaded(
            NativeBlockSource::pow2(x, Kernel::paper_poly2()),
            srht,
            4,
            1,
            1,
        );
        assert_eq!(stats.blocks, 10);
        assert!(stats.peak_in_flight <= 2);
    }

    #[test]
    fn stats_account_all_blocks() {
        let (x, srht) = setup(3, 17);
        let mut p = NativeSketchRows {
            src: NativeBlockSource::pow2(x, Kernel::Rbf { gamma: 0.5 }),
            srht,
            threads: 1,
            scratch: Vec::new(),
        };
        let (sk, stats) = run_sketch_pass(&mut p, 17, 5);
        assert_eq!(stats.blocks, 4); // 5+5+5+2
        assert!(sk.is_complete());
    }
}
