//! Small dense linear algebra, from scratch.
//!
//! The one-pass recovery (Alg. 1 steps 3–6) needs: a thin Householder QR
//! of the `n × r'` sketch, an `r' × r` least-squares solve, and a Jacobi
//! eigendecomposition of the tiny `r × r` core. Baselines additionally
//! need PSD pseudo-inverses (Nyström) and full symmetric
//! eigendecompositions at test scale. All of it is latency-bound small
//! algebra, so it lives in rust next to the coordinator instead of paying
//! a PJRT round trip; the O(n²) bulk work stays on the XLA artifacts.
//!
//! Storage is row-major `f64` — the accuracy of the recovery step matters
//! more than memory here (the matrices are `n × r'` at most).

mod eig;
mod gemm;
mod qr;
mod solve;

pub use eig::{jacobi_eig, power_iteration, spectral_norm};
pub use gemm::{gemm, gemm_into, gemm_into_with, gemm_nt, gemm_tn, gemm_with, matmul_reference};
pub use qr::{householder_qr, leading_left_singular_vectors, orthonormal_columns};
pub use solve::{cholesky, least_squares, pinv, pinv_psd, pinv_psd_rank, solve_lower, solve_upper};

/// Dense row-major matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// `self @ other` through the shared cache-blocked [`gemm`] core
    /// (single-threaded; hot paths that own a thread budget call
    /// [`gemm`] directly).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        gemm(self, other, 1)
    }

    /// `self^T @ other` through the shared [`gemm`] core.
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        gemm_tn(self, other, 1)
    }

    /// `self @ other^T` through the shared [`gemm`] core.
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        gemm_nt(self, other, 1)
    }

    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Symmetrize in place: `A <- (A + A^T) / 2` (square only).
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let m = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = m;
                self[(j, i)] = m;
            }
        }
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    /// Trace norm `||A||_* = sum |lambda_i|` of a symmetric matrix.
    pub fn trace_norm_symmetric(&self) -> f64 {
        let (evals, _) = jacobi_eig(self);
        evals.iter().map(|l| l.abs()).sum()
    }

    /// Gather a subset of rows into a new matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        Mat::from_fn(idx.len(), self.cols, |i, j| self[(idx[i], j)])
    }

    /// Gather a subset of columns into a new matrix.
    pub fn select_cols(&self, idx: &[usize]) -> Mat {
        Mat::from_fn(self.rows, idx.len(), |i, j| self[(i, idx[j])])
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, v| m.max(v.abs()))
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Test-only helpers shared across the crate's unit tests.
#[cfg(test)]
pub(crate) mod testutil {
    use super::Mat;
    use crate::rng::{Pcg64, Rng};

    pub(crate) fn random_mat(rng: &mut Pcg64, rows: usize, cols: usize) -> Mat {
        Mat::from_fn(rows, cols, |_, _| rng.normal())
    }

    pub(crate) fn assert_mat_close(a: &Mat, b: &Mat, tol: f64) {
        assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
        let diff = a.sub(b).max_abs();
        assert!(diff < tol, "matrices differ by {diff} > {tol}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use testutil::{assert_mat_close, random_mat};

    #[test]
    fn matmul_matches_manual_small() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Pcg64::seed(1);
        let a = random_mat(&mut rng, 7, 5);
        let b = random_mat(&mut rng, 5, 6);
        let base = a.matmul(&b);
        assert_mat_close(&a.transpose().t_matmul(&b), &base, 1e-12);
        assert_mat_close(&a.matmul_t(&b.transpose()), &base, 1e-12);
    }

    #[test]
    fn matmul_identity_is_noop() {
        let mut rng = Pcg64::seed(2);
        let a = random_mat(&mut rng, 6, 6);
        assert_mat_close(&a.matmul(&Mat::identity(6)), &a, 1e-15);
        assert_mat_close(&Mat::identity(6).matmul(&a), &a, 1e-15);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seed(3);
        let a = random_mat(&mut rng, 4, 9);
        assert_mat_close(&a.transpose().transpose(), &a, 0.0 + 1e-300);
    }

    #[test]
    fn select_rows_cols() {
        let a = Mat::from_fn(5, 4, |i, j| (i * 10 + j) as f64);
        let r = a.select_rows(&[4, 0]);
        assert_eq!(r.row(0), &[40., 41., 42., 43.]);
        assert_eq!(r.row(1), &[0., 1., 2., 3.]);
        let c = a.select_cols(&[3, 1]);
        assert_eq!(c.row(2), &[23., 21.]);
    }

    #[test]
    fn frobenius_and_trace() {
        let a = Mat::from_vec(2, 2, vec![3., 0., 4., 2.]);
        assert!((a.frobenius_norm() - (9.0f64 + 16. + 4.).sqrt()).abs() < 1e-12);
        assert_eq!(a.trace(), 5.0);
    }

    #[test]
    fn symmetrize_produces_symmetric() {
        let mut rng = Pcg64::seed(4);
        let mut a = random_mat(&mut rng, 8, 8);
        a.symmetrize();
        assert_mat_close(&a.transpose(), &a, 1e-15);
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn matmul_shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
