//! Symmetric eigendecomposition (cyclic Jacobi) and power iteration.
//!
//! Jacobi is exact-to-roundoff, unconditionally stable, and ideal for the
//! tiny matrices this crate diagonalizes on the hot path (the `r × r`
//! core of the one-pass recovery, the `m × m` Nyström inner matrix with
//! m ≤ ~150, and test-scale full kernels). Power/subspace iteration
//! provides spectral norms and the "exact" top-r baseline at n = 4096
//! without ever materializing K (see lowrank::exact).

use super::Mat;

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
/// Returns `(eigenvalues, eigenvectors)` sorted by *descending*
/// eigenvalue; eigenvectors are the columns of the returned matrix.
pub fn jacobi_eig(a: &Mat) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows(), a.cols(), "jacobi_eig needs a square matrix");
    let n = a.rows();
    let mut m = a.clone();
    m.symmetrize();
    let mut v = Mat::identity(n);

    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        let scale = m.frobenius_norm().max(1e-300);
        if off.sqrt() <= 1e-14 * scale {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p and q of m.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // Accumulate the rotation into v.
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    let mut order: Vec<usize> = (0..n).collect();
    let evals: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    order.sort_by(|&i, &j| evals[j].partial_cmp(&evals[i]).unwrap());
    let sorted_evals: Vec<f64> = order.iter().map(|&i| evals[i]).collect();
    let sorted_vecs = Mat::from_fn(n, n, |i, j| v[(i, order[j])]);
    (sorted_evals, sorted_vecs)
}

/// Largest-magnitude eigenvalue estimate of a symmetric operator given as
/// a matvec closure, via power iteration with a deterministic start.
pub fn power_iteration(
    n: usize,
    matvec: impl Fn(&[f64]) -> Vec<f64>,
    iters: usize,
) -> f64 {
    let mut v: Vec<f64> = (0..n)
        .map(|i| ((i * 2654435761 + 1) % 1000) as f64 / 1000.0 - 0.5)
        .collect();
    normalize(&mut v);
    let mut lambda = 0.0;
    for _ in 0..iters {
        let mut w = matvec(&v);
        lambda = super::dot(&v, &w);
        let nw = norm(&w);
        if nw < 1e-300 {
            return 0.0;
        }
        for x in &mut w {
            *x /= nw;
        }
        v = w;
    }
    lambda
}

/// Spectral norm of an explicit matrix (`||A||_2`) via power iteration on
/// `AᵀA` (handles non-symmetric and rectangular inputs).
pub fn spectral_norm(a: &Mat, iters: usize) -> f64 {
    let lambda = power_iteration(
        a.cols(),
        |v| {
            // AᵀA v
            let av: Vec<f64> = (0..a.rows()).map(|i| super::dot(a.row(i), v)).collect();
            let mut out = vec![0.0; a.cols()];
            for i in 0..a.rows() {
                let r = a.row(i);
                let s = av[i];
                for (o, &x) in out.iter_mut().zip(r) {
                    *o += s * x;
                }
            }
            out
        },
        iters,
    );
    lambda.max(0.0).sqrt()
}

fn norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

fn normalize(v: &mut [f64]) {
    let n = norm(v);
    if n > 0.0 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::testutil::{assert_mat_close, random_mat};
    use crate::rng::Pcg64;

    fn random_symmetric(seed: u64, n: usize) -> Mat {
        let mut rng = Pcg64::seed(seed);
        let mut a = random_mat(&mut rng, n, n);
        a.symmetrize();
        a
    }

    #[test]
    fn eig_reconstructs_matrix() {
        for (seed, n) in [(1, 2), (2, 5), (3, 16), (4, 40)] {
            let a = random_symmetric(seed, n);
            let (evals, v) = jacobi_eig(&a);
            // A = V diag(evals) Vᵀ
            let mut lv = v.clone();
            for i in 0..n {
                for j in 0..n {
                    lv[(i, j)] *= evals[j];
                }
            }
            assert_mat_close(&lv.matmul_t(&v), &a, 1e-9);
            // V orthonormal
            assert_mat_close(&v.t_matmul(&v), &Mat::identity(n), 1e-10);
            // descending order
            for w in evals.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
        }
    }

    #[test]
    fn eig_known_2x2() {
        let a = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let (evals, _) = jacobi_eig(&a);
        assert!((evals[0] - 3.0).abs() < 1e-12);
        assert!((evals[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn eig_diagonal_is_identity_decomposition() {
        let a = Mat::from_vec(3, 3, vec![5., 0., 0., 0., -2., 0., 0., 0., 9.]);
        let (evals, v) = jacobi_eig(&a);
        assert_eq!(evals, vec![9.0, 5.0, -2.0]);
        // each eigenvector is a signed canonical basis vector
        for j in 0..3 {
            let col = v.col(j);
            let nnz = col.iter().filter(|x| x.abs() > 1e-12).count();
            assert_eq!(nnz, 1);
        }
    }

    #[test]
    fn eig_psd_gram_has_nonnegative_spectrum() {
        let mut rng = Pcg64::seed(8);
        let b = random_mat(&mut rng, 12, 6);
        let g = b.t_matmul(&b); // 6x6 PSD
        let (evals, _) = jacobi_eig(&g);
        assert!(evals.iter().all(|&l| l > -1e-10), "{evals:?}");
    }

    #[test]
    fn spectral_norm_matches_eig() {
        let a = random_symmetric(9, 10);
        let (evals, _) = jacobi_eig(&a);
        let want = evals.iter().fold(0.0f64, |m, l| m.max(l.abs()));
        let got = spectral_norm(&a, 300);
        assert!((got - want).abs() < 1e-6 * want.max(1.0), "{got} vs {want}");
    }

    #[test]
    fn trace_norm_of_psd_equals_trace() {
        let mut rng = Pcg64::seed(10);
        let b = random_mat(&mut rng, 15, 7);
        let g = b.t_matmul(&b);
        assert!((g.trace_norm_symmetric() - g.trace()).abs() < 1e-8);
    }

    #[test]
    fn power_iteration_on_closure() {
        // operator = diag(1, 2, 7)
        let lambda = power_iteration(
            3,
            |v| vec![v[0], 2.0 * v[1], 7.0 * v[2]],
            200,
        );
        assert!((lambda - 7.0).abs() < 1e-9);
    }
}
