//! Thin Householder QR.
//!
//! Used for: the orthonormal basis `Q` of the sketch `W` (Alg. 1 step 3),
//! the least-squares solve of `B (Qᵀ Ω) = Qᵀ W` (step 4), and the
//! re-orthonormalization inside subspace iteration (exact-EVD baseline).
//! Householder reflections give unconditional orthogonality — classical
//! Gram–Schmidt on a preconditioned random sketch would be asking for
//! trouble at r' ≈ 20.

use super::Mat;

/// Thin QR of `a` (m × n, m >= n): returns `(q, r)` with `q` m × n having
/// orthonormal columns and `r` n × n upper-triangular, `a = q r`.
pub fn householder_qr(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows(), a.cols());
    assert!(m >= n, "householder_qr expects a tall matrix, got {m}x{n}");
    let mut r = a.clone();
    // Householder vectors, stored column by column (v[0..k] = 0 implied).
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);

    for k in 0..n {
        // Build the reflector annihilating r[k+1.., k].
        let mut v: Vec<f64> = (k..m).map(|i| r[(i, k)]).collect();
        let alpha = -v[0].signum() * norm(&v);
        let mut vk = v.clone();
        vk[0] -= alpha;
        let vnorm = norm(&vk);
        if vnorm > 0.0 {
            for x in &mut vk {
                *x /= vnorm;
            }
            // Apply I - 2 v vᵀ to the trailing block of r.
            for j in k..n {
                let mut s = 0.0;
                for i in k..m {
                    s += vk[i - k] * r[(i, j)];
                }
                s *= 2.0;
                for i in k..m {
                    r[(i, j)] -= s * vk[i - k];
                }
            }
        }
        v.clear();
        vs.push(vk);
    }

    // Accumulate thin Q by applying the reflectors to the first n columns
    // of the identity, in reverse order.
    let mut q = Mat::zeros(m, n);
    for j in 0..n {
        q[(j, j)] = 1.0;
    }
    for k in (0..n).rev() {
        let vk = &vs[k];
        if vk.iter().all(|&x| x == 0.0) {
            continue;
        }
        for j in 0..n {
            let mut s = 0.0;
            for i in k..m {
                s += vk[i - k] * q[(i, j)];
            }
            s *= 2.0;
            for i in k..m {
                q[(i, j)] -= s * vk[i - k];
            }
        }
    }

    // Zero the strictly-lower part of r and return the n × n block.
    let rr = Mat::from_fn(n, n, |i, j| if j >= i { r[(i, j)] } else { 0.0 });
    (q, rr)
}

/// Orthonormal basis for the column space of `a`, truncated to the first
/// `k` columns. NOTE: the first k QR columns span the first k *input*
/// columns, not the dominant subspace — use
/// [`leading_left_singular_vectors`] when the best rank-k basis matters
/// (Alg. 1 step 3 explicitly allows either; the SVD variant is what
/// makes oversampling pay off).
pub fn orthonormal_columns(a: &Mat, k: usize) -> Mat {
    assert!(k <= a.cols(), "cannot take {k} basis vectors from {} cols", a.cols());
    let (q, _) = householder_qr(a);
    Mat::from_fn(a.rows(), k, |i, j| q[(i, j)])
}

/// The `k` leading left singular vectors of a tall matrix `a` (m × n,
/// m ≥ n, k ≤ n), via QR + eigendecomposition of the small `R Rᵀ`:
/// `a = Q R`, `R Rᵀ = U Σ² Uᵀ` ⇒ left singular vectors are `Q U`.
/// This is Alg. 1 step 3's "r leading left singular vectors of W".
pub fn leading_left_singular_vectors(a: &Mat, k: usize) -> Mat {
    assert!(k <= a.cols(), "cannot take {k} singular vectors from {} cols", a.cols());
    let (q, r) = householder_qr(a);
    let rrt = r.matmul_t(&r); // n × n, symmetric PSD
    let (_evals, u) = super::jacobi_eig(&rrt); // descending
    let uk = Mat::from_fn(u.rows(), k, |i, j| u[(i, j)]);
    q.matmul(&uk)
}

fn norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::testutil::{assert_mat_close, random_mat};
    use crate::rng::Pcg64;

    fn check_qr(m: usize, n: usize, seed: u64) {
        let mut rng = Pcg64::seed(seed);
        let a = random_mat(&mut rng, m, n);
        let (q, r) = householder_qr(&a);
        assert_eq!((q.rows(), q.cols()), (m, n));
        assert_eq!((r.rows(), r.cols()), (n, n));
        // reconstruction
        assert_mat_close(&q.matmul(&r), &a, 1e-10);
        // orthonormality
        assert_mat_close(&q.t_matmul(&q), &Mat::identity(n), 1e-12);
        // upper-triangularity
        for i in 0..n {
            for j in 0..i {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn qr_reconstructs_various_shapes() {
        check_qr(5, 5, 1);
        check_qr(20, 7, 2);
        check_qr(100, 12, 3);
        check_qr(64, 1, 4);
    }

    #[test]
    fn qr_handles_rank_deficiency_gracefully() {
        // two identical columns: Q must still be orthonormal
        let mut rng = Pcg64::seed(5);
        let base = random_mat(&mut rng, 30, 1);
        let a = Mat::from_fn(30, 3, |i, j| if j < 2 { base[(i, 0)] } else { i as f64 });
        let (q, r) = householder_qr(&a);
        assert_mat_close(&q.t_matmul(&q), &Mat::identity(3), 1e-10);
        assert_mat_close(&q.matmul(&r), &a, 1e-9);
    }

    #[test]
    fn orthonormal_columns_spans_leading_subspace() {
        let mut rng = Pcg64::seed(6);
        let a = random_mat(&mut rng, 40, 10);
        let q = orthonormal_columns(&a, 4);
        assert_eq!((q.rows(), q.cols()), (40, 4));
        assert_mat_close(&q.t_matmul(&q), &Mat::identity(4), 1e-12);
        // the first column of a is in span(q): ||(I - QQᵀ) a_0|| ≈ 0
        let a0 = Mat::from_fn(40, 1, |i, _| a[(i, 0)]);
        let proj = q.matmul(&q.t_matmul(&a0));
        assert_mat_close(&proj, &a0, 1e-10);
    }

    #[test]
    fn leading_singular_vectors_beat_qr_truncation() {
        // a = [weak strong strong]: the dominant 1-dim subspace is NOT
        // spanned by the first column, so QR truncation misses it
        let mut rng = Pcg64::seed(7);
        let strong = random_mat(&mut rng, 50, 1);
        let weak = random_mat(&mut rng, 50, 1);
        let a = Mat::from_fn(50, 3, |i, j| match j {
            0 => 0.1 * weak[(i, 0)],
            1 => 10.0 * strong[(i, 0)],
            _ => 10.0 * strong[(i, 0)] + 0.05 * weak[(i, 0)],
        });
        let u = leading_left_singular_vectors(&a, 1);
        assert_mat_close(&u.t_matmul(&u), &Mat::identity(1), 1e-10);
        // u aligns with `strong`, not with the first column
        let s_norm = strong.frobenius_norm();
        let align: f64 = (0..50).map(|i| u[(i, 0)] * strong[(i, 0)] / s_norm).sum();
        assert!(align.abs() > 0.99, "alignment {align}");
        // and the projection residual of the strong direction is tiny
        let proj = u.matmul(&u.t_matmul(&strong));
        assert!(proj.sub(&strong).frobenius_norm() < 0.02 * s_norm);
    }

    #[test]
    #[should_panic(expected = "tall matrix")]
    fn qr_rejects_wide() {
        let _ = householder_qr(&Mat::zeros(3, 5));
    }
}
