//! Triangular solves, least squares, Cholesky, and PSD pseudo-inverse.
//!
//! `least_squares` is the engine of Alg. 1 step 4 (`B (QᵀΩ) = QᵀW` is
//! solved as a transposed least-squares problem); `pinv_psd` is the inner
//! inverse of the Nyström baseline.

use super::{householder_qr, jacobi_eig, Mat};

/// Solve `L x = b` with `L` lower-triangular (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for j in 0..i {
            s -= l[(i, j)] * x[j];
        }
        let d = l[(i, i)];
        assert!(d.abs() > 1e-300, "singular lower-triangular solve");
        x[i] = s / d;
    }
    x
}

/// Solve `U x = b` with `U` upper-triangular (back substitution).
pub fn solve_upper(u: &Mat, b: &[f64]) -> Vec<f64> {
    let n = u.rows();
    assert_eq!(u.cols(), n);
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        for j in (i + 1)..n {
            s -= u[(i, j)] * x[j];
        }
        let d = u[(i, i)];
        assert!(d.abs() > 1e-300, "singular upper-triangular solve");
        x[i] = s / d;
    }
    x
}

/// Minimum-norm least-squares solution of `A X = B` (A m × n tall,
/// full column rank) via QR: `X = R⁻¹ Qᵀ B`, one column of B at a time.
pub fn least_squares(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "least_squares row mismatch");
    let (q, r) = householder_qr(a);
    let qtb = q.t_matmul(b); // n × k
    let mut x = Mat::zeros(a.cols(), b.cols());
    for j in 0..b.cols() {
        let col: Vec<f64> = (0..qtb.rows()).map(|i| qtb[(i, j)]).collect();
        let sol = solve_upper(&r, &col);
        for (i, v) in sol.into_iter().enumerate() {
            x[(i, j)] = v;
        }
    }
    x
}

/// Cholesky factor `L` (lower) of a symmetric positive-definite matrix.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    let n = a.rows();
    assert_eq!(a.cols(), n);
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 {
                    return None; // not positive definite
                }
                l[(i, i)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Moore–Penrose pseudo-inverse of a symmetric PSD matrix via its
/// eigendecomposition, inverting only eigenvalues above a relative
/// threshold (the Nyström inner inverse `W_m⁺`).
pub fn pinv_psd(a: &Mat, rel_tol: f64) -> Mat {
    let (evals, v) = jacobi_eig(a);
    let lmax = evals.first().copied().unwrap_or(0.0).max(0.0);
    let tol = rel_tol * lmax.max(1e-300);
    let n = a.rows();
    // V diag(1/l where l > tol) Vᵀ
    let mut scaled = v.clone();
    for j in 0..n {
        let inv = if evals[j] > tol { 1.0 / evals[j] } else { 0.0 };
        for i in 0..n {
            scaled[(i, j)] *= inv;
        }
    }
    scaled.matmul_t(&v)
}

/// Moore–Penrose pseudo-inverse of a general (possibly rank-deficient)
/// matrix via the eigendecomposition of `MᵀM`: `M⁺ = V Σ⁻¹ Uᵀ` with
/// `MᵀM = V Σ² Vᵀ`, `U = M V Σ⁻¹`, inverting only singular values above
/// `rel_tol · σ_max`. Used by the one-pass recovery where `QᵀΩ` can be
/// numerically rank-deficient (rank(W) < r' when K itself has low rank).
pub fn pinv(m: &Mat, rel_tol: f64) -> Mat {
    let mtm = m.t_matmul(m); // n × n PSD
    let (evals, v) = jacobi_eig(&mtm);
    let smax = evals.first().copied().unwrap_or(0.0).max(0.0).sqrt();
    let tol = rel_tol * smax.max(1e-300);
    let n = m.cols();
    // M⁺ = Σ_i (1/σ_i) v_i u_iᵀ where u_i = M v_i / σ_i
    let mut out = Mat::zeros(n, m.rows());
    for i in 0..n {
        let sigma = evals[i].max(0.0).sqrt();
        if sigma <= tol {
            continue;
        }
        // u = M v_i / σ
        let mut u = vec![0.0; m.rows()];
        for (row, uval) in u.iter_mut().enumerate() {
            let mut s = 0.0;
            for k in 0..n {
                s += m[(row, k)] * v[(k, i)];
            }
            *uval = s / sigma;
        }
        for r in 0..n {
            let coef = v[(r, i)] / sigma;
            for (c, &uval) in u.iter().enumerate() {
                out[(r, c)] += coef * uval;
            }
        }
    }
    out
}

/// Rank-limited PSD pseudo-inverse: invert only the top `r` eigenvalues
/// (the rank-restricted Nyström variant used for the paper's r = 2).
pub fn pinv_psd_rank(a: &Mat, r: usize, rel_tol: f64) -> Mat {
    let (evals, v) = jacobi_eig(a);
    let lmax = evals.first().copied().unwrap_or(0.0).max(0.0);
    let tol = rel_tol * lmax.max(1e-300);
    let n = a.rows();
    let mut scaled = v.clone();
    for j in 0..n {
        let inv = if j < r && evals[j] > tol { 1.0 / evals[j] } else { 0.0 };
        for i in 0..n {
            scaled[(i, j)] *= inv;
        }
    }
    scaled.matmul_t(&v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::testutil::{assert_mat_close, random_mat};
    use crate::rng::Pcg64;

    #[test]
    fn triangular_solves_roundtrip() {
        let l = Mat::from_vec(3, 3, vec![2., 0., 0., 1., 3., 0., 4., 5., 6.]);
        let x = vec![1.0, -2.0, 0.5];
        let b: Vec<f64> = (0..3).map(|i| super::super::dot(l.row(i), &x)).collect();
        let got = solve_lower(&l, &b);
        for (g, w) in got.iter().zip(&x) {
            assert!((g - w).abs() < 1e-12);
        }
        let u = l.transpose();
        let b: Vec<f64> = (0..3).map(|i| super::super::dot(u.row(i), &x)).collect();
        let got = solve_upper(&u, &b);
        for (g, w) in got.iter().zip(&x) {
            assert!((g - w).abs() < 1e-12);
        }
    }

    #[test]
    fn least_squares_exact_when_consistent() {
        let mut rng = Pcg64::seed(1);
        let a = random_mat(&mut rng, 12, 4);
        let x_true = random_mat(&mut rng, 4, 3);
        let b = a.matmul(&x_true);
        let x = least_squares(&a, &b);
        assert_mat_close(&x, &x_true, 1e-10);
    }

    #[test]
    fn least_squares_minimizes_residual() {
        // overdetermined inconsistent system: residual must be orthogonal
        // to the column space (normal equations)
        let mut rng = Pcg64::seed(2);
        let a = random_mat(&mut rng, 20, 5);
        let b = random_mat(&mut rng, 20, 2);
        let x = least_squares(&a, &b);
        let resid = a.matmul(&x).sub(&b);
        let atr = a.t_matmul(&resid);
        assert!(atr.max_abs() < 1e-10, "AᵀR = {}", atr.max_abs());
    }

    #[test]
    fn cholesky_roundtrip_and_rejects_indefinite() {
        let mut rng = Pcg64::seed(3);
        let b = random_mat(&mut rng, 10, 6);
        let mut a = b.t_matmul(&b);
        for i in 0..6 {
            a[(i, i)] += 0.5; // well-conditioned SPD
        }
        let l = cholesky(&a).expect("SPD must factor");
        assert_mat_close(&l.matmul_t(&l), &a, 1e-10);

        let indef = Mat::from_vec(2, 2, vec![1., 2., 2., 1.]); // eigenvalues 3, -1
        assert!(cholesky(&indef).is_none());
    }

    #[test]
    fn pinv_psd_is_inverse_on_range() {
        let mut rng = Pcg64::seed(4);
        let b = random_mat(&mut rng, 8, 3);
        let a = b.t_matmul(&b); // full-rank 3x3 PSD
        let p = pinv_psd(&a, 1e-12);
        assert_mat_close(&p.matmul(&a), &Mat::identity(3), 1e-8);
    }

    #[test]
    fn pinv_psd_handles_rank_deficiency() {
        let mut rng = Pcg64::seed(5);
        let b = random_mat(&mut rng, 6, 2);
        let bb = b.matmul_t(&b); // 6x6, rank 2
        let p = pinv_psd(&bb, 1e-10);
        // A P A = A (Moore–Penrose condition 1)
        assert_mat_close(&bb.matmul(&p).matmul(&bb), &bb, 1e-8);
        // P A P = P (condition 2)
        assert_mat_close(&p.matmul(&bb).matmul(&p), &p, 1e-8);
    }

    #[test]
    fn pinv_rank_restricts_spectrum() {
        let a = Mat::from_vec(3, 3, vec![4., 0., 0., 0., 2., 0., 0., 0., 1.]);
        let p = pinv_psd_rank(&a, 2, 1e-12);
        assert!((p[(0, 0)] - 0.25).abs() < 1e-12);
        assert!((p[(1, 1)] - 0.5).abs() < 1e-12);
        assert_eq!(p[(2, 2)], 0.0);
    }
}
