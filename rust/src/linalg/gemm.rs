//! The shared cache-blocked GEMM micro-kernel every dense hot path
//! routes through: the recovery step's `QᵀW` / `Q·Uq` / `Y = Σ^½VᵀQᵀ`,
//! the Nyström projection, the gram core of
//! [`NativeBlockSource`](crate::kernels::NativeBlockSource), and the
//! K-means cross term `YᵀC`.
//!
//! Shape of the kernel (same scheme as the gram core it generalizes):
//! i-outer over rows of `C`, a `b`-wide axpy inner loop that the
//! compiler vectorizes, and `B` packed once into L2-resident
//! `KC × NC` panels so the inner loop streams contiguous memory no
//! matter how `B` was laid out. Threading fans disjoint row ranges of
//! `C` out through [`crate::util::parallel`].
//!
//! # Determinism contract (scoped per ISA)
//!
//! Every output element accumulates its `k`-sum in ascending-`k` order,
//! for any thread count and either code path (single-panel fast path or
//! packed panels — the panel loops visit `k` blocks in order). Threads
//! only partition *rows* of `C`, never a reduction, so
//! `gemm(a, b, 1)` and `gemm(a, b, N)` are bit-identical — the property
//! the crate-wide `threads=1 ≡ threads=N` contract
//! (`tests/parallel_determinism.rs`) rests on.
//!
//! The axpy inner loop is dispatched through
//! [`crate::simd::dispatch`], so the *rounding* of each `+=` depends on
//! the kernel table the process selected (AVX2/NEON fuse the
//! multiply-add): results are bit-identical across thread counts
//! **within an ISA**, and agree with [`matmul_reference`] to ≤ 1e-12
//! **across ISAs** — that oracle bound, not bit-equality, is the
//! cross-ISA contract. `RKC_SIMD=scalar` restores the pre-dispatch
//! bit-exact behavior on any host.

use super::Mat;
use crate::simd::KernelTable;
use crate::util::parallel::for_each_row_chunk;

/// Depth (`k` extent) of a packed panel of `B`.
const KC: usize = 256;
/// Width (`j` extent) of a packed panel of `B`; `KC·NC` f64 = 256 KiB,
/// sized to stay L2-resident while a worker sweeps its rows over it.
const NC: usize = 128;

/// `C = A · B`, cache-blocked and threaded over rows of `C`.
pub fn gemm(a: &Mat, b: &Mat, threads: usize) -> Mat {
    gemm_with(a, b, threads, crate::simd::dispatch())
}

/// [`gemm`] with an explicit kernel table (see [`gemm_into_with`]).
pub fn gemm_with(a: &Mat, b: &Mat, threads: usize, table: &KernelTable) -> Mat {
    assert_eq!(a.cols(), b.rows(), "gemm shape mismatch");
    let mut c = Mat::zeros(a.rows(), b.cols());
    gemm_into_with(c.data_mut(), a, b, threads, table);
    c
}

/// `C = Aᵀ · B` (both operands tall, `a.rows == b.rows`). The transpose
/// is materialized once — a copy is cheaper than the strided inner loop
/// it replaces, and it keeps one accumulation order for every variant.
pub fn gemm_tn(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.rows(), b.rows(), "gemm_tn shape mismatch");
    gemm(&a.transpose(), b, threads)
}

/// `C = A · Bᵀ` (`a.cols == b.cols`).
pub fn gemm_nt(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.cols(), b.cols(), "gemm_nt shape mismatch");
    gemm(a, &b.transpose(), threads)
}

/// Accumulate `C += A · B` into a caller-owned row-major buffer of
/// exactly `a.rows() · b.cols()` elements (callers that need `C = A·B`
/// pass a zeroed buffer). This is the entry point for callers that own
/// a larger allocation — the gram core writes the real-row prefix of a
/// padded block without a copy.
pub fn gemm_into(c: &mut [f64], a: &Mat, b: &Mat, threads: usize) {
    gemm_into_with(c, a, b, threads, crate::simd::dispatch());
}

/// [`gemm_into`] with an explicit kernel table — the seam the cross-ISA
/// property tests and `#simd` bench rows use to pin a specific axpy
/// kernel regardless of what `dispatch()` selected for the process.
pub fn gemm_into_with(c: &mut [f64], a: &Mat, b: &Mat, threads: usize, table: &KernelTable) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    assert_eq!(k, b.rows(), "gemm shape mismatch");
    assert_eq!(c.len(), m * n, "gemm output buffer mismatch");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    if table.isa == crate::simd::Isa::Scalar {
        // monomorphized direct call: the fallback tier keeps the
        // compiler's inlining + auto-vectorization of the scalar axpy
        // instead of paying an opaque indirect call per k-step (the
        // crate's hot shapes have n ≈ r′, so each axpy is short).
        // Bit-identical to the fn-pointer form — every c[i] is an
        // independent accumulation, so codegen can't reorder a sum.
        gemm_loops(c, a, b, threads, crate::simd::axpy_scalar);
    } else {
        // hoisted once: the indirect call is per-axpy, never per-element
        gemm_loops(c, a, b, threads, table.axpy);
    }
}

/// The two blocked loop nests, generic over the axpy kernel: the
/// scalar tier monomorphizes an inlinable copy, the vector tiers pass
/// the dispatched fn pointer.
fn gemm_loops(
    c: &mut [f64],
    a: &Mat,
    b: &Mat,
    threads: usize,
    axpy: impl Fn(&mut [f64], f64, &[f64]) + Copy + Sync,
) {
    let (k, n) = (a.cols(), b.cols());
    let threads = threads.max(1);
    if k <= KC && n <= NC {
        // single-panel fast path: B already fits one panel, read it
        // directly (this covers the crate's tall-skinny hot shapes,
        // where n is r, r', or the cluster count)
        for_each_row_chunk(c, n, threads, |i0, rows| {
            for (di, crow) in rows.chunks_mut(n).enumerate() {
                let arow = a.row(i0 + di);
                for (dk, &aik) in arow.iter().enumerate() {
                    axpy(crow, aik, b.row(dk));
                }
            }
        });
        return;
    }
    let packed = PackedB::new(b);
    for_each_row_chunk(c, n, threads, |i0, rows| {
        let nrows = rows.len() / n;
        for (pj, &(j0, jw)) in packed.jblocks.iter().enumerate() {
            for (pk, &(k0, kw)) in packed.kblocks.iter().enumerate() {
                let panel = packed.panel(pj, pk, jw, kw);
                for di in 0..nrows {
                    let arow = &a.row(i0 + di)[k0..k0 + kw];
                    let crow = &mut rows[di * n + j0..di * n + j0 + jw];
                    for (dk, &aik) in arow.iter().enumerate() {
                        axpy(crow, aik, &panel[dk * jw..(dk + 1) * jw]);
                    }
                }
            }
        }
    });
}

/// `B` repacked into `(j-block, k-block)` panels, each `kw × jw`
/// row-major and contiguous. Built once per product, shared read-only
/// by every worker.
struct PackedB {
    jblocks: Vec<(usize, usize)>,
    kblocks: Vec<(usize, usize)>,
    data: Vec<f64>,
    /// panel offsets indexed `pj * kblocks.len() + pk`
    offsets: Vec<usize>,
}

impl PackedB {
    fn new(b: &Mat) -> Self {
        let (k, n) = (b.rows(), b.cols());
        let jblocks = block_ranges(n, NC);
        let kblocks = block_ranges(k, KC);
        let mut data = Vec::with_capacity(k * n);
        let mut offsets = Vec::with_capacity(jblocks.len() * kblocks.len());
        for &(j0, jw) in &jblocks {
            for &(k0, kw) in &kblocks {
                offsets.push(data.len());
                for dk in 0..kw {
                    data.extend_from_slice(&b.row(k0 + dk)[j0..j0 + jw]);
                }
            }
        }
        PackedB { jblocks, kblocks, data, offsets }
    }

    #[inline]
    fn panel(&self, pj: usize, pk: usize, jw: usize, kw: usize) -> &[f64] {
        let off = self.offsets[pj * self.kblocks.len() + pk];
        &self.data[off..off + kw * jw]
    }
}

/// Split `0..total` into `(start, len)` ranges of at most `step`.
fn block_ranges(total: usize, step: usize) -> Vec<(usize, usize)> {
    (0..total).step_by(step).map(|s| (s, step.min(total - s))).collect()
}

/// Naive j-inner reference matmul — the oracle the GEMM property tests
/// and `bench_recovery`/`bench_kmeans` before/after rows compare
/// against. Never used on a hot path.
pub fn matmul_reference(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    Mat::from_fn(a.rows(), b.cols(), |i, j| {
        (0..a.cols()).map(|k| a[(i, k)] * b[(k, j)]).sum()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::testutil::{assert_mat_close, random_mat};
    use crate::rng::Pcg64;

    #[test]
    fn gemm_matches_reference_across_odd_shapes() {
        let mut rng = Pcg64::seed(1);
        // empty, 1×1, skinny, and non-multiples of both block sizes
        for &(m, k, n) in &[
            (0usize, 3usize, 4usize),
            (3, 0, 4),
            (3, 4, 0),
            (1, 1, 1),
            (5, 7, 3),
            (2, KC + 3, NC + 5),
            (17, KC, NC),
            (9, 2 * KC + 1, NC - 1),
        ] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            assert_mat_close(&gemm(&a, &b, 1), &matmul_reference(&a, &b), 1e-12);
        }
    }

    #[test]
    fn gemm_is_thread_count_invariant_bitwise() {
        let mut rng = Pcg64::seed(2);
        for &(m, k, n) in &[(37usize, 19usize, 23usize), (8, KC + 9, NC + 17)] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let base = gemm(&a, &b, 1);
            for threads in [2usize, 3, 8] {
                assert_eq!(base.data(), gemm(&a, &b, threads).data(), "threads={threads}");
            }
        }
    }

    #[test]
    fn gemm_variants_match_reference() {
        let mut rng = Pcg64::seed(3);
        let a = random_mat(&mut rng, 11, 6);
        let b = random_mat(&mut rng, 11, 9);
        assert_mat_close(&gemm_tn(&a, &b, 2), &matmul_reference(&a.transpose(), &b), 1e-12);
        let c = random_mat(&mut rng, 7, 6);
        assert_mat_close(&gemm_nt(&a, &c, 2), &matmul_reference(&a, &c.transpose()), 1e-12);
    }

    #[test]
    fn gemm_with_every_available_table_matches_reference() {
        let mut rng = Pcg64::seed(5);
        let a = random_mat(&mut rng, 9, KC + 3);
        let b = random_mat(&mut rng, KC + 3, NC + 5);
        let want = matmul_reference(&a, &b);
        for table in crate::simd::available_tables() {
            assert_mat_close(&gemm_with(&a, &b, 3, table), &want, 1e-12);
            // threads=1 ≡ threads=N holds per table, not just per process
            assert_eq!(
                gemm_with(&a, &b, 1, table).data(),
                gemm_with(&a, &b, 4, table).data(),
                "thread bit-identity [{}]",
                table.isa.name()
            );
        }
    }

    #[test]
    fn gemm_into_accumulates() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 2, vec![5., 6., 7., 8.]);
        let mut c = vec![1.0; 4];
        gemm_into(&mut c, &a, &b, 1);
        // A·B = [[19,22],[43,50]] on top of the existing ones
        assert_eq!(c, vec![20., 23., 44., 51.]);
    }

    #[test]
    #[should_panic(expected = "gemm shape mismatch")]
    fn gemm_rejects_shape_mismatch() {
        let _ = gemm(&Mat::zeros(2, 3), &Mat::zeros(2, 3), 1);
    }
}
