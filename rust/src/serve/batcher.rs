//! The bounded micro-batch queue at the heart of [`super::ModelServer`].
//!
//! Concurrent request threads [`push`](Batcher::push) into a bounded
//! queue (blocking while full — the same backpressure discipline as the
//! sharded sketch pass's bounded channel); one batch worker drains up to
//! `max_batch` requests at a time with [`next_batch`](Batcher::next_batch)
//! and fans them out over the shared fork-join pool.
//! [`close`](Batcher::close) wakes every waiter: producers get a typed
//! rejection, the worker drains what is left and exits.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use crate::error::{Result, RkcError};

use super::Request;

struct State {
    queue: VecDeque<Request>,
    closed: bool,
    /// deepest the queue has ever been — the per-model backpressure
    /// signal surfaced in [`super::ServeStats::queue_highwater`]
    highwater: usize,
}

/// Bounded multi-producer / single-consumer request queue with
/// condvar-based blocking on both ends.
pub(crate) struct Batcher {
    state: Mutex<State>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

impl Batcher {
    pub(crate) fn new(cap: usize) -> Self {
        assert!(cap > 0, "queue capacity must be positive");
        Batcher {
            state: Mutex::new(State { queue: VecDeque::new(), closed: false, highwater: 0 }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap,
        }
    }

    /// Enqueue a request, blocking while the queue is at capacity.
    /// Returns a typed error once the server has shut down.
    pub(crate) fn push(&self, req: Request) -> Result<()> {
        let mut st = self.state.lock().expect("serve queue poisoned");
        while st.queue.len() >= self.cap && !st.closed {
            st = self.not_full.wait(st).expect("serve queue poisoned");
        }
        if st.closed {
            return Err(RkcError::backend("model server is shut down"));
        }
        st.queue.push_back(req);
        st.highwater = st.highwater.max(st.queue.len());
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Take the next batch (1..=`max` requests), blocking while the
    /// queue is empty. Returns `None` once the queue is closed *and*
    /// fully drained — the worker's exit signal.
    pub(crate) fn next_batch(&self, max: usize) -> Option<Vec<Request>> {
        let mut st = self.state.lock().expect("serve queue poisoned");
        while st.queue.is_empty() && !st.closed {
            st = self.not_empty.wait(st).expect("serve queue poisoned");
        }
        if st.queue.is_empty() {
            return None; // closed and drained
        }
        let take = st.queue.len().min(max.max(1));
        let batch: Vec<Request> = st.queue.drain(..take).collect();
        drop(st);
        // every producer blocked on a full queue may now have room
        self.not_full.notify_all();
        Some(batch)
    }

    /// Current queue depth (for health reporting; racy by nature).
    pub(crate) fn depth(&self) -> usize {
        self.state.lock().expect("serve queue poisoned").queue.len()
    }

    /// Deepest the queue has ever been since the server started — how
    /// close this model's clients have come to hitting backpressure.
    pub(crate) fn highwater(&self) -> usize {
        self.state.lock().expect("serve queue poisoned").highwater
    }

    /// Whether the queue has been closed (worker exited or the server
    /// shut down) — the health endpoint's liveness signal.
    pub(crate) fn is_closed(&self) -> bool {
        self.state.lock().expect("serve queue poisoned").closed
    }

    /// Close the queue: producers are rejected from now on, the worker
    /// drains the remainder and exits.
    pub(crate) fn close(&self) {
        self.state.lock().expect("serve queue poisoned").closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }
}
