//! `serve::registry` — many named models behind one serving process.
//!
//! The paper's one-pass sketch makes a fitted model *small* (`O(n·r')`
//! persistent state instead of the `O(n²)` kernel), so the natural
//! production shape is a fleet of small models sharing one process and
//! one HTTP front-end. [`ModelRegistry`] is that fleet: a `RwLock` map
//! from model name to an independently-batched [`ModelServer`] (own
//! bounded queue, own batch worker, own [`ServeStats`]), with runtime
//! load/unload and lazy loading from a directory of `.rkc` files.
//!
//! Naming rules: a model name is a non-empty ASCII `[A-Za-z0-9._-]+`
//! token (what a `.rkc` file stem looks like, and what fits in a URL
//! path segment without escaping). The **first** model registered
//! becomes the *default* — the target of the legacy single-model
//! `/predict` and `/embed` routes; unloading it promotes the
//! alphabetically-first survivor.
//!
//! Unloading is graceful: the map drops its `Arc<ModelServer>`, and the
//! server's `Drop` closes the queue, drains in-flight requests (replies
//! are still delivered), and joins the batch worker. Requests routed in
//! the race window get the queue's typed shutdown rejection.

use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};
use std::time::Duration;

use crate::api::FittedModel;
use crate::error::{Result, RkcError};

use super::{ModelServer, ServeOpts, ServeStats, ServerHandle};

/// How many times [`ModelRegistry::load`] attempts a `.rkc` read whose
/// failures classify as transient ([`RkcError::is_transient`]) before
/// giving up, and the backoff before the first retry (doubling each
/// attempt: 10ms, 20ms, 40ms — bounded, so a hard failure still
/// surfaces in well under a second).
const LOAD_ATTEMPTS: u32 = 4;
const LOAD_BACKOFF: Duration = Duration::from_millis(10);

/// One registered model: the request-submission handle plus, for models
/// the registry loaded itself, ownership of the server (dropping it
/// shuts the model down).
struct Entry {
    handle: ServerHandle,
    /// `None` for models registered by handle ([`ModelRegistry::register`]),
    /// whose `ModelServer` the caller owns.
    owner: Option<Arc<ModelServer>>,
    /// provenance for listings: the `.rkc` path this model was loaded
    /// from, when the registry did the loading
    path: Option<String>,
}

struct Inner {
    models: BTreeMap<String, Entry>,
    /// target of the legacy single-model routes; first registered wins,
    /// unloading it promotes the alphabetically-first survivor
    default: Option<String>,
    /// per-name publish counter backing [`ModelRegistry::publish`]:
    /// monotone per name, surviving replaces and unloads, so generations
    /// observed by clients never repeat or go backwards
    generations: BTreeMap<String, u64>,
    /// models that failed to load/replace, name → last failure. A
    /// quarantined name keeps whatever generation was serving before
    /// (or nothing, for startup failures); `/healthz` reports the
    /// process `degraded` while this is non-empty. A later successful
    /// load under the name clears its entry.
    quarantined: BTreeMap<String, String>,
}

/// A point-in-time description of one registered model (the
/// `GET /models` row).
#[derive(Clone, Debug)]
pub struct ModelInfo {
    /// registry name (URL path segment)
    pub name: String,
    /// whether the legacy `/predict`/`/embed` routes alias this model
    pub is_default: bool,
    /// `Method` display form, e.g. `one_pass`
    pub method: String,
    /// number of clusters
    pub k: usize,
    /// training-set size
    pub n_train: usize,
    /// embedding rank
    pub rank: usize,
    /// expected query dimension (`None` when the model accepts any)
    pub input_dim: Option<usize>,
    /// `.rkc` file this model was loaded from, when the registry loaded it
    pub path: Option<String>,
    /// this model's serving counters
    pub stats: ServeStats,
    /// current micro-batch queue depth
    pub queue_depth: usize,
    /// refresh generation of the served model (0 = plain batch fit,
    /// g ≥ 1 = the g-th [`ModelRegistry::publish`] under this name)
    pub generation: u64,
}

/// A named collection of independently-batched [`ModelServer`]s —
/// the multi-model serving core behind [`super::serve_http_registry`].
///
/// ```
/// use rkc::api::KernelClusterer;
/// use rkc::serve::{ModelRegistry, ServeOpts};
/// use rkc::data;
/// use rkc::rng::Pcg64;
///
/// let ds = data::cross_lines(&mut Pcg64::seed(3), 128);
/// let model = KernelClusterer::new(2).oversample(8).fit(&ds.x)?;
/// let direct = model.predict(&ds.x)?;
///
/// let reg = ModelRegistry::new(ServeOpts::default());
/// reg.insert("rings", model)?;
/// let handle = reg.get("rings").expect("just inserted");
/// assert_eq!(handle.predict(ds.x.clone())?, direct);
/// assert_eq!(reg.names(), vec!["rings".to_string()]);
/// # Ok::<(), rkc::error::RkcError>(())
/// ```
pub struct ModelRegistry {
    inner: RwLock<Inner>,
    /// queue/batch/thread options every registry-created server gets
    opts: ServeOpts,
}

/// Is `name` a legal registry name (non-empty ASCII `[A-Za-z0-9._-]+`)?
pub(crate) fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 128
        && name.bytes().all(|b| b.is_ascii_alphanumeric() || matches!(b, b'.' | b'_' | b'-'))
}

impl ModelRegistry {
    /// An empty registry; `opts` applies to every model it serves.
    pub fn new(opts: ServeOpts) -> Self {
        ModelRegistry {
            inner: RwLock::new(Inner {
                models: BTreeMap::new(),
                default: None,
                generations: BTreeMap::new(),
                quarantined: BTreeMap::new(),
            }),
            opts,
        }
    }

    fn check_name(name: &str) -> Result<()> {
        if valid_name(name) {
            Ok(())
        } else {
            Err(RkcError::invalid_config(format!(
                "invalid model name '{name}' (want non-empty ASCII [A-Za-z0-9._-]+)"
            )))
        }
    }

    /// Fit-in-memory entry point: wrap `model` in its own
    /// [`ModelServer`] and register it under `name`, replacing (and
    /// gracefully shutting down) any model already there.
    pub fn insert(&self, name: &str, model: FittedModel) -> Result<()> {
        Self::check_name(name)?;
        let server = ModelServer::named(name, model, self.opts)?;
        self.insert_entry(name, server.handle(), Some(Arc::new(server)), None)
    }

    /// Atomically publish a refreshed `model` under `name`, stamping it
    /// with that name's next generation (1 for the first publish,
    /// +1 per publish; the counter survives replaces and unloads, so
    /// observed generations never repeat). Returns the generation
    /// assigned.
    ///
    /// Swap semantics are **old-or-new, never a blend**: the new
    /// [`ModelServer`] (queue + batch worker) is fully constructed
    /// before the map pointer flips under the write lock; requests
    /// already queued on the displaced server drain to completion
    /// against the old model (its handle — and any response it
    /// computes — references only the old `FittedModel`), while
    /// requests routed after the flip see only the new one. The
    /// displaced server's drain + worker join happens outside the
    /// lock. `tests/stream_hotswap.rs` drives concurrent keep-alive
    /// clients across a publish to enforce this.
    ///
    /// Generations are assigned per publish *call*; with several
    /// threads publishing the same name concurrently each gets a
    /// distinct generation, and an install that lost the build race to
    /// a newer generation is skipped — the served generation never goes
    /// backwards. The intended topology is still one refresh loop per
    /// name.
    pub fn publish(&self, name: &str, mut model: FittedModel) -> Result<u64> {
        Self::check_name(name)?;
        let generation = {
            let mut inner = self.inner.write().expect("registry lock poisoned");
            let slot = inner.generations.entry(name.to_string()).or_insert(0);
            *slot += 1;
            *slot
        };
        model.set_generation(generation);
        // build the new server (queue + batch worker) outside the lock;
        // same-name generations share one metric series, so /metrics
        // counters stay cumulative across hot-swaps
        let server = ModelServer::named(name, model, self.opts)?;
        let handle = server.handle();
        let owner = Some(Arc::new(server));
        let displaced;
        {
            let mut inner = self.inner.write().expect("registry lock poisoned");
            // between reserving the generation above and this insert a
            // concurrent publish may have installed a NEWER generation;
            // installing ours now would serve stale results under a
            // lower generation number. Skip the install instead (the
            // stale server is dropped below, outside the lock).
            if let Some(current) = inner.models.get(name) {
                if current.handle.shared.model.generation() > generation {
                    return Ok(generation);
                }
            }
            displaced =
                inner.models.insert(name.to_string(), Entry { handle, owner, path: None });
            if inner.default.is_none() {
                inner.default = Some(name.to_string());
            }
            inner.quarantined.remove(name);
        }
        // dropping the displaced owned server joins its batch worker —
        // outside the lock so other routes keep flowing
        drop(displaced);
        Ok(generation)
    }

    /// Register a caller-owned server under `name`. The registry holds
    /// only the submission handle: dropping the `ModelServer` on the
    /// caller's side shuts the model down, after which routed requests
    /// get its typed shutdown rejection.
    ///
    /// The server's `rkc_serve_*` metric series keep the `model` label
    /// it was **constructed** with (registration cannot relabel interned
    /// series behind the shared handle) — build it with
    /// [`ModelServer::named`]`(name, …)` when registering under any name
    /// other than `"default"`, or its `/metrics` traffic lands on
    /// `model="default"`.
    pub fn register(&self, name: &str, server: &ModelServer) -> Result<()> {
        self.insert_entry(name, server.handle(), None, None)
    }

    /// Load a `.rkc` file and register it under `name` (the runtime
    /// `PUT /models/{name}` path). Replaces any model already there.
    ///
    /// Transient read failures ([`RkcError::is_transient`] — an
    /// injected fault, a momentarily unavailable file) are retried with
    /// bounded exponential backoff before surfacing; hard failures
    /// (corrupt file, bad magic, version skew) surface immediately. A
    /// failure at any stage leaves the registry exactly as it was: the
    /// previous model under `name` (if any) keeps serving. Failpoint
    /// site: [`crate::fault::SERVE_LOAD`], inside the retry loop.
    pub fn load(&self, name: &str, path: &str) -> Result<()> {
        Self::check_name(name)?;
        let model = Self::read_model_with_retry(path)?;
        let server = ModelServer::named(name, model, self.opts)?;
        self.insert_entry(name, server.handle(), Some(Arc::new(server)), Some(path.to_string()))
    }

    fn read_model_with_retry(path: &str) -> Result<FittedModel> {
        let mut delay = LOAD_BACKOFF;
        for attempt in 1..=LOAD_ATTEMPTS {
            let res = crate::fault::trip(crate::fault::SERVE_LOAD)
                .and_then(|()| FittedModel::load(path));
            match res {
                Ok(model) => return Ok(model),
                Err(e) if e.is_transient() && attempt < LOAD_ATTEMPTS => {
                    crate::obs::registry()
                        .counter(
                            "rkc_serve_load_retries_total",
                            "Transient model-load failures retried with backoff.",
                            &[],
                        )
                        .inc();
                    std::thread::sleep(delay);
                    delay *= 2;
                }
                Err(e) => return Err(e),
            }
        }
        unreachable!("the final attempt always returns")
    }

    /// Record that the model under `name` failed to load or replace —
    /// the previous generation (if any) keeps serving, `/healthz`
    /// reports `degraded`, and `rkc_models_quarantined_total` counts
    /// the event. Cleared by the next successful load/insert/publish
    /// under the same name.
    pub fn quarantine(&self, name: &str, reason: impl Into<String>) {
        let reason = reason.into();
        {
            let mut inner = self.inner.write().expect("registry lock poisoned");
            inner.quarantined.insert(name.to_string(), reason);
        }
        crate::obs::registry()
            .counter(
                "rkc_models_quarantined_total",
                "Models quarantined after a failed load or hot-swap.",
                &[],
            )
            .inc();
    }

    /// Names currently quarantined, with the failure that put each
    /// there (ascending by name — the `/healthz` `degraded` listing).
    pub fn quarantined(&self) -> Vec<(String, String)> {
        let inner = self.inner.read().expect("registry lock poisoned");
        inner.quarantined.iter().map(|(n, r)| (n.clone(), r.clone())).collect()
    }

    /// Load every `*.rkc` file in `dir` (name = file stem, ascending, so
    /// the alphabetically-first model is the default), and return the
    /// names loaded. A directory with no `.rkc` files is a config error —
    /// a registry that can never answer anything is a misconfiguration
    /// worth failing loudly at startup.
    ///
    /// Individual files that fail to load — corrupt, truncated,
    /// unreadable, version skew, unusable name — do **not** abort the
    /// startup: each is [quarantined](Self::quarantine) (surfacing in
    /// `/healthz` as `degraded`) and the rest of the fleet loads. Only
    /// a directory where *nothing* loads is an error.
    pub fn load_dir(&self, dir: &str) -> Result<Vec<String>> {
        let entries = std::fs::read_dir(dir)
            .map_err(|e| RkcError::io(format!("reading model directory {dir}"), e))?;
        let mut paths: Vec<(String, String)> = Vec::new();
        for entry in entries {
            let entry = entry.map_err(|e| RkcError::io(format!("reading {dir}"), e))?;
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("rkc") {
                continue;
            }
            let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("").to_string();
            let path = path.to_string_lossy().into_owned();
            paths.push((stem, path));
        }
        if paths.is_empty() {
            return Err(RkcError::invalid_config(format!("no .rkc models found in {dir}")));
        }
        paths.sort();
        let total = paths.len();
        let mut names = Vec::with_capacity(total);
        for (name, path) in paths {
            let res = if valid_name(&name) {
                self.load(&name, &path)
            } else {
                Err(RkcError::invalid_config(format!("unusable model name for {path}")))
            };
            match res {
                Ok(()) => names.push(name),
                Err(e) => {
                    let display = if name.is_empty() { path.clone() } else { name };
                    self.quarantine(&display, format!("{path}: {e}"));
                }
            }
        }
        if names.is_empty() {
            return Err(RkcError::invalid_config(format!(
                "no loadable .rkc models in {dir}: all {total} quarantined"
            )));
        }
        Ok(names)
    }

    fn insert_entry(
        &self,
        name: &str,
        handle: ServerHandle,
        owner: Option<Arc<ModelServer>>,
        path: Option<String>,
    ) -> Result<()> {
        Self::check_name(name)?;
        // build the entry before taking the write lock; only the map
        // insert (and the displaced entry's drop) happens under it
        let displaced;
        {
            let mut inner = self.inner.write().expect("registry lock poisoned");
            displaced = inner.models.insert(name.to_string(), Entry { handle, owner, path });
            if inner.default.is_none() {
                inner.default = Some(name.to_string());
            }
            // a model serving under this name supersedes any earlier
            // failure record
            inner.quarantined.remove(name);
        }
        // dropping a displaced owned server joins its batch worker —
        // do that outside the lock so other routes keep flowing
        drop(displaced);
        Ok(())
    }

    /// Unload `name`, returning whether it was present (serving, or
    /// merely quarantined — unloading also clears the quarantine entry,
    /// so a name nobody intends to serve cannot hold `/healthz`
    /// degraded). Graceful: its queue closes, in-flight requests still
    /// get replies, and the batch worker is joined before this returns.
    /// Unloading the default promotes the alphabetically-first survivor.
    pub fn unload(&self, name: &str) -> bool {
        let removed;
        let was_quarantined;
        {
            let mut inner = self.inner.write().expect("registry lock poisoned");
            removed = inner.models.remove(name);
            // dropping a name withdraws the intent to serve it — a
            // quarantine entry must not hold /healthz degraded for a
            // model nobody expects to exist anymore
            was_quarantined = inner.quarantined.remove(name).is_some();
            if removed.is_some() && inner.default.as_deref() == Some(name) {
                inner.default = inner.models.keys().next().cloned();
            }
        }
        // the owned server's Drop (queue close + worker join) runs here,
        // outside the lock
        removed.is_some() || was_quarantined
    }

    /// The submission handle for `name`, if registered.
    pub fn get(&self, name: &str) -> Option<ServerHandle> {
        let inner = self.inner.read().expect("registry lock poisoned");
        inner.models.get(name).map(|e| e.handle.clone())
    }

    /// The default model's `(name, handle)` — the legacy single-model
    /// routes' target — if any model is registered.
    pub fn default_model(&self) -> Option<(String, ServerHandle)> {
        let inner = self.inner.read().expect("registry lock poisoned");
        let name = inner.default.clone()?;
        let handle = inner.models.get(&name)?.handle.clone();
        Some((name, handle))
    }

    /// Registered model names, ascending.
    pub fn names(&self) -> Vec<String> {
        let inner = self.inner.read().expect("registry lock poisoned");
        inner.models.keys().cloned().collect()
    }

    /// How many models are registered.
    pub fn len(&self) -> usize {
        self.inner.read().expect("registry lock poisoned").models.len()
    }

    /// Whether no models are registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn make_info(default: Option<&str>, name: &str, entry: &Entry) -> ModelInfo {
        let shared = &entry.handle.shared;
        let m = shared.model.metrics();
        ModelInfo {
            name: name.to_string(),
            is_default: default == Some(name),
            method: m.method.clone(),
            k: shared.model.k(),
            n_train: m.n,
            rank: m.rank,
            input_dim: shared.model.input_dim(),
            path: entry.path.clone(),
            stats: shared.snapshot(),
            queue_depth: shared.queue.depth(),
            generation: shared.model.generation(),
        }
    }

    /// One model's [`ModelInfo`] (one map lookup — the
    /// `GET /models/{name}` path; [`list`](ModelRegistry::list) would
    /// snapshot every model's counters just to keep one).
    pub fn info(&self, name: &str) -> Option<ModelInfo> {
        let inner = self.inner.read().expect("registry lock poisoned");
        let entry = inner.models.get(name)?;
        Some(Self::make_info(inner.default.as_deref(), name, entry))
    }

    /// One [`ModelInfo`] per registered model, ascending by name — the
    /// `GET /models` listing.
    pub fn list(&self) -> Vec<ModelInfo> {
        let inner = self.inner.read().expect("registry lock poisoned");
        inner
            .models
            .iter()
            .map(|(name, entry)| Self::make_info(inner.default.as_deref(), name, entry))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::KernelClusterer;
    use crate::data;
    use crate::rng::Pcg64;

    fn fit(seed: u64, n: usize) -> FittedModel {
        let ds = data::cross_lines(&mut Pcg64::seed(seed), n);
        KernelClusterer::new(2).oversample(8).seed(seed).fit(&ds.x).unwrap()
    }

    #[test]
    fn name_validation() {
        for ok in ["m", "rings", "model-1.v2_final", "A9"] {
            assert!(valid_name(ok), "{ok}");
        }
        for bad in ["", "a/b", "a b", "ü", "a\nb", &"x".repeat(129)] {
            assert!(!valid_name(bad), "{bad:?}");
        }
    }

    #[test]
    fn first_insert_is_default_and_unload_promotes() {
        let reg = ModelRegistry::new(ServeOpts::default());
        assert!(reg.is_empty());
        assert!(reg.default_model().is_none());
        reg.insert("zeta", fit(1, 96)).unwrap();
        reg.insert("alpha", fit(2, 96)).unwrap();
        assert_eq!(reg.names(), vec!["alpha".to_string(), "zeta".to_string()]);
        // first registered stays default even though "alpha" sorts first
        assert_eq!(reg.default_model().unwrap().0, "zeta");
        assert!(reg.unload("zeta"));
        assert_eq!(reg.default_model().unwrap().0, "alpha");
        assert!(!reg.unload("zeta"), "double unload reports absence");
        assert!(reg.unload("alpha"));
        assert!(reg.default_model().is_none());
    }

    #[test]
    fn models_serve_independently_and_bit_identically() {
        let m1 = fit(11, 128);
        let m2 = fit(22, 128);
        let query = data::cross_lines(&mut Pcg64::seed(33), 17).x;
        let want1 = m1.predict(&query).unwrap();
        let want2 = m2.predict(&query).unwrap();

        let reg = ModelRegistry::new(ServeOpts::default());
        reg.insert("one", m1).unwrap();
        reg.insert("two", m2).unwrap();
        let h1 = reg.get("one").unwrap();
        let h2 = reg.get("two").unwrap();
        assert_eq!(h1.predict(query.clone()).unwrap(), want1);
        assert_eq!(h2.predict(query.clone()).unwrap(), want2);
        assert!(reg.get("three").is_none());

        // per-model stats stay separate
        let infos = reg.list();
        assert_eq!(infos.len(), 2);
        for info in &infos {
            assert_eq!(info.stats.requests, 1, "{}", info.name);
            assert_eq!(info.method, "one_pass", "{}", info.name);
        }

        // unloaded models reject politely; the survivor keeps serving
        assert!(reg.unload("one"));
        let err = h1.predict(query.clone()).unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
        assert_eq!(h2.predict(query).unwrap(), want2);
    }

    #[test]
    fn insert_replaces_and_rejects_bad_names() {
        let reg = ModelRegistry::new(ServeOpts::default());
        let query = data::cross_lines(&mut Pcg64::seed(44), 9).x;
        let m_old = fit(5, 96);
        let m_new = fit(6, 96);
        let want_new = m_new.predict(&query).unwrap();
        reg.insert("m", m_old).unwrap();
        reg.insert("m", m_new).unwrap();
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.get("m").unwrap().predict(query).unwrap(), want_new);
        assert!(reg.insert("bad/name", fit(7, 96)).is_err());
    }

    #[test]
    fn publish_assigns_monotone_generations() {
        let reg = ModelRegistry::new(ServeOpts::default());
        assert_eq!(reg.publish("live", fit(1, 96)).unwrap(), 1);
        assert_eq!(reg.info("live").unwrap().generation, 1);
        assert_eq!(reg.publish("live", fit(2, 96)).unwrap(), 2);
        assert_eq!(reg.info("live").unwrap().generation, 2);
        // the counter survives unload: a re-published name never repeats
        assert!(reg.unload("live"));
        assert_eq!(reg.publish("live", fit(3, 96)).unwrap(), 3);
        // other names count independently; plain inserts stay generation 0
        assert_eq!(reg.publish("other", fit(4, 96)).unwrap(), 1);
        reg.insert("batch", fit(5, 96)).unwrap();
        assert_eq!(reg.info("batch").unwrap().generation, 0);
    }

    #[test]
    fn load_dir_quarantines_corrupt_files_and_serves_the_rest() {
        let _g = crate::fault::test_guard(); // saves cross a failpoint site
        let dir = std::env::temp_dir().join(format!("rkc_reg_quar_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let dir_str = dir.to_str().unwrap().to_string();
        fit(9, 96).save(&format!("{dir_str}/good.rkc")).unwrap();
        std::fs::write(format!("{dir_str}/garbage.rkc"), b"not a model at all").unwrap();
        let mut truncated = crate::model_io::model_to_bytes(&fit(10, 96));
        truncated.truncate(truncated.len() / 2);
        std::fs::write(format!("{dir_str}/torn.rkc"), &truncated).unwrap();

        let reg = ModelRegistry::new(ServeOpts::default());
        let names = reg.load_dir(&dir_str).unwrap();
        assert_eq!(names, vec!["good".to_string()], "only the intact model loads");
        assert!(reg.get("good").is_some());
        let quarantined = reg.quarantined();
        let q_names: Vec<&str> = quarantined.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(q_names, vec!["garbage", "torn"]);
        for (_, reason) in &quarantined {
            assert!(reason.contains(".rkc"), "reason names the file: {reason}");
        }
        // a later successful load under a quarantined name clears it
        reg.load("garbage", &format!("{dir_str}/good.rkc")).unwrap();
        assert_eq!(reg.quarantined().len(), 1);

        // a directory where nothing loads is still a startup error
        let all_bad = ModelRegistry::new(ServeOpts::default());
        std::fs::remove_file(format!("{dir_str}/good.rkc")).unwrap();
        let err = all_bad.load_dir(&dir_str).unwrap_err();
        assert!(err.to_string().contains("all 2 quarantined"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn load_retries_transient_faults_and_keeps_previous_model_on_failure() {
        let _g = crate::fault::test_guard();
        let dir = std::env::temp_dir().join(format!("rkc_reg_retry_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = format!("{}/m.rkc", dir.to_str().unwrap());
        let old = fit(11, 96);
        let query = data::cross_lines(&mut Pcg64::seed(55), 8).x;
        let want_old = old.predict(&query).unwrap();
        old.save(&path).unwrap();

        let reg = ModelRegistry::new(ServeOpts::default());
        reg.load("m", &path).unwrap();

        // a fault firing on every attempt exhausts the retry budget …
        crate::fault::configure("serve.load=io_error:1.0").unwrap();
        let err = reg.load("m", &path).unwrap_err();
        assert!(err.is_transient(), "{err}");
        crate::fault::clear();
        // … and the previous generation kept serving throughout
        assert_eq!(reg.get("m").unwrap().predict(query.clone()).unwrap(), want_old);

        // the deterministic per-site stream with p=0.5 recovers within
        // the backoff budget: the first spec draw that passes lets the
        // load through (seeded stream ⇒ reproducible, no flakiness)
        crate::fault::configure("serve.load=io_error:0.5").unwrap();
        let mut recovered = false;
        for _ in 0..8 {
            if reg.load("m", &path).is_ok() {
                recovered = true;
                break;
            }
        }
        crate::fault::clear();
        assert!(recovered, "p=0.5 must let a retried load through within 8 calls");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn load_dir_requires_models() {
        let reg = ModelRegistry::new(ServeOpts::default());
        let dir = std::env::temp_dir().join(format!("rkc_reg_empty_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let err = reg.load_dir(dir.to_str().unwrap()).unwrap_err();
        assert!(err.to_string().contains("no .rkc models"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
        assert!(reg.load_dir("/nonexistent/rkc-models").is_err());
    }
}
