//! `rkc::serve` — a zero-dependency batched serving runtime for fitted
//! kernel-clustering models.
//!
//! The paper's output is a compact served object: column map + rank-r
//! embedding + centroids instead of the O(n²) kernel matrix. This module
//! keeps that object resident and answers `embed`/`predict` queries
//! against it:
//!
//! - [`ModelServer`] owns a loaded [`FittedModel`] and **micro-batches**
//!   concurrent requests: callers enqueue into a bounded queue (blocking
//!   when full — the same backpressure pattern as the sharded sketch
//!   pass) and a batch worker drains up to `max_batch` requests at a
//!   time, fanning them out over the shared fork-join pool
//!   ([`crate::util::parallel`]).
//! - [`ModelRegistry`] holds **many named models** at once, each with
//!   its own `ModelServer` (independent queue, batcher, stats), with
//!   runtime load/unload and a default-model alias for the legacy
//!   single-model routes.
//! - [`serve_http_registry`] puts an HTTP/1.1 **keep-alive** front-end
//!   (plain `std::net`, JSON in/out, bounded connection queue drained by
//!   a fixed worker pool) on top; [`serve_http`] is the single-model
//!   convenience wrapper.
//!
//! Requests are processed *independently* (one model call per request,
//! never concatenated), so a served answer is bit-identical to calling
//! [`FittedModel::predict`] directly — batching changes latency and
//! throughput, never results. Combined with the bit-exact `.rkc`
//! persistence ([`crate::model_io`]): fit → save → load → serve returns
//! exactly the predictions of the original in-memory model.
//!
//! # Example
//!
//! ```
//! use rkc::api::KernelClusterer;
//! use rkc::serve::{ModelServer, ServeOpts};
//! use rkc::data;
//! use rkc::rng::Pcg64;
//!
//! let ds = data::cross_lines(&mut Pcg64::seed(2), 128);
//! let model = KernelClusterer::new(2).oversample(8).fit(&ds.x)?;
//! let direct = model.predict(&ds.x)?;
//!
//! let server = ModelServer::new(model, ServeOpts::default())?;
//! let handle = server.handle(); // Clone one per client thread
//! assert_eq!(handle.predict(ds.x.clone())?, direct);
//! assert!(server.stats().requests >= 1);
//! server.shutdown();
//! # Ok::<(), rkc::error::RkcError>(())
//! ```

mod batcher;
mod http;
mod registry;

pub use http::{serve_http, serve_http_registry, FrontendStats, HttpOpts, HttpServer};
pub use registry::{ModelInfo, ModelRegistry};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::api::FittedModel;
use crate::config::Precision;
use crate::error::{Result, RkcError};
use crate::linalg::Mat;
use crate::obs;
use crate::util::parallel;

use batcher::Batcher;

/// Tuning knobs for a [`ModelServer`].
#[derive(Clone, Copy, Debug)]
pub struct ServeOpts {
    /// Bounded queue capacity; producers block (backpressure) when the
    /// queue holds this many pending requests.
    pub queue_cap: usize,
    /// Most requests drained into one micro-batch.
    pub max_batch: usize,
    /// Worker threads a batch fans out over (`0` = auto-detect, the
    /// crate-wide convention).
    pub threads: usize,
    /// Serving-precision override stamped onto every model this server
    /// (or a registry built from these opts) hosts: `None` keeps each
    /// model's own persisted [`Precision`]; `Some(p)` forces `p`
    /// process-wide (`rkc serve --precision f32`).
    pub precision: Option<Precision>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts { queue_cap: 64, max_batch: 16, threads: 0, precision: None }
    }
}

/// What a queued request asks of the model.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Op {
    Predict,
    Embed,
}

/// A successful reply.
pub(crate) enum Reply {
    Labels(Vec<usize>),
    Points(Mat),
}

/// One queued request: the operation, its query points (p × m, columns
/// are samples), the reply channel, and the enqueue timestamp for the
/// latency counters.
pub(crate) struct Request {
    op: Op,
    points: Mat,
    reply: mpsc::Sender<Result<Reply>>,
    enqueued: Instant,
}

/// Registry-backed observability handles for one served model name.
/// Fetched once at server creation; the worker then records through the
/// `Arc`s lock-free. Servers that re-publish under the same name share
/// the same series, so `/metrics` counters stay cumulative across
/// generations (Prometheus counter semantics).
struct ServeObs {
    requests: Arc<obs::Counter>,
    points: Arc<obs::Counter>,
    errors: Arc<obs::Counter>,
    batches: Arc<obs::Counter>,
    /// enqueue→reply latency (seconds), `rkc_serve_request_seconds`
    latency: Arc<obs::Histogram>,
    /// requests drained per micro-batch, `rkc_serve_batch_size`
    batch_size: Arc<obs::Histogram>,
}

impl ServeObs {
    fn for_model(name: &str) -> ServeObs {
        let r = obs::registry();
        let labels: &[(&str, &str)] = &[("model", name)];
        ServeObs {
            requests: r.counter(
                "rkc_serve_requests_total",
                "Model calls answered by the batch worker (including per-request errors).",
                labels,
            ),
            points: r.counter(
                "rkc_serve_points_total",
                "Query points across all answered requests.",
                labels,
            ),
            errors: r.counter(
                "rkc_serve_errors_total",
                "Requests answered with a per-request error.",
                labels,
            ),
            batches: r.counter(
                "rkc_serve_batches_total",
                "Micro-batches executed by the batch worker.",
                labels,
            ),
            latency: r.histogram(
                "rkc_serve_request_seconds",
                "Enqueue-to-reply latency of served requests.",
                labels,
                obs::latency_buckets(),
            ),
            batch_size: r.histogram(
                "rkc_serve_batch_size",
                "Requests drained per micro-batch.",
                labels,
                obs::size_buckets(),
            ),
        }
    }
}

/// Monotonic serving counters (all atomics; written by the batch worker
/// and the HTTP front-end, snapshotted by [`ModelServer::stats`]).
#[derive(Default)]
struct Counters {
    requests: AtomicU64,
    points: AtomicU64,
    batches: AtomicU64,
    errors: AtomicU64,
    latency_us_total: AtomicU64,
    http_requests: AtomicU64,
    http_failures: AtomicU64,
}

/// A point-in-time snapshot of a server's throughput/latency counters.
#[derive(Clone, Copy, Debug)]
pub struct ServeStats {
    /// model calls answered (including per-request errors)
    pub requests: u64,
    /// total query points across all answered requests
    pub points: u64,
    /// micro-batches executed
    pub batches: u64,
    /// requests that returned a per-request error
    pub errors: u64,
    /// cumulative enqueue→reply latency, microseconds
    pub latency_us_total: u64,
    /// HTTP requests **routed to this model** by the front-end (0
    /// without a front-end; front-end-wide traffic including 404s and
    /// shed 503s is counted separately in [`FrontendStats`])
    pub http_requests: u64,
    /// routed HTTP requests answered with a non-2xx status
    pub http_failures: u64,
    /// deepest this model's request queue has ever been — how close its
    /// clients have come to blocking on backpressure
    pub queue_highwater: u64,
    /// seconds since the server started
    pub uptime_s: f64,
}

impl ServeStats {
    /// Mean enqueue→reply latency in microseconds (0 when idle).
    pub fn mean_latency_us(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.latency_us_total as f64 / self.requests as f64
        }
    }

    /// Mean requests per micro-batch (the batching efficiency signal).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

struct Shared {
    model: FittedModel,
    queue: Batcher,
    counters: Counters,
    obs: ServeObs,
    threads: usize,
    max_batch: usize,
    started: Instant,
}

/// Owns a loaded model and the micro-batching worker. Create with
/// [`new`](ModelServer::new), hand [`handle`](ModelServer::handle)s to
/// client threads (or [`serve_http`]), and
/// [`shutdown`](ModelServer::shutdown) when done (dropping shuts down
/// too).
pub struct ModelServer {
    shared: Arc<Shared>,
    worker: Option<JoinHandle<()>>,
}

impl ModelServer {
    /// Start serving `model` with the given options. Spawns the batch
    /// worker thread immediately; a failed spawn (thread exhaustion) is
    /// a typed error, per the crate-wide contract. Metrics are recorded
    /// under `model="default"` — the label is fixed at construction, so
    /// use [`named`](ModelServer::named) for a server that will be
    /// registered (or served) under any other name. The registry's own
    /// load paths do this; `ModelRegistry::register` cannot relabel a
    /// caller-built server after the fact.
    pub fn new(model: FittedModel, opts: ServeOpts) -> Result<Self> {
        Self::named("default", model, opts)
    }

    /// [`new`](ModelServer::new), with the registry metric series for
    /// this server labeled `model="name"`.
    pub fn named(name: &str, mut model: FittedModel, opts: ServeOpts) -> Result<Self> {
        if let Some(p) = opts.precision {
            model.set_precision(p);
        }
        let shared = Arc::new(Shared {
            model,
            queue: Batcher::new(opts.queue_cap.max(1)),
            counters: Counters::default(),
            obs: ServeObs::for_model(name),
            threads: parallel::resolve_threads(opts.threads).max(1),
            max_batch: opts.max_batch.max(1),
            started: Instant::now(),
        });
        let worker_shared = Arc::clone(&shared);
        let worker = std::thread::Builder::new()
            .name("rkc-serve-batcher".into())
            .spawn(move || {
                // normal exit or panic alike: close the queue and drop
                // whatever is still enqueued, so producers get a typed
                // rejection and waiting clients see their reply channel
                // hang up — never an eternal block on a dead worker
                let _close = CloseOnExit(&worker_shared.queue);
                worker_loop(&worker_shared);
            })
            .map_err(|e| RkcError::io("spawning the serve batch worker".to_string(), e))?;
        Ok(ModelServer { shared, worker: Some(worker) })
    }

    /// A cloneable client handle; each concurrent submitter should hold
    /// its own.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { shared: Arc::clone(&self.shared) }
    }

    /// The served model.
    pub fn model(&self) -> &FittedModel {
        &self.shared.model
    }

    /// Snapshot the latency/throughput counters.
    pub fn stats(&self) -> ServeStats {
        self.shared.snapshot()
    }

    /// Current queue depth (pending, not yet batched).
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.depth()
    }

    /// Stop accepting requests, drain the queue, and join the worker.
    pub fn shutdown(self) {
        drop(self);
    }
}

impl Drop for ModelServer {
    fn drop(&mut self) {
        self.shared.queue.close();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

/// A client of a [`ModelServer`]: submits one request at a time and
/// blocks for its reply (micro-batching happens behind the queue).
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<Shared>,
}

impl ServerHandle {
    /// Assign each column of `points` (p × m) to a trained cluster.
    /// Bit-identical to [`FittedModel::predict`] on the same points.
    pub fn predict(&self, points: Mat) -> Result<Vec<usize>> {
        match self.call(Op::Predict, points)? {
            Reply::Labels(l) => Ok(l),
            Reply::Points(_) => unreachable!("predict never yields points"),
        }
    }

    /// Embed each column of `points` into the trained space (r × m).
    /// Bit-identical to [`FittedModel::embed`] on the same points.
    pub fn embed(&self, points: Mat) -> Result<Mat> {
        match self.call(Op::Embed, points)? {
            Reply::Points(y) => Ok(y),
            Reply::Labels(_) => unreachable!("embed never yields labels"),
        }
    }

    /// Snapshot the served model's latency/throughput counters — same
    /// numbers as [`ModelServer::stats`], reachable from a handle alone
    /// (what [`ModelRegistry`] lists per model).
    pub fn stats(&self) -> ServeStats {
        self.shared.snapshot()
    }

    fn call(&self, op: Op, points: Mat) -> Result<Reply> {
        let (tx, rx) = mpsc::channel();
        self.shared.queue.push(Request { op, points, reply: tx, enqueued: Instant::now() })?;
        rx.recv()
            .map_err(|_| RkcError::backend("serving worker terminated before replying"))?
    }
}

impl Shared {
    /// Snapshot every counter in one pass, back to back, before any
    /// derived work — the tightest coherence the independent relaxed
    /// atomics allow. Fields may still race pairwise: a request
    /// delivered mid-snapshot can appear in `requests` but not yet in
    /// `points`/`latency_us_total` (or vice versa, load order above),
    /// and `queue_highwater` is read after the counters. The worker
    /// bumps `batches` *before* delivering replies, so `batches` never
    /// reads 0 while `requests` is nonzero — the one cross-field
    /// ordering clients rely on ([`ServeStats::mean_batch`]).
    fn snapshot(&self) -> ServeStats {
        let c = &self.counters;
        let requests = c.requests.load(Ordering::Relaxed);
        let points = c.points.load(Ordering::Relaxed);
        let batches = c.batches.load(Ordering::Relaxed);
        let errors = c.errors.load(Ordering::Relaxed);
        let latency_us_total = c.latency_us_total.load(Ordering::Relaxed);
        let http_requests = c.http_requests.load(Ordering::Relaxed);
        let http_failures = c.http_failures.load(Ordering::Relaxed);
        ServeStats {
            requests,
            points,
            batches,
            errors,
            latency_us_total,
            http_requests,
            http_failures,
            queue_highwater: self.queue.highwater() as u64,
            uptime_s: self.started.elapsed().as_secs_f64(),
        }
    }
}

/// Closes (and drains) the queue when dropped — runs on the worker
/// thread's normal exit and on unwind, so a panicking model call can
/// never leave producers blocked on a full queue or clients blocked on
/// a reply that will never come.
struct CloseOnExit<'a>(&'a Batcher);

impl Drop for CloseOnExit<'_> {
    fn drop(&mut self) {
        self.0.close();
        // dropping the leftover requests drops their reply senders,
        // which errors out any client still waiting in recv()
        while self.0.next_batch(usize::MAX).is_some() {}
    }
}

/// Drain → fan out → deliver, until the queue closes. Each request is an
/// independent model call (results never depend on batching); the fan-out
/// rides [`parallel::map_indexed`], which returns results in request
/// order.
fn worker_loop(shared: &Shared) {
    while let Some(batch) = shared.queue.next_batch(shared.max_batch) {
        // count the batch up front: a client unblocked by its reply may
        // snapshot the stats before this loop iteration finishes, and
        // must never observe completed requests with zero batches
        shared.counters.batches.fetch_add(1, Ordering::Relaxed);
        shared.obs.batches.inc();
        shared.obs.batch_size.observe(batch.len() as f64);
        // split the (!Sync) reply senders from the Sync compute inputs
        // before fanning out
        let mut jobs: Vec<(Op, Mat, Instant)> = Vec::with_capacity(batch.len());
        let mut replies: Vec<mpsc::Sender<Result<Reply>>> = Vec::with_capacity(batch.len());
        for req in batch {
            jobs.push((req.op, req.points, req.enqueued));
            replies.push(req.reply);
        }
        let model = &shared.model;
        let results = parallel::map_indexed(jobs.len(), shared.threads, |i| {
            let (op, points, _) = &jobs[i];
            match op {
                Op::Predict => model.predict(points).map(Reply::Labels),
                Op::Embed => model.embed(points).map(Reply::Points),
            }
        });
        let delivered = Instant::now();
        let c = &shared.counters;
        for (((_, points, enqueued), reply), result) in
            jobs.into_iter().zip(replies).zip(results)
        {
            c.requests.fetch_add(1, Ordering::Relaxed);
            c.points.fetch_add(points.cols() as u64, Ordering::Relaxed);
            shared.obs.requests.inc();
            shared.obs.points.add(points.cols() as u64);
            if result.is_err() {
                c.errors.fetch_add(1, Ordering::Relaxed);
                shared.obs.errors.inc();
            }
            let wait = delivered.duration_since(enqueued);
            let us = wait.as_micros().min(u64::MAX as u128);
            c.latency_us_total.fetch_add(us as u64, Ordering::Relaxed);
            shared.obs.latency.observe(wait.as_secs_f64());
            // a vanished caller is not an error; drop the reply
            let _ = reply.send(result);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::KernelClusterer;
    use crate::data;
    use crate::rng::Pcg64;

    fn small_model() -> FittedModel {
        let ds = data::cross_lines(&mut Pcg64::seed(51), 96);
        KernelClusterer::new(2).oversample(8).seed(9).fit(&ds.x).unwrap()
    }

    #[test]
    fn served_predictions_match_direct_calls() {
        let model = small_model();
        let query = data::cross_lines(&mut Pcg64::seed(52), 33).x;
        let direct_labels = model.predict(&query).unwrap();
        let direct_embed = model.embed(&query).unwrap();
        let server = ModelServer::new(model, ServeOpts::default()).unwrap();
        let h = server.handle();
        assert_eq!(h.predict(query.clone()).unwrap(), direct_labels);
        assert_eq!(h.embed(query).unwrap().data(), direct_embed.data());
        let stats = server.stats();
        assert_eq!(stats.requests, 2);
        assert_eq!(stats.points, 66);
        assert!(stats.batches >= 1 && stats.batches <= 2);
        assert_eq!(stats.errors, 0);
        server.shutdown();
    }

    #[test]
    fn concurrent_clients_micro_batch_and_agree() {
        let model = small_model();
        let query = data::cross_lines(&mut Pcg64::seed(53), 17).x;
        let want = model.predict(&query).unwrap();
        let server =
            ModelServer::new(model, ServeOpts { max_batch: 8, ..Default::default() }).unwrap();
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..6)
                .map(|_| {
                    let h = server.handle();
                    let q = query.clone();
                    s.spawn(move || h.predict(q).unwrap())
                })
                .collect();
            for t in handles {
                assert_eq!(t.join().unwrap(), want);
            }
        });
        let stats = server.stats();
        assert_eq!(stats.requests, 6);
        assert!(stats.mean_batch() >= 1.0);
        assert!(stats.mean_latency_us() > 0.0);
    }

    #[test]
    fn per_request_errors_are_typed_not_fatal() {
        let model = small_model();
        let query = data::cross_lines(&mut Pcg64::seed(54), 5).x;
        let want = model.predict(&query).unwrap();
        let server = ModelServer::new(model, ServeOpts::default()).unwrap();
        let h = server.handle();
        // wrong input dimension: this request fails, the server survives
        let wrong = crate::linalg::Mat::zeros(7, 3);
        assert!(h.predict(wrong).is_err());
        assert_eq!(h.predict(query).unwrap(), want);
        let stats = server.stats();
        assert_eq!(stats.errors, 1);
        assert_eq!(stats.requests, 2);
    }

    #[test]
    fn shutdown_rejects_new_requests_with_a_typed_error() {
        let model = small_model();
        let server = ModelServer::new(model, ServeOpts::default()).unwrap();
        let h = server.handle();
        server.shutdown();
        let query = data::cross_lines(&mut Pcg64::seed(55), 3).x;
        let err = h.predict(query).unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
    }
}
