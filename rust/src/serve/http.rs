//! Zero-dependency HTTP/1.1 front-end over a [`ModelServer`] —
//! `std::net` only, JSON in/out, one short-lived thread per connection
//! (`Connection: close`).
//!
//! # Protocol
//!
//! | endpoint        | request body                          | 200 response              |
//! |-----------------|---------------------------------------|---------------------------|
//! | `POST /predict` | `{"points": [[x, y, …], …]}`          | `{"labels": [0, 1, …]}`   |
//! | `POST /embed`   | `{"points": [[x, y, …], …]}`          | `{"embedding": [[…], …]}` |
//! | `GET /healthz`  | —                                     | status + serving counters |
//!
//! Each inner `points` array is one query point (its length must match
//! the model's input dimension); `embedding` returns one r-vector per
//! point, with any non-finite coordinate (a degenerate query can
//! overflow the kernel) downgraded to `null` so the body stays valid
//! JSON. Malformed JSON, wrong shapes, and unsupported model
//! operations answer **4xx with an `{"error": …}` body** — the server
//! never crashes on bad input. Backend failures answer 5xx.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::error::RkcError;
use crate::linalg::Mat;
use crate::util::Json;

use super::{ModelServer, ServerHandle};

/// request-head cap (request line + headers)
const MAX_HEAD: usize = 16 * 1024;
/// request-body cap. Sized for generous predict batches (a 1 MiB JSON
/// body is ~6k points in 8 dimensions), not for arbitrary uploads: the
/// body, its parsed JSON tree (~16-32× larger for bodies of tiny
/// numbers), and the query matrix all live on the per-connection thread
/// *before* the bounded queue's backpressure applies. The aggregate
/// worst case — [`MAX_CONNECTIONS`] × this cap × the tree amplification
/// (64 × 1 MiB × ~32 ≈ 2 GiB) — is what this number actually bounds;
/// raise it only together with that arithmetic.
const MAX_BODY: usize = 1024 * 1024;
/// total wall-clock budget for reading one request — the per-read
/// timeout alone would let a slow-loris client dribble bytes and pin a
/// connection thread indefinitely
const REQUEST_DEADLINE: Duration = Duration::from_secs(30);
/// concurrent connection-thread cap: each connection buffers its body,
/// JSON tree, and query matrix *before* the bounded queue's
/// backpressure applies, so aggregate pre-queue memory must be bounded
/// too; excess connections get an immediate 503
const MAX_CONNECTIONS: usize = 64;
/// total wall-clock budget for writing one response — the write-side
/// mirror of [`REQUEST_DEADLINE`]: a client draining its receive window
/// one byte at a time must not pin a connection thread (and a multi-MB
/// response buffer) past this
const RESPONSE_DEADLINE: Duration = Duration::from_secs(30);

/// A running HTTP front-end. Dropping (or
/// [`shutdown`](HttpServer::shutdown)) stops the accept loop;
/// [`wait`](HttpServer::wait) blocks until shutdown — the CLI's serve
/// loop.
pub struct HttpServer {
    local: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

/// Bind `addr` (e.g. `"127.0.0.1:7878"`; port 0 picks a free port) and
/// serve `server`'s model over HTTP until shutdown. Returns immediately;
/// the accept loop runs on its own thread and each connection is handled
/// on a short-lived worker thread feeding the server's micro-batch
/// queue.
pub fn serve_http(server: &ModelServer, addr: &str) -> crate::error::Result<HttpServer> {
    let listener =
        TcpListener::bind(addr).map_err(|e| RkcError::io(format!("binding {addr}"), e))?;
    let local = listener
        .local_addr()
        .map_err(|e| RkcError::io(format!("resolving local address of {addr}"), e))?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop_flag = Arc::clone(&stop);
    let handle = server.handle();
    let accept = std::thread::Builder::new()
        .name("rkc-serve-http".into())
        .spawn(move || {
            let active = Arc::new(AtomicUsize::new(0));
            for conn in listener.incoming() {
                if stop_flag.load(Ordering::SeqCst) {
                    break;
                }
                let mut stream = match conn {
                    Ok(s) => s,
                    // fd exhaustion etc. — back off instead of spinning
                    Err(_) => {
                        std::thread::sleep(Duration::from_millis(10));
                        continue;
                    }
                };
                // shed load once the connection-thread cap is reached
                // (check-then-add may overshoot by a race; the cap is a
                // resource bound, not an exact count)
                if active.load(Ordering::Relaxed) >= MAX_CONNECTIONS {
                    // overload is exactly when operators watch the
                    // counters — shed responses must show up in them
                    handle.shared.counters.http_requests.fetch_add(1, Ordering::Relaxed);
                    handle.shared.counters.http_failures.fetch_add(1, Ordering::Relaxed);
                    // write the (tiny) 503 off-thread so a hostile peer
                    // can never stall the accept loop; if even that
                    // spawn fails, dropping the connection sheds harder
                    let _ = std::thread::Builder::new()
                        .name("rkc-serve-shed".into())
                        .spawn(move || {
                            let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
                            write_response(
                                &mut stream,
                                503,
                                &error_json("too many concurrent connections"),
                            );
                        });
                    continue;
                }
                active.fetch_add(1, Ordering::Relaxed);
                let h = handle.clone();
                let slot = Arc::clone(&active);
                // a failed spawn (thread exhaustion) sheds this one
                // connection — the closure (and stream) drop — instead
                // of panicking the accept loop
                let spawned = std::thread::Builder::new()
                    .name("rkc-serve-conn".into())
                    .spawn(move || {
                        // release the slot on normal return and unwind
                        struct Slot(Arc<AtomicUsize>);
                        impl Drop for Slot {
                            fn drop(&mut self) {
                                self.0.fetch_sub(1, Ordering::Relaxed);
                            }
                        }
                        let _slot = Slot(slot);
                        handle_conn(stream, &h);
                    });
                if spawned.is_err() {
                    active.fetch_sub(1, Ordering::Relaxed);
                }
            }
        })
        .map_err(|e| RkcError::io("spawning the http accept thread".to_string(), e))?;
    Ok(HttpServer { local, stop, accept: Some(accept) })
}

impl HttpServer {
    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Stop accepting connections and join the accept thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    /// Block until the server shuts down (never, unless another owner of
    /// the process stops it) — the CLI `rkc serve` foreground loop.
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    fn stop_and_join(&mut self) {
        if self.accept.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        // the accept loop is blocked in accept(2); poke it awake. A
        // wildcard bind (0.0.0.0 / ::) is not connectable everywhere —
        // aim the wake-up at the loopback of the same family instead.
        let wake = if self.local.ip().is_unspecified() {
            let loopback: IpAddr = match self.local.ip() {
                IpAddr::V4(_) => Ipv4Addr::LOCALHOST.into(),
                IpAddr::V6(_) => Ipv6Addr::LOCALHOST.into(),
            };
            SocketAddr::new(loopback, self.local.port())
        } else {
            self.local
        };
        match TcpStream::connect_timeout(&wake, Duration::from_secs(1)) {
            Ok(_) => {
                if let Some(h) = self.accept.take() {
                    let _ = h.join();
                }
            }
            // the wake-up could not reach the listener (self-connect
            // firewalled?): detach the accept thread instead of hanging
            // the caller in join(); it exits with the process
            Err(_) => {
                self.accept.take();
            }
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

struct HttpRequest {
    method: String,
    path: String,
    body: Vec<u8>,
}

fn handle_conn(mut stream: TcpStream, handle: &ServerHandle) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    // symmetric defense: a client that never reads its response must
    // not pin this thread (and the response buffer) forever
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    let counters = &handle.shared.counters;
    let (status, body) = match read_request(&mut stream) {
        Ok(req) => {
            counters.http_requests.fetch_add(1, Ordering::Relaxed);
            route(handle, &req)
        }
        // a connection that closed without sending a single byte is
        // port-scan / LB-probe noise: no response, no counter traffic
        Err((0, _)) => return,
        // anything that DID send bytes and failed (413, 431, 408, bad
        // head) is real rejected traffic operators must see
        Err((status, msg)) => {
            counters.http_requests.fetch_add(1, Ordering::Relaxed);
            (status, error_json(&msg))
        }
    };
    if status >= 400 {
        counters.http_failures.fetch_add(1, Ordering::Relaxed);
    }
    write_response(&mut stream, status, &body);
    // half-close, then briefly drain whatever request bytes are still in
    // flight (e.g. the body behind a 413 written straight after the
    // head): closing with unread data makes the kernel RST the
    // connection, which can destroy the queued response before the
    // client reads it
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let mut sink = [0u8; 8192];
    let drain_started = std::time::Instant::now();
    while drain_started.elapsed() < Duration::from_secs(2)
        && matches!(stream.read(&mut sink), Ok(n) if n > 0)
    {}
}

fn route(handle: &ServerHandle, req: &HttpRequest) -> (u16, String) {
    match (req.method.as_str(), req.path.as_str()) {
        // a closed queue (worker died / server shut down) must fail the
        // health probe — a 200 here would keep load balancers routing
        // traffic to a server that 503s every predict
        ("GET", "/healthz") => {
            let closed = handle.shared.queue.is_closed();
            (if closed { 503 } else { 200 }, health_json(handle, closed))
        }
        ("POST", "/predict") => match parse_points(&req.body) {
            Err(msg) => (400, error_json(&msg)),
            Ok(points) => match handle.predict(points) {
                Ok(labels) => {
                    let arr = labels.iter().map(|&l| Json::Num(l as f64)).collect();
                    (200, obj([("labels", Json::Arr(arr))]))
                }
                Err(e) => error_response(&e),
            },
        },
        ("POST", "/embed") => match parse_points(&req.body) {
            Err(msg) => (400, error_json(&msg)),
            Ok(points) => match handle.embed(points) {
                Ok(y) => {
                    // non-finite coordinates (a degenerate query can
                    // overflow the kernel) become null — JSON has no
                    // inf/NaN literals and the body must stay parseable
                    let cols: Vec<Json> = (0..y.cols())
                        .map(|j| {
                            Json::Arr(
                                (0..y.rows()).map(|i| Json::finite_num(y[(i, j)])).collect(),
                            )
                        })
                        .collect();
                    (200, obj([("embedding", Json::Arr(cols))]))
                }
                Err(e) => error_response(&e),
            },
        },
        (_, "/healthz") | (_, "/predict") | (_, "/embed") => {
            (405, error_json("method not allowed for this path"))
        }
        _ => (404, error_json("no such endpoint (try /healthz, /predict, /embed)")),
    }
}

/// Map a typed serving error onto an HTTP status: caller mistakes are
/// 4xx, backend unavailability is 503, anything else 500.
fn error_response(e: &RkcError) -> (u16, String) {
    let status = match e {
        RkcError::InvalidConfig(_) | RkcError::Parse { .. } | RkcError::Unsupported(_) => 400,
        RkcError::Backend(_) => 503,
        _ => 500,
    };
    (status, error_json(&e.to_string()))
}

fn health_json(handle: &ServerHandle, closed: bool) -> String {
    let shared = &handle.shared;
    let stats = shared.snapshot();
    let m = shared.model.metrics();
    let input_dim = match shared.model.input_dim() {
        Some(p) => Json::Num(p as f64),
        None => Json::Null,
    };
    let status = if closed { "shutdown" } else { "ok" };
    obj([
        ("status", Json::Str(status.into())),
        ("method", Json::Str(m.method.clone())),
        ("k", Json::Num(shared.model.k() as f64)),
        ("n_train", Json::Num(m.n as f64)),
        ("rank", Json::Num(m.rank as f64)),
        ("input_dim", input_dim),
        ("queue_depth", Json::Num(shared.queue.depth() as f64)),
        ("requests", Json::Num(stats.requests as f64)),
        ("points", Json::Num(stats.points as f64)),
        ("batches", Json::Num(stats.batches as f64)),
        ("errors", Json::Num(stats.errors as f64)),
        ("mean_batch", Json::Num(stats.mean_batch())),
        ("mean_latency_us", Json::Num(stats.mean_latency_us())),
        ("http_requests", Json::Num(stats.http_requests as f64)),
        ("http_failures", Json::Num(stats.http_failures as f64)),
        ("uptime_s", Json::Num(stats.uptime_s)),
    ])
}

/// Decode `{"points": [[…], …]}` into a p × m query matrix (columns are
/// samples). Every defect is a caller-facing message for a 400.
fn parse_points(body: &[u8]) -> Result<Mat, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let v = Json::parse(text).map_err(|e| format!("malformed JSON: {e}"))?;
    let pts = v
        .get("points")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing 'points': expected {\"points\": [[x, y, ...], ...]}".to_string())?;
    if pts.is_empty() {
        return Err("'points' must be non-empty".to_string());
    }
    let p = pts[0]
        .as_arr()
        .ok_or_else(|| "each point must be an array of numbers".to_string())?
        .len();
    // validate every point's shape BEFORE allocating: p comes from
    // attacker-controlled input, and p × m must be known body-bounded
    // (all points the same length) before Mat::zeros commits the memory
    for (j, point) in pts.iter().enumerate() {
        let coords = point
            .as_arr()
            .ok_or_else(|| "each point must be an array of numbers".to_string())?;
        if coords.len() != p {
            return Err(format!("point {j} has {} coordinates, expected {p}", coords.len()));
        }
    }
    let mut mat = Mat::zeros(p, pts.len());
    for (j, point) in pts.iter().enumerate() {
        let coords = point.as_arr().expect("shape validated above");
        for (i, val) in coords.iter().enumerate() {
            mat[(i, j)] = val
                .as_f64()
                .ok_or_else(|| format!("point {j} coordinate {i} is not a number"))?;
        }
    }
    Ok(mat)
}

fn obj<const N: usize>(fields: [(&str, Json); N]) -> String {
    let map: BTreeMap<String, Json> =
        fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
    Json::Obj(map).to_string()
}

fn error_json(msg: &str) -> String {
    obj([("error", Json::Str(msg.to_string()))])
}

fn write_response(stream: &mut TcpStream, status: u16, body: &str) {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let started = std::time::Instant::now();
    if write_all_deadline(stream, head.as_bytes(), started) {
        let _ = write_all_deadline(stream, body.as_bytes(), started);
    }
    let _ = stream.flush();
}

/// `write_all` with an aggregate [`RESPONSE_DEADLINE`]: the 10 s
/// per-write timeout alone would let a 1-byte-per-window reader keep a
/// multi-MB response alive indefinitely. Returns false when the write
/// was abandoned.
fn write_all_deadline(stream: &mut TcpStream, mut buf: &[u8], started: std::time::Instant) -> bool {
    while !buf.is_empty() {
        if started.elapsed() > RESPONSE_DEADLINE {
            return false;
        }
        match stream.write(&buf[..buf.len().min(64 * 1024)]) {
            Ok(0) | Err(_) => return false,
            Ok(n) => buf = &buf[n..],
        }
    }
    true
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Read one HTTP request (head + Content-Length body) off the stream.
/// Errors carry the status/message pair for the failure response.
fn read_request(stream: &mut TcpStream) -> Result<HttpRequest, (u16, String)> {
    let started = std::time::Instant::now();
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 2048];
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD {
            return Err((431, "request head too large".to_string()));
        }
        if started.elapsed() > REQUEST_DEADLINE {
            return Err((408, "request took too long to arrive".to_string()));
        }
        // status 0 = nothing ever arrived (close OR idle timeout): the
        // caller drops the connection silently — probe noise, not traffic
        let n = match stream.read(&mut chunk) {
            Ok(n) => n,
            Err(_) if buf.is_empty() => return Err((0, String::new())),
            Err(e) => return Err((400, format!("read error: {e}"))),
        };
        if n == 0 {
            if buf.is_empty() {
                return Err((0, String::new()));
            }
            return Err((400, "connection closed mid-request".to_string()));
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| (400, "request head is not UTF-8".to_string()))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| (400, "empty request line".to_string()))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| (400, "request line is missing a path".to_string()))?
        .to_string();
    let mut content_length = 0usize;
    let mut expects_continue = false;
    for line in lines {
        if let Some((key, value)) = line.split_once(':') {
            let key = key.trim();
            let value = value.trim();
            if key.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .parse()
                    .map_err(|_| (400, "unparseable content-length".to_string()))?;
            } else if key.eq_ignore_ascii_case("expect")
                && value.eq_ignore_ascii_case("100-continue")
            {
                expects_continue = true;
            } else if key.eq_ignore_ascii_case("transfer-encoding") {
                // we only speak Content-Length bodies; saying so beats a
                // misleading 400 after silently dropping a chunked body
                return Err((
                    501,
                    "transfer-encoding is not supported; send Content-Length".to_string(),
                ));
            }
        }
    }
    if content_length > MAX_BODY {
        return Err((413, format!("body of {content_length} bytes exceeds the limit")));
    }
    // curl (and friends) pause up to a second waiting for this interim
    // response before sending any body over 1 KiB
    if expects_continue && content_length > 0 {
        let _ = stream.write_all(b"HTTP/1.1 100 Continue\r\n\r\n");
    }
    let mut body = buf[head_end + 4..].to_vec();
    body.truncate(content_length);
    if body.len() < content_length {
        // 64 KiB reads (bodies run up to MAX_BODY) with the same overall
        // deadline as the head. Deliberately NOT reserving the declared
        // Content-Length up front: headers alone must never commit the
        // full MAX_BODY per connection — memory grows as bytes arrive
        body.reserve((content_length - body.len()).min(64 * 1024));
        let mut big = vec![0u8; 64 * 1024];
        while body.len() < content_length {
            if started.elapsed() > REQUEST_DEADLINE {
                return Err((408, "request body took too long to arrive".to_string()));
            }
            let want = big.len().min(content_length - body.len());
            let n = stream
                .read(&mut big[..want])
                .map_err(|e| (400, format!("read error: {e}")))?;
            if n == 0 {
                return Err((400, "connection closed mid-body".to_string()));
            }
            body.extend_from_slice(&big[..n]);
        }
    }
    Ok(HttpRequest { method, path, body })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_points_builds_column_major_queries() {
        let m = parse_points(br#"{"points": [[1.0, 2.0], [3.5, -4.0], [0, 1]]}"#).unwrap();
        assert_eq!((m.rows(), m.cols()), (2, 3));
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(1, 1)], -4.0);
        assert_eq!(m[(1, 2)], 1.0);
    }

    #[test]
    fn parse_points_rejects_malformed_bodies() {
        for bad in [
            &b"{not json"[..],
            &br#"{"pts": [[1]]}"#[..],
            &br#"{"points": []}"#[..],
            &br#"{"points": [1, 2]}"#[..],
            &br#"{"points": [[1, 2], [3]]}"#[..],
            &br#"{"points": [["a", "b"]]}"#[..],
            &b"\xff\xfe"[..],
        ] {
            assert!(parse_points(bad).is_err(), "{:?} should fail", String::from_utf8_lossy(bad));
        }
    }

    #[test]
    fn error_statuses_map_caller_vs_backend_faults() {
        assert_eq!(error_response(&RkcError::invalid_config("x")).0, 400);
        assert_eq!(error_response(&RkcError::unsupported("x")).0, 400);
        assert_eq!(error_response(&RkcError::backend("down")).0, 503);
        assert_eq!(error_response(&RkcError::dataset("x")).0, 500);
    }

    #[test]
    fn head_end_detection() {
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\n\r\nbody"), Some(16));
        assert_eq!(find_head_end(b"partial\r\n"), None);
    }
}
