//! Zero-dependency HTTP/1.1 **keep-alive** front-end over a
//! [`ModelRegistry`] — `std::net` only, JSON in/out, a bounded queue of
//! accepted connections drained by a fixed worker pool (the same
//! work-queue discipline as [`crate::util::parallel`] and the sharded
//! sketch pass: accept loop produces, workers consume, overflow sheds).
//!
//! # Protocol
//!
//! | endpoint                       | request body                 | 200 response                |
//! |--------------------------------|------------------------------|-----------------------------|
//! | `POST /models/{name}/predict`  | `{"points": [[x, y, …], …]}` | `{"labels": [0, 1, …]}`     |
//! | `POST /models/{name}/embed`    | `{"points": [[x, y, …], …]}` | `{"embedding": [[…], …]}`   |
//! | `GET /models`                  | —                            | per-model listing + stats   |
//! | `GET /models/{name}`           | —                            | one model's info + stats    |
//! | `PUT /models/{name}`           | `{"path": "model.rkc"}`      | load/replace at runtime     |
//! | `DELETE /models/{name}`        | —                            | unload at runtime           |
//! | `POST /predict`, `POST /embed` | `{"points": …}`              | alias for the default model |
//! | `GET /healthz`                 | —                            | status + serving counters   |
//! | `GET /metrics`                 | —                            | Prometheus text exposition  |
//!
//! Unknown model names answer **404 with an `{"error": …}` body**;
//! malformed JSON, wrong shapes, and unsupported model operations 4xx —
//! the server never crashes on bad input. Backend failures answer 5xx.
//!
//! # Connection lifecycle
//!
//! Connections are HTTP/1.1 persistent by default: each pool worker
//! loops `read request → dispatch → respond` on one connection until
//! the client sends `Connection: close`, goes idle past
//! [`HttpOpts::keep_alive`], or breaks framing (a framing error gets a
//! 4xx **and then the connection closes** — a poisoned byte stream
//! cannot be re-synchronized; the worker itself survives and picks up
//! the next connection). Each request individually keeps the slow-loris
//! wall-clock budget ([`REQUEST_DEADLINE`]) the close-per-request
//! front-end had. HTTP/1.0 clients default to close unless they ask for
//! keep-alive.

use std::collections::{BTreeMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::RkcError;
use crate::linalg::Mat;
use crate::obs;
use crate::util::{parallel, Json};

use super::registry::valid_name;
use super::{ModelRegistry, ModelServer, ServeOpts, ServerHandle};

/// request-head cap (request line + headers)
const MAX_HEAD: usize = 16 * 1024;
/// request-body cap. Sized for generous predict batches (a 1 MiB JSON
/// body is ~6k points in 8 dimensions), not for arbitrary uploads: the
/// body, its parsed JSON tree (~16-32× larger for bodies of tiny
/// numbers), and the query matrix all live on the pool worker *before*
/// the bounded model queue's backpressure applies. The aggregate worst
/// case — worker-pool size × this cap × the tree amplification — is
/// what this number actually bounds; raise it only together with that
/// arithmetic (and [`HttpOpts::workers`]).
const MAX_BODY: usize = 1024 * 1024;
/// total wall-clock budget for reading one request, counted from its
/// first byte — the idle keep-alive timeout alone would let a
/// slow-loris client dribble bytes and pin a pool worker indefinitely
const REQUEST_DEADLINE: Duration = Duration::from_secs(30);
/// total wall-clock budget for writing one response — the write-side
/// mirror of [`REQUEST_DEADLINE`]: a client draining its receive window
/// one byte at a time must not pin a pool worker (and a multi-MB
/// response buffer) past this
const RESPONSE_DEADLINE: Duration = Duration::from_secs(30);
/// how long a fresh connection gets to send its first request byte
/// (clients that just dialed are given more grace than an idle
/// keep-alive gap)
const FIRST_REQUEST_WINDOW: Duration = Duration::from_secs(10);
/// socket-level read poll tick: bounds how stale the stop flag and the
/// deadlines can get while a worker waits for bytes
const POLL_TICK: Duration = Duration::from_millis(500);

/// Front-end tuning knobs (the model-side knobs live in [`ServeOpts`]).
#[derive(Clone, Copy, Debug)]
pub struct HttpOpts {
    /// Pool workers serving connections (`0` = auto: hardware threads
    /// clamped to `[4, 32]`). Also the concurrent-connection cap — an
    /// idle keep-alive connection holds its worker until
    /// [`keep_alive`](HttpOpts::keep_alive) expires.
    pub workers: usize,
    /// Idle gap allowed *between* requests on a persistent connection
    /// before the server closes it. `Duration::ZERO` disables
    /// keep-alive entirely (every response carries `Connection: close`).
    pub keep_alive: Duration,
    /// Bounded queue of accepted-but-unclaimed connections; beyond this
    /// the accept loop sheds with an immediate 503.
    pub backlog: usize,
    /// Total wall-clock budget for reading one request, counted from
    /// its first byte (the slow-loris 408 deadline). `Duration::ZERO`
    /// means the default 30 s — so `..Default::default()` call sites
    /// keep their behavior, while load scenarios and fault tests can
    /// shrink it to trigger the 408 path in milliseconds.
    pub request_deadline: Duration,
}

impl Default for HttpOpts {
    fn default() -> Self {
        HttpOpts {
            workers: 0,
            keep_alive: Duration::from_secs(5),
            backlog: 128,
            request_deadline: Duration::ZERO,
        }
    }
}

impl HttpOpts {
    fn resolved_request_deadline(&self) -> Duration {
        if self.request_deadline == Duration::ZERO {
            REQUEST_DEADLINE
        } else {
            self.request_deadline
        }
    }

    fn resolved_workers(&self) -> usize {
        if self.workers == 0 {
            parallel::available_threads().clamp(4, 32)
        } else {
            self.workers
        }
    }
}

/// Front-end-wide counters (per-model traffic lives in each model's
/// [`super::ServeStats`]). Each event bumps both the per-server atomic
/// (what [`FrontendStats`] snapshots — per front-end instance, so tests
/// running several servers in one process stay independent) and the
/// process-wide obs registry series (`rkc_http_*_total`, cumulative
/// across front-ends, what `GET /metrics` exposes).
struct FrontendCounters {
    connections: AtomicU64,
    requests: AtomicU64,
    failures: AtomicU64,
    shed: AtomicU64,
    started: Instant,
    obs_connections: Arc<obs::Counter>,
    obs_requests: Arc<obs::Counter>,
    obs_failures: Arc<obs::Counter>,
    obs_shed: Arc<obs::Counter>,
}

/// A snapshot of the front-end-wide counters. `requests > connections`
/// is the keep-alive reuse signal: multiple requests rode one
/// connection.
#[derive(Clone, Copy, Debug)]
pub struct FrontendStats {
    /// connections a pool worker picked up (shed connections excluded)
    pub connections: u64,
    /// HTTP requests handled across all connections — everything that
    /// sent at least one byte, including requests rejected before
    /// routing; silent connect-and-close probes and shed connections
    /// are not counted
    pub requests: u64,
    /// requests answered with a non-2xx status (sheds counted
    /// separately — they were never read)
    pub failures: u64,
    /// connections shed with an immediate 503 because the backlog was full
    pub shed: u64,
    /// seconds since this front-end started
    pub uptime_s: f64,
}

impl FrontendCounters {
    fn new() -> Self {
        let r = obs::registry();
        FrontendCounters {
            connections: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            started: Instant::now(),
            obs_connections: r.counter(
                "rkc_http_connections_total",
                "Connections picked up by a pool worker (shed connections excluded).",
                &[],
            ),
            obs_requests: r.counter(
                "rkc_http_requests_total",
                "HTTP requests handled across all connections.",
                &[],
            ),
            obs_failures: r.counter(
                "rkc_http_failures_total",
                "HTTP requests answered with a non-2xx status.",
                &[],
            ),
            obs_shed: r.counter(
                "rkc_http_shed_total",
                "Connections shed with an immediate 503 (backlog full).",
                &[],
            ),
        }
    }

    fn hit_connection(&self) {
        self.connections.fetch_add(1, Ordering::Relaxed);
        self.obs_connections.inc();
    }

    fn hit_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.obs_requests.inc();
    }

    fn hit_failure(&self) {
        self.failures.fetch_add(1, Ordering::Relaxed);
        self.obs_failures.inc();
    }

    fn hit_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        self.obs_shed.inc();
    }

    /// Load every counter in one pass, back to back — the tightest
    /// coherence independent relaxed atomics allow. Fields may still
    /// race pairwise: a request finishing mid-snapshot can show in
    /// `requests` but not yet in `failures` (loads happen in field
    /// order), and `connections` vs `requests` can be one event apart
    /// under load. Each field is individually monotone.
    fn snapshot(&self) -> FrontendStats {
        let connections = self.connections.load(Ordering::Relaxed);
        let requests = self.requests.load(Ordering::Relaxed);
        let failures = self.failures.load(Ordering::Relaxed);
        let shed = self.shed.load(Ordering::Relaxed);
        FrontendStats {
            connections,
            requests,
            failures,
            shed,
            uptime_s: self.started.elapsed().as_secs_f64(),
        }
    }
}

/// Bounded queue of accepted connections: the accept loop pushes
/// (shedding on overflow rather than blocking — the accept loop must
/// never stall), pool workers pop. Closing wakes every worker to exit
/// and drops whatever was still queued.
struct ConnQueue {
    state: Mutex<(VecDeque<TcpStream>, bool)>,
    not_empty: Condvar,
    cap: usize,
}

impl ConnQueue {
    fn new(cap: usize) -> Self {
        ConnQueue { state: Mutex::new((VecDeque::new(), false)), not_empty: Condvar::new(), cap }
    }

    /// Non-blocking push; hands the stream back when full or closed so
    /// the caller can shed it.
    fn try_push(&self, stream: TcpStream) -> Result<(), TcpStream> {
        let mut st = self.state.lock().expect("conn queue poisoned");
        if st.1 || st.0.len() >= self.cap {
            return Err(stream);
        }
        st.0.push_back(stream);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` once the queue is closed — the worker's
    /// exit signal.
    fn pop(&self) -> Option<TcpStream> {
        let mut st = self.state.lock().expect("conn queue poisoned");
        loop {
            if let Some(s) = st.0.pop_front() {
                return Some(s);
            }
            if st.1 {
                return None;
            }
            st = self.not_empty.wait(st).expect("conn queue poisoned");
        }
    }

    /// Close and drop any queued connections (their sockets close).
    fn close(&self) {
        let mut st = self.state.lock().expect("conn queue poisoned");
        st.1 = true;
        st.0.clear();
        drop(st);
        self.not_empty.notify_all();
    }
}

/// A running HTTP front-end. Dropping (or
/// [`shutdown`](HttpServer::shutdown)) stops the accept loop, closes
/// the connection queue, and joins the worker pool;
/// [`wait`](HttpServer::wait) blocks until shutdown — the CLI's serve
/// loop.
pub struct HttpServer {
    local: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    queue: Arc<ConnQueue>,
    frontend: Arc<FrontendCounters>,
    registry: Arc<ModelRegistry>,
}

/// Bind `addr` (e.g. `"127.0.0.1:7878"`; port 0 picks a free port) and
/// serve `server`'s model over HTTP until shutdown — the single-model
/// convenience wrapper: the model is registered as `default` in a fresh
/// [`ModelRegistry`], so the legacy `/predict`/`/embed` routes and the
/// `/models/default/...` routes both reach it. The caller keeps owning
/// the `ModelServer`.
pub fn serve_http(server: &ModelServer, addr: &str) -> crate::error::Result<HttpServer> {
    let registry = Arc::new(ModelRegistry::new(ServeOpts::default()));
    registry.register("default", server)?;
    serve_http_registry(registry, addr, HttpOpts::default())
}

/// Bind `addr` and serve every model in `registry` until shutdown.
/// Returns immediately; the accept loop and the pool workers run on
/// their own threads. The registry stays shared — runtime
/// `PUT`/`DELETE /models/{name}` and out-of-band
/// [`ModelRegistry::load`]/[`unload`](ModelRegistry::unload) calls are
/// visible to in-flight traffic immediately.
pub fn serve_http_registry(
    registry: Arc<ModelRegistry>,
    addr: &str,
    opts: HttpOpts,
) -> crate::error::Result<HttpServer> {
    let listener =
        TcpListener::bind(addr).map_err(|e| RkcError::io(format!("binding {addr}"), e))?;
    let local = listener
        .local_addr()
        .map_err(|e| RkcError::io(format!("resolving local address of {addr}"), e))?;
    let stop = Arc::new(AtomicBool::new(false));
    let frontend = Arc::new(FrontendCounters::new());
    let queue = Arc::new(ConnQueue::new(opts.backlog.max(1)));
    let keep_alive = opts.keep_alive;
    let request_deadline = opts.resolved_request_deadline();

    let mut workers = Vec::with_capacity(opts.resolved_workers());
    for i in 0..opts.resolved_workers() {
        let q = Arc::clone(&queue);
        let reg = Arc::clone(&registry);
        let fc = Arc::clone(&frontend);
        let st = Arc::clone(&stop);
        let spawned = std::thread::Builder::new()
            .name(format!("rkc-http-worker-{i}"))
            .spawn(move || {
                while let Some(stream) = q.pop() {
                    fc.hit_connection();
                    // a panic while serving costs that one connection,
                    // never a pool slot — the per-connection isolation
                    // the old thread-per-connection design had
                    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        handle_conn(stream, &reg, &fc, keep_alive, request_deadline, &st);
                    }));
                }
            });
        match spawned {
            Ok(h) => workers.push(h),
            Err(e) => {
                // never leak half a pool: wake what we did spawn, join
                // it, and fail construction with a typed error
                queue.close();
                for w in workers {
                    let _ = w.join();
                }
                return Err(RkcError::io("spawning the http worker pool".to_string(), e));
            }
        }
    }

    let stop_flag = Arc::clone(&stop);
    let q = Arc::clone(&queue);
    let fc = Arc::clone(&frontend);
    let accept = std::thread::Builder::new()
        .name("rkc-serve-http".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if stop_flag.load(Ordering::SeqCst) {
                    break;
                }
                let stream = match conn {
                    Ok(s) => s,
                    // fd exhaustion etc. — back off instead of spinning
                    Err(_) => {
                        std::thread::sleep(Duration::from_millis(10));
                        continue;
                    }
                };
                // chaos hook: a firing io_error here models a flaky NIC —
                // the accepted connection drops unserved (the client sees
                // a reset, not a response; chaos runs account for it on
                // the client side and via rkc_fault_trips_total)
                if crate::fault::trip(crate::fault::HTTP_ACCEPT).is_err() {
                    drop(stream);
                    continue;
                }
                if let Err(mut stream) = q.try_push(stream) {
                    // overload is exactly when operators watch the
                    // counters — sheds get their own counter (NOT
                    // `requests`: nothing was read, and inflating
                    // `requests` would fake the keep-alive reuse signal
                    // `requests > connections`)
                    fc.hit_shed();
                    // write the (tiny) 503 off-thread so a hostile peer
                    // can never stall the accept loop; if even that
                    // spawn fails, dropping the connection sheds harder
                    let _ = std::thread::Builder::new()
                        .name("rkc-serve-shed".into())
                        .spawn(move || {
                            let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
                            write_response(
                                &mut stream,
                                503,
                                &error_json("server backlog is full"),
                                true,
                            );
                        });
                }
            }
        })
        .map_err(|e| {
            queue.close();
            for w in workers.drain(..) {
                let _ = w.join();
            }
            RkcError::io("spawning the http accept thread".to_string(), e)
        })?;
    Ok(HttpServer { local, stop, accept: Some(accept), workers, queue, frontend, registry })
}

impl HttpServer {
    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// The registry this front-end routes into (load/unload models out
    /// of band; HTTP traffic sees the change immediately).
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Snapshot the front-end-wide connection/request counters.
    pub fn frontend_stats(&self) -> FrontendStats {
        self.frontend.snapshot()
    }

    /// Stop accepting connections, close the connection queue, and join
    /// the accept thread and worker pool.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    /// Block until the server shuts down (never, unless another owner of
    /// the process stops it) — the CLI `rkc serve` foreground loop.
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    fn stop_and_join(&mut self) {
        if self.accept.is_none() && self.workers.is_empty() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            // the accept loop is blocked in accept(2); poke it awake. A
            // wildcard bind (0.0.0.0 / ::) is not connectable everywhere —
            // aim the wake-up at the loopback of the same family instead.
            let wake = if self.local.ip().is_unspecified() {
                let loopback: IpAddr = match self.local.ip() {
                    IpAddr::V4(_) => Ipv4Addr::LOCALHOST.into(),
                    IpAddr::V6(_) => Ipv6Addr::LOCALHOST.into(),
                };
                SocketAddr::new(loopback, self.local.port())
            } else {
                self.local
            };
            match TcpStream::connect_timeout(&wake, Duration::from_secs(1)) {
                Ok(_) => {
                    let _ = h.join();
                }
                // the wake-up could not reach the listener (self-connect
                // firewalled?): detach the accept thread instead of
                // hanging the caller in join(); it exits with the process
                Err(_) => {}
            }
        }
        // workers drain: the stop flag bounds how long an idle
        // keep-alive connection can hold a worker (one poll tick), and
        // in-flight requests finish their reply first
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

struct HttpRequest {
    method: String,
    path: String,
    body: Vec<u8>,
    /// client asked to close (Connection: close, or HTTP/1.0 without
    /// keep-alive)
    close: bool,
}

/// What one attempt to read a request off the stream produced.
enum ReadOutcome {
    /// a complete, framed request
    Request(Box<HttpRequest>),
    /// nothing to respond to: clean close, idle timeout, probe noise,
    /// or server shutdown — drop the connection silently
    Silent,
    /// framing failure: answer with this status/message, then close
    /// (the byte stream cannot be re-synchronized)
    Fatal(u16, String),
}

/// Serve one connection until close/idle/framing-failure/shutdown: the
/// pool worker's `read request → dispatch → respond` loop. `carry`
/// holds bytes read past the previous request's body (pipelined
/// clients), so framing never loses data between iterations.
fn handle_conn(
    mut stream: TcpStream,
    registry: &ModelRegistry,
    frontend: &FrontendCounters,
    keep_alive: Duration,
    request_deadline: Duration,
    stop: &AtomicBool,
) {
    // symmetric defense: a client that never reads its response must
    // not pin this worker (and the response buffer) forever
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    let mut carry: Vec<u8> = Vec::new();
    let mut idle = FIRST_REQUEST_WINDOW;
    loop {
        match read_request(&mut stream, &mut carry, idle, request_deadline, stop) {
            ReadOutcome::Silent => return,
            ReadOutcome::Fatal(status, msg) => {
                frontend.hit_request();
                frontend.hit_failure();
                write_response(&mut stream, status, &error_json(&msg), true);
                drain_then_close(stream);
                return;
            }
            ReadOutcome::Request(req) => {
                frontend.hit_request();
                let (status, ctype, body) = route(registry, frontend, &req);
                if status >= 400 {
                    frontend.hit_failure();
                }
                let close = req.close || keep_alive.is_zero() || stop.load(Ordering::Relaxed);
                // an abandoned (timed-out / failed) write leaves a
                // truncated response on the socket — the byte stream is
                // desynced and the connection must die with it
                let sent = write_response_with(&mut stream, status, ctype, &body, close);
                if close || !sent {
                    drain_then_close(stream);
                    return;
                }
            }
        }
        idle = keep_alive;
    }
}

/// Half-close, then briefly drain whatever request bytes are still in
/// flight (e.g. the body behind a 413 written straight after the head):
/// closing with unread data makes the kernel RST the connection, which
/// can destroy the queued response before the client reads it.
fn drain_then_close(mut stream: TcpStream) {
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let mut sink = [0u8; 8192];
    let drain_started = Instant::now();
    while drain_started.elapsed() < Duration::from_secs(2)
        && matches!(stream.read(&mut sink), Ok(n) if n > 0)
    {}
}

/// Dispatch one framed request against the registry, returning
/// `(status, content type, body)`. Per-model HTTP counters are bumped
/// here (on the model the request routed to); front-end-wide counters
/// are the caller's job.
fn route(
    registry: &ModelRegistry,
    frontend: &FrontendCounters,
    req: &HttpRequest,
) -> (u16, &'static str, String) {
    let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    // /metrics is the one non-JSON response (Prometheus text
    // exposition); its non-GET methods still fall through to the JSON
    // 405 arm below
    if let ("GET", ["metrics"]) = (req.method.as_str(), segs.as_slice()) {
        return (200, "text/plain; version=0.0.4", metrics_text(registry, frontend));
    }
    let (status, body) = match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => health(registry, frontend),
        ("GET", ["models"]) => (200, models_json(registry, frontend)),
        ("GET", ["models", name]) => match registry.info(name) {
            Some(info) => {
                // `shed` is front-end-wide (connections shed before any
                // routing), merged here so the per-model view carries
                // the same overload signal as `/metrics` and `/models`
                let mut v = model_info_value(&info);
                if let Json::Obj(map) = &mut v {
                    map.insert(
                        "shed".to_string(),
                        Json::Num(frontend.snapshot().shed as f64),
                    );
                }
                (200, v.to_string())
            }
            None => (404, no_such_model(name)),
        },
        ("PUT", ["models", name]) => put_model(registry, name, &req.body),
        ("DELETE", ["models", name]) => {
            if registry.unload(name) {
                (200, obj([("unloaded", Json::Str((*name).to_string()))]))
            } else {
                (404, no_such_model(name))
            }
        }
        ("POST", ["models", name, op @ ("predict" | "embed")]) => match registry.get(name) {
            Some(handle) => model_op(&handle, op, &req.body),
            None => (404, no_such_model(name)),
        },
        ("POST", [op @ ("predict" | "embed")]) => match registry.default_model() {
            Some((_, handle)) => model_op(&handle, op, &req.body),
            None => (503, error_json("no models loaded (PUT /models/{name} to load one)")),
        },
        (_, ["healthz"] | ["metrics"] | ["predict"] | ["embed"] | ["models"] | ["models", _]) => {
            (405, error_json("method not allowed for this path"))
        }
        (_, ["models", _, "predict" | "embed"]) => {
            (405, error_json("method not allowed for this path"))
        }
        _ => (404, error_json("no such endpoint (try /healthz, /models, /models/{name}/predict)")),
    };
    (status, "application/json", body)
}

fn no_such_model(name: &str) -> String {
    obj([
        ("error", Json::Str(format!("no model named '{name}'"))),
        ("hint", Json::Str("GET /models lists loaded models".to_string())),
    ])
}

/// Run predict/embed on one model, counting the request against that
/// model's HTTP counters.
fn model_op(handle: &ServerHandle, op: &str, body: &[u8]) -> (u16, String) {
    let counters = &handle.shared.counters;
    counters.http_requests.fetch_add(1, Ordering::Relaxed);
    let (status, body) = match parse_points(body) {
        Err(msg) => (400, error_json(&msg)),
        Ok(points) if op == "predict" => match handle.predict(points) {
            Ok(labels) => {
                let arr = labels.iter().map(|&l| Json::Num(l as f64)).collect();
                (200, obj([("labels", Json::Arr(arr))]))
            }
            Err(e) => error_response(&e),
        },
        Ok(points) => match handle.embed(points) {
            Ok(y) => {
                // non-finite coordinates (a degenerate query can
                // overflow the kernel) become null — JSON has no
                // inf/NaN literals and the body must stay parseable
                let cols: Vec<Json> = (0..y.cols())
                    .map(|j| {
                        Json::Arr((0..y.rows()).map(|i| Json::finite_num(y[(i, j)])).collect())
                    })
                    .collect();
                (200, obj([("embedding", Json::Arr(cols))]))
            }
            Err(e) => error_response(&e),
        },
    };
    if status >= 400 {
        counters.http_failures.fetch_add(1, Ordering::Relaxed);
    }
    (status, body)
}

/// `PUT /models/{name}` with `{"path": "model.rkc"}`: load (or replace)
/// a model at runtime. The path is read server-side — bind the admin
/// surface to loopback (the default) unless the network is trusted.
fn put_model(registry: &ModelRegistry, name: &str, body: &[u8]) -> (u16, String) {
    if !valid_name(name) {
        return (400, error_json("invalid model name (want ASCII [A-Za-z0-9._-]+)"));
    }
    let path = match std::str::from_utf8(body).ok().and_then(|t| Json::parse(t).ok()) {
        Some(v) => match v.get("path").and_then(Json::as_str) {
            Some(p) => p.to_string(),
            None => return (400, error_json("missing 'path': expected {\"path\": \"model.rkc\"}")),
        },
        None => return (400, error_json("malformed JSON: expected {\"path\": \"model.rkc\"}")),
    };
    match registry.load(name, &path) {
        Ok(()) => (
            200,
            obj([
                ("loaded", Json::Str(name.to_string())),
                ("path", Json::Str(path)),
                ("models", Json::Num(registry.len() as f64)),
            ]),
        ),
        // any failure past this point left the registry untouched: the
        // previous model under this name (if any) keeps serving
        Err(e) => match e {
            // still transient after the registry's retry budget — the
            // environment failed an intended swap, so the name is
            // quarantined until a load succeeds (/healthz: degraded)
            // and the caller is told to try again later, not to fix
            // the request
            ref e if e.is_transient() => {
                registry.quarantine(name, format!("{path}: {e}"));
                (503, error_json(&e.to_string()))
            }
            // a missing file is the caller naming something that
            // isn't there; everything else (corrupt model, bad name)
            // is a bad request — neither degrades the fleet, so
            // neither quarantines (one typo'd PUT must not flip
            // /healthz to degraded until the next successful load)
            RkcError::Io { context, source } => {
                (404, error_json(&format!("{context}: {source}")))
            }
            e => (400, error_json(&e.to_string())),
        },
    }
}

/// `GET /healthz` — the legacy single-model health shape, aliased to
/// the **default** model (its compute counters and metrics), plus the
/// registry-wide fields (`models`, front-end connection counters). 503
/// when no model is loaded or the default's queue is closed — a 200
/// would keep load balancers routing traffic to a server that 503s
/// every predict.
fn health(registry: &ModelRegistry, frontend: &FrontendCounters) -> (u16, String) {
    let fe = frontend.snapshot();
    // per-model enqueue→reply p50/p95 from the obs latency histograms
    // (upper-bound estimates: the bucket bound the quantile falls in)
    let mut latency = BTreeMap::new();
    for info in registry.list() {
        if let Some(handle) = registry.get(&info.name) {
            let snap = handle.shared.obs.latency.snapshot();
            latency.insert(
                info.name.clone(),
                json_obj(vec![
                    ("p50_ms", Json::Num(snap.quantile(0.5) * 1e3)),
                    ("p95_ms", Json::Num(snap.quantile(0.95) * 1e3)),
                ]),
            );
        }
    }
    // models that failed to load or hot-swap, with their failures —
    // non-empty means the fleet is serving but incomplete: status
    // `degraded`, still 200 (the default model answers; a 503 would
    // pull a working server out of rotation)
    let quarantined: BTreeMap<String, Json> = registry
        .quarantined()
        .into_iter()
        .map(|(n, reason)| (n, Json::Str(reason)))
        .collect();
    let degraded = !quarantined.is_empty();
    let mut fields: Vec<(&str, Json)> = vec![
        ("models", Json::Num(registry.len() as f64)),
        ("connections", Json::Num(fe.connections as f64)),
        ("http_requests", Json::Num(fe.requests as f64)),
        ("http_failures", Json::Num(fe.failures as f64)),
        ("shed", Json::Num(fe.shed as f64)),
        ("frontend_uptime_s", Json::Num(fe.uptime_s)),
        ("latency_ms", Json::Obj(latency)),
        ("quarantined", Json::Obj(quarantined)),
    ];
    let Some((name, handle)) = registry.default_model() else {
        fields.push(("status", Json::Str("empty".into())));
        return (503, obj_vec(fields));
    };
    let shared = &handle.shared;
    let closed = shared.queue.is_closed();
    let stats = shared.snapshot();
    let m = shared.model.metrics();
    let input_dim = match shared.model.input_dim() {
        Some(p) => Json::Num(p as f64),
        None => Json::Null,
    };
    let status = if closed {
        "shutdown"
    } else if degraded {
        "degraded"
    } else {
        "ok"
    };
    fields.extend([
        ("status", Json::Str(status.into())),
        ("default", Json::Str(name)),
        ("method", Json::Str(m.method.clone())),
        ("k", Json::Num(shared.model.k() as f64)),
        ("n_train", Json::Num(m.n as f64)),
        ("rank", Json::Num(m.rank as f64)),
        ("input_dim", input_dim),
        ("generation", Json::Num(shared.model.generation() as f64)),
        ("queue_depth", Json::Num(shared.queue.depth() as f64)),
        ("queue_highwater", Json::Num(stats.queue_highwater as f64)),
        ("requests", Json::Num(stats.requests as f64)),
        ("points", Json::Num(stats.points as f64)),
        ("batches", Json::Num(stats.batches as f64)),
        ("errors", Json::Num(stats.errors as f64)),
        ("mean_batch", Json::Num(stats.mean_batch())),
        ("mean_latency_us", Json::Num(stats.mean_latency_us())),
        ("uptime_s", Json::Num(stats.uptime_s)),
    ]);
    (if closed { 503 } else { 200 }, obj_vec(fields))
}

fn model_info_value(info: &super::ModelInfo) -> Json {
    let input_dim = match info.input_dim {
        Some(p) => Json::Num(p as f64),
        None => Json::Null,
    };
    json_obj(vec![
        ("name", Json::Str(info.name.clone())),
        ("default", Json::Bool(info.is_default)),
        ("method", Json::Str(info.method.clone())),
        ("k", Json::Num(info.k as f64)),
        ("n_train", Json::Num(info.n_train as f64)),
        ("rank", Json::Num(info.rank as f64)),
        ("input_dim", input_dim),
        ("generation", Json::Num(info.generation as f64)),
        ("path", info.path.clone().map(Json::Str).unwrap_or(Json::Null)),
        ("queue_depth", Json::Num(info.queue_depth as f64)),
        ("queue_highwater", Json::Num(info.stats.queue_highwater as f64)),
        ("requests", Json::Num(info.stats.requests as f64)),
        ("points", Json::Num(info.stats.points as f64)),
        ("batches", Json::Num(info.stats.batches as f64)),
        ("errors", Json::Num(info.stats.errors as f64)),
        ("http_requests", Json::Num(info.stats.http_requests as f64)),
        ("http_failures", Json::Num(info.stats.http_failures as f64)),
        ("mean_batch", Json::Num(info.stats.mean_batch())),
        ("mean_latency_us", Json::Num(info.stats.mean_latency_us())),
    ])
}

/// `GET /models` — every model's info + stats, plus the front-end-wide
/// counters.
fn models_json(registry: &ModelRegistry, frontend: &FrontendCounters) -> String {
    let fe = frontend.snapshot();
    let infos = registry.list();
    let default = infos
        .iter()
        .find(|i| i.is_default)
        .map(|i| Json::Str(i.name.clone()))
        .unwrap_or(Json::Null);
    let rows: Vec<Json> = infos.iter().map(model_info_value).collect();
    obj_vec(vec![
        ("default", default),
        ("models", Json::Arr(rows)),
        ("connections", Json::Num(fe.connections as f64)),
        ("http_requests", Json::Num(fe.requests as f64)),
        ("http_failures", Json::Num(fe.failures as f64)),
        ("shed", Json::Num(fe.shed as f64)),
    ])
}

/// `GET /metrics` — the whole obs registry in Prometheus text
/// exposition format. Counters and histograms are recorded at source;
/// the point-in-time gauges (queue depth/highwater, generation, models
/// loaded, uptime) are set here at scrape time from the registry's live
/// state, then everything renders in one pass. A gauge series for a
/// model that has since unloaded keeps its last value (Prometheus
/// semantics: series go stale, they don't vanish).
fn metrics_text(registry: &ModelRegistry, frontend: &FrontendCounters) -> String {
    let r = obs::registry();
    for info in registry.list() {
        let labels: &[(&str, &str)] = &[("model", &info.name)];
        r.gauge(
            "rkc_serve_queue_depth",
            "Requests pending in the model's bounded queue at scrape time.",
            labels,
        )
        .set(info.queue_depth as u64);
        r.gauge(
            "rkc_serve_queue_highwater",
            "Deepest the model's request queue has ever been.",
            labels,
        )
        .set(info.stats.queue_highwater);
        r.gauge(
            "rkc_model_generation",
            "Generation of the live model (monotone across hot-swaps).",
            labels,
        )
        .set(info.generation);
    }
    r.gauge("rkc_models_loaded", "Models currently loaded in the registry.", &[])
        .set(registry.len() as u64);
    r.gauge("rkc_http_uptime_seconds", "Seconds since the HTTP front-end started.", &[])
        .set(frontend.started.elapsed().as_secs());
    // the rkc_simd_isa info gauge registers on first dispatch; touch
    // the table here so a process that scraped before any dense compute
    // ran still reports which kernels it would use
    let _ = crate::simd::dispatch();
    r.render()
}

/// Map a typed serving error onto an HTTP status: caller mistakes are
/// 4xx, backend unavailability is 503, anything else 500.
fn error_response(e: &RkcError) -> (u16, String) {
    let status = match e {
        RkcError::InvalidConfig(_) | RkcError::Parse { .. } | RkcError::Unsupported(_) => 400,
        // unavailable-now, not broken: retry-later semantics
        RkcError::Backend(_) | RkcError::Transient { .. } => 503,
        _ => 500,
    };
    (status, error_json(&e.to_string()))
}

/// Decode `{"points": [[…], …]}` into a p × m query matrix (columns are
/// samples). Every defect is a caller-facing message for a 400.
fn parse_points(body: &[u8]) -> Result<Mat, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let v = Json::parse(text).map_err(|e| format!("malformed JSON: {e}"))?;
    let pts = v
        .get("points")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing 'points': expected {\"points\": [[x, y, ...], ...]}".to_string())?;
    if pts.is_empty() {
        return Err("'points' must be non-empty".to_string());
    }
    let p = pts[0]
        .as_arr()
        .ok_or_else(|| "each point must be an array of numbers".to_string())?
        .len();
    // validate every point's shape BEFORE allocating: p comes from
    // attacker-controlled input, and p × m must be known body-bounded
    // (all points the same length) before Mat::zeros commits the memory
    for (j, point) in pts.iter().enumerate() {
        let coords = point
            .as_arr()
            .ok_or_else(|| "each point must be an array of numbers".to_string())?;
        if coords.len() != p {
            return Err(format!("point {j} has {} coordinates, expected {p}", coords.len()));
        }
    }
    let mut mat = Mat::zeros(p, pts.len());
    for (j, point) in pts.iter().enumerate() {
        let coords = point.as_arr().expect("shape validated above");
        for (i, val) in coords.iter().enumerate() {
            mat[(i, j)] = val
                .as_f64()
                .ok_or_else(|| format!("point {j} coordinate {i} is not a number"))?;
        }
    }
    Ok(mat)
}

fn obj<const N: usize>(fields: [(&str, Json); N]) -> String {
    obj_vec(fields.into_iter().collect())
}

fn obj_vec(fields: Vec<(&str, Json)>) -> String {
    json_obj(fields).to_string()
}

fn json_obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn error_json(msg: &str) -> String {
    obj([("error", Json::Str(msg.to_string()))])
}

/// Write one framed JSON response. Returns whether every byte was
/// written — a `false` means the stream now holds a truncated response
/// and a keep-alive caller must close the connection.
fn write_response(stream: &mut TcpStream, status: u16, body: &str, close: bool) -> bool {
    write_response_with(stream, status, "application/json", body, close)
}

/// [`write_response`] with an explicit content type (`/metrics` answers
/// Prometheus text, everything else JSON).
fn write_response_with(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &str,
    close: bool,
) -> bool {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Error",
    };
    let connection = if close { "close" } else { "keep-alive" };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: {connection}\r\n\r\n",
        body.len()
    );
    let started = Instant::now();
    let sent = write_all_deadline(stream, head.as_bytes(), started)
        && write_all_deadline(stream, body.as_bytes(), started);
    let _ = stream.flush();
    sent
}

/// `write_all` with an aggregate [`RESPONSE_DEADLINE`]: the 10 s
/// per-write timeout alone would let a 1-byte-per-window reader keep a
/// multi-MB response alive indefinitely. Returns false when the write
/// was abandoned.
fn write_all_deadline(stream: &mut TcpStream, mut buf: &[u8], started: Instant) -> bool {
    while !buf.is_empty() {
        if started.elapsed() > RESPONSE_DEADLINE {
            return false;
        }
        match stream.write(&buf[..buf.len().min(64 * 1024)]) {
            Ok(0) | Err(_) => return false,
            Ok(n) => buf = &buf[n..],
        }
    }
    true
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Does a `Connection:` header value ask to close? (token list,
/// case-insensitive — "keep-alive, close" closes)
fn connection_wants_close(value: &str) -> bool {
    value.split(',').any(|t| t.trim().eq_ignore_ascii_case("close"))
}

/// Read one HTTP request (head + Content-Length body) off the stream,
/// consuming `carry` first and leaving any bytes past this request's
/// body (pipelined requests) back in `carry`.
///
/// Two separate clocks govern the read: while *no* byte of this request
/// has arrived, the `idle` keep-alive window applies and expiry is a
/// [`ReadOutcome::Silent`] close; from the first byte on, the
/// `request_deadline` slow-loris budget (see
/// [`HttpOpts::request_deadline`]) applies and expiry is a 408. The
/// stop flag turns into a silent close at the next poll tick.
fn read_request(
    stream: &mut TcpStream,
    carry: &mut Vec<u8>,
    idle: Duration,
    request_deadline: Duration,
    stop: &AtomicBool,
) -> ReadOutcome {
    let mut buf = std::mem::take(carry);
    let idle_started = Instant::now();
    let mut request_started = if buf.is_empty() { None } else { Some(Instant::now()) };
    let mut chunk = [0u8; 8192];

    // None = the applicable deadline (idle vs slow-loris) expired
    let remaining = |request_started: &Option<Instant>| -> Option<Duration> {
        match request_started {
            Some(t0) => request_deadline.checked_sub(t0.elapsed()),
            None => idle.checked_sub(idle_started.elapsed()),
        }
    };

    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD {
            return ReadOutcome::Fatal(431, "request head too large".to_string());
        }
        if stop.load(Ordering::Relaxed) {
            return ReadOutcome::Silent;
        }
        let Some(left) = remaining(&request_started) else {
            return match request_started {
                None => ReadOutcome::Silent, // idle keep-alive expiry
                Some(_) => ReadOutcome::Fatal(408, "request took too long to arrive".to_string()),
            };
        };
        let _ = stream.set_read_timeout(Some(left.min(POLL_TICK).max(Duration::from_millis(1))));
        match stream.read(&mut chunk) {
            Ok(0) => {
                return if buf.is_empty() {
                    ReadOutcome::Silent // clean close between requests
                } else {
                    ReadOutcome::Fatal(400, "connection closed mid-request".to_string())
                };
            }
            Ok(n) => {
                if request_started.is_none() {
                    request_started = Some(Instant::now());
                }
                buf.extend_from_slice(&chunk[..n]);
            }
            // timeout tick: loop back and re-check stop + deadlines
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {}
            Err(_) if buf.is_empty() => return ReadOutcome::Silent,
            Err(e) => return ReadOutcome::Fatal(400, format!("read error: {e}")),
        }
    };

    let head = match std::str::from_utf8(&buf[..head_end]) {
        Ok(h) => h,
        Err(_) => return ReadOutcome::Fatal(400, "request head is not UTF-8".to_string()),
    };
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split_whitespace();
    let Some(method) = parts.next().map(str::to_string) else {
        return ReadOutcome::Fatal(400, "empty request line".to_string());
    };
    let Some(path) = parts.next().map(str::to_string) else {
        return ReadOutcome::Fatal(400, "request line is missing a path".to_string());
    };
    // HTTP/1.0 defaults to close; 1.1 (and anything newer) to keep-alive
    let version = parts.next().unwrap_or("HTTP/1.1");
    let mut close = version.eq_ignore_ascii_case("HTTP/1.0");
    let mut content_length: Option<usize> = None;
    let mut expects_continue = false;
    for line in lines {
        if let Some((key, value)) = line.split_once(':') {
            let key = key.trim();
            let value = value.trim();
            if key.eq_ignore_ascii_case("content-length") {
                let parsed: usize = match value.parse() {
                    Ok(n) => n,
                    Err(_) => return ReadOutcome::Fatal(400, "unparseable content-length".into()),
                };
                // duplicate-but-different Content-Length headers are a
                // framing (request-smuggling) hazard on a persistent
                // connection: a proxy framing by the other value would
                // desync every later request on this socket — reject
                if content_length.is_some_and(|prev| prev != parsed) {
                    return ReadOutcome::Fatal(
                        400,
                        "conflicting content-length headers".to_string(),
                    );
                }
                content_length = Some(parsed);
            } else if key.eq_ignore_ascii_case("expect")
                && value.eq_ignore_ascii_case("100-continue")
            {
                expects_continue = true;
            } else if key.eq_ignore_ascii_case("connection") {
                if connection_wants_close(value) {
                    close = true;
                } else if value.trim().eq_ignore_ascii_case("keep-alive") {
                    close = false; // HTTP/1.0 client opting in
                }
            } else if key.eq_ignore_ascii_case("transfer-encoding") {
                // we only speak Content-Length bodies; saying so beats a
                // misleading 400 after silently dropping a chunked body
                return ReadOutcome::Fatal(
                    501,
                    "transfer-encoding is not supported; send Content-Length".to_string(),
                );
            }
        }
    }
    let content_length = content_length.unwrap_or(0);
    if content_length > MAX_BODY {
        return ReadOutcome::Fatal(413, format!("body of {content_length} bytes exceeds the limit"));
    }
    // curl (and friends) pause up to a second waiting for this interim
    // response before sending any body over 1 KiB
    if expects_continue && content_length > 0 && buf.len() < head_end + 4 + content_length {
        let _ = stream.write_all(b"HTTP/1.1 100 Continue\r\n\r\n");
    }

    let total = head_end + 4 + content_length;
    if buf.len() < total {
        // 64 KiB reads (bodies run up to MAX_BODY) against the same
        // per-request deadline as the head. Deliberately NOT reserving
        // the declared Content-Length up front: headers alone must never
        // commit the full MAX_BODY per connection — memory grows as
        // bytes arrive. Reads are not capped at the body boundary:
        // pipelined follow-up bytes land in `carry` below.
        let mut big = vec![0u8; 64 * 1024];
        while buf.len() < total {
            if stop.load(Ordering::Relaxed) {
                return ReadOutcome::Silent;
            }
            let Some(left) = remaining(&request_started) else {
                return ReadOutcome::Fatal(408, "request body took too long to arrive".to_string());
            };
            let _ =
                stream.set_read_timeout(Some(left.min(POLL_TICK).max(Duration::from_millis(1))));
            match stream.read(&mut big) {
                Ok(0) => return ReadOutcome::Fatal(400, "connection closed mid-body".to_string()),
                Ok(n) => buf.extend_from_slice(&big[..n]),
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {}
                Err(e) => return ReadOutcome::Fatal(400, format!("read error: {e}")),
            }
        }
    }
    // split: this request's body stays in buf, pipelined excess carries
    // over to the next read_request call on this connection
    *carry = buf.split_off(total);
    let body = buf[head_end + 4..].to_vec();
    ReadOutcome::Request(Box::new(HttpRequest { method, path, body, close }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_points_builds_column_major_queries() {
        let m = parse_points(br#"{"points": [[1.0, 2.0], [3.5, -4.0], [0, 1]]}"#).unwrap();
        assert_eq!((m.rows(), m.cols()), (2, 3));
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(1, 1)], -4.0);
        assert_eq!(m[(1, 2)], 1.0);
    }

    #[test]
    fn parse_points_rejects_malformed_bodies() {
        for bad in [
            &b"{not json"[..],
            &br#"{"pts": [[1]]}"#[..],
            &br#"{"points": []}"#[..],
            &br#"{"points": [1, 2]}"#[..],
            &br#"{"points": [[1, 2], [3]]}"#[..],
            &br#"{"points": [["a", "b"]]}"#[..],
            &b"\xff\xfe"[..],
        ] {
            assert!(parse_points(bad).is_err(), "{:?} should fail", String::from_utf8_lossy(bad));
        }
    }

    #[test]
    fn error_statuses_map_caller_vs_backend_faults() {
        assert_eq!(error_response(&RkcError::invalid_config("x")).0, 400);
        assert_eq!(error_response(&RkcError::unsupported("x")).0, 400);
        assert_eq!(error_response(&RkcError::backend("down")).0, 503);
        assert_eq!(error_response(&RkcError::transient("injected fault")).0, 503);
        assert_eq!(error_response(&RkcError::dataset("x")).0, 500);
    }

    #[test]
    fn head_end_detection() {
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\n\r\nbody"), Some(16));
        assert_eq!(find_head_end(b"partial\r\n"), None);
    }

    #[test]
    fn connection_header_token_semantics() {
        assert!(connection_wants_close("close"));
        assert!(connection_wants_close("Close"));
        assert!(connection_wants_close("keep-alive, close"));
        assert!(!connection_wants_close("keep-alive"));
        assert!(!connection_wants_close("Keep-Alive"));
        // "close" must be its own token, not a substring
        assert!(!connection_wants_close("closely-related"));
    }

    #[test]
    fn conn_queue_bounds_sheds_and_closes() {
        // listener gives us real TcpStreams to queue
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let q = ConnQueue::new(1);
        let mk = || {
            let _c = TcpStream::connect(addr).unwrap();
            listener.accept().unwrap().0
        };
        assert!(q.try_push(mk()).is_ok());
        assert!(q.try_push(mk()).is_err(), "over capacity sheds");
        assert!(q.pop().is_some());
        q.close();
        assert!(q.try_push(mk()).is_err(), "closed queue sheds");
        assert!(q.pop().is_none(), "closed and drained");
    }

    #[test]
    fn http_opts_resolve_workers() {
        let auto = HttpOpts::default().resolved_workers();
        assert!((4..=32).contains(&auto), "{auto}");
        assert_eq!(HttpOpts { workers: 2, ..Default::default() }.resolved_workers(), 2);
    }
}
