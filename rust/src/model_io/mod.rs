//! Versioned binary persistence for fitted models — the `.rkc` format.
//!
//! A [`FittedModel`](crate::api::FittedModel) is the paper's whole point
//! made tangible: a compact object (column map + rank-r embedding +
//! centroids) that replaces the O(n²) kernel matrix. This module lets
//! that object outlive the process that fitted it, so a model is fitted
//! once and served forever ([`crate::serve`]). Loading is **bit-exact**:
//! every f64 travels as its IEEE-754 bits, so a reloaded model's
//! `embed`/`predict` outputs are bit-identical to the in-memory
//! original (enforced by `tests/serve_roundtrip.rs`).
//!
//! # Byte-level format (version 1)
//!
//! All multi-byte integers and floats are **little-endian**, written
//! explicitly via `to_le_bytes` (the format is identical on every
//! platform).
//!
//! ```text
//! offset        size  contents
//! 0             8     magic, the ASCII bytes "RKCMODEL"
//! 8             4     u32 format version (currently 1)
//! 12            4     u32 header length H in bytes
//! 16            H     UTF-8 JSON header (see below)
//! 16+H          8·Σ   payload: for each header `sections` entry, in
//!                     order, rows·cols f64 values in row-major order
//! end−8         8     u64 FNV-1a checksum of every preceding byte
//! ```
//!
//! The JSON header (written by [`crate::util::json`], no external
//! dependencies) carries the scalar model state and the payload layout:
//!
//! ```text
//! {
//!   "format":   "rkc-model",
//!   "kernel":   round-trippable kernel spec ("poly2", "rbf:0.5", …),
//!   "method":   method name ("one_pass", "nystrom_m100", …),
//!   "assigner": "embedded" | "input" | "kernel_clusters",
//!   "k" / "n" / "rank" / "n_pad" / "batch":  integers,
//!   "objective": number (null when non-finite),
//!   "times":    {"sketch": s, "recovery": s, "kmeans": s},
//!   "memory":   {"method", "persistent", "transient", "recovery"},
//!   "sections": [{"name": "...", "rows": R, "cols": C}, ...]
//! }
//! ```
//!
//! Section names and presence rules: `labels` (1 × n, always);
//! `embedding_y` (r × n) + `eigenvalues` (1 × r) when the model has an
//! embedding; `centroids` for the `embedded`/`input` assigners;
//! `cluster_sizes` + `self_terms` (1 × k each) for `kernel_clusters`;
//! `train_x` (p × n) when the training data was retained (required for
//! out-of-sample `embed`/`predict`). Integer-valued sections (labels,
//! sizes) are stored as f64, exact up to 2⁵³.
//!
//! # Versioning and failure modes
//!
//! The outer framing — magic, version word, header length, trailing
//! checksum — is **invariant across all format versions** (only the
//! header schema and section set may evolve), so integrity is checked
//! before version negotiation: a checksum mismatch always means
//! corruption, never a newer format. The loader accepts any version
//! `1..=`[`FORMAT_VERSION`]. A newer version is a typed
//! [`RkcError::ModelVersion`]; everything else that
//! can be wrong with a file — bad magic, truncated framing or payload,
//! checksum mismatch, malformed header, inconsistent shapes — is a
//! typed [`RkcError::Model`] naming the file and the defect.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::api::{Assigner, FitMetrics, FittedModel};
use crate::error::{Result, RkcError};
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::lowrank::Embedding;
use crate::metrics::MethodMemory;
use crate::util::Json;

/// The 8 magic bytes opening every `.rkc` file.
pub const MAGIC: [u8; 8] = *b"RKCMODEL";

/// Newest format version this build writes (and the newest it reads).
pub const FORMAT_VERSION: u32 = 1;

/// magic + version + header length before the header itself
const FIXED_PREFIX: usize = 8 + 4 + 4;

/// Resolve a save/load target the way every model-path entry point
/// (builder `auto_save`, the CLI `--model` flag) does: a
/// directory-style target — trailing `/`, or an existing directory —
/// means `model.rkc` inside it; anything else is the file path itself.
/// One shared rule, so the value that `save` just wrote to is exactly
/// the value `predict`/`serve` load from.
pub fn resolve_model_target(target: &str) -> String {
    if target.ends_with('/') || std::path::Path::new(target).is_dir() {
        format!("{}/model.rkc", target.trim_end_matches('/'))
    } else {
        target.to_string()
    }
}

/// 64-bit FNV-1a — the integrity checksum trailing every `.rkc` file
/// (part of the format spec, exposed so external tooling can verify or
/// re-seal files).
pub fn checksum(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Serialize a fitted model into the `.rkc` byte format.
pub fn model_to_bytes(model: &FittedModel) -> Vec<u8> {
    use std::borrow::Cow;
    // (name, rows, cols, row-major data) in the fixed writer order;
    // float sections borrow straight from the model (only the
    // integer-valued ones need an owned f64 conversion) — the byte
    // buffer below still holds one full serialized copy, so peak save
    // memory is model + bytes, not model + floats + bytes
    let mut sections: Vec<(&'static str, usize, usize, Cow<'_, [f64]>)> = Vec::new();
    let labels: Vec<f64> = model.labels().iter().map(|&l| l as f64).collect();
    sections.push(("labels", 1, labels.len(), Cow::Owned(labels)));
    if let Some(emb) = &model.embedding {
        sections.push(("embedding_y", emb.y.rows(), emb.y.cols(),
            Cow::Borrowed(emb.y.data())));
        sections.push(("eigenvalues", 1, emb.eigenvalues.len(),
            Cow::Borrowed(emb.eigenvalues.as_slice())));
    }
    let assigner_tag = match &model.assigner {
        Assigner::Embedded { centroids } => {
            sections.push(("centroids", centroids.rows(), centroids.cols(),
                Cow::Borrowed(centroids.data())));
            "embedded"
        }
        Assigner::Input { centroids } => {
            sections.push(("centroids", centroids.rows(), centroids.cols(),
                Cow::Borrowed(centroids.data())));
            "input"
        }
        Assigner::KernelClusters { sizes, self_terms } => {
            let s: Vec<f64> = sizes.iter().map(|&c| c as f64).collect();
            sections.push(("cluster_sizes", 1, s.len(), Cow::Owned(s)));
            sections.push(("self_terms", 1, self_terms.len(),
                Cow::Borrowed(self_terms.as_slice())));
            "kernel_clusters"
        }
    };
    if let Some(x) = &model.train_x {
        sections.push(("train_x", x.rows(), x.cols(), Cow::Borrowed(x.data())));
    }

    let m = model.metrics();
    let mut header = BTreeMap::new();
    header.insert("format".into(), Json::Str("rkc-model".into()));
    header.insert("kernel".into(), Json::Str(model.kernel().to_string()));
    header.insert("method".into(), Json::Str(m.method.clone()));
    header.insert("assigner".into(), Json::Str(assigner_tag.into()));
    header.insert("k".into(), uint(model.k()));
    header.insert("n".into(), uint(m.n));
    header.insert("rank".into(), uint(m.rank));
    header.insert("n_pad".into(), uint(model.n_padded()));
    header.insert("batch".into(), uint(model.batch));
    header.insert("generation".into(), uint(model.generation() as usize));
    header.insert("precision".into(), Json::Str(model.precision().to_string()));
    header.insert("objective".into(), Json::finite_num(m.objective));
    header.insert(
        "times".into(),
        Json::Obj(BTreeMap::from([
            ("sketch".to_string(), Json::finite_num(m.sketch_time.as_secs_f64())),
            ("recovery".to_string(), Json::finite_num(m.recovery_time.as_secs_f64())),
            ("kmeans".to_string(), Json::finite_num(m.kmeans_time.as_secs_f64())),
        ])),
    );
    header.insert(
        "memory".into(),
        Json::Obj(BTreeMap::from([
            ("method".to_string(), Json::Str(m.memory.method.clone())),
            ("persistent".to_string(), uint(m.memory.persistent)),
            ("transient".to_string(), uint(m.memory.transient)),
            ("recovery".to_string(), uint(m.memory.recovery)),
        ])),
    );
    header.insert(
        "sections".into(),
        Json::Arr(
            sections
                .iter()
                .map(|(name, rows, cols, _)| {
                    Json::Obj(BTreeMap::from([
                        ("name".to_string(), Json::Str((*name).into())),
                        ("rows".to_string(), uint(*rows)),
                        ("cols".to_string(), uint(*cols)),
                    ]))
                })
                .collect(),
        ),
    );

    let header_bytes = Json::Obj(header).to_string().into_bytes();
    let payload_len: usize = sections.iter().map(|(_, r, c, _)| 8 * r * c).sum();
    let mut out = Vec::with_capacity(FIXED_PREFIX + header_bytes.len() + payload_len + 8);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.extend_from_slice(&(header_bytes.len() as u32).to_le_bytes());
    out.extend_from_slice(&header_bytes);
    for (_, _, _, data) in &sections {
        for v in data.iter() {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    let ck = checksum(&out);
    out.extend_from_slice(&ck.to_le_bytes());
    out
}

/// Deserialize a `.rkc` byte buffer. `origin` names the source (a file
/// path, "network", …) in error messages.
pub fn model_from_bytes(bytes: &[u8], origin: &str) -> Result<FittedModel> {
    let bad = |d: String| RkcError::model(origin, d);
    if bytes.len() < FIXED_PREFIX + 8 {
        return Err(bad(format!(
            "truncated: {} bytes is shorter than the fixed framing",
            bytes.len()
        )));
    }
    if bytes[..8] != MAGIC {
        return Err(bad("bad magic (not an .rkc model file)".into()));
    }
    // integrity before version negotiation: the outer framing (magic,
    // version, header length, trailing FNV-1a) is invariant across ALL
    // format versions, so a checksum mismatch always means corruption —
    // never a newer format — and a bit flip inside the version bytes is
    // diagnosed truthfully instead of as "upgrade rkc"
    let payload_end = bytes.len() - 8;
    let stored = u64::from_le_bytes(bytes[payload_end..].try_into().unwrap());
    let computed = checksum(&bytes[..payload_end]);
    if stored != computed {
        return Err(bad(format!(
            "checksum mismatch (stored {stored:#018x}, computed {computed:#018x}); \
             the file is corrupt"
        )));
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    if version > FORMAT_VERSION {
        return Err(RkcError::ModelVersion { found: version, supported: FORMAT_VERSION });
    }
    if version == 0 {
        return Err(bad("format version 0 is invalid".into()));
    }
    let hlen = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
    if FIXED_PREFIX + hlen > payload_end {
        return Err(bad(format!("truncated: header length {hlen} exceeds the file")));
    }
    let header_text = std::str::from_utf8(&bytes[FIXED_PREFIX..FIXED_PREFIX + hlen])
        .map_err(|_| bad("header is not UTF-8".into()))?;
    let header =
        Json::parse(header_text).map_err(|e| bad(format!("header is not valid JSON: {e}")))?;
    if header.get("format").and_then(Json::as_str) != Some("rkc-model") {
        return Err(bad("header 'format' field is not 'rkc-model'".into()));
    }

    let secs = header
        .get("sections")
        .and_then(Json::as_arr)
        .ok_or_else(|| bad("header is missing the 'sections' array".into()))?;
    let mut mats: BTreeMap<String, Mat> = BTreeMap::new();
    let mut off = FIXED_PREFIX + hlen;
    for s in secs {
        let name = s.str_field("name").map_err(|e| bad(e.to_string()))?.to_string();
        let rows = s.usize_field("rows").map_err(|e| bad(e.to_string()))?;
        let cols = s.usize_field("cols").map_err(|e| bad(e.to_string()))?;
        let n_bytes = rows
            .checked_mul(cols)
            .and_then(|c| c.checked_mul(8))
            .ok_or_else(|| bad(format!("section '{name}' shape {rows}x{cols} overflows")))?;
        let end = off
            .checked_add(n_bytes)
            .filter(|&e| e <= payload_end)
            .ok_or_else(|| {
                bad(format!(
                    "truncated payload: section '{name}' ({rows}x{cols}) runs past \
                     the end of the file"
                ))
            })?;
        let data: Vec<f64> = bytes[off..end]
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        off = end;
        if mats.insert(name.clone(), Mat::from_vec(rows, cols, data)).is_some() {
            return Err(bad(format!("duplicate section '{name}'")));
        }
    }
    if off != payload_end {
        return Err(bad(format!(
            "payload size mismatch: {} trailing bytes after the last section",
            payload_end - off
        )));
    }
    assemble_model(&header, mats, origin)
}

/// Write `bytes` to `path` atomically **and durably**, creating parent
/// directories as needed: temp file in the same directory → `fsync` the
/// temp file → `rename` into place → best-effort `fsync` of the parent
/// directory. An interrupted write never destroys an existing good file
/// at `path` (a concurrent reader sees old bytes or new bytes, never a
/// torn mix), and once this returns `Ok` the bytes survive a power cut
/// — rename-without-fsync can leave a zero-length file after a crash.
/// Shared by `.rkc` model saves and `.rkcs` stream checkpoints; the
/// [`crate::fault::MODEL_IO_FSYNC`] failpoint fires between the data
/// write and the fsync, the window a torn-write bug would hide in.
pub fn write_durable(path: &str, bytes: &[u8]) -> Result<()> {
    use std::io::Write as _;
    let parent = std::path::Path::new(path)
        .parent()
        .filter(|p| !p.as_os_str().is_empty());
    if let Some(parent) = parent {
        std::fs::create_dir_all(parent).map_err(|e| {
            RkcError::io(format!("creating directory {}", parent.display()), e)
        })?;
    }
    let tmp = format!("{path}.tmp.{}", std::process::id());
    let write_tmp = || -> Result<()> {
        let mut f = std::fs::File::create(&tmp)
            .map_err(|e| RkcError::io(format!("creating {tmp}"), e))?;
        f.write_all(bytes).map_err(|e| RkcError::io(format!("writing {tmp}"), e))?;
        crate::fault::trip(crate::fault::MODEL_IO_FSYNC)?;
        f.sync_all().map_err(|e| RkcError::io(format!("fsyncing {tmp}"), e))
    };
    if let Err(e) = write_tmp() {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    std::fs::rename(&tmp, path).map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        RkcError::io(format!("renaming {tmp} into place as {path}"), e)
    })?;
    // durability of the *name*: fsync the directory so the rename itself
    // survives a crash. Best-effort — not every filesystem lets a
    // directory handle sync, and the data above is already safe.
    let dir = parent.map(|p| p.to_path_buf()).unwrap_or_else(|| ".".into());
    if let Ok(d) = std::fs::File::open(&dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// Write `model` to `path` in the `.rkc` format via [`write_durable`]
/// (atomic + fsynced — see there for the crash-safety contract).
/// Failpoint site: [`crate::fault::MODEL_IO_WRITE`].
pub fn save_model(model: &FittedModel, path: &str) -> Result<()> {
    crate::fault::trip(crate::fault::MODEL_IO_WRITE)?;
    write_durable(path, &model_to_bytes(model))
}

/// Read a `.rkc` model from `path`.
pub fn load_model(path: &str) -> Result<FittedModel> {
    let bytes =
        std::fs::read(path).map_err(|e| RkcError::io(format!("reading model {path}"), e))?;
    model_from_bytes(&bytes, path)
}

fn uint(v: usize) -> Json {
    Json::Num(v as f64)
}

fn assemble_model(
    header: &Json,
    mut mats: BTreeMap<String, Mat>,
    origin: &str,
) -> Result<FittedModel> {
    let bad = |d: String| RkcError::model(origin, d);
    let str_of = |key: &str| {
        header
            .get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| bad(format!("header is missing string field '{key}'")))
    };
    let uint_of = |key: &str| {
        header
            .get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| bad(format!("header is missing integer field '{key}'")))
    };
    let kernel_spec = str_of("kernel")?;
    let kernel: Kernel = kernel_spec
        .parse()
        .map_err(|_| bad(format!("unknown kernel spec '{kernel_spec}'")))?;
    let k = uint_of("k")?;
    let n = uint_of("n")?;
    let rank = uint_of("rank")?;
    let n_pad = uint_of("n_pad")?;
    let batch = uint_of("batch")?;
    // downstream code asserts these invariants (block sources require
    // n_pad >= n and batch >= 1); a re-sealed file that violates them
    // must be a typed error here, not a panic there
    if n_pad < n {
        return Err(bad(format!("n_pad={n_pad} is smaller than n={n}")));
    }
    if batch == 0 {
        return Err(bad("batch must be at least 1".into()));
    }
    // absent in files written before the streaming subsystem: those are
    // batch fits, i.e. generation 0
    let generation =
        header.get("generation").and_then(Json::as_usize).unwrap_or(0) as u64;
    // absent in files written before the mixed-precision tier: f64, the
    // mode every older model served under
    let precision = match header.get("precision").and_then(Json::as_str) {
        None => crate::config::Precision::F64,
        Some(s) => s
            .parse()
            .map_err(|_| bad(format!("unknown precision '{s}'")))?,
    };
    let method = str_of("method")?.to_string();
    let objective = header.get("objective").and_then(Json::as_f64).unwrap_or(f64::NAN);
    let time_of = |key: &str| {
        let secs = header
            .get("times")
            .and_then(|t| t.get(key))
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        // try_from handles negatives, non-finite, AND values beyond
        // u64::MAX seconds — from_secs_f64 would panic on a re-sealed
        // file carrying an absurd time
        Duration::try_from_secs_f64(secs).unwrap_or(Duration::ZERO)
    };
    let mem = header.get("memory");
    let mem_uint = |key: &str| mem.and_then(|m| m.get(key)).and_then(Json::as_usize).unwrap_or(0);
    let memory = MethodMemory {
        method: mem
            .and_then(|m| m.get("method"))
            .and_then(Json::as_str)
            .unwrap_or(method.as_str())
            .to_string(),
        persistent: mem_uint("persistent"),
        transient: mem_uint("transient"),
        recovery: mem_uint("recovery"),
    };

    let labels_mat =
        mats.remove("labels").ok_or_else(|| bad("missing 'labels' section".into()))?;
    let labels = as_usize_vec(labels_mat.data())
        .map_err(|e| bad(format!("labels section: {e}")))?;
    if labels.len() != n {
        return Err(bad(format!("labels length {} does not match n={n}", labels.len())));
    }
    // labels index k-length per-cluster tables during prediction; an
    // out-of-range value must be a typed error here, not a panic there
    if let Some(&l) = labels.iter().find(|&&l| l >= k) {
        return Err(bad(format!("label {l} is out of range for k={k}")));
    }

    let embedding = match (mats.remove("embedding_y"), mats.remove("eigenvalues")) {
        (Some(y), Some(ev)) => {
            if y.cols() != n {
                return Err(bad(format!(
                    "embedding has {} columns but n={n}",
                    y.cols()
                )));
            }
            if ev.rows() != 1 || ev.cols() != y.rows() || y.rows() != rank {
                return Err(bad(format!(
                    "embedding rank {} / eigenvalue shape {}x{} disagree with rank={rank}",
                    y.rows(),
                    ev.rows(),
                    ev.cols()
                )));
            }
            Some(Embedding { y, eigenvalues: ev.data().to_vec() })
        }
        (None, None) => None,
        _ => {
            return Err(bad(
                "'embedding_y' and 'eigenvalues' sections must appear together".into(),
            ))
        }
    };

    let assigner_tag = str_of("assigner")?;
    let assigner = match assigner_tag {
        "embedded" | "input" => {
            let centroids = mats
                .remove("centroids")
                .ok_or_else(|| bad(format!("assigner '{assigner_tag}' needs 'centroids'")))?;
            if centroids.cols() != k {
                return Err(bad(format!(
                    "centroids have {} columns but k={k}",
                    centroids.cols()
                )));
            }
            if assigner_tag == "embedded" {
                if embedding.is_none() {
                    return Err(bad(
                        "assigner 'embedded' requires an embedding section".into(),
                    ));
                }
                // prediction compares r-vectors against these columns;
                // a row mismatch would index out of bounds downstream
                if centroids.rows() != rank {
                    return Err(bad(format!(
                        "embedded centroids have {} rows but rank={rank}",
                        centroids.rows()
                    )));
                }
                Assigner::Embedded { centroids }
            } else {
                if let Some(x) = mats.get("train_x") {
                    if centroids.rows() != x.rows() {
                        return Err(bad(format!(
                            "input-space centroids have {} rows but train_x has {}",
                            centroids.rows(),
                            x.rows()
                        )));
                    }
                }
                Assigner::Input { centroids }
            }
        }
        "kernel_clusters" => {
            let sizes_mat = mats
                .remove("cluster_sizes")
                .ok_or_else(|| bad("assigner 'kernel_clusters' needs 'cluster_sizes'".into()))?;
            let sizes = as_usize_vec(sizes_mat.data())
                .map_err(|e| bad(format!("cluster_sizes section: {e}")))?;
            let self_terms = mats
                .remove("self_terms")
                .ok_or_else(|| bad("assigner 'kernel_clusters' needs 'self_terms'".into()))?
                .data()
                .to_vec();
            if sizes.len() != k || self_terms.len() != k {
                return Err(bad(format!(
                    "cluster_sizes/self_terms lengths {}/{} do not match k={k}",
                    sizes.len(),
                    self_terms.len()
                )));
            }
            Assigner::KernelClusters { sizes, self_terms }
        }
        other => return Err(bad(format!("unknown assigner '{other}'"))),
    };

    let train_x = mats.remove("train_x");
    if let Some(x) = &train_x {
        if x.cols() != n {
            return Err(bad(format!(
                "train_x has {} columns but n={n}",
                x.cols()
            )));
        }
    }
    if !mats.is_empty() {
        let names: Vec<&str> = mats.keys().map(String::as_str).collect();
        return Err(bad(format!("unknown sections {names:?}")));
    }

    Ok(FittedModel {
        kernel,
        k,
        embedding,
        labels,
        assigner,
        train_x,
        train_cols: std::sync::OnceLock::new(),
        precision,
        f32_state: std::sync::OnceLock::new(),
        n_pad,
        batch,
        generation,
        metrics: FitMetrics {
            method,
            n,
            rank,
            objective,
            memory,
            sketch_time: time_of("sketch"),
            recovery_time: time_of("recovery"),
            kmeans_time: time_of("kmeans"),
        },
    })
}

/// Decode integer-valued f64 sections (labels, cluster sizes) with a
/// strict exactness check — anything fractional, negative, or beyond
/// 2⁵³ means the file lies about its contents.
fn as_usize_vec(data: &[f64]) -> std::result::Result<Vec<usize>, String> {
    const MAX_EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
    data.iter()
        .map(|&v| {
            if v.is_finite() && v >= 0.0 && v.fract() == 0.0 && v < MAX_EXACT {
                Ok(v as usize)
            } else {
                Err(format!("value {v} is not an exact non-negative integer"))
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::KernelClusterer;
    use crate::config::Method;
    use crate::data;
    use crate::rng::Pcg64;

    fn fit(method: Method) -> FittedModel {
        let ds = data::cross_lines(&mut Pcg64::seed(31), 64);
        KernelClusterer::new(2)
            .method(method)
            .rank(2)
            .oversample(8)
            .seed(17)
            .fit(&ds.x)
            .unwrap()
    }

    fn all_methods() -> Vec<Method> {
        vec![
            Method::OnePass,
            Method::GaussianOnePass,
            Method::Nystrom { m: 30 },
            Method::Exact,
            Method::FullKernel,
            Method::PlainKmeans,
        ]
    }

    #[test]
    fn roundtrip_is_bit_exact_for_every_method() {
        let query = data::cross_lines(&mut Pcg64::seed(32), 24).x;
        for method in all_methods() {
            let model = fit(method);
            let bytes = model_to_bytes(&model);
            let back = model_from_bytes(&bytes, "mem").unwrap_or_else(|e| {
                panic!("{method}: roundtrip failed: {e}")
            });
            assert_eq!(back.labels(), model.labels(), "{method}");
            assert_eq!(back.k(), model.k(), "{method}");
            assert_eq!(back.kernel(), model.kernel(), "{method}");
            assert_eq!(back.metrics().method, model.metrics().method, "{method}");
            assert_eq!(back.metrics().n, model.metrics().n, "{method}");
            assert_eq!(back.metrics().rank, model.metrics().rank, "{method}");
            assert_eq!(back.metrics().memory, model.metrics().memory, "{method}");
            assert_eq!(
                back.predict(&query).unwrap(),
                model.predict(&query).unwrap(),
                "{method}: reloaded predictions must be identical"
            );
            match (model.embedding(), back.embedding()) {
                (Some(a), Some(b)) => {
                    assert_eq!(a.y.data(), b.y.data(), "{method}: embedding bits");
                    assert_eq!(a.eigenvalues, b.eigenvalues, "{method}: eigenvalue bits");
                    assert_eq!(
                        model.embed(&query).unwrap().data(),
                        back.embed(&query).unwrap().data(),
                        "{method}: out-of-sample embedding bits"
                    );
                }
                (None, None) => {}
                _ => panic!("{method}: embedding presence changed across the roundtrip"),
            }
        }
    }

    #[test]
    fn save_load_file_roundtrip() {
        let _g = crate::fault::test_guard(); // saves cross a failpoint site
        let model = fit(Method::OnePass);
        let path = std::env::temp_dir()
            .join(format!("rkc_model_io_{}.rkc", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        model.save(&path).unwrap();
        let back = FittedModel::load(&path).unwrap();
        assert_eq!(back.labels(), model.labels());
        let err = back.approx_error().unwrap();
        assert!(err.is_finite() && err < 1.0, "reloaded approx error {err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn write_durable_is_atomic_under_injected_fsync_faults() {
        let _g = crate::fault::test_guard();
        let dir = std::env::temp_dir().join(format!("rkc_durable_{}", std::process::id()));
        let path = dir.join("m.bin").to_str().unwrap().to_string();
        write_durable(&path, b"generation-1").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"generation-1");
        // a fault between write and fsync must abort the whole save:
        // the previous file survives byte-for-byte, no temp litter
        crate::fault::configure("model_io.fsync=io_error:1.0").unwrap();
        let err = write_durable(&path, b"generation-2").unwrap_err();
        assert!(err.is_transient(), "{err}");
        crate::fault::clear();
        assert_eq!(std::fs::read(&path).unwrap(), b"generation-1");
        assert_eq!(
            std::fs::read_dir(&dir).unwrap().count(),
            1,
            "failed write left a temp file behind"
        );
        // and save_model's own site aborts before any bytes move
        crate::fault::configure("model_io.write=io_error:1.0").unwrap();
        let err = save_model(&fit(Method::OnePass), &path).unwrap_err();
        assert!(err.is_transient(), "{err}");
        crate::fault::clear();
        assert_eq!(std::fs::read(&path).unwrap(), b"generation-1");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_magic_is_a_typed_model_error() {
        let mut bytes = model_to_bytes(&fit(Method::OnePass));
        bytes[0] = b'X';
        let err = model_from_bytes(&bytes, "mem").unwrap_err();
        assert!(matches!(err, RkcError::Model { .. }), "{err}");
        assert!(err.to_string().contains("magic"), "{err}");
    }

    #[test]
    fn corrupt_header_byte_fails_the_checksum() {
        let mut bytes = model_to_bytes(&fit(Method::Exact));
        bytes[FIXED_PREFIX + 3] ^= 0x40; // flip a bit inside the JSON header
        let err = model_from_bytes(&bytes, "mem").unwrap_err();
        assert!(matches!(err, RkcError::Model { .. }), "{err}");
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn truncated_payload_is_a_typed_model_error() {
        let bytes = model_to_bytes(&fit(Method::Nystrom { m: 30 }));
        let err = model_from_bytes(&bytes[..bytes.len() - 16], "mem").unwrap_err();
        assert!(matches!(err, RkcError::Model { .. }), "{err}");
        // a 5-byte stub dies on the framing check, not a panic
        let err = model_from_bytes(&bytes[..5], "mem").unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    #[test]
    fn newer_format_version_is_a_typed_version_error() {
        let mut bytes = model_to_bytes(&fit(Method::PlainKmeans));
        bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
        // re-seal so the version check (not the checksum) is what fires
        let end = bytes.len() - 8;
        let ck = checksum(&bytes[..end]);
        bytes[end..].copy_from_slice(&ck.to_le_bytes());
        let err = model_from_bytes(&bytes, "mem").unwrap_err();
        assert!(
            matches!(err, RkcError::ModelVersion { found: 99, supported: FORMAT_VERSION }),
            "{err}"
        );
    }

    #[test]
    fn garbage_header_with_valid_checksum_is_a_typed_model_error() {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        let hdr = b"this is not json";
        bytes.extend_from_slice(&(hdr.len() as u32).to_le_bytes());
        bytes.extend_from_slice(hdr);
        let ck = checksum(&bytes);
        bytes.extend_from_slice(&ck.to_le_bytes());
        let err = model_from_bytes(&bytes, "mem").unwrap_err();
        assert!(err.to_string().contains("JSON"), "{err}");
    }

    #[test]
    fn full_kernel_infinite_self_terms_survive_the_binary_payload() {
        // a fit whose k exceeds the populated clusters can carry
        // f64::INFINITY self-terms; those travel in the payload (JSON
        // could not hold them) and must come back bit-identical
        let ds = data::gaussian_blobs(&mut Pcg64::seed(40), 40, 3, 2, 0.2);
        let model = KernelClusterer::new(4)
            .method(Method::FullKernel)
            .kmeans_restarts(2)
            .seed(3)
            .fit(&ds.x)
            .unwrap();
        let back = model_from_bytes(&model_to_bytes(&model), "mem").unwrap();
        assert_eq!(back.predict(&ds.x).unwrap(), model.predict(&ds.x).unwrap());
    }

    #[test]
    fn generation_survives_the_roundtrip_and_defaults_to_zero() {
        let mut model = fit(Method::OnePass);
        assert_eq!(model.generation(), 0, "batch fits are generation 0");
        model.set_generation(42);
        let back = model_from_bytes(&model_to_bytes(&model), "mem").unwrap();
        assert_eq!(back.generation(), 42);

        // a file written without the field (pre-streaming) loads as 0:
        // strip it from the header and re-seal
        let bytes = model_to_bytes(&model);
        let hlen = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        let text = std::str::from_utf8(&bytes[FIXED_PREFIX..FIXED_PREFIX + hlen]).unwrap();
        let stripped = text.replace("\"generation\":42,", "");
        assert_ne!(stripped, text, "header must have carried the field");
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&(stripped.len() as u32).to_le_bytes());
        out.extend_from_slice(stripped.as_bytes());
        out.extend_from_slice(&bytes[FIXED_PREFIX + hlen..bytes.len() - 8]);
        let ck = checksum(&out);
        out.extend_from_slice(&ck.to_le_bytes());
        let old = model_from_bytes(&out, "mem").unwrap();
        assert_eq!(old.generation(), 0);
    }

    #[test]
    fn precision_survives_the_roundtrip_and_defaults_to_f64() {
        use crate::config::Precision;
        let mut model = fit(Method::OnePass);
        assert_eq!(model.precision(), Precision::F64);
        model.set_precision(Precision::F32);
        let back = model_from_bytes(&model_to_bytes(&model), "mem").unwrap();
        assert_eq!(back.precision(), Precision::F32);

        // a file written before the field existed loads as f64: strip it
        // from the header and re-seal (same surgery as the generation test)
        let bytes = model_to_bytes(&model);
        let hlen = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        let text = std::str::from_utf8(&bytes[FIXED_PREFIX..FIXED_PREFIX + hlen]).unwrap();
        let stripped = text.replace("\"precision\":\"f32\",", "");
        assert_ne!(stripped, text, "header must have carried the field");
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&(stripped.len() as u32).to_le_bytes());
        out.extend_from_slice(stripped.as_bytes());
        out.extend_from_slice(&bytes[FIXED_PREFIX + hlen..bytes.len() - 8]);
        let ck = checksum(&out);
        out.extend_from_slice(&ck.to_le_bytes());
        let old = model_from_bytes(&out, "mem").unwrap();
        assert_eq!(old.precision(), Precision::F64);

        // a garbage value is a typed error, not a silent default
        let garbled = text.replace("\"precision\":\"f32\"", "\"precision\":\"f16\"");
        let mut bad = Vec::new();
        bad.extend_from_slice(&MAGIC);
        bad.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        bad.extend_from_slice(&(garbled.len() as u32).to_le_bytes());
        bad.extend_from_slice(garbled.as_bytes());
        bad.extend_from_slice(&bytes[FIXED_PREFIX + hlen..bytes.len() - 8]);
        let ck = checksum(&bad);
        bad.extend_from_slice(&ck.to_le_bytes());
        assert!(model_from_bytes(&bad, "mem").is_err());
    }

    #[test]
    fn checksum_is_fnv1a() {
        // spot-check against the published FNV-1a test vectors
        assert_eq!(checksum(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(checksum(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
