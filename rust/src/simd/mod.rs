//! Runtime-dispatched SIMD kernel tier under the shared compute core.
//!
//! Every dense hot inner loop in the crate — the GEMM axpy
//! ([`crate::linalg::gemm`]), the FWHT butterfly
//! ([`crate::sketch::fwht_inplace`]), the K-means argmin scan
//! ([`crate::clustering`]), and the f32 serving dot/axpy — routes
//! through one [`KernelTable`] of plain function pointers. The table is
//! selected **once per process** behind a `OnceLock` ([`dispatch`]):
//! AVX2+FMA on x86_64, NEON on aarch64, the scalar kernels everywhere
//! else, overridable for testing with `RKC_SIMD=scalar|avx2|neon|auto`.
//!
//! # Determinism contract (scoped per ISA)
//!
//! Each kernel pins exactly one summation order, so **within an ISA**
//! the crate-wide `threads = 1 ≡ threads = N` bit-identity contract
//! holds unchanged — threads partition rows/points, never a reduction,
//! and the per-element op sequence is fixed by the selected table.
//! **Across ISAs** results may differ in the last ulps (FMA fuses the
//! axpy multiply-add; lane-blocked reductions reassociate the f32 dot),
//! and the contract is the oracle bound instead:
//! [`crate::linalg::matmul_reference`] agreement ≤ 1e-12 and the
//! explicit-Hadamard / sequential-scan references in
//! `tests/properties.rs`. Two kernels are *exactly* order-preserving and
//! therefore bit-identical to scalar on every ISA: the FWHT butterfly
//! (purely elementwise `a+b` / `a−b`) and the f64 argmin scan (same
//! `yn + cn − 2g` op order, no FMA, first-minimum tie-breaking
//! reproduced lexicographically).
//!
//! Selecting an ISA the host cannot run (`RKC_SIMD=neon` on x86_64, or
//! `avx2` on a machine without it) falls back to scalar with a warning
//! on stderr rather than erroring: the override exists for CI matrices
//! and debugging, and a hard failure would turn a typo into an outage.

use std::sync::OnceLock;

/// Instruction set a [`KernelTable`] was built for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// portable scalar kernels — the universal fallback and the
    /// cross-ISA reference implementation
    Scalar,
    /// x86_64 AVX2 + FMA (4 × f64 / 8 × f32 lanes)
    Avx2,
    /// aarch64 NEON (2 × f64 / 4 × f32 lanes)
    Neon,
}

impl Isa {
    /// Stable lowercase name (the `RKC_SIMD` value and the
    /// `rkc_simd_isa` metric label).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }
}

/// The dispatched inner-loop kernels. Plain `fn` pointers so one
/// indirect call hoisted outside the loop replaces any per-iteration
/// feature checks; all five share the per-ISA determinism contract
/// documented at the module level.
pub struct KernelTable {
    pub isa: Isa,
    /// `c[i] += a · b[i]` over `min(c.len, b.len)` elements — the GEMM
    /// inner loop. Ascending-index order; FMA fuses the rounding on
    /// AVX2/NEON (per-ISA pinned, not bit-equal to scalar).
    pub axpy: fn(&mut [f64], f64, &[f64]),
    /// One FWHT butterfly layer over paired halves:
    /// `(lo[i], hi[i]) ← (lo[i]+hi[i], lo[i]−hi[i])`. Purely
    /// elementwise, bit-identical to scalar on every ISA.
    pub butterfly: fn(&mut [f64], &mut [f64]),
    /// K-means argmin over one point's cross-term row: returns
    /// `(argmin_c, min_c)` of `clamp₀(yn + cn[c] − 2·g[c])` with the
    /// scalar path's exact semantics — same op order (no FMA), NaN
    /// never wins (`bestd` stays `+∞`), first minimum (lowest `c`) on
    /// ties. Bit-identical to scalar on every ISA.
    pub argmin_dist2: fn(&[f64], f64, &[f64]) -> (usize, f64),
    /// `c[i] += a · b[i]` in f32 — the mixed-precision serving
    /// accumulator.
    pub axpy_f32: fn(&mut [f32], f32, &[f32]),
    /// f32 dot product — the mixed-precision gram kernel. One pinned
    /// reduction order per ISA (single lane-block accumulator, lanes
    /// summed in lane order, sequential tail).
    pub dot_f32: fn(&[f32], &[f32]) -> f32,
}

// ---- scalar kernels (reference semantics, always available) --------

/// `pub(crate)` + `#[inline]` so the GEMM can monomorphize a direct
/// call on the scalar tier (auto-vectorized by the compiler) instead
/// of paying an opaque indirect call per axpy; each `c[i]` is
/// independent (no reduction), so any codegen of this body is
/// bit-identical to the table's fn-pointer form.
#[inline]
pub(crate) fn axpy_scalar(c: &mut [f64], a: f64, b: &[f64]) {
    for (o, &v) in c.iter_mut().zip(b) {
        *o += a * v;
    }
}

fn butterfly_scalar(lo: &mut [f64], hi: &mut [f64]) {
    for (l, h) in lo.iter_mut().zip(hi.iter_mut()) {
        let a = *l;
        let b = *h;
        *l = a + b;
        *h = a - b;
    }
}

fn argmin_dist2_scalar(g: &[f64], yn: f64, cn: &[f64]) -> (usize, f64) {
    // every kernel makes mismatched lengths the same loud panic — a
    // silent truncation in one ISA would split the bit-identity
    // contract into panic-vs-wrong-answer depending on dispatch
    assert_eq!(g.len(), cn.len(), "argmin_dist2 slice length mismatch");
    let mut best = 0usize;
    let mut bestd = f64::INFINITY;
    for (c, &gv) in g.iter().enumerate() {
        let d = clamp_dist2(yn + cn[c] - 2.0 * gv);
        if d < bestd {
            bestd = d;
            best = c;
        }
    }
    (best, bestd)
}

/// Clamp at zero without scrubbing NaN (`f64::max` would turn NaN into
/// 0.0 and let a poisoned coordinate win the argmin with a bogus
/// perfect distance — the comparison form keeps NaN as NaN). The one
/// shared copy: the argmin kernels here and every other norm-identity
/// distance in `clustering::kmeans` must clamp identically, or the
/// per-ISA bit-identity contract silently splits.
#[inline]
pub(crate) fn clamp_dist2(d: f64) -> f64 {
    if d < 0.0 {
        0.0
    } else {
        d
    }
}

fn axpy_f32_scalar(c: &mut [f32], a: f32, b: &[f32]) {
    for (o, &v) in c.iter_mut().zip(b) {
        *o += a * v;
    }
}

fn dot_f32_scalar(a: &[f32], b: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        s += x * y;
    }
    s
}

static SCALAR: KernelTable = KernelTable {
    isa: Isa::Scalar,
    axpy: axpy_scalar,
    butterfly: butterfly_scalar,
    argmin_dist2: argmin_dist2_scalar,
    axpy_f32: axpy_f32_scalar,
    dot_f32: dot_f32_scalar,
};

// ---- AVX2 + FMA kernels (x86_64) -----------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{clamp_dist2, Isa, KernelTable};
    use std::arch::x86_64::*;

    /// The safe wrappers below may only be installed in a table after
    /// [`super::avx2_available`] returned true for this process — that
    /// runtime check is the safety contract every `unsafe` block here
    /// leans on.
    pub(super) static TABLE: KernelTable = KernelTable {
        isa: Isa::Avx2,
        axpy,
        butterfly,
        argmin_dist2,
        axpy_f32,
        dot_f32,
    };

    fn axpy(c: &mut [f64], a: f64, b: &[f64]) {
        // SAFETY: table construction verified avx2+fma at runtime
        // (avx2_available), which is exactly the target-feature set the
        // callee enables.
        unsafe { axpy_impl(c, a, b) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn axpy_impl(c: &mut [f64], a: f64, b: &[f64]) {
        let n = c.len().min(b.len());
        let lanes = n - n % 4;
        // SAFETY: every load/store stays inside c[..lanes] / b[..lanes]
        // (i advances in steps of 4 strictly below `lanes <= len`), and
        // the intrinsics are available per the wrapper's contract.
        unsafe {
            let va = _mm256_set1_pd(a);
            let cp = c.as_mut_ptr();
            let bp = b.as_ptr();
            let mut i = 0;
            while i < lanes {
                let vc = _mm256_loadu_pd(cp.add(i));
                let vb = _mm256_loadu_pd(bp.add(i));
                _mm256_storeu_pd(cp.add(i), _mm256_fmadd_pd(va, vb, vc));
                i += 4;
            }
        }
        // scalar tail in ascending order: same pinned AVX2 kernel order
        // on every run (the tail's rounding differs from the fused
        // lanes, which is fine — the order is fixed, not mixed)
        for i in lanes..n {
            c[i] = a.mul_add(b[i], c[i]);
        }
    }

    fn butterfly(lo: &mut [f64], hi: &mut [f64]) {
        // SAFETY: table construction verified avx2+fma at runtime.
        unsafe { butterfly_impl(lo, hi) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn butterfly_impl(lo: &mut [f64], hi: &mut [f64]) {
        let n = lo.len().min(hi.len());
        let lanes = n - n % 4;
        // SAFETY: accesses bounded by `lanes <= n <= both lengths`;
        // intrinsics available per the wrapper's contract.
        unsafe {
            let lp = lo.as_mut_ptr();
            let hp = hi.as_mut_ptr();
            let mut i = 0;
            while i < lanes {
                let a = _mm256_loadu_pd(lp.add(i));
                let b = _mm256_loadu_pd(hp.add(i));
                _mm256_storeu_pd(lp.add(i), _mm256_add_pd(a, b));
                _mm256_storeu_pd(hp.add(i), _mm256_sub_pd(a, b));
                i += 4;
            }
        }
        for i in lanes..n {
            let a = lo[i];
            let b = hi[i];
            lo[i] = a + b;
            hi[i] = a - b;
        }
    }

    fn argmin_dist2(g: &[f64], yn: f64, cn: &[f64]) -> (usize, f64) {
        // SAFETY: table construction verified avx2+fma at runtime.
        unsafe { argmin_dist2_impl(g, yn, cn) }
    }

    /// Vectorized argmin with the scalar path's exact arithmetic:
    /// `(yn + cn[c]) − 2·g[c]` via separate add/mul/sub (no FMA — a
    /// fused product would shift distances by an ulp and flip
    /// near-ties), clamp-by-blend (keeps NaN, unlike `max_pd`), strict
    /// `<` lane updates, and a lexicographic `(d, index)` horizontal
    /// reduction so equal minima resolve to the lowest index exactly
    /// like the sequential scan.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn argmin_dist2_impl(g: &[f64], yn: f64, cn: &[f64]) -> (usize, f64) {
        // same loud panic as the scalar kernel on mismatched lengths
        assert_eq!(g.len(), cn.len(), "argmin_dist2 slice length mismatch");
        let k = g.len();
        let lanes = k - k % 4;
        let mut best = 0usize;
        let mut bestd = f64::INFINITY;
        if lanes > 0 {
            let mut dv = [0.0f64; 4];
            let mut iv = [0.0f64; 4];
            // SAFETY: loads bounded by `lanes <= k == both lengths`;
            // intrinsics available per the wrapper's contract.
            unsafe {
                let vyn = _mm256_set1_pd(yn);
                let vtwo = _mm256_set1_pd(2.0);
                let vzero = _mm256_setzero_pd();
                let mut vbd = _mm256_set1_pd(f64::INFINITY);
                let mut vbi = _mm256_setzero_pd();
                let mut vidx = _mm256_set_pd(3.0, 2.0, 1.0, 0.0);
                let vfour = _mm256_set1_pd(4.0);
                let gp = g.as_ptr();
                let cp = cn.as_ptr();
                let mut c = 0;
                while c < lanes {
                    let vg = _mm256_loadu_pd(gp.add(c));
                    let vcn = _mm256_loadu_pd(cp.add(c));
                    let mut vd =
                        _mm256_sub_pd(_mm256_add_pd(vyn, vcn), _mm256_mul_pd(vtwo, vg));
                    // clamp: d < 0 → 0, NaN compares false and survives
                    let neg = _mm256_cmp_pd::<_CMP_LT_OQ>(vd, vzero);
                    vd = _mm256_blendv_pd(vd, vzero, neg);
                    // strict < keeps the earliest index within a lane
                    let lt = _mm256_cmp_pd::<_CMP_LT_OQ>(vd, vbd);
                    vbd = _mm256_blendv_pd(vbd, vd, lt);
                    vbi = _mm256_blendv_pd(vbi, vidx, lt);
                    vidx = _mm256_add_pd(vidx, vfour);
                    c += 4;
                }
                _mm256_storeu_pd(dv.as_mut_ptr(), vbd);
                _mm256_storeu_pd(iv.as_mut_ptr(), vbi);
            }
            // lexicographic (d, index): the global first minimum may sit
            // in any lane, and equal minima must resolve to the lowest
            // index — strict-d-only lane order would miss that
            for l in 0..4 {
                let d = dv[l];
                let idx = iv[l] as usize;
                if d < bestd || (d == bestd && idx < best) {
                    bestd = d;
                    best = idx;
                }
            }
        }
        // tail indices all exceed the vector indices, so strict `<`
        // alone preserves first-minimum tie-breaking
        for c in lanes..k {
            let d = clamp_dist2(yn + cn[c] - 2.0 * g[c]);
            if d < bestd {
                bestd = d;
                best = c;
            }
        }
        (best, bestd)
    }

    fn axpy_f32(c: &mut [f32], a: f32, b: &[f32]) {
        // SAFETY: table construction verified avx2+fma at runtime.
        unsafe { axpy_f32_impl(c, a, b) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn axpy_f32_impl(c: &mut [f32], a: f32, b: &[f32]) {
        let n = c.len().min(b.len());
        let lanes = n - n % 8;
        // SAFETY: accesses bounded by `lanes <= n <= both lengths`;
        // intrinsics available per the wrapper's contract.
        unsafe {
            let va = _mm256_set1_ps(a);
            let cp = c.as_mut_ptr();
            let bp = b.as_ptr();
            let mut i = 0;
            while i < lanes {
                let vc = _mm256_loadu_ps(cp.add(i));
                let vb = _mm256_loadu_ps(bp.add(i));
                _mm256_storeu_ps(cp.add(i), _mm256_fmadd_ps(va, vb, vc));
                i += 8;
            }
        }
        for i in lanes..n {
            c[i] = a.mul_add(b[i], c[i]);
        }
    }

    fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: table construction verified avx2+fma at runtime.
        unsafe { dot_f32_impl(a, b) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn dot_f32_impl(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let lanes = n - n % 8;
        let mut acc = [0.0f32; 8];
        if lanes > 0 {
            // SAFETY: loads bounded by `lanes <= n <= both lengths`;
            // intrinsics available per the wrapper's contract.
            unsafe {
                let ap = a.as_ptr();
                let bp = b.as_ptr();
                let mut vacc = _mm256_setzero_ps();
                let mut i = 0;
                while i < lanes {
                    let va = _mm256_loadu_ps(ap.add(i));
                    let vb = _mm256_loadu_ps(bp.add(i));
                    vacc = _mm256_fmadd_ps(va, vb, vacc);
                    i += 8;
                }
                _mm256_storeu_ps(acc.as_mut_ptr(), vacc);
            }
        }
        // pinned reduction order: lanes in lane order, then the tail
        // sequentially — one fixed summation tree per ISA
        let mut s = 0.0f32;
        for v in acc {
            s += v;
        }
        for i in lanes..n {
            s += a[i] * b[i];
        }
        s
    }
}

// ---- NEON kernels (aarch64) ----------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{clamp_dist2, Isa, KernelTable};
    use std::arch::aarch64::*;

    /// Installed only after [`super::neon_available`] returned true —
    /// the safety contract for every `unsafe` block here (NEON is
    /// architecturally guaranteed on aarch64, but the check keeps the
    /// contract explicit and the override path honest).
    pub(super) static TABLE: KernelTable = KernelTable {
        isa: Isa::Neon,
        axpy,
        butterfly,
        argmin_dist2,
        axpy_f32,
        dot_f32,
    };

    fn axpy(c: &mut [f64], a: f64, b: &[f64]) {
        // SAFETY: table construction verified NEON at runtime.
        unsafe { axpy_impl(c, a, b) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn axpy_impl(c: &mut [f64], a: f64, b: &[f64]) {
        let n = c.len().min(b.len());
        let lanes = n - n % 2;
        // SAFETY: accesses bounded by `lanes <= n <= both lengths`;
        // intrinsics available per the wrapper's contract.
        unsafe {
            let cp = c.as_mut_ptr();
            let bp = b.as_ptr();
            let mut i = 0;
            while i < lanes {
                let vc = vld1q_f64(cp.add(i));
                let vb = vld1q_f64(bp.add(i));
                vst1q_f64(cp.add(i), vfmaq_n_f64(vc, vb, a));
                i += 2;
            }
        }
        for i in lanes..n {
            c[i] = a.mul_add(b[i], c[i]);
        }
    }

    fn butterfly(lo: &mut [f64], hi: &mut [f64]) {
        // SAFETY: table construction verified NEON at runtime.
        unsafe { butterfly_impl(lo, hi) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn butterfly_impl(lo: &mut [f64], hi: &mut [f64]) {
        let n = lo.len().min(hi.len());
        let lanes = n - n % 2;
        // SAFETY: accesses bounded by `lanes <= n <= both lengths`;
        // intrinsics available per the wrapper's contract.
        unsafe {
            let lp = lo.as_mut_ptr();
            let hp = hi.as_mut_ptr();
            let mut i = 0;
            while i < lanes {
                let a = vld1q_f64(lp.add(i));
                let b = vld1q_f64(hp.add(i));
                vst1q_f64(lp.add(i), vaddq_f64(a, b));
                vst1q_f64(hp.add(i), vsubq_f64(a, b));
                i += 2;
            }
        }
        for i in lanes..n {
            let a = lo[i];
            let b = hi[i];
            lo[i] = a + b;
            hi[i] = a - b;
        }
    }

    fn argmin_dist2(g: &[f64], yn: f64, cn: &[f64]) -> (usize, f64) {
        // SAFETY: table construction verified NEON at runtime.
        unsafe { argmin_dist2_impl(g, yn, cn) }
    }

    /// Same exact-arithmetic scheme as the AVX2 kernel (see its doc):
    /// separate add/mul/sub, clamp-by-select keeping NaN, strict `<`
    /// lane updates, lexicographic `(d, index)` horizontal reduction.
    #[target_feature(enable = "neon")]
    unsafe fn argmin_dist2_impl(g: &[f64], yn: f64, cn: &[f64]) -> (usize, f64) {
        // same loud panic as the scalar kernel on mismatched lengths
        assert_eq!(g.len(), cn.len(), "argmin_dist2 slice length mismatch");
        let k = g.len();
        let lanes = k - k % 2;
        let mut best = 0usize;
        let mut bestd = f64::INFINITY;
        if lanes > 0 {
            let mut dv = [0.0f64; 2];
            let mut iv = [0.0f64; 2];
            // SAFETY: loads bounded by `lanes <= k == both lengths`;
            // intrinsics available per the wrapper's contract.
            unsafe {
                let vyn = vdupq_n_f64(yn);
                let vtwo = vdupq_n_f64(2.0);
                let vzero = vdupq_n_f64(0.0);
                let mut vbd = vdupq_n_f64(f64::INFINITY);
                let mut vbi = vdupq_n_f64(0.0);
                let mut vidx = vsetq_lane_f64::<1>(1.0, vdupq_n_f64(0.0));
                let vstep = vdupq_n_f64(2.0);
                let gp = g.as_ptr();
                let cp = cn.as_ptr();
                let mut c = 0;
                while c < lanes {
                    let vg = vld1q_f64(gp.add(c));
                    let vcn = vld1q_f64(cp.add(c));
                    let mut vd = vsubq_f64(vaddq_f64(vyn, vcn), vmulq_f64(vtwo, vg));
                    // clamp: d < 0 → 0, NaN compares false and survives
                    let neg = vcltq_f64(vd, vzero);
                    vd = vbslq_f64(neg, vzero, vd);
                    // strict < keeps the earliest index within a lane
                    let lt = vcltq_f64(vd, vbd);
                    vbd = vbslq_f64(lt, vd, vbd);
                    vbi = vbslq_f64(lt, vidx, vbi);
                    vidx = vaddq_f64(vidx, vstep);
                    c += 2;
                }
                vst1q_f64(dv.as_mut_ptr(), vbd);
                vst1q_f64(iv.as_mut_ptr(), vbi);
            }
            for l in 0..2 {
                let d = dv[l];
                let idx = iv[l] as usize;
                if d < bestd || (d == bestd && idx < best) {
                    bestd = d;
                    best = idx;
                }
            }
        }
        for c in lanes..k {
            let d = clamp_dist2(yn + cn[c] - 2.0 * g[c]);
            if d < bestd {
                bestd = d;
                best = c;
            }
        }
        (best, bestd)
    }

    fn axpy_f32(c: &mut [f32], a: f32, b: &[f32]) {
        // SAFETY: table construction verified NEON at runtime.
        unsafe { axpy_f32_impl(c, a, b) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn axpy_f32_impl(c: &mut [f32], a: f32, b: &[f32]) {
        let n = c.len().min(b.len());
        let lanes = n - n % 4;
        // SAFETY: accesses bounded by `lanes <= n <= both lengths`;
        // intrinsics available per the wrapper's contract.
        unsafe {
            let cp = c.as_mut_ptr();
            let bp = b.as_ptr();
            let mut i = 0;
            while i < lanes {
                let vc = vld1q_f32(cp.add(i));
                let vb = vld1q_f32(bp.add(i));
                vst1q_f32(cp.add(i), vfmaq_n_f32(vc, vb, a));
                i += 4;
            }
        }
        for i in lanes..n {
            c[i] = a.mul_add(b[i], c[i]);
        }
    }

    fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: table construction verified NEON at runtime.
        unsafe { dot_f32_impl(a, b) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn dot_f32_impl(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let lanes = n - n % 4;
        let mut acc = [0.0f32; 4];
        if lanes > 0 {
            // SAFETY: loads bounded by `lanes <= n <= both lengths`;
            // intrinsics available per the wrapper's contract.
            unsafe {
                let ap = a.as_ptr();
                let bp = b.as_ptr();
                let mut vacc = vdupq_n_f32(0.0);
                let mut i = 0;
                while i < lanes {
                    let va = vld1q_f32(ap.add(i));
                    let vb = vld1q_f32(bp.add(i));
                    vacc = vfmaq_f32(vacc, va, vb);
                    i += 4;
                }
                vst1q_f32(acc.as_mut_ptr(), vacc);
            }
        }
        let mut s = 0.0f32;
        for v in acc {
            s += v;
        }
        for i in lanes..n {
            s += a[i] * b[i];
        }
        s
    }
}

// ---- detection and dispatch ----------------------------------------

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

#[cfg(target_arch = "aarch64")]
fn neon_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

/// The AVX2 table when this host can run it.
fn try_avx2() -> Option<&'static KernelTable> {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        return Some(&avx2::TABLE);
    }
    None
}

/// The NEON table when this host can run it.
fn try_neon() -> Option<&'static KernelTable> {
    #[cfg(target_arch = "aarch64")]
    if neon_available() {
        return Some(&neon::TABLE);
    }
    None
}

/// The portable scalar table — the universal fallback, and the
/// reference side of every scalar-vs-SIMD agreement test and `#simd`
/// bench row.
pub fn scalar_table() -> &'static KernelTable {
    &SCALAR
}

/// Every table this host can actually run, scalar first. Property tests
/// iterate this so a CI runner exercises exactly the kernels it has.
pub fn available_tables() -> Vec<&'static KernelTable> {
    let mut tables = vec![&SCALAR];
    tables.extend(try_avx2());
    tables.extend(try_neon());
    tables
}

/// Resolve an `RKC_SIMD` override (or `auto` when absent/unknown) to a
/// runnable table. Unavailable or unknown requests degrade to the best
/// available table with a stderr warning — see the module doc.
fn select(mode: Option<&str>) -> &'static KernelTable {
    let auto = || try_avx2().or_else(try_neon).unwrap_or(&SCALAR);
    match mode {
        None | Some("auto") | Some("") => auto(),
        Some("scalar") => &SCALAR,
        Some("avx2") => try_avx2().unwrap_or_else(|| {
            eprintln!("rkc: RKC_SIMD=avx2 unavailable on this host; using scalar kernels");
            &SCALAR
        }),
        Some("neon") => try_neon().unwrap_or_else(|| {
            eprintln!("rkc: RKC_SIMD=neon unavailable on this host; using scalar kernels");
            &SCALAR
        }),
        Some(other) => {
            eprintln!("rkc: unknown RKC_SIMD value '{other}' (want scalar|avx2|neon|auto); auto-detecting");
            auto()
        }
    }
}

/// The process-wide kernel table: ISA detection (or the `RKC_SIMD`
/// override) runs once, every later call is a single atomic load. The
/// first call also registers the `rkc_simd_isa` info gauge (value 1,
/// label `isa="…"`) so `/metrics` reports which kernels this process
/// dispatched.
pub fn dispatch() -> &'static KernelTable {
    static TABLE: OnceLock<&'static KernelTable> = OnceLock::new();
    TABLE.get_or_init(|| {
        let table = select(std::env::var("RKC_SIMD").ok().as_deref());
        crate::obs::registry()
            .gauge(
                "rkc_simd_isa",
                "Active SIMD kernel table (info gauge: value 1, ISA in the label).",
                &[("isa", table.isa.name())],
            )
            .set(1);
        table
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    fn vecf(rng: &mut Pcg64, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn every_available_axpy_matches_scalar_to_1e12() {
        let mut rng = Pcg64::seed(1);
        // odd lengths straddle every lane width (2, 4, 8) and force tails
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 31, 64, 65, 127] {
            let c0 = vecf(&mut rng, n);
            let b = vecf(&mut rng, n);
            let a = rng.normal();
            let mut want = c0.clone();
            axpy_scalar(&mut want, a, &b);
            for table in available_tables() {
                let mut got = c0.clone();
                (table.axpy)(&mut got, a, &b);
                for (g, w) in got.iter().zip(&want) {
                    assert!(
                        (g - w).abs() <= 1e-12 * w.abs().max(1.0),
                        "axpy[{}] n={n}: {g} vs {w}",
                        table.isa.name()
                    );
                }
            }
        }
    }

    #[test]
    fn butterfly_is_bit_identical_across_tables() {
        let mut rng = Pcg64::seed(2);
        for n in [0usize, 1, 2, 3, 5, 8, 13, 64, 65] {
            let lo0 = vecf(&mut rng, n);
            let hi0 = vecf(&mut rng, n);
            let (mut wl, mut wh) = (lo0.clone(), hi0.clone());
            butterfly_scalar(&mut wl, &mut wh);
            for table in available_tables() {
                let (mut gl, mut gh) = (lo0.clone(), hi0.clone());
                (table.butterfly)(&mut gl, &mut gh);
                assert_eq!(gl, wl, "butterfly lo [{}] n={n}", table.isa.name());
                assert_eq!(gh, wh, "butterfly hi [{}] n={n}", table.isa.name());
            }
        }
    }

    #[test]
    fn argmin_is_bit_identical_across_tables_including_ties_and_nan() {
        let mut rng = Pcg64::seed(3);
        for k in [1usize, 2, 3, 4, 5, 7, 8, 9, 16, 33] {
            for case in 0..20 {
                let mut g = vecf(&mut rng, k);
                let cn = vecf(&mut rng, k).iter().map(|v| v.abs()).collect::<Vec<_>>();
                let yn = rng.normal().abs();
                // force exact cross-lane ties and NaN poisoning in some cases
                if case % 3 == 0 && k > 2 {
                    g[k - 1] = g[0];
                }
                if case % 5 == 0 {
                    g[case % k] = f64::NAN;
                }
                let want = argmin_dist2_scalar(&g, yn, &cn);
                for table in available_tables() {
                    let got = (table.argmin_dist2)(&g, yn, &cn);
                    assert_eq!(got.0, want.0, "argmin idx [{}] k={k} case={case}", table.isa.name());
                    assert!(
                        got.1 == want.1 || (got.1.is_nan() && want.1.is_nan()),
                        "argmin dist [{}] k={k} case={case}: {} vs {}",
                        table.isa.name(),
                        got.1,
                        want.1
                    );
                }
            }
        }
    }

    #[test]
    fn all_nan_row_keeps_scalar_semantics() {
        let g = vec![f64::NAN; 6];
        let cn = vec![1.0; 6];
        for table in available_tables() {
            let (idx, d) = (table.argmin_dist2)(&g, 1.0, &cn);
            assert_eq!(idx, 0, "[{}]", table.isa.name());
            assert_eq!(d, f64::INFINITY, "[{}]", table.isa.name());
        }
    }

    #[test]
    fn f32_kernels_match_scalar_within_f32_rounding() {
        let mut rng = Pcg64::seed(4);
        for n in [0usize, 1, 3, 4, 7, 8, 9, 17, 64, 100] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let want = dot_f32_scalar(&a, &b);
            for table in available_tables() {
                let got = (table.dot_f32)(&a, &b);
                // reassociation across ≤ 8 lanes: a few ulps at f32
                let tol = 1e-5f32 * want.abs().max(1.0) * (n.max(1) as f32).sqrt();
                assert!((got - want).abs() <= tol, "dot_f32 [{}] n={n}", table.isa.name());

                let mut cw: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
                let mut cg = cw.clone();
                let s = rng.normal() as f32;
                axpy_f32_scalar(&mut cw, s, &a);
                (table.axpy_f32)(&mut cg, s, &a);
                for (g, w) in cg.iter().zip(&cw) {
                    assert!(
                        (g - w).abs() <= 1e-5 * w.abs().max(1.0),
                        "axpy_f32 [{}] n={n}",
                        table.isa.name()
                    );
                }
            }
        }
    }

    #[test]
    fn dispatch_is_stable_and_named() {
        let a = dispatch();
        let b = dispatch();
        assert!(std::ptr::eq(a, b), "dispatch must return one table per process");
        assert!(["scalar", "avx2", "neon"].contains(&a.isa.name()));
        // the override env var is honored at first call; here we only
        // check the selection logic directly (the process-level env
        // behavior is exercised by the CI isa-matrix job)
        assert_eq!(select(Some("scalar")).isa, Isa::Scalar);
        assert_eq!(select(Some("definitely-not-an-isa")).isa, select(None).isa);
    }
}
