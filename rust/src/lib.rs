//! # rkc — Randomized Kernel Clustering
//!
//! Production reproduction of *"A Randomized Approach to Efficient Kernel
//! Clustering"* (Pourkamali-Anaraki & Becker, IEEE GlobalSIP 2016): one-pass
//! SRHT-preconditioned randomized low-rank kernel approximation followed by
//! standard K-means on the embedded points, with Nyström / exact-EVD /
//! full-kernel baselines, a streaming rust coordinator, a fork-join parallel
//! execution subsystem threading every stage, and XLA-compiled JAX+Pallas
//! compute artifacts. Start with the repository `README.md`; the system
//! design, memory model, and determinism contract live in
//! `ARCHITECTURE.md`.
//!
//! ## Quickstart
//!
//! The crate's public face is [`api::KernelClusterer`]: a typed builder
//! whose `fit` returns a [`api::FittedModel`] with labels, the recovered
//! embedding, and out-of-sample `embed`/`predict`:
//!
//! ```
//! use rkc::api::KernelClusterer;
//! use rkc::data;
//! use rkc::rng::Pcg64;
//!
//! let ds = data::cross_lines(&mut Pcg64::seed(7), 512);
//! let model = KernelClusterer::new(2) // k = 2 clusters
//!     .rank(2)                        // embedding rank r
//!     .oversample(10)                 // sketch width r' = r + l
//!     .fit(&ds.x)?;
//! let accuracy = rkc::clustering::accuracy(model.labels(), &ds.labels, 2);
//! assert!(accuracy > 0.9);
//! let fresh = data::cross_lines(&mut Pcg64::seed(8), 64);
//! let assigned = model.predict(&fresh.x)?; // never-seen points
//! assert_eq!(assigned.len(), 64);
//! # Ok::<(), rkc::error::RkcError>(())
//! ```
//!
//! ## Layer map
//!
//! - [`api`] — **the public face**: `KernelClusterer` builder → `fit` →
//!   `FittedModel`, the [`api::Embedder`] trait unifying every low-rank
//!   method, out-of-sample embedding/prediction.
//! - [`model_io`] — versioned, endianness-explicit `.rkc` binary
//!   persistence for fitted models (`FittedModel::save`/`load`),
//!   bit-exact across the roundtrip.
//! - [`stream`] — online one-pass clustering: `StreamClusterer` folds
//!   unbounded point batches into a running SRHT sketch and, on a
//!   refresh policy, publishes warm-started refits into a live
//!   [`serve::ModelRegistry`] under monotone generations (atomic
//!   hot-swap — requests see old or new, never a blend).
//! - [`serve`] — the batched serving runtime: `ModelServer`
//!   micro-batches concurrent `embed`/`predict` requests through a
//!   bounded queue onto the fork-join pool; `ModelRegistry` serves many
//!   named models from one process; a zero-dependency HTTP/1.1
//!   keep-alive front-end (worker pool over a bounded connection queue)
//!   exposes `/models/{name}/predict|embed`, runtime load/unload, and
//!   the legacy single-model routes.
//! - [`error`] — the crate-wide [`error::RkcError`]; every library layer
//!   returns it (no stringly-typed or `anyhow` errors anywhere).
//! - [`coordinator`] — L3: the streaming pipeline (scheduler, sketch
//!   accumulator, sharded multi-producer/consumer) plus the experiment
//!   driver, now a thin compatibility client of [`api`].
//! - [`experiment`] — the declarative harness: `.plan` files describing
//!   a trial grid (method × kernel × rank × …, seed-per-trial derived
//!   from coordinates, JSONL rows byte-identical across reruns and
//!   thread counts) or load scenarios replayed against a live [`serve`]
//!   registry (open-loop/burst/slow-loris/partial-write, latency
//!   percentiles + shed counts).
//! - [`util::parallel`] — the scoped fork-join substrate every parallel
//!   stage shares; `threads(0)` auto-detection and the determinism
//!   contract (`threads = 1` ≡ `threads = N`, bit for bit).
//! - [`fault`] — failpoint injection for chaos testing: named sites on
//!   the IO/availability edges (`model_io.write`, `serve.load`,
//!   `http.accept`, …) armed via `RKC_FAULTS`, deterministic per-site
//!   decision streams, a single relaxed atomic load when disarmed.
//! - [`obs`] — process-wide observability: the metrics registry
//!   (counters / gauges / log-bucket histograms rendered as Prometheus
//!   text at `GET /metrics`), span tracing into a bounded lock-striped
//!   ring (`--trace out.jsonl`), strictly out-of-band — determinism
//!   contracts hold with tracing on or off.
//! - [`runtime`] — PJRT wrapper loading `artifacts/*.hlo.txt` (L2/L1
//!   compute compiled from JAX + Pallas by `python/compile/aot.py`);
//!   gated behind the `xla` cargo feature with a graceful native
//!   fallback when absent.
//! - [`simd`] — the runtime-dispatched SIMD kernel tier (AVX2+FMA /
//!   NEON / scalar) under every dense inner loop, selected once per
//!   process, `RKC_SIMD`-overridable, with the determinism contract
//!   scoped per ISA.
//! - [`lowrank`], [`sketch`], [`kernels`], [`clustering`], [`linalg`],
//!   [`rng`], [`data`], [`metrics`], [`config`], [`bench_harness`],
//!   [`util`] — the substrates, all implemented from scratch.

// The SIMD tier is the only unsafe code in the crate; every unsafe
// operation inside an unsafe fn must sit in its own `// SAFETY:`-
// documented block (clippy::undocumented_unsafe_blocks enforces the
// comments, this lint the blocks).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod clustering;
pub mod data;
pub mod error;
pub mod kernels;
pub mod linalg;
pub mod lowrank;
pub mod rng;
pub mod simd;
pub mod sketch;
pub mod util;

pub mod api;
pub mod bench_harness;
pub mod config;
pub mod coordinator;
pub mod experiment;
pub mod fault;
pub mod metrics;
pub mod model_io;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod stream;

pub use api::{FittedModel, KernelClusterer};
pub use error::{Result, RkcError};
