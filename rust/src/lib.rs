//! # rkc — Randomized Kernel Clustering
//!
//! Production reproduction of *"A Randomized Approach to Efficient Kernel
//! Clustering"* (Pourkamali-Anaraki & Becker, IEEE GlobalSIP 2016): one-pass
//! SRHT-preconditioned randomized low-rank kernel approximation followed by
//! standard K-means on the embedded points, with Nyström / exact-EVD /
//! full-kernel baselines, a streaming rust coordinator, and XLA-compiled
//! JAX+Pallas compute artifacts (see DESIGN.md for the full architecture).
//!
//! Layer map:
//! - [`coordinator`] — L3: the streaming pipeline (scheduler, sketch
//!   accumulator, recovery, K-means driver, metrics).
//! - [`runtime`] — PJRT wrapper loading `artifacts/*.hlo.txt` (L2/L1
//!   compute compiled from JAX + Pallas by `python/compile/aot.py`).
//! - [`lowrank`], [`sketch`], [`kernels`], [`clustering`], [`linalg`],
//!   [`rng`], [`data`], [`metrics`], [`config`], [`bench_harness`],
//!   [`util`] — the substrates, all implemented from scratch.

pub mod clustering;
pub mod data;
pub mod kernels;
pub mod linalg;
pub mod lowrank;
pub mod rng;
pub mod sketch;
pub mod util;

pub mod bench_harness;
pub mod config;
pub mod coordinator;
pub mod metrics;
pub mod runtime;
