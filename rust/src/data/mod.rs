//! Datasets: synthetic generators mirroring the paper's workloads, a CSV
//! loader for the real UCI file when available, and normalization.
//!
//! - `two_rings` — the Fig. 1 synthetic set: n = 4000 points in R², an
//!   inner disk surrounded by an annulus; not linearly separable, exactly
//!   separable under the homogeneous quadratic kernel.
//! - `segmentation_like` — substitute for the UCI *image segmentation*
//!   set (n = 2310, p = 19, K = 7, unit-ℓ2 rows); see DESIGN.md
//!   §Substitutions. `load_segmentation_csv` consumes the real file when
//!   the user provides it.
//! - `gaussian_blobs` / `two_moons` — extra workloads for examples and
//!   tests.

use crate::linalg::Mat;
use crate::rng::{Pcg64, Rng};

/// A labelled dataset: `x` is p × n (column = sample), labels in 0..k.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Mat,
    pub labels: Vec<usize>,
    pub k: usize,
    pub name: String,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.cols()
    }

    pub fn p(&self) -> usize {
        self.x.rows()
    }
}

/// Fig. 1 workload: inner disk (class 0) + annulus (class 1), balanced.
/// Radially symmetric, so plain K-means centroids collapse uselessly at
/// the origin while the quadratic-kernel embedding separates by radius².
pub fn two_rings(rng: &mut Pcg64, n: usize) -> Dataset {
    let mut x = Mat::zeros(2, n);
    let mut labels = vec![0usize; n];
    for j in 0..n {
        let class = j % 2;
        labels[j] = class;
        let (rmin, rmax) = if class == 0 { (0.0, 0.5) } else { (1.0, 1.5) };
        // uniform over the annulus area
        let u = rng.next_f64();
        let r = (rmin * rmin + u * (rmax * rmax - rmin * rmin)).sqrt();
        let theta = rng.next_f64() * std::f64::consts::TAU;
        x[(0, j)] = r * theta.cos();
        x[(1, j)] = r * theta.sin();
    }
    Dataset { x, labels, k: 2, name: format!("two_rings(n={n})") }
}

/// Fig. 1 / Table 1 workload: two crossing thick line segments through
/// the origin (±45°, |t| ∈ [0.75, 1.35], perpendicular noise σ = 0.42).
///
/// Chosen to reproduce Table 1's measurements through the paper's exact
/// pipeline (homogeneous quadratic kernel, r = 2, √λ-scaled embedding):
/// plain K-means ≈ 0.5 (the clusters are centrally symmetric, so both
/// centroids collapse near the origin), kernel methods ≈ 0.99, rank-2
/// truncation error ≈ 0.33–0.40. Under ⟨x,y⟩² each line maps to a ray on
/// the feature-space cone (antipodal points identify), making the two
/// classes linearly separable exactly as the paper's Fig. 2 shows.
/// (Concentric rings — the other classic non-linearly-separable figure —
/// do NOT reproduce Table 1: their quadratic-kernel embedding caps
/// K-means accuracy near 0.75 for any radii; see DESIGN.md.)
pub fn cross_lines(rng: &mut Pcg64, n: usize) -> Dataset {
    let mut x = Mat::zeros(2, n);
    let mut labels = vec![0usize; n];
    let (tmin, tmax, sigma) = (0.75, 1.35, 0.42);
    for j in 0..n {
        let class = j % 2;
        labels[j] = class;
        let ang = if class == 0 {
            std::f64::consts::FRAC_PI_4
        } else {
            -std::f64::consts::FRAC_PI_4
        };
        let sign = if rng.next_f64() < 0.5 { -1.0 } else { 1.0 };
        let t = sign * (tmin + rng.next_f64() * (tmax - tmin));
        let noise = sigma * rng.normal();
        // along-line + perpendicular components
        x[(0, j)] = t * ang.cos() - noise * ang.sin();
        x[(1, j)] = t * ang.sin() + noise * ang.cos();
    }
    Dataset { x, labels, k: 2, name: format!("cross_lines(n={n})") }
}

/// Fig. 3 workload substitute for the UCI *image segmentation* set
/// (n = 2310, p = 19, K = 7, unit-ℓ2 rows; see DESIGN.md §Substitutions).
///
/// Structure chosen to reproduce the figure's *shape* under the
/// homogeneous quadratic kernel at r = 2:
/// - a large shared component (all image patches share brightness-like
///   structure) → dominant λ₁;
/// - class means on a circle in a 2-dim discriminative subspace → the
///   information the rank-2 embedding keeps;
/// - 3 shared *bimodal* nuisance directions (±δ per sample — think
///   texture polarity) → energy that full kernel K-means wrongly splits
///   clusters on, but that rank-2 truncation denoises away. This yields
///   the paper's characteristic ordering: rank-2 methods (exact ≈ ours)
///   ≈ 0.5 accuracy > full kernel K-means ≈ 0.45, with a rank-2
///   approximation error ≈ 0.5 (paper: 0.46 / ≈0.4);
/// - small isotropic noise over all 19 attributes.
pub fn segmentation_like(rng: &mut Pcg64, n: usize, p: usize, k: usize) -> Dataset {
    assert!(p >= 8, "segmentation_like needs p >= 8 structural dims");
    let (rho, common, ns, nr, delta) = (1.0, 1.5, 0.22, 0.08, 0.6);
    // orthonormal 7-dim structural basis via QR of a random p×7 matrix
    let raw = Mat::from_fn(p, 7, |_, _| rng.normal());
    let (basis, _) = crate::linalg::householder_qr(&raw);
    let tau = std::f64::consts::TAU;
    let mut x = Mat::zeros(p, n);
    let mut labels = vec![0usize; n];
    let mut coef = [0.0f64; 7];
    for j in 0..n {
        let c = j % k;
        labels[j] = c;
        let ang = tau * c as f64 / k as f64;
        coef[0] = common + ns * rng.normal();
        coef[1] = rho * ang.cos() + ns * rng.normal();
        coef[2] = rho * ang.sin() + ns * rng.normal();
        for t in 0..3 {
            coef[3 + t] = delta * rng.rademacher() * (0.8 + 0.4 * rng.next_f64());
        }
        coef[6] = ns * rng.normal();
        for i in 0..p {
            let mut v = nr * rng.normal();
            for (t, &ct) in coef.iter().enumerate() {
                v += basis[(i, t)] * ct;
            }
            x[(i, j)] = v;
        }
    }
    let mut ds = Dataset { x, labels, k, name: format!("segmentation_like(n={n},p={p},K={k})") };
    normalize_columns(&mut ds.x);
    ds
}

/// K isotropic Gaussian blobs in R^p (quickstart workload).
pub fn gaussian_blobs(rng: &mut Pcg64, n: usize, p: usize, k: usize, spread: f64) -> Dataset {
    let mut centers = Mat::zeros(p, k);
    for c in 0..k {
        for i in 0..p {
            centers[(i, c)] = 4.0 * rng.normal();
        }
    }
    let mut x = Mat::zeros(p, n);
    let mut labels = vec![0usize; n];
    for j in 0..n {
        let c = j % k;
        labels[j] = c;
        for i in 0..p {
            x[(i, j)] = centers[(i, c)] + spread * rng.normal();
        }
    }
    Dataset { x, labels, k, name: format!("gaussian_blobs(n={n},p={p},K={k})") }
}

/// Two interleaved half-moons in R² (RBF-kernel example workload).
pub fn two_moons(rng: &mut Pcg64, n: usize, noise: f64) -> Dataset {
    let mut x = Mat::zeros(2, n);
    let mut labels = vec![0usize; n];
    for j in 0..n {
        let class = j % 2;
        labels[j] = class;
        let t = rng.next_f64() * std::f64::consts::PI;
        let (cx, cy) = if class == 0 {
            (t.cos(), t.sin())
        } else {
            (1.0 - t.cos(), 0.5 - t.sin())
        };
        x[(0, j)] = cx + noise * rng.normal();
        x[(1, j)] = cy + noise * rng.normal();
    }
    Dataset { x, labels, k: 2, name: format!("two_moons(n={n})") }
}

/// Normalize each column (sample) to unit ℓ2 norm — the paper's
/// preprocessing for the segmentation data.
pub fn normalize_columns(x: &mut Mat) {
    for j in 0..x.cols() {
        let mut norm = 0.0;
        for i in 0..x.rows() {
            norm += x[(i, j)] * x[(i, j)];
        }
        let norm = norm.sqrt();
        if norm > 1e-300 {
            for i in 0..x.rows() {
                x[(i, j)] /= norm;
            }
        }
    }
}

/// Load the real UCI image segmentation file if present: CSV rows of
/// `class_name, 19 numeric attributes`. Returns None when the file does
/// not exist (callers fall back to `segmentation_like`).
pub fn load_segmentation_csv(path: &str) -> Option<Dataset> {
    let text = std::fs::read_to_string(path).ok()?;
    let mut cols: Vec<Vec<f64>> = Vec::new();
    let mut class_names: Vec<String> = Vec::new();
    let mut labels = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split(',');
        let class = parts.next()?.trim().to_string();
        if class.chars().next().is_none_or(|c| c.is_ascii_digit() || c == '-') {
            continue; // header / malformed
        }
        let feats: Vec<f64> = parts.filter_map(|s| s.trim().parse().ok()).collect();
        if feats.is_empty() {
            continue;
        }
        let label = match class_names.iter().position(|c| *c == class) {
            Some(i) => i,
            None => {
                class_names.push(class);
                class_names.len() - 1
            }
        };
        labels.push(label);
        cols.push(feats);
    }
    if cols.is_empty() {
        return None;
    }
    let p = cols[0].len();
    if cols.iter().any(|c| c.len() != p) {
        return None;
    }
    let n = cols.len();
    let mut x = Mat::zeros(p, n);
    for (j, c) in cols.iter().enumerate() {
        for (i, &v) in c.iter().enumerate() {
            x[(i, j)] = v;
        }
    }
    normalize_columns(&mut x);
    let k = class_names.len();
    Some(Dataset { x, labels, k, name: format!("uci_segmentation({path})") })
}

/// Load query points from a CSV of comma-separated coordinates, one row
/// per point — the `rkc predict` input format. Every column is read as a
/// coordinate (strip label columns before feeding files written by
/// [`write_points_csv`]); blank lines are skipped. Returns the p × m
/// matrix (columns are samples) the prediction APIs consume.
pub fn load_points_csv(path: &str) -> crate::error::Result<Mat> {
    use crate::error::RkcError;
    let text = std::fs::read_to_string(path)
        .map_err(|e| RkcError::io(format!("reading points csv {path}"), e))?;
    parse_points_csv(path, &text)
}

/// [`load_points_csv`] on already-read text (`origin` labels parse
/// errors — a path, or `"stdin"` for the `rkc stream` pipe source).
pub fn parse_points_csv(origin: &str, text: &str) -> crate::error::Result<Mat> {
    use crate::error::RkcError;
    let path = origin;
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for (idx, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let vals = line
            .split(',')
            .map(|t| {
                let t = t.trim();
                t.parse::<f64>().map_err(|_| {
                    RkcError::dataset(format!(
                        "{path}:{}: '{t}' is not a number",
                        idx + 1
                    ))
                })
            })
            .collect::<crate::error::Result<Vec<f64>>>()?;
        if let Some(first) = rows.first() {
            if vals.len() != first.len() {
                return Err(RkcError::dataset(format!(
                    "{path}:{}: row has {} columns, expected {}",
                    idx + 1,
                    vals.len(),
                    first.len()
                )));
            }
        }
        rows.push(vals);
    }
    if rows.is_empty() {
        return Err(RkcError::dataset(format!("{path}: no data rows")));
    }
    let (m, p) = (rows.len(), rows[0].len());
    Ok(Mat::from_fn(p, m, |i, j| rows[j][i]))
}

/// Write a dataset (transposed: one sample per line, label last) to CSV —
/// used by the figure dumps.
pub fn write_points_csv(path: &str, x: &Mat, labels: &[usize]) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::fs::File::create(path)?;
    for j in 0..x.cols() {
        let mut row: Vec<String> = (0..x.rows()).map(|i| format!("{}", x[(i, j)])).collect();
        row.push(format!("{}", labels.get(j).copied().unwrap_or(0)));
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Deterministic non-stationary source for the streaming subsystem's
/// drift scenarios: k Gaussian blobs whose generating process changes a
/// little after every [`chunk`](DriftStream::chunk).
///
/// - [`moving_blobs`](DriftStream::moving_blobs): every blob center
///   translates along its own fixed random direction by `step` per
///   chunk — the geometry drifts, the class mixture stays uniform.
/// - [`label_churn`](DriftStream::label_churn): centers stay put, but
///   the class mixture rotates — class c's sampling weight is
///   `1 + 0.9·sin(phase + 2πc/k)` with `phase` advancing by `churn` per
///   chunk, so the dominant class cycles through `0..k`.
///
/// Everything derives from the constructor seed: two streams built with
/// the same parameters emit bit-identical chunk sequences.
pub struct DriftStream {
    rng: Pcg64,
    centers: Mat,
    velocity: Mat,
    spread: f64,
    phase: f64,
    churn: f64,
    k: usize,
    chunks: usize,
    name: String,
}

impl DriftStream {
    /// Blobs translating by `step` (input-space distance) per chunk.
    pub fn moving_blobs(seed: u64, p: usize, k: usize, spread: f64, step: f64) -> Self {
        let mut rng = Pcg64::seed_stream(seed, 0xd51f7);
        let centers = Mat::from_fn(p, k, |_, _| 4.0 * rng.normal());
        // unit direction per blob, scaled to `step`
        let mut velocity = Mat::from_fn(p, k, |_, _| rng.normal());
        for c in 0..k {
            let norm: f64 = (0..p).map(|i| velocity[(i, c)].powi(2)).sum::<f64>().sqrt();
            let s = if norm > 1e-12 { step / norm } else { 0.0 };
            for i in 0..p {
                velocity[(i, c)] *= s;
            }
        }
        DriftStream {
            rng,
            centers,
            velocity,
            spread,
            phase: 0.0,
            churn: 0.0,
            k,
            chunks: 0,
            name: format!("moving_blobs(p={p},K={k},step={step})"),
        }
    }

    /// Fixed blobs with a rotating class mixture (`churn` radians of
    /// phase advance per chunk).
    pub fn label_churn(seed: u64, p: usize, k: usize, spread: f64, churn: f64) -> Self {
        let mut rng = Pcg64::seed_stream(seed, 0xd51f8);
        let centers = Mat::from_fn(p, k, |_, _| 4.0 * rng.normal());
        DriftStream {
            rng,
            centers,
            velocity: Mat::zeros(p, k),
            spread,
            phase: 0.0,
            churn,
            k,
            chunks: 0,
            name: format!("label_churn(p={p},K={k},churn={churn})"),
        }
    }

    /// Current class-sampling weights (uniform unless churning).
    fn weights(&self) -> Vec<f64> {
        let tau = std::f64::consts::TAU;
        (0..self.k)
            .map(|c| {
                if self.churn == 0.0 {
                    1.0
                } else {
                    1.0 + 0.9 * (self.phase + tau * c as f64 / self.k as f64).sin()
                }
            })
            .collect()
    }

    /// Draw the next `m` points, then advance the drift state by one
    /// step. Labels are the generating class indices (ground truth for
    /// accuracy-lag measurements).
    pub fn chunk(&mut self, m: usize) -> Dataset {
        let p = self.centers.rows();
        let weights = self.weights();
        let total: f64 = weights.iter().sum();
        let mut x = Mat::zeros(p, m);
        let mut labels = Vec::with_capacity(m);
        for j in 0..m {
            let mut u = self.rng.next_f64() * total;
            let mut class = self.k - 1;
            for (c, &wc) in weights.iter().enumerate() {
                if u < wc {
                    class = c;
                    break;
                }
                u -= wc;
            }
            labels.push(class);
            for i in 0..p {
                x[(i, j)] = self.centers[(i, class)] + self.spread * self.rng.normal();
            }
        }
        // advance the process: translate centers, rotate the mixture
        for c in 0..self.k {
            for i in 0..p {
                self.centers[(i, c)] += self.velocity[(i, c)];
            }
        }
        self.phase += self.churn;
        self.chunks += 1;
        Dataset {
            x,
            labels,
            k: self.k,
            name: format!("{}#{}", self.name, self.chunks),
        }
    }

    /// Chunks emitted so far.
    pub fn chunks_emitted(&self) -> usize {
        self.chunks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drift_streams_are_deterministic() {
        let mut a = DriftStream::moving_blobs(9, 3, 2, 0.2, 0.5);
        let mut b = DriftStream::moving_blobs(9, 3, 2, 0.2, 0.5);
        for _ in 0..3 {
            let (ca, cb) = (a.chunk(17), b.chunk(17));
            assert_eq!(ca.x.data(), cb.x.data());
            assert_eq!(ca.labels, cb.labels);
        }
        let mut c = DriftStream::label_churn(9, 3, 2, 0.2, 0.8);
        let mut d = DriftStream::label_churn(9, 3, 2, 0.2, 0.8);
        let (cc, cd) = (c.chunk(25), d.chunk(25));
        assert_eq!(cc.x.data(), cd.x.data());
        assert_eq!(cc.labels, cd.labels);
    }

    #[test]
    fn moving_blobs_actually_move() {
        let mut s = DriftStream::moving_blobs(4, 2, 1, 0.0, 1.0);
        // spread 0 => every point IS the (current) center
        let first = s.chunk(4);
        for _ in 0..9 {
            s.chunk(4);
        }
        let late = s.chunk(4);
        let dist = ((first.x[(0, 0)] - late.x[(0, 0)]).powi(2)
            + (first.x[(1, 0)] - late.x[(1, 0)]).powi(2))
        .sqrt();
        // 10 advances at unit step: the center walked 10 units
        assert!((dist - 10.0).abs() < 1e-9, "center drifted {dist}, expected 10");
        assert_eq!(s.chunks_emitted(), 11);
    }

    #[test]
    fn label_churn_rotates_the_dominant_class() {
        // with k = 2 the class sine offsets are 0 and π, so the mixture
        // is balanced at integer multiples of π and maximally skewed at
        // odd multiples of π/2; churn π/2 per chunk walks through both
        let mut s = DriftStream::label_churn(7, 2, 2, 0.1, std::f64::consts::FRAC_PI_2);
        let count0 = |ds: &Dataset| ds.labels.iter().filter(|&&l| l == 0).count();
        s.chunk(10); // phase 0: balanced, discard
        let a = count0(&s.chunk(400)); // phase π/2: weights 1.9 vs 0.1
        s.chunk(10); // phase π: balanced, discard
        let b = count0(&s.chunk(400)); // phase 3π/2: weights 0.1 vs 1.9
        assert!(a > 300, "phase-π/2 chunk should be class-0 heavy, got {a}/400");
        assert!(b < 100, "phase-3π/2 chunk should be class-1 heavy, got {b}/400");
    }

    #[test]
    fn load_points_csv_roundtrips_coordinates() {
        let path = std::env::temp_dir().join(format!("rkc_points_{}.csv", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, "1.5, -2.0\n\n0.25,3.0\n").unwrap();
        let m = load_points_csv(&path).unwrap();
        assert_eq!((m.rows(), m.cols()), (2, 2));
        assert_eq!(m[(0, 0)], 1.5);
        assert_eq!(m[(1, 1)], 3.0);
        // ragged and non-numeric rows are typed errors, empty is too
        std::fs::write(&path, "1,2\n3\n").unwrap();
        assert!(load_points_csv(&path).is_err());
        std::fs::write(&path, "x,y\n1,2\n").unwrap();
        assert!(load_points_csv(&path).is_err());
        std::fs::write(&path, "\n").unwrap();
        assert!(load_points_csv(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn two_rings_radii_are_separated() {
        let mut rng = Pcg64::seed(1);
        let ds = two_rings(&mut rng, 1000);
        assert_eq!(ds.n(), 1000);
        assert_eq!(ds.p(), 2);
        for j in 0..ds.n() {
            let r = (ds.x[(0, j)].powi(2) + ds.x[(1, j)].powi(2)).sqrt();
            if ds.labels[j] == 0 {
                assert!(r <= 0.5 + 1e-9);
            } else {
                assert!((1.0..=1.5 + 1e-9).contains(&r));
            }
        }
    }

    #[test]
    fn cross_lines_shape_and_symmetry() {
        let mut rng = Pcg64::seed(8);
        let ds = cross_lines(&mut rng, 4000);
        assert_eq!((ds.p(), ds.n(), ds.k), (2, 4000, 2));
        // centrally symmetric-ish: the mean is near the origin relative
        // to the typical point norm, which is why plain K-means fails
        let (mut mx, mut my, mut norm) = (0.0, 0.0, 0.0);
        for j in 0..ds.n() {
            mx += ds.x[(0, j)];
            my += ds.x[(1, j)];
            norm += (ds.x[(0, j)].powi(2) + ds.x[(1, j)].powi(2)).sqrt();
        }
        let n = ds.n() as f64;
        assert!((mx / n).abs() < 0.05 && (my / n).abs() < 0.05);
        assert!(norm / n > 0.8);
    }

    #[test]
    fn two_rings_is_balanced() {
        let mut rng = Pcg64::seed(2);
        let ds = two_rings(&mut rng, 4000);
        let c0 = ds.labels.iter().filter(|&&l| l == 0).count();
        assert_eq!(c0, 2000);
    }

    #[test]
    fn segmentation_like_shapes_and_unit_norm() {
        let mut rng = Pcg64::seed(3);
        let ds = segmentation_like(&mut rng, 2310, 19, 7);
        assert_eq!((ds.p(), ds.n(), ds.k), (19, 2310, 7));
        for j in 0..ds.n() {
            let norm: f64 = (0..19).map(|i| ds.x[(i, j)].powi(2)).sum();
            assert!((norm - 1.0).abs() < 1e-9, "column {j} norm {norm}");
        }
        // every class represented with ~n/k members
        for c in 0..7 {
            let cnt = ds.labels.iter().filter(|&&l| l == c).count();
            assert!(cnt >= 2310 / 7 - 1);
        }
    }

    #[test]
    fn blobs_and_moons_shapes() {
        let mut rng = Pcg64::seed(4);
        let b = gaussian_blobs(&mut rng, 120, 5, 4, 0.3);
        assert_eq!((b.p(), b.n(), b.k), (5, 120, 4));
        let m = two_moons(&mut rng, 100, 0.05);
        assert_eq!((m.p(), m.n(), m.k), (2, 100, 2));
    }

    #[test]
    fn generators_are_deterministic() {
        let a = two_rings(&mut Pcg64::seed(9), 64);
        let b = two_rings(&mut Pcg64::seed(9), 64);
        assert_eq!(a.x.data(), b.x.data());
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn csv_roundtrip_via_loader() {
        let mut rng = Pcg64::seed(5);
        let ds = gaussian_blobs(&mut rng, 30, 4, 3, 0.2);
        let dir = std::env::temp_dir().join("rkc_test_csv");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("seg.csv");
        // write in the UCI format: CLASS,feat...
        use std::io::Write;
        let mut f = std::fs::File::create(&path).unwrap();
        for j in 0..ds.n() {
            let feats: Vec<String> =
                (0..ds.p()).map(|i| format!("{}", ds.x[(i, j)])).collect();
            writeln!(f, "CLASS{},{}", ds.labels[j], feats.join(",")).unwrap();
        }
        drop(f);
        let loaded = load_segmentation_csv(path.to_str().unwrap()).expect("loads");
        assert_eq!(loaded.n(), 30);
        assert_eq!(loaded.p(), 4);
        assert_eq!(loaded.k, 3);
        assert_eq!(loaded.labels, ds.labels);
        // loader normalizes columns
        for j in 0..loaded.n() {
            let norm: f64 = (0..4).map(|i| loaded.x[(i, j)].powi(2)).sum();
            assert!((norm - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn loader_returns_none_for_missing_file() {
        assert!(load_segmentation_csv("/nonexistent/file.csv").is_none());
    }
}
