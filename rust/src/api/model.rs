//! The trained artifact of a [`KernelClusterer`](super::KernelClusterer)
//! fit: recovered embedding, centroids, labels, and out-of-sample
//! assignment.
//!
//! Out-of-sample extension: the recovered factorization is
//! `K̂ = Yᵀ Y = U Λ Uᵀ` with `Y = Λ^{1/2} Uᵀ`, so `Y Yᵀ = Λ` and a new
//! point `z` embeds as
//!
//! ```text
//! y(z) = Λ⁻¹ · Y · k_z,    k_z = [κ(z, x_j)]_{j=1..n}
//! ```
//!
//! (the Nyström-style column-map extension: plugging `z = x_l` in gives
//! `Λ⁻¹ Y K[:, l] ≈ Λ⁻¹ (Y Yᵀ) Y[:, l] = Y[:, l]`, i.e. it reproduces
//! the in-sample embedding up to approximation error). Prediction then
//! assigns the nearest trained centroid in embedding space.

use std::time::Duration;

use crate::config::Precision;
use crate::error::{Result, RkcError};
use crate::kernels::{BlockSource, Kernel, NativeBlockSource};
use crate::linalg::Mat;
use crate::lowrank::{streamed_frobenius_error, Embedding};
use crate::metrics::MethodMemory;

/// Everything a fit measures about itself.
#[derive(Clone, Debug)]
pub struct FitMetrics {
    /// stable method name (the `Method` `Display` form)
    pub method: String,
    /// training sample count
    pub n: usize,
    /// embedding rank (0 for plain K-means)
    pub rank: usize,
    /// final K-means / kernel-K-means objective
    pub objective: f64,
    /// byte-accounting memory model of the fit
    pub memory: MethodMemory,
    pub sketch_time: Duration,
    pub recovery_time: Duration,
    pub kmeans_time: Duration,
}

/// How a fitted model assigns new points to clusters.
pub(crate) enum Assigner {
    /// nearest centroid in embedding space (r × k centroids)
    Embedded { centroids: Mat },
    /// nearest centroid in input space (p × k centroids; plain K-means)
    Input { centroids: Mat },
    /// kernel K-means assignment (Dhillon et al. Eq. 4): per-cluster
    /// sizes and the constant intra-cluster kernel terms, members
    /// resolved through the stored training labels
    KernelClusters { sizes: Vec<usize>, self_terms: Vec<f64> },
}

/// A trained clustering model: embedding + centroids + labels, with
/// out-of-sample [`embed`](FittedModel::embed) /
/// [`predict`](FittedModel::predict) when the training data was retained
/// (i.e. the model came from `fit`, not `fit_stream`).
///
/// # Examples
///
/// ```
/// use rkc::api::KernelClusterer;
/// use rkc::data;
/// use rkc::rng::Pcg64;
///
/// let ds = data::cross_lines(&mut Pcg64::seed(2), 128);
/// let model = KernelClusterer::new(2).oversample(8).fit(&ds.x)?;
/// assert_eq!(model.labels().len(), 128);
/// assert_eq!(model.k(), 2);
///
/// // never-seen points embed into the trained space and get a cluster
/// let novel = data::cross_lines(&mut Pcg64::seed(3), 16);
/// assert_eq!(model.embed(&novel.x)?.cols(), 16);
/// assert_eq!(model.predict(&novel.x)?.len(), 16);
/// # Ok::<(), rkc::error::RkcError>(())
/// ```
pub struct FittedModel {
    pub(crate) kernel: Kernel,
    pub(crate) k: usize,
    pub(crate) embedding: Option<Embedding>,
    pub(crate) labels: Vec<usize>,
    pub(crate) assigner: Assigner,
    pub(crate) train_x: Option<Mat>,
    pub(crate) n_pad: usize,
    pub(crate) batch: usize,
    pub(crate) metrics: FitMetrics,
    /// refresh generation: 0 for a plain batch fit; the streaming
    /// subsystem stamps each published refresh with a monotonically
    /// increasing value. Serialized as a `.rkc` header field (older
    /// files load as generation 0).
    pub(crate) generation: u64,
    /// lazily materialized columns of `train_x` (the p × n matrix is
    /// row-major, so the κ(z, x_j) loops want contiguous per-column
    /// slices). Built once on the first out-of-sample call instead of
    /// per call — the serving hot path hits `embed`/`predict` per
    /// request. Derived state: never serialized.
    pub(crate) train_cols: std::sync::OnceLock<Vec<Vec<f64>>>,
    /// serving precision for `embed`/`predict`: `F64` (default) keeps
    /// the bit-exact contracts; `F32` routes the out-of-sample gram +
    /// embed accumulation through single-precision SIMD kernels.
    /// Persisted as a `.rkc` header field (older files load as `F64`).
    pub(crate) precision: Precision,
    /// lazily materialized single-precision shadow of the serving state
    /// (train columns, point-major Yᵀ, 1/λ). Built on the first f32
    /// `embed`/`predict`; derived state, never serialized, reset when
    /// [`set_precision`](FittedModel::set_precision) changes mode.
    pub(crate) f32_state: std::sync::OnceLock<F32State>,
}

/// Single-precision serving state derived from the f64 model (see
/// [`FittedModel::f32_state`]).
pub(crate) struct F32State {
    /// training columns cast to f32, one contiguous slice per point
    train_cols: Vec<Vec<f32>>,
    /// `Y` transposed point-major: `yt[t·r ..(t+1)·r]` is point `t`'s
    /// embedding row, so the accumulation is one contiguous axpy
    yt: Vec<f32>,
    /// `1/λ_i` with the same numerically-absent-direction floor as the
    /// f64 path (computed in f64, then cast)
    inv_lambda: Vec<f32>,
}

/// `1/λ_i` per embedding row, zeroing numerically-absent directions.
/// The single copy of the floor rule: the f64 embed path applies these
/// scales directly and [`FittedModel::f32_state`] casts them, so both
/// precisions zero exactly the same eigendirections by construction.
fn inv_lambda_scales(eigenvalues: &[f64], r: usize) -> Vec<f64> {
    let lmax = eigenvalues.first().copied().unwrap_or(0.0).max(0.0);
    let floor = 1e-12 * lmax.max(1e-300);
    (0..r)
        .map(|i| {
            let l = eigenvalues[i];
            if l > floor {
                1.0 / l
            } else {
                0.0
            }
        })
        .collect()
}

impl FittedModel {
    /// Cluster index per training point.
    pub fn labels(&self) -> &[usize] {
        &self.labels
    }

    /// Number of clusters.
    pub fn k(&self) -> usize {
        self.k
    }

    /// The kernel this model was trained with.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// The recovered low-rank embedding (`None` for plain K-means and
    /// the full-kernel baseline, which never form one).
    pub fn embedding(&self) -> Option<&Embedding> {
        self.embedding.as_ref()
    }

    /// Trained centroids: r × k in embedding space, or p × k in input
    /// space for plain K-means. `None` for the full-kernel baseline
    /// (kernel K-means centroids exist only implicitly in feature space).
    pub fn centroids(&self) -> Option<&Mat> {
        match &self.assigner {
            Assigner::Embedded { centroids } | Assigner::Input { centroids } => Some(centroids),
            Assigner::KernelClusters { .. } => None,
        }
    }

    /// Timings, memory model, and the final objective of the fit.
    pub fn metrics(&self) -> &FitMetrics {
        &self.metrics
    }

    /// Mutable metrics access. Exists so byte-level model comparisons
    /// (crash-recovery tests, reproducibility harnesses) can zero the
    /// wall-clock timing fields — they measure the run, not the model,
    /// and are the only non-deterministic bytes in a `.rkc` file.
    pub fn metrics_mut(&mut self) -> &mut FitMetrics {
        &mut self.metrics
    }

    /// Refresh generation of this model: `0` for a plain batch fit,
    /// `g ≥ 1` for the g-th model a [`StreamClusterer`](crate::stream)
    /// refresh published. Survives save/load (a `.rkc` header field;
    /// files written before the field existed load as generation 0).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Stamp this model with a refresh generation (used by the
    /// streaming refresh loop before publishing into a registry).
    pub fn set_generation(&mut self, generation: u64) {
        self.generation = generation;
    }

    /// Serving precision of `embed`/`predict` (see
    /// [`Precision`]): `F64` by default.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Switch the serving precision. `F32` opts the out-of-sample gram
    /// and embed accumulation into single precision (the fit itself is
    /// immutable and stays f64); `F64` restores the bit-exact path.
    /// Survives save/load as a `.rkc` header field.
    pub fn set_precision(&mut self, precision: Precision) {
        if self.precision != precision {
            self.precision = precision;
            // derived shadow state may be stale relative to the mode
            self.f32_state = std::sync::OnceLock::new();
        }
    }

    /// The input-space dimension p that [`embed`](Self::embed) /
    /// [`predict`](Self::predict) queries must have. `None` when the
    /// model retained no training data (a `fit_stream` model) and has no
    /// input-space centroids — such models cannot answer out-of-sample
    /// queries at all.
    pub fn input_dim(&self) -> Option<usize> {
        match &self.assigner {
            Assigner::Input { centroids } => Some(centroids.rows()),
            _ => self.train_x.as_ref().map(Mat::rows),
        }
    }

    /// Persist this model to `path` in the versioned `.rkc` binary
    /// format (see [`crate::model_io`] for the byte-level spec). Parent
    /// directories are created as needed. The roundtrip is **bit-exact**:
    /// [`load`](Self::load) reproduces a model whose `embed`/`predict`
    /// outputs are bit-identical to this one's.
    ///
    /// ```
    /// use rkc::api::{FittedModel, KernelClusterer};
    /// use rkc::data;
    /// use rkc::rng::Pcg64;
    ///
    /// let ds = data::cross_lines(&mut Pcg64::seed(2), 64);
    /// let model = KernelClusterer::new(2).oversample(8).fit(&ds.x)?;
    /// let path = std::env::temp_dir().join("rkc-doc-model.rkc");
    /// let path = path.to_str().unwrap();
    /// model.save(path)?;
    /// let reloaded = FittedModel::load(path)?;
    /// assert_eq!(reloaded.predict(&ds.x)?, model.predict(&ds.x)?);
    /// std::fs::remove_file(path).ok();
    /// # Ok::<(), rkc::error::RkcError>(())
    /// ```
    pub fn save(&self, path: &str) -> Result<()> {
        crate::model_io::save_model(self, path)
    }

    /// Load a model previously written by [`save`](Self::save).
    /// Corruption (bad magic, truncation, checksum mismatch) is a typed
    /// [`RkcError::Model`]; a file from a newer release is
    /// [`RkcError::ModelVersion`].
    pub fn load(path: &str) -> Result<FittedModel> {
        crate::model_io::load_model(path)
    }

    /// The padded kernel length the fit used (power of two on the
    /// native path; an artifact-baked size on the XLA path). Callers
    /// building their own [`BlockSource`] for
    /// [`approx_error_with`](Self::approx_error_with) should match it.
    pub fn n_padded(&self) -> usize {
        self.n_pad
    }

    /// Embed out-of-sample points `xq` (p × m) into the trained
    /// embedding space via the column-map extension `y(z) = Λ⁻¹ Y k_z`.
    pub fn embed(&self, xq: &Mat) -> Result<Mat> {
        let emb = self.embedding.as_ref().ok_or_else(|| {
            RkcError::unsupported(format!(
                "method {} has no kernel embedding to extend",
                self.metrics.method
            ))
        })?;
        let xt = self.require_train_x()?;
        self.check_dims(xt, xq)?;
        let (m, r) = (xq.cols(), emb.rank());
        if self.precision == Precision::F32 {
            return Ok(self.embed_f32(xt, emb, xq));
        }

        let train_cols = self.train_cols(xt);
        let mut out = Mat::zeros(r, m);
        for j in 0..m {
            let zq = xq.col(j);
            for (t, xcol) in train_cols.iter().enumerate() {
                let kv = self.kernel.eval(xcol, &zq);
                if kv == 0.0 {
                    continue;
                }
                for i in 0..r {
                    out[(i, j)] += emb.y[(i, t)] * kv;
                }
            }
        }
        // scale row i by 1/λ_i; numerically-absent directions stay zero
        let scales = inv_lambda_scales(&emb.eigenvalues, r);
        for i in 0..r {
            let s = scales[i];
            for v in out.row_mut(i) {
                *v *= s;
            }
        }
        Ok(out)
    }

    /// Assign out-of-sample points `xq` (p × m) to trained clusters.
    ///
    /// Under [`Precision::F32`] the embedding leg runs single-precision
    /// (via [`embed`](Self::embed)); the final nearest-centroid scan —
    /// O(m·k·r), negligible next to the gram — and the input-space /
    /// kernel-clusters assigners stay f64.
    pub fn predict(&self, xq: &Mat) -> Result<Vec<usize>> {
        match &self.assigner {
            Assigner::Embedded { centroids } => {
                let yq = self.embed(xq)?;
                Ok(nearest_centroids(&yq, centroids))
            }
            Assigner::Input { centroids } => {
                if xq.rows() != centroids.rows() {
                    return Err(RkcError::invalid_config(format!(
                        "query dimension {} does not match trained dimension {}",
                        xq.rows(),
                        centroids.rows()
                    )));
                }
                Ok(nearest_centroids(xq, centroids))
            }
            Assigner::KernelClusters { sizes, self_terms } => {
                let xt = self.require_train_x()?;
                self.check_dims(xt, xq)?;
                let train_cols = self.train_cols(xt);
                let mut out = Vec::with_capacity(xq.cols());
                for j in 0..xq.cols() {
                    let zq = xq.col(j);
                    // cross term Σ_{l∈S_c} κ(z, x_l) per cluster
                    let mut cross = vec![0.0f64; self.k];
                    for (t, xcol) in train_cols.iter().enumerate() {
                        cross[self.labels[t]] += self.kernel.eval(xcol, &zq);
                    }
                    // κ(z,z) is constant over clusters — argmin ignores it
                    let mut best = 0usize;
                    let mut best_score = f64::INFINITY;
                    for c in 0..self.k {
                        if sizes[c] == 0 {
                            continue;
                        }
                        let score = self_terms[c] - 2.0 * cross[c] / sizes[c] as f64;
                        if score < best_score {
                            best_score = score;
                            best = c;
                        }
                    }
                    out.push(best);
                }
                Ok(out)
            }
        }
    }

    /// Streamed normalized approximation error `‖K − K̂‖_F / ‖K‖_F`
    /// against the model's own training kernel — one extra pass over
    /// native kernel blocks, never violating the O(r'n) memory budget.
    pub fn approx_error(&self) -> Result<f64> {
        let xt = self.require_train_x()?;
        let mut src = NativeBlockSource::new(xt.clone(), self.kernel, self.n_pad);
        self.approx_error_with(&mut src)
    }

    /// Streamed approximation error against a caller-provided block
    /// source (e.g. an XLA-backed one).
    pub fn approx_error_with(&self, src: &mut dyn BlockSource) -> Result<f64> {
        let emb = self.embedding.as_ref().ok_or_else(|| {
            RkcError::unsupported(format!(
                "method {} has no embedding to measure",
                self.metrics.method
            ))
        })?;
        Ok(streamed_frobenius_error(src, emb, self.batch))
    }

    /// The training columns as contiguous slices, materialized once per
    /// model (out-of-sample calls run per-request on the serving path).
    fn train_cols(&self, xt: &Mat) -> &[Vec<f64>] {
        self.train_cols
            .get_or_init(|| (0..xt.cols()).map(|j| xt.col(j)).collect())
    }

    /// Single-precision column-map extension `y(z) = Λ⁻¹ Y k_z`: the
    /// same loop structure as the f64 path in [`embed`](Self::embed),
    /// with the gram through [`Kernel::eval_f32_with`] (table resolved
    /// once, not per evaluation) and the rank-r
    /// accumulation through the dispatched f32 axpy. The result is cast
    /// back to the f64 `Mat` the API returns; deviation from the f64
    /// path is bounded by the `f32_max_abs_dev` guard the serve bench
    /// reports.
    fn embed_f32(&self, xt: &Mat, emb: &Embedding, xq: &Mat) -> Mat {
        let st = self.f32_state(xt, emb);
        let (m, r, p) = (xq.cols(), emb.rank(), xt.rows());
        let table = crate::simd::dispatch();
        let axpy = table.axpy_f32;
        let mut out = Mat::zeros(r, m);
        let mut zq = vec![0.0f32; p];
        let mut acc = vec![0.0f32; r];
        for j in 0..m {
            for (i, v) in zq.iter_mut().enumerate() {
                *v = xq[(i, j)] as f32;
            }
            acc.fill(0.0);
            for (t, xcol) in st.train_cols.iter().enumerate() {
                let kv = self.kernel.eval_f32_with(xcol, &zq, table);
                if kv == 0.0 {
                    continue;
                }
                axpy(&mut acc, kv, &st.yt[t * r..(t + 1) * r]);
            }
            for i in 0..r {
                out[(i, j)] = (acc[i] * st.inv_lambda[i]) as f64;
            }
        }
        out
    }

    /// The f32 serving shadow, materialized once per model. The 1/λ
    /// floor is computed in f64 with the exact rule the f64 path uses,
    /// so both precisions zero the same numerically-absent directions.
    fn f32_state(&self, xt: &Mat, emb: &Embedding) -> &F32State {
        self.f32_state.get_or_init(|| {
            let (n, r) = (xt.cols(), emb.rank());
            let train_cols = (0..n)
                .map(|j| xt.col(j).iter().map(|&v| v as f32).collect())
                .collect();
            let mut yt = vec![0.0f32; n * r];
            for t in 0..n {
                for i in 0..r {
                    yt[t * r + i] = emb.y[(i, t)] as f32;
                }
            }
            let inv_lambda = inv_lambda_scales(&emb.eigenvalues, r)
                .into_iter()
                .map(|s| s as f32)
                .collect();
            F32State { train_cols, yt, inv_lambda }
        })
    }

    fn require_train_x(&self) -> Result<&Mat> {
        self.train_x.as_ref().ok_or_else(|| {
            RkcError::unsupported(
                "model was fit from a block stream without retained training data \
                 (use `fit` instead of `fit_stream` for out-of-sample operations)",
            )
        })
    }

    fn check_dims(&self, xt: &Mat, xq: &Mat) -> Result<()> {
        if xq.rows() != xt.rows() {
            return Err(RkcError::invalid_config(format!(
                "query dimension {} does not match trained dimension {}",
                xq.rows(),
                xt.rows()
            )));
        }
        Ok(())
    }
}

/// Nearest-centroid assignment: `points` and `centroids` share their row
/// dimension; returns one centroid index per point column.
fn nearest_centroids(points: &Mat, centroids: &Mat) -> Vec<usize> {
    let (r, m) = (points.rows(), points.cols());
    let k = centroids.cols();
    debug_assert_eq!(centroids.rows(), r);
    let mut out = Vec::with_capacity(m);
    for j in 0..m {
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        for c in 0..k {
            let mut d = 0.0;
            for i in 0..r {
                let t = points[(i, j)] - centroids[(i, c)];
                d += t * t;
            }
            if d < best_d {
                best_d = d;
                best = c;
            }
        }
        out.push(best);
    }
    out
}
