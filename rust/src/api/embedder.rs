//! Methods as objects: every low-rank strategy behind one trait.
//!
//! The experiment driver used to dispatch on `Method` with a giant match;
//! the [`Embedder`] trait turns each strategy into a value that knows how
//! to produce an [`Embedding`] from any [`BlockSource`] and how to account
//! its memory. [`embedder_for`] maps a [`Method`] to its object (every
//! method except plain K-means, which never touches the kernel).

use std::time::{Duration, Instant};

use crate::config::Method;
use crate::error::{Result, RkcError};
use crate::kernels::{column_batches, BlockSource};
use crate::linalg::Mat;
use crate::lowrank::{
    exact_topr_dense, exact_topr_streaming_threaded, gaussian_one_pass_recovery_threaded,
    nystrom_threaded, one_pass_recovery_threaded, Embedding, NystromSampling, OnePassSketch,
};
use crate::metrics::{MemoryModel, MethodMemory};
use crate::rng::Pcg64;
use crate::sketch::{GaussianSketch, Srht};

/// Result of one embedding pass, with the phase split the paper reports.
pub struct EmbedOutcome {
    pub embedding: Embedding,
    /// streaming / sketch phase (for Nyström and exact this is the whole
    /// pass — there is no separate recovery solve)
    pub sketch_time: Duration,
    /// recovery phase (QR + solve + eigendecomposition)
    pub recovery_time: Duration,
}

/// A low-rank kernel embedding strategy.
///
/// All implementors produce an [`Embedding`] `Y` (r × n) with `K ≈ YᵀY`
/// from streamed kernel column blocks, so standard K-means on `Y`
/// approximates kernel K-means on `K` (Theorem 1).
pub trait Embedder {
    /// Stable method name (matches the `Method` `Display` form).
    fn name(&self) -> String;

    /// Produce the embedding from streamed blocks of the kernel.
    fn embed(&self, src: &mut dyn BlockSource, rng: &mut Pcg64) -> Result<EmbedOutcome>;

    /// Byte-accounting model of the pass (the paper's headline axis).
    fn memory_model(&self, n: usize, n_pad: usize) -> MethodMemory;
}

/// The paper's Alg. 1: one-pass SRHT sketch, then recovery.
pub struct OnePassEmbedder {
    pub rank: usize,
    pub oversample: usize,
    pub batch: usize,
    /// FWHT worker threads inside the transform stage
    pub threads: usize,
}

impl OnePassEmbedder {
    fn width(&self) -> usize {
        self.rank + self.oversample
    }
}

impl Embedder for OnePassEmbedder {
    fn name(&self) -> String {
        Method::OnePass.to_string()
    }

    fn embed(&self, src: &mut dyn BlockSource, rng: &mut Pcg64) -> Result<EmbedOutcome> {
        let n = src.n();
        let n_pad = src.n_padded();
        if !n_pad.is_power_of_two() {
            return Err(RkcError::invalid_config(format!(
                "SRHT needs a power-of-two padded length, got n_padded={n_pad}"
            )));
        }
        let width = self.width();
        // the sketch W is n × r' and its recovery QR needs a tall matrix
        if width > n {
            return Err(RkcError::invalid_config(format!(
                "sketch width r'={width} exceeds sample count n={n}"
            )));
        }
        let mut srht = Srht::draw(rng, n_pad, width);
        srht.mask_padding(n);
        let t0 = Instant::now();
        let mut sketch = OnePassSketch::new(srht, n);
        let mut scratch = Vec::new(); // one transform buffer for the whole pass
        for cols in column_batches(n, self.batch) {
            let kb = src.block(&cols);
            let rows =
                sketch.srht().apply_to_block_with(&kb, self.threads.max(1), &mut scratch);
            sketch.ingest(&cols, &rows);
        }
        let sketch_time = t0.elapsed();
        let t1 = Instant::now();
        let embedding = one_pass_recovery_threaded(&sketch, self.rank, self.threads.max(1));
        Ok(EmbedOutcome { embedding, sketch_time, recovery_time: t1.elapsed() })
    }

    fn memory_model(&self, n: usize, n_pad: usize) -> MethodMemory {
        MemoryModel::one_pass(n, n_pad, self.width(), self.rank, self.batch)
    }
}

/// One-pass sketch with a dense Gaussian test matrix (ablation baseline:
/// same accuracy as the SRHT, but Ω itself costs O(n_pad · r') memory —
/// the structured-vs-Gaussian gap the paper's §4 calls out).
pub struct GaussianOnePassEmbedder {
    pub rank: usize,
    pub oversample: usize,
    pub batch: usize,
    /// worker threads for the sketch GEMM and the recovery products
    pub threads: usize,
}

impl GaussianOnePassEmbedder {
    fn width(&self) -> usize {
        self.rank + self.oversample
    }
}

impl Embedder for GaussianOnePassEmbedder {
    fn name(&self) -> String {
        Method::GaussianOnePass.to_string()
    }

    fn embed(&self, src: &mut dyn BlockSource, rng: &mut Pcg64) -> Result<EmbedOutcome> {
        let n = src.n();
        let n_pad = src.n_padded();
        let width = self.width();
        // the sketch W is n × r' and its recovery QR needs a tall matrix
        if width > n {
            return Err(RkcError::invalid_config(format!(
                "sketch width r'={width} exceeds sample count n={n}"
            )));
        }
        // dense Gaussian test matrix over the padded length, padded rows
        // zeroed (same masking convention as the SRHT)
        let gauss = {
            let mut g = GaussianSketch::draw(rng, n_pad, width);
            for i in n..n_pad {
                for j in 0..width {
                    g.omega[(i, j)] = 0.0;
                }
            }
            g
        };
        let threads = self.threads.max(1);
        let t0 = Instant::now();
        let mut w = Mat::zeros(n, width);
        for cols in column_batches(n, self.batch) {
            let kb = src.block(&cols);
            let rows = gauss.apply_to_block(&kb, threads); // b × r'
            for (bj, &j) in cols.iter().enumerate() {
                w.row_mut(j).copy_from_slice(rows.row(bj));
            }
        }
        let sketch_time = t0.elapsed();
        let t1 = Instant::now();
        let omega_real = Mat::from_fn(n, width, |i, j| gauss.omega[(i, j)]);
        let embedding =
            gaussian_one_pass_recovery_threaded(&w, &omega_real, self.rank, threads);
        Ok(EmbedOutcome { embedding, sketch_time, recovery_time: t1.elapsed() })
    }

    fn memory_model(&self, n: usize, n_pad: usize) -> MethodMemory {
        let mut mem = MemoryModel::one_pass(n, n_pad, self.width(), self.rank, self.batch);
        mem.method = self.name();
        // Ω itself is n_pad × r' dense and persistent
        mem.persistent += std::mem::size_of::<f64>() * n_pad * self.width();
        mem
    }
}

/// Nyström with m sampled columns (the paper's main baseline).
pub struct NystromEmbedder {
    /// embedding rank r (top-r eigenpairs of the inner matrix)
    pub rank: usize,
    /// number of sampled landmark columns
    pub m: usize,
    /// landmark sampling strategy
    pub sampling: NystromSampling,
    /// worker threads for the embedding projection (`0` = auto-detect)
    pub threads: usize,
}

impl Embedder for NystromEmbedder {
    fn name(&self) -> String {
        Method::Nystrom { m: self.m }.to_string()
    }

    fn embed(&self, src: &mut dyn BlockSource, rng: &mut Pcg64) -> Result<EmbedOutcome> {
        let n = src.n();
        if self.m > n {
            return Err(RkcError::invalid_config(format!(
                "nystrom m={} exceeds sample count n={n}",
                self.m
            )));
        }
        if self.rank > self.m {
            return Err(RkcError::invalid_config(format!(
                "rank r={} exceeds nystrom sample count m={}",
                self.rank, self.m
            )));
        }
        let t0 = Instant::now();
        let embedding =
            nystrom_threaded(src, self.m, self.rank, self.sampling, rng, self.threads);
        Ok(EmbedOutcome { embedding, sketch_time: t0.elapsed(), recovery_time: Duration::ZERO })
    }

    fn memory_model(&self, n: usize, _n_pad: usize) -> MethodMemory {
        MemoryModel::nystrom(n, self.m, self.rank)
    }
}

/// Exact top-r via streamed subspace iteration (multi-pass, O(rn) memory).
pub struct ExactEmbedder {
    pub rank: usize,
    pub iters: usize,
    pub batch: usize,
    /// worker threads for the streamed `K V` products
    pub threads: usize,
}

impl Embedder for ExactEmbedder {
    fn name(&self) -> String {
        Method::Exact.to_string()
    }

    fn embed(&self, src: &mut dyn BlockSource, _rng: &mut Pcg64) -> Result<EmbedOutcome> {
        let n = src.n();
        if self.rank > n {
            return Err(RkcError::invalid_config(format!(
                "rank r={} exceeds sample count n={n}",
                self.rank
            )));
        }
        let t0 = Instant::now();
        let embedding = exact_topr_streaming_threaded(
            src,
            self.rank,
            self.iters,
            self.batch,
            self.threads.max(1),
        );
        Ok(EmbedOutcome { embedding, sketch_time: t0.elapsed(), recovery_time: Duration::ZERO })
    }

    fn memory_model(&self, n: usize, n_pad: usize) -> MethodMemory {
        MemoryModel::exact_streaming(n, n_pad, self.rank, self.batch)
    }
}

/// Dense exact top-r over the fully materialized kernel — the O(n²)
/// embedding the paper avoids, kept as an embedder so the full-kernel
/// strategy is a first-class object too. (Note: [`Method::FullKernel`]
/// in `fit`/the experiment driver runs *kernel K-means* on the
/// materialized matrix — the paper's baseline; this embedder is the
/// embedding-flavored counterpart for `embed`/`predict` workflows.)
pub struct FullKernelEmbedder {
    pub rank: usize,
    pub batch: usize,
}

impl Embedder for FullKernelEmbedder {
    fn name(&self) -> String {
        Method::FullKernel.to_string()
    }

    fn embed(&self, src: &mut dyn BlockSource, _rng: &mut Pcg64) -> Result<EmbedOutcome> {
        let n = src.n();
        if self.rank > n {
            return Err(RkcError::invalid_config(format!(
                "rank r={} exceeds sample count n={n}",
                self.rank
            )));
        }
        let t0 = Instant::now();
        let mut kmat = Mat::zeros(n, n);
        for cols in column_batches(n, self.batch) {
            let kb = src.block(&cols);
            for (bj, &j) in cols.iter().enumerate() {
                for i in 0..n {
                    kmat[(i, j)] = kb[(i, bj)];
                }
            }
        }
        let sketch_time = t0.elapsed();
        let t1 = Instant::now();
        let embedding = exact_topr_dense(&kmat, self.rank);
        Ok(EmbedOutcome { embedding, sketch_time, recovery_time: t1.elapsed() })
    }

    fn memory_model(&self, n: usize, _n_pad: usize) -> MethodMemory {
        MemoryModel::exact_dense(n)
    }
}

/// Map a [`Method`] to its embedder object. Returns `None` for
/// [`Method::PlainKmeans`], which never forms a kernel embedding.
/// `threads` parameterizes the strategies with their own parallel
/// stages (one-pass FWHT, Nyström projection); block-level parallelism
/// belongs to the [`BlockSource`] the embedder is fed.
///
/// # Examples
///
/// ```
/// use rkc::api::embedder_for;
/// use rkc::config::Method;
/// use rkc::kernels::{Kernel, NativeBlockSource};
/// use rkc::rng::Pcg64;
///
/// let ds = rkc::data::cross_lines(&mut Pcg64::seed(3), 96);
/// let embedder = embedder_for(Method::OnePass, 2, 8, 32, 1).unwrap();
/// let mut src = NativeBlockSource::pow2(ds.x, Kernel::paper_poly2());
/// let out = embedder.embed(&mut src, &mut Pcg64::seed(1))?;
/// assert_eq!((out.embedding.rank(), out.embedding.n()), (2, 96));
/// # Ok::<(), rkc::error::RkcError>(())
/// ```
pub fn embedder_for(
    method: Method,
    rank: usize,
    oversample: usize,
    batch: usize,
    threads: usize,
) -> Option<Box<dyn Embedder>> {
    // resolve the crate-wide `0 = auto-detect` convention here, once,
    // so every method sees the same semantics
    let threads = crate::util::parallel::resolve_threads(threads).max(1);
    match method {
        Method::OnePass => Some(Box::new(OnePassEmbedder { rank, oversample, batch, threads })),
        Method::GaussianOnePass => {
            Some(Box::new(GaussianOnePassEmbedder { rank, oversample, batch, threads }))
        }
        Method::Nystrom { m } => Some(Box::new(NystromEmbedder {
            rank,
            m,
            sampling: NystromSampling::Uniform,
            threads,
        })),
        Method::Exact => Some(Box::new(ExactEmbedder { rank, iters: 40, batch, threads })),
        Method::FullKernel => Some(Box::new(FullKernelEmbedder { rank, batch })),
        Method::PlainKmeans => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{full_kernel_matrix, Kernel, NativeBlockSource};
    use crate::lowrank::normalized_frobenius_error;
    use crate::rng::Rng;

    fn random_x(seed: u64, p: usize, n: usize) -> Mat {
        let mut rng = Pcg64::seed(seed);
        Mat::from_fn(p, n, |_, _| rng.normal())
    }

    #[test]
    fn every_embedder_reconstructs_a_low_rank_kernel() {
        // R² quadratic kernel has rank ≤ 3: rank-3 embedders are near-exact
        let x = random_x(1, 2, 48);
        let kern = Kernel::paper_poly2();
        let k = full_kernel_matrix(&x, kern);
        for method in [
            Method::OnePass,
            Method::GaussianOnePass,
            Method::Nystrom { m: 48 },
            Method::Exact,
            Method::FullKernel,
        ] {
            let e = embedder_for(method, 3, 10, 16, 1).unwrap();
            let mut src = NativeBlockSource::pow2(x.clone(), kern);
            let mut rng = Pcg64::seed(7);
            let out = e.embed(&mut src, &mut rng).unwrap();
            let err = normalized_frobenius_error(&k, &out.embedding);
            assert!(err < 1e-5, "{}: err {err}", e.name());
            assert_eq!(out.embedding.rank(), 3);
            assert_eq!(out.embedding.n(), 48);
        }
    }

    #[test]
    fn plain_kmeans_has_no_embedder() {
        assert!(embedder_for(Method::PlainKmeans, 2, 5, 64, 1).is_none());
    }

    #[test]
    fn embedder_names_match_method_display() {
        for method in [
            Method::OnePass,
            Method::GaussianOnePass,
            Method::Nystrom { m: 17 },
            Method::Exact,
            Method::FullKernel,
        ] {
            let e = embedder_for(method, 2, 5, 64, 1).unwrap();
            assert_eq!(e.name(), method.to_string());
        }
    }

    #[test]
    fn nystrom_embedder_rejects_bad_geometry() {
        let x = random_x(2, 2, 20);
        let mut src = NativeBlockSource::pow2(x, Kernel::paper_poly2());
        let mut rng = Pcg64::seed(1);
        let too_many =
            NystromEmbedder { rank: 2, m: 50, sampling: NystromSampling::Uniform, threads: 1 };
        assert!(too_many.embed(&mut src, &mut rng).is_err());
        let rank_over_m =
            NystromEmbedder { rank: 6, m: 4, sampling: NystromSampling::Uniform, threads: 1 };
        assert!(rank_over_m.embed(&mut src, &mut rng).is_err());
    }

    #[test]
    fn one_pass_embedder_rejects_non_pow2_padding() {
        let x = random_x(3, 2, 20);
        let mut src = NativeBlockSource::new(x, Kernel::paper_poly2(), 20); // not pow2
        let mut rng = Pcg64::seed(1);
        let e = OnePassEmbedder { rank: 2, oversample: 4, batch: 8, threads: 1 };
        let err = e.embed(&mut src, &mut rng).unwrap_err();
        assert!(err.to_string().contains("power-of-two"));
    }

    #[test]
    fn gaussian_memory_model_exceeds_srht() {
        let srht = OnePassEmbedder { rank: 2, oversample: 5, batch: 64, threads: 1 };
        let gauss = GaussianOnePassEmbedder { rank: 2, oversample: 5, batch: 64, threads: 1 };
        assert!(gauss.memory_model(1000, 1024).persistent > srht.memory_model(1000, 1024).persistent);
    }
}
