//! Library-first public API: builder → fit → model.
//!
//! The experiment CLI (`rkc run …`) is one client of this layer; embed it
//! directly for services, sharding, and anything else that needs the
//! paper's one-pass kernel clustering without the experiment harness.
//!
//! # Quickstart
//!
//! ```
//! use rkc::api::KernelClusterer;
//! use rkc::data;
//! use rkc::rng::Pcg64;
//!
//! // the paper's Fig-1 synthetic set: plain K-means scores ~0.5 on it
//! let ds = data::cross_lines(&mut Pcg64::seed(7), 512);
//!
//! let model = KernelClusterer::new(2)   // k = 2 clusters
//!     .rank(2)                          // embedding rank r
//!     .oversample(10)                   // sketch width r' = r + l
//!     .seed(42)
//!     .fit(&ds.x)?;
//!
//! let acc = rkc::clustering::accuracy(model.labels(), &ds.labels, 2);
//! assert!(acc > 0.9, "kernel embedding separates the crossing lines");
//!
//! // out-of-sample: embed + assign points the model never saw
//! let held_out = data::cross_lines(&mut Pcg64::seed(8), 64);
//! let predicted = model.predict(&held_out.x)?;
//! assert_eq!(predicted.len(), 64);
//! # Ok::<(), rkc::error::RkcError>(())
//! ```

mod embedder;
mod model;

pub use embedder::{
    embedder_for, EmbedOutcome, Embedder, ExactEmbedder, FullKernelEmbedder,
    GaussianOnePassEmbedder, NystromEmbedder, OnePassEmbedder,
};
pub use model::{FitMetrics, FittedModel};

use std::sync::OnceLock;
use std::time::{Duration, Instant};

use crate::clustering::{kernel_kmeans, kmeans_threaded, KmeansOpts};
use crate::config::{Backend, ExperimentConfig, Method, Precision};
use crate::coordinator::{
    run_sketch_pass_sharded, xla_kmeans, xla_preferred_n_pad, FusedXlaSketchRows, XlaBlockSource,
};
use crate::error::{Result, RkcError};
use crate::kernels::{
    column_batches, full_kernel_matrix_threaded, BlockSource, Kernel, NativeBlockSource,
};
use crate::linalg::Mat;
use crate::lowrank::{one_pass_recovery_threaded, OnePassSketch};
use crate::metrics::{MemoryModel, MethodMemory};
use crate::rng::Pcg64;
use crate::runtime::ArtifactRegistry;
use crate::sketch::Srht;
use crate::util::parallel;

pub(crate) use model::Assigner;

/// Builder for a kernel clustering run: kernel, method, rank,
/// oversampling, backend, seed and K-means options — typed, validated,
/// and defaulted to the paper's protocol.
///
/// `fit(&x)` consumes a p × n data matrix (columns are samples) and
/// returns a [`FittedModel`]; `fit_stream` consumes kernel blocks from
/// any [`BlockSource`] instead, for data that never materializes.
#[derive(Clone, Debug)]
pub struct KernelClusterer {
    k: usize,
    kernel: Kernel,
    method: Method,
    rank: usize,
    oversample: usize,
    batch: usize,
    seed: u64,
    backend: Backend,
    threads: usize,
    kmeans_restarts: usize,
    kmeans_iters: usize,
    kmeans_tol: f64,
    artifacts_dir: String,
    /// serving precision stamped onto the fitted model (`F64` default;
    /// `F32` opts embed/predict into the single-precision SIMD path)
    precision: Precision,
    /// persist every successful fit here (path or directory); `None`
    /// means no auto-save
    auto_save: Option<String>,
    /// strict builders reject advisory misconfigurations (l < r); the
    /// experiment-config path relaxes this for ablation sweeps
    strict: bool,
}

impl KernelClusterer {
    /// A clusterer for `k` clusters with the paper's defaults: one-pass
    /// SRHT method, homogeneous quadratic kernel, r = 2, l = 5, native
    /// backend, 10 K-means restarts × 20 iterations.
    pub fn new(k: usize) -> Self {
        KernelClusterer {
            k,
            kernel: Kernel::paper_poly2(),
            method: Method::OnePass,
            rank: 2,
            oversample: 5,
            batch: 256,
            seed: 2016,
            backend: Backend::Native,
            threads: 1,
            kmeans_restarts: 10,
            kmeans_iters: 20,
            kmeans_tol: 1e-9,
            artifacts_dir: "artifacts".into(),
            precision: Precision::F64,
            auto_save: None,
            strict: true,
        }
    }

    /// Mirror an [`ExperimentConfig`] (the compatibility bridge the
    /// experiment driver rides on). Advisory validation is relaxed so
    /// ablation sweeps (e.g. oversampling l below r) still run.
    pub fn from_config(cfg: &ExperimentConfig) -> Self {
        KernelClusterer {
            k: cfg.k,
            kernel: cfg.kernel,
            method: cfg.method,
            rank: cfg.rank,
            oversample: cfg.oversample,
            batch: cfg.batch,
            seed: cfg.seed,
            backend: cfg.backend,
            threads: cfg.threads,
            kmeans_restarts: cfg.kmeans_restarts,
            kmeans_iters: cfg.kmeans_iters,
            kmeans_tol: cfg.kmeans_tol,
            artifacts_dir: cfg.artifacts_dir.clone(),
            precision: cfg.precision.unwrap_or_default(),
            auto_save: None,
            strict: false,
        }
    }

    /// Serving precision for the fitted model's `embed`/`predict`
    /// (default [`Precision::F64`]; fitting always runs in f64 either
    /// way — see [`Precision`]).
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Override the cluster count after construction (e.g. to adopt a
    /// dataset's ground-truth k).
    pub fn clusters(mut self, k: usize) -> Self {
        self.k = k;
        self
    }

    /// The Mercer kernel to cluster under (default: the paper's
    /// homogeneous quadratic, [`Kernel::paper_poly2`]).
    pub fn kernel(mut self, kernel: Kernel) -> Self {
        self.kernel = kernel;
        self
    }

    /// The low-rank strategy (default: [`Method::OnePass`], the paper's
    /// Alg. 1).
    pub fn method(mut self, method: Method) -> Self {
        self.method = method;
        self
    }

    /// Embedding rank r (the number of kept eigenpairs).
    pub fn rank(mut self, rank: usize) -> Self {
        self.rank = rank;
        self
    }

    /// Oversampling l; the sketch width is r' = r + l.
    pub fn oversample(mut self, oversample: usize) -> Self {
        self.oversample = oversample;
        self
    }

    /// Streaming batch width (columns per kernel block).
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Master seed. Every random draw in the fit — SRHT signs and row
    /// sampling, Nyström landmarks, K-means++ — derives from it through
    /// split PCG streams, so a fit is exactly reproducible (and
    /// thread-count-independent; see [`threads`](Self::threads)).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Compute backend for the bulk work (default: [`Backend::Native`]).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Worker threads for the parallel execution subsystem: sharded
    /// gram-block production, the FWHT stage, K-means restarts, and the
    /// Nyström projection. `0` means auto-detect via
    /// `std::thread::available_parallelism`. Results are bit-identical
    /// for every thread count (the determinism contract in
    /// `ARCHITECTURE.md`, enforced by `tests/parallel_determinism.rs`).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Number of independent K-means++ restarts; the best objective
    /// wins. The paper's protocol (§4) runs 10 — the default.
    pub fn kmeans_restarts(mut self, restarts: usize) -> Self {
        self.kmeans_restarts = restarts;
        self
    }

    /// Lloyd-iteration cap per restart. The paper's protocol runs 20 —
    /// the default.
    pub fn kmeans_iters(mut self, iters: usize) -> Self {
        self.kmeans_iters = iters;
        self
    }

    /// Relative objective-improvement tolerance for early stopping a
    /// Lloyd run (default `1e-9`, effectively "run to convergence" —
    /// the paper-protocol value [`KmeansOpts::paper`] uses).
    pub fn kmeans_tol(mut self, tol: f64) -> Self {
        self.kmeans_tol = tol;
        self
    }

    /// Directory holding the compiled XLA artifacts (XLA backend only).
    pub fn artifacts_dir(mut self, dir: impl Into<String>) -> Self {
        self.artifacts_dir = dir.into();
        self
    }

    /// Persist every successful fit to `target` in the `.rkc` format
    /// (see [`crate::model_io`]). If `target` is an existing directory
    /// (or ends with `/`), the model is written as `model.rkc` inside it
    /// — the artifacts-directory-driven flavor the CLI `save` subcommand
    /// uses. Parent directories are created as needed.
    ///
    /// A failed write fails the whole `fit` call (the in-memory model is
    /// dropped with the error): when persistence was requested, silently
    /// returning an unpersisted model would be worse. Callers who want
    /// the model regardless of disk state should fit without `auto_save`
    /// and call [`FittedModel::save`] themselves.
    pub fn auto_save(mut self, target: impl Into<String>) -> Self {
        self.auto_save = Some(target.into());
        self
    }

    /// r' = r + l, the sketch width.
    pub fn sketch_width(&self) -> usize {
        self.rank + self.oversample
    }

    /// The effective worker count: the configured value, with `0`
    /// resolved to the machine's available parallelism.
    fn threads_resolved(&self) -> usize {
        parallel::resolve_threads(self.threads).max(1)
    }

    /// Check the configuration against a dataset of `n` samples.
    pub fn validate(&self, n: usize) -> Result<()> {
        let bad = |m: String| Err(RkcError::InvalidConfig(m));
        if self.k == 0 {
            return bad("k must be at least 1".into());
        }
        if n == 0 {
            return bad("cannot fit an empty dataset (n = 0)".into());
        }
        if self.k > n {
            return bad(format!("k={} clusters exceed n={n} samples", self.k));
        }
        if self.batch == 0 {
            return bad("batch must be at least 1".into());
        }
        if self.kmeans_restarts == 0 {
            return bad("kmeans_restarts must be at least 1 (0 reaches the solver with \
                        no run to pick a winner from)"
                .into());
        }
        if self.kmeans_iters == 0 {
            return bad("kmeans_iters must be at least 1 (0 never runs a Lloyd step, so \
                        centroids would stay at their K-means++ seeds)"
                .into());
        }
        if self.method != Method::PlainKmeans {
            if self.rank == 0 {
                return bad("rank must be at least 1 for embedding methods".into());
            }
            if self.rank > n {
                return bad(format!("rank r={} exceeds n={n} samples", self.rank));
            }
        }
        match self.method {
            Method::OnePass | Method::GaussianOnePass => {
                if self.strict && self.oversample < self.rank {
                    return bad(format!(
                        "oversampling l={} must be at least rank r={} (sketch width \
                         r' = r + l >= 2r keeps the recovery solve well-conditioned)",
                        self.oversample, self.rank
                    ));
                }
                if self.sketch_width() > n {
                    return bad(format!(
                        "sketch width r'={} exceeds n={n} samples",
                        self.sketch_width()
                    ));
                }
            }
            Method::Nystrom { m } => {
                if m < self.rank {
                    return bad(format!("nystrom m={m} is below rank r={}", self.rank));
                }
                if m > n {
                    return bad(format!("nystrom m={m} exceeds n={n} samples"));
                }
            }
            _ => {}
        }
        Ok(())
    }

    /// Fit on a p × n data matrix (columns are samples). Opens the
    /// artifact registry itself when the XLA backend is selected.
    ///
    /// Note: with [`Backend::Xla`] every `fit` call opens a fresh
    /// registry and (re)compiles the artifacts it touches. Long-running
    /// services should open one [`ArtifactRegistry`] and call
    /// [`fit_with_registry`](Self::fit_with_registry) so compiled
    /// executables are reused across fits.
    pub fn fit(&self, x: &Mat) -> Result<FittedModel> {
        // plain/full-kernel baselines never touch XLA — don't demand
        // artifacts for them even when the backend says Xla
        let needs_backend = !matches!(self.method, Method::PlainKmeans | Method::FullKernel);
        match self.backend {
            Backend::Xla if needs_backend => {
                let registry = ArtifactRegistry::open(&self.artifacts_dir)?;
                self.fit_with_registry(x, Some(&registry))
            }
            _ => self.fit_with_registry(x, None),
        }
    }

    /// Fit with a caller-managed registry (lets services compile the
    /// artifacts once and reuse them across many fits).
    pub fn fit_with_registry(
        &self,
        x: &Mat,
        registry: Option<&ArtifactRegistry>,
    ) -> Result<FittedModel> {
        let model = self.fit_with_registry_inner(x, registry)?;
        self.auto_save_model(&model)?;
        Ok(model)
    }

    fn fit_with_registry_inner(
        &self,
        x: &Mat,
        registry: Option<&ArtifactRegistry>,
    ) -> Result<FittedModel> {
        let _fit_span = crate::obs::span("api.fit");
        let n = x.cols();
        self.validate(n)?;
        // only the embedding methods can route compute through XLA;
        // plain/full-kernel baselines run fine without a registry
        let needs_backend = !matches!(self.method, Method::PlainKmeans | Method::FullKernel);
        if needs_backend && self.backend == Backend::Xla && registry.is_none() {
            return Err(RkcError::backend(
                "XLA backend requires an artifact registry (run `make artifacts`)",
            ));
        }
        let mut rng = Pcg64::seed_stream(self.seed, 0x7a1a1);
        let kopts = self.kmeans_opts();

        match self.method {
            Method::PlainKmeans => {
                let t0 = Instant::now();
                let res = kmeans_threaded(x, &kopts, &mut rng, self.threads_resolved());
                let kmeans_time = t0.elapsed();
                crate::obs::record_stage("kmeans", kmeans_time);
                Ok(FittedModel {
                    kernel: self.kernel,
                    k: self.k,
                    embedding: None,
                    labels: res.labels,
                    assigner: Assigner::Input { centroids: res.centroids },
                    train_x: Some(x.clone()),
                    train_cols: OnceLock::new(),
                    precision: self.precision,
                    f32_state: OnceLock::new(),
                    generation: 0,
                    n_pad: n.next_power_of_two(),
                    batch: self.batch,
                    metrics: FitMetrics {
                        method: self.method.to_string(),
                        n,
                        rank: 0,
                        objective: res.objective,
                        memory: MethodMemory {
                            method: self.method.to_string(),
                            persistent: std::mem::size_of::<f64>() * x.rows() * self.k,
                            transient: 0,
                            recovery: 0,
                        },
                        sketch_time: Duration::ZERO,
                        recovery_time: Duration::ZERO,
                        kmeans_time,
                    },
                })
            }
            Method::FullKernel => {
                let t0 = Instant::now();
                let kmat = full_kernel_matrix_threaded(x, self.kernel, self.threads_resolved());
                let sketch_time = t0.elapsed(); // "sketch" = materialization
                let t1 = Instant::now();
                let res =
                    kernel_kmeans(&kmat, self.k, self.kmeans_restarts, self.kmeans_iters, &mut rng);
                let kmeans_time = t1.elapsed();
                // per-cluster constants for out-of-sample assignment
                let mut sizes = vec![0usize; self.k];
                for &l in &res.labels {
                    sizes[l] += 1;
                }
                let mut sums = vec![0.0f64; self.k];
                for i in 0..n {
                    for j in 0..n {
                        if res.labels[i] == res.labels[j] {
                            sums[res.labels[i]] += kmat[(i, j)];
                        }
                    }
                }
                let self_terms: Vec<f64> = sums
                    .iter()
                    .zip(&sizes)
                    .map(|(&s, &c)| if c > 0 { s / (c * c) as f64 } else { f64::INFINITY })
                    .collect();
                crate::obs::record_stage("sketch", sketch_time);
                crate::obs::record_stage("kmeans", kmeans_time);
                Ok(FittedModel {
                    kernel: self.kernel,
                    k: self.k,
                    embedding: None,
                    labels: res.labels,
                    assigner: Assigner::KernelClusters { sizes, self_terms },
                    train_x: Some(x.clone()),
                    train_cols: OnceLock::new(),
                    precision: self.precision,
                    f32_state: OnceLock::new(),
                    generation: 0,
                    n_pad: n.next_power_of_two(),
                    batch: self.batch,
                    metrics: FitMetrics {
                        method: self.method.to_string(),
                        n,
                        rank: 0,
                        objective: res.objective,
                        memory: MemoryModel::full_kernel_kmeans(n, self.k),
                        sketch_time,
                        recovery_time: Duration::ZERO,
                        kmeans_time,
                    },
                })
            }
            _ => {
                let n_pad = match (self.backend, registry) {
                    (Backend::Xla, Some(reg)) => {
                        xla_preferred_n_pad(reg, self.kernel, x.rows(), n)
                            .unwrap_or_else(|| n.next_power_of_two())
                    }
                    _ => n.next_power_of_two(),
                };
                let (outcome, memory) = self.compute_embedding(x, registry, n_pad, &mut rng)?;
                self.finish_embedded(outcome, memory, Some(x.clone()), n_pad, registry, &mut rng)
            }
        }
    }

    /// Fit from streamed kernel blocks (data never materialized). The
    /// resulting model cannot `embed`/`predict` out-of-sample points —
    /// there is no retained training data to evaluate the kernel against.
    pub fn fit_stream(&self, mut src: impl BlockSource) -> Result<FittedModel> {
        self.fit_stream_dyn(&mut src)
    }

    /// Object-safe flavor of [`fit_stream`](Self::fit_stream).
    pub fn fit_stream_dyn(&self, src: &mut dyn BlockSource) -> Result<FittedModel> {
        let _fit_span = crate::obs::span("api.fit_stream");
        let n = src.n();
        self.validate(n)?;
        match self.method {
            Method::PlainKmeans => {
                return Err(RkcError::unsupported(
                    "plain K-means needs raw coordinates; use `fit` with the data matrix",
                ))
            }
            Method::FullKernel => {
                return Err(RkcError::unsupported(
                    "full-kernel K-means clusters on the materialized kernel; use `fit` \
                     with the data matrix (or the FullKernelEmbedder for a dense \
                     rank-r embedding from a stream)",
                ))
            }
            _ => {}
        }
        let mut rng = Pcg64::seed_stream(self.seed, 0x7a1a1);
        let embedder = embedder_for(
            self.method,
            self.rank,
            self.oversample,
            self.batch,
            self.threads_resolved(),
        )
        .expect("non-embedding methods rejected above");
        let outcome = embedder.embed(src, &mut rng)?;
        let memory = embedder.memory_model(n, src.n_padded());
        let n_pad = src.n_padded();
        let model = self.finish_embedded(outcome, memory, None, n_pad, None, &mut rng)?;
        self.auto_save_model(&model)?;
        Ok(model)
    }

    /// Apply the [`auto_save`](Self::auto_save) setting to a fresh fit:
    /// a directory target gets `model.rkc` appended, a file target is
    /// written as-is (the shared rule in
    /// [`model_io::resolve_model_target`](crate::model_io::resolve_model_target)).
    fn auto_save_model(&self, model: &FittedModel) -> Result<()> {
        let Some(target) = &self.auto_save else {
            return Ok(());
        };
        model.save(&crate::model_io::resolve_model_target(target))
    }

    /// K-means on the recovered embedding + model assembly (shared by
    /// `fit` and `fit_stream`).
    fn finish_embedded(
        &self,
        outcome: EmbedOutcome,
        memory: MethodMemory,
        train_x: Option<Mat>,
        n_pad: usize,
        registry: Option<&ArtifactRegistry>,
        rng: &mut Pcg64,
    ) -> Result<FittedModel> {
        let kopts = self.kmeans_opts();
        let threads = self.threads_resolved();
        let emb = outcome.embedding;
        let t0 = Instant::now();
        let res = match (self.backend, registry) {
            (Backend::Xla, Some(reg)) => match xla_kmeans(reg, &emb.y, &kopts, rng) {
                Ok(r) => r,
                // no artifact for this (r, k, n) — fall back silently;
                // the artifact set covers the paper's experiments
                Err(_) => kmeans_threaded(&emb.y, &kopts, rng, threads),
            },
            _ => kmeans_threaded(&emb.y, &kopts, rng, threads),
        };
        let kmeans_time = t0.elapsed();
        crate::obs::record_stage("sketch", outcome.sketch_time);
        crate::obs::record_stage("recovery", outcome.recovery_time);
        crate::obs::record_stage("kmeans", kmeans_time);
        Ok(FittedModel {
            kernel: self.kernel,
            k: self.k,
            labels: res.labels,
            assigner: Assigner::Embedded { centroids: res.centroids },
            train_x,
            train_cols: OnceLock::new(),
            precision: self.precision,
            f32_state: OnceLock::new(),
            generation: 0,
            n_pad,
            batch: self.batch,
            metrics: FitMetrics {
                method: self.method.to_string(),
                n: emb.n(),
                rank: emb.rank(),
                objective: res.objective,
                memory,
                sketch_time: outcome.sketch_time,
                recovery_time: outcome.recovery_time,
                kmeans_time,
            },
            embedding: Some(emb),
        })
    }

    /// Produce the embedding for the configured method/backend, with the
    /// production fast paths (fused XLA sketch, sharded native pipeline)
    /// layered over the generic [`Embedder`] dispatch.
    fn compute_embedding(
        &self,
        x: &Mat,
        registry: Option<&ArtifactRegistry>,
        n_pad: usize,
        rng: &mut Pcg64,
    ) -> Result<(EmbedOutcome, MethodMemory)> {
        let n = x.cols();
        let width = self.sketch_width();
        let threads = self.threads_resolved();

        // fused XLA fast path: one artifact call computes (HD)K[:, J]
        if self.method == Method::OnePass && self.backend == Backend::Xla {
            let reg = registry.expect("registry presence checked by caller");
            let mut srht = Srht::draw(rng, n_pad, width);
            srht.mask_padding(n);
            let t0 = Instant::now();
            let sketch = match FusedXlaSketchRows::new(reg, x, self.kernel, srht.clone()) {
                Ok(mut p) => run_xla_sketch_pass(&mut p, x, n)?,
                // no fused artifact for this (kernel, p, n) — reuse the
                // SAME SRHT draw over a block source, so a fallback run
                // stays bit-identical to the native backend at this seed
                Err(_) => {
                    let mut src = self.block_source(x, registry, n_pad)?;
                    let mut sk = OnePassSketch::new(srht, n);
                    let mut scratch = Vec::new();
                    for cols in column_batches(n, self.batch) {
                        let kb = src.block(&cols);
                        let rows = sk.srht().apply_to_block_with(&kb, threads, &mut scratch);
                        sk.ingest(&cols, &rows);
                    }
                    sk
                }
            };
            let sketch_time = t0.elapsed();
            let t1 = Instant::now();
            let embedding = one_pass_recovery_threaded(&sketch, self.rank, threads);
            let outcome = EmbedOutcome { embedding, sketch_time, recovery_time: t1.elapsed() };
            return Ok((outcome, MemoryModel::one_pass(n, n_pad, width, self.rank, self.batch)));
        }

        // sharded native pipeline: one producer shard per worker feeding
        // the bounded-channel consumer; channel cap = producer count, so
        // peak memory stays O(n·r' + P·b·n_pad). The producers consume
        // the whole thread budget — gram production dominates the FWHT —
        // so the consumer transforms single-threaded rather than
        // oversubscribing the cores. Bit-identical to the sequential
        // embedder path at the same seed (same SRHT draw,
        // order-independent accumulation).
        if self.method == Method::OnePass && self.backend == Backend::Native && threads > 1 {
            let mut srht = Srht::draw(rng, n_pad, width);
            srht.mask_padding(n);
            let t0 = Instant::now();
            let (sketch, _stats) = run_sketch_pass_sharded(
                &NativeBlockSource::new(x.clone(), self.kernel, n_pad),
                srht,
                self.batch,
                threads,
                threads,
                1,
            );
            let sketch_time = t0.elapsed();
            let t1 = Instant::now();
            let embedding = one_pass_recovery_threaded(&sketch, self.rank, threads);
            let outcome = EmbedOutcome { embedding, sketch_time, recovery_time: t1.elapsed() };
            return Ok((outcome, MemoryModel::one_pass(n, n_pad, width, self.rank, self.batch)));
        }

        let embedder = embedder_for(self.method, self.rank, self.oversample, self.batch, threads)
            .expect("non-embedding methods handled by fit");
        let mut src = self.block_source(x, registry, n_pad)?;
        let outcome = embedder.embed(src.as_mut(), rng)?;
        let memory = embedder.memory_model(n, n_pad);
        Ok((outcome, memory))
    }

    /// Kernel block source for the configured backend, degrading to the
    /// native gram path when no matching artifact exists. Native block
    /// production fans out over the resolved worker count.
    fn block_source(
        &self,
        x: &Mat,
        registry: Option<&ArtifactRegistry>,
        n_pad: usize,
    ) -> Result<Box<dyn BlockSource>> {
        let native = |clusterer: &Self| {
            NativeBlockSource::new(x.clone(), clusterer.kernel, n_pad)
                .with_threads(clusterer.threads_resolved())
        };
        Ok(match (self.backend, registry) {
            (Backend::Xla, Some(reg)) => {
                match XlaBlockSource::new(reg, x.clone(), self.kernel, n_pad) {
                    Ok(src) => Box::new(src),
                    // graceful degradation when no gram artifact matches
                    Err(_) => Box::new(native(self)),
                }
            }
            _ => Box::new(native(self)),
        })
    }

    fn kmeans_opts(&self) -> KmeansOpts {
        KmeansOpts {
            k: self.k,
            restarts: self.kmeans_restarts,
            max_iters: self.kmeans_iters,
            tol: self.kmeans_tol,
        }
    }
}

/// Sequential sketch pass over the fused XLA producer (PJRT handles are
/// not Send, so this cannot reuse the threaded native pipeline).
fn run_xla_sketch_pass(
    p: &mut FusedXlaSketchRows,
    x: &Mat,
    n_real: usize,
) -> Result<OnePassSketch> {
    let mut sketch = OnePassSketch::new(p.srht().clone(), n_real);
    // the artifact has a fixed batch width; stream at exactly that width
    let width = p.batch_width();
    for cols in column_batches(n_real, width) {
        let rows = p.rows_for(x, &cols)?;
        sketch.ingest(&cols, &rows);
    }
    Ok(sketch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::accuracy;
    use crate::data;

    #[test]
    fn builder_validation_catches_bad_geometry() {
        let x = data::cross_lines(&mut Pcg64::seed(1), 40).x;
        // rank 0
        assert!(KernelClusterer::new(2).rank(0).fit(&x).is_err());
        // oversampling below rank (strict builder)
        assert!(KernelClusterer::new(2).rank(4).oversample(2).fit(&x).is_err());
        // k > n
        assert!(KernelClusterer::new(100).fit(&x).is_err());
        // k = 0
        assert!(KernelClusterer::new(0).fit(&x).is_err());
        // nystrom m below rank
        assert!(KernelClusterer::new(2)
            .method(Method::Nystrom { m: 1 })
            .rank(2)
            .fit(&x)
            .is_err());
        // the defaults are fine
        assert!(KernelClusterer::new(2).fit(&x).is_ok());
    }

    #[test]
    fn zero_kmeans_restarts_or_iters_is_a_typed_error() {
        let x = data::cross_lines(&mut Pcg64::seed(10), 32).x;
        let err = KernelClusterer::new(2).kmeans_restarts(0).fit(&x).unwrap_err();
        assert!(matches!(err, RkcError::InvalidConfig(_)), "{err}");
        assert!(err.to_string().contains("kmeans_restarts"), "{err}");
        let err = KernelClusterer::new(2).kmeans_iters(0).fit(&x).unwrap_err();
        assert!(matches!(err, RkcError::InvalidConfig(_)), "{err}");
        assert!(err.to_string().contains("kmeans_iters"), "{err}");
        // the relaxed config path rejects them too: 0 is never meaningful
        let mut cfg = ExperimentConfig::table1();
        cfg.kmeans_iters = 0;
        assert!(KernelClusterer::from_config(&cfg).fit(&x).is_err());
    }

    #[test]
    fn auto_save_persists_the_fit() {
        let _g = crate::fault::test_guard(); // saves cross a failpoint site
        let ds = data::cross_lines(&mut Pcg64::seed(16), 64);
        let dir = std::env::temp_dir().join(format!("rkc_auto_save_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let dir_str = dir.to_str().unwrap().to_string();
        // directory target: model.rkc appears inside
        let model = KernelClusterer::new(2)
            .oversample(8)
            .auto_save(dir_str.clone())
            .fit(&ds.x)
            .unwrap();
        let path = format!("{dir_str}/model.rkc");
        let back = FittedModel::load(&path).unwrap();
        assert_eq!(back.labels(), model.labels());
        assert_eq!(back.predict(&ds.x).unwrap(), model.predict(&ds.x).unwrap());
        std::fs::remove_file(&path).unwrap();
        std::fs::remove_dir(&dir).unwrap();
    }

    #[test]
    fn relaxed_config_path_allows_ablation_oversampling() {
        let mut cfg = ExperimentConfig::table1();
        cfg.n = 64;
        cfg.oversample = 0; // below rank: rejected strictly, allowed here
        let x = data::cross_lines(&mut Pcg64::seed(2), 64).x;
        assert!(KernelClusterer::from_config(&cfg).fit(&x).is_ok());
    }

    #[test]
    fn fit_separates_cross_lines() {
        let ds = data::cross_lines(&mut Pcg64::seed(3), 400);
        let model = KernelClusterer::new(2).oversample(10).seed(9).fit(&ds.x).unwrap();
        let acc = accuracy(model.labels(), &ds.labels, 2);
        assert!(acc > 0.9, "one-pass accuracy {acc}");
        assert!(model.metrics().memory.peak() > 0);
        assert_eq!(model.metrics().rank, 2);
        let err = model.approx_error().unwrap();
        assert!(err.is_finite() && err < 1.0, "approx error {err}");
    }

    #[test]
    fn fit_stream_works_without_raw_data() {
        let ds = data::cross_lines(&mut Pcg64::seed(4), 200);
        let src = NativeBlockSource::pow2(ds.x.clone(), Kernel::paper_poly2());
        let model = KernelClusterer::new(2).oversample(8).fit_stream(src).unwrap();
        let acc = accuracy(model.labels(), &ds.labels, 2);
        assert!(acc > 0.9, "streamed accuracy {acc}");
        // no retained data => no out-of-sample ops
        assert!(model.predict(&ds.x).is_err());
        assert!(model.embed(&ds.x).is_err());
    }

    #[test]
    fn plain_kmeans_model_predicts_in_input_space() {
        let ds = data::gaussian_blobs(&mut Pcg64::seed(5), 120, 3, 4, 0.3);
        let model = KernelClusterer::new(4)
            .method(Method::PlainKmeans)
            .fit(&ds.x)
            .unwrap();
        // predicting the training points reproduces the fit labels
        let pred = model.predict(&ds.x).unwrap();
        assert_eq!(pred, model.labels());
        assert!(model.embed(&ds.x).is_err(), "no kernel embedding for plain");
    }

    #[test]
    fn full_kernel_model_assigns_out_of_sample() {
        let ds = data::cross_lines(&mut Pcg64::seed(6), 120);
        let model = KernelClusterer::new(2)
            .method(Method::FullKernel)
            .kmeans_restarts(20)
            .fit(&ds.x)
            .unwrap();
        let acc = accuracy(model.labels(), &ds.labels, 2);
        assert!(acc > 0.9, "kernel k-means accuracy {acc}");
        // re-assigning the training points agrees with the fit labels
        let pred = model.predict(&ds.x).unwrap();
        let agree = pred.iter().zip(model.labels()).filter(|(a, b)| a == b).count();
        assert!(agree as f64 / 120.0 > 0.95, "only {agree}/120 agree");
    }

    #[test]
    fn xla_backend_without_registry_is_typed_error() {
        let ds = data::cross_lines(&mut Pcg64::seed(7), 64);
        let err = KernelClusterer::new(2)
            .backend(Backend::Xla)
            .artifacts_dir("/nonexistent/rkc_artifacts")
            .fit(&ds.x)
            .unwrap_err();
        assert!(err.to_string().contains("manifest.json"), "{err}");
    }
}
