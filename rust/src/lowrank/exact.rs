//! "Exact" top-r eigendecomposition baselines.
//!
//! `exact_topr_dense` — full Jacobi eigendecomposition of a materialized
//! kernel (test scale; O(n²) memory, the thing the paper avoids).
//!
//! `exact_topr_streaming` — blocked subspace iteration against a
//! [`BlockSource`]: converges to the true top-r eigenpairs to machine
//! precision while touching `K` only through streamed column blocks
//! (multiple passes, O(nr) memory). This is the "Exact Eigenvalue
//! Decomposition" reference line of Table 1 / Fig. 3 at production scale.

use crate::kernels::BlockSource;
use crate::linalg::{householder_qr, jacobi_eig, Mat};

use super::Embedding;

/// Dense exact top-r: eigendecompose the full matrix.
pub fn exact_topr_dense(kmat: &Mat, rank: usize) -> Embedding {
    let n = kmat.rows();
    assert!(rank <= n);
    let (evals, v) = jacobi_eig(kmat);
    let mut y = Mat::zeros(rank, n);
    let mut eigenvalues = vec![0.0; rank];
    for i in 0..rank {
        let l = evals[i].max(0.0);
        eigenvalues[i] = l;
        let s = l.sqrt();
        for j in 0..n {
            y[(i, j)] = s * v[(j, i)];
        }
    }
    Embedding { y, eigenvalues }
}

/// Streaming exact top-r via blocked subspace (orthogonal) iteration:
/// `V ← orth(K V)` repeated `iters` times, then a Rayleigh–Ritz step.
/// Each `K V` product is one streamed pass over column blocks of size
/// `batch`. With a spectral gap this converges geometrically; `iters` of
/// 30–50 reaches f64 precision on the paper's kernels.
pub fn exact_topr_streaming(
    src: &mut dyn BlockSource,
    rank: usize,
    iters: usize,
    batch: usize,
) -> Embedding {
    exact_topr_streaming_threaded(src, rank, iters, batch, 1)
}

/// [`exact_topr_streaming`] with the `K V` products fanned out over
/// `threads` workers. Each worker owns a disjoint contiguous span of the
/// product's output rows and accumulates them in the same per-element
/// order as the sequential loop, so `threads = 1` and `threads = N`
/// are bit-identical (the crate-wide determinism contract).
pub fn exact_topr_streaming_threaded(
    src: &mut dyn BlockSource,
    rank: usize,
    iters: usize,
    batch: usize,
    threads: usize,
) -> Embedding {
    let n = src.n();
    assert!(rank <= n);
    // deterministic full-rank start: mixed cosine basis
    let mut v = Mat::from_fn(n, rank, |i, j| {
        let t = (i * (j + 1)) as f64 / n as f64;
        (std::f64::consts::TAU * t).cos() + if i == j { 1.0 } else { 0.0 }
    });
    let (q0, _) = householder_qr(&v);
    v = q0;

    for it in 0..iters {
        let kv = stream_k_times(src, &v, batch, threads); // n × r
        let (q, _) = householder_qr(&kv);
        // convergence: principal angles between successive subspaces via
        // the singular values of VᵀQ (all ≈ 1 when converged). Cheap
        // (r × r) and saves full passes over K once the gap has done its
        // work — typically 10–20 iterations instead of the cap.
        let overlap = v.t_matmul(&q); // r × r
        v = q;
        if it >= 3 {
            let gram = overlap.t_matmul(&overlap);
            let min_cos2 = (0..rank)
                .map(|i| gram[(i, i)])
                .fold(f64::INFINITY, f64::min);
            if min_cos2 > 1.0 - 1e-14 {
                break;
            }
        }
    }

    // Rayleigh–Ritz: project K into span(V), diagonalize the r × r core.
    let kv = stream_k_times(src, &v, batch, threads);
    let mut core = v.t_matmul(&kv); // r × r ≈ VᵀKV
    core.symmetrize();
    let (evals, u) = jacobi_eig(&core);
    // rotate the basis: V* = V U, eigenvalue i = evals[i]
    let vstar = v.matmul(&u);
    let mut y = Mat::zeros(rank, n);
    let mut eigenvalues = vec![0.0; rank];
    for i in 0..rank {
        let l = evals[i].max(0.0);
        eigenvalues[i] = l;
        let s = l.sqrt();
        for j in 0..n {
            y[(i, j)] = s * vstar[(j, i)];
        }
    }
    Embedding { y, eigenvalues }
}

/// One streamed product `K V` (n × r) using blocks of `batch` columns.
/// Uses symmetry: `(K V)[J, :] = K[:, J]ᵀ V` block by block.
///
/// Column batches are contiguous, so each block's output rows form one
/// contiguous span of `out`; the span is split across workers via
/// [`parallel::for_each_row_chunk`](crate::util::parallel), each worker
/// accumulating its rows over `i` ascending with the same zero-skip —
/// the per-element add sequence is identical at every thread count.
fn stream_k_times(src: &mut dyn BlockSource, v: &Mat, batch: usize, threads: usize) -> Mat {
    let n = src.n();
    let r = v.cols();
    let mut out = Mat::zeros(n, r);
    for cols in crate::kernels::column_batches(n, batch) {
        let kb = src.block(&cols); // n_padded × b, padded rows zero
        let j0 = cols[0];
        let span = &mut out.data_mut()[j0 * r..(j0 + cols.len()) * r];
        crate::util::parallel::for_each_row_chunk(span, r, threads, |first, rows| {
            for (dj, orow) in rows.chunks_mut(r).enumerate() {
                let bj = first + dj;
                for i in 0..n {
                    let kij = kb[(i, bj)];
                    if kij == 0.0 {
                        continue;
                    }
                    let vrow = v.row(i);
                    for (o, &vv) in orow.iter_mut().zip(vrow) {
                        *o += kij * vv;
                    }
                }
            }
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{full_kernel_matrix, Kernel, NativeBlockSource};
    use crate::linalg::testutil::random_mat;
    use crate::rng::Pcg64;

    #[test]
    fn dense_exact_reproduces_best_rank_r() {
        let mut rng = Pcg64::seed(1);
        let x = random_mat(&mut rng, 4, 30);
        let k = full_kernel_matrix(&x, Kernel::Rbf { gamma: 0.6 });
        let emb = exact_topr_dense(&k, 5);
        let khat = emb.y.t_matmul(&emb.y);
        // optimal rank-5 residual from the spectrum
        let (evals, _) = jacobi_eig(&k);
        let best: f64 = evals[5..].iter().map(|l| l * l).sum::<f64>().sqrt();
        let got = k.sub(&khat).frobenius_norm();
        assert!((got - best).abs() < 1e-8 * k.frobenius_norm().max(1.0), "{got} vs {best}");
    }

    #[test]
    fn streaming_matches_dense_exact() {
        let mut rng = Pcg64::seed(2);
        let x = random_mat(&mut rng, 2, 50);
        let kern = Kernel::paper_poly2();
        let k = full_kernel_matrix(&x, kern);
        let dense = exact_topr_dense(&k, 2);
        let mut src = NativeBlockSource::pow2(x, kern);
        let stream = exact_topr_streaming(&mut src, 2, 40, 16);
        for i in 0..2 {
            assert!(
                (dense.eigenvalues[i] - stream.eigenvalues[i]).abs()
                    < 1e-7 * dense.eigenvalues[0].max(1.0),
                "eigenvalue {i}: {} vs {}",
                dense.eigenvalues[i],
                stream.eigenvalues[i]
            );
        }
        // the reconstructions must agree (eigvectors up to sign/rotation)
        let ka = dense.y.t_matmul(&dense.y);
        let kb = stream.y.t_matmul(&stream.y);
        let rel = ka.sub(&kb).frobenius_norm() / ka.frobenius_norm();
        assert!(rel < 1e-6, "reconstruction mismatch {rel}");
    }

    #[test]
    fn streaming_batch_size_invariance() {
        let mut rng = Pcg64::seed(3);
        let x = random_mat(&mut rng, 3, 33);
        let kern = Kernel::Rbf { gamma: 1.0 };
        let run = |batch: usize| {
            let mut src = NativeBlockSource::pow2(x.clone(), kern);
            exact_topr_streaming(&mut src, 3, 30, batch)
        };
        let a = run(1);
        let b = run(33);
        for i in 0..3 {
            assert!((a.eigenvalues[i] - b.eigenvalues[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn streaming_thread_count_bit_identity() {
        let mut rng = Pcg64::seed(5);
        let x = random_mat(&mut rng, 3, 41);
        let kern = Kernel::Rbf { gamma: 0.8 };
        let run = |threads: usize| {
            let mut src = NativeBlockSource::pow2(x.clone(), kern);
            exact_topr_streaming_threaded(&mut src, 3, 25, 8, threads)
        };
        let base = run(1);
        for threads in [2usize, 4, 7] {
            let got = run(threads);
            assert_eq!(got.y.data(), base.y.data(), "threads={threads}");
            assert_eq!(got.eigenvalues, base.eigenvalues, "threads={threads}");
        }
        // the threads=1 wrapper is the same code path
        let mut src = NativeBlockSource::pow2(x.clone(), kern);
        let wrapped = exact_topr_streaming(&mut src, 3, 25, 8);
        assert_eq!(wrapped.y.data(), base.y.data());
    }

    #[test]
    fn eigenvalues_descend_and_nonnegative() {
        let mut rng = Pcg64::seed(4);
        let x = random_mat(&mut rng, 2, 40);
        let mut src = NativeBlockSource::pow2(x, Kernel::paper_poly2());
        let emb = exact_topr_streaming(&mut src, 4, 30, 8);
        assert!(emb.eigenvalues.iter().all(|&l| l >= 0.0));
        for w in emb.eigenvalues.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        // quadratic kernel on R² has rank 3: λ₄ ≈ 0
        assert!(emb.eigenvalues[3] < 1e-8 * emb.eigenvalues[0]);
    }
}
