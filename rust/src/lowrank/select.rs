//! Model selection: inferring the number of clusters and choosing r.
//!
//! The paper points at both problems without solving them: §2.3 notes
//! that the kernel eigenvalue decomposition "can be used to infer the
//! number of clusters" (Girolami 2002, ref. [11]), and §5 says "the
//! parameter r is typically chosen with cross-validation on a subset of
//! data". Both fit naturally on top of the one-pass machinery, so we
//! ship them as first-class features:
//!
//! - [`infer_clusters_by_eigengap`] — the classic spectral heuristic:
//!   K̂'s dominant eigenvalues (which the one-pass sketch recovers for
//!   free) cluster into "signal" vs "tail"; the largest relative gap
//!   marks the cluster count.
//! - [`select_rank_by_subset`] — the §5 recipe: run the cheap pipeline
//!   on a uniformly-subsampled subset for each candidate r and pick the
//!   smallest r whose subset approximation error is within `tolerance`
//!   of the best candidate's.

use crate::kernels::{BlockSource, Kernel, NativeBlockSource};
use crate::linalg::Mat;
use crate::lowrank::{one_pass_recovery, streamed_frobenius_error, OnePassSketch};
use crate::rng::{sample_without_replacement, Pcg64};
use crate::sketch::Srht;

/// Largest-relative-eigengap estimate of the cluster count from a
/// descending nonnegative eigenvalue sequence. Considers gaps between
/// positions 1..max_k; returns the position after which the spectrum
/// drops the most (relative to the level before the drop).
pub fn infer_clusters_by_eigengap(eigenvalues: &[f64], max_k: usize) -> usize {
    let m = eigenvalues.len().min(max_k + 1);
    assert!(m >= 2, "need at least two eigenvalues to find a gap");
    let lambda1 = eigenvalues[0].max(1e-300);
    // only gaps that start at a *signal-level* eigenvalue count — the
    // relative gap at the noise floor is always ≈ 1 and meaningless
    let min_level = 1e-2 * lambda1;
    let mut best_k = 1;
    let mut best_gap = f64::NEG_INFINITY;
    for k in 1..m {
        let hi = eigenvalues[k - 1].max(0.0);
        let lo = eigenvalues[k].max(0.0);
        if hi < min_level {
            break;
        }
        let gap = (hi - lo) / hi.max(1e-300);
        if gap > best_gap {
            best_gap = gap;
            best_k = k;
        }
    }
    best_k
}

/// One-pass eigenvalue probe: run the sketch at width `probe_width` and
/// return the recovered dominant eigenvalues (descending). O(r'n) memory,
/// one pass — the cheap input to [`infer_clusters_by_eigengap`].
pub fn probe_spectrum(
    x: &Mat,
    kernel: Kernel,
    probe_width: usize,
    batch: usize,
    rng: &mut Pcg64,
) -> Vec<f64> {
    let mut src = NativeBlockSource::pow2(x.clone(), kernel);
    let (n, np) = (src.n(), src.n_padded());
    let mut srht = Srht::draw(rng, np, probe_width.min(n));
    srht.mask_padding(n);
    let mut sk = OnePassSketch::new(srht, n);
    for cols in crate::kernels::column_batches(n, batch) {
        let kb = src.block(&cols);
        let rows = sk.srht().apply_to_block(&kb, 1);
        sk.ingest(&cols, &rows);
    }
    let emb = one_pass_recovery(&sk, probe_width.min(n));
    emb.eigenvalues
}

/// §5's cross-validation recipe: for each candidate rank, run the
/// one-pass pipeline on a random subset of the data (size `subset`) and
/// measure the streamed approximation error; return the smallest
/// candidate within `tolerance` (relative) of the best error seen.
pub fn select_rank_by_subset(
    x: &Mat,
    kernel: Kernel,
    candidates: &[usize],
    oversample: usize,
    subset: usize,
    tolerance: f64,
    rng: &mut Pcg64,
) -> usize {
    assert!(!candidates.is_empty());
    let n = x.cols();
    let take = subset.min(n);
    let idx = sample_without_replacement(rng, n, take);
    let xs = x.select_cols(&idx);

    let mut errs = Vec::with_capacity(candidates.len());
    for &r in candidates {
        let mut src = NativeBlockSource::pow2(xs.clone(), kernel);
        let (ns, np) = (src.n(), src.n_padded());
        let mut srht = Srht::draw(rng, np, (r + oversample).min(ns));
        srht.mask_padding(ns);
        let mut sk = OnePassSketch::new(srht, ns);
        for cols in crate::kernels::column_batches(ns, 128) {
            let kb = src.block(&cols);
            let rows = sk.srht().apply_to_block(&kb, 1);
            sk.ingest(&cols, &rows);
        }
        let emb = one_pass_recovery(&sk, r.min(ns));
        errs.push(streamed_frobenius_error(&mut src, &emb, 128));
    }
    let best = errs.iter().cloned().fold(f64::INFINITY, f64::min);
    for (i, &r) in candidates.iter().enumerate() {
        if errs[i] <= best * (1.0 + tolerance) {
            return r;
        }
    }
    *candidates.last().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;

    #[test]
    fn eigengap_finds_block_structure() {
        // spectrum with a clear drop after 3
        let evals = vec![10.0, 9.0, 8.5, 0.5, 0.4, 0.3];
        assert_eq!(infer_clusters_by_eigengap(&evals, 5), 3);
        // monotone geometric decay: biggest relative gap is the first
        let evals = vec![8.0, 4.0, 2.0, 1.0];
        assert_eq!(infer_clusters_by_eigengap(&evals, 3), 1);
    }

    #[test]
    fn probe_recovers_cluster_count_on_blobs() {
        // well-separated blobs with a linear kernel: top-k eigenvalues
        // dominate, gap at k
        let mut rng = Pcg64::seed(1);
        for k_true in [2usize, 3] {
            let ds = data::gaussian_blobs(&mut rng, 120, 4, k_true, 0.3);
            let mut prng = Pcg64::seed(7);
            let evals = probe_spectrum(&ds.x, Kernel::Linear, 10, 32, &mut prng);
            let k_hat = infer_clusters_by_eigengap(&evals, 6);
            assert_eq!(k_hat, k_true, "evals {evals:?}");
        }
    }

    #[test]
    fn rank_selection_picks_the_spectral_rank() {
        // quadratic kernel on R² data: true rank 3 — candidates beyond 3
        // bring no error improvement, so the CV picks 3
        let mut rng = Pcg64::seed(2);
        let ds = data::cross_lines(&mut rng, 300);
        let mut srng = Pcg64::seed(3);
        let picked = select_rank_by_subset(
            &ds.x,
            Kernel::paper_poly2(),
            &[1, 2, 3, 4, 6],
            8,
            150,
            0.05,
            &mut srng,
        );
        assert_eq!(picked, 3, "quadratic kernel on R² has rank 3");
    }

    #[test]
    fn eigengap_rejects_degenerate_input() {
        let r = std::panic::catch_unwind(|| infer_clusters_by_eigengap(&[1.0], 3));
        assert!(r.is_err());
    }
}
