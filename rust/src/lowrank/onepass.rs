//! The paper's contribution: one-pass SRHT-preconditioned randomized
//! eigendecomposition (Alg. 1 steps 1–6).
//!
//! Streaming phase (owned by the coordinator): for each column block
//! `K[:, J]` — computed on the fly, never stored — apply `D`, FWHT, and
//! keep the `r'` sampled rows, accumulating `W = (Rᵀ H D K)ᵀ ∈ R^{n×r'}`.
//! [`OnePassSketch`] is that accumulator.
//!
//! Recovery phase (this file): `Q = orth(W)[:, :r]`, solve
//! `B (QᵀΩ) = QᵀW` by least squares without revisiting `K`, symmetrize,
//! eigendecompose `B = VΣVᵀ`, clamp negative eigenvalues (PSD projection,
//! required by Theorem 1), and return `Y = Σ^{1/2} Vᵀ Qᵀ` restricted to
//! the unpadded columns.

use crate::linalg::{gemm, gemm_tn, jacobi_eig, Mat};
use crate::sketch::{qt_omega_via_fwht, Srht};

use super::Embedding;

/// Accumulator for the streaming sketch pass.
pub struct OnePassSketch {
    srht: Srht,
    /// W = (Rᵀ H D K)ᵀ, built n_padded rows at a time… rows arrive per
    /// *column* of K: row j of W is filled when column j streams past.
    w: Mat,
    filled: Vec<bool>,
}

impl OnePassSketch {
    pub fn new(srht: Srht, n_real: usize) -> Self {
        assert!(n_real <= srht.n, "more real samples than transform length");
        let rp = srht.samples();
        OnePassSketch { w: Mat::zeros(n_real, rp), srht, filled: vec![false; n_real] }
    }

    /// Wrap an already-complete sketch matrix `w` (n_real × r') — the
    /// streaming refresh path holds W in exactly this layout and would
    /// otherwise pay a second full copy (plus a filled-flag pass) just
    /// to route it through [`ingest`](Self::ingest) column by column.
    pub fn from_rows(srht: Srht, w: Mat) -> Self {
        assert!(w.rows() <= srht.n, "more real samples than transform length");
        assert_eq!(w.cols(), srht.samples(), "sketch width must match the operator");
        let filled = vec![true; w.rows()];
        OnePassSketch { w, srht, filled }
    }

    pub fn srht(&self) -> &Srht {
        &self.srht
    }

    /// Ingest the preconditioned rows for columns `cols`: `rows[b, :]` is
    /// the r' sampled entries of `(H D K)[:, cols[b]]` — i.e. W[cols[b], :].
    /// `rows` is (b × r'), as produced by `Srht::apply_to_block` or by the
    /// XLA precond artifact + row gather.
    pub fn ingest(&mut self, cols: &[usize], rows: &Mat) {
        assert_eq!(rows.rows(), cols.len());
        assert_eq!(rows.cols(), self.srht.samples());
        for (b, &j) in cols.iter().enumerate() {
            assert!(!self.filled[j], "column {j} streamed twice");
            self.filled[j] = true;
            self.w.row_mut(j).copy_from_slice(rows.row(b));
        }
    }

    pub fn is_complete(&self) -> bool {
        self.filled.iter().all(|&f| f)
    }

    /// The sketch matrix W (n_real × r'). Padded kernel columns are all
    /// zero, so their W rows are zero and are simply never streamed.
    pub fn w(&self) -> &Mat {
        &self.w
    }

    /// Peak extra memory of the streaming phase in bytes: W plus the
    /// Rademacher signs (the per-block buffers are accounted by the
    /// coordinator since batch size is its policy choice).
    pub fn sketch_bytes(&self) -> usize {
        std::mem::size_of::<f64>() * (self.w.rows() * self.w.cols() + self.srht.d.len())
    }
}

/// Alg. 1 steps 3–6. `rank` = r; the sketch was drawn with r' = r + l.
///
/// The solve uses the *padded* Ω restricted to the real rows: K's padded
/// rows/columns are identically zero, so W's padded rows are zero and the
/// identity `W = K Ω` restricted to real rows needs Ω's real rows only.
pub fn one_pass_recovery(sketch: &OnePassSketch, rank: usize) -> Embedding {
    one_pass_recovery_threaded(sketch, rank, 1)
}

/// [`one_pass_recovery`] with the dense products (GEMM) and the
/// per-column FWHTs of `QᵀΩ` fanned out over `threads` workers.
/// Bit-identical for any thread count: GEMM threads only partition
/// output rows and the FWHT transforms columns independently.
pub fn one_pass_recovery_threaded(
    sketch: &OnePassSketch,
    rank: usize,
    threads: usize,
) -> Embedding {
    assert!(sketch.is_complete(), "recovery before the stream finished");
    // `QᵀΩ` over the real rows via the FWHT identity: Q's missing padded
    // rows are implicit zeros (see the module docs — K's padded
    // rows/columns are identically zero, so W's padded rows are too)
    recover(sketch.w(), rank, threads, |q, t| qt_omega_via_fwht(sketch.srht(), q, t))
}

/// The pre-overhaul recovery algorithm, kept verbatim as the before-row
/// oracle for `bench_recovery`/`bench_pipeline` and the agreement tests
/// — never on a hot path. What it reproduces of the old code: the
/// entrywise `QᵀΩ` (O(n·r·r'), a popcount per scalar) and the
/// column-strided triple loop assembling `Y = Σ^½VᵀQᵀ`; the remaining
/// `Q·Uq`/`QᵀW` products go through the `Mat` wrappers, whose ascending-k
/// loop order matches the pre-overhaul `matmul`/`t_matmul` like for like.
pub fn one_pass_recovery_entrywise_reference(sketch: &OnePassSketch, rank: usize) -> Embedding {
    assert!(sketch.is_complete(), "recovery before the stream finished");
    let srht = sketch.srht();
    let w = sketch.w();
    let n = w.rows();
    let rp = w.cols();
    assert!(rank <= rp, "rank {rank} exceeds sketch width {rp}");

    let (qfull, rmat) = crate::linalg::householder_qr(w);
    let rrt = rmat.matmul_t(&rmat);
    let (sv2, u) = jacobi_eig(&rrt);
    let smax2 = sv2[0].max(0.0);
    let numerical_rank = sv2.iter().filter(|&&s2| s2 > 1e-14 * smax2).count();
    let qdim = numerical_rank.clamp(rank.min(rp), rp);
    let uq = Mat::from_fn(rp, qdim, |i, j| u[(i, j)]);
    let q = qfull.matmul(&uq);

    // the old entrywise QᵀΩ over the real rows
    let mut qt_omega = Mat::zeros(qdim, rp);
    for i in 0..n {
        for j in 0..rp {
            let w_ij = srht.omega_entry(i, j);
            for k in 0..qdim {
                qt_omega[(k, j)] += w_ij * q[(i, k)];
            }
        }
    }
    let qt_w = q.t_matmul(w);
    let bt = crate::linalg::least_squares(&qt_omega.transpose(), &qt_w.transpose());
    let mut b = bt.transpose();
    b.symmetrize();
    let (evals, v) = jacobi_eig(&b);

    // the old column-strided Y assembly
    let mut clamped: Vec<f64> =
        evals.iter().take(rank.min(qdim)).map(|&l| l.max(0.0)).collect();
    clamped.resize(rank, 0.0);
    let mut y = Mat::zeros(rank, n);
    for i in 0..rank.min(qdim) {
        let s = clamped[i].sqrt();
        for j in 0..n {
            let mut acc = 0.0;
            for k in 0..qdim {
                acc += v[(k, i)] * q[(j, k)];
            }
            y[(i, j)] = s * acc;
        }
    }
    Embedding { y, eigenvalues: clamped }
}

/// One-pass recovery for a dense Gaussian test matrix: identical math to
/// [`one_pass_recovery`] with an explicit `Ω` (restricted to the real
/// rows — padded kernel rows are zero, so the identity `W = KΩ` over real
/// rows only needs Ω's real rows). `w` is the accumulated sketch
/// `K Ω` (n × r'); `omega_real` is n × r'.
pub fn gaussian_one_pass_recovery(w: &Mat, omega_real: &Mat, rank: usize) -> Embedding {
    gaussian_one_pass_recovery_threaded(w, omega_real, rank, 1)
}

/// [`gaussian_one_pass_recovery`] with the dense products threaded
/// (bit-identical for any thread count, like the SRHT variant).
pub fn gaussian_one_pass_recovery_threaded(
    w: &Mat,
    omega_real: &Mat,
    rank: usize,
    threads: usize,
) -> Embedding {
    assert_eq!(w.rows(), omega_real.rows(), "sketch/test-matrix row mismatch");
    assert_eq!(w.cols(), omega_real.cols(), "sketch/test-matrix width mismatch");
    recover(w, rank, threads, |q, t| gemm_tn(q, omega_real, t))
}

/// Shared recovery core (Alg. 1 steps 3–6) over any test matrix: the
/// caller supplies `QᵀΩ` (how Ω is represented — implicit SRHT or dense
/// Gaussian — is the only difference between the variants).
fn recover(
    w: &Mat,
    rank: usize,
    threads: usize,
    qt_omega_of: impl FnOnce(&Mat, usize) -> Mat,
) -> Embedding {
    let threads = threads.max(1);
    let n = w.rows();
    let rp = w.cols();
    assert!(rank <= rp, "rank {rank} exceeds sketch width {rp}");

    // Step 3: orthonormal basis of range(W), truncated to the NUMERICAL
    // rank q of W (but never below the requested rank). Keeping all
    // numerically-significant directions through the solve and
    // truncating to r only after the eigendecomposition (Halko et al.
    // Alg. 5.6) is what makes the oversampling l pay off; dropping the
    // below-noise directions is what keeps the solve well-conditioned
    // when K itself has rank < r' (their singular values are O(eps) and
    // the corresponding rows of B are pure noise amplification).
    let (qfull, rmat) = crate::linalg::householder_qr(w); // n × r', r' × r'
    let rrt = rmat.matmul_t(&rmat); // r' × r' = singular values² of W
    let (sv2, u) = jacobi_eig(&rrt); // descending
    let smax2 = sv2[0].max(0.0);
    let numerical_rank = sv2.iter().filter(|&&s2| s2 > 1e-14 * smax2).count();
    let qdim = numerical_rank.clamp(rank.min(rp), rp);
    let uq = Mat::from_fn(rp, qdim, |i, j| u[(i, j)]);
    let q = gemm(&qfull, &uq, threads); // n × q leading left singular vectors of W

    // Step 4: solve B (QᵀΩ) = QᵀW without revisiting K, as the
    // least-squares problem (QᵀΩ)ᵀ Bᵀ = (QᵀW)ᵀ over the r' × q tall
    // (well-conditioned) transposed system.
    let qt_omega = qt_omega_of(&q, threads); // q × r'
    let qt_w = gemm_tn(&q, w, threads); // q × r'
    let bt = crate::linalg::least_squares(&qt_omega.transpose(), &qt_w.transpose());
    let mut b = bt.transpose(); // q × q

    // Step 5: symmetric eigendecomposition of the core; keep the top r.
    b.symmetrize();
    let (evals, v) = jacobi_eig(&b); // descending, q pairs

    // Step 6: Y = Σ_r^{1/2} V_rᵀ Qᵀ with negative eigenvalues clamped to
    // 0 — the PSD projection that makes K̂ = YᵀY positive semidefinite.
    // If q < rank the missing directions carry zero eigenvalues.
    // (V_rᵀ Qᵀ)ᵀ = Q·V_r is one n × r_used GEMM; the old triple loop
    // walked Q column-strided per output entry.
    let mut clamped: Vec<f64> =
        evals.iter().take(rank.min(qdim)).map(|&l| l.max(0.0)).collect();
    clamped.resize(rank, 0.0);
    let r_used = rank.min(qdim);
    let v_used = Mat::from_fn(qdim, r_used, |i, j| v[(i, j)]);
    let qv = gemm(&q, &v_used, threads); // n × r_used
    let mut y = Mat::zeros(rank, n);
    for i in 0..r_used {
        let s = clamped[i].sqrt();
        for (j, out) in y.row_mut(i).iter_mut().enumerate() {
            *out = s * qv[(j, i)];
        }
    }
    Embedding { y, eigenvalues: clamped }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{column_batches, full_kernel_matrix, Kernel, NativeBlockSource, BlockSource};
    use crate::linalg::testutil::{assert_mat_close, random_mat};
    use crate::rng::Pcg64;
    use crate::sketch::Srht;

    /// run the full streaming pipeline natively on a small problem
    fn run_onepass(
        x: &Mat,
        kernel: Kernel,
        rank: usize,
        oversample: usize,
        seed: u64,
        batch: usize,
    ) -> Embedding {
        let mut src = NativeBlockSource::pow2(x.clone(), kernel);
        let n = src.n();
        let np = src.n_padded();
        let mut rng = Pcg64::seed(seed);
        let srht = Srht::draw(&mut rng, np, rank + oversample);
        let mut sk = OnePassSketch::new(srht, n);
        for cols in column_batches(n, batch) {
            let kb = src.block(&cols);
            let rows = sk.srht().apply_to_block(&kb, 1);
            sk.ingest(&cols, &rows);
        }
        assert!(sk.is_complete());
        one_pass_recovery(&sk, rank)
    }

    #[test]
    fn recovers_low_rank_kernel_nearly_exactly() {
        // data in R², homogeneous quadratic kernel ⇒ K has rank ≤ 3
        let mut rng = Pcg64::seed(1);
        let x = random_mat(&mut rng, 2, 60);
        let k = full_kernel_matrix(&x, Kernel::paper_poly2());
        let emb = run_onepass(&x, Kernel::paper_poly2(), 3, 10, 7, 16);
        let khat = emb.y.t_matmul(&emb.y);
        let rel = k.sub(&khat).frobenius_norm() / k.frobenius_norm();
        assert!(rel < 1e-6, "relative error {rel}");
    }

    #[test]
    fn rank2_matches_best_rank2_error_closely() {
        let mut rng = Pcg64::seed(2);
        let x = random_mat(&mut rng, 2, 80);
        let k = full_kernel_matrix(&x, Kernel::paper_poly2());
        let (evals, _) = crate::linalg::jacobi_eig(&k);
        let best2: f64 = evals[2..].iter().map(|l| l * l).sum::<f64>().sqrt();
        let emb = run_onepass(&x, Kernel::paper_poly2(), 2, 10, 3, 32);
        let khat = emb.y.t_matmul(&emb.y);
        let got = k.sub(&khat).frobenius_norm();
        // randomized bound: within a modest factor of optimal
        assert!(got < 3.0 * best2 + 1e-9 * k.frobenius_norm(), "{got} vs best {best2}");
    }

    #[test]
    fn embedding_is_psd_and_padding_free() {
        let mut rng = Pcg64::seed(3);
        let x = random_mat(&mut rng, 3, 50); // pads 50 → 64
        let emb = run_onepass(&x, Kernel::Rbf { gamma: 0.5 }, 4, 6, 11, 13);
        assert_eq!(emb.n(), 50);
        assert_eq!(emb.rank(), 4);
        assert!(emb.eigenvalues.iter().all(|&l| l >= 0.0));
        for w in emb.eigenvalues.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    fn threaded_recovery_is_bit_identical() {
        let mut rng = Pcg64::seed(8);
        let x = random_mat(&mut rng, 2, 70);
        let mut src = NativeBlockSource::pow2(x, Kernel::paper_poly2());
        let (n, np) = (src.n(), src.n_padded());
        let mut srht = Srht::draw(&mut Pcg64::seed(21), np, 8);
        srht.mask_padding(n);
        let mut sk = OnePassSketch::new(srht, n);
        for cols in column_batches(n, 16) {
            let kb = src.block(&cols);
            let rows = sk.srht().apply_to_block(&kb, 1);
            sk.ingest(&cols, &rows);
        }
        let base = one_pass_recovery_threaded(&sk, 3, 1);
        for threads in [2usize, 4] {
            let par = one_pass_recovery_threaded(&sk, 3, threads);
            assert_eq!(base.y.data(), par.y.data(), "threads={threads}");
            assert_eq!(base.eigenvalues, par.eigenvalues, "threads={threads}");
        }
    }

    #[test]
    fn entrywise_reference_recovery_agrees_with_fwht_path() {
        // the two QᵀΩ paths differ only by summation-order rounding, so
        // the recovered kernels must agree far below the sketch error
        let mut rng = Pcg64::seed(9);
        let x = random_mat(&mut rng, 2, 60);
        let mut src = NativeBlockSource::pow2(x, Kernel::paper_poly2());
        let (n, np) = (src.n(), src.n_padded());
        let mut srht = Srht::draw(&mut Pcg64::seed(33), np, 9);
        srht.mask_padding(n);
        let mut sk = OnePassSketch::new(srht, n);
        for cols in column_batches(n, 16) {
            let kb = src.block(&cols);
            let rows = sk.srht().apply_to_block(&kb, 1);
            sk.ingest(&cols, &rows);
        }
        let fwht = one_pass_recovery(&sk, 3);
        let entry = one_pass_recovery_entrywise_reference(&sk, 3);
        let ka = fwht.y.t_matmul(&fwht.y);
        let kb = entry.y.t_matmul(&entry.y);
        let rel = ka.sub(&kb).frobenius_norm() / ka.frobenius_norm().max(1e-300);
        assert!(rel < 1e-8, "paths diverged: {rel}");
    }

    #[test]
    fn batch_size_does_not_change_result() {
        let mut rng = Pcg64::seed(4);
        let x = random_mat(&mut rng, 2, 40);
        let a = run_onepass(&x, Kernel::paper_poly2(), 2, 5, 99, 1);
        let b = run_onepass(&x, Kernel::paper_poly2(), 2, 5, 99, 40);
        assert_mat_close(&a.y, &b.y, 1e-8);
    }

    #[test]
    #[should_panic(expected = "streamed twice")]
    fn double_ingest_detected() {
        let mut rng = Pcg64::seed(5);
        let srht = Srht::draw(&mut rng, 16, 4);
        let mut sk = OnePassSketch::new(srht, 10);
        let rows = Mat::zeros(2, 4);
        sk.ingest(&[0, 1], &rows);
        sk.ingest(&[1, 2], &rows);
    }

    #[test]
    #[should_panic(expected = "before the stream finished")]
    fn recovery_requires_complete_stream() {
        let mut rng = Pcg64::seed(6);
        let srht = Srht::draw(&mut rng, 16, 4);
        let sk = OnePassSketch::new(srht, 10);
        let _ = one_pass_recovery(&sk, 2);
    }

    #[test]
    fn reconstruct_block_matches_full_reconstruction() {
        let mut rng = Pcg64::seed(7);
        let x = random_mat(&mut rng, 2, 30);
        let emb = run_onepass(&x, Kernel::paper_poly2(), 2, 8, 1, 10);
        let khat = emb.y.t_matmul(&emb.y);
        let blk = emb.reconstruct_block(&[3, 17, 29]);
        for (bj, &j) in [3usize, 17, 29].iter().enumerate() {
            for i in 0..30 {
                assert!((blk[(i, bj)] - khat[(i, j)]).abs() < 1e-12);
            }
        }
    }
}
