//! Nyström low-rank approximation (the paper's main baseline).
//!
//! Sample m columns `C = K[:, J]`, form the inner matrix
//! `W_m = K[J, J]`, and approximate `K ≈ C W_m⁺ Cᵀ`. The rank-r
//! embedding uses the top-r eigenpairs of `W_m`:
//! `Y = Λ_r^{-1/2} U_rᵀ Cᵀ` (Williams & Seeger 2001). One pass, uniform
//! sampling without replacement — exactly the variant the paper compares
//! against (§4); column-norm sampling (Drineas & Mahoney 2005, ≥2 passes)
//! is included as an ablation.

use crate::kernels::BlockSource;
use crate::linalg::{jacobi_eig, Mat};
use crate::rng::{sample_without_replacement, Pcg64, Rng};

use super::Embedding;

/// Column-sampling strategy for Nyström.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NystromSampling {
    /// uniform without replacement — one pass (Williams & Seeger 2001)
    Uniform,
    /// probability ∝ K_ii² … for kernels with constant diagonal this
    /// reduces to uniform; for general kernels it needs the diagonal
    /// (one extra cheap pass) — included as the multi-pass ablation
    ColumnNorm,
}

/// Nyström rank-r embedding from `m` sampled columns (single-threaded;
/// see [`nystrom_threaded`] for the fork-join variant).
///
/// A relative floor guards tiny/negative eigenvalues of the inner matrix
/// (it is PSD in exact arithmetic but m ≈ 100 with a quadratic kernel is
/// numerically delicate).
pub fn nystrom(
    src: &mut dyn BlockSource,
    m: usize,
    rank: usize,
    sampling: NystromSampling,
    rng: &mut Pcg64,
) -> Embedding {
    nystrom_threaded(src, m, rank, sampling, rng, 1)
}

/// [`nystrom`] with the O(n·m·r) embedding projection
/// `Y = Λ_r^{-1/2} U_rᵀ Cᵀ` chunked over samples across `threads`
/// workers (`C` itself parallelizes inside the block source). All RNG
/// draws happen on the calling thread and every entry keeps its
/// sequential accumulation order, so the result is bit-identical for
/// any thread count.
pub fn nystrom_threaded(
    src: &mut dyn BlockSource,
    m: usize,
    rank: usize,
    sampling: NystromSampling,
    rng: &mut Pcg64,
    threads: usize,
) -> Embedding {
    let n = src.n();
    assert!(m <= n, "cannot sample {m} of {n} columns");
    assert!(rank <= m, "rank {rank} exceeds sample count {m}");

    let picked: Vec<usize> = match sampling {
        NystromSampling::Uniform => sample_without_replacement(rng, n, m),
        NystromSampling::ColumnNorm => {
            // weighted without replacement via sequential draws
            let diag = src.diag();
            let mut weights: Vec<f64> = diag.iter().map(|d| d * d).collect();
            let mut idx = Vec::with_capacity(m);
            for _ in 0..m {
                let total: f64 = weights.iter().sum();
                let mut target = rng.next_f64() * total.max(1e-300);
                let mut chosen = weights.len() - 1;
                for (j, &w) in weights.iter().enumerate() {
                    target -= w;
                    if target <= 0.0 && w > 0.0 {
                        chosen = j;
                        break;
                    }
                }
                idx.push(chosen);
                weights[chosen] = 0.0;
            }
            idx
        }
    };

    // C = K[:, J] (one streamed block of m columns), W_m = C[J, :].
    let c = src.block(&picked); // n_padded × m
    let c_real = Mat::from_fn(n, m, |i, j| c[(i, j)]);
    let w_m = c_real.select_rows(&picked); // m × m

    // top-r eigenpairs of the inner matrix
    let (evals, u) = jacobi_eig(&w_m);
    let lmax = evals.first().copied().unwrap_or(0.0).max(0.0);
    let floor = 1e-12 * lmax.max(1e-300);

    // per-direction scales; numerically-absent directions stay zero
    let mut scale = vec![0.0f64; rank];
    let mut eigenvalues = vec![0.0; rank];
    for i in 0..rank {
        let l = evals[i];
        if l <= floor {
            continue;
        }
        // Nyström eigenvalue estimate for K is (n/m) λ_i; the embedding
        // scale that reproduces K̂ = C W⁺ C is λ^{-1/2} regardless.
        eigenvalues[i] = l * (n as f64) / (m as f64);
        scale[i] = 1.0 / l.sqrt();
    }

    // Y = Λ_r^{-1/2} U_rᵀ Cᵀ (r × n): one n × r GEMM `C·U_r` through the
    // shared micro-kernel (per-entry accumulation stays in ascending-t
    // order for any thread count, so the result is bit-identical to the
    // sequential run), then a scale-and-transpose pass. Numerically
    // absent directions (scale 0) keep exactly-zero rows.
    let workers = crate::util::parallel::resolve_threads(threads).max(1);
    let ur = Mat::from_fn(m, rank, |t, i| u[(t, i)]);
    let cu = crate::linalg::gemm(&c_real, &ur, workers); // n × rank
    let mut y = Mat::zeros(rank, n);
    for i in 0..rank {
        if scale[i] == 0.0 {
            continue; // direction numerically absent: row stays zero
        }
        let s = scale[i];
        for (j, out) in y.row_mut(i).iter_mut().enumerate() {
            *out = s * cu[(j, i)];
        }
    }
    Embedding { y, eigenvalues }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{full_kernel_matrix, Kernel, NativeBlockSource};
    use crate::linalg::testutil::random_mat;
    use crate::rng::Pcg64;

    #[test]
    fn exact_when_all_columns_sampled_low_rank() {
        // rank(K) = 3 (R² quadratic kernel); m = n makes Nyström exact
        let mut rng = Pcg64::seed(1);
        let x = random_mat(&mut rng, 2, 24);
        let k = full_kernel_matrix(&x, Kernel::paper_poly2());
        let mut src = NativeBlockSource::pow2(x, Kernel::paper_poly2());
        let emb = nystrom(&mut src, 24, 3, NystromSampling::Uniform, &mut rng);
        let khat = emb.y.t_matmul(&emb.y);
        let rel = k.sub(&khat).frobenius_norm() / k.frobenius_norm();
        assert!(rel < 1e-7, "relative error {rel}");
    }

    #[test]
    fn error_decreases_with_more_columns() {
        let mut rng = Pcg64::seed(2);
        let x = random_mat(&mut rng, 5, 120);
        let k = full_kernel_matrix(&x, Kernel::Rbf { gamma: 0.4 });
        let mut errs = Vec::new();
        for m in [6, 24, 96] {
            // average over draws to damp sampling noise
            let mut acc = 0.0;
            for t in 0..5 {
                let mut src = NativeBlockSource::pow2(x.clone(), Kernel::Rbf { gamma: 0.4 });
                let mut r = Pcg64::seed(100 + t);
                let emb = nystrom(&mut src, m, 4, NystromSampling::Uniform, &mut r);
                let khat = emb.y.t_matmul(&emb.y);
                acc += k.sub(&khat).frobenius_norm() / k.frobenius_norm();
            }
            errs.push(acc / 5.0);
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }

    #[test]
    fn embedding_shape_and_determinism() {
        let mut rng_data = Pcg64::seed(3);
        let x = random_mat(&mut rng_data, 3, 40);
        let run = |seed: u64| {
            let mut src = NativeBlockSource::pow2(x.clone(), Kernel::paper_poly2());
            let mut rng = Pcg64::seed(seed);
            nystrom(&mut src, 10, 2, NystromSampling::Uniform, &mut rng)
        };
        let a = run(7);
        let b = run(7);
        assert_eq!(a.y.data(), b.y.data());
        assert_eq!((a.rank(), a.n()), (2, 40));
    }

    #[test]
    fn threaded_matches_sequential_bitwise() {
        let mut rng_data = Pcg64::seed(8);
        let x = random_mat(&mut rng_data, 3, 50);
        let run = |threads: usize| {
            let mut src = NativeBlockSource::pow2(x.clone(), Kernel::paper_poly2());
            let mut rng = Pcg64::seed(21);
            nystrom_threaded(&mut src, 12, 3, NystromSampling::Uniform, &mut rng, threads)
        };
        let a = run(1);
        for threads in [2usize, 4] {
            let b = run(threads);
            assert_eq!(a.y.data(), b.y.data(), "threads={threads}");
            assert_eq!(a.eigenvalues, b.eigenvalues, "threads={threads}");
        }
    }

    #[test]
    fn column_norm_sampling_runs_and_is_sane() {
        let mut rng = Pcg64::seed(4);
        let x = random_mat(&mut rng, 4, 60);
        let k = full_kernel_matrix(&x, Kernel::paper_poly2());
        let mut src = NativeBlockSource::pow2(x, Kernel::paper_poly2());
        let emb = nystrom(&mut src, 30, 3, NystromSampling::ColumnNorm, &mut rng);
        let khat = emb.y.t_matmul(&emb.y);
        let rel = k.sub(&khat).frobenius_norm() / k.frobenius_norm();
        assert!(rel < 0.9, "column-norm Nyström wildly off: {rel}");
    }

    #[test]
    #[should_panic(expected = "exceeds sample count")]
    fn rank_must_not_exceed_m() {
        let mut rng = Pcg64::seed(5);
        let x = random_mat(&mut rng, 2, 20);
        let mut src = NativeBlockSource::pow2(x, Kernel::paper_poly2());
        let _ = nystrom(&mut src, 3, 5, NystromSampling::Uniform, &mut rng);
    }
}
