//! Approximation-error measurement.
//!
//! The paper reports the normalized kernel approximation error
//! `‖K − K̂‖_F / ‖K‖_F` (Table 1, Fig. 3a) and Theorem 1 bounds the
//! clustering suboptimality by `2‖E‖_*` / `tr(E)`. The streamed variant
//! recomputes kernel blocks on the fly and compares them to `YᵀY` block
//! by block, so the measurement itself respects the O(r'n) memory budget.

use crate::kernels::BlockSource;
use crate::linalg::Mat;

use super::Embedding;

/// Dense `‖K − K̂‖_F / ‖K‖_F` with `K̂ = YᵀY` (test scale).
pub fn normalized_frobenius_error(kmat: &Mat, emb: &Embedding) -> f64 {
    let khat = emb.y.t_matmul(&emb.y);
    kmat.sub(&khat).frobenius_norm() / kmat.frobenius_norm().max(1e-300)
}

/// Streamed `‖K − K̂‖_F / ‖K‖_F`: one extra pass over kernel blocks,
/// never holding more than one block.
pub fn streamed_frobenius_error(
    src: &mut dyn BlockSource,
    emb: &Embedding,
    batch: usize,
) -> f64 {
    let n = src.n();
    assert_eq!(emb.n(), n, "embedding size mismatch");
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for cols in crate::kernels::column_batches(n, batch) {
        let kb = src.block(&cols); // n_padded × b
        let khat_b = emb.reconstruct_block(&cols); // n × b
        for (bj, _) in cols.iter().enumerate() {
            for i in 0..n {
                let kij = kb[(i, bj)];
                let d = kij - khat_b[(i, bj)];
                num += d * d;
                den += kij * kij;
            }
        }
    }
    (num / den.max(1e-300)).sqrt()
}

/// Trace-norm of the error `E = K − K̂` for a PSD pair at test scale
/// (Theorem 1's right-hand side). Uses the symmetric eigendecomposition
/// of the dense error matrix.
pub fn trace_norm_error_psd(kmat: &Mat, emb: &Embedding) -> f64 {
    let khat = emb.y.t_matmul(&emb.y);
    let e = kmat.sub(&khat);
    e.trace_norm_symmetric()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{full_kernel_matrix, Kernel, NativeBlockSource};
    use crate::linalg::testutil::random_mat;
    use crate::lowrank::exact_topr_dense;
    use crate::rng::Pcg64;

    #[test]
    fn streamed_equals_dense_error() {
        let mut rng = Pcg64::seed(1);
        let x = random_mat(&mut rng, 3, 40);
        let kern = Kernel::Rbf { gamma: 0.8 };
        let k = full_kernel_matrix(&x, kern);
        let emb = exact_topr_dense(&k, 3);
        let dense = normalized_frobenius_error(&k, &emb);
        let mut src = NativeBlockSource::pow2(x, kern);
        for batch in [1, 7, 40] {
            let streamed = streamed_frobenius_error(&mut src, &emb, batch);
            assert!((dense - streamed).abs() < 1e-10, "batch {batch}: {dense} vs {streamed}");
        }
    }

    #[test]
    fn perfect_embedding_has_zero_error() {
        let mut rng = Pcg64::seed(2);
        let x = random_mat(&mut rng, 2, 25);
        let k = full_kernel_matrix(&x, Kernel::paper_poly2()); // rank 3
        let emb = exact_topr_dense(&k, 3);
        assert!(normalized_frobenius_error(&k, &emb) < 1e-8);
        assert!(trace_norm_error_psd(&k, &emb).abs() < 1e-6 * k.trace());
    }

    #[test]
    fn trace_norm_equals_trace_gap_for_best_rank_r() {
        // for the best rank-r approx of a PSD matrix, E is PSD and
        // ‖E‖_* = tr(E) = Σ_{i>r} λ_i
        let mut rng = Pcg64::seed(3);
        let x = random_mat(&mut rng, 4, 20);
        let k = full_kernel_matrix(&x, Kernel::Rbf { gamma: 0.5 });
        let emb = exact_topr_dense(&k, 4);
        let (evals, _) = crate::linalg::jacobi_eig(&k);
        let tail: f64 = evals[4..].iter().map(|l| l.max(0.0)).sum();
        let tn = trace_norm_error_psd(&k, &emb);
        assert!((tn - tail).abs() < 1e-7 * k.trace().max(1.0), "{tn} vs {tail}");
    }
}
