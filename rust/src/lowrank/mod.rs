//! Low-rank kernel approximation: the paper's one-pass randomized method
//! and every baseline it is evaluated against.
//!
//! All methods produce an [`Embedding`] `Y` (r × n) with `K ≈ YᵀY`, so
//! standard K-means on `Y` approximates kernel K-means on `K`
//! (Theorem 1). Approximation error is measured *streamed* — blocks of
//! `K` are recomputed on the fly and compared to `YᵀY` block by block —
//! so measuring error never violates the O(r'n) memory budget.

mod error;
mod exact;
mod nystrom;
mod onepass;
mod select;

pub use error::{normalized_frobenius_error, streamed_frobenius_error, trace_norm_error_psd};
pub use exact::{exact_topr_dense, exact_topr_streaming, exact_topr_streaming_threaded};
pub use nystrom::{nystrom, nystrom_threaded, NystromSampling};
pub use onepass::{
    gaussian_one_pass_recovery, gaussian_one_pass_recovery_threaded, one_pass_recovery,
    one_pass_recovery_entrywise_reference, one_pass_recovery_threaded, OnePassSketch,
};
pub use select::{infer_clusters_by_eigengap, probe_spectrum, select_rank_by_subset};

use crate::linalg::Mat;

/// A rank-r PSD factorization `K ≈ YᵀY` restricted to the unpadded
/// samples. Columns of `y` are the embedded points fed to K-means.
#[derive(Clone, Debug)]
pub struct Embedding {
    /// r × n embedding (n = real sample count, padding already dropped)
    pub y: Mat,
    /// recovered eigenvalues (descending, clamped at zero), length r
    pub eigenvalues: Vec<f64>,
}

impl Embedding {
    pub fn rank(&self) -> usize {
        self.y.rows()
    }

    pub fn n(&self) -> usize {
        self.y.cols()
    }

    /// Reconstruct a block of the approximate kernel `K̂[:, cols] = Yᵀ Y_J`.
    pub fn reconstruct_block(&self, cols: &[usize]) -> Mat {
        let yj = self.y.select_cols(cols);
        self.y.t_matmul(&yj)
    }
}
