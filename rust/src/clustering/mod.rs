//! Clustering algorithms and evaluation metrics.
//!
//! `kmeans` is the standard Lloyd algorithm with k-means++ seeding and
//! restarts — exactly what the paper runs on the embedded points `Y`
//! (MATLAB `kmeans`, 10 initializations, 20 iterations); `kmeans_threaded`
//! fans the restarts (and, when they run alone, the assignment step)
//! across worker threads with bit-identical results. `kernel_kmeans`
//! is the full-kernel-matrix baseline (Dhillon et al. 2004, Eq. 4 of the
//! paper) used for the "full Kernel K-means = 0.46" reference line in
//! Fig. 3(b). `metrics` provides clustering accuracy (best label
//! permutation via the Hungarian algorithm), NMI and ARI.

mod hungarian;
mod kernel_kmeans;
mod kmeans;
mod metrics;

pub use hungarian::hungarian_min_cost;
pub use kernel_kmeans::{kernel_kmeans, kernel_kmeans_objective, KernelKmeansResult};
pub use kmeans::{
    kmeans, kmeans_once, kmeans_once_threaded, kmeans_reference, kmeans_threaded,
    kmeans_warm_threaded, KmeansOpts, KmeansResult,
};
pub use metrics::{accuracy, adjusted_rand_index, confusion_matrix, normalized_mutual_info};
