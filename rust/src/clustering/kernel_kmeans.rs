//! Full-kernel-matrix Kernel K-means (Dhillon, Guan & Kulis 2004).
//!
//! The O(n²)-memory baseline the paper is escaping from: iterates
//! assignments using Eq. (4),
//!   ||Φ(x_i) − μ_j||² = K_ii − (2/|S_j|) Σ_{l∈S_j} K_il
//!                      + (1/|S_j|²) Σ_{l,l'∈S_j} K_ll',
//! requiring the full kernel matrix each iteration. Used for Fig. 3(b)'s
//! "full Kernel K-means accuracy = 0.46" reference and for Theorem 1
//! validation (exact objective under K vs under K̂).

use crate::linalg::Mat;
use crate::rng::{Pcg64, Rng};

#[derive(Clone, Debug)]
pub struct KernelKmeansResult {
    pub labels: Vec<usize>,
    /// kernel K-means objective L(C) = Σ_i ||Φ(x_i) − μ_{c(i)}||²
    pub objective: f64,
    pub iterations: usize,
}

/// Kernel K-means with `restarts` random-assignment initializations.
/// `kmat` must be symmetric PSD (n × n).
pub fn kernel_kmeans(
    kmat: &Mat,
    k: usize,
    restarts: usize,
    max_iters: usize,
    rng: &mut Pcg64,
) -> KernelKmeansResult {
    assert_eq!(kmat.rows(), kmat.cols(), "kernel matrix must be square");
    let mut best: Option<KernelKmeansResult> = None;
    for t in 0..restarts.max(1) {
        let mut run_rng = rng.split(t as u64 + 101);
        let run = kernel_kmeans_once(kmat, k, max_iters, &mut run_rng);
        if best.as_ref().is_none_or(|b| run.objective < b.objective) {
            best = Some(run);
        }
    }
    best.unwrap()
}

fn kernel_kmeans_once(
    kmat: &Mat,
    k: usize,
    max_iters: usize,
    rng: &mut Pcg64,
) -> KernelKmeansResult {
    let n = kmat.rows();
    assert!(k <= n);
    // random initial assignment with every cluster non-empty
    let mut labels: Vec<usize> = (0..n).map(|_| rng.below(k)).collect();
    for c in 0..k {
        labels[rng.below(n)] = c; // cheap non-emptiness nudge
    }

    let mut iterations = 0;
    for it in 0..max_iters {
        iterations = it + 1;
        // cluster sizes and the intra-cluster kernel sums
        let mut sizes = vec![0usize; k];
        for &l in &labels {
            sizes[l] += 1;
        }
        // self term: (1/|S_j|²) Σ_{l,l'∈S_j} K_ll'
        let mut self_term = vec![0.0f64; k];
        // per-point cross sums: Σ_{l∈S_j} K_il, computed as K @ indicator
        let mut cross = Mat::zeros(n, k);
        for i in 0..n {
            let row = kmat.row(i);
            let crow = cross.row_mut(i);
            for (l, &kil) in row.iter().enumerate() {
                crow[labels[l]] += kil;
            }
        }
        for j in 0..k {
            if sizes[j] == 0 {
                continue;
            }
            let mut s = 0.0;
            for i in 0..n {
                if labels[i] == j {
                    s += cross[(i, j)];
                }
            }
            self_term[j] = s / (sizes[j] * sizes[j]) as f64;
        }
        // reassignment
        let mut changed = 0usize;
        for i in 0..n {
            let mut best_j = labels[i];
            let mut best_d = f64::INFINITY;
            for j in 0..k {
                if sizes[j] == 0 {
                    continue;
                }
                let d = kmat[(i, i)] - 2.0 * cross[(i, j)] / sizes[j] as f64 + self_term[j];
                if d < best_d {
                    best_d = d;
                    best_j = j;
                }
            }
            if best_j != labels[i] {
                changed += 1;
                labels[i] = best_j;
            }
        }
        if changed == 0 {
            break;
        }
    }

    let objective = kernel_kmeans_objective(kmat, &labels, k);
    KernelKmeansResult { labels, objective, iterations }
}

/// Exact kernel K-means objective L(C) (Eq. 6 of the paper):
/// tr(K) − Σ_j (1/|S_j|) Σ_{l,l'∈S_j} K_ll'.
pub fn kernel_kmeans_objective(kmat: &Mat, labels: &[usize], k: usize) -> f64 {
    let n = kmat.rows();
    assert_eq!(labels.len(), n);
    let mut sizes = vec![0usize; k];
    for &l in labels {
        sizes[l] += 1;
    }
    let mut intra = vec![0.0f64; k];
    for i in 0..n {
        let row = kmat.row(i);
        for l in 0..n {
            if labels[l] == labels[i] {
                intra[labels[i]] += row[l];
            }
        }
    }
    let mut obj = kmat.trace();
    for j in 0..k {
        if sizes[j] > 0 {
            obj -= intra[j] / sizes[j] as f64;
        }
    }
    obj
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::accuracy;

    /// Kernel matrix of two tight blobs under a linear kernel.
    fn two_blob_kernel(per: usize, rng: &mut Pcg64) -> (Mat, Vec<usize>) {
        let n = 2 * per;
        let mut x = Mat::zeros(2, n);
        let mut truth = vec![0usize; n];
        for j in 0..n {
            let c = j / per;
            truth[j] = c;
            let (cx, cy) = if c == 0 { (0.0, 0.0) } else { (8.0, 8.0) };
            x[(0, j)] = cx + 0.3 * rng.normal();
            x[(1, j)] = cy + 0.3 * rng.normal();
        }
        let k = x.t_matmul(&x);
        (k, truth)
    }

    #[test]
    fn clusters_two_blobs_linear_kernel() {
        let mut rng = Pcg64::seed(1);
        let (k, truth) = two_blob_kernel(40, &mut rng);
        let res = kernel_kmeans(&k, 2, 5, 30, &mut rng);
        let acc = accuracy(&res.labels, &truth, 2);
        assert!(acc > 0.97, "accuracy {acc}");
    }

    #[test]
    fn objective_matches_euclidean_kmeans_for_linear_kernel() {
        // with K = XᵀX, kernel K-means objective equals the Euclidean
        // K-means objective of the same partition
        let mut rng = Pcg64::seed(2);
        let per = 20;
        let (k, truth) = two_blob_kernel(per, &mut rng);
        let obj = kernel_kmeans_objective(&k, &truth, 2);
        assert!(obj >= 0.0);
        // reconstruct points from the PSD kernel via eig to cross-check
        let (evals, v) = crate::linalg::jacobi_eig(&k);
        let r = evals.iter().filter(|&&l| l > 1e-9).count();
        let n = k.rows();
        let mut y = Mat::zeros(r, n);
        for i in 0..r {
            for j in 0..n {
                y[(i, j)] = evals[i].max(0.0).sqrt() * v[(j, i)];
            }
        }
        // Euclidean objective of partition `truth` on y
        let mut obj2 = 0.0;
        for c in 0..2 {
            let idx: Vec<usize> = (0..n).filter(|&j| truth[j] == c).collect();
            let mut mu = vec![0.0; r];
            for &j in &idx {
                for i in 0..r {
                    mu[i] += y[(i, j)];
                }
            }
            for m in &mut mu {
                *m /= idx.len() as f64;
            }
            for &j in &idx {
                for i in 0..r {
                    let d = y[(i, j)] - mu[i];
                    obj2 += d * d;
                }
            }
        }
        assert!((obj - obj2).abs() < 1e-6 * obj.max(1.0), "{obj} vs {obj2}");
    }

    #[test]
    fn converges_and_reports_iterations() {
        let mut rng = Pcg64::seed(3);
        let (k, _) = two_blob_kernel(15, &mut rng);
        let res = kernel_kmeans(&k, 2, 3, 50, &mut rng);
        assert!(res.iterations <= 50);
        assert!(res.objective.is_finite());
    }

    #[test]
    fn single_cluster_objective_is_total_scatter() {
        let mut rng = Pcg64::seed(4);
        let (k, _) = two_blob_kernel(10, &mut rng);
        let n = k.rows();
        let labels = vec![0usize; n];
        let obj = kernel_kmeans_objective(&k, &labels, 1);
        let total: f64 = k.data().iter().sum();
        let want = k.trace() - total / n as f64;
        assert!((obj - want).abs() < 1e-9 * want.max(1.0));
    }
}
