//! Clustering evaluation: accuracy (Hungarian-matched), NMI, ARI.

use super::hungarian_min_cost;

/// K × K confusion matrix: `m[p][t]` counts points with predicted label
/// `p` and true label `t`.
pub fn confusion_matrix(pred: &[usize], truth: &[usize], k: usize) -> Vec<Vec<usize>> {
    assert_eq!(pred.len(), truth.len());
    let mut m = vec![vec![0usize; k]; k];
    for (&p, &t) in pred.iter().zip(truth) {
        assert!(p < k && t < k, "label out of range");
        m[p][t] += 1;
    }
    m
}

/// Clustering accuracy: fraction of points correctly labelled under the
/// best one-to-one mapping between predicted and true labels (the
/// standard metric in the kernel clustering literature, incl. the paper).
pub fn accuracy(pred: &[usize], truth: &[usize], k: usize) -> f64 {
    if pred.is_empty() {
        return 0.0;
    }
    let conf = confusion_matrix(pred, truth, k);
    // maximize matches == minimize (max - count)
    let maxc = conf.iter().flatten().copied().max().unwrap_or(0) as f64;
    let cost: Vec<Vec<f64>> =
        conf.iter().map(|row| row.iter().map(|&c| maxc - c as f64).collect()).collect();
    let asg = hungarian_min_cost(&cost);
    let matched: usize = asg.iter().enumerate().map(|(p, &t)| conf[p][t]).sum();
    matched as f64 / pred.len() as f64
}

/// Normalized mutual information (arithmetic-mean normalization).
pub fn normalized_mutual_info(pred: &[usize], truth: &[usize], k: usize) -> f64 {
    let n = pred.len();
    if n == 0 {
        return 0.0;
    }
    let conf = confusion_matrix(pred, truth, k);
    let nf = n as f64;
    let rowsum: Vec<f64> = conf.iter().map(|r| r.iter().sum::<usize>() as f64).collect();
    let colsum: Vec<f64> =
        (0..k).map(|j| conf.iter().map(|r| r[j]).sum::<usize>() as f64).collect();
    let mut mi = 0.0;
    for i in 0..k {
        for j in 0..k {
            let nij = conf[i][j] as f64;
            if nij > 0.0 {
                mi += (nij / nf) * ((nf * nij) / (rowsum[i] * colsum[j])).ln();
            }
        }
    }
    let h = |sums: &[f64]| -> f64 {
        sums.iter()
            .filter(|&&s| s > 0.0)
            .map(|&s| {
                let p = s / nf;
                -p * p.ln()
            })
            .sum()
    };
    let hp = h(&rowsum);
    let ht = h(&colsum);
    if hp + ht == 0.0 {
        1.0 // both partitions trivial — identical
    } else {
        2.0 * mi / (hp + ht)
    }
}

/// Adjusted Rand index (Hubert & Arabie 1985).
pub fn adjusted_rand_index(pred: &[usize], truth: &[usize], k: usize) -> f64 {
    let n = pred.len();
    if n < 2 {
        return 1.0;
    }
    let conf = confusion_matrix(pred, truth, k);
    let choose2 = |x: usize| -> f64 { (x as f64) * (x as f64 - 1.0) / 2.0 };
    let sum_ij: f64 = conf.iter().flatten().map(|&c| choose2(c)).sum();
    let rowsum: Vec<usize> = conf.iter().map(|r| r.iter().sum()).collect();
    let colsum: Vec<usize> = (0..k).map(|j| conf.iter().map(|r| r[j]).sum()).collect();
    let sum_a: f64 = rowsum.iter().map(|&a| choose2(a)).sum();
    let sum_b: f64 = colsum.iter().map(|&b| choose2(b)).sum();
    let total = choose2(n);
    let expected = sum_a * sum_b / total;
    let max_index = 0.5 * (sum_a + sum_b);
    if (max_index - expected).abs() < 1e-300 {
        return 1.0;
    }
    (sum_ij - expected) / (max_index - expected)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_prediction_scores_one() {
        let truth = vec![0, 0, 1, 1, 2, 2];
        assert_eq!(accuracy(&truth, &truth, 3), 1.0);
        assert!((normalized_mutual_info(&truth, &truth, 3) - 1.0).abs() < 1e-12);
        assert!((adjusted_rand_index(&truth, &truth, 3) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn accuracy_is_permutation_invariant() {
        let truth = vec![0, 0, 1, 1, 2, 2];
        let pred = vec![2, 2, 0, 0, 1, 1]; // relabeled but identical partition
        assert_eq!(accuracy(&pred, &truth, 3), 1.0);
        assert!((adjusted_rand_index(&pred, &truth, 3) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn one_mistake() {
        let truth = vec![0, 0, 0, 1, 1, 1];
        let pred = vec![1, 1, 1, 0, 0, 1]; // one point of class 1 mislabeled
        assert!((accuracy(&pred, &truth, 2) - 5.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn random_labels_score_near_half_for_two_balanced_classes() {
        use crate::rng::{Pcg64, Rng};
        let mut rng = Pcg64::seed(1);
        let n = 10_000;
        let truth: Vec<usize> = (0..n).map(|i| i % 2).collect();
        let pred: Vec<usize> = (0..n).map(|_| rng.below(2)).collect();
        let acc = accuracy(&pred, &truth, 2);
        assert!(acc >= 0.5 - 1e-12 && acc < 0.54, "acc={acc}");
        let ari = adjusted_rand_index(&pred, &truth, 2);
        assert!(ari.abs() < 0.05, "ari={ari}");
        let nmi = normalized_mutual_info(&pred, &truth, 2);
        assert!(nmi < 0.05, "nmi={nmi}");
    }

    #[test]
    fn accuracy_handles_unbalanced_and_missing_clusters() {
        let truth = vec![0, 0, 0, 0, 1];
        let pred = vec![0, 0, 0, 0, 0]; // predictor collapsed to one cluster
        assert!((accuracy(&pred, &truth, 2) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn confusion_matrix_counts() {
        let truth = vec![0, 1, 1, 2];
        let pred = vec![1, 1, 0, 2];
        let m = confusion_matrix(&pred, &truth, 3);
        assert_eq!(m[1][0], 1);
        assert_eq!(m[1][1], 1);
        assert_eq!(m[0][1], 1);
        assert_eq!(m[2][2], 1);
        assert_eq!(m.iter().flatten().sum::<usize>(), 4);
    }

    #[test]
    #[should_panic(expected = "label out of range")]
    fn rejects_out_of_range_labels() {
        confusion_matrix(&[3], &[0], 2);
    }
}
