//! Hungarian algorithm (Kuhn–Munkres) for minimum-cost assignment.
//!
//! Clustering accuracy compares predicted labels to ground truth up to
//! the best label permutation; the confusion matrix gives a K × K cost
//! matrix and this solver finds the optimal matching in O(K³). The
//! implementation is the classic potentials-based shortest augmenting
//! path formulation (e-maxx style), exact for rectangular matrices padded
//! to square.

/// Minimum-cost perfect matching on a square cost matrix given as rows of
/// equal length. Returns `assignment[row] = col`.
pub fn hungarian_min_cost(cost: &[Vec<f64>]) -> Vec<usize> {
    let n = cost.len();
    assert!(n > 0, "empty cost matrix");
    assert!(cost.iter().all(|r| r.len() == n), "cost matrix must be square");
    const INF: f64 = f64::INFINITY;

    // 1-based potentials over rows (u) and columns (v); way[j] is the
    // predecessor column on the augmenting path; p[j] = row matched to j.
    let mut u = vec![0.0; n + 1];
    let mut v = vec![0.0; n + 1];
    let mut p = vec![0usize; n + 1];
    let mut way = vec![0usize; n + 1];

    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![INF; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = INF;
            let mut j1 = 0usize;
            for j in 1..=n {
                if used[j] {
                    continue;
                }
                let cur = cost[i0 - 1][j - 1] - u[i0] - v[j];
                if cur < minv[j] {
                    minv[j] = cur;
                    way[j] = j0;
                }
                if minv[j] < delta {
                    delta = minv[j];
                    j1 = j;
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        // augment along the path
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }

    let mut assignment = vec![0usize; n];
    for j in 1..=n {
        if p[j] > 0 {
            assignment[p[j] - 1] = j - 1;
        }
    }
    assignment
}

#[cfg(test)]
mod tests {
    use super::*;

    fn total_cost(cost: &[Vec<f64>], asg: &[usize]) -> f64 {
        asg.iter().enumerate().map(|(i, &j)| cost[i][j]).sum()
    }

    fn brute_force_min(cost: &[Vec<f64>]) -> f64 {
        let n = cost.len();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut best = f64::INFINITY;
        // Heap's algorithm
        fn heap(k: usize, perm: &mut Vec<usize>, cost: &[Vec<f64>], best: &mut f64) {
            if k == 1 {
                let c: f64 = perm.iter().enumerate().map(|(i, &j)| cost[i][j]).sum();
                if c < *best {
                    *best = c;
                }
                return;
            }
            for i in 0..k {
                heap(k - 1, perm, cost, best);
                if k % 2 == 0 {
                    perm.swap(i, k - 1);
                } else {
                    perm.swap(0, k - 1);
                }
            }
        }
        heap(n, &mut perm, cost, &mut best);
        best
    }

    #[test]
    fn identity_when_diagonal_is_cheapest() {
        let cost = vec![
            vec![0.0, 9.0, 9.0],
            vec![9.0, 0.0, 9.0],
            vec![9.0, 9.0, 0.0],
        ];
        assert_eq!(hungarian_min_cost(&cost), vec![0, 1, 2]);
    }

    #[test]
    fn picks_off_diagonal_when_better() {
        let cost = vec![vec![10.0, 1.0], vec![1.0, 10.0]];
        assert_eq!(hungarian_min_cost(&cost), vec![1, 0]);
    }

    #[test]
    fn matches_brute_force_on_random_instances() {
        use crate::rng::{Pcg64, Rng};
        let mut rng = Pcg64::seed(7);
        for n in 2..=7 {
            for _ in 0..20 {
                let cost: Vec<Vec<f64>> = (0..n)
                    .map(|_| (0..n).map(|_| (rng.below(1000) as f64) / 10.0).collect())
                    .collect();
                let asg = hungarian_min_cost(&cost);
                // valid permutation
                let mut seen = vec![false; n];
                for &j in &asg {
                    assert!(!seen[j]);
                    seen[j] = true;
                }
                let got = total_cost(&cost, &asg);
                let want = brute_force_min(&cost);
                assert!((got - want).abs() < 1e-9, "n={n}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn handles_negative_costs() {
        let cost = vec![vec![-5.0, 0.0], vec![0.0, -5.0]];
        let asg = hungarian_min_cost(&cost);
        assert_eq!(asg, vec![0, 1]);
    }

    #[test]
    fn single_element() {
        assert_eq!(hungarian_min_cost(&[vec![3.0]]), vec![0]);
    }
}
