//! Lloyd K-means with k-means++ seeding, restarts, and fork-join
//! parallelism over both axes.
//!
//! Runs on the embedded points `Y` (r × n, r tiny) produced by any of the
//! low-rank paths — the paper's step 7. Matches the paper's experimental
//! protocol: 10 restarts, 20 iterations, best objective kept. The
//! XLA-accelerated assignment path lives in the coordinator; this native
//! implementation is the reference and the restart engine (at r = 2 the
//! native loop is faster than a PJRT round trip per iteration — measured
//! in EXPERIMENTS.md §Perf).
//!
//! Parallel execution ([`kmeans_threaded`]) fans the independent
//! restarts out across worker threads, and chunks the O(n·k·r)
//! assignment step over points when a single restart has the machine to
//! itself. Both axes preserve the determinism contract: per-restart PCG
//! streams are split from the caller's RNG in restart order on the
//! calling thread, per-point assignments are pure functions of
//! `(Y, centroids)`, and the objective is reduced in point order — so
//! `threads = 1` and `threads = N` return bit-identical results.

use crate::linalg::Mat;
use crate::rng::{Pcg64, Rng};
use crate::util::parallel::{for_each_task, map_indexed};

/// Options mirroring the paper's protocol (MATLAB kmeans defaults used
/// in §4: 10 replicates, 20 max iterations).
#[derive(Clone, Debug)]
pub struct KmeansOpts {
    pub k: usize,
    pub restarts: usize,
    pub max_iters: usize,
    /// relative objective improvement below which a run stops early
    pub tol: f64,
}

impl KmeansOpts {
    /// The paper's experimental protocol (§4, MATLAB `kmeans` defaults):
    /// 10 restarts, 20 Lloyd iterations, and an effectively-exact
    /// relative-improvement tolerance of `1e-9`. Override any of these
    /// through the [`KernelClusterer`](crate::api::KernelClusterer)
    /// builder (`kmeans_restarts` / `kmeans_iters` / `kmeans_tol`).
    pub fn paper(k: usize) -> Self {
        KmeansOpts { k, restarts: 10, max_iters: 20, tol: 1e-9 }
    }
}

/// Result of a K-means run.
#[derive(Clone, Debug)]
pub struct KmeansResult {
    /// cluster index per point, length n
    pub labels: Vec<usize>,
    /// centroids, r × k
    pub centroids: Mat,
    /// final objective (sum of squared distances)
    pub objective: f64,
    /// Lloyd iterations executed in the winning restart
    pub iterations: usize,
}

/// K-means++ seeding (Arthur & Vassilvitskii 2007): first centroid
/// uniform, subsequent ones D²-weighted.
fn kmeanspp_init(y: &Mat, k: usize, rng: &mut Pcg64) -> Mat {
    let (r, n) = (y.rows(), y.cols());
    assert!(k <= n, "more clusters than points");
    let mut centroids = Mat::zeros(r, k);
    let first = rng.below(n);
    for i in 0..r {
        centroids[(i, 0)] = y[(i, first)];
    }
    let mut d2 = vec![0.0f64; n];
    for j in 0..n {
        d2[j] = col_dist2(y, j, &centroids, 0);
    }
    for c in 1..k {
        let total: f64 = d2.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut target = rng.next_f64() * total;
            let mut chosen = n - 1;
            for (j, &w) in d2.iter().enumerate() {
                target -= w;
                if target <= 0.0 {
                    chosen = j;
                    break;
                }
            }
            chosen
        };
        for i in 0..r {
            centroids[(i, c)] = y[(i, pick)];
        }
        for j in 0..n {
            let nd = col_dist2(y, j, &centroids, c);
            if nd < d2[j] {
                d2[j] = nd;
            }
        }
    }
    centroids
}

#[inline]
fn col_dist2(y: &Mat, j: usize, c: &Mat, cj: usize) -> f64 {
    let mut s = 0.0;
    for i in 0..y.rows() {
        let d = y[(i, j)] - c[(i, cj)];
        s += d * d;
    }
    s
}

/// Assignment step over a contiguous chunk of points starting at global
/// index `start`: nearest centroid and squared distance per point. Pure
/// per-point function of `(y, centroids)` — safe to run on any worker.
fn assign_range(
    y: &Mat,
    centroids: &Mat,
    k: usize,
    start: usize,
    labels: &mut [usize],
    dist: &mut [f64],
) {
    for (o, (lab, ds)) in labels.iter_mut().zip(dist.iter_mut()).enumerate() {
        let j = start + o;
        let mut best = 0usize;
        let mut bestd = f64::INFINITY;
        for c in 0..k {
            let d = col_dist2(y, j, centroids, c);
            if d < bestd {
                bestd = d;
                best = c;
            }
        }
        *lab = best;
        *ds = bestd;
    }
}

/// Full assignment step, chunked over points across `threads` workers.
/// Labels and distances land in per-point slots, so the result does not
/// depend on the chunking; callers sum `dist` sequentially in point
/// order to keep the objective bit-identical across thread counts.
fn assign_points(
    y: &Mat,
    centroids: &Mat,
    k: usize,
    labels: &mut [usize],
    dist: &mut [f64],
    threads: usize,
) {
    let n = y.cols();
    let workers = threads.min(n).max(1);
    if workers <= 1 {
        assign_range(y, centroids, k, 0, labels, dist);
        return;
    }
    let chunk = n.div_ceil(workers);
    let tasks: Vec<(usize, &mut [usize], &mut [f64])> = labels
        .chunks_mut(chunk)
        .zip(dist.chunks_mut(chunk))
        .enumerate()
        .map(|(g, (lc, dc))| (g * chunk, lc, dc))
        .collect();
    for_each_task(tasks, workers, |(start, lc, dc)| {
        assign_range(y, centroids, k, start, lc, dc);
    });
}

/// One seeded Lloyd run. Empty clusters are re-seeded to the point
/// farthest from its centroid (standard repair).
pub fn kmeans_once(y: &Mat, opts: &KmeansOpts, rng: &mut Pcg64) -> KmeansResult {
    kmeans_once_threaded(y, opts, rng, 1)
}

/// [`kmeans_once`] with the assignment step chunked over `threads`
/// workers. Bit-identical to the sequential run for any thread count:
/// only the O(n·k·r) per-point search is distributed; the update step
/// and the objective reduction stay in point order.
pub fn kmeans_once_threaded(
    y: &Mat,
    opts: &KmeansOpts,
    rng: &mut Pcg64,
    threads: usize,
) -> KmeansResult {
    let (r, n) = (y.rows(), y.cols());
    let k = opts.k;
    let mut centroids = kmeanspp_init(y, k, rng);
    let mut labels = vec![0usize; n];
    let mut dist = vec![0.0f64; n];
    let mut objective = f64::INFINITY;
    let mut iterations = 0;

    for it in 0..opts.max_iters {
        iterations = it + 1;
        // assignment step (parallel over points, reduced in point order)
        assign_points(y, &centroids, k, &mut labels, &mut dist, threads);
        let obj: f64 = dist.iter().sum();
        // update step
        let mut sums = Mat::zeros(r, k);
        let mut counts = vec![0usize; k];
        for j in 0..n {
            let c = labels[j];
            counts[c] += 1;
            for i in 0..r {
                sums[(i, c)] += y[(i, j)];
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // re-seed to the globally worst-fit point
                let far = (0..n)
                    .max_by(|&a, &b| {
                        col_dist2(y, a, &centroids, labels[a])
                            .partial_cmp(&col_dist2(y, b, &centroids, labels[b]))
                            .unwrap()
                    })
                    .unwrap();
                for i in 0..r {
                    centroids[(i, c)] = y[(i, far)];
                }
            } else {
                for i in 0..r {
                    centroids[(i, c)] = sums[(i, c)] / counts[c] as f64;
                }
            }
        }
        let improved = objective - obj;
        objective = obj;
        if improved.abs() <= opts.tol * objective.max(1e-300) && it > 0 {
            break;
        }
    }
    // final assignment under the last centroids (objective consistent)
    assign_points(y, &centroids, k, &mut labels, &mut dist, threads);
    let obj: f64 = dist.iter().sum();
    KmeansResult { labels, centroids, objective: obj, iterations }
}

/// K-means with restarts: best-of-`opts.restarts` independent seeded
/// runs (the paper's protocol). Deterministic given the rng.
pub fn kmeans(y: &Mat, opts: &KmeansOpts, rng: &mut Pcg64) -> KmeansResult {
    kmeans_threaded(y, opts, rng, 1)
}

/// [`kmeans`] with the restarts fanned out across `threads` workers.
///
/// Determinism contract (verified by `tests/parallel_determinism.rs`):
/// every restart's PCG stream is split from `rng` in restart order *on
/// the calling thread* — exactly the sequence the sequential loop draws
/// — and the winning restart is reduced in restart order with the same
/// strict `<` comparison, so labels, centroids, and objective are
/// bit-identical for any thread count. With a single restart the
/// parallelism moves into the chunked assignment step instead.
pub fn kmeans_threaded(
    y: &Mat,
    opts: &KmeansOpts,
    rng: &mut Pcg64,
    threads: usize,
) -> KmeansResult {
    assert!(opts.restarts >= 1);
    // pre-split per-restart streams in restart order: the parent rng
    // advances exactly as in the sequential loop, for any thread count
    let streams: Vec<Pcg64> =
        (0..opts.restarts).map(|t| rng.split(t as u64 + 1)).collect();
    if threads <= 1 || opts.restarts == 1 {
        // fold run by run — only the current best result stays alive
        let mut best: Option<KmeansResult> = None;
        for mut r in streams {
            let run = kmeans_once_threaded(y, opts, &mut r, threads);
            if best.as_ref().is_none_or(|b| run.objective < b.objective) {
                best = Some(run);
            }
        }
        return best.expect("restarts >= 1");
    }
    // the fan-out holds one result per restart until the index-order
    // reduction (restarts are ~10 under the paper's protocol)
    let runs = map_indexed(opts.restarts, threads, |t| {
        let mut r = streams[t].clone();
        kmeans_once_threaded(y, opts, &mut r, 1)
    });
    let mut best: Option<KmeansResult> = None;
    for run in runs {
        if best.as_ref().is_none_or(|b| run.objective < b.objective) {
            best = Some(run);
        }
    }
    best.expect("restarts >= 1")
}

#[cfg(test)]
mod tests {
    use super::*;

    /// three well-separated blobs in R²
    fn blobs(rng: &mut Pcg64, per: usize) -> (Mat, Vec<usize>) {
        let centers = [(0.0, 0.0), (10.0, 0.0), (0.0, 10.0)];
        let n = per * 3;
        let mut y = Mat::zeros(2, n);
        let mut truth = vec![0usize; n];
        for (c, &(cx, cy)) in centers.iter().enumerate() {
            for j in 0..per {
                let idx = c * per + j;
                y[(0, idx)] = cx + 0.5 * rng.normal();
                y[(1, idx)] = cy + 0.5 * rng.normal();
                truth[idx] = c;
            }
        }
        (y, truth)
    }

    #[test]
    fn recovers_separated_blobs() {
        let mut rng = Pcg64::seed(1);
        let (y, truth) = blobs(&mut rng, 50);
        let res = kmeans(&y, &KmeansOpts::paper(3), &mut rng);
        let acc = crate::clustering::accuracy(&res.labels, &truth, 3);
        assert!(acc > 0.99, "accuracy {acc}");
    }

    #[test]
    fn objective_is_sum_of_squared_distances() {
        let mut rng = Pcg64::seed(2);
        let (y, _) = blobs(&mut rng, 20);
        let res = kmeans(&y, &KmeansOpts::paper(3), &mut rng);
        let manual: f64 = (0..y.cols())
            .map(|j| col_dist2(&y, j, &res.centroids, res.labels[j]))
            .sum();
        assert!((res.objective - manual).abs() < 1e-9 * manual.max(1.0));
    }

    #[test]
    fn restarts_never_hurt() {
        let mut rng_a = Pcg64::seed(3);
        let mut rng_b = Pcg64::seed(3);
        let (y, _) = blobs(&mut rng_a, 15);
        let (_, _) = blobs(&mut rng_b, 15); // keep rngs aligned
        let one = kmeans(&y, &KmeansOpts { restarts: 1, ..KmeansOpts::paper(3) }, &mut rng_a);
        let ten = kmeans(&y, &KmeansOpts::paper(3), &mut rng_b);
        assert!(ten.objective <= one.objective + 1e-9);
    }

    #[test]
    fn k_equals_n_gives_zero_objective() {
        let y = Mat::from_vec(1, 3, vec![1.0, 5.0, 9.0]);
        let mut rng = Pcg64::seed(4);
        let res = kmeans(&y, &KmeansOpts { k: 3, restarts: 5, max_iters: 10, tol: 0.0 }, &mut rng);
        assert!(res.objective < 1e-18);
    }

    #[test]
    fn deterministic_for_same_seed() {
        let mut r1 = Pcg64::seed(5);
        let (y, _) = blobs(&mut r1, 10);
        let mut a_rng = Pcg64::seed(77);
        let mut b_rng = Pcg64::seed(77);
        let a = kmeans(&y, &KmeansOpts::paper(3), &mut a_rng);
        let b = kmeans(&y, &KmeansOpts::paper(3), &mut b_rng);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.objective, b.objective);
    }

    #[test]
    fn threaded_is_bit_identical_to_sequential() {
        let mut r1 = Pcg64::seed(7);
        let (y, _) = blobs(&mut r1, 40);
        let run = |threads: usize| {
            let mut rng = Pcg64::seed(123);
            kmeans_threaded(&y, &KmeansOpts::paper(3), &mut rng, threads)
        };
        let base = run(1);
        for threads in [2usize, 4, 16] {
            let par = run(threads);
            assert_eq!(base.labels, par.labels, "threads={threads}");
            assert_eq!(base.objective.to_bits(), par.objective.to_bits(), "threads={threads}");
            assert_eq!(base.centroids.data(), par.centroids.data(), "threads={threads}");
        }
        // the caller's rng must advance identically on both paths
        let mut a = Pcg64::seed(5);
        let mut b = Pcg64::seed(5);
        let _ = kmeans(&y, &KmeansOpts::paper(3), &mut a);
        let _ = kmeans_threaded(&y, &KmeansOpts::paper(3), &mut b, 4);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn handles_duplicate_points() {
        let y = Mat::from_vec(1, 6, vec![1.0, 1.0, 1.0, 8.0, 8.0, 8.0]);
        let mut rng = Pcg64::seed(6);
        let res = kmeans(&y, &KmeansOpts::paper(2), &mut rng);
        assert!(res.objective < 1e-18);
        assert_eq!(res.labels[0], res.labels[1]);
        assert_ne!(res.labels[0], res.labels[5]);
    }
}
