//! Lloyd K-means with k-means++ seeding, restarts, and fork-join
//! parallelism over both axes.
//!
//! Runs on the embedded points `Y` (r × n, r tiny) produced by any of the
//! low-rank paths — the paper's step 7. Matches the paper's experimental
//! protocol: 10 restarts, 20 iterations, best objective kept. The
//! XLA-accelerated assignment path lives in the coordinator; this native
//! implementation is the reference and the restart engine (at r = 2 the
//! native loop is faster than a PJRT round trip per iteration — measured
//! in EXPERIMENTS.md §Perf).
//!
//! The assignment step uses the norm identity
//! `‖y − c‖² = ‖y‖² + ‖c‖² − 2 y·c`: point norms are computed once per
//! run, centroid norms once per iteration, and the cross term `YᵀC` is
//! one GEMM through the shared [`crate::linalg::gemm`] core — the old
//! path walked column-strided memory per (point, centroid) pair. The
//! pre-GEMM implementation survives as [`kmeans_reference`] for the
//! bench before/after rows and the agreement tests.
//!
//! Parallel execution ([`kmeans_threaded`]) fans the independent
//! restarts out across worker threads — surplus workers beyond the
//! restart count move into the chunked assignment step — and chunks the
//! assignment over points when a single restart has the machine to
//! itself. Both axes preserve the determinism contract: per-restart PCG
//! streams are split from the caller's RNG in restart order on the
//! calling thread, per-point assignments are pure functions of
//! `(Y, centroids)`, and the objective is reduced in point order — so
//! `threads = 1` and `threads = N` return bit-identical results.

use crate::linalg::{dot, Mat};
use crate::rng::{Pcg64, Rng};
use crate::util::parallel::{for_each_task, map_indexed};

/// Options mirroring the paper's protocol (MATLAB kmeans defaults used
/// in §4: 10 replicates, 20 max iterations).
#[derive(Clone, Debug)]
pub struct KmeansOpts {
    pub k: usize,
    pub restarts: usize,
    pub max_iters: usize,
    /// relative objective improvement below which a run stops early
    pub tol: f64,
}

impl KmeansOpts {
    /// The paper's experimental protocol (§4, MATLAB `kmeans` defaults):
    /// 10 restarts, 20 Lloyd iterations, and an effectively-exact
    /// relative-improvement tolerance of `1e-9`. Override any of these
    /// through the [`KernelClusterer`](crate::api::KernelClusterer)
    /// builder (`kmeans_restarts` / `kmeans_iters` / `kmeans_tol`).
    pub fn paper(k: usize) -> Self {
        KmeansOpts { k, restarts: 10, max_iters: 20, tol: 1e-9 }
    }
}

/// Result of a K-means run.
#[derive(Clone, Debug)]
pub struct KmeansResult {
    /// cluster index per point, length n
    pub labels: Vec<usize>,
    /// centroids, r × k
    pub centroids: Mat,
    /// final objective (sum of squared distances)
    pub objective: f64,
    /// Lloyd iterations executed in the winning restart
    pub iterations: usize,
}

#[inline]
fn sq_norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum()
}

// Identity distances clamp through the single shared
// `crate::simd::clamp_dist2` (NaN-preserving): this path and the
// dispatched argmin kernels must round identically or the per-ISA
// bit-identity contract splits.
use crate::simd::clamp_dist2;

/// `‖y − c‖²` via the norm identity, clamped at zero (the identity can
/// land a few ulps negative when `y ≈ c`; when `c` was copied from `y`
/// the dot product reruns the norm's exact op sequence and the result
/// is exactly zero).
#[inline]
fn point_dist2(y: &[f64], yn: f64, c: &[f64], cn: f64) -> f64 {
    clamp_dist2(yn + cn - 2.0 * dot(y, c))
}

/// K-means++ seeding (Arthur & Vassilvitskii 2007) over point-major
/// data: first centroid uniform, subsequent ones D²-weighted, with all
/// distances through the norm identity. Returns centroids point-major
/// (k × r).
fn kmeanspp_init(yt: &Mat, yn: &[f64], k: usize, rng: &mut Pcg64) -> Mat {
    let n = yt.rows();
    let r = yt.cols();
    assert!(k <= n, "more clusters than points");
    let mut ct = Mat::zeros(k, r);
    let first = rng.below(n);
    ct.row_mut(0).copy_from_slice(yt.row(first));
    let cn0 = sq_norm(ct.row(0));
    let mut d2 = vec![0.0f64; n];
    for j in 0..n {
        d2[j] = point_dist2(yt.row(j), yn[j], ct.row(0), cn0);
    }
    for c in 1..k {
        let total: f64 = d2.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut target = rng.next_f64() * total;
            let mut chosen = n - 1;
            for (j, &w) in d2.iter().enumerate() {
                target -= w;
                if target <= 0.0 {
                    chosen = j;
                    break;
                }
            }
            chosen
        };
        ct.row_mut(c).copy_from_slice(yt.row(pick));
        let cnc = sq_norm(ct.row(c));
        for j in 0..n {
            let nd = point_dist2(yt.row(j), yn[j], ct.row(c), cnc);
            if nd < d2[j] {
                d2[j] = nd;
            }
        }
    }
    ct
}

#[inline]
fn col_dist2(y: &Mat, j: usize, c: &Mat, cj: usize) -> f64 {
    let mut s = 0.0;
    for i in 0..y.rows() {
        let d = y[(i, j)] - c[(i, cj)];
        s += d * d;
    }
    s
}

/// Argmin scan over a contiguous chunk of the cross-term rows (`g` is
/// the flat row-major n × k cross term) starting at global point index
/// `start`: nearest centroid and squared distance per point from
/// `‖y‖² + ‖c‖² − 2 y·c`. Pure per-point function of the precomputed
/// `(g, yn, cn)` — safe to run on any worker.
fn assign_range(
    g: &[f64],
    k: usize,
    yn: &[f64],
    cn: &[f64],
    start: usize,
    labels: &mut [usize],
    dist: &mut [f64],
) {
    // dispatched argmin kernel, hoisted outside the point loop; the
    // kernel reproduces this loop's exact semantics (clamp keeping NaN,
    // strict <, first minimum on ties) bit-identically on every ISA
    let argmin = crate::simd::dispatch().argmin_dist2;
    for (o, (lab, ds)) in labels.iter_mut().zip(dist.iter_mut()).enumerate() {
        let j = start + o;
        let (best, bestd) = argmin(&g[j * k..(j + 1) * k], yn[j], cn);
        *lab = best;
        *ds = bestd;
    }
}

/// Full assignment step: one GEMM for the cross term `G = Y·Cᵀ`
/// (point-major operands) into the caller-owned `g_scratch` buffer —
/// reused across Lloyd iterations, no per-iteration allocation — then
/// the argmin scan chunked over points across `threads` workers. Labels
/// and distances land in per-point slots and `G` is
/// thread-count-invariant by the GEMM contract, so the result does not
/// depend on the chunking; callers sum `dist` sequentially in point
/// order to keep the objective bit-identical across thread counts.
fn assign_points(
    yt: &Mat,
    yn: &[f64],
    ct: &Mat,
    cn: &[f64],
    labels: &mut [usize],
    dist: &mut [f64],
    threads: usize,
    g_scratch: &mut Vec<f64>,
) {
    let n = yt.rows();
    let k = ct.rows();
    g_scratch.clear();
    g_scratch.resize(n * k, 0.0); // gemm_into accumulates: start from zero
    crate::linalg::gemm_into(g_scratch, yt, &ct.transpose(), threads);
    let workers = threads.min(n).max(1);
    if workers <= 1 {
        assign_range(g_scratch, k, yn, cn, 0, labels, dist);
        return;
    }
    let chunk = n.div_ceil(workers);
    let tasks: Vec<(usize, &mut [usize], &mut [f64])> = labels
        .chunks_mut(chunk)
        .zip(dist.chunks_mut(chunk))
        .enumerate()
        .map(|(t, (lc, dc))| (t * chunk, lc, dc))
        .collect();
    let g = &*g_scratch;
    for_each_task(tasks, workers, |(start, lc, dc)| {
        assign_range(g, k, yn, cn, start, lc, dc);
    });
}

/// One seeded Lloyd run. Empty clusters are re-seeded to the point
/// farthest from its centroid (standard repair).
pub fn kmeans_once(y: &Mat, opts: &KmeansOpts, rng: &mut Pcg64) -> KmeansResult {
    kmeans_once_threaded(y, opts, rng, 1)
}

/// [`kmeans_once`] with the assignment step (GEMM + argmin scan) fanned
/// over `threads` workers. Bit-identical to the sequential run for any
/// thread count: only per-point work is distributed; the update step
/// and the objective reduction stay in point order.
pub fn kmeans_once_threaded(
    y: &Mat,
    opts: &KmeansOpts,
    rng: &mut Pcg64,
    threads: usize,
) -> KmeansResult {
    let (yt, yn) = point_major(y);
    kmeans_once_on(&yt, &yn, opts, rng, threads)
}

/// Point-major layout + squared norms: every distance below is
/// ‖y‖² + ‖c‖² − 2 y·c over contiguous rows. A pure function of `y`,
/// computed once per `kmeans_threaded` call and shared by all restarts.
fn point_major(y: &Mat) -> (Mat, Vec<f64>) {
    let yt = y.transpose(); // n × r
    let yn = (0..yt.rows()).map(|j| sq_norm(yt.row(j))).collect();
    (yt, yn)
}

/// One Lloyd run over pre-transposed data (`yt` n × r, `yn` per-point
/// squared norms) — the shared core of [`kmeans_once_threaded`] and the
/// restart fan-out.
fn kmeans_once_on(
    yt: &Mat,
    yn: &[f64],
    opts: &KmeansOpts,
    rng: &mut Pcg64,
    threads: usize,
) -> KmeansResult {
    let ct = kmeanspp_init(yt, yn, opts.k, rng); // k × r
    lloyd_from(yt, yn, ct, opts, threads)
}

/// The Lloyd loop proper, starting from caller-provided point-major
/// centroids `ct` (k × r). Shared by the seeded path
/// ([`kmeans_once_on`], which draws `ct` via k-means++) and the
/// warm-started path ([`kmeans_warm_threaded`], which inherits `ct`
/// from a previous model). Pure function of `(yt, yn, ct, opts)` —
/// no RNG — and bit-identical for any thread count.
fn lloyd_from(
    yt: &Mat,
    yn: &[f64],
    mut ct: Mat,
    opts: &KmeansOpts,
    threads: usize,
) -> KmeansResult {
    let (n, r) = (yt.rows(), yt.cols());
    let k = opts.k;
    let mut cn: Vec<f64> = (0..k).map(|c| sq_norm(ct.row(c))).collect();
    let mut labels = vec![0usize; n];
    let mut dist = vec![0.0f64; n];
    let mut g_scratch = Vec::new(); // cross-term buffer, reused every iteration
    let mut objective = f64::INFINITY;
    let mut iterations = 0;

    for it in 0..opts.max_iters {
        iterations = it + 1;
        // assignment step (parallel over points, reduced in point order)
        assign_points(yt, yn, &ct, &cn, &mut labels, &mut dist, threads, &mut g_scratch);
        let obj: f64 = dist.iter().sum();
        // update step: per-cluster sums accumulate over contiguous rows
        let mut sums = Mat::zeros(k, r);
        let mut counts = vec![0usize; k];
        for j in 0..n {
            let c = labels[j];
            counts[c] += 1;
            for (s, &v) in sums.row_mut(c).iter_mut().zip(yt.row(j)) {
                *s += v;
            }
        }
        // empty-cluster repair: re-seed to the point worst fit by the
        // assignment just computed (`dist` is per-point and
        // thread-count-invariant). total_cmp keeps a NaN distance from
        // panicking, and each repaired cluster consumes its point so two
        // empty clusters never adopt the same one.
        let mut repair_d: Option<Vec<f64>> = None;
        for c in 0..k {
            if counts[c] == 0 {
                let d = repair_d.get_or_insert_with(|| dist.clone());
                let far = (0..n)
                    .max_by(|&a, &b| d[a].total_cmp(&d[b]))
                    .expect("kmeans on zero points");
                d[far] = f64::NEG_INFINITY;
                ct.row_mut(c).copy_from_slice(yt.row(far));
            } else {
                let count = counts[c] as f64;
                for (cv, &s) in ct.row_mut(c).iter_mut().zip(sums.row(c)) {
                    *cv = s / count;
                }
            }
        }
        for c in 0..k {
            cn[c] = sq_norm(ct.row(c));
        }
        let improved = objective - obj;
        objective = obj;
        if improved.abs() <= opts.tol * objective.max(1e-300) && it > 0 {
            break;
        }
    }
    // final assignment under the last centroids (objective consistent)
    assign_points(yt, yn, &ct, &cn, &mut labels, &mut dist, threads, &mut g_scratch);
    let obj: f64 = dist.iter().sum();
    KmeansResult { labels, centroids: ct.transpose(), objective: obj, iterations }
}

/// K-means with restarts: best-of-`opts.restarts` independent seeded
/// runs (the paper's protocol). Deterministic given the rng.
pub fn kmeans(y: &Mat, opts: &KmeansOpts, rng: &mut Pcg64) -> KmeansResult {
    kmeans_threaded(y, opts, rng, 1)
}

/// [`kmeans`] with the restarts fanned out across `threads` workers.
///
/// Determinism contract (verified by `tests/parallel_determinism.rs`):
/// every restart's PCG stream is split from `rng` in restart order *on
/// the calling thread* — exactly the sequence the sequential loop draws
/// — and the winning restart is reduced in restart order with the same
/// strict `<` comparison, so labels, centroids, and objective are
/// bit-identical for any thread count. Whole surplus multiples of the
/// restart count are not left idle: each restart runs its assignment
/// step with `(threads / restarts).max(1)` inner workers (the inner
/// chunking is thread-count-invariant, so bit-identity survives; a
/// fractional surplus below one extra worker per restart still idles).
/// With a single restart all the parallelism moves into the assignment
/// step.
pub fn kmeans_threaded(
    y: &Mat,
    opts: &KmeansOpts,
    rng: &mut Pcg64,
    threads: usize,
) -> KmeansResult {
    assert!(opts.restarts >= 1);
    // pre-split per-restart streams in restart order: the parent rng
    // advances exactly as in the sequential loop, for any thread count
    let streams: Vec<Pcg64> =
        (0..opts.restarts).map(|t| rng.split(t as u64 + 1)).collect();
    // transpose + norms once, shared read-only by every restart (a pure
    // function of y — sharing it changes no bits)
    let (yt, yn) = point_major(y);
    if threads <= 1 || opts.restarts == 1 {
        // fold run by run — only the current best result stays alive
        let mut best: Option<KmeansResult> = None;
        for mut r in streams {
            let run = kmeans_once_on(&yt, &yn, opts, &mut r, threads);
            if best.as_ref().is_none_or(|b| run.objective < b.objective) {
                best = Some(run);
            }
        }
        return best.expect("restarts >= 1");
    }
    // the fan-out holds one result per restart until the index-order
    // reduction (restarts are ~10 under the paper's protocol); surplus
    // workers beyond the restart count chunk each restart's assignment
    let inner = (threads / opts.restarts).max(1);
    let runs = map_indexed(opts.restarts, threads, |t| {
        let mut r = streams[t].clone();
        kmeans_once_on(&yt, &yn, opts, &mut r, inner)
    });
    let mut best: Option<KmeansResult> = None;
    for run in runs {
        if best.as_ref().is_none_or(|b| run.objective < b.objective) {
            best = Some(run);
        }
    }
    best.expect("restarts >= 1")
}

/// Warm-started Lloyd: one K-means run seeded from caller-provided
/// centroids (r × k, the [`KmeansResult::centroids`] layout) instead of
/// k-means++ — the refresh path of the streaming subsystem, where the
/// previous generation's clustering is a far better start than a fresh
/// draw. No restarts and no RNG: the result is a pure function of
/// `(y, init_centroids, opts)`, and the assignment fan-out preserves the
/// crate-wide `threads = 1 ≡ threads = N` bit-identity contract.
///
/// Empty clusters (a warm centroid stranded by drifted data) go through
/// the same farthest-point repair as the seeded path.
pub fn kmeans_warm_threaded(
    y: &Mat,
    init_centroids: &Mat,
    opts: &KmeansOpts,
    threads: usize,
) -> KmeansResult {
    assert_eq!(
        init_centroids.rows(),
        y.rows(),
        "warm centroids must live in the embedding space of y"
    );
    assert_eq!(init_centroids.cols(), opts.k, "warm centroids must have k columns");
    assert!(opts.k <= y.cols(), "more clusters than points");
    let (yt, yn) = point_major(y);
    lloyd_from(&yt, &yn, init_centroids.transpose(), opts, threads)
}

/// The pre-GEMM Lloyd implementation: per-(point, centroid) squared
/// distances walked column-strided, sequential only. Kept verbatim as
/// the oracle for `bench_kmeans`/`bench_pipeline` before/after rows and
/// the agreement tests — never on a hot path.
pub fn kmeans_reference(y: &Mat, opts: &KmeansOpts, rng: &mut Pcg64) -> KmeansResult {
    assert!(opts.restarts >= 1);
    let streams: Vec<Pcg64> =
        (0..opts.restarts).map(|t| rng.split(t as u64 + 1)).collect();
    let mut best: Option<KmeansResult> = None;
    for mut r in streams {
        let run = kmeans_once_reference(y, opts, &mut r);
        if best.as_ref().is_none_or(|b| run.objective < b.objective) {
            best = Some(run);
        }
    }
    best.expect("restarts >= 1")
}

fn kmeanspp_init_reference(y: &Mat, k: usize, rng: &mut Pcg64) -> Mat {
    let (r, n) = (y.rows(), y.cols());
    assert!(k <= n, "more clusters than points");
    let mut centroids = Mat::zeros(r, k);
    let first = rng.below(n);
    for i in 0..r {
        centroids[(i, 0)] = y[(i, first)];
    }
    let mut d2 = vec![0.0f64; n];
    for j in 0..n {
        d2[j] = col_dist2(y, j, &centroids, 0);
    }
    for c in 1..k {
        let total: f64 = d2.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut target = rng.next_f64() * total;
            let mut chosen = n - 1;
            for (j, &w) in d2.iter().enumerate() {
                target -= w;
                if target <= 0.0 {
                    chosen = j;
                    break;
                }
            }
            chosen
        };
        for i in 0..r {
            centroids[(i, c)] = y[(i, pick)];
        }
        for j in 0..n {
            let nd = col_dist2(y, j, &centroids, c);
            if nd < d2[j] {
                d2[j] = nd;
            }
        }
    }
    centroids
}

fn kmeans_once_reference(y: &Mat, opts: &KmeansOpts, rng: &mut Pcg64) -> KmeansResult {
    let (r, n) = (y.rows(), y.cols());
    let k = opts.k;
    let mut centroids = kmeanspp_init_reference(y, k, rng);
    let mut labels = vec![0usize; n];
    let mut dist = vec![0.0f64; n];
    let mut objective = f64::INFINITY;
    let mut iterations = 0;

    for it in 0..opts.max_iters {
        iterations = it + 1;
        for j in 0..n {
            let mut bd = f64::INFINITY;
            let mut bc = 0usize;
            for c in 0..k {
                let d = col_dist2(y, j, &centroids, c);
                if d < bd {
                    bd = d;
                    bc = c;
                }
            }
            labels[j] = bc;
            dist[j] = bd;
        }
        let obj: f64 = dist.iter().sum();
        let mut sums = Mat::zeros(r, k);
        let mut counts = vec![0usize; k];
        for j in 0..n {
            let c = labels[j];
            counts[c] += 1;
            for i in 0..r {
                sums[(i, c)] += y[(i, j)];
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                let far = (0..n)
                    .max_by(|&a, &b| {
                        col_dist2(y, a, &centroids, labels[a])
                            .total_cmp(&col_dist2(y, b, &centroids, labels[b]))
                    })
                    .expect("kmeans on zero points");
                for i in 0..r {
                    centroids[(i, c)] = y[(i, far)];
                }
            } else {
                for i in 0..r {
                    centroids[(i, c)] = sums[(i, c)] / counts[c] as f64;
                }
            }
        }
        let improved = objective - obj;
        objective = obj;
        if improved.abs() <= opts.tol * objective.max(1e-300) && it > 0 {
            break;
        }
    }
    for j in 0..n {
        let mut bd = f64::INFINITY;
        let mut bc = 0usize;
        for c in 0..k {
            let d = col_dist2(y, j, &centroids, c);
            if d < bd {
                bd = d;
                bc = c;
            }
        }
        labels[j] = bc;
        dist[j] = bd;
    }
    let obj: f64 = dist.iter().sum();
    KmeansResult { labels, centroids, objective: obj, iterations }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// three well-separated blobs in R²
    fn blobs(rng: &mut Pcg64, per: usize) -> (Mat, Vec<usize>) {
        let centers = [(0.0, 0.0), (10.0, 0.0), (0.0, 10.0)];
        let n = per * 3;
        let mut y = Mat::zeros(2, n);
        let mut truth = vec![0usize; n];
        for (c, &(cx, cy)) in centers.iter().enumerate() {
            for j in 0..per {
                let idx = c * per + j;
                y[(0, idx)] = cx + 0.5 * rng.normal();
                y[(1, idx)] = cy + 0.5 * rng.normal();
                truth[idx] = c;
            }
        }
        (y, truth)
    }

    #[test]
    fn recovers_separated_blobs() {
        let mut rng = Pcg64::seed(1);
        let (y, truth) = blobs(&mut rng, 50);
        let res = kmeans(&y, &KmeansOpts::paper(3), &mut rng);
        let acc = crate::clustering::accuracy(&res.labels, &truth, 3);
        assert!(acc > 0.99, "accuracy {acc}");
    }

    #[test]
    fn objective_is_sum_of_squared_distances() {
        let mut rng = Pcg64::seed(2);
        let (y, _) = blobs(&mut rng, 20);
        let res = kmeans(&y, &KmeansOpts::paper(3), &mut rng);
        let manual: f64 = (0..y.cols())
            .map(|j| col_dist2(&y, j, &res.centroids, res.labels[j]))
            .sum();
        assert!((res.objective - manual).abs() < 1e-9 * manual.max(1.0));
    }

    #[test]
    fn restarts_never_hurt() {
        let mut rng_a = Pcg64::seed(3);
        let mut rng_b = Pcg64::seed(3);
        let (y, _) = blobs(&mut rng_a, 15);
        let (_, _) = blobs(&mut rng_b, 15); // keep rngs aligned
        let one = kmeans(&y, &KmeansOpts { restarts: 1, ..KmeansOpts::paper(3) }, &mut rng_a);
        let ten = kmeans(&y, &KmeansOpts::paper(3), &mut rng_b);
        assert!(ten.objective <= one.objective + 1e-9);
    }

    #[test]
    fn k_equals_n_gives_zero_objective() {
        let y = Mat::from_vec(1, 3, vec![1.0, 5.0, 9.0]);
        let mut rng = Pcg64::seed(4);
        let res = kmeans(&y, &KmeansOpts { k: 3, restarts: 5, max_iters: 10, tol: 0.0 }, &mut rng);
        assert!(res.objective < 1e-18);
    }

    #[test]
    fn deterministic_for_same_seed() {
        let mut r1 = Pcg64::seed(5);
        let (y, _) = blobs(&mut r1, 10);
        let mut a_rng = Pcg64::seed(77);
        let mut b_rng = Pcg64::seed(77);
        let a = kmeans(&y, &KmeansOpts::paper(3), &mut a_rng);
        let b = kmeans(&y, &KmeansOpts::paper(3), &mut b_rng);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.objective, b.objective);
    }

    #[test]
    fn threaded_is_bit_identical_to_sequential() {
        let mut r1 = Pcg64::seed(7);
        let (y, _) = blobs(&mut r1, 40);
        let run = |threads: usize| {
            let mut rng = Pcg64::seed(123);
            kmeans_threaded(&y, &KmeansOpts::paper(3), &mut rng, threads)
        };
        let base = run(1);
        // 64 exercises the surplus-thread path (inner workers > 1)
        for threads in [2usize, 4, 16, 64] {
            let par = run(threads);
            assert_eq!(base.labels, par.labels, "threads={threads}");
            assert_eq!(base.objective.to_bits(), par.objective.to_bits(), "threads={threads}");
            assert_eq!(base.centroids.data(), par.centroids.data(), "threads={threads}");
        }
        // the caller's rng must advance identically on both paths
        let mut a = Pcg64::seed(5);
        let mut b = Pcg64::seed(5);
        let _ = kmeans(&y, &KmeansOpts::paper(3), &mut a);
        let _ = kmeans_threaded(&y, &KmeansOpts::paper(3), &mut b, 4);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn warm_start_from_good_centroids_converges_fast() {
        let mut rng = Pcg64::seed(21);
        let (y, truth) = blobs(&mut rng, 40);
        let cold = kmeans(&y, &KmeansOpts::paper(3), &mut rng);
        // warm-start from the converged centroids: one pass, same labels
        let warm = kmeans_warm_threaded(&y, &cold.centroids, &KmeansOpts::paper(3), 1);
        assert_eq!(warm.labels, cold.labels);
        assert!(warm.objective <= cold.objective + 1e-12);
        assert!(warm.iterations <= 2, "iterations {}", warm.iterations);
        let acc = crate::clustering::accuracy(&warm.labels, &truth, 3);
        assert!(acc > 0.99, "accuracy {acc}");
    }

    #[test]
    fn warm_start_is_thread_count_invariant() {
        let mut rng = Pcg64::seed(22);
        let (y, _) = blobs(&mut rng, 35);
        // a deliberately poor warm start so the loop actually iterates
        let init = Mat::from_vec(2, 3, vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0]);
        let opts = KmeansOpts { k: 3, restarts: 1, max_iters: 20, tol: 1e-9 };
        let base = kmeans_warm_threaded(&y, &init, &opts, 1);
        for threads in [2usize, 4, 16] {
            let par = kmeans_warm_threaded(&y, &init, &opts, threads);
            assert_eq!(base.labels, par.labels, "threads={threads}");
            assert_eq!(base.objective.to_bits(), par.objective.to_bits(), "threads={threads}");
            assert_eq!(base.centroids.data(), par.centroids.data(), "threads={threads}");
        }
    }

    #[test]
    fn warm_start_repairs_stranded_centroids() {
        // all mass near two blobs, but three warm centroids — one lands
        // empty and must be re-seeded, not silently kept
        let y = Mat::from_vec(1, 6, vec![0.0, 0.1, 0.2, 9.0, 9.1, 9.2]);
        let init = Mat::from_vec(1, 3, vec![0.1, 9.1, 100.0]);
        let opts = KmeansOpts { k: 3, restarts: 1, max_iters: 10, tol: 0.0 };
        let res = kmeans_warm_threaded(&y, &init, &opts, 1);
        assert_eq!(res.labels.len(), 6);
        // every cluster ends non-empty after repair
        let mut counts = [0usize; 3];
        for &l in &res.labels {
            counts[l] += 1;
        }
        assert!(counts.iter().all(|&c| c > 0), "counts {counts:?}");
    }

    #[test]
    fn handles_duplicate_points() {
        let y = Mat::from_vec(1, 6, vec![1.0, 1.0, 1.0, 8.0, 8.0, 8.0]);
        let mut rng = Pcg64::seed(6);
        let res = kmeans(&y, &KmeansOpts::paper(2), &mut rng);
        assert!(res.objective < 1e-18);
        assert_eq!(res.labels[0], res.labels[1]);
        assert_ne!(res.labels[0], res.labels[5]);
    }

    #[test]
    fn agrees_with_reference_implementation() {
        // the GEMM/norm-identity path and the pre-GEMM reference draw the
        // same RNG sequence and converge to the same clustering on
        // separated data; objectives agree to rounding noise
        let mut r1 = Pcg64::seed(9);
        let (y, truth) = blobs(&mut r1, 30);
        let opts = KmeansOpts::paper(3);
        let mut ra = Pcg64::seed(55);
        let mut rb = Pcg64::seed(55);
        let a = kmeans(&y, &opts, &mut ra);
        let b = kmeans_reference(&y, &opts, &mut rb);
        assert!((a.objective - b.objective).abs() < 1e-6 * a.objective.max(1.0));
        let acc_a = crate::clustering::accuracy(&a.labels, &truth, 3);
        let acc_b = crate::clustering::accuracy(&b.labels, &truth, 3);
        assert!(acc_a > 0.99 && acc_b > 0.99, "{acc_a} vs {acc_b}");
        // both paths must leave the caller's rng at the same state
        assert_eq!(ra.next_u64(), rb.next_u64());
    }

    #[test]
    fn empty_cluster_repair_survives_nan_distances() {
        // a NaN coordinate used to panic the repair's partial_cmp sort;
        // with total_cmp the run completes (labels for the NaN point are
        // arbitrary but defined)
        let y = Mat::from_vec(1, 6, vec![0.0, 0.1, 5.0, 5.1, 9.0, f64::NAN]);
        let mut rng = Pcg64::seed(11);
        let res = kmeans(&y, &KmeansOpts { k: 3, restarts: 3, max_iters: 10, tol: 0.0 }, &mut rng);
        assert_eq!(res.labels.len(), 6);
        // the distance clamp must not scrub NaN to 0.0: the NaN point's
        // best distance stays infinite, so no restart can win with a
        // bogus zero objective (the pre-GEMM NaN semantics)
        assert!(res.objective.is_infinite(), "objective {}", res.objective);
    }
}
