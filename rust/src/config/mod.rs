//! Experiment configuration and CLI argument parsing.
//!
//! Configs load from JSON files (see `util::json`) and/or `--key value`
//! command-line overrides, so every experiment in EXPERIMENTS.md is
//! reproducible from a single command line.

mod cli;

pub use cli::{Cli, CliError};

use crate::kernels::Kernel;
use crate::util::Json;

/// Which low-rank / clustering method to run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    /// the paper's Alg. 1 (SRHT one-pass)
    OnePass,
    /// one-pass randomized sketch with a dense Gaussian test matrix
    GaussianOnePass,
    /// Nyström with uniform column sampling, parameterized by m
    Nystrom { m: usize },
    /// exact top-r via streamed subspace iteration
    Exact,
    /// full kernel K-means on the materialized kernel (O(n²) baseline)
    FullKernel,
    /// plain K-means on the raw input (no kernel)
    PlainKmeans,
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::OnePass => "one_pass".into(),
            Method::GaussianOnePass => "gaussian_one_pass".into(),
            Method::Nystrom { m } => format!("nystrom_m{m}"),
            Method::Exact => "exact".into(),
            Method::FullKernel => "full_kernel".into(),
            Method::PlainKmeans => "plain_kmeans".into(),
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        match s {
            "one_pass" | "ours" => Some(Method::OnePass),
            "gaussian" | "gaussian_one_pass" => Some(Method::GaussianOnePass),
            "exact" => Some(Method::Exact),
            "full_kernel" => Some(Method::FullKernel),
            "plain" | "plain_kmeans" => Some(Method::PlainKmeans),
            _ => s.strip_prefix("nystrom_m")
                .and_then(|m| m.parse().ok())
                .map(|m| Method::Nystrom { m }),
        }
    }
}

/// Execution backend for the bulk compute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// native rust gram/FWHT (reference; always available)
    Native,
    /// XLA artifacts via PJRT (the production path; requires artifacts/)
    Xla,
}

/// A full experiment specification.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub dataset: String,
    pub n: usize,
    pub p: usize,
    pub k: usize,
    pub kernel: Kernel,
    pub method: Method,
    pub rank: usize,
    pub oversample: usize,
    pub batch: usize,
    pub trials: usize,
    pub seed: u64,
    pub backend: Backend,
    pub kmeans_restarts: usize,
    pub kmeans_iters: usize,
    pub threads: usize,
    pub artifacts_dir: String,
}

impl Default for ExperimentConfig {
    /// Fig. 3 defaults (the paper's real-data protocol).
    fn default() -> Self {
        ExperimentConfig {
            dataset: "segmentation_like".into(),
            n: 2310,
            p: 19,
            k: 7,
            kernel: Kernel::paper_poly2(),
            method: Method::OnePass,
            rank: 2,
            oversample: 5,
            batch: 256,
            trials: 100,
            seed: 2016,
            backend: Backend::Native,
            kmeans_restarts: 10,
            kmeans_iters: 20,
            threads: 1,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl ExperimentConfig {
    /// Table 1 / Fig. 1–2 defaults (synthetic two-rings protocol).
    pub fn table1() -> Self {
        ExperimentConfig {
            dataset: "cross_lines".into(),
            n: 4000,
            p: 2,
            k: 2,
            oversample: 10,
            ..Default::default()
        }
    }

    /// r' = r + l, the sketch width.
    pub fn sketch_width(&self) -> usize {
        self.rank + self.oversample
    }

    /// Apply a `key=value` override; unknown keys are an error so typos
    /// fail loudly.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        let uint = |v: &str| v.parse::<usize>().map_err(|e| format!("{key}: {e}"));
        match key {
            "dataset" => self.dataset = value.into(),
            "n" => self.n = uint(value)?,
            "p" => self.p = uint(value)?,
            "k" => self.k = uint(value)?,
            "rank" | "r" => self.rank = uint(value)?,
            "oversample" | "l" => self.oversample = uint(value)?,
            "batch" => self.batch = uint(value)?,
            "trials" => self.trials = uint(value)?,
            "seed" => self.seed = value.parse().map_err(|e| format!("seed: {e}"))?,
            "kmeans_restarts" => self.kmeans_restarts = uint(value)?,
            "kmeans_iters" => self.kmeans_iters = uint(value)?,
            "threads" => self.threads = uint(value)?,
            "artifacts_dir" => self.artifacts_dir = value.into(),
            "method" => {
                self.method =
                    Method::parse(value).ok_or_else(|| format!("unknown method '{value}'"))?;
            }
            "backend" => {
                self.backend = match value {
                    "native" => Backend::Native,
                    "xla" => Backend::Xla,
                    _ => return Err(format!("unknown backend '{value}'")),
                };
            }
            "kernel" => {
                self.kernel = match value {
                    "poly2" => Kernel::paper_poly2(),
                    "linear" => Kernel::Linear,
                    _ if value.starts_with("rbf:") => {
                        let g: f64 = value[4..].parse().map_err(|e| format!("rbf gamma: {e}"))?;
                        Kernel::Rbf { gamma: g }
                    }
                    _ if value.starts_with("poly:") => {
                        let rest = &value[5..];
                        let (g, d) = rest.split_once(':').ok_or("poly:<gamma>:<degree>")?;
                        Kernel::Poly {
                            gamma: g.parse().map_err(|e| format!("poly gamma: {e}"))?,
                            degree: d.parse().map_err(|e| format!("poly degree: {e}"))?,
                        }
                    }
                    _ => return Err(format!("unknown kernel '{value}'")),
                };
            }
            _ => return Err(format!("unknown config key '{key}'")),
        }
        Ok(())
    }

    /// Load overrides from a JSON object file: `{"n": 1000, "r": 2, ...}`.
    pub fn apply_json(&mut self, json: &Json) -> Result<(), String> {
        let Json::Obj(map) = json else {
            return Err("config file must be a JSON object".into());
        };
        for (k, v) in map {
            let as_text = match v {
                Json::Str(s) => s.clone(),
                Json::Num(x) => {
                    if x.fract() == 0.0 {
                        format!("{}", *x as i64)
                    } else {
                        format!("{x}")
                    }
                }
                Json::Bool(b) => format!("{b}"),
                _ => return Err(format!("unsupported value for '{k}'")),
            };
            self.set(k, &as_text)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_protocol() {
        let c = ExperimentConfig::default();
        assert_eq!((c.n, c.p, c.k), (2310, 19, 7));
        assert_eq!(c.rank, 2);
        assert_eq!(c.oversample, 5);
        assert_eq!(c.sketch_width(), 7);
        assert_eq!(c.trials, 100);
        assert_eq!(c.kmeans_restarts, 10);
        assert_eq!(c.kmeans_iters, 20);
        let t = ExperimentConfig::table1();
        assert_eq!((t.n, t.k, t.oversample), (4000, 2, 10));
        assert_eq!(t.dataset, "cross_lines");
        assert_eq!(t.sketch_width(), 12); // "equivalent of m=12 columns"
    }

    #[test]
    fn set_overrides() {
        let mut c = ExperimentConfig::default();
        c.set("method", "nystrom_m50").unwrap();
        assert_eq!(c.method, Method::Nystrom { m: 50 });
        c.set("kernel", "rbf:2.5").unwrap();
        assert_eq!(c.kernel, Kernel::Rbf { gamma: 2.5 });
        c.set("kernel", "poly:1:3").unwrap();
        assert_eq!(c.kernel, Kernel::Poly { gamma: 1.0, degree: 3 });
        c.set("backend", "xla").unwrap();
        assert_eq!(c.backend, Backend::Xla);
        assert!(c.set("nope", "1").is_err());
        assert!(c.set("backend", "gpu").is_err());
        assert!(c.set("n", "abc").is_err());
    }

    #[test]
    fn method_parse_roundtrip() {
        for m in [
            Method::OnePass,
            Method::GaussianOnePass,
            Method::Nystrom { m: 20 },
            Method::Exact,
            Method::FullKernel,
            Method::PlainKmeans,
        ] {
            assert_eq!(Method::parse(&m.name()), Some(m), "{}", m.name());
        }
        assert_eq!(Method::parse("bogus"), None);
    }

    #[test]
    fn json_config_applies() {
        let mut c = ExperimentConfig::default();
        let j = Json::parse(r#"{"n": 512, "method": "exact", "seed": 7}"#).unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.n, 512);
        assert_eq!(c.method, Method::Exact);
        assert_eq!(c.seed, 7);
        let bad = Json::parse(r#"{"wat": 1}"#).unwrap();
        assert!(c.apply_json(&bad).is_err());
    }
}
