//! Experiment configuration and CLI argument parsing.
//!
//! Configs load from JSON files (see `util::json`) and/or `--key value`
//! command-line overrides, so every experiment in EXPERIMENTS.md is
//! reproducible from a single command line. [`Method`] and [`Backend`]
//! implement the standard [`FromStr`]/[`fmt::Display`] pair (round-tripping
//! for every variant), so they parse with plain `"exact".parse()` and
//! print with `{}` like any other Rust type.

mod cli;

pub use cli::{Cli, CliError};

use std::fmt;
use std::str::FromStr;

use crate::error::{Result, RkcError};
use crate::kernels::Kernel;
use crate::util::Json;

/// Default Nyström landmark count for a bare `"nystrom"` method string
/// (the paper's largest sweep point — Table 1's `m = 100` column).
pub const DEFAULT_NYSTROM_M: usize = 100;

/// Which low-rank / clustering method to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// the paper's Alg. 1 (SRHT one-pass)
    OnePass,
    /// one-pass randomized sketch with a dense Gaussian test matrix
    GaussianOnePass,
    /// Nyström with uniform column sampling, parameterized by m
    Nystrom { m: usize },
    /// exact top-r via streamed subspace iteration
    Exact,
    /// full kernel K-means on the materialized kernel (O(n²) baseline)
    FullKernel,
    /// plain K-means on the raw input (no kernel)
    PlainKmeans,
}

impl fmt::Display for Method {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Method::OnePass => write!(f, "one_pass"),
            Method::GaussianOnePass => write!(f, "gaussian_one_pass"),
            Method::Nystrom { m } => write!(f, "nystrom_m{m}"),
            Method::Exact => write!(f, "exact"),
            Method::FullKernel => write!(f, "full_kernel"),
            Method::PlainKmeans => write!(f, "plain_kmeans"),
        }
    }
}

impl FromStr for Method {
    type Err = RkcError;

    /// Accepts every `Display` form plus the historical aliases
    /// (`ours`, `gaussian`, `plain`) and a bare `nystrom`, which gets
    /// [`DEFAULT_NYSTROM_M`] landmarks.
    fn from_str(s: &str) -> Result<Method> {
        match s {
            "one_pass" | "ours" => Ok(Method::OnePass),
            "gaussian" | "gaussian_one_pass" => Ok(Method::GaussianOnePass),
            "exact" => Ok(Method::Exact),
            "full_kernel" => Ok(Method::FullKernel),
            "plain" | "plain_kmeans" => Ok(Method::PlainKmeans),
            "nystrom" => Ok(Method::Nystrom { m: DEFAULT_NYSTROM_M }),
            _ => s
                .strip_prefix("nystrom_m")
                .and_then(|m| m.parse().ok())
                .map(|m| Method::Nystrom { m })
                .ok_or_else(|| RkcError::parse("method", s)),
        }
    }
}

/// Execution backend for the bulk compute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// native rust gram/FWHT (reference; always available)
    Native,
    /// XLA artifacts via PJRT (the production path; requires artifacts/)
    Xla,
}

impl fmt::Display for Backend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Backend::Native => write!(f, "native"),
            Backend::Xla => write!(f, "xla"),
        }
    }
}

impl FromStr for Backend {
    type Err = RkcError;

    fn from_str(s: &str) -> Result<Backend> {
        match s {
            "native" => Ok(Backend::Native),
            "xla" => Ok(Backend::Xla),
            _ => Err(RkcError::parse("backend", s)),
        }
    }
}

/// Serving-side floating-point precision for `embed`/`predict`.
///
/// Fitting always runs in f64; `F32` opts the *serving* gram + embed
/// accumulation into single precision (roughly 2× the SIMD lane width),
/// justified by the paper's own error analysis: the low-rank
/// approximation error dwarfs f32 rounding. The f64↔f32 deviation is
/// measured and reported as `f32_max_abs_dev` in the serve BENCH rows.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// double precision everywhere (the default; bit-exact contracts)
    #[default]
    F64,
    /// single-precision serving gram/embed (opt-in, fit stays f64)
    F32,
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Precision::F64 => write!(f, "f64"),
            Precision::F32 => write!(f, "f32"),
        }
    }
}

impl FromStr for Precision {
    type Err = RkcError;

    fn from_str(s: &str) -> Result<Precision> {
        match s {
            "f64" | "double" => Ok(Precision::F64),
            "f32" | "single" => Ok(Precision::F32),
            _ => Err(RkcError::parse("precision", s)),
        }
    }
}

/// A full experiment specification.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub dataset: String,
    pub n: usize,
    pub p: usize,
    pub k: usize,
    pub kernel: Kernel,
    pub method: Method,
    pub rank: usize,
    pub oversample: usize,
    pub batch: usize,
    pub trials: usize,
    pub seed: u64,
    pub backend: Backend,
    pub kmeans_restarts: usize,
    pub kmeans_iters: usize,
    /// relative objective-improvement tolerance for K-means early
    /// stopping (the paper protocol's effectively-exact `1e-9`)
    pub kmeans_tol: f64,
    /// worker threads for the parallel execution subsystem; `0` means
    /// auto-detect via `std::thread::available_parallelism`
    pub threads: usize,
    pub artifacts_dir: String,
    /// root directory for on-disk datasets (e.g. `segmentation.csv`);
    /// CSV dataset names resolve relative to it when not found as given
    pub data_dir: String,
    /// where `save` writes and `predict`/`serve` read the fitted model;
    /// empty means the artifacts-dir-driven default
    /// (see [`resolved_model_path`](ExperimentConfig::resolved_model_path))
    pub model_path: String,
    /// listen address for the `serve` subcommand's HTTP front-end
    pub serve_addr: String,
    /// directory of `.rkc` files the `serve` subcommand loads into its
    /// model registry (name = file stem); empty means single-model
    /// serving from [`model_path`](ExperimentConfig::model_path)
    pub models_dir: String,
    /// HTTP front-end pool workers (= concurrent connections); `0`
    /// means auto-detect from the hardware
    pub http_workers: usize,
    /// idle seconds a keep-alive connection may sit between requests
    /// before the server closes it; `0` disables keep-alive (every
    /// response closes its connection)
    pub keep_alive_s: u64,
    /// points per ingest chunk for the `stream` subcommand
    pub chunk: usize,
    /// refresh the streaming model every this many ingested points;
    /// `0` disables the point trigger
    pub refresh_points: usize,
    /// refresh the streaming model at least every this many seconds;
    /// `0` disables the time trigger
    pub refresh_secs: f64,
    /// drift scenario for the `stream` subcommand's synthetic source
    /// (`"moving_blobs"` or `"label_churn"`); empty means a stationary
    /// source built from `dataset`
    pub scenario: String,
    /// per-chunk drift magnitude for the synthetic scenarios (center
    /// step for `moving_blobs`, phase advance for `label_churn`)
    pub drift: f64,
    /// serve each published streaming generation over HTTP (the
    /// `stream` subcommand starts the registry front-end on
    /// [`serve_addr`](ExperimentConfig::serve_addr))
    pub stream_http: bool,
    /// `.rkcs` checkpoint file for the `stream` subcommand; empty
    /// disables checkpointing. When the file already exists at startup,
    /// `stream` resumes from it instead of starting cold, so a crashed
    /// (or `kill -9`ed) run continues where its last checkpoint left off
    pub checkpoint_path: String,
    /// checkpoint the streaming state every this many ingested points;
    /// `0` leaves only the refresh-driven checkpoints
    pub checkpoint_points: usize,
    /// checkpoint the streaming state at least every this many seconds;
    /// `0` disables the time trigger
    pub checkpoint_secs: f64,
    /// serving-side precision for `embed`/`predict`. Unset (default)
    /// fits in f64 and lets serving respect each model's own persisted
    /// precision header; an explicit `f64`/`f32` forces that precision
    /// on every served model — so `precision f64` can restore double
    /// precision over a model saved with `f32`, which a plain default
    /// could not express.
    pub precision: Option<Precision>,
    /// `.plan` file the `experiment` subcommand runs (grid or load
    /// kind; see [`crate::experiment::Plan`])
    pub plan_path: String,
    /// where the `experiment` subcommand writes its JSONL report;
    /// empty means `exp_<plan stem>.jsonl` in the working directory
    pub out_path: String,
}

impl Default for ExperimentConfig {
    /// Fig. 3 defaults (the paper's real-data protocol).
    fn default() -> Self {
        ExperimentConfig {
            dataset: "segmentation_like".into(),
            n: 2310,
            p: 19,
            k: 7,
            kernel: Kernel::paper_poly2(),
            method: Method::OnePass,
            rank: 2,
            oversample: 5,
            batch: 256,
            trials: 100,
            seed: 2016,
            backend: Backend::Native,
            kmeans_restarts: 10,
            kmeans_iters: 20,
            kmeans_tol: 1e-9,
            threads: 1,
            artifacts_dir: "artifacts".into(),
            data_dir: "data".into(),
            model_path: String::new(),
            serve_addr: "127.0.0.1:7878".into(),
            models_dir: String::new(),
            http_workers: 0,
            keep_alive_s: 5,
            chunk: 200,
            refresh_points: 1000,
            refresh_secs: 0.0,
            scenario: String::new(),
            drift: 0.05,
            stream_http: false,
            checkpoint_path: String::new(),
            checkpoint_points: 0,
            checkpoint_secs: 0.0,
            precision: None,
            plan_path: String::new(),
            out_path: String::new(),
        }
    }
}

impl ExperimentConfig {
    /// Table 1 / Fig. 1–2 defaults (synthetic two-rings protocol).
    pub fn table1() -> Self {
        ExperimentConfig {
            dataset: "cross_lines".into(),
            n: 4000,
            p: 2,
            k: 2,
            oversample: 10,
            ..Default::default()
        }
    }

    /// r' = r + l, the sketch width.
    pub fn sketch_width(&self) -> usize {
        self.rank + self.oversample
    }

    /// The model file the `save`/`predict`/`serve` subcommands use: the
    /// explicit `model` override when given, else `model.rkc` inside
    /// [`artifacts_dir`](ExperimentConfig::artifacts_dir) (the fit
    /// artifacts live next to the compiled compute artifacts). A
    /// directory-style override (trailing `/`, or an existing directory)
    /// resolves to `model.rkc` inside it, so the same `--model` value
    /// works identically for `save` and for `predict`/`serve`.
    pub fn resolved_model_path(&self) -> String {
        if self.model_path.is_empty() {
            // artifacts_dir is a directory by definition (the trailing
            // slash tells the shared rule so, without it having to exist
            // yet)
            let dir = format!("{}/", self.artifacts_dir.trim_end_matches('/'));
            return crate::model_io::resolve_model_target(&dir);
        }
        crate::model_io::resolve_model_target(&self.model_path)
    }

    /// Apply a `key=value` override; unknown keys are an error so typos
    /// fail loudly.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let uint = |what: &'static str, v: &str| {
            v.parse::<usize>().map_err(|_| RkcError::parse(what, v))
        };
        match key {
            "dataset" => self.dataset = value.into(),
            "n" => self.n = uint("n", value)?,
            "p" => self.p = uint("p", value)?,
            "k" => self.k = uint("k", value)?,
            "rank" | "r" => self.rank = uint("rank", value)?,
            "oversample" | "l" => self.oversample = uint("oversample", value)?,
            "batch" => self.batch = uint("batch", value)?,
            "trials" => self.trials = uint("trials", value)?,
            "seed" => {
                self.seed = value.parse().map_err(|_| RkcError::parse("seed", value))?;
            }
            "kmeans_restarts" => self.kmeans_restarts = uint("kmeans_restarts", value)?,
            "kmeans_iters" => self.kmeans_iters = uint("kmeans_iters", value)?,
            "kmeans_tol" => {
                self.kmeans_tol =
                    value.parse().map_err(|_| RkcError::parse("kmeans_tol", value))?;
            }
            "threads" => self.threads = uint("threads", value)?,
            "artifacts_dir" => self.artifacts_dir = value.into(),
            "data_dir" => self.data_dir = value.into(),
            "model" | "model_path" => self.model_path = value.into(),
            "addr" | "serve_addr" => self.serve_addr = value.into(),
            "models" | "models_dir" => self.models_dir = value.into(),
            "http_workers" => self.http_workers = uint("http_workers", value)?,
            "keep_alive" | "keep_alive_s" => {
                self.keep_alive_s =
                    value.parse().map_err(|_| RkcError::parse("keep_alive_s", value))?;
            }
            "chunk" => self.chunk = uint("chunk", value)?,
            "refresh_points" => self.refresh_points = uint("refresh_points", value)?,
            "refresh_secs" => {
                // non-finite or negative seconds would panic later in
                // Duration::from_secs_f64 — reject at the parse boundary
                self.refresh_secs = value
                    .parse()
                    .ok()
                    .filter(|s: &f64| s.is_finite() && *s >= 0.0)
                    .ok_or_else(|| RkcError::parse("refresh_secs", value))?;
            }
            "scenario" => self.scenario = value.into(),
            "drift" => {
                self.drift = value.parse().map_err(|_| RkcError::parse("drift", value))?;
            }
            "stream_http" => {
                self.stream_http =
                    value.parse().map_err(|_| RkcError::parse("stream_http", value))?;
            }
            "checkpoint" | "checkpoint_path" => self.checkpoint_path = value.into(),
            "checkpoint_points" => {
                self.checkpoint_points = uint("checkpoint_points", value)?;
            }
            "checkpoint_secs" => {
                // same panic-free domain rule as refresh_secs
                self.checkpoint_secs = value
                    .parse()
                    .ok()
                    .filter(|s: &f64| s.is_finite() && *s >= 0.0)
                    .ok_or_else(|| RkcError::parse("checkpoint_secs", value))?;
            }
            "precision" => self.precision = Some(value.parse()?),
            "plan" | "plan_path" => self.plan_path = value.into(),
            "out" | "out_path" => self.out_path = value.into(),
            "method" => self.method = value.parse()?,
            "backend" => self.backend = value.parse()?,
            "kernel" => self.kernel = value.parse()?,
            _ => return Err(RkcError::invalid_config(format!("unknown config key '{key}'"))),
        }
        Ok(())
    }

    /// Load overrides from a JSON object file: `{"n": 1000, "r": 2, ...}`.
    pub fn apply_json(&mut self, json: &Json) -> Result<()> {
        let Json::Obj(map) = json else {
            return Err(RkcError::invalid_config("config file must be a JSON object"));
        };
        for (k, v) in map {
            let as_text = match v {
                Json::Str(s) => s.clone(),
                Json::Num(x) => {
                    if x.fract() == 0.0 {
                        format!("{}", *x as i64)
                    } else {
                        format!("{x}")
                    }
                }
                Json::Bool(b) => format!("{b}"),
                _ => {
                    return Err(RkcError::invalid_config(format!(
                        "unsupported value for '{k}'"
                    )))
                }
            };
            self.set(k, &as_text)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_protocol() {
        let c = ExperimentConfig::default();
        assert_eq!((c.n, c.p, c.k), (2310, 19, 7));
        assert_eq!(c.rank, 2);
        assert_eq!(c.oversample, 5);
        assert_eq!(c.sketch_width(), 7);
        assert_eq!(c.trials, 100);
        assert_eq!(c.kmeans_restarts, 10);
        assert_eq!(c.kmeans_iters, 20);
        assert_eq!(c.kmeans_tol, 1e-9);
        assert_eq!(c.threads, 1);
        assert_eq!(c.data_dir, "data");
        assert_eq!(c.serve_addr, "127.0.0.1:7878");
        assert_eq!(c.models_dir, "");
        assert_eq!(c.http_workers, 0);
        assert_eq!(c.keep_alive_s, 5);
        assert_eq!(c.chunk, 200);
        assert_eq!(c.refresh_points, 1000);
        assert_eq!(c.refresh_secs, 0.0);
        assert_eq!(c.scenario, "");
        assert_eq!(c.drift, 0.05);
        assert!(!c.stream_http);
        assert_eq!(c.checkpoint_path, "");
        assert_eq!(c.checkpoint_points, 0);
        assert_eq!(c.checkpoint_secs, 0.0);
        assert_eq!(c.precision, None);
        assert_eq!(c.plan_path, "");
        assert_eq!(c.out_path, "");
        // artifacts-dir-driven model path when no explicit override
        assert_eq!(c.resolved_model_path(), "artifacts/model.rkc");
        let t = ExperimentConfig::table1();
        assert_eq!((t.n, t.k, t.oversample), (4000, 2, 10));
        assert_eq!(t.dataset, "cross_lines");
        assert_eq!(t.sketch_width(), 12); // "equivalent of m=12 columns"
    }

    #[test]
    fn set_overrides() {
        let mut c = ExperimentConfig::default();
        c.set("method", "nystrom_m50").unwrap();
        assert_eq!(c.method, Method::Nystrom { m: 50 });
        c.set("kernel", "rbf:2.5").unwrap();
        assert_eq!(c.kernel, Kernel::Rbf { gamma: 2.5 });
        c.set("kernel", "poly:1:3").unwrap();
        assert_eq!(c.kernel, Kernel::Poly { gamma: 1.0, degree: 3 });
        c.set("backend", "xla").unwrap();
        assert_eq!(c.backend, Backend::Xla);
        c.set("data_dir", "/tmp/datasets").unwrap();
        assert_eq!(c.data_dir, "/tmp/datasets");
        c.set("kmeans_tol", "1e-6").unwrap();
        assert_eq!(c.kmeans_tol, 1e-6);
        c.set("threads", "0").unwrap(); // 0 = auto-detect
        assert_eq!(c.threads, 0);
        c.set("model", "/tmp/m.rkc").unwrap();
        assert_eq!(c.model_path, "/tmp/m.rkc");
        assert_eq!(c.resolved_model_path(), "/tmp/m.rkc");
        // a directory-style override resolves to model.rkc inside it,
        // matching what save's auto-save would write there
        c.set("model", "models/").unwrap();
        assert_eq!(c.resolved_model_path(), "models/model.rkc");
        c.set("addr", "0.0.0.0:9000").unwrap();
        assert_eq!(c.serve_addr, "0.0.0.0:9000");
        c.set("models", "/tmp/model-fleet").unwrap();
        assert_eq!(c.models_dir, "/tmp/model-fleet");
        c.set("http_workers", "8").unwrap();
        assert_eq!(c.http_workers, 8);
        c.set("keep_alive", "30").unwrap();
        assert_eq!(c.keep_alive_s, 30);
        c.set("keep_alive_s", "0").unwrap(); // 0 = close per request
        assert_eq!(c.keep_alive_s, 0);
        c.set("chunk", "64").unwrap();
        assert_eq!(c.chunk, 64);
        c.set("refresh_points", "0").unwrap(); // 0 = point trigger off
        assert_eq!(c.refresh_points, 0);
        c.set("refresh_secs", "2.5").unwrap();
        assert_eq!(c.refresh_secs, 2.5);
        c.set("scenario", "label_churn").unwrap();
        assert_eq!(c.scenario, "label_churn");
        c.set("plan", "plans/smoke.plan").unwrap();
        assert_eq!(c.plan_path, "plans/smoke.plan");
        c.set("out", "results.jsonl").unwrap();
        assert_eq!(c.out_path, "results.jsonl");
        c.set("drift", "0.3").unwrap();
        assert_eq!(c.drift, 0.3);
        c.set("stream_http", "true").unwrap();
        assert!(c.stream_http);
        c.set("checkpoint", "/tmp/state.rkcs").unwrap();
        assert_eq!(c.checkpoint_path, "/tmp/state.rkcs");
        c.set("checkpoint_points", "500").unwrap();
        assert_eq!(c.checkpoint_points, 500);
        c.set("checkpoint_secs", "1.5").unwrap();
        assert_eq!(c.checkpoint_secs, 1.5);
        c.set("precision", "f32").unwrap();
        assert_eq!(c.precision, Some(Precision::F32));
        // explicit f64 is distinct from unset: it *forces* f64 serving
        c.set("precision", "double").unwrap();
        assert_eq!(c.precision, Some(Precision::F64));
        assert!(c.set("precision", "f16").is_err());
        assert!(c.set("checkpoint_points", "-1").is_err());
        assert!(c.set("checkpoint_secs", "inf").is_err());
        assert!(c.set("checkpoint_secs", "-1").is_err());
        assert!(c.set("stream_http", "yep").is_err());
        assert!(c.set("drift", "lots").is_err());
        assert!(c.set("refresh_secs", "inf").is_err());
        assert!(c.set("refresh_secs", "NaN").is_err());
        assert!(c.set("refresh_secs", "-1").is_err());
        assert!(c.set("keep_alive", "forever").is_err());
        assert!(c.set("http_workers", "-1").is_err());
        assert!(c.set("kmeans_tol", "tiny").is_err());
        assert!(c.set("nope", "1").is_err());
        assert!(c.set("backend", "gpu").is_err());
        assert!(c.set("n", "abc").is_err());
    }

    #[test]
    fn method_display_fromstr_roundtrip() {
        for m in [
            Method::OnePass,
            Method::GaussianOnePass,
            Method::Nystrom { m: 20 },
            Method::Exact,
            Method::FullKernel,
            Method::PlainKmeans,
        ] {
            assert_eq!(m.to_string().parse::<Method>().unwrap(), m, "{m}");
        }
        assert!("bogus".parse::<Method>().is_err());
    }

    #[test]
    fn bare_nystrom_gets_default_m() {
        assert_eq!(
            "nystrom".parse::<Method>().unwrap(),
            Method::Nystrom { m: DEFAULT_NYSTROM_M }
        );
        assert!("nystrom_m".parse::<Method>().is_err());
        assert!("nystrom_mNaN".parse::<Method>().is_err());
    }

    #[test]
    fn backend_display_fromstr_roundtrip() {
        for b in [Backend::Native, Backend::Xla] {
            assert_eq!(b.to_string().parse::<Backend>().unwrap(), b);
        }
        assert!("gpu".parse::<Backend>().is_err());
    }

    #[test]
    fn precision_display_fromstr_roundtrip() {
        for p in [Precision::F64, Precision::F32] {
            assert_eq!(p.to_string().parse::<Precision>().unwrap(), p);
        }
        assert_eq!(Precision::default(), Precision::F64);
        assert!("f128".parse::<Precision>().is_err());
    }

    #[test]
    fn json_config_applies() {
        let mut c = ExperimentConfig::default();
        let j = Json::parse(r#"{"n": 512, "method": "exact", "seed": 7}"#).unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.n, 512);
        assert_eq!(c.method, Method::Exact);
        assert_eq!(c.seed, 7);
        let bad = Json::parse(r#"{"wat": 1}"#).unwrap();
        assert!(c.apply_json(&bad).is_err());
    }
}
