//! Tiny CLI argument parser (clap is unavailable offline; this is the
//! substrate replacement). Grammar:
//!
//! ```text
//! rkc <subcommand> [--key value]... [--flag]... [positional]...
//! ```

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cli error: {}", self.0)
    }
}

impl std::error::Error for CliError {}

impl From<CliError> for crate::error::RkcError {
    fn from(e: CliError) -> Self {
        crate::error::RkcError::InvalidConfig(e.0)
    }
}

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Cli {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Cli {
    /// Parse from raw args (excluding argv[0]). `known_flags` lists
    /// boolean options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        args: I,
        known_flags: &[&str],
    ) -> Result<Cli, CliError> {
        let mut it = args.into_iter().peekable();
        let mut cli = Cli {
            subcommand: None,
            options: BTreeMap::new(),
            flags: Vec::new(),
            positional: Vec::new(),
        };
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                cli.subcommand = it.next();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    // `--` terminator: the rest is positional
                    cli.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = name.split_once('=') {
                    cli.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&name) {
                    cli.flags.push(name.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| CliError(format!("--{name} expects a value")))?;
                    cli.options.insert(name.to_string(), v);
                }
            } else if arg.starts_with('-') && arg.len() > 1 {
                return Err(CliError(format!("unknown short option '{arg}'")));
            } else {
                cli.positional.push(arg);
            }
        }
        Ok(cli)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| CliError(format!("--{name}: {e}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Cli {
        Cli::parse(args.iter().map(|s| s.to_string()), &["verbose", "csv"]).unwrap()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let c = parse(&["fig3", "--trials", "10", "--verbose", "--method=exact", "out.csv"]);
        assert_eq!(c.subcommand.as_deref(), Some("fig3"));
        assert_eq!(c.get("trials"), Some("10"));
        assert_eq!(c.get("method"), Some("exact"));
        assert!(c.has_flag("verbose"));
        assert_eq!(c.positional, vec!["out.csv"]);
    }

    #[test]
    fn no_subcommand_when_leading_dash() {
        let c = parse(&["--trials", "5"]);
        assert_eq!(c.subcommand, None);
        assert_eq!(c.get_usize("trials").unwrap(), Some(5));
    }

    #[test]
    fn double_dash_terminator() {
        let c = parse(&["run", "--", "--not-an-option"]);
        assert_eq!(c.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn missing_value_is_error() {
        let r = Cli::parse(["cmd".to_string(), "--n".to_string()], &[]);
        assert!(r.is_err());
    }

    #[test]
    fn bad_usize_is_error() {
        let c = parse(&["x", "--trials", "ten"]);
        assert!(c.get_usize("trials").is_err());
    }
}
