//! Grid expansion and the deterministic trial runner.
//!
//! [`expand`] turns a [`GridPlan`] into the full cartesian product of
//! its axes (a fixed nesting order, so trial indices are stable), and
//! [`trial_seed`] derives every trial's RNG seed purely from the plan
//! seed and the trial's coordinates — NOT from its position in the
//! expansion or the thread that happens to run it. That is the whole
//! determinism argument: reordering axis values, re-running, or raising
//! the runner's parallelism cannot change any trial's inputs, so the
//! emitted JSONL is byte-identical (with `timings false`) across all of
//! them. `rust/tests/experiment_golden.rs` pins this end to end.

use std::collections::BTreeMap;

use crate::config::{ExperimentConfig, Method};
use crate::coordinator::{build_dataset, run_experiment, RunOutcome};
use crate::data::Dataset;
use crate::error::Result;
use crate::kernels::Kernel;
use crate::util::parallel::{map_indexed, resolve_threads};
use crate::util::Json;

use super::plan::GridPlan;
use super::PlanReport;

/// One fully-specified grid point: every axis pinned plus the derived
/// per-trial seed.
#[derive(Clone, Debug, PartialEq)]
pub struct Trial {
    /// position in the expansion (row order in the JSONL)
    pub index: usize,
    pub dataset: String,
    pub n: usize,
    pub method: Method,
    pub kernel: Kernel,
    pub rank: usize,
    pub oversample: usize,
    pub threads: usize,
    pub repeat: usize,
    /// derived via [`trial_seed`] — a pure function of the coordinates
    pub seed: u64,
}

/// Derive a trial's seed from the plan seed and its coordinates by
/// hashing their canonical spec strings (FNV-1a 64, the same checksum
/// the `.rkc` model format trails with). Coordinates, not positions:
/// permuting an axis's value order moves a trial in the expansion but
/// never changes its seed.
#[allow(clippy::too_many_arguments)]
pub fn trial_seed(
    plan_seed: u64,
    dataset: &str,
    n: usize,
    method: Method,
    kernel: Kernel,
    rank: usize,
    oversample: usize,
    threads: usize,
    repeat: usize,
) -> u64 {
    let coords = format!(
        "{plan_seed}|{dataset}|{n}|{method}|{kernel}|{rank}|{oversample}|{threads}|{repeat}"
    );
    crate::model_io::checksum(coords.as_bytes())
}

/// Expand the grid in its fixed nesting order (dataset → n → method →
/// kernel → rank → oversample → threads → repeat). The trial count is
/// exactly the product of the axis lengths times `repeats`.
pub fn expand(plan: &GridPlan) -> Vec<Trial> {
    let mut trials = Vec::new();
    for dataset in &plan.datasets {
        for &n in &plan.ns {
            for &method in &plan.methods {
                for &kernel in &plan.kernels {
                    for &rank in &plan.ranks {
                        for &oversample in &plan.oversamples {
                            for &threads in &plan.threads {
                                for repeat in 0..plan.repeats {
                                    let seed = trial_seed(
                                        plan.seed, dataset, n, method, kernel, rank, oversample,
                                        threads, repeat,
                                    );
                                    trials.push(Trial {
                                        index: trials.len(),
                                        dataset: dataset.clone(),
                                        n,
                                        method,
                                        kernel,
                                        rank,
                                        oversample,
                                        threads,
                                        repeat,
                                        seed,
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    trials
}

/// Run every trial of the grid through [`run_experiment`] (the
/// `rkc::api` fit + metrics path) and assemble the JSONL report.
///
/// `runner_threads` only sets how many trials run concurrently
/// ([`map_indexed`] keeps results in trial-index order); it never
/// enters any trial's computation, which is why `threads=1` and
/// `threads=N` runners emit identical bytes. Datasets are built once
/// per `(dataset, n)` key, sequentially, before the fan-out.
pub fn run_grid(plan: &GridPlan, plan_hash: u64, runner_threads: usize) -> Result<PlanReport> {
    let trials = expand(plan);
    let mut datasets: BTreeMap<(String, usize), Dataset> = BTreeMap::new();
    for t in &trials {
        let key = (t.dataset.clone(), t.n);
        if let std::collections::btree_map::Entry::Vacant(e) = datasets.entry(key) {
            e.insert(build_dataset(&trial_config(plan, t))?);
        }
    }

    let workers = resolve_threads(runner_threads);
    let outcomes = map_indexed(trials.len(), workers, |i| {
        let t = &trials[i];
        let ds = &datasets[&(t.dataset.clone(), t.n)];
        run_experiment(&trial_config(plan, t), ds, None, t.seed)
    });

    let mut jsonl = String::new();
    jsonl.push_str(&super::header_json("grid", plan_hash, trials.len(), plan.timings).to_string());
    jsonl.push('\n');
    for (t, outcome) in trials.iter().zip(outcomes) {
        let k = datasets[&(t.dataset.clone(), t.n)].k;
        jsonl.push_str(&trial_json(plan, t, k, &outcome?).to_string());
        jsonl.push('\n');
    }
    Ok(PlanReport { kind: "grid", plan_hash, rows: trials.len(), jsonl })
}

/// The [`ExperimentConfig`] a trial hands to the fit path — plan
/// scalars plus this trial's coordinates. The per-trial seed is passed
/// to [`run_experiment`] separately; `cfg.seed` only drives dataset
/// construction, which stays at the plan seed so every trial on the
/// same `(dataset, n)` key sees the same points.
fn trial_config(plan: &GridPlan, t: &Trial) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.dataset = t.dataset.clone();
    cfg.n = t.n;
    cfg.p = plan.p;
    cfg.k = plan.k;
    cfg.method = t.method;
    cfg.kernel = t.kernel;
    cfg.rank = t.rank;
    cfg.oversample = t.oversample;
    cfg.batch = plan.batch;
    cfg.trials = 1;
    cfg.seed = plan.seed;
    cfg.kmeans_restarts = plan.kmeans_restarts;
    cfg.kmeans_iters = plan.kmeans_iters;
    cfg.threads = t.threads;
    cfg
}

/// One schema-stable JSONL row. `Json::Obj` is a `BTreeMap`, so key
/// order is sorted and stable; u64 seeds are emitted as 16-hex strings
/// (f64-backed JSON numbers cannot hold them exactly); non-finite
/// metrics (e.g. `approx_error` for `plain_kmeans`) become `null`.
fn trial_json(plan: &GridPlan, t: &Trial, k: usize, out: &RunOutcome) -> Json {
    let mut fields = BTreeMap::from([
        ("row".to_string(), Json::Str("trial".to_string())),
        ("trial".to_string(), Json::Num(t.index as f64)),
        ("repeat".to_string(), Json::Num(t.repeat as f64)),
        ("dataset".to_string(), Json::Str(t.dataset.clone())),
        ("n".to_string(), Json::Num(t.n as f64)),
        ("k".to_string(), Json::Num(k as f64)),
        ("method".to_string(), Json::Str(t.method.to_string())),
        ("kernel".to_string(), Json::Str(t.kernel.to_string())),
        ("rank".to_string(), Json::Num(t.rank as f64)),
        ("oversample".to_string(), Json::Num(t.oversample as f64)),
        ("threads".to_string(), Json::Num(t.threads as f64)),
        ("batch".to_string(), Json::Num(plan.batch as f64)),
        ("seed".to_string(), Json::Str(format!("{:016x}", t.seed))),
        ("accuracy".to_string(), Json::finite_num(out.accuracy)),
        ("ari".to_string(), Json::finite_num(out.ari)),
        ("nmi".to_string(), Json::finite_num(out.nmi)),
        ("approx_error".to_string(), Json::finite_num(out.approx_error)),
        ("objective".to_string(), Json::finite_num(out.kmeans_objective)),
        ("peak_bytes".to_string(), Json::Num(out.memory.peak() as f64)),
        ("persistent_bytes".to_string(), Json::Num(out.memory.persistent as f64)),
    ]);
    if plan.timings {
        let stages = [
            ("sketch_s", out.sketch_time),
            ("recovery_s", out.recovery_time),
            ("kmeans_s", out.kmeans_time),
            ("error_s", out.error_time),
        ];
        for (key, d) in stages {
            fields.insert(key.to_string(), Json::finite_num(d.as_secs_f64()));
        }
    }
    Json::Obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_plan() -> GridPlan {
        GridPlan {
            seed: 5,
            ns: vec![96, 128],
            methods: vec![Method::OnePass, Method::PlainKmeans],
            oversamples: vec![4, 6],
            repeats: 2,
            ..GridPlan::default()
        }
    }

    #[test]
    fn expansion_is_the_axis_product_in_index_order() {
        let plan = tiny_plan();
        let trials = expand(&plan);
        assert_eq!(trials.len(), 2 * 2 * 2 * 2);
        for (i, t) in trials.iter().enumerate() {
            assert_eq!(t.index, i);
        }
        // innermost axis varies fastest
        assert_eq!(trials[0].repeat, 0);
        assert_eq!(trials[1].repeat, 1);
        assert_eq!(trials[0].oversample, trials[1].oversample);
    }

    #[test]
    fn trial_seed_depends_on_coordinates_not_position() {
        let a = tiny_plan();
        let mut b = tiny_plan();
        b.ns.reverse();
        b.methods.reverse();
        b.oversamples.reverse();
        let key = |t: &Trial| (t.n, t.method.to_string(), t.oversample, t.repeat);
        let seeds_a: BTreeMap<_, _> = expand(&a).iter().map(|t| (key(t), t.seed)).collect();
        for t in expand(&b) {
            assert_eq!(seeds_a[&key(&t)], t.seed);
        }
    }
}
