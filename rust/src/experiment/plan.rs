//! The declarative `.plan` format: a line-based `key value` file (the
//! same zero-dependency shape as the CLI config parser) describing
//! either a **grid** of clustering trials or a list of **load
//! scenarios** to replay against a live serve registry.
//!
//! Grammar: one `key value` pair per line, split on the first
//! whitespace; blank lines and `#` comments are ignored; axis-valued
//! keys (grid `dataset`/`n`/`method`/`kernel`/`rank`/`oversample`/
//! `threads`) take comma-separated lists. The mandatory `kind` line
//! (`grid` or `load`) selects the schema. Parsing is strict — unknown
//! keys, duplicate keys, empty axis entries, and malformed values are
//! typed [`RkcError`]s, never panics — and [`fmt::Display`] emits a
//! canonical form that parses back to an equal plan (the round-trip
//! property `rust/tests/properties.rs` pins).

use std::collections::BTreeSet;
use std::fmt;
use std::str::FromStr;

use crate::config::Method;
use crate::error::{Result, RkcError};
use crate::kernels::Kernel;

/// A parsed plan file: the experiment grid or the load-scenario list.
#[derive(Clone, Debug, PartialEq)]
pub enum Plan {
    Grid(GridPlan),
    Load(LoadPlan),
}

impl Plan {
    /// Parse a plan file's text. The `kind` line decides the schema.
    pub fn parse(text: &str) -> Result<Plan> {
        let mut pairs: Vec<(String, String)> = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((key, value)) = line.split_once(char::is_whitespace) else {
                return Err(RkcError::invalid_config(format!(
                    "plan line {}: expected 'key value', got '{line}'",
                    lineno + 1
                )));
            };
            pairs.push((key.to_string(), value.trim().to_string()));
        }
        let kind = pairs
            .iter()
            .find(|(k, _)| k == "kind")
            .map(|(_, v)| v.clone())
            .ok_or_else(|| {
                RkcError::invalid_config("plan is missing its 'kind' line (grid or load)")
            })?;
        match kind.as_str() {
            "grid" => Ok(Plan::Grid(GridPlan::from_pairs(&pairs)?)),
            "load" => Ok(Plan::Load(LoadPlan::from_pairs(&pairs)?)),
            other => Err(RkcError::parse("plan kind", other)),
        }
    }
}

impl fmt::Display for Plan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Plan::Grid(p) => p.fmt(f),
            Plan::Load(p) => p.fmt(f),
        }
    }
}

/// A grid of clustering trials: the cartesian product of the axis
/// fields (`datasets × ns × methods × kernels × ranks × oversamples ×
/// threads`) times `repeats`, every trial seeded purely from its
/// coordinates (see [`super::trial_seed`]).
#[derive(Clone, Debug, PartialEq)]
pub struct GridPlan {
    /// root seed; every trial seed is derived from it + the trial's
    /// coordinates, so the plan text fully determines every RNG stream
    pub seed: u64,
    /// axis: dataset names ([`crate::coordinator::build_dataset`] vocabulary)
    pub datasets: Vec<String>,
    /// axis: dataset sizes
    pub ns: Vec<usize>,
    /// input dimension (synthetic generators that honor it)
    pub p: usize,
    /// cluster count handed to the fit (generators may override)
    pub k: usize,
    /// axis: clustering methods
    pub methods: Vec<Method>,
    /// axis: kernels
    pub kernels: Vec<Kernel>,
    /// axis: recovery ranks r
    pub ranks: Vec<usize>,
    /// axis: sketch oversampling (sketch size d = r + oversample)
    pub oversamples: Vec<usize>,
    /// axis: worker threads per trial (`0` = auto)
    pub threads: Vec<usize>,
    /// sketch pass batch size
    pub batch: usize,
    /// repeats per grid point (distinct seeds)
    pub repeats: usize,
    pub kmeans_restarts: usize,
    pub kmeans_iters: usize,
    /// emit per-stage wall times in the JSONL rows. `false` keeps the
    /// output byte-identical across reruns — the golden-determinism
    /// mode the committed smoke plan uses.
    pub timings: bool,
}

impl Default for GridPlan {
    fn default() -> Self {
        GridPlan {
            seed: 2016,
            datasets: vec!["cross_lines".to_string()],
            ns: vec![256],
            p: 2,
            k: 2,
            methods: vec![Method::OnePass],
            kernels: vec![Kernel::paper_poly2()],
            ranks: vec![2],
            oversamples: vec![8],
            threads: vec![1],
            batch: 64,
            repeats: 1,
            kmeans_restarts: 5,
            kmeans_iters: 20,
            timings: true,
        }
    }
}

impl GridPlan {
    fn from_pairs(pairs: &[(String, String)]) -> Result<GridPlan> {
        let mut plan = GridPlan::default();
        let mut seen = BTreeSet::new();
        for (key, value) in pairs {
            if !seen.insert(key.clone()) {
                return Err(RkcError::invalid_config(format!("duplicate plan key '{key}'")));
            }
            match key.as_str() {
                "kind" => {}
                "seed" => plan.seed = scalar("seed", value)?,
                "dataset" => plan.datasets = axis("dataset", value, |s| Ok(s.to_string()))?,
                "n" => plan.ns = axis("n", value, |s| scalar("n", s))?,
                "p" => plan.p = scalar("p", value)?,
                "k" => plan.k = scalar("k", value)?,
                "method" => plan.methods = axis("method", value, Method::from_str)?,
                "kernel" => plan.kernels = axis("kernel", value, Kernel::from_str)?,
                "rank" => plan.ranks = axis("rank", value, |s| scalar("rank", s))?,
                "oversample" => {
                    plan.oversamples = axis("oversample", value, |s| scalar("oversample", s))?
                }
                "threads" => plan.threads = axis("threads", value, |s| scalar("threads", s))?,
                "batch" => plan.batch = scalar("batch", value)?,
                "repeats" => plan.repeats = scalar("repeats", value)?,
                "kmeans_restarts" => plan.kmeans_restarts = scalar("kmeans_restarts", value)?,
                "kmeans_iters" => plan.kmeans_iters = scalar("kmeans_iters", value)?,
                "timings" => {
                    plan.timings =
                        value.parse().map_err(|_| RkcError::parse("timings", value.clone()))?
                }
                other => {
                    return Err(RkcError::invalid_config(format!(
                        "unknown grid-plan key '{other}'"
                    )))
                }
            }
        }
        plan.validate()?;
        Ok(plan)
    }

    fn validate(&self) -> Result<()> {
        for (name, v) in [
            ("p", self.p),
            ("k", self.k),
            ("batch", self.batch),
            ("repeats", self.repeats),
            ("kmeans_restarts", self.kmeans_restarts),
            ("kmeans_iters", self.kmeans_iters),
        ] {
            if v == 0 {
                return Err(RkcError::invalid_config(format!("plan {name} must be >= 1")));
            }
        }
        if self.ns.iter().any(|&n| n < 8) {
            return Err(RkcError::invalid_config("plan n axis values must be >= 8"));
        }
        if self.ranks.contains(&0) || self.oversamples.contains(&0) {
            return Err(RkcError::invalid_config(
                "plan rank/oversample axis values must be >= 1",
            ));
        }
        // duplicate axis values would collapse coordinate tuples onto
        // the same derived seed — the uniqueness property forbids that
        no_axis_duplicates("dataset", &self.datasets)?;
        no_axis_duplicates("n", &self.ns)?;
        no_axis_duplicates("method", &self.methods)?;
        no_axis_duplicates("kernel", &self.kernels)?;
        no_axis_duplicates("rank", &self.ranks)?;
        no_axis_duplicates("oversample", &self.oversamples)?;
        no_axis_duplicates("threads", &self.threads)?;
        Ok(())
    }
}

impl fmt::Display for GridPlan {
    /// Canonical form: every key, fixed order, axes comma-joined.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "kind grid")?;
        writeln!(f, "seed {}", self.seed)?;
        writeln!(f, "dataset {}", self.datasets.join(","))?;
        writeln!(f, "n {}", join_csv(&self.ns))?;
        writeln!(f, "p {}", self.p)?;
        writeln!(f, "k {}", self.k)?;
        writeln!(f, "method {}", join_csv(&self.methods))?;
        writeln!(f, "kernel {}", join_csv(&self.kernels))?;
        writeln!(f, "rank {}", join_csv(&self.ranks))?;
        writeln!(f, "oversample {}", join_csv(&self.oversamples))?;
        writeln!(f, "threads {}", join_csv(&self.threads))?;
        writeln!(f, "batch {}", self.batch)?;
        writeln!(f, "repeats {}", self.repeats)?;
        writeln!(f, "kmeans_restarts {}", self.kmeans_restarts)?;
        writeln!(f, "kmeans_iters {}", self.kmeans_iters)?;
        write!(f, "timings {}", self.timings)
    }
}

/// Traffic shape a load scenario replays against the live front-end.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScenarioMode {
    /// paced request stream (`rate` req/s across all clients; `0`
    /// means unpaced), honoring `keep_alive`
    OpenLoop,
    /// every client connects at once BEFORE any request is sent —
    /// exercises the bounded connection queue and its shed 503s
    Burst,
    /// sends half a request head and then nothing — must be cut by the
    /// server's request deadline with a 408
    SlowLoris,
    /// promises a Content-Length then disconnects mid-body; each
    /// aborted write is followed by a fresh-connection good request to
    /// prove the poison stayed on its own connection
    PartialWrite,
}

impl fmt::Display for ScenarioMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScenarioMode::OpenLoop => write!(f, "open_loop"),
            ScenarioMode::Burst => write!(f, "burst"),
            ScenarioMode::SlowLoris => write!(f, "slow_loris"),
            ScenarioMode::PartialWrite => write!(f, "partial_write"),
        }
    }
}

impl FromStr for ScenarioMode {
    type Err = RkcError;

    fn from_str(s: &str) -> Result<ScenarioMode> {
        match s {
            "open_loop" => Ok(ScenarioMode::OpenLoop),
            "burst" => Ok(ScenarioMode::Burst),
            "slow_loris" => Ok(ScenarioMode::SlowLoris),
            "partial_write" => Ok(ScenarioMode::PartialWrite),
            _ => Err(RkcError::parse("scenario mode", s)),
        }
    }
}

/// One `scenario` line of a load plan.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub mode: ScenarioMode,
    /// concurrent client threads
    pub clients: usize,
    /// requests per client
    pub requests: usize,
    /// aggregate open-loop arrival rate in req/s (`0` = unpaced)
    pub rate_hz: f64,
    /// reuse one connection per client (`false` = close per request)
    pub keep_alive: bool,
}

impl ScenarioSpec {
    /// Parse the value of a `scenario` line:
    /// `<name> mode=<m> [clients=<c>] [requests=<r>] [rate=<hz>] [keep_alive=<bool>]`.
    fn parse(value: &str) -> Result<ScenarioSpec> {
        let mut tokens = value.split_whitespace();
        let name = tokens
            .next()
            .filter(|t| !t.contains('='))
            .ok_or_else(|| {
                RkcError::invalid_config(format!(
                    "scenario line needs a name before its settings: '{value}'"
                ))
            })?
            .to_string();
        let mut mode = None;
        let mut spec = ScenarioSpec {
            name,
            mode: ScenarioMode::OpenLoop,
            clients: 1,
            requests: 1,
            rate_hz: 0.0,
            keep_alive: true,
        };
        let mut seen = BTreeSet::new();
        for tok in tokens {
            let Some((k, v)) = tok.split_once('=') else {
                return Err(RkcError::invalid_config(format!(
                    "scenario setting '{tok}' must be key=value"
                )));
            };
            if !seen.insert(k.to_string()) {
                return Err(RkcError::invalid_config(format!(
                    "duplicate scenario setting '{k}' in '{}'",
                    spec.name
                )));
            }
            match k {
                "mode" => mode = Some(v.parse::<ScenarioMode>()?),
                "clients" => spec.clients = scalar("scenario clients", v)?,
                "requests" => spec.requests = scalar("scenario requests", v)?,
                "rate" => {
                    let r: f64 =
                        v.parse().map_err(|_| RkcError::parse("scenario rate", v.to_string()))?;
                    if !r.is_finite() || r < 0.0 {
                        return Err(RkcError::parse("scenario rate", v.to_string()));
                    }
                    spec.rate_hz = r;
                }
                "keep_alive" => {
                    spec.keep_alive = v
                        .parse()
                        .map_err(|_| RkcError::parse("scenario keep_alive", v.to_string()))?
                }
                other => {
                    return Err(RkcError::invalid_config(format!(
                        "unknown scenario setting '{other}'"
                    )))
                }
            }
        }
        spec.mode = mode.ok_or_else(|| {
            RkcError::invalid_config(format!("scenario '{}' is missing mode=...", spec.name))
        })?;
        if spec.clients == 0 || spec.requests == 0 {
            return Err(RkcError::invalid_config(format!(
                "scenario '{}' clients/requests must be >= 1",
                spec.name
            )));
        }
        Ok(spec)
    }
}

impl fmt::Display for ScenarioSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "scenario {} mode={} clients={} requests={} rate={} keep_alive={}",
            self.name, self.mode, self.clients, self.requests, self.rate_hz, self.keep_alive
        )
    }
}

/// A load plan: a small registry of fitted models served over HTTP plus
/// the scenario list replayed against it, in order.
#[derive(Clone, Debug, PartialEq)]
pub struct LoadPlan {
    /// seeds the fitted models and the shared query batch
    pub seed: u64,
    /// points per fitted model's training set
    pub n: usize,
    /// clusters per fitted model
    pub k: usize,
    /// how many models to fit and serve (`m0`, `m1`, …; scenarios
    /// round-robin across them — the mixed-models shape)
    pub models: usize,
    /// points per predict request body
    pub points: usize,
    /// front-end pool workers (`0` = auto)
    pub workers: usize,
    /// front-end connection-queue bound (beyond it: shed 503)
    pub backlog: usize,
    /// server-side idle keep-alive seconds (`0` = close per request)
    pub keep_alive_s: u64,
    /// server-side request deadline in ms (`0` = the 30 s default);
    /// the slow-loris scenario needs this well under the client's 10 s
    /// read timeout
    pub deadline_ms: u64,
    pub scenarios: Vec<ScenarioSpec>,
}

impl Default for LoadPlan {
    fn default() -> Self {
        LoadPlan {
            seed: 2016,
            n: 256,
            k: 2,
            models: 1,
            points: 4,
            workers: 0,
            backlog: 128,
            keep_alive_s: 5,
            deadline_ms: 0,
            scenarios: Vec::new(),
        }
    }
}

impl LoadPlan {
    fn from_pairs(pairs: &[(String, String)]) -> Result<LoadPlan> {
        let mut plan = LoadPlan::default();
        let mut seen = BTreeSet::new();
        for (key, value) in pairs {
            if key != "scenario" && !seen.insert(key.clone()) {
                return Err(RkcError::invalid_config(format!("duplicate plan key '{key}'")));
            }
            match key.as_str() {
                "kind" => {}
                "seed" => plan.seed = scalar("seed", value)?,
                "n" => plan.n = scalar("n", value)?,
                "k" => plan.k = scalar("k", value)?,
                "models" => plan.models = scalar("models", value)?,
                "points" => plan.points = scalar("points", value)?,
                "workers" => plan.workers = scalar("workers", value)?,
                "backlog" => plan.backlog = scalar("backlog", value)?,
                "keep_alive_s" => plan.keep_alive_s = scalar("keep_alive_s", value)?,
                "deadline_ms" => plan.deadline_ms = scalar("deadline_ms", value)?,
                "scenario" => plan.scenarios.push(ScenarioSpec::parse(value)?),
                other => {
                    return Err(RkcError::invalid_config(format!(
                        "unknown load-plan key '{other}'"
                    )))
                }
            }
        }
        plan.validate()?;
        Ok(plan)
    }

    fn validate(&self) -> Result<()> {
        if self.scenarios.is_empty() {
            return Err(RkcError::invalid_config(
                "load plan needs at least one 'scenario' line",
            ));
        }
        if self.models == 0 || self.points == 0 || self.k == 0 {
            return Err(RkcError::invalid_config(
                "load plan models/points/k must be >= 1",
            ));
        }
        if self.n < 16 {
            return Err(RkcError::invalid_config("load plan n must be >= 16"));
        }
        let names: BTreeSet<_> = self.scenarios.iter().map(|s| s.name.as_str()).collect();
        if names.len() != self.scenarios.len() {
            return Err(RkcError::invalid_config("scenario names must be unique"));
        }
        Ok(())
    }
}

impl fmt::Display for LoadPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "kind load")?;
        writeln!(f, "seed {}", self.seed)?;
        writeln!(f, "n {}", self.n)?;
        writeln!(f, "k {}", self.k)?;
        writeln!(f, "models {}", self.models)?;
        writeln!(f, "points {}", self.points)?;
        writeln!(f, "workers {}", self.workers)?;
        writeln!(f, "backlog {}", self.backlog)?;
        writeln!(f, "keep_alive_s {}", self.keep_alive_s)?;
        write!(f, "deadline_ms {}", self.deadline_ms)?;
        for s in &self.scenarios {
            write!(f, "\n{s}")?;
        }
        Ok(())
    }
}

/// Parse one unsigned scalar with a typed error naming the key.
fn scalar<T: FromStr>(what: &'static str, value: &str) -> Result<T> {
    value.parse().map_err(|_| RkcError::parse(what, value.to_string()))
}

/// Split a comma-separated axis value; empty items are errors.
fn axis<T>(what: &'static str, value: &str, parse: impl Fn(&str) -> Result<T>) -> Result<Vec<T>> {
    let mut out = Vec::new();
    for item in value.split(',') {
        let item = item.trim();
        if item.is_empty() {
            return Err(RkcError::parse(what, value.to_string()));
        }
        out.push(parse(item)?);
    }
    Ok(out)
}

fn no_axis_duplicates<T: fmt::Display>(axis: &str, values: &[T]) -> Result<()> {
    let mut seen = BTreeSet::new();
    for v in values {
        if !seen.insert(v.to_string()) {
            return Err(RkcError::invalid_config(format!(
                "duplicate value '{v}' in plan axis '{axis}'"
            )));
        }
    }
    Ok(())
}

fn join_csv<T: fmt::Display>(values: &[T]) -> String {
    values.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",")
}

#[cfg(test)]
mod tests {
    use super::*;

    const GRID: &str = "\
# smoke grid
kind grid
seed 7
dataset cross_lines
n 96
method one_pass,exact
kernel poly2,rbf:0.5
rank 2
oversample 4,6
threads 1,2
repeats 2
timings false
";

    #[test]
    fn grid_plan_parses_axes_and_scalars() {
        let Plan::Grid(p) = Plan::parse(GRID).unwrap() else { panic!("expected grid") };
        assert_eq!(p.seed, 7);
        assert_eq!(p.methods, vec![Method::OnePass, Method::Exact]);
        assert_eq!(p.kernels, vec![Kernel::paper_poly2(), Kernel::Rbf { gamma: 0.5 }]);
        assert_eq!(p.oversamples, vec![4, 6]);
        assert!(!p.timings);
        // unset keys keep their defaults
        assert_eq!(p.batch, GridPlan::default().batch);
    }

    #[test]
    fn load_plan_parses_scenarios_in_order() {
        let text = "kind load\nseed 3\nmodels 2\n\
                    scenario a mode=burst clients=4\n\
                    scenario b mode=slow_loris requests=2 keep_alive=false\n";
        let Plan::Load(p) = Plan::parse(text).unwrap() else { panic!("expected load") };
        assert_eq!(p.models, 2);
        assert_eq!(p.scenarios.len(), 2);
        assert_eq!(p.scenarios[0].mode, ScenarioMode::Burst);
        assert_eq!(p.scenarios[0].clients, 4);
        assert_eq!(p.scenarios[1].requests, 2);
        assert!(!p.scenarios[1].keep_alive);
    }

    #[test]
    fn display_is_canonical_and_reparses() {
        let plan = Plan::parse(GRID).unwrap();
        let text = plan.to_string();
        let again = Plan::parse(&text).unwrap();
        assert_eq!(plan, again);
        assert_eq!(text, again.to_string());
    }

    #[test]
    fn strictness_rejects_unknown_and_duplicate_keys() {
        assert!(Plan::parse("kind grid\nwat 1\n").is_err());
        assert!(Plan::parse("kind grid\nseed 1\nseed 2\n").is_err());
        assert!(Plan::parse("kind load\nscenario a mode=burst\nwat 1\n").is_err());
    }
}
