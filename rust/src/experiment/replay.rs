//! Load-scenario replay: drive traffic shapes against a live serve
//! front-end with [`MiniHttpClient`] and measure what came back.
//!
//! [`run_load`] fits a small model registry, serves it over HTTP with
//! the plan's front-end knobs (workers, backlog, keep-alive, request
//! deadline), replays every scenario in order, and emits one latency
//! row per scenario (p50/p95/p99 via the shared
//! [`latency_summary`] helper, plus the [`FrontendStats`] deltas —
//! shed 503s, failures — the scenario provoked). [`replay_scenario`]
//! is also callable directly against any served registry; the
//! failure-injection tests use it to assert the 408 deadline, mid-body
//! poisoning, and queue-shed behaviors without hand-rolled sockets.
//!
//! All client failure handling is tolerant (`try_*` methods): broken
//! connections are the *subject* of several scenarios, so a dead socket
//! is counted as `dropped`, never a panic.

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::api::KernelClusterer;
use crate::bench_harness::{latency_summary, MiniHttpClient};
use crate::data;
use crate::error::Result;
use crate::linalg::Mat;
use crate::rng::Pcg64;
use crate::serve::{serve_http_registry, FrontendStats, HttpOpts, ModelRegistry, ServeOpts};
use crate::util::Json;

use super::plan::{LoadPlan, ScenarioMode, ScenarioSpec};
use super::PlanReport;

/// Where a scenario sends its traffic: the front-end address and the
/// predict paths to round-robin across (one per served model — the
/// mixed-models shape when there are several).
#[derive(Clone, Debug)]
pub struct ReplayTarget {
    pub addr: SocketAddr,
    pub paths: Vec<String>,
}

impl ReplayTarget {
    fn path(&self, client: usize, requests_per_client: usize, r: usize) -> &str {
        &self.paths[(client * requests_per_client + r) % self.paths.len()]
    }
}

/// What one scenario's replay observed. `sent` counts request attempts
/// actually written (partial-write scenarios write an aborted attempt
/// AND a follow-up good request per nominal request, so `sent` can
/// exceed `clients × requests`); `dropped` counts attempts that ended
/// without any parseable response.
#[derive(Clone, Debug, Default)]
pub struct ScenarioOutcome {
    pub sent: usize,
    /// 2xx responses
    pub ok: usize,
    /// attempts with no response (reset, close, client-side timeout)
    pub dropped: usize,
    /// responses by status code
    pub statuses: BTreeMap<u16, usize>,
    /// per-response latencies (seconds), all clients concatenated
    pub latencies_s: Vec<f64>,
    pub wall_s: f64,
}

impl ScenarioOutcome {
    /// Responses with this exact status code.
    pub fn count(&self, status: u16) -> usize {
        self.statuses.get(&status).copied().unwrap_or(0)
    }

    fn record(&mut self, resp: Option<(u16, String)>, latency_s: f64) {
        self.sent += 1;
        match resp {
            Some((status, _)) => {
                *self.statuses.entry(status).or_insert(0) += 1;
                if (200..300).contains(&status) {
                    self.ok += 1;
                }
                self.latencies_s.push(latency_s);
            }
            None => self.dropped += 1,
        }
    }

    fn absorb(&mut self, other: ScenarioOutcome) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.dropped += other.dropped;
        for (status, count) in other.statuses {
            *self.statuses.entry(status).or_insert(0) += count;
        }
        self.latencies_s.extend(other.latencies_s);
    }
}

/// Replay one scenario with `spec.clients` concurrent client threads
/// and merge their observations (client order, so the merge itself is
/// deterministic). `body` is the JSON predict body every good request
/// sends.
pub fn replay_scenario(target: &ReplayTarget, spec: &ScenarioSpec, body: &str) -> ScenarioOutcome {
    let t0 = Instant::now();
    // burst: ALL clients connect here, sequentially, BEFORE any request
    // byte moves — the accept loop sees the full connection spike and
    // its shed decisions are made while the worker pool is idle.
    // Outer None = not a burst client; Some(None) = the dial itself
    // failed (OS backlog overflow), which the client records as a drop.
    let preconnected: Vec<Option<Option<MiniHttpClient>>> = (0..spec.clients)
        .map(|_| {
            (spec.mode == ScenarioMode::Burst).then(|| MiniHttpClient::try_connect(target.addr))
        })
        .collect();

    let mut merged = ScenarioOutcome::default();
    std::thread::scope(|s| {
        let handles: Vec<_> = preconnected
            .into_iter()
            .enumerate()
            .map(|(client, pre)| s.spawn(move || run_client(target, spec, body, client, pre)))
            .collect();
        for h in handles {
            merged.absorb(h.join().expect("replay client thread"));
        }
    });
    merged.wall_s = t0.elapsed().as_secs_f64();
    merged
}

fn run_client(
    target: &ReplayTarget,
    spec: &ScenarioSpec,
    body: &str,
    client: usize,
    pre: Option<Option<MiniHttpClient>>,
) -> ScenarioOutcome {
    let mut st = ScenarioOutcome::default();
    match spec.mode {
        ScenarioMode::OpenLoop => open_loop(target, spec, body, client, &mut st),
        ScenarioMode::Burst => burst(target, spec, body, client, pre, &mut st),
        ScenarioMode::SlowLoris => slow_loris(target, spec, client, &mut st),
        ScenarioMode::PartialWrite => partial_write(target, spec, body, client, &mut st),
    }
    st
}

/// Paced request stream. With `keep_alive`, one connection per client
/// is reused, and a request that dies on a *reused* socket is retried
/// once on a fresh one (a server that idle-closed between requests is
/// healthy, not failing); otherwise every request dials fresh and asks
/// for `Connection: close`. `rate` is the aggregate target across all
/// clients, so each client paces at `clients / rate` seconds per
/// request. Pacing is closed-loop: each client waits for its response
/// before sleeping out the remainder of the interval, so under server
/// stalls the achieved rate (`sent / wall_s` in the row) slips below
/// the configured `rate` rather than queueing sends.
fn open_loop(
    target: &ReplayTarget,
    spec: &ScenarioSpec,
    body: &str,
    client: usize,
    st: &mut ScenarioOutcome,
) {
    let interval_s = if spec.rate_hz > 0.0 { spec.clients as f64 / spec.rate_hz } else { 0.0 };
    let mut conn: Option<MiniHttpClient> = None;
    for r in 0..spec.requests {
        let path = target.path(client, spec.requests, r);
        let t0 = Instant::now();
        let resp = if spec.keep_alive {
            let reused = conn.is_some();
            let mut got = keep_alive_request(&mut conn, target.addr, path, body);
            if got.is_none() && reused {
                got = keep_alive_request(&mut conn, target.addr, path, body);
            }
            got
        } else {
            MiniHttpClient::try_connect(target.addr)
                .and_then(|mut c| c.try_request("POST", path, body, true))
        };
        st.record(resp, t0.elapsed().as_secs_f64());
        if interval_s > 0.0 {
            let elapsed = t0.elapsed().as_secs_f64();
            if elapsed < interval_s {
                std::thread::sleep(Duration::from_secs_f64(interval_s - elapsed));
            }
        }
    }
}

/// One request over the client's cached keep-alive connection, dialing
/// a fresh one if none is cached. The connection is kept only when the
/// request got a response; a dead socket is dropped so the next
/// attempt re-dials.
fn keep_alive_request(
    conn: &mut Option<MiniHttpClient>,
    addr: SocketAddr,
    path: &str,
    body: &str,
) -> Option<(u16, String)> {
    let mut c = conn.take().or_else(|| MiniHttpClient::try_connect(addr))?;
    let got = c.try_request("POST", path, body, false);
    if got.is_some() {
        *conn = Some(c);
    }
    got
}

/// Connection-spike client. Its pre-dialed connection is probed first:
/// a connection the server shed already carries an unsolicited 503,
/// which must be read *instead of* sending a request into a closed
/// socket. Admitted connections (and every later request) run as
/// ordinary close-per-request traffic.
fn burst(
    target: &ReplayTarget,
    spec: &ScenarioSpec,
    body: &str,
    client: usize,
    pre: Option<Option<MiniHttpClient>>,
    st: &mut ScenarioOutcome,
) {
    let mut first = pre;
    for r in 0..spec.requests {
        let path = target.path(client, spec.requests, r);
        match first.take() {
            Some(Some(mut c)) => {
                if let Some((status, _)) = c.probe(Duration::from_millis(500)) {
                    // shed at accept: the 503 consumed this request slot
                    st.sent += 1;
                    *st.statuses.entry(status).or_insert(0) += 1;
                    continue;
                }
                let t0 = Instant::now();
                let resp = c.try_request("POST", path, body, true);
                st.record(resp, t0.elapsed().as_secs_f64());
            }
            Some(None) => {
                // the spike's own dial was refused at the OS level
                st.sent += 1;
                st.dropped += 1;
            }
            None => {
                let t0 = Instant::now();
                let resp = MiniHttpClient::try_connect(target.addr)
                    .and_then(|mut c| c.try_request("POST", path, body, true));
                st.record(resp, t0.elapsed().as_secs_f64());
            }
        }
    }
}

/// Slow-loris client: sends half a request head and then goes quiet.
/// The server's request deadline must cut it off with a 408 (counted
/// here as a response, with the latency showing the deadline).
fn slow_loris(target: &ReplayTarget, spec: &ScenarioSpec, client: usize, st: &mut ScenarioOutcome) {
    for r in 0..spec.requests {
        let path = target.path(client, spec.requests, r);
        let Some(mut c) = MiniHttpClient::try_connect(target.addr) else {
            st.sent += 1;
            st.dropped += 1;
            continue;
        };
        let t0 = Instant::now();
        let partial = format!("POST {path} HTTP/1.1\r\nHost: rkc\r\n");
        if !c.try_send_raw(partial.as_bytes()) {
            st.sent += 1;
            st.dropped += 1;
            continue;
        }
        st.record(c.try_read_response(), t0.elapsed().as_secs_f64());
    }
}

/// Mid-body disconnect client: each nominal request is an aborted
/// write (full head promising `Content-Length`, half the body, socket
/// dropped) followed by a fresh-connection good request — the pair
/// proves the poisoned framing died with its own connection while the
/// pool worker and every other connection kept serving.
fn partial_write(
    target: &ReplayTarget,
    spec: &ScenarioSpec,
    body: &str,
    client: usize,
    st: &mut ScenarioOutcome,
) {
    for r in 0..spec.requests {
        let path = target.path(client, spec.requests, r);
        {
            let c = MiniHttpClient::try_connect(target.addr);
            st.sent += 1;
            st.dropped += 1;
            if let Some(mut c) = c {
                let head = format!(
                    "POST {path} HTTP/1.1\r\nHost: rkc\r\nContent-Type: application/json\r\n\
                     Content-Length: {}\r\n\r\n",
                    body.len()
                );
                let sent_head = c.try_send_raw(head.as_bytes());
                let _ = sent_head && c.try_send_raw(&body.as_bytes()[..body.len() / 2]);
                // dropping `c` closes the socket mid-body
            }
        }
        let t0 = Instant::now();
        let resp = MiniHttpClient::try_connect(target.addr)
            .and_then(|mut c| c.try_request("POST", path, body, true));
        st.record(resp, t0.elapsed().as_secs_f64());
    }
}

/// Column-major points matrix → the serve front-end's predict body.
pub fn points_body(x: &Mat) -> String {
    let pts: Vec<Json> = (0..x.cols())
        .map(|j| Json::Arr((0..x.rows()).map(|i| Json::Num(x[(i, j)])).collect()))
        .collect();
    Json::Obj(BTreeMap::from([("points".to_string(), Json::Arr(pts))])).to_string()
}

/// Run a load plan: fit `plan.models` models, serve them, replay every
/// scenario in order, and emit one JSONL latency row per scenario.
pub fn run_load(plan: &LoadPlan, plan_hash: u64) -> Result<PlanReport> {
    let registry = Arc::new(ModelRegistry::new(ServeOpts { threads: 1, ..Default::default() }));
    let mut paths = Vec::with_capacity(plan.models);
    for m in 0..plan.models {
        let ds = data::cross_lines(&mut Pcg64::seed_stream(plan.seed, 0x10ad + m as u64), plan.n);
        let model = KernelClusterer::new(plan.k)
            .rank(2)
            .oversample(8)
            .seed(plan.seed.wrapping_add(m as u64))
            .threads(1)
            .fit(&ds.x)?;
        let name = format!("m{m}");
        registry.insert(&name, model)?;
        paths.push(format!("/models/{name}/predict"));
    }
    let http = serve_http_registry(
        Arc::clone(&registry),
        "127.0.0.1:0",
        HttpOpts {
            workers: plan.workers,
            keep_alive: Duration::from_secs(plan.keep_alive_s),
            backlog: plan.backlog,
            request_deadline: Duration::from_millis(plan.deadline_ms),
        },
    )?;
    let target = ReplayTarget { addr: http.local_addr(), paths };
    let query = data::cross_lines(&mut Pcg64::seed_stream(plan.seed, 0xb0d7), plan.points).x;
    let body = points_body(&query);

    let mut jsonl = String::new();
    jsonl.push_str(&super::header_json("load", plan_hash, plan.scenarios.len(), true).to_string());
    jsonl.push('\n');
    for spec in &plan.scenarios {
        let before = http.frontend_stats();
        let outcome = replay_scenario(&target, spec, &body);
        let after = http.frontend_stats();
        jsonl.push_str(&scenario_json(spec, &outcome, &before, &after).to_string());
        jsonl.push('\n');
    }
    http.shutdown();
    Ok(PlanReport { kind: "load", plan_hash, rows: plan.scenarios.len(), jsonl })
}

/// One latency-histogram row: the scenario's shape, what the clients
/// observed, the shared percentile summary, and the front-end counter
/// deltas the scenario provoked.
fn scenario_json(
    spec: &ScenarioSpec,
    out: &ScenarioOutcome,
    before: &FrontendStats,
    after: &FrontendStats,
) -> Json {
    let mut fields = BTreeMap::from([
        ("row".to_string(), Json::Str("scenario".to_string())),
        ("scenario".to_string(), Json::Str(spec.name.clone())),
        ("mode".to_string(), Json::Str(spec.mode.to_string())),
        ("clients".to_string(), Json::Num(spec.clients as f64)),
        ("requests_per_client".to_string(), Json::Num(spec.requests as f64)),
        ("rate_hz".to_string(), Json::finite_num(spec.rate_hz)),
        ("keep_alive".to_string(), Json::Bool(spec.keep_alive)),
        ("sent".to_string(), Json::Num(out.sent as f64)),
        ("ok".to_string(), Json::Num(out.ok as f64)),
        ("dropped".to_string(), Json::Num(out.dropped as f64)),
        ("http_408".to_string(), Json::Num(out.count(408) as f64)),
        ("http_503".to_string(), Json::Num(out.count(503) as f64)),
        ("wall_s".to_string(), Json::finite_num(out.wall_s)),
    ]);
    let deltas = [
        ("fe_connections", after.connections - before.connections),
        ("fe_requests", after.requests - before.requests),
        ("fe_failures", after.failures - before.failures),
        ("fe_shed", after.shed - before.shed),
    ];
    for (key, delta) in deltas {
        fields.insert(key.to_string(), Json::Num(delta as f64));
    }
    for (key, value) in latency_summary(&out.latencies_s).json_fields("") {
        fields.insert(key, value);
    }
    Json::Obj(fields)
}
