//! `rkc::experiment` — the declarative experiment + load-scenario
//! harness behind the `rkc experiment` subcommand.
//!
//! A `.plan` file (see [`Plan::parse`]) declares either:
//!
//! - a **grid**: method × kernel × rank × oversample × threads ×
//!   dataset × repeats. [`run_grid`] expands it deterministically
//!   ([`expand`]), derives every trial's seed purely from the plan seed
//!   and the trial's coordinates ([`trial_seed`]), runs each trial
//!   through the [`crate::api`] fit path via
//!   [`crate::coordinator::run_experiment`] (accuracy/ARI/NMI,
//!   approximation error, peak approximation memory, per-stage wall
//!   times), and emits one schema-stable JSONL row per trial; or
//! - a **load** scenario list: traffic shapes (open-loop, burst,
//!   slow-loris, partial-write; keep-alive or close; round-robin over
//!   several served models) replayed by [`run_load`] against a live
//!   [`crate::serve`] registry, emitting one latency-histogram row per
//!   scenario (p50/p95/p99 plus shed/failure deltas from
//!   [`crate::serve::FrontendStats`]).
//!
//! Every JSONL file opens with a header row carrying the FNV-1a hash
//! of the plan text ([`plan_hash`]), so a result file can always be
//! matched to the exact plan that produced it
//! (`tools/check_experiment_jsonl.py` enforces this in CI). With
//! `timings false`, grid output is **byte-identical** across reruns
//! and runner thread counts — the determinism contract
//! `rust/tests/experiment_golden.rs` pins.

mod grid;
mod plan;
mod replay;

pub use grid::{expand, run_grid, trial_seed, Trial};
pub use plan::{GridPlan, LoadPlan, Plan, ScenarioMode, ScenarioSpec};
pub use replay::{points_body, replay_scenario, run_load, ReplayTarget, ScenarioOutcome};

use std::collections::BTreeMap;

use crate::error::Result;
use crate::util::Json;

/// JSONL schema version stamped into every header row; bump when a
/// required key changes meaning or disappears.
pub const JSONL_SCHEMA: u32 = 1;

/// A completed plan run: the full JSONL text (header + one row per
/// trial/scenario) plus what the summary line needs.
#[derive(Clone, Debug)]
pub struct PlanReport {
    /// `"grid"` or `"load"`
    pub kind: &'static str,
    pub plan_hash: u64,
    /// data rows (excluding the header)
    pub rows: usize,
    pub jsonl: String,
}

/// FNV-1a 64 over the plan text — the same checksum `.rkc` files trail
/// with, here binding a JSONL result file to the exact plan bytes that
/// produced it.
pub fn plan_hash(text: &str) -> u64 {
    crate::model_io::checksum(text.as_bytes())
}

/// Parse and run a plan's text: hash it, dispatch on its kind, return
/// the JSONL report. `runner_threads` (grid only) sets how many trials
/// run concurrently — never what any trial computes (`0` = auto).
pub fn run_plan_text(text: &str, runner_threads: usize) -> Result<PlanReport> {
    let hash = plan_hash(text);
    match Plan::parse(text)? {
        Plan::Grid(p) => run_grid(&p, hash, runner_threads),
        Plan::Load(p) => run_load(&p, hash),
    }
}

/// The header row every experiment JSONL file opens with.
pub(crate) fn header_json(kind: &str, plan_hash: u64, rows: usize, timings: bool) -> Json {
    Json::Obj(BTreeMap::from([
        ("row".to_string(), Json::Str("header".to_string())),
        ("kind".to_string(), Json::Str(kind.to_string())),
        ("plan_hash".to_string(), Json::Str(format!("{plan_hash:016x}"))),
        ("schema".to_string(), Json::Num(JSONL_SCHEMA as f64)),
        ("rows".to_string(), Json::Num(rows as f64)),
        ("timings".to_string(), Json::Bool(timings)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_hash_matches_model_io_checksum() {
        assert_eq!(plan_hash(""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(plan_hash("kind grid"), plan_hash("kind load"));
    }

    #[test]
    fn header_row_is_schema_stable() {
        let h = header_json("grid", 0xabc, 3, false).to_string();
        assert_eq!(
            h,
            "{\"kind\":\"grid\",\"plan_hash\":\"0000000000000abc\",\"row\":\"header\",\
             \"rows\":3,\"schema\":1,\"timings\":false}"
        );
    }
}
