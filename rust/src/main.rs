//! `rkc` — command-line launcher for the randomized kernel clustering
//! system (GlobalSIP 2016 reproduction). A thin client of `rkc::api`:
//! every subcommand parses flags into an `ExperimentConfig` and drives
//! the library's `KernelClusterer` through the compatibility driver.
//!
//! ```text
//! rkc run      [--key value]...     one experiment (any method/backend)
//! rkc table1   [--trials N]         regenerate Table 1
//! rkc fig2     [--out-dir D]        dump Fig. 1/2 embedding CSVs
//! rkc fig3     [--trials N]         regenerate Fig. 3(a)+(b) series
//! rkc theorem1                      empirical Theorem-1 bound check
//! rkc memory                        memory model across methods
//! rkc artifacts                     list compiled artifacts
//! rkc save     [--model path]       fit once, persist the .rkc model
//! rkc predict  [--model path] [--data pts.csv]   offline predictions
//! rkc serve    [--model path | --models dir] [--addr host:port]
//!              multi-model HTTP serving runtime (keep-alive pool)
//! rkc stream   [--scenario moving_blobs|label_churn | --data pts.csv|-]
//!              online clustering with live generation hot-swap
//! rkc experiment --plan plans/file.plan [--out results.jsonl]
//!              declarative trial grid / load-scenario replay -> JSONL
//! ```
//!
//! Every subcommand accepts the config overrides documented in
//! `config::ExperimentConfig::set` (e.g. `--method nystrom_m50`,
//! `--backend xla`, `--trials 10`, `--kernel rbf:2.0`,
//! `--data_dir /path/to/csvs`).

use rkc::config::{Cli, ExperimentConfig};
use rkc::error::{Result, RkcError};
use rkc::runtime::ArtifactRegistry;

mod commands;

const FLAGS: &[&str] = &["verbose", "csv", "help"];

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = real_main(args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn real_main(args: Vec<String>) -> Result<()> {
    rkc::obs::init_from_env();
    // a malformed RKC_FAULTS spec must abort, not silently run unfaulted
    // (a chaos run that quietly degrades to a clean run proves nothing)
    rkc::fault::init_from_env()?;
    let cli = Cli::parse(args, FLAGS)?;
    if cli.has_flag("help") || cli.subcommand.is_none() {
        print_help();
        return Ok(());
    }
    let sub = cli.subcommand.clone().unwrap();

    // base config per subcommand, then apply --config file, then flags
    let mut cfg = match sub.as_str() {
        "table1" | "fig2" => ExperimentConfig::table1(),
        _ => ExperimentConfig::default(),
    };
    if let Some(path) = cli.get("config") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| RkcError::io(format!("reading config {path}"), e))?;
        let json = rkc::util::Json::parse(&text)
            .map_err(|e| RkcError::invalid_config(format!("parsing config {path}: {e}")))?;
        cfg.apply_json(&json)?;
    }
    for (k, v) in &cli.options {
        // "data" is the query/source CSV for predict and stream, not a
        // config key — but only there; everywhere else an unknown key
        // still fails loudly
        if k == "config"
            || k == "out-dir"
            || k == "trace"
            || (k == "data" && (sub == "predict" || sub == "stream"))
        {
            continue;
        }
        cfg.set(k, v)?;
    }

    // the registry is optional: native backend works without artifacts
    let registry = ArtifactRegistry::open(&cfg.artifacts_dir).ok();
    if cfg.backend == rkc::config::Backend::Xla && registry.is_none() {
        return Err(RkcError::backend(
            "--backend xla needs artifacts/ (run `make artifacts`)",
        ));
    }

    let out_dir = cli.get("out-dir").unwrap_or("results").to_string();
    let result = match sub.as_str() {
        "run" => commands::cmd_run(&cfg, registry.as_ref()),
        "table1" => commands::cmd_table1(&cfg, registry.as_ref()),
        "fig2" => commands::cmd_fig2(&cfg, registry.as_ref(), &out_dir),
        "fig3" => commands::cmd_fig3(&cfg, registry.as_ref(), &out_dir),
        "theorem1" => commands::cmd_theorem1(&cfg),
        "memory" => commands::cmd_memory(&cfg),
        "artifacts" => commands::cmd_artifacts(registry.as_ref()),
        "save" => commands::cmd_save(&cfg, registry.as_ref()),
        "predict" => commands::cmd_predict(&cfg, cli.get("data")),
        "serve" => commands::cmd_serve(&cfg),
        "stream" => commands::cmd_stream(&cfg, cli.get("data")),
        "experiment" => commands::cmd_experiment(&cfg),
        other => Err(RkcError::invalid_config(format!(
            "unknown subcommand '{other}' (try --help)"
        ))),
    };

    // dump the span ring last (even after a failed subcommand — partial
    // traces are exactly what you want when diagnosing the failure)
    let trace = cli
        .get("trace")
        .map(str::to_string)
        .or_else(|| std::env::var("RKC_TRACE").ok().filter(|p| !p.is_empty()));
    if let Some(path) = trace {
        match rkc::obs::dump_trace(std::path::Path::new(&path)) {
            Ok(n) => eprintln!("rkc: wrote {n} span(s) to {path}"),
            Err(e) if result.is_ok() => return Err(e),
            // don't let a failed dump mask the subcommand's own error
            Err(e) => eprintln!("rkc: failed to write trace {path}: {e}"),
        }
    }
    result
}

fn print_help() {
    println!(
        "rkc — randomized kernel clustering (one-pass SRHT kernel K-means)

USAGE: rkc <subcommand> [--key value]...

SUBCOMMANDS
  run        run one experiment (method/backend/dataset configurable)
  table1     regenerate Table 1 (cross_lines, exact/ours/nystrom)
  fig2       dump Fig. 1/2 embedding CSVs to --out-dir
  fig3       regenerate Fig. 3(a)(b): error + accuracy vs m sweep
  theorem1   empirical validation of the Theorem-1 bounds
  memory     peak-memory model across methods
  artifacts  list the compiled XLA artifacts
  save       fit once and persist the model to --model (.rkc format)
  predict    load --model, assign --data points.csv (or the dataset)
  serve      serve --model (or every .rkc in --models DIR, keyed by
             file stem) over keep-alive HTTP at --addr
  stream     ingest --chunk-sized batches from --scenario / --data
             (- = stdin) / the dataset, fold them into a running
             sketch, and hot-swap refreshed models into the registry
  experiment run a declarative --plan file (grid of trials, or load
             scenarios replayed against a live registry) and write one
             schema-stable JSONL row per trial/scenario

COMMON OPTIONS (config overrides)
  --method one_pass|gaussian|exact|full_kernel|plain|nystrom[_m<M>]
  --backend native|xla        --dataset cross_lines|segmentation_like|...
  --n N --p P --k K           --rank R --oversample L --batch B
  --trials T --seed S         --kernel poly2|rbf:<g>|poly:<g>:<d>
  --threads T (0 = auto)      --config file.json
  --kmeans_restarts N --kmeans_iters N --kmeans_tol EPS
  --out-dir DIR (fig2/fig3)   --artifacts_dir DIR --data_dir DIR
  --model PATH (default {{artifacts_dir}}/model.rkc)
  --models DIR (serve; load every .rkc in DIR, name = file stem)
  --addr HOST:PORT (serve; default 127.0.0.1:7878)
  --http_workers N (serve; connection-pool size, 0 = auto)
  --keep_alive_s S (serve; idle seconds per connection, 0 = close)
  --data points.csv (predict/stream; one coordinate row per point)
  --chunk N (stream; points per ingest batch, default 200)
  --refresh_points N (stream; refresh every N points, 0 = off)
  --refresh_secs S (stream; refresh every S seconds, 0 = off)
  --scenario moving_blobs|label_churn (stream; synthetic drift source)
  --drift X (stream; per-chunk drift magnitude, default 0.05)
  --stream_http true (stream; serve generations on --addr while running)
  --checkpoint state.rkcs (stream; durable state file — if it already
                      exists the run RESUMES from it instead of starting
                      cold, so rerunning a crashed command continues it)
  --checkpoint_points N (stream; checkpoint every N points, 0 = off)
  --checkpoint_secs S (stream; checkpoint every S seconds, 0 = off)
  --plan plans/file.plan (experiment; grid or load plan to run)
  --out results.jsonl (experiment; default exp_<plan-stem>.jsonl)

OBSERVABILITY
  --trace out.jsonl   dump the span ring (stage/request timings) on exit;
                      the RKC_TRACE env var does the same thing
  RKC_OBS=0           disable all metric/span recording (out-of-band
                      either way: results are bit-identical on or off)

FAULT INJECTION (chaos testing)
  RKC_FAULTS=\"site=action[:p[,...]]\"  arm named failpoints, e.g.
      RKC_FAULTS=\"model_io.fsync=io_error:0.3,serve.load=delay_ms:50\"
  sites: model_io.write model_io.fsync stream.checkpoint serve.load
         http.accept
  actions: io_error:<p> (typed transient IO error with probability p)
           delay_ms:<ms>[:<p>] (sleep ms milliseconds, p defaults to 1)
  unset => zero behavior change (single relaxed atomic load per site);
  trips surface in /metrics as rkc_fault_trips_total{{site,action}}

SERVING PROTOCOL (serve)
  POST /models/NAME/predict {{\"points\": [[x, ...], ...]}} -> {{\"labels\": [...]}}
  POST /models/NAME/embed   same body                     -> {{\"embedding\": [...]}}
  GET  /models                 -> per-model listing + stats
  PUT  /models/NAME {{\"path\": \"m.rkc\"}} / DELETE /models/NAME  (load/unload)
  POST /predict, POST /embed   -> the default model (legacy aliases)
  GET  /healthz                -> status + counters + per-model latency
  GET  /metrics                -> Prometheus text exposition"
    );
}
