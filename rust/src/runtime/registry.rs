//! Manifest-driven artifact registry with lazy compilation.

use std::cell::OnceCell;
use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::error::{Result, RkcError};
use crate::util::Json;

use super::backend;
use super::PjrtRuntime;

/// One entry of `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    /// static params recorded by aot.py (op, n, b, r, k, kind, …)
    pub params: BTreeMap<String, String>,
    /// input shapes in call order
    pub inputs: Vec<Vec<usize>>,
    /// output shapes in result order
    pub outputs: Vec<Vec<usize>>,
}

impl ArtifactInfo {
    pub fn param_usize(&self, key: &str) -> Result<usize> {
        self.params.get(key).and_then(|v| v.parse().ok()).ok_or_else(|| {
            RkcError::missing_artifact(format!(
                "artifact {}: missing numeric param '{key}'",
                self.name
            ))
        })
    }

    fn from_json(j: &Json) -> Result<ArtifactInfo> {
        let field = |key: &str| -> Result<String> {
            j.str_field(key)
                .map(str::to_string)
                .map_err(|e| RkcError::backend(format!("artifact manifest entry: {e}")))
        };
        let name = field("name")?;
        let file = field("file")?;
        let mut params = BTreeMap::new();
        if let Some(Json::Obj(map)) = j.get("params") {
            for (k, v) in map {
                let text = match v {
                    Json::Str(s) => s.clone(),
                    Json::Num(x) => {
                        if x.fract() == 0.0 {
                            format!("{}", *x as i64)
                        } else {
                            format!("{x}")
                        }
                    }
                    other => other.to_string(),
                };
                params.insert(k.clone(), text);
            }
        }
        let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
            j.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| RkcError::backend(format!("artifact {name}: missing '{key}'")))?
                .iter()
                .map(|e| {
                    e.get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| {
                            RkcError::backend(format!("artifact {name}: bad shape entry"))
                        })?
                        .iter()
                        .map(|d| {
                            d.as_usize()
                                .ok_or_else(|| RkcError::backend("bad shape dimension"))
                        })
                        .collect()
                })
                .collect()
        };
        Ok(ArtifactInfo { inputs: shapes("inputs")?, outputs: shapes("outputs")?, name, file, params })
    }
}

/// A compiled artifact plus its manifest metadata.
pub struct Executable {
    pub info: ArtifactInfo,
    exe: backend::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with the given input literals; returns the flattened
    /// output tuple (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[backend::Literal]) -> Result<Vec<backend::Literal>> {
        if inputs.len() != self.info.inputs.len() {
            return Err(RkcError::backend(format!(
                "artifact {} expects {} inputs, got {}",
                self.info.name,
                self.info.inputs.len(),
                inputs.len()
            )));
        }
        let result = self
            .exe
            .execute::<backend::Literal>(inputs)
            .map_err(|e| RkcError::backend(format!("executing {}: {e}", self.info.name)))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| RkcError::backend(format!("fetching result of {}: {e}", self.info.name)))?;
        lit.to_tuple()
            .map_err(|e| RkcError::backend(format!("untupling result of {}: {e}", self.info.name)))
    }
}

/// Loads `manifest.json`, compiles artifacts on first use, and caches
/// the executables for the lifetime of the process. The PJRT client is
/// created lazily too: listing / inspecting artifacts never requires a
/// working XLA backend.
pub struct ArtifactRegistry {
    runtime: OnceCell<PjrtRuntime>,
    dir: String,
    infos: BTreeMap<String, ArtifactInfo>,
    compiled: Mutex<BTreeMap<String, &'static Executable>>,
}

impl ArtifactRegistry {
    /// Open the registry at `dir` (must contain manifest.json).
    pub fn open(dir: &str) -> Result<Self> {
        let manifest_path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .map_err(|e| RkcError::io(format!("reading {manifest_path} (run `make artifacts`)"), e))?;
        let json = Json::parse(&text).map_err(|e| RkcError::backend(format!("parsing manifest: {e}")))?;
        let arr = json
            .as_arr()
            .ok_or_else(|| RkcError::backend("manifest must be a JSON array"))?;
        let mut infos = BTreeMap::new();
        for entry in arr {
            let info = ArtifactInfo::from_json(entry)?;
            infos.insert(info.name.clone(), info);
        }
        Ok(ArtifactRegistry {
            runtime: OnceCell::new(),
            dir: dir.to_string(),
            infos,
            compiled: Mutex::new(BTreeMap::new()),
        })
    }

    fn runtime(&self) -> Result<&PjrtRuntime> {
        if self.runtime.get().is_none() {
            let rt = PjrtRuntime::cpu()?;
            // single-threaded cell (PJRT clients are !Sync); a lost race
            // is impossible, but ignore the Err to stay panic-free
            let _ = self.runtime.set(rt);
        }
        Ok(self.runtime.get().expect("runtime initialized above"))
    }

    pub fn names(&self) -> Vec<String> {
        self.infos.keys().cloned().collect()
    }

    pub fn info(&self, name: &str) -> Option<&ArtifactInfo> {
        self.infos.get(name)
    }

    /// Find an artifact by params predicate (e.g. op == "gram" with the
    /// right shape) — how the coordinator picks shape-compatible modules.
    pub fn find(&self, pred: impl Fn(&ArtifactInfo) -> bool) -> Option<&ArtifactInfo> {
        self.infos.values().find(|i| pred(i))
    }

    /// Get (compiling if needed) an executable by name. The returned
    /// reference lives as long as the process (executables are leaked
    /// intentionally: they are few, large, and used until exit).
    pub fn get(&self, name: &str) -> Result<&'static Executable> {
        let mut cache = self.compiled.lock().unwrap();
        if let Some(exe) = cache.get(name) {
            return Ok(exe);
        }
        let info = self
            .infos
            .get(name)
            .ok_or_else(|| {
                RkcError::missing_artifact(format!(
                    "unknown artifact '{name}' (have: {:?})",
                    self.names()
                ))
            })?
            .clone();
        let path = format!("{}/{}", self.dir, info.file);
        let exe = self.runtime()?.compile_hlo_file(&path)?;
        let boxed: &'static Executable = Box::leak(Box::new(Executable { info, exe }));
        cache.insert(name.to_string(), boxed);
        Ok(boxed)
    }

    /// PJRT platform name, or a placeholder when no client can start
    /// (e.g. built without the `xla` feature).
    pub fn platform(&self) -> String {
        match self.runtime() {
            Ok(rt) => rt.platform(),
            Err(_) => "unavailable".into(),
        }
    }
}
