//! Manifest-driven artifact registry with lazy compilation.

use std::collections::BTreeMap;
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use crate::util::Json;

use super::PjrtRuntime;

/// One entry of `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    /// static params recorded by aot.py (op, n, b, r, k, kind, …)
    pub params: BTreeMap<String, String>,
    /// input shapes in call order
    pub inputs: Vec<Vec<usize>>,
    /// output shapes in result order
    pub outputs: Vec<Vec<usize>>,
}

impl ArtifactInfo {
    pub fn param_usize(&self, key: &str) -> Result<usize> {
        self.params
            .get(key)
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| anyhow!("artifact {}: missing numeric param '{key}'", self.name))
    }

    fn from_json(j: &Json) -> Result<ArtifactInfo> {
        let name = j.str_field("name").map_err(|e| anyhow!("{e}"))?.to_string();
        let file = j.str_field("file").map_err(|e| anyhow!("{e}"))?.to_string();
        let mut params = BTreeMap::new();
        if let Some(Json::Obj(map)) = j.get("params") {
            for (k, v) in map {
                let text = match v {
                    Json::Str(s) => s.clone(),
                    Json::Num(x) => {
                        if x.fract() == 0.0 {
                            format!("{}", *x as i64)
                        } else {
                            format!("{x}")
                        }
                    }
                    other => other.to_string(),
                };
                params.insert(k.clone(), text);
            }
        }
        let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
            j.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact {name}: missing '{key}'"))?
                .iter()
                .map(|e| {
                    e.get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("artifact {name}: bad shape entry"))?
                        .iter()
                        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                        .collect()
                })
                .collect()
        };
        Ok(ArtifactInfo { inputs: shapes("inputs")?, outputs: shapes("outputs")?, name, file, params })
    }
}

/// A compiled artifact plus its manifest metadata.
pub struct Executable {
    pub info: ArtifactInfo,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with the given input literals; returns the flattened
    /// output tuple (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            inputs.len() == self.info.inputs.len(),
            "artifact {} expects {} inputs, got {}",
            self.info.name,
            self.info.inputs.len(),
            inputs.len()
        );
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.info.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.info.name))?;
        Ok(lit.to_tuple()?)
    }
}

/// Loads `manifest.json`, compiles artifacts on first use, and caches
/// the executables for the lifetime of the process.
pub struct ArtifactRegistry {
    runtime: PjrtRuntime,
    dir: String,
    infos: BTreeMap<String, ArtifactInfo>,
    compiled: Mutex<BTreeMap<String, &'static Executable>>,
}

impl ArtifactRegistry {
    /// Open the registry at `dir` (must contain manifest.json).
    pub fn open(dir: &str) -> Result<Self> {
        let manifest_path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path} (run `make artifacts`)"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
        let arr = json.as_arr().ok_or_else(|| anyhow!("manifest must be a JSON array"))?;
        let mut infos = BTreeMap::new();
        for entry in arr {
            let info = ArtifactInfo::from_json(entry)?;
            infos.insert(info.name.clone(), info);
        }
        Ok(ArtifactRegistry {
            runtime: PjrtRuntime::cpu()?,
            dir: dir.to_string(),
            infos,
            compiled: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn names(&self) -> Vec<String> {
        self.infos.keys().cloned().collect()
    }

    pub fn info(&self, name: &str) -> Option<&ArtifactInfo> {
        self.infos.get(name)
    }

    /// Find an artifact by params predicate (e.g. op == "gram" with the
    /// right shape) — how the coordinator picks shape-compatible modules.
    pub fn find(&self, pred: impl Fn(&ArtifactInfo) -> bool) -> Option<&ArtifactInfo> {
        self.infos.values().find(|i| pred(i))
    }

    /// Get (compiling if needed) an executable by name. The returned
    /// reference lives as long as the process (executables are leaked
    /// intentionally: they are few, large, and used until exit).
    pub fn get(&self, name: &str) -> Result<&'static Executable> {
        let mut cache = self.compiled.lock().unwrap();
        if let Some(exe) = cache.get(name) {
            return Ok(exe);
        }
        let info = self
            .infos
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}' (have: {:?})", self.names()))?
            .clone();
        let path = format!("{}/{}", self.dir, info.file);
        let exe = self.runtime.compile_hlo_file(&path)?;
        let boxed: &'static Executable = Box::leak(Box::new(Executable { info, exe }));
        cache.insert(name.to_string(), boxed);
        Ok(boxed)
    }

    pub fn platform(&self) -> String {
        self.runtime.platform()
    }
}
