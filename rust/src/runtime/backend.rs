//! PJRT binding surface, feature-gated.
//!
//! With `--features xla` this re-exports the real `xla` bindings crate
//! (add it to Cargo.toml when enabling — the offline image ships no
//! registry). Without the feature (the default), a type-compatible stub
//! stands in: literal containers are fully functional pure-data types
//! (so conversion helpers and their tests keep working), while anything
//! that would touch a PJRT client returns a typed
//! [`RkcError::Backend`](crate::error::RkcError) — callers degrade to
//! the native backend exactly as they do for a missing artifact.

#[cfg(feature = "xla")]
pub use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

#[cfg(not(feature = "xla"))]
pub use stub::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

#[cfg(not(feature = "xla"))]
mod stub {
    use crate::error::{Result, RkcError};

    fn unavailable() -> RkcError {
        RkcError::backend(
            "PJRT runtime unavailable: rkc was built without the `xla` feature \
             (native backend remains fully functional)",
        )
    }

    /// Element types a stub literal can hold.
    pub trait NativeType: Copy {
        fn to_f64(self) -> f64;
        fn from_f64(v: f64) -> Self;
    }

    impl NativeType for f32 {
        fn to_f64(self) -> f64 {
            self as f64
        }
        fn from_f64(v: f64) -> Self {
            v as f32
        }
    }

    impl NativeType for i32 {
        fn to_f64(self) -> f64 {
            self as f64
        }
        fn from_f64(v: f64) -> Self {
            v as i32
        }
    }

    /// Pure-data literal: values plus a shape. Mirrors the subset of the
    /// real `xla::Literal` API the crate uses.
    #[derive(Clone, Debug)]
    pub struct Literal {
        data: Vec<f64>,
        dims: Vec<i64>,
    }

    impl Literal {
        pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
            Literal {
                data: v.iter().map(|x| x.to_f64()).collect(),
                dims: vec![v.len() as i64],
            }
        }

        pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
            let want: i64 = dims.iter().product();
            if want as usize != self.data.len() {
                return Err(RkcError::backend(format!(
                    "cannot reshape literal of {} elements to {dims:?}",
                    self.data.len()
                )));
            }
            Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
        }

        pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
            Ok(self.data.iter().map(|&v| T::from_f64(v)).collect())
        }

        pub fn to_tuple(self) -> Result<Vec<Literal>> {
            Err(unavailable())
        }
    }

    /// Stand-in for a device buffer handle.
    #[derive(Debug)]
    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal> {
            Err(unavailable())
        }
    }

    #[derive(Debug)]
    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
            Err(unavailable())
        }
    }

    #[derive(Debug)]
    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    #[derive(Debug)]
    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient> {
            Err(unavailable())
        }

        pub fn platform_name(&self) -> String {
            "unavailable".into()
        }

        pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
            Err(unavailable())
        }
    }

    #[derive(Debug)]
    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
            Err(unavailable())
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn literal_roundtrips_data() {
            let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
            let shaped = lit.reshape(&[2, 2]).unwrap();
            let back: Vec<f32> = shaped.to_vec().unwrap();
            assert_eq!(back, vec![1.0, 2.0, 3.0, 4.0]);
            assert!(lit.reshape(&[3, 3]).is_err());
        }

        #[test]
        fn client_reports_unavailable() {
            let err = PjRtClient::cpu().unwrap_err();
            assert!(err.to_string().contains("xla"));
        }
    }
}
