//! PJRT runtime: load and execute the AOT-compiled XLA artifacts.
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! only bridge between the rust coordinator and the compiled L2/L1
//! compute. Interchange is HLO *text* (see python/compile/aot.py):
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`.
//!
//! The [`ArtifactRegistry`] is driven entirely by `artifacts/manifest.json`
//! and compiles lazily: an experiment that only needs the gram artifact
//! never pays for the others, and a build without the `xla` feature can
//! still open a registry and list artifacts — only execution requires
//! the real PJRT bindings (see [`backend`]).

pub mod backend;
mod registry;

pub use backend::Literal;
pub use registry::{ArtifactInfo, ArtifactRegistry, Executable};

use crate::error::{Result, RkcError};
use crate::linalg::Mat;

/// Shared PJRT CPU client (one per process).
pub struct PjrtRuntime {
    client: backend::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        let client = backend::PjRtClient::cpu()
            .map_err(|e| RkcError::backend(format!("creating PJRT CPU client: {e}")))?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile HLO text from `path` into an executable.
    pub fn compile_hlo_file(&self, path: &str) -> Result<backend::PjRtLoadedExecutable> {
        let proto = backend::HloModuleProto::from_text_file(path)
            .map_err(|e| RkcError::backend(format!("parsing HLO text {path}: {e}")))?;
        let comp = backend::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| RkcError::backend(format!("compiling {path}: {e}")))
    }
}

/// Convert a row-major f64 [`Mat`] into an f32 PJRT literal of shape
/// (rows, cols).
pub fn mat_to_literal(m: &Mat) -> Result<Literal> {
    let data: Vec<f32> = m.data().iter().map(|&v| v as f32).collect();
    let lit = Literal::vec1(&data);
    lit.reshape(&[m.rows() as i64, m.cols() as i64])
        .map_err(|e| RkcError::backend(format!("reshaping literal: {e}")))
}

/// Convert a f64 slice into a rank-1 f32 literal.
pub fn vec_to_literal(v: &[f64]) -> Result<Literal> {
    let data: Vec<f32> = v.iter().map(|&x| x as f32).collect();
    Ok(Literal::vec1(&data))
}

/// Read an f32 literal of shape (rows, cols) back into a [`Mat`].
pub fn literal_to_mat(lit: &Literal, rows: usize, cols: usize) -> Result<Mat> {
    let v: Vec<f32> = lit
        .to_vec()
        .map_err(|e| RkcError::backend(format!("reading literal: {e}")))?;
    if v.len() != rows * cols {
        return Err(RkcError::backend(format!(
            "literal has {} elements, want {rows}x{cols}",
            v.len()
        )));
    }
    Ok(Mat::from_vec(rows, cols, v.into_iter().map(|x| x as f64).collect()))
}

/// Read an f32 literal into a f64 vector.
pub fn literal_to_vec(lit: &Literal) -> Result<Vec<f64>> {
    let v: Vec<f32> = lit
        .to_vec()
        .map_err(|e| RkcError::backend(format!("reading literal: {e}")))?;
    Ok(v.into_iter().map(|x| x as f64).collect())
}

/// Read an i32 literal into usize labels.
pub fn literal_to_indices(lit: &Literal) -> Result<Vec<usize>> {
    let v: Vec<i32> = lit
        .to_vec()
        .map_err(|e| RkcError::backend(format!("reading literal: {e}")))?;
    Ok(v.into_iter().map(|x| x.max(0) as usize).collect())
}
