//! PJRT runtime: load and execute the AOT-compiled XLA artifacts.
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! only bridge between the rust coordinator and the compiled L2/L1
//! compute. Interchange is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//!
//! The [`ArtifactRegistry`] is driven entirely by `artifacts/manifest.json`
//! and compiles lazily: an experiment that only needs the gram artifact
//! never pays for the others.

mod registry;

pub use registry::{ArtifactInfo, ArtifactRegistry, Executable};

use anyhow::{Context, Result};

use crate::linalg::Mat;

/// Shared PJRT CPU client (one per process).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile HLO text from `path` into an executable.
    pub fn compile_hlo_file(&self, path: &str) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {path}"))
    }
}

/// Convert a row-major f64 [`Mat`] into an f32 PJRT literal of shape
/// (rows, cols).
pub fn mat_to_literal(m: &Mat) -> Result<xla::Literal> {
    let data: Vec<f32> = m.data().iter().map(|&v| v as f32).collect();
    let lit = xla::Literal::vec1(&data);
    Ok(lit.reshape(&[m.rows() as i64, m.cols() as i64])?)
}

/// Convert a f64 slice into a rank-1 f32 literal.
pub fn vec_to_literal(v: &[f64]) -> Result<xla::Literal> {
    let data: Vec<f32> = v.iter().map(|&x| x as f32).collect();
    Ok(xla::Literal::vec1(&data))
}

/// Read an f32 literal of shape (rows, cols) back into a [`Mat`].
pub fn literal_to_mat(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Mat> {
    let v: Vec<f32> = lit.to_vec()?;
    anyhow::ensure!(
        v.len() == rows * cols,
        "literal has {} elements, want {}x{}",
        v.len(),
        rows,
        cols
    );
    Ok(Mat::from_vec(rows, cols, v.into_iter().map(|x| x as f64).collect()))
}

/// Read an f32 literal into a f64 vector.
pub fn literal_to_vec(lit: &xla::Literal) -> Result<Vec<f64>> {
    let v: Vec<f32> = lit.to_vec()?;
    Ok(v.into_iter().map(|x| x as f64).collect())
}

/// Read an i32 literal into usize labels.
pub fn literal_to_indices(lit: &xla::Literal) -> Result<Vec<usize>> {
    let v: Vec<i32> = lit.to_vec()?;
    Ok(v.into_iter().map(|x| x.max(0) as usize).collect())
}
