//! Process-wide failpoint injection, strictly opt-in — the chaos-side
//! twin of [`crate::obs`].
//!
//! A **failpoint site** is a named hook compiled into an IO or
//! availability edge of the system (`model_io.write`, `serve.load`,
//! `http.accept`, …). Sites do nothing until armed: with no
//! configuration installed, [`trip`] is a single relaxed atomic load —
//! the same zero-overhead contract as `RKC_OBS`, and the
//! experiment-golden byte-identity test holds with the fault layer
//! compiled in.
//!
//! # Configuration
//!
//! Arm sites via the `RKC_FAULTS` environment variable (read once by
//! [`init_from_env`], which the CLI calls at startup) or at runtime
//! with [`configure`] / [`clear`]:
//!
//! ```text
//! RKC_FAULTS="model_io.write=io_error:0.3,serve.load=delay_ms:50"
//! ```
//!
//! Grammar: comma-separated `site=action` entries, where `action` is
//!
//! - `io_error:<prob>` — the site returns a typed
//!   [`RkcError::Transient`] with probability `prob` ∈ \[0, 1\]
//! - `delay_ms:<ms>[:<prob>]` — the site sleeps `ms` milliseconds with
//!   probability `prob` (default 1)
//!
//! Unknown site names are accepted (a spec can name sites a given build
//! doesn't compile in); malformed actions are typed errors.
//!
//! # Reproducible chaos
//!
//! Each armed site owns a dedicated [`Pcg64`] stream seeded from the
//! FNV-1a hash of the *full spec text* and the site name, so the k-th
//! trip decision at a site is a pure function of (spec, site, k) — two
//! runs with the same spec and the same per-site trip order inject
//! identical fault sequences, regardless of what other sites do.
//!
//! # Observability
//!
//! Every fired fault bumps `rkc_fault_trips_total{site,action}` in the
//! [`crate::obs`] registry, so `/metrics` shows exactly which faults a
//! chaos run injected (the CI chaos smoke asserts on it).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock, RwLock};
use std::time::Duration;

use crate::error::{Result, RkcError};
use crate::rng::{Pcg64, Rng};

// ---------------------------------------------------------------------------
// site names
//
// One constant per compiled-in hook, so call sites and specs share the
// exact spelling. Arbitrary names are still accepted in specs (and by
// `trip` in tests); these are the ones wired into the crate.

/// `model_io::save_model`, before the temp-file write.
pub const MODEL_IO_WRITE: &str = "model_io.write";
/// `model_io::save_model`, before the temp-file `sync_all`.
pub const MODEL_IO_FSYNC: &str = "model_io.fsync";
/// `StreamClusterer` checkpoint write, before the temp-file write.
pub const STREAM_CHECKPOINT: &str = "stream.checkpoint";
/// `ModelRegistry::load`, before reading the `.rkc` file (inside the
/// transient-retry loop, so `io_error` here exercises the backoff).
pub const SERVE_LOAD: &str = "serve.load";
/// HTTP front-end accept loop, after `accept()` returns a connection
/// (an `io_error` trip drops the connection unserved — a flaky NIC).
pub const HTTP_ACCEPT: &str = "http.accept";

// ---------------------------------------------------------------------------
// global armed switch + site table

/// `true` iff at least one site is armed. The only state `trip` reads
/// on the disabled path.
static ARMED: AtomicBool = AtomicBool::new(false);

/// What an armed site does when its probability draw fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultAction {
    /// Return [`RkcError::Transient`] with probability `prob`.
    IoError { prob: f64 },
    /// Sleep `ms` milliseconds with probability `prob`, then proceed.
    DelayMs { ms: u64, prob: f64 },
}

impl FaultAction {
    fn prob(&self) -> f64 {
        match *self {
            FaultAction::IoError { prob } | FaultAction::DelayMs { prob, .. } => prob,
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            FaultAction::IoError { .. } => "io_error",
            FaultAction::DelayMs { .. } => "delay_ms",
        }
    }
}

struct Site {
    action: FaultAction,
    /// Per-site deterministic decision stream; trips at one site are
    /// serialized on this lock (sites sit on slow IO edges — never a
    /// hot path).
    rng: Mutex<Pcg64>,
}

fn sites() -> &'static RwLock<BTreeMap<String, Site>> {
    static SITES: OnceLock<RwLock<BTreeMap<String, Site>>> = OnceLock::new();
    SITES.get_or_init(|| RwLock::new(BTreeMap::new()))
}

/// Whether any failpoint is currently armed.
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Parse and install a fault spec, replacing any previous
/// configuration. An empty spec (or [`clear`]) disarms everything.
pub fn configure(spec: &str) -> Result<()> {
    let parsed = parse_spec(spec)?;
    let mut table = sites().write().unwrap_or_else(|p| p.into_inner());
    table.clear();
    let spec_seed = crate::model_io::checksum(spec.as_bytes());
    for (name, action) in parsed {
        let site_seed = crate::model_io::checksum(name.as_bytes());
        table.insert(
            name,
            Site { action, rng: Mutex::new(Pcg64::seed_stream(spec_seed, site_seed)) },
        );
    }
    ARMED.store(!table.is_empty(), Ordering::Relaxed);
    Ok(())
}

/// Disarm every failpoint.
pub fn clear() {
    let mut table = sites().write().unwrap_or_else(|p| p.into_inner());
    table.clear();
    ARMED.store(false, Ordering::Relaxed);
}

/// Apply the `RKC_FAULTS` environment variable, if set. A malformed
/// spec is a typed error — the CLI reports it and exits rather than
/// running a chaos experiment with silently dropped faults.
pub fn init_from_env() -> Result<()> {
    match std::env::var("RKC_FAULTS") {
        Ok(v) if !v.trim().is_empty() => configure(&v),
        // set-but-undecodable is malformed, not unset — swallowing it
        // would be exactly the silent degrade-to-clean-run this
        // function exists to prevent
        Err(std::env::VarError::NotUnicode(_)) => Err(RkcError::invalid_config(
            "RKC_FAULTS is set but is not valid UTF-8".to_string(),
        )),
        _ => Ok(()),
    }
}

fn parse_spec(spec: &str) -> Result<Vec<(String, FaultAction)>> {
    let mut out = Vec::new();
    for entry in spec.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (site, action) = entry.split_once('=').ok_or_else(|| {
            RkcError::invalid_config(format!(
                "fault spec entry '{entry}' is not site=action (e.g. model_io.write=io_error:0.3)"
            ))
        })?;
        let site = site.trim();
        if site.is_empty() {
            return Err(RkcError::invalid_config(format!(
                "fault spec entry '{entry}' has an empty site name"
            )));
        }
        if out.iter().any(|(s, _)| s == site) {
            return Err(RkcError::invalid_config(format!(
                "fault spec arms site '{site}' twice"
            )));
        }
        out.push((site.to_string(), parse_action(action.trim())?));
    }
    Ok(out)
}

fn parse_action(action: &str) -> Result<FaultAction> {
    let mut parts = action.split(':');
    let kind = parts.next().unwrap_or("");
    match kind {
        "io_error" => {
            let prob = parse_prob(parts.next(), action)?;
            if parts.next().is_some() {
                return Err(bad_action(action));
            }
            Ok(FaultAction::IoError { prob })
        }
        "delay_ms" => {
            let ms: u64 = parts
                .next()
                .ok_or_else(|| bad_action(action))?
                .parse()
                .map_err(|_| bad_action(action))?;
            let prob = match parts.next() {
                Some(p) => parse_prob(Some(p), action)?,
                None => 1.0,
            };
            if parts.next().is_some() {
                return Err(bad_action(action));
            }
            Ok(FaultAction::DelayMs { ms, prob })
        }
        _ => Err(bad_action(action)),
    }
}

fn parse_prob(p: Option<&str>, action: &str) -> Result<f64> {
    let prob: f64 = p
        .ok_or_else(|| bad_action(action))?
        .parse()
        .map_err(|_| bad_action(action))?;
    if !(0.0..=1.0).contains(&prob) {
        return Err(RkcError::invalid_config(format!(
            "fault action '{action}': probability {prob} is outside [0, 1]"
        )));
    }
    Ok(prob)
}

fn bad_action(action: &str) -> RkcError {
    RkcError::invalid_config(format!(
        "fault action '{action}' is not io_error:<prob> or delay_ms:<ms>[:<prob>]"
    ))
}

// ---------------------------------------------------------------------------
// the injection point

/// Evaluate the failpoint `site`. Disarmed (the normal case): one
/// relaxed atomic load, `Ok(())`. Armed: draw from the site's
/// deterministic stream; a firing `io_error` returns
/// [`RkcError::Transient`], a firing `delay_ms` sleeps and returns
/// `Ok(())`. Either firing bumps `rkc_fault_trips_total{site,action}`.
pub fn trip(site: &str) -> Result<()> {
    if !ARMED.load(Ordering::Relaxed) {
        return Ok(());
    }
    trip_armed(site)
}

#[cold]
fn trip_armed(site: &str) -> Result<()> {
    let table = sites().read().unwrap_or_else(|p| p.into_inner());
    let Some(s) = table.get(site) else { return Ok(()) };
    let action = s.action;
    let fire = {
        let mut rng = s.rng.lock().unwrap_or_else(|p| p.into_inner());
        rng.next_f64() < action.prob()
    };
    drop(table);
    if !fire {
        return Ok(());
    }
    crate::obs::registry()
        .counter(
            "rkc_fault_trips_total",
            "Injected faults fired at failpoint sites (chaos testing only).",
            &[("site", site), ("action", action.kind())],
        )
        .inc();
    match action {
        FaultAction::IoError { .. } => Err(RkcError::transient(format!(
            "injected fault at failpoint '{site}'"
        ))),
        FaultAction::DelayMs { ms, .. } => {
            std::thread::sleep(Duration::from_millis(ms));
            Ok(())
        }
    }
}

/// Unit tests that arm/clear the process-global table serialize on this
/// lock (same pattern as `obs::test_guard`). Public to the crate so the
/// serve/stream/model_io unit tests that exercise injected faults can
/// share it.
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static GUARD: std::sync::Mutex<()> = std::sync::Mutex::new(());
    GUARD.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_site_is_inert() {
        let _g = test_guard();
        clear();
        assert!(!armed());
        for _ in 0..100 {
            assert!(trip(MODEL_IO_WRITE).is_ok());
        }
    }

    #[test]
    fn spec_parses_both_actions_and_rejects_garbage() {
        let ok = parse_spec("model_io.write=io_error:0.3, serve.load=delay_ms:50:0.5").unwrap();
        assert_eq!(ok.len(), 2);
        assert_eq!(ok[0].1, FaultAction::IoError { prob: 0.3 });
        assert_eq!(ok[1].1, FaultAction::DelayMs { ms: 50, prob: 0.5 });
        // bare delay defaults to always firing
        assert_eq!(
            parse_spec("a=delay_ms:7").unwrap()[0].1,
            FaultAction::DelayMs { ms: 7, prob: 1.0 }
        );
        for bad in [
            "no_equals",
            "=io_error:0.5",
            "s=io_error",
            "s=io_error:2.0",
            "s=io_error:0.1:9",
            "s=delay_ms",
            "s=delay_ms:abc",
            "s=warp_drive:1",
            "s=io_error:0.1,s=io_error:0.2",
        ] {
            assert!(parse_spec(bad).is_err(), "spec '{bad}' must be rejected");
        }
        // empty entries are tolerated (trailing commas)
        assert!(parse_spec("a=io_error:1.0,,").unwrap().len() == 1);
    }

    #[test]
    fn certain_io_error_always_trips_with_a_transient_error() {
        let _g = test_guard();
        configure("boom=io_error:1.0").unwrap();
        assert!(armed());
        for _ in 0..5 {
            let err = trip("boom").unwrap_err();
            assert!(
                matches!(err, RkcError::Transient { .. }),
                "fault trips must be typed Transient: {err}"
            );
        }
        // unarmed sites in an armed process still pass
        assert!(trip(MODEL_IO_FSYNC).is_ok());
        clear();
    }

    #[test]
    fn trip_sequence_is_deterministic_per_spec() {
        let _g = test_guard();
        let spec = "flaky=io_error:0.5";
        let sample = |spec: &str| -> Vec<bool> {
            configure(spec).unwrap();
            let s = (0..64).map(|_| trip("flaky").is_err()).collect();
            clear();
            s
        };
        let a = sample(spec);
        let b = sample(spec);
        assert_eq!(a, b, "same spec must inject the same fault sequence");
        assert!(a.iter().any(|&t| t) && a.iter().any(|&t| !t), "p=0.5 must mix outcomes");
        // a different spec text reseeds the stream
        let c = sample("flaky=io_error:0.5,other=delay_ms:1:0.0");
        assert_ne!(a, c, "spec text must seed the decision stream");
    }

    #[test]
    fn zero_probability_never_fires() {
        let _g = test_guard();
        configure("quiet=io_error:0.0").unwrap();
        for _ in 0..64 {
            assert!(trip("quiet").is_ok());
        }
        clear();
    }

    #[test]
    fn delay_action_sleeps_then_proceeds() {
        let _g = test_guard();
        configure("slow=delay_ms:20").unwrap();
        let t0 = std::time::Instant::now();
        assert!(trip("slow").is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(15), "delay_ms must actually sleep");
        clear();
    }

    #[test]
    fn env_init_accepts_unset_and_rejects_malformed() {
        let _g = test_guard();
        // unset: no-op (the test runner may not have RKC_FAULTS)
        std::env::remove_var("RKC_FAULTS");
        init_from_env().unwrap();
        assert!(!armed());
        std::env::set_var("RKC_FAULTS", "a=io_error:nope");
        assert!(init_from_env().is_err());
        std::env::remove_var("RKC_FAULTS");
        clear();
    }
}
