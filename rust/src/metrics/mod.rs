//! The byte-accounting memory model (the paper's headline axis — §1:
//! "around 10 times lower memory") and a table reporter for the
//! experiment harness.
//!
//! Wall-clock timing moved to [`crate::obs`] (the registry + span ring
//! are the one timing system); `Stopwatch`/`ScopedTimer` are re-exported
//! here for compatibility. The table/CSV reporter stays — it renders
//! results, it doesn't measure.

mod memory;
mod report;

pub use crate::obs::{ScopedTimer, Stopwatch};
pub use memory::{MemoryModel, MethodMemory};
pub use report::{Table, write_csv};
