//! Observability: wall-clock timers, the byte-accounting memory model
//! (the paper's headline axis — §1: "around 10 times lower memory"), and
//! a table reporter for the experiment harness.

mod memory;
mod report;
mod timer;

pub use memory::{MemoryModel, MethodMemory};
pub use report::{Table, write_csv};
pub use timer::{ScopedTimer, Stopwatch};
