//! Wall-clock timing helpers for the pipeline's per-stage metrics.

use std::time::{Duration, Instant};

/// Accumulating stopwatch: start/stop across many block iterations.
#[derive(Debug)]
pub struct Stopwatch {
    total: Duration,
    started: Option<Instant>,
    laps: usize,
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { total: Duration::ZERO, started: None, laps: 0 }
    }

    pub fn start(&mut self) {
        debug_assert!(self.started.is_none(), "stopwatch already running");
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.total += t0.elapsed();
            self.laps += 1;
        }
    }

    pub fn elapsed(&self) -> Duration {
        match self.started {
            Some(t0) => self.total + t0.elapsed(),
            None => self.total,
        }
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn laps(&self) -> usize {
        self.laps
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

/// RAII timer: adds its lifetime to a cell on drop.
pub struct ScopedTimer<'a> {
    target: &'a mut Duration,
    start: Instant,
}

impl<'a> ScopedTimer<'a> {
    pub fn new(target: &'a mut Duration) -> Self {
        ScopedTimer { target, start: Instant::now() }
    }
}

impl Drop for ScopedTimer<'_> {
    fn drop(&mut self) {
        *self.target += self.start.elapsed();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates_laps() {
        let mut sw = Stopwatch::new();
        for _ in 0..3 {
            sw.start();
            std::thread::sleep(Duration::from_millis(2));
            sw.stop();
        }
        assert_eq!(sw.laps(), 3);
        assert!(sw.secs() >= 0.006);
        assert!(sw.secs() < 1.0);
    }

    #[test]
    fn scoped_timer_adds_on_drop() {
        let mut total = Duration::ZERO;
        {
            let _t = ScopedTimer::new(&mut total);
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(total >= Duration::from_millis(2));
    }
}
