//! Byte-accounting memory model.
//!
//! The paper's claim is about *memory*, not time: the one-pass method
//! needs O(r'n) while Nyström needs O(mn) with m ≈ 7–8·r' for equal
//! accuracy, and exact/full methods need O(n²). Rather than trusting an
//! allocator high-water mark (noisy, allocator-dependent), we account
//! the dominant data structures of each method explicitly — the same
//! methodology the paper's complexity table uses — and verify the model
//! against actual allocation sizes in tests.

const F64: usize = std::mem::size_of::<f64>();

/// Peak working-set model of one clustering method run.
#[derive(Clone, Debug, PartialEq)]
pub struct MethodMemory {
    pub method: String,
    /// bytes persistent across the whole pass (sketch W, signs d, …)
    pub persistent: usize,
    /// bytes of transient per-block buffers (kernel block, FWHT buffer)
    pub transient: usize,
    /// bytes of the recovery-phase temporaries (Q, Ω restricted, …)
    pub recovery: usize,
}

impl MethodMemory {
    /// Peak = persistent + max(streaming transient, recovery phase):
    /// the block buffers are freed before recovery allocates.
    pub fn peak(&self) -> usize {
        self.persistent + self.transient.max(self.recovery)
    }

    pub fn peak_mib(&self) -> f64 {
        self.peak() as f64 / (1024.0 * 1024.0)
    }
}

/// Builders for each method's memory model. All counts are f64 words of
/// the *minimum faithful implementation* (what our coordinator actually
/// allocates), excluding the p × n input data shared by every method.
pub struct MemoryModel;

impl MemoryModel {
    /// Ours (Alg. 1): sketch W (n × r'), signs d (n), per-block kernel
    /// buffer (n_pad × b) + FWHT workspace (n_pad × b); recovery Q (n×r),
    /// QᵀΩ + QᵀW (2 · r·r'), B/V (r²), Y (r × n).
    pub fn one_pass(n: usize, n_pad: usize, rp: usize, r: usize, batch: usize) -> MethodMemory {
        MethodMemory {
            method: "one_pass".into(),
            persistent: F64 * (n * rp + n_pad),
            transient: F64 * (2 * n_pad * batch),
            recovery: F64 * (n * r + 2 * r * rp + 2 * r * r + r * n),
        }
    }

    /// Nyström: sampled columns C (n × m) held for the whole run (they
    /// ARE the sketch), inner W_m (m × m) + its eigendecomposition
    /// (2 m²), embedding Y (r × n).
    pub fn nystrom(n: usize, m: usize, r: usize) -> MethodMemory {
        MethodMemory {
            method: format!("nystrom(m={m})"),
            persistent: F64 * (n * m),
            transient: 0,
            recovery: F64 * (3 * m * m + r * n),
        }
    }

    /// Exact streaming top-r (subspace iteration): basis V (n × r), the
    /// product KV (n × r), per-block buffer (n_pad × b).
    pub fn exact_streaming(n: usize, n_pad: usize, r: usize, batch: usize) -> MethodMemory {
        MethodMemory {
            method: "exact_streaming".into(),
            persistent: F64 * (2 * n * r),
            transient: F64 * (n_pad * batch),
            recovery: F64 * (2 * r * r + r * n),
        }
    }

    /// Exact dense EVD of the full kernel (what the paper's "exact
    /// decomposition" costs if done directly): K (n²) + eigenvectors (n²).
    pub fn exact_dense(n: usize) -> MethodMemory {
        MethodMemory {
            method: "exact_dense".into(),
            persistent: F64 * (2 * n * n),
            transient: 0,
            recovery: 0,
        }
    }

    /// Full kernel K-means: K (n²) + per-iteration cross sums (n × K).
    pub fn full_kernel_kmeans(n: usize, k: usize) -> MethodMemory {
        MethodMemory {
            method: "full_kernel_kmeans".into(),
            persistent: F64 * (n * n),
            transient: F64 * (n * k),
            recovery: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_pass_is_linear_in_n() {
        let a = MemoryModel::one_pass(1000, 1024, 7, 2, 256);
        let b = MemoryModel::one_pass(2000, 2048, 7, 2, 256);
        // persistent part scales ~linearly
        assert!(b.persistent < 2 * a.persistent + 4096 * F64);
        assert!(b.persistent > (2 * a.persistent) / 2);
    }

    #[test]
    fn paper_headline_memory_ratio_holds() {
        // Fig. 3 setting: n = 2310, r' = 7, Nyström needs m ≈ 50 for the
        // same error ⇒ memory ratio ≈ m / r' ≈ 7× and ≥ 10× at m = 100
        let ours = MemoryModel::one_pass(2310, 4096, 7, 2, 256);
        let nys50 = MemoryModel::nystrom(2310, 50, 2);
        let nys100 = MemoryModel::nystrom(2310, 100, 2);
        // compare the persistent (streaming-independent) footprints: the
        // sketch-vs-columns comparison the paper makes
        let ratio50 = nys50.persistent as f64 / ours.persistent as f64;
        let ratio100 = nys100.persistent as f64 / ours.persistent as f64;
        assert!(ratio50 > 4.0, "ratio50 = {ratio50}");
        assert!(ratio100 > 9.0, "ratio100 = {ratio100}");
    }

    #[test]
    fn quadratic_methods_dwarf_streaming_methods() {
        let n = 4000;
        let ours = MemoryModel::one_pass(n, 4096, 12, 2, 256);
        let dense = MemoryModel::exact_dense(n);
        let full = MemoryModel::full_kernel_kmeans(n, 2);
        // peak includes the transient block buffer; persistent state is
        // the paper's sketch-vs-matrix comparison
        assert!(dense.peak() > 10 * ours.peak());
        assert!(full.peak() > 5 * ours.peak());
        assert!(dense.persistent > 500 * ours.persistent);
    }

    #[test]
    fn peak_takes_max_of_phases() {
        let m = MethodMemory {
            method: "x".into(),
            persistent: 100,
            transient: 50,
            recovery: 80,
        };
        assert_eq!(m.peak(), 180);
    }

    #[test]
    fn model_matches_actual_sketch_allocation() {
        // the model's W + d bytes must equal OnePassSketch::sketch_bytes
        use crate::rng::Pcg64;
        use crate::sketch::Srht;
        let (n, n_pad, rp) = (100usize, 128usize, 7usize);
        let mut rng = Pcg64::seed(1);
        let srht = Srht::draw(&mut rng, n_pad, rp);
        let sk = crate::lowrank::OnePassSketch::new(srht, n);
        let model = MemoryModel::one_pass(n, n_pad, rp, 2, 16);
        assert_eq!(model.persistent, sk.sketch_bytes());
    }
}
