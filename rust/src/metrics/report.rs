//! Experiment table reporter: aligned text/markdown rendering and CSV
//! dumps, used by every bench and the CLI to print the paper's tables.

use std::fmt::Write as _;

/// A simple column-aligned table.
#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as an aligned markdown table (what EXPERIMENTS.md embeds).
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (j, c) in row.iter().enumerate() {
                widths[j] = widths[j].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "### {}", self.title);
        }
        let hdr: Vec<String> = (0..ncol)
            .map(|j| format!("{:w$}", self.headers[j], w = widths[j]))
            .collect();
        let _ = writeln!(out, "| {} |", hdr.join(" | "));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        let _ = writeln!(out, "| {} |", sep.join(" | "));
        for row in &self.rows {
            let cells: Vec<String> =
                (0..ncol).map(|j| format!("{:w$}", row[j], w = widths[j])).collect();
            let _ = writeln!(out, "| {} |", cells.join(" | "));
        }
        out
    }

    /// CSV rendering (headers + rows).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join(","));
        }
        out
    }
}

/// Write rows of f64 series to a CSV file (figure data dumps).
pub fn write_csv(path: &str, headers: &[&str], rows: &[Vec<f64>]) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", headers.join(","))?;
    for row in rows {
        let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        writeln!(f, "{}", cells.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["method", "err"]);
        t.row(vec!["ours".into(), "0.40".into()]);
        t.row(vec!["nystrom_m100".into(), "0.44".into()]);
        let s = t.render();
        assert!(s.contains("### Demo"));
        assert!(s.contains("| ours         | 0.40 |"));
        let lines: Vec<&str> = s.lines().collect();
        // all table lines same width
        let widths: Vec<usize> = lines[1..].iter().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn write_csv_creates_file() {
        let dir = std::env::temp_dir().join("rkc_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.csv");
        write_csv(p.to_str().unwrap(), &["m", "err"], &[vec![10.0, 0.5], vec![20.0, 0.25]])
            .unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s, "m,err\n10,0.5\n20,0.25\n");
    }
}
